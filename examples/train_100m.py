"""End-to-end driver: train a ~100M-parameter model for a few hundred steps.

A scaled-down granite-style dense transformer (the paper's training-side
substrate exercised for real): deterministic synthetic corpus, AdamW with
cosine schedule, gradient accumulation, periodic async checkpoints, fault
tolerance on, straggler detector armed.

    PYTHONPATH=src python examples/train_100m.py --steps 300
"""

import argparse
import dataclasses
import time

import jax

from repro.configs import get_config
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.models import ModelConfig
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, Trainer


def model_100m() -> ModelConfig:
    # ~100M params: 12L x 512 x 8H, ff 2048, 32k vocab
    return dataclasses.replace(
        get_config("granite_8b"),
        name="granite_100m",
        n_layers=12,
        d_model=512,
        n_heads=8,
        n_kv_heads=4,
        d_ff=2048,
        vocab=32_000,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_100m")
    args = ap.parse_args()

    cfg = model_100m()
    from repro.configs import param_count

    print(f"model: {cfg.name}, {param_count(cfg)/1e6:.0f}M params")
    mesh = make_host_mesh()
    trainer = Trainer(
        model_cfg=cfg,
        opt_cfg=AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
        train_cfg=TrainConfig(
            steps=args.steps,
            microbatches=2,
            checkpoint_every=100,
            checkpoint_dir=args.ckpt,
            attn_impl="chunked",
            remat="dots",
            log_every=20,
        ),
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch),
        mesh=mesh,
        straggler_callback=lambda s, dt: print(f"  [straggler] step {s}: {dt:.2f}s"),
    )
    t0 = time.time()
    out = trainer.run()
    dt = time.time() - t0
    losses = out["losses"]
    tok_s = args.steps * args.batch * args.seq / dt
    print(f"done in {dt:.0f}s ({tok_s:.0f} tok/s on {jax.default_backend()})")
    for i in range(0, len(losses), max(1, len(losses) // 10)):
        print(f"  step {i:4d}  loss {losses[i]:.3f}")
    print(f"  final loss {losses[-1]:.3f} (started {losses[0]:.3f})")


if __name__ == "__main__":
    main()
