"""Serve a small model with batched requests (continuous batching).

    PYTHONPATH=src python examples/serve_batched.py
"""

import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.models import Model
from repro.runtime import ServeConfig, Server
from repro.runtime.serving import Request


def main() -> None:
    cfg = reduced_config("gemma3_1b")
    model = Model(cfg, attn_impl="xla")
    params, _ = model.init(jax.random.PRNGKey(0))
    server = Server(
        cfg,
        ServeConfig(batch_slots=4, max_len=64, max_new_tokens=12, eos=-1, temperature=0.0),
        params,
    )
    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, cfg.vocab, size=rng.integers(4, 12)).astype(np.int32))
        for i in range(10)
    ]
    t0 = time.time()
    done = server.serve(requests)
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, 4 slots)")
    for c in done[:4]:
        print(f"  req {c.uid}: {len(c.tokens)} tokens, {c.latency_s*1e3:.0f} ms -> {c.tokens[:6]}...")


if __name__ == "__main__":
    main()
