"""End-to-end demo: tune a model config's real kernel corpus, then serve it.

Stage 1 — **tune**: the model's Pallas kernels (extracted as RegDem profiles
by :mod:`repro.data.corpus`) are packed into one container and pushed
through :meth:`repro.core.translator.TranslationService.tune` — the full
predictor-guided search — backed by a persistent
:class:`~repro.core.artifacts.ArtifactStore`.  Run the script twice with the
same ``--store`` directory and the second tune is served **warm**: every
kernel is a disk cache hit, zero pipeline passes run, and the emitted
container bytes are identical.

Stage 2 — **serve**: the (reduced) model itself serves a batch of requests
with continuous batching, exactly as before.

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --store /tmp/regdem_cache
    PYTHONPATH=src python examples/serve_batched.py --model zamba2_2_7b
"""

import argparse
import tempfile
import time

import jax
import numpy as np

from repro.configs import reduced_config
from repro.core.artifacts import ArtifactStore
from repro.core.search import SearchConfig
from repro.core.translator import TranslationService
from repro.data.corpus import corpus_container, model_corpus_names
from repro.models import Model
from repro.runtime import ServeConfig, Server
from repro.runtime.serving import Request

#: small-but-real search: one cliff target per arch, top beam survivors only
TUNE = SearchConfig(max_targets=1, beam_width=2, top_k=1)


def tune_corpus(model: str, store_dir: str) -> None:
    """Tune the model's extracted kernel corpus against the artifact store."""
    names = model_corpus_names(model)
    data = corpus_container(model)
    svc = TranslationService(store=ArtifactStore(store_dir))
    t0 = time.time()
    _, report = svc.tune(data, TUNE)
    dt = time.time() - t0
    warm = report.cache_hits == len(names)
    print(
        f"tuned {len(names)} corpus kernels for {model} in {dt:.1f}s "
        f"({'WARM: all ' + str(report.cache_hits) + ' from store, zero passes' if warm else f'{report.cache_misses} searched, {report.cache_hits} cached'})"
    )
    for r in report.reports:
        sr = r.search
        line = f"  {r.kernel_name}: {r.baseline_regs} regs -> chose {r.chosen}"
        if sr is not None:
            line += f" ({sr.speedup:.3f}x vs nvcc, {sr.explored} variants explored)"
        print(line)

    # second tune of identical content: served entirely from the warm
    # TranslationCache/ArtifactStore — the serving-path invariant
    again, rep2 = svc.tune(data, TUNE)
    assert rep2.cache_hits == len(names) and rep2.cache_misses == 0
    first, _ = TranslationService(store=ArtifactStore(store_dir)).tune(data, TUNE)
    assert first == again, "warm restart must be byte-identical"
    print(f"  re-tune: {rep2.cache_hits}/{len(names)} warm hits, byte-identical")


def serve(model: str) -> None:
    cfg = reduced_config(model)
    m = Model(cfg, attn_impl="xla")
    params, _ = m.init(jax.random.PRNGKey(0))
    server = Server(
        cfg,
        ServeConfig(batch_slots=4, max_len=64, max_new_tokens=12, eos=-1, temperature=0.0),
        params,
    )
    rng = np.random.default_rng(0)
    requests = [
        Request(uid=i, prompt=rng.integers(1, cfg.vocab, size=rng.integers(4, 12)).astype(np.int32))
        for i in range(10)
    ]
    t0 = time.time()
    done = server.serve(requests)
    dt = time.time() - t0
    total_tokens = sum(len(c.tokens) for c in done)
    print(f"served {len(done)} requests / {total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens/dt:.1f} tok/s, 4 slots)")
    for c in done[:4]:
        print(f"  req {c.uid}: {len(c.tokens)} tokens, {c.latency_s*1e3:.0f} ms -> {c.tokens[:6]}...")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--model", default="gemma3_1b",
                    help="model config id (default gemma3_1b)")
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="ArtifactStore directory; reuse it across runs for a "
                         "warm start (default: a fresh temp dir)")
    args = ap.parse_args()
    store_dir = args.store or tempfile.mkdtemp(prefix="regdem_store_")
    print(f"artifact store: {store_dir}")
    tune_corpus(args.model, store_dir)
    serve(args.model)


if __name__ == "__main__":
    main()
