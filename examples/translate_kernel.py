"""pyReDe walkthrough: translate a register-pressure-bound GPU kernel.

Shows the paper's full pipeline on one benchmark: occupancy diagnosis,
automatic spill-target choice, demotion, and predictor-based variant
selection — then verifies the translated binary on the ISA interpreter and
grades it on the timing simulator.

    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd

The pipeline is binary->binary: the kernel is serialized to pseudo-cubin
container bytes, translated bytes-in/bytes-out, and disassembled again.
``--overlay`` prints the chosen variant as SASSOverlay-style annotated
disassembly (stall / yield / barrier columns).
"""

import argparse

from repro.binary import dumps, loads, overlay
from repro.core import occupancy_of, translate_binary
from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.regdem import auto_targets
from repro.core.simulator import simulate, speedup


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="cfd", choices=sorted(PAPER_BENCHMARKS))
    ap.add_argument("--overlay", action="store_true",
                    help="print annotated disassembly of the chosen variant")
    args = ap.parse_args()

    k = paper_kernel(args.kernel)
    occ = occupancy_of(k)
    print(f"kernel {k.name}: {k.reg_count} regs, {k.threads_per_block} thr/block, "
          f"occupancy {occ.occupancy:.3f} (limited by {occ.limiter})")
    print(f"occupancy-cliff spill targets: {auto_targets(k)}")

    # the shipping path: container bytes in, container bytes out
    blob = dumps(k)
    out, report = translate_binary(blob)
    chosen = loads(out)
    print(f"considered {len(report.considered)} variants; predictor chose: {report.chosen}")
    print(f"binary->binary: {len(blob)}B container in, {len(out)}B container out")
    if report.chosen != "nvcc":
        occ2 = occupancy_of(chosen)
        print(f"  regs {k.reg_count} -> {chosen.reg_count}, "
              f"occupancy {occ.occupancy:.3f} -> {occ2.occupancy:.3f}, "
              f"+{chosen.demoted_size}B shared for demoted registers")
        assert equivalent(k, chosen), "translation must preserve semantics"
        s = speedup(simulate(k), simulate(chosen))
        print(f"  simulated speedup over baseline: {s:.3f}x")
    if args.overlay:
        print(overlay(chosen))
    print("OK")


if __name__ == "__main__":
    main()
