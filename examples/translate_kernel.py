"""pyReDe walkthrough: translate a register-pressure-bound GPU kernel.

Shows the paper's full pipeline on one benchmark: occupancy diagnosis,
automatic spill-target choice, demotion, and predictor-based variant
selection — then verifies the translated binary on the ISA interpreter and
grades it on the timing simulator.

    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd
"""

import argparse

from repro.core import occupancy_of, translate
from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.regdem import auto_targets
from repro.core.simulator import simulate, speedup


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="cfd", choices=sorted(PAPER_BENCHMARKS))
    args = ap.parse_args()

    k = paper_kernel(args.kernel)
    occ = occupancy_of(k)
    print(f"kernel {k.name}: {k.reg_count} regs, {k.threads_per_block} thr/block, "
          f"occupancy {occ.occupancy:.3f} (limited by {occ.limiter})")
    print(f"occupancy-cliff spill targets: {auto_targets(k)}")

    report = translate(k)
    print(f"considered {len(report.considered)} variants; predictor chose: {report.chosen}")
    if report.chosen != "nvcc":
        chosen = report.chosen_kernel
        occ2 = occupancy_of(chosen)
        print(f"  regs {k.reg_count} -> {chosen.reg_count}, "
              f"occupancy {occ.occupancy:.3f} -> {occ2.occupancy:.3f}, "
              f"+{chosen.demoted_size}B shared for demoted registers")
        assert equivalent(k, chosen), "translation must preserve semantics"
        s = speedup(simulate(k), simulate(chosen))
        print(f"  simulated speedup over baseline: {s:.3f}x")
    print("OK")


if __name__ == "__main__":
    main()
