"""pyReDe walkthrough: translate a register-pressure-bound GPU kernel.

Shows the paper's full pipeline on one benchmark: occupancy diagnosis,
automatic spill-target choice, demotion, and predictor-based variant
selection — then verifies the translated binary on the ISA interpreter and
grades it on the timing simulator.

    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd

The pipeline is binary->binary: the kernel is serialized to pseudo-cubin
container bytes, translated bytes-in/bytes-out, and disassembled again.
``--overlay`` prints the chosen variant as SASSOverlay-style annotated
disassembly (stall / yield / barrier columns).

``--batch`` exercises the multi-kernel service instead: it packs several
benchmark kernels (plus a duplicate) into ONE v2 container, translates it in
one call, and prints per-kernel outcomes and the translation-cache hit rate:

    PYTHONPATH=src python examples/translate_kernel.py --batch cfd,nn,cfd

``--tune`` replaces the fixed variant set with the predictor-guided
autotuning search (every candidate strategy x the full spill-target ladder x
option knobs x every registered architecture), fanning out over ``--workers``
processes; the per-kernel search report comes back as a ``.note`` section of
the emitted container:

    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd --tune
    PYTHONPATH=src python examples/translate_kernel.py --batch cfd,nn,cfd --tune --workers 4

``--profile`` grades the chosen variant with stall attribution turned on and
prints the profiled overlay — every instruction line gains an attributed
stall-cycle column (cycles, share of total, dominant reason).  With
``--tune`` the search itself runs profiled, so every confirmed variant's
stall profile lands in the search report.  ``--trace out.json`` records
telemetry spans for the whole walkthrough and writes a Chrome trace
(chrome://tracing / Perfetto); ``--trace out.jsonl`` writes the JSONL event
log instead:

    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd --profile
    PYTHONPATH=src python examples/translate_kernel.py --kernel cfd --tune --trace trace.json
"""

import argparse
import json
import sys

from repro import obs
from repro.binary import dumps, kernel_names, loads, loads_many, overlay, read_notes
from repro.core import SearchConfig, TranslationService, occupancy_of, translate_binary
from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.regdem import auto_targets
from repro.core.simulator import simulate, speedup


def run_batch(names, tune=False, workers=0) -> None:
    """Pack the named kernels into one multi-kernel container and translate
    (or autotune) the whole batch in a single call."""
    kernels = [paper_kernel(n) for n in names]
    blob = dumps(kernels)
    print(f"batch: {len(kernels)} kernels {names} in one {len(blob)}B container "
          f"({kernel_names(blob)})")
    service = TranslationService()
    if tune:
        out, report = service.tune(blob, SearchConfig(workers=workers))
    else:
        out, report = service.translate(blob)
    translated = loads_many(out)
    for orig, dec, rep, hit in zip(kernels, translated, report.reports, report.cached):
        src = "cache" if hit else f"{len(rep.considered)} variants"
        print(f"  {orig.name:10s} {orig.reg_count:3d} -> {dec.reg_count:3d} regs "
              f"({dec.arch}), chose {rep.chosen} ({src})")
        assert equivalent(orig, dec), "translation must preserve semantics"
    if tune:
        for name, payload in sorted(read_notes(out).items()):
            r = json.loads(payload)
            print(f"  note {name}: explored {r['explored']}/{r['space_size']}, "
                  f"simulated {r['simulated']}, speedup {r['speedup']:.3f}x, "
                  f"agreement {r['agreement']:.2f}")
    print(f"one call: {len(blob)}B in, {len(out)}B out; cache "
          f"{report.cache_hits} hits / {report.cache_misses} misses "
          f"(hit rate {report.hit_rate:.2f})")
    print("OK")


def run_tune(name, workers=0, overlay_out=False, profile=False) -> None:
    """Autotune one kernel binary->binary and walk through the search report."""
    k = paper_kernel(name)
    occ = occupancy_of(k)
    print(f"kernel {k.name}: {k.reg_count} regs, occupancy {occ.occupancy:.3f} "
          f"(limited by {occ.limiter}); spill-target ladder {auto_targets(k)}")
    blob = dumps(k)
    cfg = SearchConfig(workers=workers, profile=profile)
    out, report = translate_binary(blob, tune=True, search_config=cfg)
    sr = report.search
    print(f"searched {sr.space_size} configurations: explored {sr.explored} "
          f"demotions, beam {len(sr.beam)}, simulated {sr.simulated}")
    print(f"predictor choice: {sr.predictor_choice}; confirmed winner: {sr.chosen} "
          f"({sr.speedup:.3f}x over its arch's nvcc baseline, "
          f"agreement {sr.agreement:.2f})")
    for arch, best in sorted(sr.per_arch.items()):
        print(f"  best on {arch:8s}: {best} ({sr.cycles[best]} cycles)")
    chosen = loads(out)
    assert equivalent(k, chosen), "tuned kernel must preserve semantics"
    print(f"binary->binary: {len(blob)}B in, {len(out)}B out "
          f"(+{len(read_notes(out))} search-report note)")
    if profile:
        for label, prof in sorted(sr.stall_profiles.items()):
            top = prof.hot(1)
            hot = (f"hottest #{top[0].index} {top[0].op} "
                   f"({prof.share(top[0]):.0%} {top[0].top_reason})"
                   if top else "no attributed stalls")
            print(f"  profile {label:28s} {prof.total:6d} stall cycles, {hot}")
        if sr.chosen in sr.stall_profiles:
            print(overlay(chosen, profile=sr.stall_profiles[sr.chosen]))
    elif overlay_out:
        print(overlay(chosen))
    print("OK")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--kernel", default="cfd", choices=sorted(PAPER_BENCHMARKS))
    ap.add_argument("--overlay", action="store_true",
                    help="print annotated disassembly of the chosen variant")
    ap.add_argument("--batch", nargs="?", const="cfd,nn,md5hash,cfd", default=None,
                    metavar="K1,K2,...",
                    help="translate several kernels as one multi-kernel "
                         "container (default batch repeats cfd to show the "
                         "translation cache)")
    ap.add_argument("--tune", action="store_true",
                    help="autotune with the predictor-guided search instead "
                         "of the fixed variant set")
    ap.add_argument("--workers", type=int, default=0, metavar="N",
                    help="search process-pool size (default: in-process; "
                         "results are identical for any pool size)")
    ap.add_argument("--profile", action="store_true",
                    help="attribute stall cycles per instruction and print "
                         "the profiled overlay (with --tune: profile every "
                         "confirmed search variant)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry for the whole run and write a "
                         "Chrome trace (.json) or JSONL event log (.jsonl)")
    args = ap.parse_args()

    if args.trace:
        obs.enable()
    try:
        _run(ap, args)
    finally:
        if args.trace:
            fmt = obs.write_trace(args.trace)
            spans = obs.get_telemetry().event_count()
            print(f"trace: {spans} spans -> {args.trace} ({fmt})",
                  file=sys.stderr)


def _run(ap, args) -> None:
    if args.batch:
        names = [n.strip() for n in args.batch.split(",") if n.strip()]
        bad = [n for n in names if n not in PAPER_BENCHMARKS]
        if bad or not names:
            ap.error(f"--batch: invalid kernel name(s) {bad or args.batch!r} "
                     f"(choose from {', '.join(sorted(PAPER_BENCHMARKS))})")
        run_batch(names, tune=args.tune, workers=args.workers)
        return

    if args.tune:
        run_tune(args.kernel, workers=args.workers, overlay_out=args.overlay,
                 profile=args.profile)
        return

    k = paper_kernel(args.kernel)
    occ = occupancy_of(k)
    print(f"kernel {k.name}: {k.reg_count} regs, {k.threads_per_block} thr/block, "
          f"occupancy {occ.occupancy:.3f} (limited by {occ.limiter})")
    print(f"occupancy-cliff spill targets: {auto_targets(k)}")

    # the shipping path: container bytes in, container bytes out
    blob = dumps(k)
    out, report = translate_binary(blob)
    chosen = loads(out)
    print(f"considered {len(report.considered)} variants; predictor chose: {report.chosen}")
    print(f"binary->binary: {len(blob)}B container in, {len(out)}B container out")
    if report.chosen != "nvcc":
        occ2 = occupancy_of(chosen)
        print(f"  regs {k.reg_count} -> {chosen.reg_count}, "
              f"occupancy {occ.occupancy:.3f} -> {occ2.occupancy:.3f}, "
              f"+{chosen.demoted_size}B shared for demoted registers")
        assert equivalent(k, chosen), "translation must preserve semantics"
        s = speedup(simulate(k), simulate(chosen))
        print(f"  simulated speedup over baseline: {s:.3f}x")
    if args.profile:
        prof = simulate(chosen, profile=True).stall_profile
        print(f"stall attribution: {prof.total} cycles across "
              f"{len(prof.instructions)} instructions")
        print(overlay(chosen, profile=prof))
    elif args.overlay:
        print(overlay(chosen))
    print("OK")


if __name__ == "__main__":
    main()
