"""Fault-tolerant translation serving: the TranslationDaemon walkthrough.

Brings up a :class:`repro.runtime.TranslationDaemon` over a persistent
artifact store, serves a mixed translate/tune workload, restarts the daemon
over the same store directory to show the warm-start path (repeat content
served byte-identically from disk, zero pipeline passes), then replays the
workload under an injected fault storm to show graceful degradation — every
response is either the fault-free bytes or an explicitly ``degraded``
baseline emission.

    PYTHONPATH=src python examples/serve_daemon.py
    PYTHONPATH=src python examples/serve_daemon.py --store /tmp/regdem_store
    PYTHONPATH=src python examples/serve_daemon.py --chaos

Pass ``--store DIR`` to keep the artifact store between invocations and
watch the second run serve everything from disk.  ``--chaos`` adds the
fault-storm phase (deterministic: same seed, same outcome, every run).
"""

import argparse
import shutil
import tempfile
import time

from repro.binary import dumps, kernel_names, loads_many
from repro.binary.roundtrip import verified_dumps_many
from repro.core.artifacts import ArtifactStore
from repro.core.kernelgen import paper_kernel
from repro.core.passes import PIPELINE_COUNTERS
from repro.core.search import SearchConfig
from repro.core.translator import TranslationService
from repro.runtime import DaemonConfig, TranslationDaemon
from repro.testing import FaultPlan, injected

TUNE = SearchConfig(max_targets=1, beam_width=2, top_k=1)


def workload():
    """(data, mode) request mix: three translates and one autotune."""
    blobs = [dumps(paper_kernel(n)) for n in ("md5hash", "conv", "nn")]
    return [(b, "translate") for b in blobs] + [(blobs[0], "tune")]


def drive(daemon, requests):
    t0 = time.perf_counter()
    handles = [
        daemon.submit(data, mode=mode, config=TUNE if mode == "tune" else None)
        for data, mode in requests
    ]
    responses = [h.result(timeout=120) for h in handles]
    wall = time.perf_counter() - t0
    for (data, mode), resp in zip(requests, responses):
        names = ",".join(kernel_names(data))
        print(f"  {mode:<9} [{names:<18}] {resp.status:<8} "
              f"attempts={resp.attempts} {resp.latency_s * 1e3:7.1f} ms")
    print(f"  {len(responses)} responses in {wall * 1e3:.0f} ms")
    return responses


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None, metavar="DIR",
                    help="artifact-store directory (default: a temp dir)")
    ap.add_argument("--chaos", action="store_true",
                    help="replay the workload under an injected fault storm")
    args = ap.parse_args()

    store_root = args.store or tempfile.mkdtemp(prefix="regdem_daemon_")
    requests = workload()
    try:
        print(f"== cold serve (store: {store_root}) ==")
        with TranslationDaemon(store=ArtifactStore(store_root)) as daemon:
            drive(daemon, requests)
            snap = daemon.metrics_snapshot()
            print(f"  store: {snap['service']['store']['entries']} entries, "
                  f"cache hit rate {snap['service']['cache']['hit_rate']:.2f}")

        print("\n== warm restart: fresh daemon, same store directory ==")
        svc = TranslationService(store=ArtifactStore(store_root))
        with TranslationDaemon(service=svc) as daemon:
            passes0 = PIPELINE_COUNTERS["passes"]
            drive(daemon, requests)
            zero = PIPELINE_COUNTERS["passes"] == passes0
        print(f"  pipeline passes run: {'ZERO (all from disk)' if zero else 'some'}; "
              f"disk hits: {svc.cache.disk_hits}")

        if args.chaos:
            print("\n== fault storm: transient errors + store bit flips ==")
            data = requests[0][0]
            expected, _ = TranslationService().translate(data)
            baseline = verified_dumps_many(loads_many(data))
            # probabilistic transients plus one request scheduled to fail
            # every attempt, so both the retry path and the degradation
            # path are on display
            plan = FaultPlan(seed=7, error_p=0.45, bit_flip_p=0.3,
                             schedule={("daemon.error", "2"): 3})
            cfg = DaemonConfig(deadline_s=10.0, backoff_s=0.001)
            with injected(plan) as inj:
                with TranslationDaemon(config=cfg) as daemon:
                    responses = drive(daemon, [(data, "translate")] * 6)
            for resp in responses:
                assert (resp.ok and resp.payload == expected) or (
                    resp.degraded and resp.payload == baseline
                ), "serving invariant violated"
            degraded = sum(r.degraded for r in responses)
            print(f"  faults fired: {dict(inj.counts())}")
            print(f"  invariant held: {len(responses) - degraded} fault-free, "
                  f"{degraded} flagged-degraded, 0 corrupt")
    finally:
        if args.store is None:
            shutil.rmtree(store_root, ignore_errors=True)


if __name__ == "__main__":
    main()
