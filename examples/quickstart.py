"""Quickstart: train a small model end-to-end on CPU in ~a minute.

    PYTHONPATH=src python examples/quickstart.py
"""

import tempfile

from repro.configs import reduced_config
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, Trainer


def main() -> None:
    cfg = reduced_config("stablelm_3b")
    mesh = make_host_mesh()
    with tempfile.TemporaryDirectory() as tmp:
        trainer = Trainer(
            model_cfg=cfg,
            opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=60),
            train_cfg=TrainConfig(
                steps=60, checkpoint_every=20, checkpoint_dir=tmp, attn_impl="xla"
            ),
            data_cfg=DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8),
            mesh=mesh,
        )
        out = trainer.run()
    losses = out["losses"]
    print(f"steps: {out['final_step']}  restarts: {out['restarts']}")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "training should reduce loss"
    print("OK")


if __name__ == "__main__":
    main()
