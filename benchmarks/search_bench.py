"""Autotuning-search benchmarks: the widened variant space vs the fixed §5.3 set.

For every Table-1 benchmark and every registered architecture, run the
predictor-guided search (:func:`repro.core.search.search`) restricted to
that arch, anchored on the fixed ``make_variants`` comparison set — so the
search winner is by construction simulated alongside what the paper's fixed
pipeline would have shipped, and the ``win`` column is a direct
like-for-like comparison:

* ``win``        fixed-pick simulated cycles / search-pick simulated cycles
                 (>= 1.0 always: the fixed set is anchored into the
                 confirmation stage; > 1.0 where the wider space found a
                 strictly better variant);
* ``agreement``  predictor-vs-simulator ranking agreement over the
                 confirmed set (the §5 accuracy claim as one number);
* ``variants_per_s``  demotion pipelines explored per second of search
                 wall time — the headline throughput the CI trend gate
                 watches.

The summary also attributes every cell's winner to its strategy family
(``family_hist``: nvcc / fixed / paper / warp_share / block_share /
compressed), counts per-strategy search wins (``strategy_wins``), and
reports ``new_family_wins`` — cells won by a related-work family — which
the CI trend gate holds non-decreasing.

Writes ``BENCH_search.json`` atomically.
"""

from __future__ import annotations

import math
import time
from typing import Dict, Iterator, List, Optional

from repro.arch import arch_names, retarget
from repro.core.kernelgen import PAPER_BENCHMARKS, generate
from repro.core.predictor import predict
from repro.core.search import SearchConfig, search
from repro.core.simcache import simulate_cached
from repro.core.variants import make_variants_for

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative, i.e. the
#: repo root under the documented ``python -m benchmarks.run`` invocation).
JSON_PATH = "BENCH_search.json"


def _geomean(xs: List[float]) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


#: strategy families introduced by the registry (vs the paper's machinery)
NEW_FAMILIES = ("warp_share", "block_share", "compressed")


def chosen_family(chosen: str) -> tuple:
    """``(family, strategy_name)`` of one search-chosen label.

    ``<arch>/nvcc`` is the do-nothing baseline; ``<arch>/regdem@T:<strategy>
    :<opts>`` resolves its strategy's registry family; anything else is an
    anchored fixed-§5.3 variant (``local``, ``local-shared``, ...).
    """
    from repro.core.strategies import get_strategy

    tail = chosen.split("/", 1)[1]
    if tail == "nvcc":
        return "nvcc", None
    if tail.startswith("regdem@"):
        name = tail.split(":", 1)[1].split(":", 1)[0]
        return get_strategy(name).family, name
    return "fixed", None


def tune_profile(prof, arch: str, workers: int = 0) -> Dict:
    """Search one (Profile, arch) cell, anchored on the fixed §5.3 set.

    Profile-generic core of :func:`tune_benchmark`: also the entry point the
    real-workload corpus bench (:mod:`benchmarks.corpus_bench`) drives, so
    synthetic and extracted profiles go through byte-for-byte the same
    tune pipeline.
    """
    base = generate(prof)
    k = base if arch == "maxwell" else retarget(base, arch)
    # the fixed §5.3 pipeline: five variants, predictor picks one
    fixed = make_variants_for(k, prof.regdem_target, prof.nvcc_spills)
    fixed_kernels = {n: v.kernel for n, v in fixed.items()}
    fixed_best, _ = predict(fixed_kernels)
    fixed_cycles = simulate_cached(fixed_kernels[fixed_best]).total_cycles
    # the search, anchored on that same fixed set
    anchors = {f"{arch}/{n}": v.kernel for n, v in fixed.items() if n != "nvcc"}
    outcome = search(
        k, SearchConfig(archs=(arch,), workers=workers), extra_variants=anchors
    )
    sr = outcome.report
    best_cycles = sr.cycles[sr.chosen]
    family, _ = chosen_family(sr.chosen)
    return {
        "chosen": sr.chosen,
        "chosen_family": family,
        "fixed_best": fixed_best,
        "cycles_chosen": best_cycles,
        "cycles_fixed": fixed_cycles,
        "win": round(fixed_cycles / best_cycles, 4),
        "speedup_vs_nvcc": round(sr.speedup, 4),
        "agreement": round(sr.agreement, 4),
        "space_size": sr.space_size,
        "explored": sr.explored,
        "simulated": sr.simulated,
        "seconds": round(sr.seconds, 4),
    }


def tune_benchmark(bench: str, arch: str, workers: int = 0) -> Dict:
    """Search one (benchmark, arch) cell, anchored on the fixed §5.3 set.

    Returns the per-cell report row (what ``BENCH_search.json`` stores under
    ``kernels.<bench>.<arch>``, plus the wall ``seconds``).  The golden test
    recomputes single cells through this same entry point.
    """
    return tune_profile(PAPER_BENCHMARKS[bench], arch, workers=workers)


def measure(workers: int = 0) -> Dict[str, Dict]:
    """The full 9-benchmarks-x-every-arch sweep as a report dict."""
    archs = arch_names()
    report: Dict[str, Dict] = {"kernels": {}, "summary": {}}
    explored_total = 0
    searches = 0
    agreements: List[float] = []
    wins: List[float] = []
    strict_wins = 0
    search_seconds = 0.0
    family_hist: Dict[str, int] = {}
    strategy_wins: Dict[str, int] = {}
    new_family_wins = 0

    t0 = time.perf_counter()
    for bench in PAPER_BENCHMARKS:
        report["kernels"][bench] = {}
        for arch in archs:
            row = tune_benchmark(bench, arch, workers=workers)
            report["kernels"][bench][arch] = row
            explored_total += row["explored"]
            searches += 1
            search_seconds += row["seconds"]
            agreements.append(row["agreement"])
            wins.append(row["cycles_fixed"] / row["cycles_chosen"])
            strict_wins += row["cycles_chosen"] < row["cycles_fixed"]
            family, strat = chosen_family(row["chosen"])
            family_hist[family] = family_hist.get(family, 0) + 1
            if strat is not None:
                strategy_wins[strat] = strategy_wins.get(strat, 0) + 1
            new_family_wins += family in NEW_FAMILIES
    elapsed = time.perf_counter() - t0

    report["summary"] = {
        "searches": searches,
        "explored": explored_total,
        "variants_per_s": round(explored_total / search_seconds, 2)
        if search_seconds
        else 0.0,
        "mean_agreement": round(sum(agreements) / len(agreements), 4),
        "geomean_win": round(_geomean(wins), 4),
        "strict_wins": strict_wins,
        "family_hist": dict(sorted(family_hist.items())),
        "strategy_wins": dict(sorted(strategy_wins.items())),
        "new_family_wins": new_family_wins,
        "seconds": round(elapsed, 3),
        "workers": workers,
    }
    return report


def search_rows(
    json_path: Optional[str] = JSON_PATH, workers: int = 0
) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_search.json`` as a side effect."""
    report = measure(workers=workers)
    for bench, per_arch in report["kernels"].items():
        for arch, row in per_arch.items():
            yield (
                f"search_{arch}_{bench},{row['seconds'] * 1e6:.0f},"
                f"chosen={row['chosen']};win={round(row['win'], 3)};"
                f"agreement={round(row['agreement'], 3)};"
                f"explored={row['explored']}/{row['space_size']}"
            )
    if json_path:
        write_json_atomic(json_path, report)
    s = report["summary"]
    yield (
        f"search_summary,{s['seconds'] * 1e6:.0f},"
        f"variants_per_s={s['variants_per_s']};"
        f"geomean_win={s['geomean_win']};"
        f"strict_wins={s['strict_wins']}/{s['searches']};"
        f"new_family_wins={s['new_family_wins']};"
        f"mean_agreement={s['mean_agreement']}"
    )
