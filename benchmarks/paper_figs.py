"""Benchmarks reproducing the paper's tables/figures on the simulator.

Each function emits ``name,us_per_call,derived`` CSV rows (one per cell).
``us_per_call`` is the simulated kernel execution time (total cycles at the
Titan X's 1.075 GHz boost clock); ``derived`` carries the figure's metric
(occupancy, speedup, ...).

All simulator runs go through the process-wide content-addressed
:data:`repro.core.simcache.DEFAULT_SIM_CACHE`, so sections stop re-measuring
each other's kernels (fig6's baselines are fig9's; fig7's ``full`` demotion
is table1's ``regdem`` variant), and variant generation runs the pass
pipeline with the hot-path ``verify="final"`` policy.
"""

from __future__ import annotations

import math
from typing import Dict, List

from repro.core.kernelgen import PAPER_BENCHMARKS
from repro.core.occupancy import occupancy_of
from repro.core.predictor import predict, predict_naive
from repro.core.regdem import RegDemOptions, demote
from repro.core.simcache import simulate_cached
from repro.core.simulator import SimResult, speedup
from repro.core.variants import make_variants

CLOCK_GHZ = 1.075  # GTX Titan X boost clock


def _us(sim: SimResult) -> float:
    return sim.total_cycles / (CLOCK_GHZ * 1e3)


def _geomean(xs: List[float]) -> float:
    return math.exp(sum(math.log(x) for x in xs) / len(xs))


_VCACHE: Dict[str, Dict] = {}


def _variants(name: str):
    if name not in _VCACHE:
        _VCACHE[name] = make_variants(PAPER_BENCHMARKS[name])
    return _VCACHE[name]


def _sim(name: str, vname: str) -> SimResult:
    return simulate_cached(_variants(name)[vname].kernel)


# ---------------------------------------------------------------------------
# Table 1: occupancy before/after RegDem
# ---------------------------------------------------------------------------

#: paper Table 1 achieved-occupancy columns (orig, regdem) for reference
PAPER_TABLE1 = {
    "cfd": (0.35, 0.54), "qtc": (0.51, 0.57), "md5hash": (0.70, 0.94),
    "md": (0.75, 0.83), "gaussian": (0.58, 0.62), "conv": (0.73, 0.98),
    "nn": (0.55, 0.72), "pc": (0.54, 0.72), "vp": (0.52, 0.68),
}


def table1_occupancy() -> List[str]:
    rows = []
    for name in PAPER_BENCHMARKS:
        vs = _variants(name)
        o0 = occupancy_of(vs["nvcc"].kernel).occupancy
        o1 = occupancy_of(vs["regdem"].kernel).occupancy
        spilled = vs["regdem"].spilled
        p0, p1 = PAPER_TABLE1[name]
        rows.append(
            f"table1_{name},{_us(_sim(name, 'regdem')):.1f},"
            f"occ {o0:.3f}->{o1:.3f} demoted={spilled} paper={p0:.2f}->{p1:.2f}"
        )
    gain = _geomean([
        occupancy_of(_variants(n)["regdem"].kernel).occupancy
        / occupancy_of(_variants(n)["nvcc"].kernel).occupancy
        for n in PAPER_BENCHMARKS
    ])
    rows.append(f"table1_geomean_occupancy_gain,0.0,{gain:.3f}x (paper ~1.27x)")
    return rows


# ---------------------------------------------------------------------------
# Fig 6: variant speedups over nvcc
# ---------------------------------------------------------------------------


def fig6_speedups() -> List[str]:
    rows = []
    geos: Dict[str, List[float]] = {}
    for name in PAPER_BENCHMARKS:
        base = _sim(name, "nvcc")
        for vn in ("regdem", "local", "local-shared", "local-shared-relax"):
            s = speedup(base, _sim(name, vn))
            geos.setdefault(vn, []).append(s)
            rows.append(f"fig6_{name}_{vn},{_us(_sim(name, vn)):.1f},{s:.3f}x")
    for vn, xs in geos.items():
        rows.append(f"fig6_geomean_{vn},0.0,{_geomean(xs):.3f}x")
    rows.append("fig6_paper_reference,0.0,regdem 1.07x / local 1.03x / ls 0.90x / relax 1.05x")
    return rows


# ---------------------------------------------------------------------------
# Fig 7: post-spilling optimization ablation
# ---------------------------------------------------------------------------


def fig7_postopt() -> List[str]:
    rows = []
    slow_bank, slow_enh = [], []
    for name, prof in PAPER_BENCHMARKS.items():
        base_kernel = _variants(name)["nvcc"].kernel
        full = simulate_cached(
            demote(base_kernel, prof.regdem_target, RegDemOptions(), verify="final").kernel
        )
        no_bank = simulate_cached(
            demote(
                base_kernel, prof.regdem_target,
                RegDemOptions(bank_avoid=False), verify="final",
            ).kernel
        )
        no_enh = simulate_cached(
            demote(
                base_kernel,
                prof.regdem_target,
                RegDemOptions(elim_redundant=False, reschedule=False, substitute=False),
                verify="final",
            ).kernel
        )
        sb = full.total_cycles / no_bank.total_cycles
        se = full.total_cycles / no_enh.total_cycles
        slow_bank.append(max(sb, 1e-9))
        slow_enh.append(max(se, 1e-9))
        rows.append(f"fig7_{name},{_us(full):.1f},no_bank={1/sb:.3f}x no_enh={1/se:.3f}x")
    rows.append(
        f"fig7_geomean,0.0,bank_avoid_impact={1/_geomean(slow_bank):.3f}x (paper <1%) "
        f"perf_enh_impact={1/_geomean(slow_enh):.3f}x (paper ~3%)"
    )
    return rows


# ---------------------------------------------------------------------------
# Fig 8: candidate-selection strategies
# ---------------------------------------------------------------------------


def fig8_candidates() -> List[str]:
    rows = []
    wins = {"static": 0, "cfg": 0, "conflict": 0}
    for name, prof in PAPER_BENCHMARKS.items():
        base_kernel = _variants(name)["nvcc"].kernel
        cycles = {}
        for strat in ("static", "cfg", "conflict"):
            res = demote(
                base_kernel, prof.regdem_target,
                RegDemOptions(candidate_strategy=strat), verify="final",
            )
            cycles[strat] = simulate_cached(res.kernel).total_cycles
        best = min(cycles.values())
        wins[min(cycles, key=cycles.get)] += 1
        norm = {s: best / c for s, c in cycles.items()}
        rows.append(
            f"fig8_{name},{best / (CLOCK_GHZ * 1e3):.1f},"
            + " ".join(f"{s}={norm[s]:.3f}" for s in norm)
        )
    rows.append(
        f"fig8_wins,0.0,static={wins['static']} cfg={wins['cfg']} "
        f"conflict={wins['conflict']} (paper: cfg best overall)"
    )
    return rows


# ---------------------------------------------------------------------------
# Fig 9: predictor vs oracle vs naive
# ---------------------------------------------------------------------------


def fig9_predictor() -> List[str]:
    rows = []
    geo = {"oracle": [], "predictor": [], "naive": []}
    correct = 0
    for name in PAPER_BENCHMARKS:
        vs = _variants(name)
        kernels = {vn: v.kernel for vn, v in vs.items()}
        base = _sim(name, "nvcc")
        sp = {vn: speedup(base, _sim(name, vn)) for vn in kernels}
        oracle = max(sp, key=sp.get)
        pred, _ = predict(kernels)
        nv = predict_naive(kernels)
        correct += pred == oracle
        geo["oracle"].append(sp[oracle])
        geo["predictor"].append(sp[pred])
        geo["naive"].append(sp[nv])
        rows.append(
            f"fig9_{name},{_us(_sim(name, pred)):.1f},"
            f"oracle={oracle}({sp[oracle]:.3f}) pred={pred}({sp[pred]:.3f}) naive={nv}"
        )
    gm = {k: _geomean(v) for k, v in geo.items()}
    rows.append(
        f"fig9_geomeans,0.0,oracle={gm['oracle']:.3f}x predictor={gm['predictor']:.3f}x "
        f"naive={gm['naive']:.3f}x ratio={gm['predictor']/gm['oracle']*100:.1f}% "
        f"correct={correct}/9 (paper: 1.10x/1.09x/99.0%/7 of 9)"
    )
    return rows
