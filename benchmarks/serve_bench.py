"""Serving benchmarks: daemon latency, warm-restart hit rate, fault behaviour.

Three phases against :class:`repro.runtime.TranslationDaemon`, writing
``BENCH_serve.json`` for the CI trend gate:

* **cold / serve** — first-contact and steady-state request latency
  (p50/p99 ms) plus warm requests/s through the full daemon path (queue,
  slots, watchdog, cache).  The warm throughput is the gated headline; the
  latency percentiles ship for inspection but are not gated (absolute
  wall-clock numbers on shared CI machines are too noisy for a relative
  gate — the ``BENCH_obs`` precedent).
* **warm** — a *restarted* daemon over the same artifact-store directory:
  the fraction of requests served from disk with zero pipeline passes
  (``hit_rate``, gated; anything under 1.0 means restart durability broke).
* **faults** — a deterministic fault storm (transient errors + store bit
  flips): ``degraded_ok_rate`` is the fraction of responses that are
  byte-identical to the fault-free output *or* correctly-flagged degraded
  baselines (gated; under 1.0 means the serving invariant broke — wrong
  bytes or an unflagged failure), alongside the observed degradation rate.
"""

from __future__ import annotations

import shutil
import tempfile
import time
from typing import Iterator, List, Optional

from repro.binary import dumps, loads_many
from repro.binary.roundtrip import verified_dumps_many
from repro.core.artifacts import ArtifactStore
from repro.core.kernelgen import paper_kernel
from repro.core.passes import PIPELINE_COUNTERS
from repro.core.search import SearchConfig
from repro.core.translator import TranslationService
from repro.runtime import DaemonConfig, TranslationDaemon
from repro.testing import FaultPlan
from repro.testing import injected as faults_injected

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative).
JSON_PATH = "BENCH_serve.json"

#: translate-mode workload kernels (Table-1 subset)
BATCH_NAMES = ["md5hash", "conv", "nn"]
#: tune-mode workload (kept small: the bench measures serving, not search)
TUNE_CONFIG = SearchConfig(max_targets=1, beam_width=2, top_k=1)
#: steady-state repetitions per request kind
WARM_REPS = 20
#: fault-storm request count
FAULT_REQS = 8


def _percentiles(lat_ms: List[float]) -> dict:
    ordered = sorted(lat_ms)

    def pct(p: float) -> float:
        rank = min(len(ordered) - 1, int(round(p / 100.0 * (len(ordered) - 1))))
        return round(ordered[rank], 3)

    return {"p50_ms": pct(50), "p99_ms": pct(99)}


def _drive(daemon: TranslationDaemon, requests) -> List[float]:
    """Run ``(data, mode)`` requests; return per-request latency in ms."""
    lat = []
    for data, mode in requests:
        t0 = time.perf_counter()
        resp = daemon.request(
            data, mode=mode, config=TUNE_CONFIG if mode == "tune" else None
        )
        lat.append((time.perf_counter() - t0) * 1e3)
        assert resp.ok, f"bench request failed: {resp.status} {resp.reason}"
    return lat


def serve_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_serve.json`` as a side effect."""
    blobs = [dumps(paper_kernel(n)) for n in BATCH_NAMES]
    tune_blob = blobs[0]
    requests = [(b, "translate") for b in blobs] + [(tune_blob, "tune")]

    store_root = tempfile.mkdtemp(prefix="regdem_serve_bench_")
    try:
        # -- cold + steady-state through one daemon ---------------------------
        with TranslationDaemon(store=ArtifactStore(store_root)) as daemon:
            cold_lat = _drive(daemon, requests)
            t0 = time.perf_counter()
            warm_lat: List[float] = []
            for _ in range(WARM_REPS):
                warm_lat.extend(_drive(daemon, requests))
            warm_wall = time.perf_counter() - t0
            n_warm = WARM_REPS * len(requests)
            requests_per_s = n_warm / warm_wall if warm_wall else 0.0

        # -- warm restart: fresh process state, same store dir ----------------
        svc = TranslationService(store=ArtifactStore(store_root))
        with TranslationDaemon(service=svc) as daemon2:
            passes0 = PIPELINE_COUNTERS["passes"]
            restart_lat = _drive(daemon2, requests)
            zero_passes = PIPELINE_COUNTERS["passes"] == passes0
        disk_hits = svc.cache.disk_hits
        warm_hit_rate = disk_hits / len(requests) if zero_passes else 0.0

        # -- fault storm -------------------------------------------------------
        expected, _ = TranslationService().translate(blobs[0])
        baseline = verified_dumps_many(loads_many(blobs[0]))
        # probabilistic transient errors plus three scheduled
        # fail-every-attempt requests, so the report always exercises both
        # the retry-recovery path and the degradation path
        plan = FaultPlan(
            seed=5,
            error_p=0.4,
            bit_flip_p=0.3,
            schedule={("daemon.error", str(rid)): 3 for rid in (2, 5, 7)},
        )
        ok = degraded = invariant_ok = 0
        with faults_injected(plan):
            cfg = DaemonConfig(deadline_s=10.0, backoff_s=0.001)
            with TranslationDaemon(config=cfg) as daemon3:
                handles = [daemon3.submit(blobs[0]) for _ in range(FAULT_REQS)]
                for h in handles:
                    resp = h.result(timeout=60)
                    if resp.ok and resp.payload == expected:
                        ok += 1
                        invariant_ok += 1
                    elif resp.degraded and resp.payload == baseline:
                        degraded += 1
                        invariant_ok += 1
    finally:
        shutil.rmtree(store_root, ignore_errors=True)

    report = {
        "serve": {
            "requests": n_warm,
            "requests_per_s": round(requests_per_s, 3),
            **_percentiles(warm_lat),
        },
        "cold": {"requests": len(cold_lat), **_percentiles(cold_lat)},
        "warm": {
            "requests": len(requests),
            "disk_hits": disk_hits,
            "zero_passes": zero_passes,
            "hit_rate": round(warm_hit_rate, 3),
            **_percentiles(restart_lat),
        },
        "faults": {
            "requests": FAULT_REQS,
            "ok": ok,
            "degraded": degraded,
            "degradation_rate": round(degraded / FAULT_REQS, 3),
            "degraded_ok_rate": round(invariant_ok / FAULT_REQS, 3),
        },
    }
    if json_path:
        write_json_atomic(json_path, report)

    c = report["cold"]
    s = report["serve"]
    w = report["warm"]
    f = report["faults"]
    yield f"serve_cold_p99,{c['p99_ms'] * 1e3:.0f},ms={c['p99_ms']}"
    yield f"serve_warm_p50,{s['p50_ms'] * 1e3:.0f},ms={s['p50_ms']}"
    yield f"serve_warm_p99,{s['p99_ms'] * 1e3:.0f},ms={s['p99_ms']}"
    yield (
        f"serve_throughput,{1e6 / s['requests_per_s']:.0f},"
        f"requests_per_s={s['requests_per_s']}"
    )
    yield (
        f"serve_restart_hit_rate,{w['p50_ms'] * 1e3:.0f},"
        f"hit_rate={w['hit_rate']}"
    )
    yield (
        f"serve_fault_invariant,{f['degraded_ok_rate'] * 100:.0f},"
        f"degraded_ok_rate={f['degraded_ok_rate']}"
        f";degradation_rate={f['degradation_rate']}"
    )
