"""Fig.-9 adapted: the TPU static variant selector vs exhaustive ranking.

For each cell where multiple variants were lowered (the §Perf probes plus
the baseline dry-run records), the adapted predictor
(`repro.core.tpu_predictor`) ranks the variants from their compiled
artifacts; the "oracle" is the exhaustive ranking under the same bound
model with feasibility enforced — the quantity of interest is whether the
*selection* (never running the worst variant, rejecting OOM ones) matches,
mirroring the paper's Fig. 9 contract.
"""

from __future__ import annotations

import json
import os
from typing import List

from repro.core.tpu_predictor import VariantCost, select

PERF_LOG = os.environ.get("PERF_ITER_LOG", "perf_iter.log")


def _variants_from_log() -> List[VariantCost]:
    out: List[VariantCost] = []
    if not os.path.exists(PERF_LOG):
        return out
    for line in open(PERF_LOG):
        line = line.strip()
        if not line.startswith("{"):
            continue
        rec = json.loads(line)
        out.append(
            VariantCost(
                name=rec["label"],
                compute_s=rec["flops"] / 197e12,
                memory_s=0.01,
                collective_s=rec["wire_mb"] * 2**20 / 50e9,
                fits_hbm=rec["temp_gib"] <= 50,
                n_options=0,
            )
        )
    return out


def selector_rows() -> List[str]:
    rows = []
    variants = _variants_from_log()
    if len(variants) >= 2:
        best, ranked = select(variants)
        feasible = [v for v in ranked if v.fits_hbm]
        oracle = feasible[0] if feasible else ranked[0]
        agree = best.name == oracle.name
        for v in ranked:
            rows.append(
                f"tpu_selector_{v.name},{v.estimate_s*1e6:.1f},"
                f"fits={v.fits_hbm} dominant={v.dominant}"
            )
        rows.append(
            f"tpu_selector_verdict,0.0,chose={best.name} oracle={oracle.name} "
            f"agree={agree} (Fig.9-adapted: static selection from compiled artifacts)"
        )
    else:
        rows.append("tpu_selector_missing,0.0,run the §Perf probes first")
    return rows
