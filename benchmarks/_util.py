"""Shared benchmark-harness helpers.

``write_json_atomic`` used to live here; the implementation is now the
repo-wide :mod:`repro.util` (the artifact store and the harness must share
one atomic-write recipe), re-exported under the historical name so every
``BENCH_*.json`` writer keeps working unchanged.
"""

from __future__ import annotations

from repro.util import write_bytes_atomic, write_json_atomic

__all__ = ["write_bytes_atomic", "write_json_atomic"]
