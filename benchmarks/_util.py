"""Shared benchmark-harness helpers."""

from __future__ import annotations

import json
import os
import tempfile


def write_json_atomic(path: str, obj: object) -> None:
    """Write a ``BENCH_*.json`` report atomically.

    The report is first written to a temporary file in the same directory
    and then renamed over the target, so an interrupted run (ctrl-C, OOM,
    CI timeout) can never leave a truncated baseline behind for the CI
    perf-trend gate to trip over.  ``os.replace`` is atomic on POSIX and
    Windows when source and destination share a filesystem — which the
    same-directory temp file guarantees.
    """
    path = os.path.abspath(path)
    directory = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(
        dir=directory, prefix=os.path.basename(path) + ".", suffix=".tmp"
    )
    try:
        with os.fdopen(fd, "w") as fh:
            json.dump(obj, fh, indent=2, sort_keys=True)
            fh.write("\n")
        os.replace(tmp, path)
    except BaseException:
        # never leave the temp file behind on a failed/interrupted write
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
