"""Binary-substrate benchmarks: codec throughput and container sizes.

Measures, over the Table-1 corpus, what the pseudo-cubin layer costs:
``dumps`` (assemble) and ``loads`` (disassemble) wall time per instruction,
and the container footprint per kernel.  Rows follow the harness CSV
contract (``name,us_per_call,derived``); the same numbers are also written
to ``BENCH_binary.json`` so the performance trajectory accumulates
machine-readably across PRs.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Optional

from repro.binary import dumps, loads
from repro.core.kernelgen import all_paper_kernels

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative, i.e. the
#: repo root under the documented ``python -m benchmarks.run`` invocation).
JSON_PATH = "BENCH_binary.json"

_MIN_REPS = 5
_MIN_NS = 20_000_000  # calibrate reps so each timing loop runs >= 20 ms


def _time_ns(fn, arg) -> float:
    """Median-of-3 wall time of ``fn(arg)`` in ns, rep-calibrated."""
    reps = _MIN_REPS
    while True:
        t0 = time.perf_counter_ns()
        for _ in range(reps):
            fn(arg)
        elapsed = time.perf_counter_ns() - t0
        if elapsed >= _MIN_NS:
            break
        reps *= 4
    samples = []
    for _ in range(3):
        t0 = time.perf_counter_ns()
        for _ in range(reps):
            fn(arg)
        samples.append((time.perf_counter_ns() - t0) / reps)
    samples.sort()
    return samples[1]


def binary_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_binary.json`` as a side effect."""
    report: Dict[str, Dict] = {}
    tot_instrs = tot_bytes = 0
    enc_ns = dec_ns = 0.0
    for name, kernel in all_paper_kernels().items():
        blob = dumps(kernel)
        n = len(kernel.instructions())
        encode_ns = _time_ns(dumps, kernel)
        decode_ns = _time_ns(loads, blob)
        report[name] = {
            "instrs": n,
            "container_bytes": len(blob),
            "bytes_per_instr": round(len(blob) / n, 2),
            "encode_ns_per_instr": round(encode_ns / n, 1),
            "decode_ns_per_instr": round(decode_ns / n, 1),
        }
        tot_instrs += n
        tot_bytes += len(blob)
        enc_ns += encode_ns
        dec_ns += decode_ns
        yield f"binary_encode_{name},{encode_ns / 1e3:.2f},ns_per_instr={encode_ns / n:.0f}"
        yield f"binary_decode_{name},{decode_ns / 1e3:.2f},ns_per_instr={decode_ns / n:.0f}"
        yield f"binary_size_{name},0.00,bytes={len(blob)}"

    summary = {
        "total_instrs": tot_instrs,
        "total_container_bytes": tot_bytes,
        "encode_ns_per_instr": round(enc_ns / tot_instrs, 1),
        "decode_ns_per_instr": round(dec_ns / tot_instrs, 1),
        "bytes_per_instr": round(tot_bytes / tot_instrs, 2),
    }
    if json_path:
        write_json_atomic(json_path, {"kernels": report, "summary": summary})
    yield (
        f"binary_corpus,0.00,encode_ns={summary['encode_ns_per_instr']};"
        f"decode_ns={summary['decode_ns_per_instr']};"
        f"bytes_per_instr={summary['bytes_per_instr']}"
    )
