"""Simulator-engine benchmark: tracks the measurement loop's own speed.

Everything the harness reports is *measured on the simulator*, so the
simulator's throughput bounds how large a variant sweep is feasible.  This
section measures the two-stage engine (trace compiler + event-driven issue
loop) end to end on a fixed workload — the ``nvcc`` and ``regdem`` variants
of all nine paper benchmarks — and compares against the recorded
pre-optimization baseline, so the engine's performance trajectory
accumulates machine-readably in ``BENCH_sim.json`` across PRs.

Also measured: the content-addressed :class:`repro.core.simcache.SimCache`
(hit rate and per-hit latency over a repeated pass), since the harness and
the service lean on it to avoid re-simulating identical kernels.
"""

from __future__ import annotations

import time
from typing import Iterator, Optional

from repro.core.kernelgen import PAPER_BENCHMARKS
from repro.core.simcache import SimCache
from repro.core.simulator import CheckpointStore, simulate, simulate_batch
from repro.core.variants import make_variants

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative, i.e. the
#: repo root under the documented ``python -m benchmarks.run`` invocation).
JSON_PATH = "BENCH_sim.json"

#: Pre-optimization engine throughput on this exact workload (the PR-2 tree's
#: cycle-by-cycle ``simulate()``, measured on the reference machine before
#: the two-stage engine landed).  The CSV/JSON speedup is relative to this.
BASELINE_KERNELS_PER_S = 1.77

#: Workload: the nvcc + regdem variants of every paper benchmark.
VARIANT_NAMES = ("nvcc", "regdem")


def _workload():
    kernels = []
    for name in PAPER_BENCHMARKS:
        vs = make_variants(PAPER_BENCHMARKS[name])
        kernels.extend(vs[vn].kernel for vn in VARIANT_NAMES)
    return kernels


def sim_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_sim.json`` as a side effect."""
    kernels = _workload()
    n = len(kernels)

    # engine throughput: every kernel simulated fresh (no cache involved)
    t0 = time.perf_counter()
    dyn = sum(simulate(k).dynamic_instructions for k in kernels)
    engine_s = time.perf_counter() - t0
    kernels_per_s = n / engine_s

    # batched entry point: the same workload through one simulate_batch
    # sweep (fresh checkpoint store, no result cache — pure engine path)
    t0 = time.perf_counter()
    batched = simulate_batch(kernels, checkpoints=CheckpointStore())
    batch_s = time.perf_counter() - t0
    batch_kernels_per_s = n / batch_s
    assert all(
        b.dynamic_instructions == 0 or b.total_cycles > 0 for b in batched
    )

    # incremental re-simulation: re-running a workload whose checkpoints are
    # already captured resumes each kernel at the deepest milestone; the
    # reuse rate is the position-weighted fraction of trace skipped
    store = CheckpointStore()
    simulate_batch(kernels, checkpoints=store)  # cold: captures milestones
    t0 = time.perf_counter()
    resumed = simulate_batch(kernels, checkpoints=store)
    incr_s = time.perf_counter() - t0
    incremental_reuse_rate = store.reuse_rate
    assert all(
        r.total_cycles == b.total_cycles for r, b in zip(resumed, batched)
    ), "checkpoint resume diverged from cold simulation"

    # cache behaviour: a cold pass populates, a warm pass must fully hit
    cache = SimCache()
    cold = [cache.simulate(k) for k in kernels]
    hits_before_warm = cache.hits
    t0 = time.perf_counter()
    warm = [cache.simulate(k) for k in kernels]
    warm_s = time.perf_counter() - t0
    warm_hit_rate = (cache.hits - hits_before_warm) / n
    assert all(
        w.total_cycles == f.total_cycles for w, f in zip(warm, cold)
    ), "cache hit diverged from fresh simulation"

    report = {
        "engine": {
            "kernels": n,
            "dynamic_instructions": dyn,
            "seconds": round(engine_s, 3),
            "kernels_per_s": round(kernels_per_s, 2),
            "baseline_kernels_per_s": BASELINE_KERNELS_PER_S,
            "speedup_vs_baseline": round(kernels_per_s / BASELINE_KERNELS_PER_S, 2),
            "batch_kernels_per_s": round(batch_kernels_per_s, 2),
            "incremental_kernels_per_s": round(n / incr_s, 2),
            "incremental_reuse_rate": round(incremental_reuse_rate, 3),
        },
        "cache": {
            "hits": cache.hits,
            "misses": cache.misses,
            "warm_hit_rate": round(warm_hit_rate, 3),
            "warm_us_per_kernel": round(warm_s * 1e6 / n, 1),
        },
    }
    if json_path:
        write_json_atomic(json_path, report)

    e, c = report["engine"], report["cache"]
    yield (
        f"sim_engine,{engine_s * 1e6 / n:.1f},"
        f"kernels_per_s={e['kernels_per_s']};"
        f"speedup_vs_baseline={e['speedup_vs_baseline']}x"
    )
    yield (
        f"sim_batch,{batch_s * 1e6 / n:.1f},"
        f"batch_kernels_per_s={e['batch_kernels_per_s']};"
        f"incremental_kernels_per_s={e['incremental_kernels_per_s']};"
        f"incremental_reuse_rate={e['incremental_reuse_rate']}"
    )
    yield (
        f"sim_cache_warm,{c['warm_us_per_kernel']},"
        f"warm_hit_rate={c['warm_hit_rate']};hits={c['hits']};misses={c['misses']}"
    )
