"""Roofline analysis over the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from the compiled per-device module:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = wire_bytes_per_device / link_bw

(The per-device framing is equivalent to the global/chips form since the
dry-run records the SPMD-partitioned per-device module, with scans unrolled
so loop bodies are counted the correct number of times.)

Also reports MODEL_FLOPS = 6*N*D (dense) / 6*N_active*D (MoE) per device and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs, plus the dominant term and a
one-line "what would move it" note.
"""

from __future__ import annotations

import json
import os
from typing import Any, Dict, List, Optional

from repro.configs import active_param_count, get_config, param_count, shape_cells
from repro.launch.specs import cell_geometry

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s per ICI link

RESULTS_FILE = os.environ.get("DRYRUN_RESULTS", "dryrun_results.json")


def chips(mesh: str) -> int:
    return 512 if mesh == "2x16x16" else 256


def model_flops_cell(arch: str, cell_name: str) -> float:
    """Global MODEL_FLOPS for one cell (6ND train, 2ND prefill/decode +
    attention/SSD terms), before dividing by chips."""
    cfg = get_config(arch)
    cell = next(c for c in shape_cells(arch) if c.name == cell_name)
    g = cell_geometry(cfg, cell)
    B, S = g["batch"], g["seq"]
    n = active_param_count(cfg) if cfg.moe else param_count(cfg)

    def attn_flops(tokens: int, kv_len: int, causal: bool) -> float:
        if cfg.n_heads == 0:
            return 0.0
        per_layer = 2 * 2 * tokens * kv_len * cfg.n_heads * cfg.dh
        if causal:
            per_layer *= 0.5
        return per_layer * cfg.n_layers

    if cell.kind == "train":
        flops = 6 * n * B * S + 3 * attn_flops(B * S, S, True)
        if cfg.family == "audio":
            flops += 3 * attn_flops(B * g["n_frames"], g["n_frames"], False)
    elif cell.kind == "prefill":
        flops = 2 * n * B * S + attn_flops(B * S, S, True)
    else:  # decode: one token per sequence against the full context
        flops = 2 * n * B + attn_flops(B, S, False)
    return flops


def load_results(path: str = RESULTS_FILE) -> List[Dict[str, Any]]:
    with open(path) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Analytic per-device traffic model
# ---------------------------------------------------------------------------
# The rolled dry-run counts while-loop bodies once, so flops / bytes /
# collectives for scanned programs come from this explicit model instead;
# it is validated against the fully-unrolled HLO measurements on the
# calibration cells (EXPERIMENTS.md §Roofline, "calibration").


def analytic_cell(arch: str, cell_name: str, mesh: str,
                  remat: str = "full", fsdp: bool = True) -> Dict[str, float]:
    cfg = get_config(arch)
    cell = next(c for c in shape_cells(arch) if c.name == cell_name)
    g = cell_geometry(cfg, cell)
    B, S = g["batch"], g["seq"]
    nchips = chips(mesh)
    tp = 16
    dp = nchips // tp
    n_total = param_count(cfg)
    n_active = active_param_count(cfg) if cfg.moe else n_total
    tokens = B * S if cell.kind != "decode" else B
    tok_dev = max(tokens // nchips, 1) if cell.kind != "decode" else max(B // dp, 1)

    # ---- FLOPs per device ---------------------------------------------------
    mf_global = model_flops_cell(arch, cell_name)
    remat_factor = {"none": 1.0, "dots": 1.1, "full": 4.0 / 3.0}[remat] if cell.kind == "train" else 1.0
    flops_dev = mf_global * remat_factor / nchips

    # ---- HBM bytes per device ------------------------------------------------
    D, L = cfg.d_model, cfg.n_layers
    act_bytes_layer = tok_dev * D * 2  # one activation tensor, bf16
    n_tensors = 14 if cell.kind == "train" else 5  # fwd(+bwd+remat) traffic
    if cell.kind == "train" and remat == "full":
        n_tensors += 6
    act_traffic = act_bytes_layer * n_tensors * L
    p_shard = n_active / tp / (dp if fsdp and cell.kind == "train" else 1)
    if cell.kind == "train":
        # p(bf16) rw + grad(f32) rw + mu/nu(f32) rw  (microbatch reuse ignored)
        param_traffic = p_shard * (2 * 2 + 2 * 4 + 4 * 4)
    else:
        param_traffic = (n_active / tp) * 2  # weights read once per step
    cache_traffic = 0.0
    if cell.kind == "decode" and cfg.n_heads:
        kv_total = 2 * cfg.n_layers * B * S * cfg.n_kv_heads * cfg.dh * 2
        if cfg.family == "hybrid":
            from repro.models.hybrid import n_attn_applications

            kv_total = 2 * n_attn_applications(cfg) * B * S * cfg.n_kv_heads * cfg.dh * 2
        cache_traffic = kv_total / nchips
    hbm_dev = act_traffic + param_traffic + cache_traffic

    # ---- collective wire bytes per device ------------------------------------
    wire = 0.0
    if cfg.n_heads or cfg.family in ("ssm", "hybrid"):
        # TP: 2 all-reduces of the activation per layer (ring: ~2x size)
        wire += 2 * 2 * act_bytes_layer * L * (tp - 1) / tp
    if cell.kind == "train":
        if fsdp:
            # per-layer param all-gather fwd+bwd + grad reduce-scatter
            wire += 3 * (n_active / tp / dp) * 2 * (dp - 1)
        else:
            wire += 2 * (n_active / tp / dp) * 4 * (dp - 1) / dp  # grad all-reduce
    return {
        "flops": flops_dev,
        "bytes_accessed": hbm_dev,
        "wire_bytes": wire,
        "model_flops_per_chip": mf_global / nchips,
    }


def analyze(rec: Dict[str, Any]) -> Optional[Dict[str, Any]]:
    if rec.get("status") != "ok":
        return None
    nchips = chips(rec["mesh"])
    if rec.get("mode") == "unrolled":
        # fully-unrolled HLO: measured numbers are loop-complete
        flops = rec["flops"]
        hbm = rec["bytes_accessed"]
        wire = rec["collectives"].get("wire_bytes", rec["collectives"]["total_bytes"])
        src = "hlo"
    else:
        a = analytic_cell(
            rec["arch"], rec["shape"], rec["mesh"],
            remat=rec.get("remat", "full"), fsdp=rec.get("fsdp", True),
        )
        flops, hbm, wire = a["flops"], a["bytes_accessed"], a["wire_bytes"]
        src = "analytic"
    t_compute = flops / PEAK_FLOPS
    t_memory = hbm / HBM_BW
    t_coll = wire / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_cell(rec["arch"], rec["shape"]) / nchips
    useful = mf / flops if flops else 0.0
    bound = max(terms.values())
    # roofline fraction: useful work at peak over the modelled step time
    frac = (mf / PEAK_FLOPS) / bound if bound else 0.0
    hints = {
        "compute": "reduce recompute (remat policy) / increase arithmetic intensity",
        "memory": "fuse + keep working set in VMEM (kernel demotion), cast activations bf16",
        "collective": "reshard to cut all-gathers; overlap collectives with compute",
    }
    return {
        **{k: rec[k] for k in ("arch", "shape", "mesh", "kind")},
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_chip": mf,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "hint": hints[dominant],
        "temp_gib": rec["memory"]["temp_bytes"] / 2**30,
        "source": src,
    }


def markdown_table(rows: List[Dict[str, Any]], results: List[Dict[str, Any]]) -> str:
    out = [
        "| arch | shape | mesh | compute s | memory s | collective s | dominant "
        "| useful ratio | roofline frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | {r['t_collective_s']:.3e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} | {r['roofline_fraction']:.2f} "
            f"| {r['hint']} |"
        )
    for rec in results:
        if rec.get("status") == "skipped":
            out.append(
                f"| {rec['arch']} | {rec['shape']} | {rec['mesh']} | — | — | — "
                f"| skipped | — | — | {rec['skip_reason']} |"
            )
    return "\n".join(out)


def roofline_rows(path: str = RESULTS_FILE, mesh: str = "16x16") -> List[str]:
    """CSV rows for benchmarks.run (single-pod table per the assignment)."""
    try:
        results = load_results(path)
    except FileNotFoundError:
        return ["roofline_missing,0.0,run launch/dryrun.py first"]
    rows = []
    for rec in results:
        if rec["mesh"] != mesh or rec.get("mode") != "rolled":
            continue
        a = analyze(rec)
        if a is None:
            reason = rec.get("skip_reason", rec.get("error", ""))[:60]
            rows.append(f"roofline_{rec['arch']}_{rec['shape']},0.0,{rec['status']}:{reason}")
            continue
        dom_us = max(a["t_compute_s"], a["t_memory_s"], a["t_collective_s"]) * 1e6
        rows.append(
            f"roofline_{a['arch']}_{a['shape']},{dom_us:.1f},"
            f"dom={a['dominant']} frac={a['roofline_fraction']:.2f} useful={a['useful_ratio']:.2f}"
        )
    return rows


if __name__ == "__main__":
    results = load_results()
    rows = [a for r in results if (a := analyze(r))]
    print(markdown_table(rows, results))
