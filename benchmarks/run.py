# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

Sections:
  table1        occupancy before/after RegDem          (paper Table 1)
  fig6          variant speedups over nvcc             (paper Fig. 6)
  fig7          post-spilling optimization ablation    (paper Fig. 7)
  fig8          candidate-strategy comparison          (paper Fig. 8)
  fig9          predictor vs oracle vs naive           (paper Fig. 9)
  roofline      dry-run three-term roofline per cell   (EXPERIMENTS §Roofline)
  tpu_selector  TPU-adapted variant selector           (EXPERIMENTS §TPU)
  binary        pseudo-cubin codec throughput + sizes  (writes BENCH_binary.json)
  pipeline      batch-translate throughput, cache hit rate, per-pass breakdown
                (writes BENCH_pipeline.json)
  sim           simulator-engine throughput + sim-cache behaviour vs the
                recorded pre-optimization baseline     (writes BENCH_sim.json)
  arch          cross-architecture Table-3 demotion results + occupancy
                comparison over every registered arch  (writes BENCH_arch.json)
  search        predictor-guided autotuning search vs the fixed variant set
                over all 9 benchmarks x every arch    (writes BENCH_search.json)
  corpus        the real-workload Pallas corpus (repro.data.corpus) through
                the same anchored search, every arch  (writes BENCH_corpus.json)
  obs           telemetry overhead (enabled vs disabled) + span throughput
                (writes BENCH_obs.json)
  serve         translation-daemon latency, warm-restart hit rate, and the
                serving invariant under a fault storm (writes BENCH_serve.json)

Run all: ``PYTHONPATH=src python -m benchmarks.run``
Some sections: ``... -m benchmarks.run --only fig6,fig7`` (comma-separated
and/or repeated ``--only``); an unknown section name is an error.
``--trace out.json`` records telemetry for the whole harness run and writes
a Chrome trace (load in chrome://tracing or Perfetto); ``--trace out.jsonl``
writes the JSONL event log instead.
"""

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--only",
        action="append",
        default=None,
        metavar="SECTION[,SECTION...]",
        help="run only these sections (comma-separated, repeatable): "
             "table1|fig6|fig7|fig8|fig9|roofline|tpu_selector|binary|"
             "pipeline|sim|arch|search|corpus|obs|serve",
    )
    ap.add_argument("--binary-json", default=None, metavar="PATH",
                    help="where the binary section writes its JSON report "
                         "(default: BENCH_binary.json in the cwd)")
    ap.add_argument("--pipeline-json", default=None, metavar="PATH",
                    help="where the pipeline section writes its JSON report "
                         "(default: BENCH_pipeline.json in the cwd)")
    ap.add_argument("--sim-json", default=None, metavar="PATH",
                    help="where the sim section writes its JSON report "
                         "(default: BENCH_sim.json in the cwd)")
    ap.add_argument("--arch-json", default=None, metavar="PATH",
                    help="where the arch section writes its JSON report "
                         "(default: BENCH_arch.json in the cwd)")
    ap.add_argument("--search-json", default=None, metavar="PATH",
                    help="where the search section writes its JSON report "
                         "(default: BENCH_search.json in the cwd)")
    ap.add_argument("--search-workers", type=int, default=0, metavar="N",
                    help="process-pool size for the search section "
                         "(default: in-process; results are identical)")
    ap.add_argument("--corpus-json", default=None, metavar="PATH",
                    help="where the corpus section writes its JSON report "
                         "(default: BENCH_corpus.json in the cwd)")
    ap.add_argument("--obs-json", default=None, metavar="PATH",
                    help="where the obs section writes its JSON report "
                         "(default: BENCH_obs.json in the cwd)")
    ap.add_argument("--serve-json", default=None, metavar="PATH",
                    help="where the serve section writes its JSON report "
                         "(default: BENCH_serve.json in the cwd)")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record telemetry for the whole run and write a "
                         "Chrome trace (.json) or JSONL event log (.jsonl)")
    args = ap.parse_args()

    from benchmarks import (
        arch_bench,
        binary_bench,
        corpus_bench,
        obs_bench,
        paper_figs,
        pipeline_bench,
        roofline,
        search_bench,
        serve_bench,
        sim_bench,
        tpu_selector,
    )

    def binary_rows():
        return binary_bench.binary_rows(args.binary_json or binary_bench.JSON_PATH)

    def pipeline_rows():
        return pipeline_bench.pipeline_rows(args.pipeline_json or pipeline_bench.JSON_PATH)

    def sim_rows():
        return sim_bench.sim_rows(args.sim_json or sim_bench.JSON_PATH)

    def arch_rows():
        return arch_bench.arch_rows(args.arch_json or arch_bench.JSON_PATH)

    def search_rows():
        return search_bench.search_rows(
            args.search_json or search_bench.JSON_PATH,
            workers=args.search_workers,
        )

    def corpus_rows():
        return corpus_bench.corpus_rows(
            args.corpus_json or corpus_bench.JSON_PATH,
            workers=args.search_workers,
        )

    def obs_rows():
        return obs_bench.obs_rows(args.obs_json or obs_bench.JSON_PATH)

    def serve_rows():
        return serve_bench.serve_rows(args.serve_json or serve_bench.JSON_PATH)

    sections = {
        "table1": paper_figs.table1_occupancy,
        "fig6": paper_figs.fig6_speedups,
        "fig7": paper_figs.fig7_postopt,
        "fig8": paper_figs.fig8_candidates,
        "fig9": paper_figs.fig9_predictor,
        "roofline": roofline.roofline_rows,
        "tpu_selector": tpu_selector.selector_rows,
        "binary": binary_rows,
        "pipeline": pipeline_rows,
        "sim": sim_rows,
        "arch": arch_rows,
        "search": search_rows,
        "corpus": corpus_rows,
        "obs": obs_rows,
        "serve": serve_rows,
    }

    selected = None
    if args.only is not None:
        selected = []
        for chunk in args.only:
            selected.extend(s.strip() for s in chunk.split(",") if s.strip())
        unknown = sorted(set(selected) - set(sections))
        if unknown:
            ap.error(
                f"unknown --only section(s): {', '.join(unknown)} "
                f"(choose from: {', '.join(sections)})"
            )
        if not selected:
            # "--only ''" / "--only ," must not silently run zero sections
            ap.error(f"--only selected no sections (choose from: {', '.join(sections)})")

    if args.trace:
        from repro import obs

        obs.enable()

    print("name,us_per_call,derived")
    for name, fn in sections.items():
        if selected is not None and name not in selected:
            continue
        t0 = time.time()
        for row in fn():
            print(row)
        print(f"section_{name}_wall,{(time.time()-t0)*1e6:.0f},elapsed", file=sys.stderr)

    if args.trace:
        fmt = obs.write_trace(args.trace)
        spans = obs.get_telemetry().event_count()
        print(f"trace: {spans} spans -> {args.trace} ({fmt})", file=sys.stderr)


if __name__ == "__main__":
    main()
