"""Pass-pipeline / translation-service benchmarks.

Measures the batch binary-translation service end to end: a multi-kernel v2
container (with a repeated kernel) is translated cold (every kernel runs the
pass pipeline) and then warm (every kernel served from the content-CRC
translation cache), giving batch throughput, cache hit rate, and a per-pass
wall-time breakdown.  Rows follow the harness CSV contract
(``name,us_per_call,derived``); the same numbers are written to
``BENCH_pipeline.json`` so the performance trajectory accumulates
machine-readably across PRs.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Optional

from repro.binary import dumps
from repro.core.kernelgen import paper_kernel
from repro.core.regdem import RegDemOptions
from repro.core.translator import TranslationService

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative, i.e. the
#: repo root under the documented ``python -m benchmarks.run`` invocation).
JSON_PATH = "BENCH_pipeline.json"

#: Batch composition: four distinct Table-1 kernels, each appearing twice,
#: so even the cold call exercises the cache on the duplicates.
BATCH_NAMES = ["md5hash", "nn", "conv", "pc", "md5hash", "nn", "conv", "pc"]


def pipeline_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_pipeline.json`` as a side effect."""
    kernels = [paper_kernel(n) for n in BATCH_NAMES]
    blob = dumps(kernels)
    n_kernels = len(kernels)
    n_instrs = sum(len(k.instructions()) for k in kernels)

    # one grouped option set keeps the enumeration representative but cheap
    service = TranslationService(options=[RegDemOptions()])

    t0 = time.perf_counter()
    out_cold, rep_cold = service.translate(blob)
    cold_s = time.perf_counter() - t0

    t0 = time.perf_counter()
    out_warm, rep_warm = service.translate(blob)
    warm_s = time.perf_counter() - t0
    assert out_warm == out_cold, "warm batch must be byte-identical"

    # per-pass wall-time breakdown over every pipeline the cold call ran
    # (cache-hit entries share the miss's report object — skip them so
    # passes are not double-counted)
    passes: Dict[str, Dict[str, float]] = {}
    for rep, was_cached in zip(rep_cold.reports, rep_cold.cached):
        if was_cached:
            continue
        for stats in rep.pass_stats.values():
            for p in stats:
                agg = passes.setdefault(p.name, {"calls": 0, "total_ms": 0.0})
                agg["calls"] += 1
                agg["total_ms"] += p.seconds * 1e3
    total_pass_ms = sum(a["total_ms"] for a in passes.values()) or 1.0
    for agg in passes.values():
        agg["total_ms"] = round(agg["total_ms"], 3)
        agg["share"] = round(agg["total_ms"] / total_pass_ms, 3)

    report = {
        "batch": {
            "kernels": n_kernels,
            "unique_kernels": len(set(BATCH_NAMES)),
            "instrs": n_instrs,
            "container_bytes_in": len(blob),
            "container_bytes_out": len(out_cold),
            "cold_us_per_kernel": round(cold_s * 1e6 / n_kernels, 1),
            "warm_us_per_kernel": round(warm_s * 1e6 / n_kernels, 1),
            "cold_kernels_per_s": round(n_kernels / cold_s, 1),
            "warm_kernels_per_s": round(n_kernels / warm_s, 1),
            "warm_speedup": round(cold_s / warm_s, 1),
        },
        "cache": {
            "cold_hits": rep_cold.cache_hits,
            "cold_misses": rep_cold.cache_misses,
            "cold_hit_rate": round(rep_cold.hit_rate, 3),
            "warm_hits": rep_warm.cache_hits,
            "warm_misses": rep_warm.cache_misses,
            "warm_hit_rate": round(rep_warm.hit_rate, 3),
        },
        "passes": passes,
    }
    if json_path:
        write_json_atomic(json_path, report)

    b, c = report["batch"], report["cache"]
    yield (
        f"pipeline_batch_cold,{cold_s * 1e6 / n_kernels:.1f},"
        f"kernels_per_s={b['cold_kernels_per_s']};hit_rate={c['cold_hit_rate']}"
    )
    yield (
        f"pipeline_batch_warm,{warm_s * 1e6 / n_kernels:.1f},"
        f"kernels_per_s={b['warm_kernels_per_s']};hit_rate={c['warm_hit_rate']}"
    )
    yield f"pipeline_cache_speedup,0.00,warm_speedup={b['warm_speedup']}x"
    for name in sorted(passes):
        agg = passes[name]
        yield (
            f"pipeline_pass_{name},{agg['total_ms'] * 1e3 / max(agg['calls'], 1):.1f},"
            f"calls={agg['calls']};share={agg['share']}"
        )
