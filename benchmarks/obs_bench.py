"""Telemetry-overhead benchmarks (the repro.obs cost contract).

The observability layer's design promise is *near-zero overhead when
disabled*: ``obs.span()`` with telemetry off is one attribute check
returning a shared no-op.  This section measures that promise on the real
pipeline-bench workload (a cold batch translation of four Table-1 kernels)
three ways:

* **overhead_pct** — the *attributable* enabled-mode tax: spans recorded
  per batch x the measured per-span record cost, as a share of the batch's
  disabled-mode wall time.  (An end-to-end enabled-vs-disabled diff cannot
  resolve a sub-2% effect on a shared machine — run-to-run noise is an
  order of magnitude larger — so the headline is computed from the two
  stable micro-measurements; the noisy paired diff still ships as
  ``paired_delta_pct`` for the curious.)  The budget is <=2%;
* **events_per_s** — span record throughput in isolation, enabled (the
  trend-gated headline: a slowdown in the span hot path shows up here);
* **null_span_ns** — the disabled-mode ``span()`` call in isolation.

Rows follow the harness CSV contract (``name,us_per_call,derived``); the
same numbers land in ``BENCH_obs.json`` for the CI trend gate.
"""

from __future__ import annotations

import time
from typing import Iterator, Optional

from repro import obs
from repro.binary import dumps
from repro.core.kernelgen import paper_kernel
from repro.core.regdem import RegDemOptions
from repro.core.translator import TranslationService

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative).
JSON_PATH = "BENCH_obs.json"

#: Cold-translation workload: four distinct Table-1 kernels (the
#: pipeline-bench batch without the duplicates — every kernel runs the
#: full pass pipeline every repetition).
BATCH_NAMES = ["md5hash", "nn", "conv", "pc"]

#: Measured (disabled, enabled) pairs for the informational end-to-end
#: delta.  Each pair runs back-to-back (shared noise cancels), in-pair
#: order alternates (back-to-back runs are not identically costed, so a
#: fixed order would bias the sign), and the median discards the pairs a
#: scheduler hiccup landed in.  Even so, per-pair noise on a shared
#: machine is +-10-35%% — which is exactly why this number is *not* the
#: headline.
REPS = 6


def _workload(blob: bytes) -> float:
    """One cold batch translation on a fresh service; returns seconds."""
    service = TranslationService(options=[RegDemOptions()])
    t0 = time.perf_counter()
    service.translate(blob)
    return time.perf_counter() - t0


def obs_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_obs.json`` as a side effect."""
    kernels = [paper_kernel(n) for n in BATCH_NAMES]
    blob = dumps(kernels)
    n_kernels = len(kernels)

    # the bench toggles and resets the process-wide telemetry; stash whatever
    # the caller recorded so far (e.g. ``benchmarks.run --trace``) and put it
    # back afterwards
    was_enabled = obs.enabled()
    prior_events = obs.get_telemetry().export_events(0)
    prior_metrics = obs.metrics().export()
    try:
        obs.disable()
        # warm-up: fills the process-wide predictor/sim caches once, so
        # every *measured* run below does identical (warm) work
        _workload(blob)

        # -- disabled vs enabled, paired, alternating in-pair order ----------
        disabled_runs: list = []
        enabled_runs: list = []
        pair_deltas: list = []
        events = 0

        def run_enabled() -> float:
            nonlocal events
            obs.reset()
            obs.enable()
            s = _workload(blob)
            obs.disable()
            if not enabled_runs or s < min(enabled_runs):
                events = obs.get_telemetry().event_count()
            enabled_runs.append(s)
            return s

        for i in range(REPS):
            if i % 2:
                e = run_enabled()
                d = _workload(blob)
            else:
                d = _workload(blob)
                e = run_enabled()
            disabled_runs.append(d)
            pair_deltas.append((e - d) / d)
        disabled_s = min(disabled_runs)
        enabled_s = min(enabled_runs)
        pair_deltas.sort()
        mid = len(pair_deltas) // 2
        paired_delta = (
            pair_deltas[mid]
            if len(pair_deltas) % 2
            else (pair_deltas[mid - 1] + pair_deltas[mid]) / 2
        )

        # -- span recording throughput in isolation (the trend headline) -----
        obs.reset()
        obs.enable()
        n_spans = 50_000
        t0 = time.perf_counter()
        for _ in range(n_spans):
            with obs.span("bench"):
                pass
        span_record_s = time.perf_counter() - t0
        obs.disable()

        # -- the disabled no-op span in isolation -----------------------------
        n_calls = 200_000
        t0 = time.perf_counter()
        for _ in range(n_calls):
            with obs.span("noop"):
                pass
        null_span_ns = (time.perf_counter() - t0) / n_calls * 1e9
    finally:
        obs.reset()
        obs.get_telemetry().adopt(prior_events)
        obs.metrics().merge(prior_metrics)
        (obs.enable if was_enabled else obs.disable)()

    events_per_s = n_spans / span_record_s if span_record_s else 0.0
    span_cost_s = span_record_s / n_spans
    # the stable headline: every span the enabled batch records costs one
    # measured span-record unit; everything else in the hot path is a
    # handful of gated dict operations (well under a span each)
    overhead_pct = (events * span_cost_s) / disabled_s * 100.0 if disabled_s else 0.0
    paired_delta_pct = paired_delta * 100.0

    report = {
        "overhead": {
            "disabled_us_per_kernel": round(disabled_s * 1e6 / n_kernels, 1),
            "enabled_us_per_kernel": round(enabled_s * 1e6 / n_kernels, 1),
            "overhead_pct": round(overhead_pct, 3),
            "paired_delta_pct": round(paired_delta_pct, 2),
        },
        "events": {
            "spans_per_batch": events,
            "events_per_s": round(events_per_s, 1),
            "null_span_ns": round(null_span_ns, 1),
        },
    }
    if json_path:
        write_json_atomic(json_path, report)

    o, e = report["overhead"], report["events"]
    yield (
        f"obs_disabled,{disabled_s * 1e6 / n_kernels:.1f},"
        f"us_per_kernel={o['disabled_us_per_kernel']}"
    )
    yield (
        f"obs_enabled,{enabled_s * 1e6 / n_kernels:.1f},"
        f"overhead_pct={o['overhead_pct']}"
    )
    yield f"obs_events,{1e6 / events_per_s if events_per_s else 0.0:.3f},events_per_s={e['events_per_s']}"
    yield f"obs_null_span,{null_span_ns / 1e3:.4f},ns_per_call={e['null_span_ns']}"
