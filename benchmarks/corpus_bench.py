"""Real-workload corpus benchmarks: the extracted Pallas profiles, tuned.

Runs the same anchored predictor-guided search as :mod:`benchmarks.
search_bench` — byte-for-byte the same :func:`~benchmarks.search_bench.
tune_profile` cell — but over :data:`repro.data.corpus.CORPUS_BENCHMARKS`,
the ~22 profiles extracted from the in-repo flash-attention / Mamba2-SSD
Pallas kernels across every model config and serving phase.  This is the
"does the paper's machinery survive contact with kernels nobody
hand-picked?" benchmark:

* ``win``            fixed-§5.3-pick cycles / search-pick cycles per cell
                     (anchoring guarantees >= 1.0; the trend gate holds the
                     geomean non-decreasing);
* ``speedup_vs_nvcc``  search pick vs the untouched baseline;
* ``family_hist``    which strategy family wins on *real* register/smem
                     mixes (decode cells with tiny register counts and big
                     kv-tile smem behave nothing like Table 1);
* ``phase_wins``     geomean win split by serving phase (prefill vs
                     decode), the corpus-specific axis.

Writes ``BENCH_corpus.json`` atomically.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, List, Optional

from repro.arch import arch_names
from repro.data.corpus import CORPUS_BENCHMARKS

from ._util import write_json_atomic
from .search_bench import NEW_FAMILIES, _geomean, chosen_family, tune_profile

#: Default location of the machine-readable report (cwd-relative).
JSON_PATH = "BENCH_corpus.json"


def measure(workers: int = 0) -> Dict[str, Dict]:
    """The full corpus-x-every-arch sweep as a report dict."""
    archs = arch_names()
    report: Dict[str, Dict] = {"kernels": {}, "summary": {}}
    explored_total = 0
    searches = 0
    agreements: List[float] = []
    wins: List[float] = []
    speedups: List[float] = []
    strict_wins = 0
    beats_or_ties = 0
    search_seconds = 0.0
    family_hist: Dict[str, int] = {}
    strategy_wins: Dict[str, int] = {}
    phase_wins: Dict[str, List[float]] = {"prefill": [], "decode": []}
    new_family_wins = 0

    t0 = time.perf_counter()
    for name, prof in CORPUS_BENCHMARKS.items():
        report["kernels"][name] = {}
        phase = name.split(".")[1]
        for arch in archs:
            row = tune_profile(prof, arch, workers=workers)
            report["kernels"][name][arch] = row
            explored_total += row["explored"]
            searches += 1
            search_seconds += row["seconds"]
            agreements.append(row["agreement"])
            win = row["cycles_fixed"] / row["cycles_chosen"]
            wins.append(win)
            speedups.append(row["speedup_vs_nvcc"])
            strict_wins += row["cycles_chosen"] < row["cycles_fixed"]
            beats_or_ties += row["cycles_chosen"] <= row["cycles_fixed"]
            phase_wins[phase].append(win)
            family, strat = chosen_family(row["chosen"])
            family_hist[family] = family_hist.get(family, 0) + 1
            if strat is not None:
                strategy_wins[strat] = strategy_wins.get(strat, 0) + 1
            new_family_wins += family in NEW_FAMILIES
    elapsed = time.perf_counter() - t0

    report["summary"] = {
        "profiles": len(report["kernels"]),
        "searches": searches,
        "explored": explored_total,
        "variants_per_s": round(explored_total / search_seconds, 2)
        if search_seconds
        else 0.0,
        "mean_agreement": round(sum(agreements) / len(agreements), 4),
        "geomean_win": round(_geomean(wins), 4),
        "geomean_speedup_vs_nvcc": round(_geomean(speedups), 4),
        "strict_wins": strict_wins,
        "beats_or_ties": beats_or_ties,
        "phase_geomean_win": {
            ph: round(_geomean(ws), 4) for ph, ws in phase_wins.items() if ws
        },
        "family_hist": dict(sorted(family_hist.items())),
        "strategy_wins": dict(sorted(strategy_wins.items())),
        "new_family_wins": new_family_wins,
        "seconds": round(elapsed, 3),
        "workers": workers,
    }
    return report


def corpus_rows(
    json_path: Optional[str] = JSON_PATH, workers: int = 0
) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_corpus.json`` as a side effect."""
    report = measure(workers=workers)
    for name, per_arch in report["kernels"].items():
        for arch, row in per_arch.items():
            yield (
                f"corpus_{arch}_{name},{row['seconds'] * 1e6:.0f},"
                f"chosen={row['chosen']};win={round(row['win'], 3)};"
                f"speedup={round(row['speedup_vs_nvcc'], 3)};"
                f"agreement={round(row['agreement'], 3)}"
            )
    if json_path:
        write_json_atomic(json_path, report)
    s = report["summary"]
    yield (
        f"corpus_summary,{s['seconds'] * 1e6:.0f},"
        f"profiles={s['profiles']};"
        f"geomean_win={s['geomean_win']};"
        f"beats_or_ties={s['beats_or_ties']}/{s['searches']};"
        f"new_family_wins={s['new_family_wins']};"
        f"mean_agreement={s['mean_agreement']}"
    )
