"""Cross-architecture benchmarks: RegDem on every registered backend.

For each Table-1 benchmark and each registered architecture, the kernel is
ported to the arch (:func:`repro.arch.retarget` re-schedules it under that
arch's machine model), demoted to its Table-1 register target, and graded
on the timing simulator — a Table-3-style ``nvcc`` vs ``regdem`` result per
architecture, plus a cross-arch occupancy comparison and per-arch container
footprints (Volta's in-word control encoding trades bundle padding for a
larger per-instruction record).

Everything except the throughput row is deterministic, which is what lets
``tests/test_arch.py`` pin a cross-arch demotion result against the
committed ``BENCH_arch.json``.
"""

from __future__ import annotations

import time
from typing import Dict, Iterator, Optional

from repro.arch import arch_names, get_arch, retarget
from repro.binary import dumps
from repro.core.kernelgen import PAPER_BENCHMARKS, generate
from repro.core.occupancy import occupancy_of
from repro.core.regdem import demote
from repro.core.simulator import simulate, speedup

from ._util import write_json_atomic

#: Default location of the machine-readable report (cwd-relative, i.e. the
#: repo root under the documented ``python -m benchmarks.run`` invocation).
JSON_PATH = "BENCH_arch.json"


def arch_rows(json_path: Optional[str] = JSON_PATH) -> Iterator[str]:
    """Yield CSV rows; write ``BENCH_arch.json`` as a side effect."""
    archs = arch_names()
    report: Dict[str, Dict] = {
        "archs": {name: get_arch(name).describe() for name in archs},
        "table3": {},
        "occupancy": {},
        "container": {},
    }

    t0 = time.perf_counter()
    n_pipelines = 0
    for bench, prof in PAPER_BENCHMARKS.items():
        base = generate(prof)
        report["table3"][bench] = {}
        report["occupancy"][bench] = {}
        report["container"][bench] = {}
        for name in archs:
            k = base if name == "maxwell" else retarget(base, name)
            res = demote(k, prof.regdem_target, verify="final")
            n_pipelines += 1
            occ_before = occupancy_of(k)
            occ_after = occupancy_of(res.kernel)
            sim_nvcc = simulate(k)
            sim_regdem = simulate(res.kernel)
            spd = speedup(sim_nvcc, sim_regdem)
            report["table3"][bench][name] = {
                "baseline_regs": k.reg_count,
                "target_regs": prof.regdem_target,
                "demoted_words": res.demoted_words,
                "regs_after": res.kernel.reg_count,
                "demoted_smem_bytes": res.kernel.demoted_size,
                "cycles_nvcc": sim_nvcc.total_cycles,
                "cycles_regdem": sim_regdem.total_cycles,
                "sim_speedup": round(spd, 4),
            }
            report["occupancy"][bench][name] = {
                "before": round(occ_before.occupancy, 4),
                "after": round(occ_after.occupancy, 4),
                "limiter_before": occ_before.limiter,
                "limiter_after": occ_after.limiter,
            }
            report["container"][bench][name] = {
                "bytes": len(dumps(res.kernel)),
                "instrs": len(res.kernel.instructions()),
            }
            yield (
                f"arch_{name}_{bench},0.00,"
                f"demoted={res.demoted_words};speedup={round(spd, 3)};"
                f"occ={round(occ_before.occupancy, 3)}->{round(occ_after.occupancy, 3)}"
            )
    elapsed = time.perf_counter() - t0

    report["timing"] = {
        "pipelines": n_pipelines,
        "seconds": round(elapsed, 3),
        "pipelines_per_s": round(n_pipelines / elapsed, 2),
    }
    # headline cross-arch summary: geometric-mean speedup per arch
    summary: Dict[str, float] = {}
    for name in archs:
        spds = [report["table3"][b][name]["sim_speedup"] for b in report["table3"]]
        prod = 1.0
        for s in spds:
            prod *= s
        summary[name] = round(prod ** (1 / len(spds)), 4)
    report["geomean_speedup"] = summary

    if json_path:
        write_json_atomic(json_path, report)
    for name in archs:
        yield f"arch_geomean_{name},0.00,speedup={summary[name]}"
    yield (
        f"arch_corpus,{elapsed * 1e6 / n_pipelines:.1f},"
        f"pipelines_per_s={report['timing']['pipelines_per_s']};"
        f"archs={len(archs)}"
    )
