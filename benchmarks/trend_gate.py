"""CI perf-trend gate: compare fresh ``BENCH_*.json`` against baselines.

The benchmark harness writes machine-readable reports (``BENCH_binary``,
``BENCH_pipeline``, ``BENCH_sim``, ``BENCH_arch``); the repo commits them
as the performance baseline.  This gate re-reads a freshly measured set and
fails when a *headline* metric regressed beyond the tolerance — throughput
metrics (kernels/s) may not drop more than ``--tolerance`` relative to the
baseline, latency metrics (ns/instr) may not grow more than it, and cache
hit rates may not fall more than it.  Improvements always pass (and are
reported, so a stale baseline is visible in the job log).

Usage (what ``.github/workflows/ci.yml`` runs)::

    python -m benchmarks.run --only binary,pipeline,sim \
        --binary-json fresh/BENCH_binary.json \
        --pipeline-json fresh/BENCH_pipeline.json \
        --sim-json fresh/BENCH_sim.json
    python -m benchmarks.trend_gate --baseline-dir . --fresh-dir fresh

Exit status: 0 = within tolerance, 1 = regression, 2 = missing/corrupt
report (a truncated baseline would mean the atomic-write contract broke).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Iterator, List, Optional, Tuple

#: (file, path-into-json, direction) per headline metric.  Direction:
#: "higher" = regression when the fresh value drops below
#: baseline*(1-tol); "lower" = regression when it grows above
#: baseline*(1+tol).
METRICS: List[Tuple[str, Tuple[str, ...], str]] = [
    ("BENCH_binary.json", ("summary", "encode_ns_per_instr"), "lower"),
    ("BENCH_binary.json", ("summary", "decode_ns_per_instr"), "lower"),
    ("BENCH_pipeline.json", ("batch", "cold_kernels_per_s"), "higher"),
    ("BENCH_pipeline.json", ("batch", "warm_kernels_per_s"), "higher"),
    ("BENCH_pipeline.json", ("cache", "warm_hit_rate"), "higher"),
    ("BENCH_sim.json", ("engine", "kernels_per_s"), "higher"),
    ("BENCH_sim.json", ("engine", "batch_kernels_per_s"), "higher"),
    ("BENCH_sim.json", ("engine", "incremental_reuse_rate"), "higher"),
    ("BENCH_sim.json", ("cache", "warm_hit_rate"), "higher"),
    ("BENCH_search.json", ("summary", "variants_per_s"), "higher"),
    ("BENCH_search.json", ("summary", "mean_agreement"), "higher"),
    ("BENCH_search.json", ("summary", "geomean_win"), "higher"),
    # cells won by a related-work strategy family (warp_share/block_share/
    # compressed): the registry's new families must keep earning their keep
    ("BENCH_search.json", ("summary", "new_family_wins"), "higher"),
    # the real-workload corpus must keep beating-or-tying the fixed pick
    # (geomean_win >= 1.0 by anchoring) and the predictor must stay honest
    # on extracted profiles, not just the synthetic nine
    ("BENCH_corpus.json", ("summary", "geomean_win"), "higher"),
    ("BENCH_corpus.json", ("summary", "mean_agreement"), "higher"),
    ("BENCH_corpus.json", ("summary", "geomean_speedup_vs_nvcc"), "higher"),
    # overhead percentages are too noisy for a relative gate; the span
    # recording throughput is the stable telemetry headline
    ("BENCH_obs.json", ("events", "events_per_s"), "higher"),
    # serving: p50/p99 latencies ship in the report but are not gated
    # (absolute wall-clock on shared CI is too noisy); the gated headlines
    # are warm throughput and the two deterministic correctness rates
    ("BENCH_serve.json", ("serve", "requests_per_s"), "higher"),
    ("BENCH_serve.json", ("warm", "hit_rate"), "higher"),
    ("BENCH_serve.json", ("faults", "degraded_ok_rate"), "higher"),
]

DEFAULT_TOLERANCE = 0.30


class GateError(RuntimeError):
    """A report file is missing or unreadable."""


def _load(path: str) -> dict:
    try:
        with open(path) as fh:
            return json.load(fh)
    except FileNotFoundError:
        raise GateError(f"missing report {path}") from None
    except json.JSONDecodeError as exc:
        raise GateError(f"corrupt report {path}: {exc}") from None


def _lookup(report: dict, path: Tuple[str, ...], origin: str) -> float:
    node = report
    for key in path:
        if not isinstance(node, dict) or key not in node:
            raise GateError(f"{origin}: metric {'.'.join(path)} not found")
        node = node[key]
    if not isinstance(node, (int, float)):
        raise GateError(f"{origin}: metric {'.'.join(path)} is not a number")
    return float(node)


def compare(
    baseline_dir: str,
    fresh_dir: str,
    tolerance: float = DEFAULT_TOLERANCE,
    metrics: Optional[List[Tuple[str, Tuple[str, ...], str]]] = None,
) -> Iterator[Tuple[str, float, float, str]]:
    """Yield ``(metric, baseline, fresh, verdict)`` per headline metric;
    verdict is ``"ok"``, ``"improved"``, ``"REGRESSED"``, or
    ``"no-baseline"``.

    A fresh report with **no committed baseline at all** is warned about and
    skipped (verdict ``"no-baseline"``, baseline reported as ``nan``) rather
    than failing the gate: that is exactly the state of the first CI run
    after a new benchmark section lands, before its ``BENCH_*.json`` is
    committed.  A *corrupt* baseline, a missing metric inside an existing
    baseline, or a missing fresh report remain hard errors — those mean the
    atomic-write contract or the harness broke, not that a section is new.
    """
    cache: dict = {}
    for fname, path, direction in metrics or METRICS:
        base_path = os.path.join(baseline_dir, fname)
        if base_path not in cache:
            cache[base_path] = (
                _load(base_path) if os.path.exists(base_path) else None
            )
        fresh_path = os.path.join(fresh_dir, fname)
        if fresh_path not in cache:
            cache[fresh_path] = _load(fresh_path)
        label = f"{fname}:{'.'.join(path)}"
        new = _lookup(cache[fresh_path], path, f"fresh {fname}")
        if cache[base_path] is None:
            yield label, float("nan"), new, "no-baseline"
            continue
        base = _lookup(cache[base_path], path, f"baseline {fname}")
        if direction == "higher":
            if new < base * (1 - tolerance):
                verdict = "REGRESSED"
            elif new > base * (1 + tolerance):
                verdict = "improved"
            else:
                verdict = "ok"
        else:
            if new > base * (1 + tolerance):
                verdict = "REGRESSED"
            elif new < base * (1 - tolerance):
                verdict = "improved"
            else:
                verdict = "ok"
        yield label, base, new, verdict


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline-dir", default=".",
                    help="directory holding the committed BENCH_*.json baselines")
    ap.add_argument("--fresh-dir", default="fresh",
                    help="directory holding the freshly measured BENCH_*.json")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="relative tolerance before a change counts as a "
                         "regression (default 0.30 = +-30%%)")
    args = ap.parse_args(argv)

    try:
        rows = list(compare(args.baseline_dir, args.fresh_dir, args.tolerance))
    except GateError as exc:
        print(f"trend-gate error: {exc}", file=sys.stderr)
        return 2

    width = max(len(r[0]) for r in rows)
    failed = False
    skipped = 0
    for label, base, new, verdict in rows:
        if verdict == "no-baseline":
            skipped += 1
            print(f"{label:<{width}}  baseline=<missing>  fresh={new:<10g} "
                  f"         {verdict}")
            continue
        delta = (new - base) / base * 100 if base else float("inf")
        print(f"{label:<{width}}  baseline={base:<10g} fresh={new:<10g} "
              f"{delta:+7.1f}%  {verdict}")
        failed = failed or verdict == "REGRESSED"
    if skipped:
        print(
            f"\nWARNING: {skipped} metric(s) have no committed baseline yet "
            "and were skipped — commit the freshly measured BENCH_*.json to "
            "start gating them.",
            file=sys.stderr,
        )
    if failed:
        print(
            f"\nFAIL: headline metric regressed beyond +-{args.tolerance:.0%} "
            "of the committed baseline.  If the change is intentional, rerun "
            "`python -m benchmarks.run --only binary,pipeline,sim` and commit "
            "the refreshed BENCH_*.json.",
            file=sys.stderr,
        )
        return 1
    print("\nOK: all headline metrics within tolerance of the committed baseline.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
