"""Regenerate the §Dry-run and §Roofline tables inside EXPERIMENTS.md.

    PYTHONPATH=src python benchmarks/report.py
"""

from __future__ import annotations

import re
import sys

sys.path.insert(0, ".")

from benchmarks.roofline import analyze, load_results


def dryrun_table(results) -> str:
    rows = [
        "| arch | shape | mesh | status | compile s | temp GiB | collectives (static) | wire GiB |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(results, key=lambda x: (x["arch"], x["shape"], x["mesh"])):
        if r.get("mode") != "rolled":
            continue
        if r["status"] == "ok":
            c = r["collectives"]["counts"]
            cstr = " ".join(f"{k.split('-')[-1][:4]}:{v}" for k, v in c.items() if v)
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | {r['compile_s']} "
                f"| {r['memory']['temp_bytes']/2**30:.1f} | {cstr or '—'} "
                f"| {r['collectives'].get('wire_bytes',0)/2**30:.2f} |"
            )
        else:
            reason = (r.get("skip_reason") or r.get("error", ""))[:70]
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | — | — | — | {reason} |"
            )
    return "\n".join(rows)


def roofline_table(results) -> str:
    rows = [
        "| arch | shape | compute s | memory s | collective s | dominant | useful | roofline frac | source |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(results, key=lambda x: (x["arch"], x["shape"])):
        if r["mesh"] != "16x16" or r.get("mode") != "rolled":
            continue
        a = analyze(r)
        if a is None:
            rows.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | skipped | — | — "
                f"| {(r.get('skip_reason') or '')[:50]} |"
            )
            continue
        rows.append(
            f"| {a['arch']} | {a['shape']} | {a['t_compute_s']:.2e} | {a['t_memory_s']:.2e} "
            f"| {a['t_collective_s']:.2e} | **{a['dominant']}** | {a['useful_ratio']:.2f} "
            f"| {a['roofline_fraction']:.2f} | {a['source']} |"
        )
    return "\n".join(rows)


def main() -> None:
    results = load_results("dryrun_results.json")
    with open("EXPERIMENTS.md") as f:
        doc = f.read()
    doc = re.sub(
        r"<!-- DRYRUN_TABLE -->.*?(?=\n## §Roofline)",
        "<!-- DRYRUN_TABLE -->\n" + dryrun_table(results) + "\n",
        doc,
        flags=re.S,
    )
    doc = re.sub(
        r"<!-- ROOFLINE_TABLE -->.*?(?=\n---\n\n## §Perf)",
        "<!-- ROOFLINE_TABLE -->\n" + roofline_table(results) + "\n",
        doc,
        flags=re.S,
    )
    with open("EXPERIMENTS.md", "w") as f:
        f.write(doc)
    n_ok = sum(r["status"] == "ok" and r.get("mode") == "rolled" for r in results)
    n_skip = sum(r["status"] == "skipped" and r.get("mode") == "rolled" for r in results)
    print(f"EXPERIMENTS.md updated: {n_ok} ok + {n_skip} skipped rolled cells")


if __name__ == "__main__":
    main()
