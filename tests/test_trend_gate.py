"""CI perf-trend gate behaviour (benchmarks.trend_gate).

Pins the warn-and-skip contract: a fresh report whose baseline was never
committed (exactly the first CI run after a new benchmark section lands)
must be reported and skipped, not crash the gate — while corrupt baselines,
missing metrics, and genuine regressions stay hard failures.
"""

import json

import pytest

from benchmarks import trend_gate

M_THROUGHPUT = [("BENCH_x.json", ("summary", "kernels_per_s"), "higher")]


def _write(directory, fname, obj):
    path = directory / fname
    path.write_text(json.dumps(obj))
    return path


def test_within_tolerance_ok(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    _write(base, "BENCH_x.json", {"summary": {"kernels_per_s": 100.0}})
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 95.0}})
    rows = list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))
    assert [r[3] for r in rows] == ["ok"]


def test_regression_detected(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    _write(base, "BENCH_x.json", {"summary": {"kernels_per_s": 100.0}})
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 50.0}})
    rows = list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))
    assert [r[3] for r in rows] == ["REGRESSED"]


def test_missing_baseline_warns_and_skips(tmp_path):
    """No committed baseline file at all: verdict no-baseline, no error."""
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 50.0}})
    rows = list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))
    (label, baseline, val, verdict), = rows
    assert verdict == "no-baseline"
    assert baseline != baseline  # nan
    assert val == 50.0


def test_missing_baseline_gate_passes(tmp_path, monkeypatch, capsys):
    """End to end through main(): first landing of a new BENCH_*.json must
    exit 0 with a warning, alongside gated metrics that do have baselines."""
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    metrics = M_THROUGHPUT + [("BENCH_y.json", ("summary", "rate"), "higher")]
    monkeypatch.setattr(trend_gate, "METRICS", metrics)
    _write(base, "BENCH_y.json", {"summary": {"rate": 1.0}})
    _write(fresh, "BENCH_y.json", {"summary": {"rate": 1.1}})
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 50.0}})
    code = trend_gate.main(["--baseline-dir", str(base), "--fresh-dir", str(fresh)])
    captured = capsys.readouterr()
    assert code == 0
    assert "no-baseline" in captured.out
    assert "no committed baseline" in captured.err


def test_corrupt_baseline_still_errors(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    (base / "BENCH_x.json").write_text("{not json")
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 50.0}})
    with pytest.raises(trend_gate.GateError, match="corrupt"):
        list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))


def test_missing_fresh_still_errors(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    _write(base, "BENCH_x.json", {"summary": {"kernels_per_s": 100.0}})
    with pytest.raises(trend_gate.GateError, match="missing report"):
        list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))


def test_missing_metric_in_existing_baseline_errors(tmp_path):
    base, fresh = tmp_path / "base", tmp_path / "fresh"
    base.mkdir(), fresh.mkdir()
    _write(base, "BENCH_x.json", {"summary": {}})
    _write(fresh, "BENCH_x.json", {"summary": {"kernels_per_s": 50.0}})
    with pytest.raises(trend_gate.GateError, match="not found"):
        list(trend_gate.compare(str(base), str(fresh), 0.30, M_THROUGHPUT))


def test_search_metrics_are_gated():
    """BENCH_search.json's headline metrics are wired into the default set."""
    files = {fname for fname, _, _ in trend_gate.METRICS}
    assert "BENCH_search.json" in files
    paths = {
        ".".join(path)
        for fname, path, _ in trend_gate.METRICS
        if fname == "BENCH_search.json"
    }
    assert paths == {
        "summary.variants_per_s",
        "summary.mean_agreement",
        "summary.geomean_win",
        "summary.new_family_wins",
    }


def test_gate_against_committed_baselines():
    """The committed BENCH_*.json baselines gate against themselves (a
    smoke check that every default metric exists in the committed files)."""
    import os

    root = os.path.join(os.path.dirname(__file__), "..")
    rows = list(trend_gate.compare(root, root))
    assert len(rows) == len(trend_gate.METRICS)
    assert all(r[3] == "ok" for r in rows)
