"""Persistent artifact store: integrity, recovery, eviction, cache spill."""

import os

import pytest

from repro.core.artifacts import ArtifactStore
from repro.core.simcache import SimCache
from repro.core.translator import TranslationCache, TranslationService
from repro.binary import dumps
from repro.core.kernelgen import paper_kernel
from repro.testing import FaultPlan
from repro.testing import injected as faults_injected


def test_put_get_roundtrip(tmp_path):
    store = ArtifactStore(str(tmp_path))
    assert store.get("k") is None
    assert store.misses == 1
    assert store.put("k", b"payload", meta={"x": 1})
    payload, meta = store.get("k")
    assert payload == b"payload"
    assert meta["x"] == 1
    assert meta["key"] == "k"  # collision guard rides in the meta
    assert store.hits == 1 and store.puts == 1
    assert len(store) == 1


def test_overwrite_and_binary_payloads(tmp_path):
    store = ArtifactStore(str(tmp_path))
    blob = bytes(range(256)) * 7
    store.put("k", b"old")
    store.put("k", blob)
    payload, _ = store.get("k")
    assert payload == blob
    assert len(store) == 1


def test_persists_across_instances(tmp_path):
    ArtifactStore(str(tmp_path)).put("k", b"v", meta={"n": 2})
    reopened = ArtifactStore(str(tmp_path))
    payload, meta = reopened.get("k")
    assert payload == b"v" and meta["n"] == 2


def test_corrupt_entry_quarantined_not_served(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.put("k", b"precious bytes")
    path = store._path("k")
    raw = bytearray(open(path, "rb").read())
    raw[-1] ^= 0xFF  # flip payload bits on disk
    with open(path, "wb") as fh:
        fh.write(bytes(raw))
    assert store.get("k") is None  # miss, never wrong bytes
    assert store.quarantined == 1
    assert not os.path.exists(path)  # moved aside...
    assert os.listdir(store.quarantine_dir)  # ...kept for post-mortem
    # the slot is reusable after quarantine
    store.put("k", b"recomputed")
    assert store.get("k")[0] == b"recomputed"


def test_truncated_entry_is_a_miss(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.put("k", b"x" * 100)
    path = store._path("k")
    raw = open(path, "rb").read()
    with open(path, "wb") as fh:
        fh.write(raw[: len(raw) // 2])
    assert store.get("k") is None
    assert store.quarantined == 1


def test_lru_eviction_is_deterministic(tmp_path):
    store = ArtifactStore(str(tmp_path), max_entries=2)
    store.put("a", b"1")
    store.put("b", b"2")
    os.utime(store._path("a"), (1.0, 1.0))  # "a" is stalest
    os.utime(store._path("b"), (2.0, 2.0))
    store.put("c", b"3")
    assert len(store) == 2
    assert store.evictions == 1
    assert store.get("a") is None  # the stale one went
    assert store.get("b")[0] == b"2"
    assert store.get("c")[0] == b"3"


def test_crash_mid_write_self_heals_on_restart(tmp_path):
    """A write that dies before its rename leaves only a tmp file; the next
    open sweeps it and the entry is simply absent — never half-read."""
    store = ArtifactStore(str(tmp_path))
    plan = FaultPlan(schedule={("store.tmp", "k"): 1})
    with faults_injected(plan):
        assert store.put("k", b"never lands") is False
    leftovers = [
        name
        for _, _, files in os.walk(store.objects_dir)
        for name in files
        if name.endswith(".tmp")
    ]
    assert leftovers  # the simulated crash left debris
    reopened = ArtifactStore(str(tmp_path))
    assert reopened.recovered >= 1
    assert reopened.get("k") is None
    assert not any(
        name.endswith(".tmp")
        for _, _, files in os.walk(reopened.objects_dir)
        for name in files
    )
    # and the store still works
    reopened.put("k", b"lands now")
    assert reopened.get("k")[0] == b"lands now"


def test_torn_write_caught_on_read(tmp_path):
    store = ArtifactStore(str(tmp_path))
    plan = FaultPlan(schedule={("store.torn", "k"): 1})
    with faults_injected(plan):
        store.put("k", b"torn to shreds")
    assert store.get("k") is None
    assert store.quarantined == 1


def test_bit_flip_on_read_caught(tmp_path):
    store = ArtifactStore(str(tmp_path))
    store.put("k", b"x" * 64)
    plan = FaultPlan(bit_flip_p=1.0)
    with faults_injected(plan) as inj:
        assert store.get("k") is None
        assert inj.counts()["store.flip"] >= 1
    assert store.quarantined == 1


def test_warm_load_serves_only_verified_entries(tmp_path):
    """Restart after a partial corruption: the intact entry warm-loads, the
    corrupt one is quarantined — self-healing, no manual intervention."""
    store = ArtifactStore(str(tmp_path))
    store.put("good", b"good bytes")
    store.put("bad", b"bad bytes")
    path = store._path("bad")
    raw = bytearray(open(path, "rb").read())
    raw[len(raw) // 2] ^= 0x01
    with open(path, "wb") as fh:
        fh.write(bytes(raw))
    reopened = ArtifactStore(str(tmp_path))
    assert reopened.get("good")[0] == b"good bytes"
    assert reopened.get("bad") is None
    assert reopened.quarantined == 1


def test_stats_shape(tmp_path):
    store = ArtifactStore(str(tmp_path), max_entries=10)
    store.put("k", b"v")
    store.get("k")
    store.get("missing")
    s = store.stats()
    assert s["entries"] == 1 and s["capacity"] == 10
    assert s["hits"] == 1 and s["misses"] == 1 and s["puts"] == 1
    assert s["hit_rate"] == 0.5


# -- cache spill / warm-load ---------------------------------------------------


def test_translation_cache_spills_and_warm_loads(tmp_path):
    blob = dumps(paper_kernel("md5hash"))
    svc = TranslationService(store=ArtifactStore(str(tmp_path)))
    out, rep = svc.translate(blob)
    assert rep.cached == [False]

    # fresh process: new cache, same store directory
    svc2 = TranslationService(store=ArtifactStore(str(tmp_path)))
    out2, rep2 = svc2.translate(blob)
    assert rep2.cached == [True]  # served from disk, not recomputed
    assert out2 == out  # byte-identical across the restart
    assert svc2.cache.disk_hits == 1
    snap = svc2.metrics_snapshot()
    assert snap["cache"]["disk_hits"] == 1
    assert "store" in snap


def test_translation_service_rejects_cache_and_store():
    with pytest.raises(ValueError):
        TranslationService(cache=TranslationCache(), store=object())


def test_simcache_spills_and_warm_loads(tmp_path):
    k = paper_kernel("md5hash")
    c1 = SimCache(store=ArtifactStore(str(tmp_path)))
    r1 = c1.simulate(k)
    s1 = c1.estimate_stalls(k, 0.5)

    c2 = SimCache(store=ArtifactStore(str(tmp_path)))
    r2 = c2.simulate(k)
    s2 = c2.estimate_stalls(k, 0.5)
    assert r2.total_cycles == r1.total_cycles
    assert s2 == s1
    assert c2.disk_hits == 2
    assert c2.stats()["disk_hits"] == 2
    # second access within the process is a pure memory hit
    c2.simulate(k)
    assert c2.disk_hits == 2
