"""Deterministic chaos suite: fault storms against the serving stack.

The invariant under test (the PR's acceptance bar): **every** daemon
response is either byte-identical to the fault-free translation or an
explicitly ``degraded``-flagged baseline emission — never corrupt bytes,
never a hang past the deadline — while the fault injector tears writes,
flips bits, crashes pool workers, and fails translate attempts.

``REGDEM_PROPERTY_SCALE`` multiplies the storm sizes (nightly CI sets it);
the default sizing keeps the suite inside the CI chaos smoke budget.
"""

import os
import warnings

import pytest

from repro.binary import dumps, loads_many
from repro.binary.roundtrip import verified_dumps_many
from repro.core import workerpool
from repro.core.artifacts import ArtifactStore
from repro.core.kernelgen import paper_kernel
from repro.core.search import SearchConfig, search
from repro.core.translator import (
    DegradedSearchError,
    TranslationService,
)
from repro.core.workerpool import Quarantined, supervised_map
from repro.runtime import DaemonConfig, TranslationDaemon
from repro.testing import FaultPlan
from repro.testing import injected as faults_injected

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

SMALL_TUNE = SearchConfig(max_targets=1, beam_width=2, top_k=1)


# -- supervised worker pool ----------------------------------------------------


def _square(x):
    return x * x


def _boom(x):
    raise ValueError(f"task {x} exploded")


def test_supervised_map_plain():
    assert supervised_map(_square, list(range(8)), workers=3) == [
        x * x for x in range(8)
    ]


def test_supervised_map_in_process_when_single():
    assert supervised_map(_square, [5], workers=8) == [25]
    assert supervised_map(_square, [2, 3], workers=1) == [4, 9]


def test_supervised_map_task_exception_propagates():
    with pytest.raises(ValueError, match="exploded"):
        supervised_map(_boom, [1, 2, 3], workers=2)


def test_crashed_worker_restarts_and_task_retries():
    """One crash on task 2: a fresh worker picks the task up again and the
    full result set still comes back correct and ordered."""
    plan = FaultPlan(schedule={("worker.crash", "2"): 1})
    with faults_injected(plan):
        res = supervised_map(_square, list(range(6)), workers=2)
    assert res == [x * x for x in range(6)]


def test_repeat_offender_task_is_quarantined():
    """A task that kills two workers is quarantined; everyone else's result
    is unaffected."""
    plan = FaultPlan(schedule={("worker.crash", "1"): 99})
    with faults_injected(plan):
        res = supervised_map(_square, list(range(4)), workers=2)
    assert isinstance(res[1], Quarantined)
    assert res[1].crashes == workerpool.QUARANTINE_AFTER
    assert [res[i] for i in (0, 2, 3)] == [0, 4, 9]


def test_crash_storm_is_deterministic():
    """Same plan, same payloads — same quarantine set, every run."""
    plan = FaultPlan(schedule={("worker.crash", "0"): 99,
                               ("worker.crash", "3"): 1})
    outs = []
    for _ in range(2):
        with faults_injected(plan):
            res = supervised_map(_square, list(range(5)), workers=2)
        outs.append(
            [r if not isinstance(r, Quarantined) else "Q" for r in res]
        )
    assert outs[0] == outs[1] == ["Q", 1, 4, 9, 16]


# -- search under worker crashes -----------------------------------------------


def test_search_drops_quarantined_variants_and_reports_them():
    """A beam task that keeps killing workers shrinks the space instead of
    hanging the search; the narrowing is declared on the outcome."""
    kernel = paper_kernel("md5hash")
    config = SearchConfig(
        archs=("maxwell",), max_targets=1, beam_width=2, top_k=1, workers=2
    )
    clean = search(kernel, config)
    assert clean.quarantined == []

    plan = FaultPlan(schedule={("worker.crash", "2"): 2})
    with faults_injected(plan):
        hurt = search(kernel, config)
    assert hurt.quarantined  # the dropped labels are named
    assert all(isinstance(lb, str) for lb in hurt.quarantined)
    # what survived is still a coherent, verified result
    assert hurt.report.chosen in hurt.report.cycles


def test_service_refuses_to_cache_quarantine_narrowed_tune():
    data = dumps(paper_kernel("md5hash"))
    config = SearchConfig(
        archs=("maxwell",), max_targets=1, beam_width=2, top_k=1, workers=2
    )
    svc = TranslationService()
    plan = FaultPlan(schedule={("worker.crash", "2"): 2})
    with faults_injected(plan):
        with pytest.raises(DegradedSearchError):
            svc.tune(data, config)
    assert len(svc.cache) == 0  # the narrowed result never landed


# -- the serving invariant under fault storms ----------------------------------


def _storm_responses(data, plan, n, mode="translate", config=None,
                     store=None, deadline_s=5.0):
    responses = []
    with faults_injected(plan) as inj:
        cfg = DaemonConfig(deadline_s=deadline_s, backoff_s=0.001,
                           max_retries=2)
        with TranslationDaemon(config=cfg, store=store) as daemon:
            handles = [
                daemon.submit(data, mode=mode, config=config)
                for _ in range(n)
            ]
            responses = [h.result(timeout=60) for h in handles]
    return responses, inj.counts()


def test_no_wrong_bytes_ever_under_error_storm():
    data = dumps([paper_kernel("md5hash"), paper_kernel("conv")])
    expected, _ = TranslationService().translate(data)
    baseline = verified_dumps_many(loads_many(data))
    plan = FaultPlan(seed=7, error_p=0.45)
    responses, counts = _storm_responses(data, plan, 8 * SCALE)
    assert counts.get("daemon.error", 0) > 0  # the storm actually blew
    degraded = 0
    for resp in responses:
        if resp.ok:
            assert resp.payload == expected
        else:
            assert resp.degraded
            assert resp.payload == baseline
            degraded += 1
    # with p=0.45 and 3 attempts some requests recover, and determinism
    # means the split is stable; the invariant above is the real assertion
    assert degraded < len(responses)


def test_no_wrong_bytes_under_store_corruption_storm(tmp_path):
    """Torn writes, dropped renames, and read-side bit flips against the
    artifact store: the daemon still serves only fault-free bytes or
    flagged baselines, and the store quarantines instead of serving junk."""
    data = dumps(paper_kernel("md5hash"))
    expected, _ = TranslationService().tune(data, SMALL_TUNE)
    baseline = verified_dumps_many(loads_many(data))
    store = ArtifactStore(str(tmp_path))
    plan = FaultPlan(seed=11, torn_write_p=0.3, tmp_write_p=0.3,
                     bit_flip_p=0.3)
    responses, _ = _storm_responses(
        data, plan, 6 * SCALE, mode="tune", config=SMALL_TUNE, store=store,
        deadline_s=30.0,
    )
    for resp in responses:
        if resp.ok:
            assert resp.payload == expected
        else:
            assert resp.degraded and resp.payload == baseline
    assert any(r.ok for r in responses)


def test_deadline_never_overruns_under_latency_storm():
    import time

    data = dumps(paper_kernel("md5hash"))
    baseline = verified_dumps_many(loads_many(data))
    plan = FaultPlan(latency_p=1.0, latency_s=60.0)
    t0 = time.monotonic()
    responses, _ = _storm_responses(data, plan, 3, deadline_s=0.3)
    elapsed = time.monotonic() - t0
    assert all(r.degraded for r in responses)
    assert all(r.payload == baseline for r in responses)
    assert elapsed < 30.0  # nowhere near 3 x 60s of injected hang


def test_mixed_storm_scaled():
    """The kitchen sink at property scale: errors + latency + store faults,
    every response accounted for, none corrupt."""
    data = dumps(paper_kernel("conv"))
    expected, _ = TranslationService().translate(data)
    baseline = verified_dumps_many(loads_many(data))
    plan = FaultPlan(seed=23, error_p=0.3, latency_p=0.2, latency_s=3.0,
                     torn_write_p=0.2, bit_flip_p=0.2)
    responses, _ = _storm_responses(data, plan, 6 * SCALE, deadline_s=1.0)
    statuses = {r.status for r in responses}
    assert statuses <= {"ok", "degraded"}
    for resp in responses:
        assert resp.payload in (expected, baseline)
        if resp.ok:
            assert resp.payload == expected


# -- native-engine fallback (satellite) ----------------------------------------


def test_native_fallback_warns_once_and_counts(monkeypatch):
    from repro import obs
    from repro.core import _native

    def _fail_compile():
        raise RuntimeError("no compiler here")

    monkeypatch.setattr(_native, "_fn", None)
    monkeypatch.setattr(_native, "_failed", False)
    monkeypatch.setattr(_native, "_warned", False)
    monkeypatch.setattr(_native, "_compile", _fail_compile)
    monkeypatch.setenv("REGDEM_SIM_NATIVE", "1")

    obs.enable()
    try:
        before = obs.metrics().counter("simulator.native_unavailable").value
        with pytest.warns(RuntimeWarning, match="native simulator engine"):
            assert _native.engine() is None
        assert (
            obs.metrics().counter("simulator.native_unavailable").value
            == before + 1
        )
    finally:
        obs.disable()
    # second call: still the Python fallback, but silent (warn-once)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        assert _native.engine() is None
