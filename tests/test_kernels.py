"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracles.

Sweeps shapes and dtypes per the deliverable contract; every cell asserts
allclose against :mod:`repro.kernels.ref`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import choose_block_sizes


def _mk_attention(B, Sq, Skv, Hq, Hkv, Dh, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, Dh), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, Dh), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, Dh), jnp.float32).astype(dtype)
    qpos = jnp.broadcast_to(
        jnp.arange(Skv - Sq, Skv, dtype=jnp.int32)[None], (B, Sq)
    )
    kpos = jnp.broadcast_to(jnp.arange(Skv, dtype=jnp.int32)[None], (B, Skv))
    return q, k, v, qpos, kpos


def _ref_model_layout(q, k, v, qpos, kpos, **kw):
    B, Sq, Hq, Dh = q.shape
    Hkv = k.shape[2]
    g = Hq // Hkv
    qf = q.transpose(0, 2, 1, 3).reshape(B * Hq, Sq, Dh)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, 1).reshape(B * Hq, -1, Dh)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, 1).reshape(B * Hq, -1, Dh)
    qp = jnp.repeat(qpos[:, None, :], Hq, 1).reshape(B * Hq, Sq)
    kp = jnp.repeat(kpos[:, None, :], Hq, 1).reshape(B * Hq, -1)
    r = ref.attention_reference(qf, kf, vf, qp, kp, **kw)
    return r.reshape(B, Hq, Sq, Dh).transpose(0, 2, 1, 3)


ATTN_SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, Dh)
    (1, 128, 128, 2, 2, 64),     # MHA square
    (2, 128, 128, 4, 1, 64),     # extreme GQA (gemma3-style kv=1)
    (2, 64, 256, 4, 2, 128),     # decode-ish: short q, long kv
    (1, 256, 256, 8, 4, 128),    # GQA 2:1
    (2, 128, 128, 4, 4, 256),    # wide heads (gemma3 head_dim)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes_dtypes(shape, dtype):
    B, Sq, Skv, Hq, Hkv, Dh = shape
    q, k, v, qpos, kpos = _mk_attention(B, Sq, Skv, Hq, Hkv, Dh, dtype)
    out = ops.flash_attention(q, k, v, qpos, kpos, block_q=64, block_kv=64)
    want = _ref_model_layout(q, k, v, qpos, kpos)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        out.astype(jnp.float32), want.astype(jnp.float32), atol=tol, rtol=tol
    )


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_sliding_window(window):
    q, k, v, qpos, kpos = _mk_attention(2, 128, 128, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, qpos, kpos, window=window, block_q=64, block_kv=64)
    want = _ref_model_layout(q, k, v, qpos, kpos, window=window)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("chunk", [32, 64])
def test_flash_attention_chunked_mask(chunk):
    q, k, v, qpos, kpos = _mk_attention(2, 128, 128, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, qpos, kpos, chunk_attn=chunk, block_q=64, block_kv=64)
    want = _ref_model_layout(q, k, v, qpos, kpos, chunk=chunk)
    np.testing.assert_allclose(out, want, atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("bq,bkv", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(bq, bkv):
    """Block shape must not change the math (the demotion-knob invariant)."""
    q, k, v, qpos, kpos = _mk_attention(1, 128, 128, 2, 2, 64, jnp.float32)
    base = ops.flash_attention(q, k, v, qpos, kpos, block_q=128, block_kv=128)
    out = ops.flash_attention(q, k, v, qpos, kpos, block_q=bq, block_kv=bkv)
    np.testing.assert_allclose(out, base, atol=2e-5, rtol=2e-5)


def test_choose_block_sizes_alignment_and_budget():
    bq, bkv = choose_block_sizes(4096, 4096, 128)
    assert bq % 128 == 0 and bkv % 128 == 0
    # working set must respect the budget it was given
    small_bq, small_bkv = choose_block_sizes(4096, 4096, 128, vmem_budget=2 * 2**20)
    assert small_bq * small_bkv <= bq * bkv
    # short sequences never exceed their length
    bq, bkv = choose_block_sizes(64, 64, 64)
    assert bq <= 64 and bkv <= 64


def test_choose_block_sizes_always_sublane_aligned():
    """Regression: the old `bq > max(seq_q, LANE)` guard admitted bq=128 for
    seq_q < 128 and then returned the raw (possibly unaligned) seq_q.  Every
    returned block must now be SUBLANE-aligned regardless of sequence
    length, and launchable via padding (no divisibility requirement)."""
    from repro.kernels.flash_attention import SUBLANE

    for sq in (1, 7, 17, 100, 120, 127, 129, 200, 333, 4096):
        for skv in (1, 40, 200, 1500, 32768):
            bq, bkv = choose_block_sizes(sq, skv, 128)
            assert bq % SUBLANE == 0 and bkv % SUBLANE == 0, (sq, skv, bq, bkv)
            assert bq <= max(sq + SUBLANE - 1, SUBLANE), (sq, bq)


ODD_SHAPES = [
    # (B, Sq, Skv, Hq, Hkv, Dh, window, chunk) — none block-aligned
    (1, 200, 200, 2, 2, 64, None, None),     # partial final blocks both axes
    (2, 17, 40, 4, 2, 64, None, None),       # tiny unaligned lengths
    (1, 1, 333, 4, 4, 64, None, None),       # decode-style single q row
    (2, 100, 100, 4, 2, 64, 32, None),       # sliding window over padding
    (1, 200, 200, 2, 2, 64, None, 64),       # chunked mask over padding
    (1, 129, 257, 2, 1, 128, None, None),    # just past a block boundary
]


@pytest.mark.parametrize("shape", ODD_SHAPES)
def test_flash_attention_unaligned_lengths(shape):
    """Regression sweep for the partial-final-block path: odd/short lengths
    must produce exactly the reference result (padded rows/columns masked
    through the position arrays, never through luck)."""
    B, Sq, Skv, Hq, Hkv, Dh, window, chunk = shape
    q, k, v, qpos, kpos = _mk_attention(B, Sq, Skv, Hq, Hkv, Dh, jnp.float32)
    out = ops.flash_attention(q, k, v, qpos, kpos, window=window, chunk_attn=chunk)
    want = _ref_model_layout(q, k, v, qpos, kpos, window=window, chunk=chunk)
    assert out.shape == want.shape
    np.testing.assert_allclose(out, want, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# Mamba2 SSD kernel
# ---------------------------------------------------------------------------

SSD_SHAPES = [
    # (B, S, H, P, N, chunk)
    (1, 64, 2, 16, 16, 16),
    (2, 128, 4, 32, 32, 32),
    (1, 96, 8, 16, 64, 32),     # mamba2-style wide state
    (2, 64, 4, 64, 16, 16),     # zamba2-style wide heads
]


def _mk_ssd(B, S, H, P, N, seed=0, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    x = (jax.random.normal(ks[0], (B, S, H, P)) * 0.5).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H))).astype(dtype)
    a = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    bm = (jax.random.normal(ks[3], (B, S, N)) * 0.4).astype(dtype)
    cm = (jax.random.normal(ks[4], (B, S, N)) * 0.4).astype(dtype)
    return x, dt, a, bm, cm


@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_shapes(shape):
    B, S, H, P, N, chunk = shape
    x, dt, a, bm, cm = _mk_ssd(B, S, H, P, N)
    y, h = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=chunk)
    yr, hr = ref.ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(y, yr, atol=2e-4, rtol=2e-4)
    np.testing.assert_allclose(h, hr, atol=2e-4, rtol=2e-4)


def test_ssd_kernel_bf16():
    x, dt, a, bm, cm = _mk_ssd(1, 64, 4, 16, 32, dtype=jnp.bfloat16)
    y, h = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=16)
    yr, hr = ref.ssd_reference(x, dt, a, bm, cm)
    np.testing.assert_allclose(
        y.astype(jnp.float32), yr.astype(jnp.float32), atol=5e-2, rtol=5e-2
    )


def test_ssd_head_blocking_invariant():
    """Head-block size must not change results (VMEM footprint knob)."""
    x, dt, a, bm, cm = _mk_ssd(1, 64, 8, 16, 16)
    base, hb = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=16, head_block=8)
    for blk in (1, 2, 4):
        y, h = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=16, head_block=blk)
        np.testing.assert_allclose(y, base, atol=1e-5, rtol=1e-5)
        np.testing.assert_allclose(h, hb, atol=1e-5, rtol=1e-5)


def test_ssd_matches_model_scan_path():
    """The kernel agrees with the model's lax.scan SSD (chunked dual form)."""
    from repro.models.mamba2 import ssd_chunked

    x, dt, a, bm, cm = _mk_ssd(2, 64, 4, 16, 32)
    y_kernel, h_kernel = ops.mamba2_ssd(x, dt, a, bm, cm, chunk=16)
    y_model, h_model = ssd_chunked(x, dt, a, bm[:, :, None, :], cm[:, :, None, :], chunk=16)
    np.testing.assert_allclose(y_kernel, y_model, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(h_kernel, h_model, atol=1e-4, rtol=1e-4)
