"""Batch, cached, multi-kernel translation-service tests (acceptance: a v2
multi-kernel container round-trips through translate_binary, and a repeated
kernel is served from the cache byte-identically with zero pipeline passes)."""

import pytest

from repro.binary import dumps, kernel_crc, kernel_names, loads, loads_many
from repro.core.isa import equivalent
from repro.core.kernelgen import paper_kernel
from repro.core.passes import PIPELINE_COUNTERS
from repro.core.regdem import RegDemOptions
from repro.core.sched import verify_schedule
from repro.core.translator import (
    BatchTranslationReport,
    TranslationCache,
    TranslationReport,
    TranslationService,
    translate_binary,
)

OPTS = [RegDemOptions()]  # one option set keeps the enumeration cheap


@pytest.fixture(scope="module")
def service():
    return TranslationService(options=OPTS)


def test_batch_translates_every_kernel(service):
    a, b = paper_kernel("md5hash"), paper_kernel("conv")
    out, rep = service.translate(dumps([a, b]))
    assert isinstance(rep, BatchTranslationReport)
    assert rep.kernel_names == ["md5hash", "conv"]
    decoded = loads_many(out)
    assert kernel_names(out) == ["md5hash", "conv"]
    for orig, dec in zip([a, b], decoded):
        assert equivalent(orig, dec)
        assert verify_schedule(dec) == []


def test_repeated_kernel_served_from_cache(service):
    """The headline cache guarantee: a repeated kernel in a batch runs zero
    pipeline passes and produces byte-identical output."""
    a = paper_kernel("md5hash")
    blob = dumps([a, a.copy(), a.copy()])
    before = dict(PIPELINE_COUNTERS)
    out, rep = service.translate(blob)
    after = dict(PIPELINE_COUNTERS)
    # md5hash was already translated by the previous test through this
    # service: all three batch entries hit the cache, zero passes run
    assert rep.cached == [True, True, True]
    assert rep.cache_hits == 3 and rep.cache_misses == 0
    assert after["passes"] == before["passes"]
    assert after["pipelines"] == before["pipelines"]
    # byte-identical per-kernel output: all three decode to the same render
    k0, k1, k2 = loads_many(out)
    assert k0.render() == k1.render() == k2.render()
    assert kernel_crc(k0) == kernel_crc(k1) == kernel_crc(k2)


def test_warm_service_is_byte_stable(service):
    a, b = paper_kernel("md5hash"), paper_kernel("conv")
    blob = dumps([a, b])
    out1, _ = service.translate(blob)
    out2, rep2 = service.translate(blob)
    assert out1 == out2
    assert rep2.cache_hits == 2 and rep2.hit_rate == 1.0


def test_cache_key_separates_translation_parameters():
    a = paper_kernel("md5hash")
    cache = TranslationCache()
    blob = dumps(a)
    translate_binary(blob, options=OPTS, cache=cache)
    assert (cache.hits, cache.misses) == (0, 1)
    # same kernel, same parameters -> hit
    translate_binary(blob, options=OPTS, cache=cache)
    assert (cache.hits, cache.misses) == (1, 1)
    # different target -> different key -> miss
    translate_binary(blob, target_regs=32, options=OPTS, cache=cache)
    assert (cache.hits, cache.misses) == (1, 2)
    assert len(cache) == 2


def test_single_kernel_contract_unchanged():
    """translate_binary on a single-kernel container still returns the
    kernel's TranslationReport (the historical contract)."""
    a = paper_kernel("md5hash")
    out, rep = translate_binary(dumps(a), options=OPTS)
    assert isinstance(rep, TranslationReport)
    assert rep.kernel_name == "md5hash"
    chosen = loads(out)
    assert equivalent(a, chosen)
    # per-pass stats surface for every considered variant
    assert rep.pass_stats and all(stats for stats in rep.pass_stats.values())
    assert rep.total_pipeline_seconds > 0.0


def test_cache_crc_collision_served_as_miss():
    """A CRC collision must never serve another kernel's translation: the
    stored input rendering is compared on every hit."""
    a, b = paper_kernel("md5hash"), paper_kernel("nn")
    cache = TranslationCache()
    key = cache.key(a, None, OPTS, True)
    cache.put(key, a, a, None)
    # same key, different kernel (simulated 32-bit CRC collision)
    assert cache.get(key, b) is None
    assert cache.get(key, a) is not None
    assert (cache.hits, cache.misses) == (1, 1)


def test_cache_bound_evicts_fifo():
    cache = TranslationCache(max_entries=1)
    a, b = paper_kernel("md5hash"), paper_kernel("nn")
    translate_binary(dumps(a), options=OPTS, cache=cache)
    translate_binary(dumps(b), options=OPTS, cache=cache)  # evicts a
    assert len(cache) == 1
    translate_binary(dumps(a), options=OPTS, cache=cache)
    assert cache.hits == 0 and cache.misses == 3
    stats = cache.stats()
    assert stats["evictions"] == 2
    assert stats["capacity"] == 1
    assert stats["entries"] == 1
    assert stats["hit_rate"] == 0.0


def test_cache_stats_and_shared_hit_rate():
    cache = TranslationCache()
    a = paper_kernel("md5hash")
    translate_binary(dumps(a), options=OPTS, cache=cache)
    translate_binary(dumps(a), options=OPTS, cache=cache)
    stats = cache.stats()
    assert (stats["hits"], stats["misses"], stats["evictions"]) == (1, 1, 0)
    assert stats["hit_rate"] == 0.5 == cache.hit_rate


def test_service_metrics_snapshot():
    service = TranslationService(options=OPTS)
    a = paper_kernel("md5hash")
    service.translate(dumps([a, a.copy()]))
    service.translate(dumps(a))
    snap = service.metrics_snapshot()
    assert snap["calls"] == 2
    assert snap["kernels"] == 3
    assert snap["kernels_per_s"] > 0
    lat = snap["translate_ms"]
    assert lat["count"] == 2
    assert 0 < lat["p50"] <= lat["p99"]
    assert lat["p99"] == pytest.approx(lat["max"], rel=1e-4)
    # one cold miss, then two in-batch + one cross-call hit
    assert snap["cache"]["hits"] == 2
    assert snap["cache"]["misses"] == 1
    assert snap["cache"]["hit_rate"] == pytest.approx(2 / 3, abs=1e-3)
