"""Differential property test (hypothesis): the batched simulator entry
point is element-wise identical to per-variant simulation.

``simulate_batch`` reorders its inputs by schedule-signature prefix and
resumes runs from mid-trace checkpoints captured by sibling kernels — both
are pure scheduling moves, so for ANY variant set the results (cycle
counts, idle books, truncation flags, and ``profile=True`` stall books)
must match a fresh per-variant :func:`simulate` exactly.

``REGDEM_PROPERTY_SCALE`` multiplies the example budget (the nightly CI
workflow sweeps a much larger input space than the per-push run).
"""

import os

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.kernelgen import generate, random_profile
from repro.core.regdem import auto_targets, demote
from repro.core.simcache import SimCache
from repro.core.simulator import simulate, simulate_batch

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

_slow = settings(
    max_examples=5 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _variant_set(seed: int):
    """A realistic sibling set: one random kernel, its demotions (schedule
    prefixes shared with the base), and a content-duplicate (dedup path)."""
    base = generate(random_profile(seed))
    variants = [base]
    for target in auto_targets(base)[:2]:
        variants.append(demote(base, target).kernel)
    variants.append(base.copy())
    return variants


def _assert_same(a, b):
    assert a.total_cycles == b.total_cycles
    assert a.issue_stalls == b.issue_stalls
    assert a.truncated == b.truncated
    if a.stall_profile is None or b.stall_profile is None:
        assert a.stall_profile is None and b.stall_profile is None
    else:
        assert a.stall_profile.to_json() == b.stall_profile.to_json()


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_simulate_batch_elementwise_identical(seed):
    variants = _variant_set(seed)
    solo = [simulate(k) for k in variants]
    batched = simulate_batch(variants)
    for a, b in zip(solo, batched):
        _assert_same(a, b)


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_simulate_batch_profiled_books_identical(seed):
    """The profiled engine's stall-attribution books survive checkpoint
    resume and batch reordering bit-for-bit."""
    variants = _variant_set(seed)
    solo = [simulate(k, profile=True) for k in variants]
    batched = simulate_batch(variants, profile=True)
    for a, b in zip(solo, batched):
        _assert_same(a, b)


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_simulate_batch_through_cache_identical(seed):
    """The cache-backed path (what the search confirm stage runs) returns
    the same results; content-duplicate members dedup to one measurement."""
    variants = _variant_set(seed)
    solo = [simulate(k) for k in variants]
    cache = SimCache()
    batched = simulate_batch(variants, cache=cache)
    for a, b in zip(solo, batched):
        _assert_same(a, b)
    # the duplicate (last member copies the first) was served from cache
    assert cache.hits >= 1
