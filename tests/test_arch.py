"""Architecture-registry tests: registry behaviour, the Maxwell descriptor's
parity with the historical constants, golden pins for the Volta/Turing codec
layout, and a cross-arch demotion golden against ``BENCH_arch.json``."""

import json
import os
import struct

import pytest

from repro.arch import (
    MAXWELL_ARCH,
    VOLTA_ARCH,
    ArchError,
    arch_names,
    arch_of,
    get_arch,
    retarget,
)
from repro.binary import dumps, loads
from repro.binary.archcodec import MAXWELL_CODEC, VOLTA_CODEC
from repro.binary.container import ContainerError
from repro.binary.ctrlwords import CtrlWordError
from repro.core.occupancy import MAXWELL as LEGACY_MAXWELL_SM
from repro.core.simulator import (
    ISSUE_INTERVAL as LEGACY_ISSUE_INTERVAL,
    ISSUE_WIDTH as LEGACY_ISSUE_WIDTH,
    LOCAL_EFFECTIVE_LATENCY as LEGACY_LOCAL_LATENCY,
)
from repro.core.isa import Ctrl, Instr, Kernel, OpClass, equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.occupancy import occupancy
from repro.core.regdem import demote
from repro.core.sched import schedule, verify_schedule
from repro.core.simulator import simulate, simulate_reference

BENCH_ARCH_PATH = os.path.join(os.path.dirname(__file__), "..", "BENCH_arch.json")


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_names_and_aliases():
    assert arch_names() == ["maxwell", "volta"]
    assert get_arch("maxwell") is MAXWELL_ARCH
    assert get_arch("volta") is VOLTA_ARCH
    # aliases resolve to the canonical descriptor
    assert get_arch("pascal") is MAXWELL_ARCH
    assert get_arch("sm_52") is MAXWELL_ARCH
    assert get_arch("turing") is VOLTA_ARCH
    assert get_arch("SM_75") is VOLTA_ARCH  # case-insensitive


def test_unknown_arch_rejected():
    with pytest.raises(ArchError, match="unknown architecture"):
        get_arch("ampere")


def test_arch_of_defaults_to_maxwell():
    assert arch_of(Kernel(name="k")) is MAXWELL_ARCH


# ---------------------------------------------------------------------------
# Maxwell descriptor == the historical constants (regression pin)
# ---------------------------------------------------------------------------


def test_maxwell_descriptor_matches_legacy_constants():
    a = MAXWELL_ARCH
    assert a.sm is LEGACY_MAXWELL_SM
    assert a.num_barriers == 6 and a.num_reg_banks == 4 and a.num_smem_banks == 32
    assert a.issue_width == LEGACY_ISSUE_WIDTH
    for k in OpClass:
        assert a.issue_interval(k) == LEGACY_ISSUE_INTERVAL[k]
        assert a.throughput_ratio(k) == 128 / k.throughput
    assert a.latency.global_mem == 200
    assert a.latency.local == LEGACY_LOCAL_LATENCY
    assert a.latency.shared == 24 and a.latency.alu == 6
    assert a.smem_spill_limit == 48 * 1024
    # signal latencies match the simulator's historical table
    assert a.signal_latency(OpClass.LSU_GLOBAL) == 200
    assert a.signal_latency(OpClass.LSU_LOCAL) == 80
    assert a.signal_latency(OpClass.LSU_SHARED) == 24
    assert a.signal_latency(OpClass.FP64) == 48
    assert a.signal_latency(OpClass.SFU) == 20
    assert a.codec is MAXWELL_CODEC


def test_volta_descriptor_headlines():
    a = VOLTA_ARCH
    assert a.dual_issue is False and MAXWELL_ARCH.dual_issue is True
    assert a.num_reg_banks == 2
    assert a.smem_spill_limit == 96 * 1024
    assert a.sm.smem_per_block == 96 * 1024
    assert a.codec is VOLTA_CODEC
    # FP64 is 8x wider than Maxwell: 32 lanes -> one warp per cycle
    assert a.issue_interval(OpClass.FP64) == 1.0
    assert MAXWELL_ARCH.issue_interval(OpClass.FP64) == 8.0


def test_volta_register_banking():
    assert [VOLTA_ARCH.reg_bank(r) for r in range(4)] == [0, 1, 0, 1]
    assert VOLTA_ARCH.rdv_banks(wide=False) == [0, 1]
    # pair demotion pins RDV to the even bank on a 2-bank file
    assert VOLTA_ARCH.rdv_banks(wide=True) == [0]
    assert MAXWELL_ARCH.rdv_banks(wide=True) == [0, 2]
    ins = Instr("FADD", [8], [3, 5])  # banks 1 and 1 on volta; 3 and 1 on maxwell
    assert VOLTA_ARCH.bank_conflicts(ins) == 1
    assert MAXWELL_ARCH.bank_conflicts(ins) == 0


# ---------------------------------------------------------------------------
# golden: the Volta/Turing control-word layout (TuringAs field order)
# ---------------------------------------------------------------------------


def test_golden_volta_ctrl_layout():
    # stall 1, no yield, no barriers, no waits
    assert VOLTA_CODEC.pack_ctrl(Ctrl()) == 0x7E1
    # stall 2, yield, WR0, waits {0,5} — yield is bit 4, NOT inverted
    assert (
        VOLTA_CODEC.pack_ctrl(Ctrl(stall=2, yield_flag=True, write_bar=0, wait={0, 5}))
        == 0x10F12
    )
    # everything maxed: stall 15, WR5, RD3, all six waits
    assert (
        VOLTA_CODEC.pack_ctrl(
            Ctrl(stall=15, write_bar=5, read_bar=3, wait=set(range(6)))
        )
        == 0x1FBAF
    )


def test_volta_yield_not_inverted():
    quiet = Ctrl()  # yield_flag=False
    loud = Ctrl(yield_flag=True)
    # Maxwell sets bit 4 for NO yield; Volta sets it FOR yield
    assert MAXWELL_CODEC.pack_ctrl(quiet) & 0x10
    assert not MAXWELL_CODEC.pack_ctrl(loud) & 0x10
    assert not VOLTA_CODEC.pack_ctrl(quiet) & 0x10
    assert VOLTA_CODEC.pack_ctrl(loud) & 0x10


def test_volta_ctrl_roundtrip_and_range_checks():
    for ctrl in (
        Ctrl(),
        Ctrl(stall=7, yield_flag=True, write_bar=2, read_bar=4, wait={1, 3, 5}),
        Ctrl(stall=0, write_bar=0, read_bar=0, wait=set(range(6))),
    ):
        back = VOLTA_CODEC.unpack_ctrl(VOLTA_CODEC.pack_ctrl(ctrl))
        assert (back.stall, back.yield_flag, back.write_bar, back.read_bar, back.wait) == (
            ctrl.stall, ctrl.yield_flag, ctrl.write_bar, ctrl.read_bar, ctrl.wait
        )
    with pytest.raises(CtrlWordError, match="stall"):
        VOLTA_CODEC.pack_ctrl(Ctrl(stall=16))
    with pytest.raises(CtrlWordError, match="barrier"):
        VOLTA_CODEC.pack_ctrl(Ctrl(write_bar=6))
    with pytest.raises(CtrlWordError, match="wider"):
        VOLTA_CODEC.unpack_ctrl(1 << 21)


def test_golden_volta_in_word_embedding():
    """The control block sits at bits 105..125 of the 128-bit instruction:
    bit 41 of the trailing 8-byte high word, one 32-byte record per
    instruction, no bundles."""
    assert VOLTA_CODEC.instr_size == 32
    assert VOLTA_CODEC.text_size(3) == 96 and VOLTA_CODEC.instr_addr(2) == 64
    # Maxwell geometry for the same three instructions: one 8B bundle + 3x24B
    assert MAXWELL_CODEC.text_size(3) == 80

    rec = bytes(range(24))
    blob = VOLTA_CODEC.encode_text_section([rec], [Ctrl()])
    assert len(blob) == 32
    assert blob[:24] == rec
    # golden: default ctrl 0x7e1 << 41 little-endian
    assert blob[24:] == struct.pack("<Q", 0x7E1 << 41)
    assert blob[24:].hex() == "0000000000c20f00"
    ctrls, records = VOLTA_CODEC.decode_text_section(blob, 1)
    assert records == [rec]
    assert ctrls[0].stall == 1 and ctrls[0].write_bar is None

    # stray bits outside the control field are corruption, not data
    bad = bytearray(blob)
    bad[24] ^= 0x01
    with pytest.raises(CtrlWordError, match="non-control"):
        VOLTA_CODEC.decode_text_section(bytes(bad), 1)


# ---------------------------------------------------------------------------
# retarget + containers
# ---------------------------------------------------------------------------


def test_retarget_produces_schedulable_equivalent_kernel():
    k = paper_kernel("conv")
    kv = retarget(k, "volta")
    assert kv.arch == "volta" and k.arch == "maxwell"  # input untouched
    assert verify_schedule(kv) == []
    assert equivalent(k, kv)
    assert "arch=volta" in kv.render().splitlines()[0]


def test_volta_container_roundtrip_and_mixed_batch():
    k = paper_kernel("md5hash")
    kv = retarget(k, "volta")
    blob = dumps(kv)
    back = loads(blob)
    assert back.arch == "volta"
    assert back.render() == kv.render()
    assert dumps(back) == blob  # byte stability
    # one v3 container can mix architectures
    from repro.binary import loads_many

    mixed = dumps([k, kv])
    a, b = loads_many(mixed)
    assert (a.arch, b.arch) == ("maxwell", "volta")
    assert a.render() == k.render() and b.render() == kv.render()


def test_volta_rejected_by_legacy_container_versions():
    kv = retarget(paper_kernel("md5hash"), "volta")
    for version in (1, 2):
        with pytest.raises(ContainerError, match="v3 required"):
            dumps(kv, version=version)


def test_alias_arch_tag_round_trips_verbatim():
    """An alias tag ("turing") is stored verbatim so the container round
    trip is render- and byte-identity; behaviour still resolves through the
    registry to the same descriptor."""
    kv = retarget(paper_kernel("md5hash"), "turing")
    assert kv.arch == "volta"  # retarget canonicalizes its output
    kv.arch = "turing"  # an alias tag applied directly
    assert arch_of(kv) is VOLTA_ARCH
    blob = dumps(kv)
    back = loads(blob)
    assert back.arch == "turing"
    assert back.render() == kv.render()
    assert dumps(back) == blob
    # the round-trip oracle accepts alias-tagged kernels
    from repro.binary.roundtrip import check_roundtrip

    check_roundtrip(kv, check_semantics=False)


# ---------------------------------------------------------------------------
# cross-arch machine model: occupancy, scheduling, simulation
# ---------------------------------------------------------------------------


def test_volta_shared_memory_carveout():
    # 60 KiB static shared per block: legal on Volta, over Maxwell's limit
    v = occupancy(40, 256, 60 * 1024, sm=VOLTA_ARCH.sm)
    assert v.resident_blocks >= 1
    with pytest.raises(ValueError, match="per-block limit"):
        occupancy(40, 256, 60 * 1024, sm=MAXWELL_ARCH.sm)


def test_volta_schedule_uses_shorter_alu_latency():
    def chain(arch):
        k = Kernel(name="chain", arch=arch, live_in={1}, live_out={4})
        k.items = [
            Instr("FADD", [2], [1, 1]),
            Instr("FADD", [3], [2, 2]),
            Instr("FADD", [4], [3, 3]),
            Instr("EXIT"),
        ]
        schedule(k)
        return [ins.ctrl.stall for ins in k.instructions()]

    m = chain("maxwell")
    v = chain("volta")
    # dependent ALU chain: Maxwell pads to 6 cycles, Volta to 4
    assert m[0] == 6 and v[0] == 4
    assert sum(m) > sum(v)


def test_volta_sim_engine_matches_reference():
    k = retarget(paper_kernel("nn"), "volta")
    fast = simulate(k)
    ref = simulate_reference(k)
    assert fast.total_cycles == ref.total_cycles
    assert fast.issue_stalls == ref.issue_stalls
    assert fast.occupancy.occupancy == ref.occupancy.occupancy


# ---------------------------------------------------------------------------
# golden: cross-arch demotion results pinned against BENCH_arch.json
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def bench_arch():
    with open(BENCH_ARCH_PATH) as fh:
        return json.load(fh)


def test_bench_arch_covers_all_archs_and_benchmarks(bench_arch):
    assert sorted(bench_arch["archs"]) == ["maxwell", "volta"]
    assert set(bench_arch["table3"]) == set(PAPER_BENCHMARKS)
    for bench, per_arch in bench_arch["table3"].items():
        assert sorted(per_arch) == ["maxwell", "volta"]


@pytest.mark.parametrize("bench", ["conv", "md"])
def test_golden_cross_arch_demotion(bench, bench_arch):
    """Recompute one Table-3-style demotion per arch and pin it against the
    committed BENCH_arch.json (and hard literals, so a stale regeneration
    of the JSON cannot silently shift the baseline)."""
    prof = PAPER_BENCHMARKS[bench]
    base = paper_kernel(bench)
    for arch in ("maxwell", "volta"):
        k = base if arch == "maxwell" else retarget(base, arch)
        res = demote(k, prof.regdem_target, verify="final")
        row = bench_arch["table3"][bench][arch]
        assert res.demoted_words == row["demoted_words"]
        assert res.kernel.reg_count == row["regs_after"]
        assert simulate(res.kernel).total_cycles == row["cycles_regdem"]
        assert simulate(k).total_cycles == row["cycles_nvcc"]
    # hard pins (computed at PR time): the demotion count is arch-invariant
    # for these kernels, the *cycles* are not
    assert bench_arch["table3"][bench]["maxwell"]["demoted_words"] == (
        5 if bench == "conv" else 4
    )
    assert (
        bench_arch["table3"][bench]["maxwell"]["cycles_regdem"]
        != bench_arch["table3"][bench]["volta"]["cycles_regdem"]
    )


def test_golden_volta_md_regression_case(bench_arch):
    """The register/shared trade-off shifts across generations: ``md``
    (FP64-bound) gains from demotion on neither arch dramatically, but on
    Volta — with 8x the FP64 throughput — the demotion overhead makes it a
    clear loss.  This is the cross-generation effect the multi-arch backend
    exists to expose; pin the direction."""
    md = bench_arch["table3"]["md"]
    assert md["volta"]["sim_speedup"] < 1.0 < md["maxwell"]["sim_speedup"]
