"""Two-stage simulator engine + SimCache + verify-policy tests.

The engine rewrite (trace compiler + event-driven issue loop) must be
*cycle-exact* with the pre-optimization engine:

* a golden file (``tests/golden/sim_cycles.json``, captured from the
  reference engine before the rewrite) pins ``total_cycles`` /
  ``cycles_per_wave`` / ``issue_stalls`` for every paper benchmark × all
  five variants;
* :func:`repro.core.simulator.simulate_reference` (the old loop, kept
  verbatim) is compared live against the new engine on a sample of kernels,
  including an FP64-heavy one that exercises the capacity-crawl fast path.

The content-addressed :class:`~repro.core.simcache.SimCache` must be
invisible: a hit returns a result equal to a fresh simulation, and a
colliding-but-different kernel is never served another kernel's result.
The pipeline's ``verify="final"`` hot-path policy must produce containers
byte-identical to ``verify="each"``.
"""

import dataclasses
import json
import os

import pytest

from repro.binary import dumps
from repro.core import _native
from repro.core.kernelgen import PAPER_BENCHMARKS, Profile, generate, paper_kernel
from repro.core.simcache import SimCache, simulate_cached
from repro.core.simulator import (
    CheckpointStore,
    compile_trace,
    flatten_trace,
    simulate,
    simulate_batch,
    simulate_reference,
)
from repro.core.variants import make_variants

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "golden", "sim_cycles.json")


@pytest.fixture(scope="module")
def golden():
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def all_variants():
    return {name: make_variants(PAPER_BENCHMARKS[name]) for name in PAPER_BENCHMARKS}


# ---------------------------------------------------------------------------
# golden cycle parity (all paper benchmarks x all five variants)
# ---------------------------------------------------------------------------


def test_golden_covers_full_matrix(golden):
    want = {f"{n}/{v}" for n in PAPER_BENCHMARKS for v in
            ("nvcc", "regdem", "local", "local-shared", "local-shared-relax")}
    assert set(golden) == want


def test_engine_matches_golden_cycles(golden, all_variants):
    """The new engine reproduces the pre-rewrite engine's cycles exactly."""
    for name, vs in all_variants.items():
        for vn, v in vs.items():
            s = simulate(v.kernel)
            g = golden[f"{name}/{vn}"]
            got = {
                "total_cycles": s.total_cycles,
                "cycles_per_wave": s.cycles_per_wave,
                "dynamic_instructions": s.dynamic_instructions,
                "issue_stalls": s.issue_stalls,
            }
            assert got == g, f"{name}/{vn}"


# ---------------------------------------------------------------------------
# live old-engine vs new-engine parity (a sample incl. the FP64 crawl path)
# ---------------------------------------------------------------------------

#: small FP64-bound profile: short trace, but saturates the 4-lane FP64 unit,
#: driving the issue loop through its capacity-crawl skip
_MINI_FP64 = Profile(
    name="mini_fp64", target_regs=48, threads_per_block=128, num_blocks=512,
    shared_size=0, regdem_target=40, nvcc_spills=0, loop_trips=3,
    n_consts=4, n_temps=4, fp64_frac=1.0, loads_per_iter=1, seed=7,
)


def _parity_kernels(all_variants):
    yield "mini_fp64", generate(_MINI_FP64)
    yield "gaussian/nvcc", all_variants["gaussian"]["nvcc"].kernel
    yield "gaussian/regdem", all_variants["gaussian"]["regdem"].kernel
    yield "nn/local-shared", all_variants["nn"]["local-shared"].kernel


def test_engine_matches_reference_engine(all_variants):
    for label, k in _parity_kernels(all_variants):
        new = simulate(k)
        old = simulate_reference(k)
        assert dataclasses.asdict(new) == dataclasses.asdict(old), label


def test_engine_matches_reference_under_truncation():
    """Parity must hold in the max_cycles-truncation regime too — the
    capacity-crawl bulk jump has to stop exactly where the reference's
    cycle-by-cycle crawl stops."""
    k = generate(_MINI_FP64)
    full = simulate(k).cycles_per_wave
    for cap in (1, 7, full // 3, full // 2, full - 1, full + 10):
        new = simulate(k, max_cycles=cap)
        old = simulate_reference(k, max_cycles=cap)
        assert dataclasses.asdict(new) == dataclasses.asdict(old), f"max_cycles={cap}"


def test_compile_trace_lowers_unique_instructions_once():
    k = paper_kernel("conv")
    trace = flatten_trace(k)
    ct = compile_trace(trace)
    assert len(ct.code) == len(trace)
    uniq = {ins.uid for ins in trace}
    assert len(ct.klass) == len(uniq)  # one record per static instruction
    assert all(0 <= j < len(ct.klass) for j in ct.code)


# ---------------------------------------------------------------------------
# SimCache properties
# ---------------------------------------------------------------------------


def test_simcache_hit_equals_fresh_simulation(all_variants):
    cache = SimCache()
    for vn, v in all_variants["cfd"].items():
        fresh = simulate(v.kernel)
        miss = cache.simulate(v.kernel)
        hit = cache.simulate(v.kernel)
        assert dataclasses.asdict(miss) == dataclasses.asdict(fresh), vn
        assert dataclasses.asdict(hit) == dataclasses.asdict(fresh), vn
    assert cache.hits == len(all_variants["cfd"])
    assert cache.misses == len(all_variants["cfd"])


def test_simcache_hit_returns_a_copy(all_variants):
    cache = SimCache()
    k = all_variants["cfd"]["nvcc"].kernel
    first = cache.simulate(k)
    first.total_cycles = -1  # caller mutates its copy...
    again = cache.simulate(k)
    assert again.total_cycles != -1  # ...without poisoning the cache


def test_simcache_keys_on_content_not_identity(all_variants):
    """A copy of a kernel (new uids, same content) is a hit; a kernel whose
    content differs (here: launch geometry) is not served the stale entry."""
    cache = SimCache()
    k = all_variants["cfd"]["nvcc"].kernel
    r1 = cache.simulate(k)
    r2 = cache.simulate(k.copy())
    assert cache.hits == 1
    assert dataclasses.asdict(r1) == dataclasses.asdict(r2)

    bigger = k.copy()
    bigger.num_blocks *= 2
    r3 = cache.simulate(bigger)
    assert r3.total_cycles > r1.total_cycles  # fresh sim, not the cached one


def test_simulate_cached_uses_supplied_cache(all_variants):
    cache = SimCache()
    k = all_variants["nn"]["nvcc"].kernel
    simulate_cached(k, cache=cache)
    simulate_cached(k, cache=cache)
    assert cache.hits == 1 and cache.misses == 1


def test_simcache_bounded_eviction(all_variants):
    cache = SimCache(max_entries=1)
    a = all_variants["cfd"]["nvcc"].kernel
    b = all_variants["nn"]["nvcc"].kernel
    cache.simulate(a)
    cache.simulate(b)   # evicts a (FIFO bound of 1)
    cache.simulate(a)   # miss again
    assert cache.hits == 0 and cache.misses == 3


# ---------------------------------------------------------------------------
# native engine vs Python fallback conformance
# ---------------------------------------------------------------------------

needs_native = pytest.mark.skipif(
    not _native.available(), reason="compiled engine unavailable (no C compiler)"
)


@needs_native
def test_native_engine_matches_python_engine(all_variants, monkeypatch):
    """The compiled issue loop is state-for-state identical to the Python
    fallback — results AND stall-attribution books, over the parity sample
    (which includes the FP64 capacity-crawl path)."""
    sample = list(_parity_kernels(all_variants))
    native = [simulate(k, profile=True) for _, k in sample]
    monkeypatch.setenv("REGDEM_SIM_NATIVE", "0")
    fallback = [simulate(k, profile=True) for _, k in sample]
    for (label, _), a, b in zip(sample, native, fallback):
        assert dataclasses.asdict(a) == dataclasses.asdict(b), label


@needs_native
def test_native_and_python_capture_identical_checkpoints(all_variants, monkeypatch):
    """Both engines capture checkpoints at the same trace milestones with
    bit-identical state (clocks are IEEE-754 doubles in both)."""
    k = all_variants["gaussian"]["regdem"].kernel
    s_native = CheckpointStore()
    simulate(k, profile=True, checkpoints=s_native)
    monkeypatch.setenv("REGDEM_SIM_NATIVE", "0")
    s_py = CheckpointStore()
    simulate(k, profile=True, checkpoints=s_py)
    assert len(s_native) >= 1
    assert s_native._entries.keys() == s_py._entries.keys()
    for key, cp in s_native._entries.items():
        assert cp == s_py._entries[key], key[1]


# ---------------------------------------------------------------------------
# incremental re-simulation: checkpoint capture + resume exactness
# ---------------------------------------------------------------------------


@pytest.fixture(params=["native", "python"])
def engine_mode(request, monkeypatch):
    """Run checkpoint semantics under both engines."""
    if request.param == "native":
        if not _native.available():
            pytest.skip("compiled engine unavailable")
    else:
        monkeypatch.setenv("REGDEM_SIM_NATIVE", "0")
    return request.param


def test_checkpoint_resume_matches_cold_run(all_variants, engine_mode):
    k = all_variants["gaussian"]["regdem"].kernel
    cold = simulate(k)
    store = CheckpointStore()
    first = simulate(k, checkpoints=store)   # cold, captures milestones
    assert len(store) >= 1
    resumed = simulate(k, checkpoints=store)  # resumes from the deepest
    assert store.hits >= 1
    assert dataclasses.asdict(first) == dataclasses.asdict(cold)
    assert dataclasses.asdict(resumed) == dataclasses.asdict(cold)
    assert 0.0 < store.reuse_rate <= 1.0
    st = store.stats()
    assert st["entries"] == len(store) and st["hits"] == store.hits


def test_checkpoint_resume_profiled_books_exact(all_variants, engine_mode):
    """A resumed profiled run restores the mid-trace blame books and ends
    with the exact stall attribution of a cold profiled run."""
    k = all_variants["nn"]["local-shared"].kernel
    cold = simulate(k, profile=True)
    store = CheckpointStore()
    simulate(k, profile=True, checkpoints=store)
    resumed = simulate(k, profile=True, checkpoints=store)
    assert store.hits >= 1
    assert resumed.stall_profile.to_json() == cold.stall_profile.to_json()
    assert resumed.total_cycles == cold.total_cycles


def test_plain_checkpoint_never_serves_profiled_run(all_variants, engine_mode):
    """A checkpoint without blame books cannot resume a profiled run (the
    books would start mid-trace with holes)."""
    k = all_variants["gaussian"]["nvcc"].kernel
    store = CheckpointStore()
    simulate(k, checkpoints=store)           # plain captures
    cold = simulate(k, profile=True)
    prof = simulate(k, profile=True, checkpoints=store)  # must not resume
    assert prof.stall_profile.to_json() == cold.stall_profile.to_json()


# ---------------------------------------------------------------------------
# batched entry point (the non-property smoke; the hypothesis differential
# lives in test_sim_batch_property.py)
# ---------------------------------------------------------------------------


def test_simulate_batch_matches_per_variant(all_variants):
    kernels = [v.kernel for v in all_variants["gaussian"].values()]
    solo = [simulate(k, profile=True) for k in kernels]
    batched = simulate_batch(kernels, profile=True)
    for vn, a, b in zip(all_variants["gaussian"], solo, batched):
        assert dataclasses.asdict(a) == dataclasses.asdict(b), vn


def test_simulate_batch_through_simcache_dedups(all_variants):
    k = all_variants["cfd"]["nvcc"].kernel
    cache = SimCache()
    res = cache.simulate_batch([k, k.copy(), k])
    assert cache.hits >= 2  # content-duplicates served from the cache
    assert len({r.total_cycles for r in res}) == 1
    stats = cache.stats()
    assert "checkpoint_entries" in stats and "checkpoint_reuse_rate" in stats


# ---------------------------------------------------------------------------
# trace-truncation cap is visible, never silent
# ---------------------------------------------------------------------------


def test_flatten_trace_truncation_is_visible():
    k = paper_kernel("cfd")
    k.name = "trunc_probe"
    full = flatten_trace(k)
    assert not full.truncated
    cap = len(full) // 2
    with pytest.warns(RuntimeWarning, match="truncated prefix"):
        t = flatten_trace(k, max_len=cap)
    assert t.truncated and len(t) == cap
    # the warning fires once per kernel; the truncated flag every time
    import warnings as _warnings

    with _warnings.catch_warnings():
        _warnings.simplefilter("error")
        t2 = flatten_trace(k, max_len=cap)
    assert t2.truncated


# ---------------------------------------------------------------------------
# verify="final" regression: byte-identical containers vs verify="each"
# ---------------------------------------------------------------------------


def test_verify_final_containers_byte_identical():
    for name in ("cfd", "md", "conv"):
        prof = PAPER_BENCHMARKS[name]
        each = make_variants(prof, verify="each")
        final = make_variants(prof, verify="final")
        blob_each = dumps([v.kernel for v in each.values()])
        blob_final = dumps([v.kernel for v in final.values()])
        assert blob_each == blob_final, name
