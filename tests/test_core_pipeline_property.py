"""Property-based tests (hypothesis) for the spill pass pipeline: every
pipeline *prefix* — the state at each pass boundary — preserves dataflow
equivalence and schedule validity, for random kernels and option sets."""

import os

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.isa import equivalent
from repro.core.kernelgen import generate, random_profile
from repro.core.passes import (
    PassContext,
    RegDemOptions,
    aggressive_pipeline,
    demotion_pipeline,
)
from repro.core.regdem import auto_targets
from repro.core.sched import verify_schedule
from repro.core.spillspace import LocalSpace, SharedSpace
from repro.core.strategies import get_strategy, strategy_names

#: nightly CI sets REGDEM_PROPERTY_SCALE to sweep a larger input space
SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

_slow = settings(
    max_examples=10 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


def _check_prefixes(original, pipeline, ctx, tag):
    boundaries = []
    pipeline.run(
        ctx,
        observer=lambda p, c: boundaries.append(
            (p.name, verify_schedule(c.kernel), equivalent(original, c.kernel))
        ),
    )
    assert boundaries, "pipeline ran no passes"
    for pass_name, sched_errs, equiv in boundaries:
        assert sched_errs == [], (tag, pass_name, sched_errs[:2])
        assert equiv, (tag, f"dataflow broken after pass {pass_name!r}")


@given(
    seed=st.integers(min_value=0, max_value=10_000),
    strategy=st.sampled_from(["static", "cfg", "conflict"]),
    flags=st.tuples(st.booleans(), st.booleans(), st.booleans(), st.booleans()),
)
@_slow
def test_demotion_pipeline_prefixes(seed, strategy, flags):
    k = generate(random_profile(seed % 30))
    targets = auto_targets(k)
    if not targets:
        return
    b, e, r, s = flags
    opt = RegDemOptions(
        candidate_strategy=strategy,
        bank_avoid=b,
        elim_redundant=e,
        reschedule=r,
        substitute=s,
    )
    ctx = PassContext(k, SharedSpace(), opt, target=targets[0])
    _check_prefixes(k, demotion_pipeline(opt, verify="none"), ctx, opt.label())


@given(
    seed=st.integers(min_value=0, max_value=10_000),
    name=st.sampled_from(strategy_names()),
    combo_index=st.integers(min_value=0, max_value=3),
)
@_slow
def test_registered_strategy_prefixes(seed, name, combo_index):
    """Every registered strategy's pipeline — whatever passes and spill
    space its ``build`` wires up — preserves schedule validity and dataflow
    equivalence at every pass boundary."""
    strat = get_strategy(name)
    k = generate(random_profile(seed % 30))
    if not strat.select(k):
        return
    targets = strat.targets(k, None)
    if not targets:
        return
    combos = strat.option_combos(False)
    combo = combos[combo_index % len(combos)]

    boundaries = []
    strat.build(
        k,
        targets[0],
        combo,
        verify="none",
        observer=lambda p, c: boundaries.append(
            (p.name, verify_schedule(c.kernel), equivalent(k, c.kernel))
        ),
    )
    assert boundaries, "strategy pipeline ran no passes"
    tag = strat.options_label(combo)
    for pass_name, sched_errs, equiv in boundaries:
        assert sched_errs == [], (tag, pass_name, sched_errs[:2])
        assert equiv, (tag, f"dataflow broken after pass {pass_name!r}")


@given(
    seed=st.integers(min_value=0, max_value=10_000),
    shared=st.booleans(),
    max_remat=st.one_of(st.none(), st.integers(min_value=0, max_value=8)),
)
@_slow
def test_aggressive_pipeline_prefixes(seed, shared, max_remat):
    k = generate(random_profile(seed % 30))
    targets = auto_targets(k)
    if not targets:
        return
    space = SharedSpace(check_limit=False) if shared else LocalSpace()
    opt = RegDemOptions(
        candidate_strategy="static",
        bank_avoid=False,
        elim_redundant=False,
        reschedule=False,
        substitute=False,
    )
    ctx = PassContext(
        k, space, opt, target=targets[0], floor=max(targets[0], 0), max_remat=max_remat
    )
    _check_prefixes(k, aggressive_pipeline(verify="none"), ctx, space.name)
