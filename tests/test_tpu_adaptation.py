"""Tests for the TPU-adapted RegDem layers: residency planner + selector."""

import json
import os

import pytest

from repro.configs import get_config
from repro.core.tpu_predictor import (
    ALPHA,
    VariantCost,
    cost_from_record,
    select,
)
from repro.core.vmem_demotion import (
    VMEM_BUDGET,
    Residency,
    attention_site,
    plan_residency,
    spilled_hbm_traffic,
    ssd_site,
)


def _cost(name, c, m, k, fits=True, opts=0):
    return VariantCost(name, c, m, k, fits_hbm=fits, n_options=opts)


def test_selector_prefers_lower_bound():
    best, ranked = select([
        _cost("a", 1.0, 0.1, 0.1),
        _cost("b", 0.5, 0.1, 0.1),
    ])
    assert best.name == "b"
    assert [v.name for v in ranked] == ["b", "a"]


def test_selector_never_ships_infeasible():
    """The paper's worst-case-avoidance contract: an HBM-overflow variant is
    never chosen when a feasible one exists (cf. qwen2 dots-remat, §Perf I5)."""
    best, _ = select([
        _cost("fast_but_oom", 0.1, 0.1, 0.1, fits=False),
        _cost("fits", 0.5, 0.1, 0.1, fits=True),
    ])
    assert best.name == "fits"


def test_selector_tie_breaks_toward_more_options():
    # paper §5.7: ties break toward the variant with more options enabled
    best, _ = select([
        _cost("plain", 1.0, 0.2, 0.2, opts=0),
        _cost("optimized", 1.0, 0.2, 0.2, opts=3),
    ])
    assert best.name == "optimized"


def test_overlap_model():
    v = _cost("x", 1.0, 0.5, 0.25)
    assert v.dominant == "compute"
    assert v.estimate_s == pytest.approx(1.0 + ALPHA * 0.75)


def test_cost_from_dryrun_record():
    rec = {
        "arch": "qwen2_7b",
        "shape": "train_4k",
        "flops": 1.97e12,          # exactly 0.01 s at peak
        "bytes_accessed": 8.19e9,  # exactly 0.01 s at HBM bw
        "collectives": {"total_bytes": 1, "wire_bytes": int(5e8)},
        "memory": {"argument_bytes": 2**30, "temp_bytes": 2**30, "output_bytes": 0},
    }
    v = cost_from_record(rec)
    assert v.compute_s == pytest.approx(0.01)
    assert v.memory_s == pytest.approx(0.01)
    assert v.collective_s == pytest.approx(0.01)
    assert v.fits_hbm


def test_selector_on_real_dryrun_records():
    """End-to-end: rank the real qwen2 remat variants from §Perf I5 — the
    selector must reject the OOM dots variants and ship full+mb8."""
    path = os.path.join(os.path.dirname(__file__), "..", "perf_iter.log")
    if not os.path.exists(path):
        pytest.skip("perf_iter.log not present")
    variants = []
    for line in open(path):
        line = line.strip()
        if not line.startswith("{"):
            continue
        rec = json.loads(line)
        variants.append(
            VariantCost(
                name=rec["label"],
                compute_s=rec["flops"] / 197e12,
                memory_s=0.01,
                collective_s=rec["wire_mb"] * 2**20 / 50e9,
                fits_hbm=rec["temp_gib"] <= 50,  # CPU-pessimism-adjusted roof
                n_options=0,
            )
        )
    if len(variants) < 3:
        pytest.skip("probe log incomplete")
    best, ranked = select(variants)
    assert best.name == "qwen2_train_remat_full_mb8"


# ---------------------------------------------------------------------------
# VMEM residency planner
# ---------------------------------------------------------------------------


def test_attention_site_fits_and_demotes():
    cfg = get_config("qwen2_7b")
    site = attention_site(cfg, seq_q=4096, seq_kv=4096)
    plan = plan_residency([site])
    assert plan[site.name] is Residency.DEMOTE_VMEM
    assert spilled_hbm_traffic(site, plan[site.name]) == 0


def test_oversized_site_spills_or_recomputes():
    from repro.core.vmem_demotion import Site

    huge = Site("huge", state_bytes=VMEM_BUDGET * 2, operand_bytes=1024,
                spill_bytes_per_step=VMEM_BUDGET, steps=8)
    plan = plan_residency([huge])
    assert plan["huge"] in (Residency.SPILL_HBM, Residency.RECOMPUTE)
    assert spilled_hbm_traffic(huge, plan["huge"]) > 0


def test_plan_prioritizes_expensive_spills():
    from repro.core.vmem_demotion import Site

    a = Site("cheap", state_bytes=VMEM_BUDGET // 2 - 4096, operand_bytes=1024,
             spill_bytes_per_step=10, steps=2)
    b = Site("hot", state_bytes=VMEM_BUDGET // 2 - 4096, operand_bytes=1024,
             spill_bytes_per_step=10_000_000, steps=64)
    plan = plan_residency([a, b], vmem_budget=VMEM_BUDGET // 2)
    # only one fits: it must be the one whose spill would be most expensive
    assert plan["hot"] is Residency.DEMOTE_VMEM
    assert plan["cheap"] is not Residency.DEMOTE_VMEM


def test_ssd_site_matches_kernel_scratch():
    cfg = get_config("mamba2_370m")
    site = ssd_site(cfg, seq=4096)
    # the kernel's VMEM scratch is (hb, P, N) fp32; the site models the full
    # (H, P, N) state — head-blocking divides it, so the plan must demote
    assert site.state_bytes == cfg.ssm_heads * cfg.ssm_head_dim * cfg.ssm_state * 4
    plan = plan_residency([site])
    assert plan[site.name] is Residency.DEMOTE_VMEM


def test_block_size_chooser_responds_to_budget():
    """The demotion knob: smaller VMEM budget -> smaller blocks (the
    occupancy-cliff analogue), never misaligned."""
    from repro.kernels.flash_attention import choose_block_sizes

    big = choose_block_sizes(8192, 8192, 128, vmem_budget=64 * 2**20)
    small = choose_block_sizes(8192, 8192, 128, vmem_budget=4 * 2**20)
    assert big[0] * big[1] > small[0] * small[1]
    for b in (*big, *small):
        assert b % 128 == 0
