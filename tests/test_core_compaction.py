"""Register-compaction tests (paper §3.3, Fig. 4)."""


from repro.core.compaction import compact, packed_reg_count
from repro.core.isa import Instr, Kernel, equivalent
from repro.core.kernelgen import all_paper_kernels
from repro.core.sched import schedule


def _gap_kernel(pairs=False):
    """A kernel using a sparse register set with gaps."""
    k = Kernel(name="gappy", live_in={1}, live_out=set())
    items = [
        Instr("MOV32I", [10], imm=1.0),
        Instr("MOV32I", [20], imm=2.0),
        Instr("FADD", [30], [10, 20]),
    ]
    if pairs:
        items += [
            Instr("MOV32I", [40], imm=3.0),
            Instr("MOV32I", [41], imm=3.5),
            Instr("DADD", [40], [40, 40]),
        ]
    items += [Instr("STG", srcs=[1, 30]), Instr("EXIT")]
    k.items = items
    return schedule(k)


def test_compaction_packs_singles():
    k = _gap_kernel()
    before = k.reg_count
    compact(k)
    assert k.reg_count < before
    assert k.reg_count == packed_reg_count(k)


def test_compaction_preserves_semantics():
    k = _gap_kernel(pairs=True)
    k0 = k.copy()
    compact(k)
    assert equivalent(k0, k)


def test_compaction_keeps_pair_alignment():
    k = _gap_kernel(pairs=True)
    compact(k)
    for ins in k.instructions():
        if ins.info.width == 2:
            for r in ins.dsts + (ins.srcs if not ins.info.is_memory else ins.srcs[1:]):
                assert r % 2 == 0, ins.render()


def test_compaction_pins_abi_registers():
    k = _gap_kernel()
    compact(k)
    # live-in register 1 must still be register 1
    stg = [i for i in k.instructions() if i.op == "STG"][0]
    assert stg.srcs[0] == 1


def test_compaction_never_increases_count():
    for name, k in all_paper_kernels().items():
        before = k.reg_count
        kk = k.copy()
        compact(kk)
        assert kk.reg_count <= before, name
        assert equivalent(k, kk), name


def test_bank_aware_compaction_safe():
    for name, k in all_paper_kernels().items():
        kk = k.copy()
        compact(kk, bank_avoid=True)
        assert equivalent(k, kk), name
        assert kk.reg_count <= k.reg_count


def test_relocation_space_swap_window():
    """Fig. 4(c): a pair blocked by alignment swaps with the window below."""
    k = Kernel(name="swap", live_in=set())
    k.items = [
        Instr("MOV32I", [0], imm=1.0),
        Instr("MOV32I", [3], imm=2.0),  # gap at 1,2 ; single at 3
        Instr("MOV32I", [4], imm=3.0),
        Instr("MOV32I", [5], imm=3.5),
        Instr("DADD", [4], [4, 4]),     # pair at 4-5
        Instr("STG", srcs=[0, 3]),
        Instr("EXIT"),
    ]
    schedule(k)
    k0 = k.copy()
    compact(k)
    assert k.reg_count <= 4  # 0 + single + pair = 4 registers packed
    assert equivalent(k0, k)


def test_packed_reg_count_lower_bound():
    for name, k in all_paper_kernels().items():
        kk = k.copy()
        est = packed_reg_count(kk)
        compact(kk)
        assert kk.reg_count >= est - 1  # estimator is a (near-)tight bound
        assert kk.reg_count <= est + 1
