"""Pass-pipeline tests: prefix invariants (hypothesis), Table-3 regression
golden values, spill spaces, per-pass diagnostics, and self-check teeth."""

import pytest

from repro.core.isa import RZ, Instr, equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.passes import (
    PIPELINE_COUNTERS,
    Pass,
    PassContext,
    PassPipeline,
    PassVerificationError,
    RegDemOptions,
    aggressive_pipeline,
    demotion_pipeline,
)
from repro.core.regdem import REG_FLOOR, demote
from repro.core.sched import verify_schedule
from repro.core.spillspace import SMEM_LIMIT, LocalSpace, SharedSpace, spill_space
from repro.core.variants import aggressive, make_variants

# ---------------------------------------------------------------------------
# Regression: the refactored pipeline reproduces the pre-refactor Table-3
# variant register counts, spilled/demoted word counts, and remat counts
# (captured from the hard-wired demote()/aggressive() implementations).
# ---------------------------------------------------------------------------

# {benchmark: {variant: (reg_count, spilled_words, remat_count)}}
GOLDEN_TABLE3 = {
    "cfd": {"nvcc": (68, 0, 0), "regdem": (56, 14, 0), "local": (56, 11, 2),
            "local-shared": (38, 18, 15), "local-shared-relax": (56, 12, 2)},
    "qtc": {"nvcc": (55, 0, 0), "regdem": (48, 9, 0), "local": (48, 8, 0),
            "local-shared": (32, 14, 12), "local-shared-relax": (48, 9, 0)},
    "md5hash": {"nvcc": (33, 0, 0), "regdem": (32, 3, 0), "local": (32, 0, 1),
                "local-shared": (32, 0, 3), "local-shared-relax": (32, 2, 1)},
    "md": {"nvcc": (34, 0, 0), "regdem": (32, 4, 0), "local": (32, 2, 1),
           "local-shared": (32, 0, 4), "local-shared-relax": (32, 3, 1)},
    "gaussian": {"nvcc": (43, 0, 0), "regdem": (40, 5, 0), "local": (40, 2, 2),
                 "local-shared": (32, 1, 13), "local-shared-relax": (40, 3, 2)},
    "conv": {"nvcc": (35, 0, 0), "regdem": (32, 5, 0), "local": (32, 2, 3),
             "local-shared": (32, 0, 6), "local-shared-relax": (32, 3, 3)},
    "nn": {"nvcc": (35, 0, 0), "regdem": (32, 5, 0), "local": (32, 2, 3),
           "local-shared": (32, 0, 6), "local-shared-relax": (32, 3, 3)},
    "pc": {"nvcc": (36, 0, 0), "regdem": (32, 6, 0), "local": (32, 3, 2),
           "local-shared": (32, 0, 7), "local-shared-relax": (32, 4, 2)},
    "vp": {"nvcc": (34, 0, 0), "regdem": (32, 4, 0), "local": (32, 2, 2),
           "local-shared": (32, 0, 5), "local-shared-relax": (32, 3, 2)},
}


@pytest.mark.parametrize("name", sorted(GOLDEN_TABLE3))
def test_refactor_reproduces_table3_golden(name):
    vs = make_variants(PAPER_BENCHMARKS[name])
    for vname, (regs, spilled, remat) in GOLDEN_TABLE3[name].items():
        v = vs[vname]
        assert v.kernel.reg_count == regs, (name, vname)
        assert v.spilled == spilled, (name, vname)
        assert v.remat == remat, (name, vname)
    assert vs["regdem"].regdem.demoted_words == GOLDEN_TABLE3[name]["regdem"][1]


# ---------------------------------------------------------------------------
# Pipeline prefixes preserve the core invariants (fixed-seed smoke version;
# the hypothesis-driven sweep lives in test_core_pipeline_property.py)
# ---------------------------------------------------------------------------


def _check_prefixes(original, pipeline, ctx):
    boundaries = []
    pipeline.run(
        ctx,
        observer=lambda p, c: boundaries.append(
            (p.name, verify_schedule(c.kernel), equivalent(original, c.kernel))
        ),
    )
    assert boundaries, "pipeline ran no passes"
    for pass_name, sched_errs, equiv in boundaries:
        assert sched_errs == [], (pass_name, sched_errs[:2])
        assert equiv, f"dataflow broken after pass {pass_name!r}"


@pytest.mark.parametrize("name", ["cfd", "pc", "nn"])
def test_demotion_pipeline_prefixes_preserve_invariants(name):
    """After *every* pass boundary of the demotion pipeline — not just the
    end — the kernel is dataflow-equivalent to the original and the schedule
    verifies clean."""
    k = paper_kernel(name)
    opt = RegDemOptions()
    ctx = PassContext(k, SharedSpace(), opt, target=PAPER_BENCHMARKS[name].regdem_target)
    _check_prefixes(k, demotion_pipeline(opt, verify="none"), ctx)


@pytest.mark.parametrize("space_name", ["local", "shared"])
def test_aggressive_pipeline_prefixes_preserve_invariants(space_name):
    k = paper_kernel("gaussian")
    space = LocalSpace() if space_name == "local" else SharedSpace(check_limit=False)
    opt = RegDemOptions(candidate_strategy="static", bank_avoid=False,
                        elim_redundant=False, reschedule=False, substitute=False)
    ctx = PassContext(k, space, opt, target=32, floor=32)
    _check_prefixes(k, aggressive_pipeline(verify="none"), ctx)


# ---------------------------------------------------------------------------
# Spill spaces
# ---------------------------------------------------------------------------


def test_spill_space_lookup():
    assert isinstance(spill_space("shared"), SharedSpace)
    assert isinstance(spill_space("local"), LocalSpace)
    with pytest.raises(ValueError):
        spill_space("global")


def test_shared_space_offsets_follow_eq1():
    k = paper_kernel("nn")
    ctx = PassContext(k, SharedSpace(), target=32)
    n = k.threads_per_block
    s_up = (k.shared_size + 3) // 4 * 4
    assert ctx.space.offsets(ctx, 2) == [s_up, s_up + n * 4]
    ctx.demoted_words = 3
    assert ctx.space.offsets(ctx, 1) == [s_up + 3 * n * 4]


def test_local_space_offsets_are_per_thread_slots():
    k = paper_kernel("nn")
    ctx = PassContext(k, LocalSpace(), target=32)
    ctx.demoted_words = 2
    assert ctx.space.offsets(ctx, 2) == [8, 12]
    assert not ctx.space.needs_base
    assert ctx.space.emit_prologue(ctx) == 0  # no base register, no prologue


def test_shared_space_limit_enforced():
    k = paper_kernel("nn")
    ctx = PassContext(k, SharedSpace(check_limit=True), target=32)
    ctx.demoted_words = (SMEM_LIMIT // (k.threads_per_block * 4)) + 1
    with pytest.raises(ValueError, match="shared memory limit"):
        ctx.space.account(ctx)
    relaxed = PassContext(k, SharedSpace(check_limit=False), target=32)
    relaxed.demoted_words = ctx.demoted_words
    relaxed.space.account(relaxed)  # conversion variants historically do not guard


# ---------------------------------------------------------------------------
# Diagnostics, prologue semantics, and the pipeline's teeth
# ---------------------------------------------------------------------------


def test_demote_surfaces_per_pass_stats():
    k = paper_kernel("pc")
    res = demote(k, PAPER_BENCHMARKS["pc"].regdem_target)
    names = [p.name for p in res.passes]
    assert names == ["reserve", "prologue", "demote", "eliminate_redundant",
                     "compact", "substitute", "reschedule", "fixup_stalls"]
    stats = res.pass_stats()
    assert stats["demote"]["demoted_words"] == res.demoted_words
    assert stats["prologue"]["inserted"] == 2
    assert stats["compact"]["reg_count"] == res.kernel.reg_count
    assert all(p.seconds >= 0.0 for p in res.passes)


def test_options_gate_pipeline_passes():
    opt = RegDemOptions(elim_redundant=False, reschedule=False, substitute=False)
    names = [p.name for p in demotion_pipeline(opt).passes]
    assert "eliminate_redundant" not in names
    assert "reschedule" not in names
    assert "substitute" not in names
    assert names == ["reserve", "prologue", "demote", "compact", "fixup_stalls"]


def test_aggressive_prologue_uses_barrier_tracker():
    """Satellite fix: the shared-space prologue of aggressive() carries
    tracker-assigned barriers (S2R signals a write barrier, SHL waits on
    it), matching demote()'s prologue semantics instead of the old
    hard-coded write_bar=0/stall=15."""
    base = paper_kernel("gaussian")
    v = aggressive(base, REG_FLOOR, spill_space="shared")
    s2r, shl = v.kernel.instructions()[:2]
    assert s2r.op == "S2R" and shl.op == "SHL"
    assert s2r.ctrl.write_bar is not None
    assert s2r.ctrl.write_bar in shl.ctrl.wait
    assert shl.ctrl.stall < 15  # no hard-coded 15-cycle stall

    rd = demote(base, REG_FLOOR)
    d_s2r, d_shl = rd.kernel.instructions()[:2]
    assert (s2r.ctrl.write_bar, s2r.ctrl.stall) == (d_s2r.ctrl.write_bar, d_s2r.ctrl.stall)
    assert (shl.ctrl.wait, shl.ctrl.stall) == (d_shl.ctrl.wait, d_shl.ctrl.stall)


class _CorruptingPass(Pass):
    """Deliberately breaks dataflow: emits a spurious global store."""

    name = "corrupt"

    def run(self, ctx):
        ctx.kernel.items.insert(
            len(ctx.kernel.items) - 1,
            Instr("STG", srcs=[RZ, RZ], offset=0x7000),
        )


def test_pipeline_self_check_catches_corruption():
    k = paper_kernel("md5hash")
    ctx = PassContext(k, SharedSpace(), target=32)
    with pytest.raises(PassVerificationError, match="corrupt"):
        PassPipeline([_CorruptingPass()], verify="each").run(ctx)
    # verify="none" tolerates it: callers own verification
    ctx2 = PassContext(k, SharedSpace(), target=32)
    PassPipeline([_CorruptingPass()], verify="none").run(ctx2)
    assert not equivalent(k, ctx2.kernel)


def test_pipeline_counters_advance():
    k = paper_kernel("md5hash")
    before = dict(PIPELINE_COUNTERS)
    demote(k, 32)
    after = dict(PIPELINE_COUNTERS)
    assert after["pipelines"] == before["pipelines"] + 1
    assert after["passes"] >= before["passes"] + 5


class _TaggedPass(Pass):
    """Records which run it is so duplicate-name stats are tellable apart."""

    name = "tagged"

    def __init__(self, tag):
        self.tag = tag

    def run(self, ctx):
        return {"tag": self.tag}


def test_pass_stats_keeps_duplicate_pass_runs():
    """Satellite fix: a pipeline that runs the same pass twice reports both
    runs' stats (``name``, ``name#2``, ...) instead of silently collapsing
    them into whichever ran last."""
    k = paper_kernel("md5hash")
    ctx = PassContext(k, SharedSpace(), target=32)
    PassPipeline(
        [_TaggedPass(1), _TaggedPass(2), _TaggedPass(3)], verify="none"
    ).run(ctx)
    assert [p.name for p in ctx.passes] == ["tagged", "tagged", "tagged"]
    stats = ctx.pass_stats()
    assert list(stats) == ["tagged", "tagged#2", "tagged#3"]
    assert [s["tag"] for s in stats.values()] == [1, 2, 3]


def test_context_reserves_above_reg_count():
    k = paper_kernel("conv")
    ctx = PassContext(k, SharedSpace(), target=32)
    demotion_pipeline(verify="none").run(ctx)
    assert ctx.rdv >= k.reg_count or ctx.rdv != RZ  # reserved, then compacted
    assert ctx.rda == ctx.kernel.rda
