"""Candidate-selection tests (paper §3.4.3): every strategy on every arch,
plus the conflict-pruning and register-width edge cases the autotuning
search leans on."""

import pytest

from repro.arch import retarget
from repro.core.candidates import (
    STRATEGIES,
    make_candidates,
    operand_conflicts,
    spillable,
    width_map,
)
from repro.core.isa import RZ, Instr, Kernel
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.regdem import RegDemOptions, demote
from repro.core.sched import schedule

ARCHS = ("maxwell", "volta")


def _kernel(name="cfd", arch="maxwell"):
    k = paper_kernel(name)
    return k if arch == "maxwell" else retarget(k, arch)


# ---------------------------------------------------------------------------
# every strategy x every arch
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("strategy", STRATEGIES)
def test_strategy_basic_contract(strategy, arch):
    """Candidates are unique leading registers with their widths, none of
    them excluded (ABI, RZ, RDA, odd halves of pairs)."""
    k = _kernel("cfd", arch)
    cands = make_candidates(k, strategy)
    assert cands, "cfd must have demotable registers"
    widths = width_map(k)
    regs = [r for r, _ in cands]
    assert len(regs) == len(set(regs))
    excluded = set(k.live_in) | set(k.live_out) | {RZ}
    for r, w in cands:
        assert r not in excluded
        assert w == widths[r]
    # retargeting changes scheduling, not the candidate pool
    assert set(regs) == set(spillable(k))


@pytest.mark.parametrize("arch", ARCHS)
def test_strategy_pool_is_arch_invariant(arch):
    """The same program retargeted must expose the same candidate pool per
    strategy (ordering may legally shift with the schedule)."""
    base = paper_kernel("qtc")
    k = _kernel("qtc", arch)
    for strategy in STRATEGIES:
        assert {r for r, _ in make_candidates(k, strategy)} == {
            r for r, _ in make_candidates(base, strategy)
        }


def test_static_strategy_orders_by_static_counts():
    k = paper_kernel("nn")
    counts = k.static_access_counts()
    cands = make_candidates(k, "static")
    costs = [counts.get(r, 0) for r, _ in cands]
    assert costs == sorted(costs)


def test_conflict_strategy_orders_by_conflict_degree():
    k = paper_kernel("nn")
    conf = operand_conflicts(k)
    cands = make_candidates(k, "conflict")
    degrees = [len(conf.get(r, ())) for r, _ in cands]
    assert degrees == sorted(degrees)


def test_cfg_strategy_weights_loop_bodies():
    """A register touched once inside the loop must rank above (cheaper
    than) it would with static counting x10 — i.e. cfg ordering differs
    from static exactly through the loop weight."""
    k = Kernel(name="loopy", live_in={0, 1}, num_blocks=64, threads_per_block=64)
    from repro.core.isa import Label

    k.items = [
        # r10 used 3x outside the loop, r11 once inside
        Instr("MOV32I", [10], imm=1.0),
        Instr("FADD", [10], [10, 10]),
        Instr("FADD", [10], [10, 10]),
        Instr("MOV32I", [11], imm=2.0),
        Instr("MOV32I", [3], imm=0.0),
        Instr("MOV32I", [4], imm=4.0),
        Label("LOOP"),
        Instr("FADD", [11], [11, 11]),
        Instr("IADD", [3], [3], imm=1.0),
        Instr("ISETP", srcs=[3, 4], pdst=1),
        Instr("BRA", target="LOOP", pred=1, trip_count=4),
        Instr("STG", srcs=[1, 10]),
        Instr("STG", srcs=[1, 11], offset=4),
        Instr("EXIT"),
    ]
    schedule(k)
    static_order = [r for r, _ in make_candidates(k, "static")]
    cfg_order = [r for r, _ in make_candidates(k, "cfg")]
    # statically r11 (2 accesses) is cheaper than r10 (4); with the x10
    # loop weight r11 becomes the expensive one
    assert static_order.index(11) < static_order.index(10)
    assert cfg_order.index(10) < cfg_order.index(11)


def test_unknown_strategy_rejected():
    with pytest.raises(ValueError, match="unknown strategy"):
        make_candidates(paper_kernel("conv"), "greedy")


# ---------------------------------------------------------------------------
# edge case: all candidates mutually conflicting
# ---------------------------------------------------------------------------


def _padded_kernel(name, live_pad, payload, live_out=frozenset()):
    """A kernel whose register pressure sits above REG_FLOOR (32, below
    which demotion never triggers) through *read* live-in padding registers
    — ABI registers count toward the packed register pressure but are
    excluded from candidacy, so only ``payload``'s registers are demotable.
    """
    acc = 2
    k = Kernel(name=name, live_in={0, 1} | set(live_pad),
               live_out={acc} | set(live_out),
               num_blocks=64, threads_per_block=64)
    k.items = [Instr("MOV32I", [acc], imm=0.0)]
    k.items += [Instr("FADD", [acc], [acc, r]) for r in sorted(live_pad)]
    k.items += payload
    k.items += [Instr("STG", srcs=[1, acc], offset=0x40), Instr("EXIT")]
    return schedule(k)


def _all_conflicting_kernel():
    """Three demotable registers that co-occur in every instruction that
    touches them: demoting any one prunes the other two (§3.1 challenge 2)."""
    return _padded_kernel("clash", range(20, 56), [
        Instr("MOV32I", [10], imm=1.0),
        Instr("MOV32I", [11], imm=2.0),
        Instr("MOV32I", [12], imm=3.0),
        Instr("FFMA", [10], [10, 11, 12]),
        Instr("FFMA", [11], [11, 12, 10]),
        Instr("FFMA", [12], [12, 10, 11]),
        Instr("STG", srcs=[1, 10]),
        Instr("STG", srcs=[1, 11], offset=4),
        Instr("STG", srcs=[1, 12], offset=8),
    ])


def test_operand_conflicts_fully_connected():
    conf = operand_conflicts(_all_conflicting_kernel())
    for r in (10, 11, 12):
        assert conf[r] >= {10, 11, 12} - {r}


def test_demote_prunes_conflicting_candidates():
    """With a fully conflicting pool, demotion moves exactly one register
    and stops — the others are pruned, not corrupted."""
    k = _all_conflicting_kernel()
    res = demote(k, 32, RegDemOptions(candidate_strategy="conflict"))
    assert len(res.demoted) == 1
    from repro.core.isa import equivalent

    assert equivalent(k, res.kernel)
    assert not res.reached_target  # pruning stopped it short of the target


# ---------------------------------------------------------------------------
# edge case: zero spillable registers
# ---------------------------------------------------------------------------


def _abi_only_kernel():
    k = Kernel(name="abionly", live_in={0, 1}, live_out={2},
               num_blocks=64, threads_per_block=64)
    k.items = [
        Instr("FADD", [2], [0, 1]),
        Instr("STG", srcs=[1, 2]),
        Instr("EXIT"),
    ]
    return schedule(k)


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_zero_spillable_registers(strategy):
    k = _abi_only_kernel()
    assert spillable(k) == []
    assert make_candidates(k, strategy) == []
    res = demote(k, 0, RegDemOptions(candidate_strategy=strategy))
    assert res.demoted_words == 0
    assert res.kernel.demoted_size == 0


# ---------------------------------------------------------------------------
# edge case: wide (64-bit pair) registers
# ---------------------------------------------------------------------------


def _wide_kernel():
    return _padded_kernel("wide", range(20, 56), [
        Instr("MOV32I", [10], imm=1.0),
        Instr("MOV32I", [11], imm=1.5),
        Instr("DFMA", [10], [10, 10, 10]),   # r10:r11 is a pair
        Instr("MOV32I", [14], imm=2.0),
        Instr("FADD", [14], [14, 14]),
        Instr("STG64", srcs=[1, 10]),
        Instr("STG", srcs=[1, 14], offset=8),
    ])


def test_width_map_marks_pairs():
    widths = width_map(_wide_kernel())
    assert widths[10] == 2
    assert widths[14] == 1


@pytest.mark.parametrize("strategy", STRATEGIES)
def test_pair_alias_words_are_not_candidates(strategy):
    """Pairs are demoted through their leading word: the odd alias never
    appears, and the pair carries width 2 into the demotion queue."""
    cands = make_candidates(_wide_kernel(), strategy)
    by_reg = dict(cands)
    assert 11 not in by_reg        # odd alias of the r10:r11 pair
    assert by_reg.get(10) == 2
    assert by_reg.get(14) == 1


def test_wide_demotion_accounts_two_words():
    k = _wide_kernel()
    res = demote(k, 32, RegDemOptions(candidate_strategy="static"))
    assert (10, 2) in res.demoted
    assert res.demoted_words >= 2
    from repro.core.isa import equivalent

    assert equivalent(k, res.kernel)


# ---------------------------------------------------------------------------
# paper-corpus sweep: every strategy yields a usable queue on every benchmark
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(PAPER_BENCHMARKS))
def test_all_benchmarks_have_candidates(name):
    k = paper_kernel(name)
    pool = set(spillable(k))
    assert pool
    for strategy in STRATEGIES:
        assert {r for r, _ in make_candidates(k, strategy)} == pool
