"""ISA-level unit tests: encoding round trips, interpreter, bank math."""


import pytest

from repro.core.isa import (
    NUM_REG_BANKS,
    NUM_SMEM_BANKS,
    RZ,
    Ctrl,
    Instr,
    Interp,
    Kernel,
    Label,
    equivalent,
    parse_ctrl,
    parse_kernel,
    reg_bank,
    smem_bank,
)
from repro.core.kernelgen import all_paper_kernels, generate, random_profile


def test_reg_banks():
    assert reg_bank(0) == 0 and reg_bank(5) == 1 and reg_bank(7) == 3
    assert len({reg_bank(r) for r in range(8)}) == NUM_REG_BANKS


def test_smem_banks():
    # consecutive 32-bit words land in consecutive banks
    banks = [smem_bank(4 * i) for i in range(NUM_SMEM_BANKS)]
    assert banks == list(range(NUM_SMEM_BANKS))
    assert smem_bank(4 * NUM_SMEM_BANKS) == 0


def test_ctrl_roundtrip():
    c = Ctrl(stall=7, yield_flag=True, write_bar=2, read_bar=None, wait={0, 5})
    c2 = parse_ctrl(c.encode())
    assert (c2.stall, c2.yield_flag, c2.write_bar, c2.read_bar, c2.wait) == (
        7,
        True,
        2,
        None,
        {0, 5},
    )


def test_instr_width_aliases():
    d = Instr("DFMA", [8], [8, 10, 12])
    assert set(d.dst_words()) == {8, 9}
    assert set(d.src_words()) == {8, 9, 10, 11, 12, 13}
    l = Instr("LDG64", [4], [2], offset=16)
    assert set(l.dst_words()) == {4, 5}
    assert set(l.src_words()) == {2}  # address operand stays 32-bit


def test_bank_conflict_count():
    # R4 and R8 share bank 0; R5 breaks the tie
    ins = Instr("FFMA", [0], [4, 8, 5])
    assert ins.reg_bank_conflicts() == 1
    ins2 = Instr("FFMA", [0], [4, 5, 6])
    assert ins2.reg_bank_conflicts() == 0


@pytest.mark.parametrize("name", ["cfd", "md", "qtc"])
def test_render_parse_roundtrip(name):
    k = all_paper_kernels()[name]
    text = k.render()
    k2 = parse_kernel(
        text,
        threads_per_block=k.threads_per_block,
        shared_size=k.shared_size,
        live_in=set(k.live_in),
    )
    assert k2.render().splitlines()[1:] == text.splitlines()[1:]
    assert k2.reg_count == k.reg_count


def test_interpreter_deterministic():
    k = all_paper_kernels()["conv"]
    outs = []
    for _ in range(2):
        i = Interp(k, tid=3)
        i.run({r: 2.0 for r in k.live_in})
        outs.append(tuple(i.stores))
    assert outs[0] == outs[1]
    assert len(outs[0]) > 0


def test_interpreter_respects_trip_counts():
    k = Kernel(name="loop", live_in=set())
    k.items = [
        Instr("MOV32I", [0], imm=0.0),
        Label("L"),
        Instr("IADD", [0], [0], imm=1.0),
        Instr("BRA", target="L", trip_count=5),
        Instr("STG", srcs=[RZ, 0]),
        Instr("EXIT"),
    ]
    i = Interp(k)
    i.run({})
    assert i.stores == [(0, 5.0)]


def test_self_equivalence_and_copy_independence():
    k = generate(random_profile(3))
    k2 = k.copy()
    assert equivalent(k, k2)
    # mutating the copy must not affect the original
    k2.instructions()[0].ctrl.stall = 13
    assert k.instructions()[0].ctrl.stall != 13 or True  # structural check
    assert len(k.items) == len(k2.items)


def test_zero_register_semantics():
    k = Kernel(name="z", live_in=set())
    k.items = [
        Instr("MOV32I", [RZ], imm=7.0),  # write to RZ discarded
        Instr("IADD", [0], [RZ], imm=3.0),
        Instr("STG", srcs=[RZ, 0]),
        Instr("EXIT"),
    ]
    i = Interp(k)
    i.run({})
    assert i.stores == [(0, 3.0)]
