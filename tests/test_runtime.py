"""Trainer / checkpoint / serving / fault-tolerance integration tests."""

import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, restore_tree, save_tree
from repro.configs import reduced_config
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.optim import AdamWConfig
from repro.runtime import ServeConfig, Server, TrainConfig, Trainer
from repro.runtime.serving import Request
from repro.runtime.trainer import StragglerDetector


def _mk_trainer(tmp_path, steps=6, ckpt_every=3, arch="stablelm_3b", **tkw):
    cfg = reduced_config(arch)
    mesh = make_host_mesh()
    tcfg = TrainConfig(
        steps=steps,
        checkpoint_every=ckpt_every,
        checkpoint_dir=str(tmp_path / "ckpt"),
        attn_impl="xla",
        **tkw,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=7)
    return Trainer(cfg, AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=steps), tcfg, dcfg, mesh)


def test_training_loss_decreases(tmp_path):
    tr = _mk_trainer(tmp_path, steps=30, ckpt_every=100)
    out = tr.run()
    losses = out["losses"]
    assert len(losses) == 30
    first, last = np.mean(losses[:5]), np.mean(losses[-5:])
    assert last < first - 0.2, (first, last)


def test_checkpoint_restart_is_bit_exact(tmp_path):
    # uninterrupted run
    tr1 = _mk_trainer(tmp_path / "a", steps=8, ckpt_every=4)
    out1 = tr1.run()

    # interrupted run: dies once at step 5, restarts from step-4 checkpoint
    boom = {"armed": True}

    def injector(step):
        if step == 5 and boom["armed"]:
            boom["armed"] = False
            raise RuntimeError("injected node failure")

    tr2 = _mk_trainer(tmp_path / "b", steps=8, ckpt_every=4)
    out2 = tr2.run(fault_injector=injector)
    assert out2["restarts"] == 1
    # deterministic data replay => the final losses agree exactly
    np.testing.assert_allclose(out1["losses"][-1], out2["losses"][-1], rtol=1e-6)
    leaves1 = jax.tree.leaves(out1["params"])
    leaves2 = jax.tree.leaves(out2["params"])
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity_and_keep_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.arange(8.0), "nested": {"b": jnp.ones((3, 3))}}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree, extra={"tag": step}, async_=False)
    steps = sorted(
        int(n.split("_")[1]) for n in os.listdir(tmp_path) if n.startswith("step_")
    )
    assert steps == [3, 4]  # keep-2 GC
    restored, extra = mgr.restore(tree)
    assert extra["step"] == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.arange(8.0))


def test_checkpoint_async_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.ones((64, 64))}
    mgr.save(10, tree, async_=True)
    mgr.wait()
    assert mgr.latest_step() == 10


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    path = str(tmp_path / "ck")
    save_tree(path, {"w": np.ones((4,))})
    with pytest.raises(ValueError):
        restore_tree(path, {"w": jnp.ones((5,))})


def test_straggler_detector():
    det = StragglerDetector(z_threshold=3.0, warmup=5)
    for _ in range(20):
        assert not det.observe(0.1)
    assert det.observe(10.0)  # a 100x step is a straggler
    assert det.flagged == 1


def test_straggler_hook_fires(tmp_path):
    """The detector->callback wiring, fed deterministic step times (wall
    times on a contended CI box are too noisy for timing assertions)."""
    events = []
    cfg = reduced_config("stablelm_3b")
    tcfg = TrainConfig(
        steps=4, checkpoint_every=100, checkpoint_dir=str(tmp_path / "c"),
        attn_impl="xla", straggler_zscore=3.0, straggler_warmup=4,
    )
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    tr = Trainer(
        cfg, AdamWConfig(), tcfg, dcfg, make_host_mesh(),
        straggler_callback=lambda step, dt: events.append((step, dt)),
    )
    # steady steps, then a 100x stall at "step 20"
    tr._observe_step(0, 5.0)  # compile step (ignored by design)
    for s in range(1, 20):
        tr._observe_step(s, 0.1 + 0.001 * (s % 3))
    tr._observe_step(20, 10.0)
    assert events and events[-1][0] == 20
    assert tr.detector.flagged == 1


def test_elastic_restore_across_meshes(tmp_path):
    """Save under one mesh, restore under another (elastic rescale)."""
    tr = _mk_trainer(tmp_path, steps=4, ckpt_every=2)
    out = tr.run()
    # rescale: new mesh with model axis (1 device => (n,1) vs (1,n) layouts)
    new_mesh = make_host_mesh(model=1)
    tr.remesh(new_mesh)
    params_like, opt_like = tr.init_state()
    params, opt, step = tr._restore(params_like, opt_like)
    assert step == 4
    for a, b in zip(jax.tree.leaves(out["params"]), jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_equivalence(tmp_path):
    """microbatches=2 must match microbatches=1 numerically (fp32)."""
    cfg = dataclasses.replace(reduced_config("stablelm_3b"), dtype=jnp.float32)
    mesh = make_host_mesh()
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3)
    outs = []
    for mb in (1, 2):
        tcfg = TrainConfig(
            steps=3, checkpoint_every=100, microbatches=mb,
            checkpoint_dir=str(tmp_path / f"mb{mb}"), attn_impl="xla",
        )
        tr = Trainer(cfg, AdamWConfig(lr=1e-3), tcfg, dcfg, mesh)
        outs.append(tr.run()["losses"])
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


def test_data_pipeline_determinism_and_packing():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=11)
    pipe = SyntheticLM(cfg)
    b1, b2 = pipe.batch(5), pipe.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (8, 64)
    assert (b1["tokens"] >= 0).all() and (b1["tokens"] < 1000).all()
    # host sharding partitions the global batch
    h0 = SyntheticLM(cfg, host_id=0, n_hosts=2).batch(5)
    h1 = SyntheticLM(cfg, host_id=1, n_hosts=2).batch(5)
    assert h0["tokens"].shape == (4, 64)
    assert not np.array_equal(h0["tokens"], h1["tokens"])


def test_server_continuous_batching():
    cfg = reduced_config("stablelm_3b")
    model = Model(cfg, attn_impl="xla")
    params, _ = model.init(jax.random.PRNGKey(0))
    server = Server(cfg, ServeConfig(batch_slots=2, max_len=32, max_new_tokens=4, eos=-1), params)
    reqs = [
        Request(uid=i, prompt=np.arange(1, 5 + i, dtype=np.int32)) for i in range(5)
    ]
    done = server.serve(reqs)
    assert [c.uid for c in done] == [0, 1, 2, 3, 4]
    for c in done:
        assert 1 <= len(c.tokens) <= 4
