"""Strategy-registry tests (repro.core.strategies).

Four layers:

* **registry API** — registration order, the duplicate-name guard, and the
  unknown-name error (must list what *is* registered);
* **compatibility shim** — the paper's ordering names resolve through the
  registry byte-identically: same candidate queues, same option labels,
  same :meth:`SearchConfig.signature` (so translation-cache keys and golden
  files survive the registry refactor), and re-tuning cached content under
  an explicit paper-strategy config runs zero pipeline passes;
* **correctness oracle** — every registered strategy's ``build`` output
  passes the full schedule check and stays dataflow-equivalent to its
  baseline, at every rung of its own target ladder;
* **golden win cell** — at least one benchmark x arch cell is won by a
  related-work family, strictly beating every paper-five anchor (the
  acceptance criterion the re-pinned golden encodes).
"""

import json
import os

import pytest

from benchmarks.search_bench import NEW_FAMILIES, chosen_family
from repro.binary import dumps
from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, generate, random_profile
from repro.core.candidates import make_candidates
from repro.core.passes import PIPELINE_COUNTERS, RegDemOptions
from repro.core.sched import verify_schedule
from repro.core.search import SearchConfig, search
from repro.core.strategies import (
    PaperOptions,
    Strategy,
    StrategyHints,
    get_strategy,
    register_strategy,
    strategies,
    strategy_names,
)
from repro.core.translator import TranslationService, option_space
from repro.core.variants import make_variants_for

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "search_choices.json"
)

PAPER_NAMES = ("static", "cfg", "conflict")


# ---------------------------------------------------------------------------
# Registry API
# ---------------------------------------------------------------------------


def test_registration_order_paper_first():
    names = strategy_names()
    assert names[:3] == list(PAPER_NAMES)
    assert set(names) >= {"warp_share", "block_share", "compressed"}
    assert [s.name for s in strategies()] == names


def test_duplicate_name_guard():
    static = get_strategy("static")
    with pytest.raises(ValueError, match="already registered"):
        register_strategy(
            Strategy(
                name="static",
                doc="imposter",
                family="paper",
                options_cls=PaperOptions,
                hints=StrategyHints(),
                select=static.select,
                option_combos=static.option_combos,
                options_label=static.options_label,
                build=static.build,
                targets=static.targets,
            )
        )
    # the guard must not have clobbered the original
    assert get_strategy("static") is static


def test_unknown_strategy_error_lists_registered():
    with pytest.raises(ValueError) as exc:
        get_strategy("does-not-exist")
    msg = str(exc.value)
    assert "does-not-exist" in msg
    for name in strategy_names():
        assert name in msg


def test_families():
    for name in PAPER_NAMES:
        assert get_strategy(name).family == "paper"
    for name in NEW_FAMILIES:
        assert get_strategy(name).family == name


# ---------------------------------------------------------------------------
# Compatibility shim: paper names resolve byte-identically
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", PAPER_NAMES)
def test_paper_select_matches_make_candidates(name):
    k = generate(PAPER_BENCHMARKS["cfd"])
    assert get_strategy(name).select(k) == make_candidates(k, name)


@pytest.mark.parametrize("name", PAPER_NAMES)
def test_paper_labels_match_regdem_options(name):
    strat = get_strategy(name)
    for full in (False, True):
        for combo in strat.option_combos(full):
            b, e, r, s = combo
            opts = RegDemOptions(
                candidate_strategy=name,
                bank_avoid=b,
                elim_redundant=e,
                reschedule=r,
                substitute=s,
            )
            assert strat.options_label(combo) == opts.label()


def test_signature_stability_for_explicit_paper_strategies():
    """An explicit paper-strategy tuple signs exactly as it did before the
    registry existed — translation-cache tune keys for those configs must
    not silently change."""
    cfg = SearchConfig(strategies=PAPER_NAMES, archs=("maxwell",))
    assert cfg.signature() == (
        ("static", "cfg", "conflict"),
        ("maxwell",),
        None,
        False,
        6,
        4,
        "chosen",
        False,
    )


def test_default_signature_resolves_registered_names():
    sig = SearchConfig().signature()
    assert sig[0] == tuple(strategy_names())


def test_option_space_rejects_non_paper_families():
    with pytest.raises(ValueError, match="family"):
        option_space(strategies=("warp_share",))
    with pytest.raises(ValueError, match="registered"):
        option_space(strategies=("no-such-strategy",))


def test_retune_paper_config_is_pure_cache_hit():
    """Re-tuning cached content under an explicit paper-strategy config runs
    zero pipeline passes and reproduces the container byte-for-byte."""
    blob = dumps([generate(random_profile(21))])
    svc = TranslationService()
    cfg = SearchConfig(strategies=PAPER_NAMES, archs=("maxwell",))
    out1, batch1 = svc.tune(blob, cfg)
    assert batch1.cached == [False]

    before = dict(PIPELINE_COUNTERS)
    out2, batch2 = svc.tune(blob, cfg)
    assert batch2.cached == [True]
    assert PIPELINE_COUNTERS == before  # zero pipelines, zero passes
    assert out2 == out1  # unchanged bytes => unchanged kernel CRCs


# ---------------------------------------------------------------------------
# Correctness oracle: every registered strategy, every ladder rung
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", strategy_names())
def test_strategy_build_schedule_and_dataflow(name):
    strat = get_strategy(name)
    base = generate(PAPER_BENCHMARKS["cfd"])
    if not strat.select(base):
        pytest.skip(f"{name}: no candidates on cfd")
    targets = strat.targets(base, None)
    if not targets:
        pytest.skip(f"{name}: empty target ladder on cfd")
    for combo in strat.option_combos(False):
        for tgt in targets:
            res = strat.build(base, tgt, combo, verify="none")
            tag = f"{strat.options_label(combo)}@{tgt}"
            assert verify_schedule(res.kernel) == [], tag
            assert equivalent(base, res.kernel), tag


@pytest.mark.parametrize("name", strategy_names())
def test_strategy_pipeline_prefixes(name):
    """Deterministic prefix invariant (the hypothesis sweep in
    test_core_pipeline_property.py generalizes this): at every pass boundary
    of the strategy's own pipeline, the schedule verifies and dataflow is
    preserved."""
    strat = get_strategy(name)
    base = generate(PAPER_BENCHMARKS["cfd"])
    if not strat.select(base):
        pytest.skip(f"{name}: no candidates on cfd")
    targets = strat.targets(base, 1)
    if not targets:
        pytest.skip(f"{name}: empty target ladder on cfd")

    boundaries = []
    strat.build(
        base,
        targets[0],
        strat.option_combos(False)[0],
        verify="none",
        observer=lambda p, c: boundaries.append(
            (p.name, verify_schedule(c.kernel), equivalent(base, c.kernel))
        ),
    )
    assert boundaries, "strategy pipeline ran no passes"
    for pass_name, sched_errs, equiv in boundaries:
        assert sched_errs == [], (name, pass_name, sched_errs[:2])
        assert equiv, (name, f"dataflow broken after pass {pass_name!r}")


@pytest.mark.parametrize("name", strategy_names())
def test_strategy_targets_respect_truncation(name):
    strat = get_strategy(name)
    base = generate(PAPER_BENCHMARKS["cfd"])
    full = strat.targets(base, None)
    assert strat.targets(base, 2) == full[:2]


def test_extra_strategies_in_variant_matrix():
    base = generate(PAPER_BENCHMARKS["cfd"])
    prof = PAPER_BENCHMARKS["cfd"]
    out = make_variants_for(
        base,
        prof.regdem_target,
        prof.nvcc_spills,
        extra_strategies=list(NEW_FAMILIES),
    )
    built = [n for n in NEW_FAMILIES if n in out]
    assert built, "no registry extra built on cfd"
    for name in built:
        v = out[name]
        assert v.name == name
        assert v.spilled > 0
        assert verify_schedule(v.kernel) == []
        assert equivalent(base, v.kernel)


# ---------------------------------------------------------------------------
# Golden win cell: a related-work family strictly beats the paper five
# ---------------------------------------------------------------------------


def test_golden_pins_a_new_family_win():
    with open(GOLDEN_PATH) as fh:
        golden = json.load(fh)
    new_cells = [
        (bench, arch)
        for bench, per_arch in golden.items()
        for arch, chosen in per_arch.items()
        if chosen_family(chosen)[0] in NEW_FAMILIES
    ]
    assert new_cells, "golden pins no related-work-family winner"
    assert ("cfd", "volta") in new_cells


def test_new_family_strictly_beats_every_paper_variant():
    """The cfd/volta cell: the search (anchored on the fixed §5.3 set) picks
    a related-work strategy whose simulated cycles strictly beat nvcc and
    all four paper-five variants."""
    from repro.arch import retarget

    prof = PAPER_BENCHMARKS["cfd"]
    k = retarget(generate(prof), "volta")
    fixed = make_variants_for(k, prof.regdem_target, prof.nvcc_spills)
    anchors = {f"volta/{n}": v.kernel for n, v in fixed.items() if n != "nvcc"}
    outcome = search(k, SearchConfig(archs=("volta",)), extra_variants=anchors)
    sr = outcome.report
    family, strat = chosen_family(sr.chosen)
    assert family in NEW_FAMILIES, sr.chosen
    chosen_cycles = sr.cycles[sr.chosen]
    rivals = list(anchors) + [sr.baseline]
    for label in rivals:
        assert chosen_cycles < sr.cycles[label], (sr.chosen, label)
