"""Autotuning-search tests (repro.core.search).

Three layers:

* **differential** — on small ``kernelgen`` kernels, the beam-pruned search
  must land within :data:`repro.core.search.SEARCH_TOLERANCE` of exhaustive
  simulate-everything ground truth;
* **golden** — the chosen variant names for all 9 paper benchmarks on both
  arches are pinned in ``tests/golden/search_choices.json`` and must match a
  live recompute *and* the committed ``BENCH_search.json``;
* **service** — tuned containers embed their search reports as ``.note``
  sections, and re-tuning known content is a pure translation-cache hit.
"""

import json
import os

import pytest

from benchmarks import search_bench
from repro.binary import dumps, loads_many, read_notes
from repro.core.isa import Instr, Kernel, equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, generate, random_profile
from repro.core.regdem import auto_targets
from repro.core.sched import schedule, verify_schedule
from repro.core.search import (
    SEARCH_TOLERANCE,
    SearchConfig,
    search,
)
from repro.core.simcache import SimCache
from repro.core.translator import TranslationService

GOLDEN_PATH = os.path.join(
    os.path.dirname(__file__), "golden", "search_choices.json"
)
BENCH_SEARCH_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_search.json"
)

#: nightly CI raises REGDEM_PROPERTY_SCALE: the live recompute then sweeps
#: every benchmark x arch cell; tier-1 recomputes a fixed slice spanning the
#: win regimes (strict search win on each arch, fp64, conversion-dominated)
#: — full-grid agreement with the goldens is still pinned every run through
#: the committed BENCH_search.json cross-check.
SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))
TIER1_RECOMPUTE = ["cfd", "pc", "md", "nn"]


@pytest.fixture(scope="module")
def golden_choices():
    with open(GOLDEN_PATH) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def bench_search():
    with open(BENCH_SEARCH_PATH) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def measured():
    """The live search recompute shared by the golden and acceptance tests:
    the full 9-benchmarks x both-arches sweep at nightly scale, the
    TIER1_RECOMPUTE slice otherwise (the process-wide SimCache keeps it
    warm for every consumer)."""
    if SCALE > 1:
        return search_bench.measure(workers=0)
    return {
        "kernels": {
            bench: {
                arch: search_bench.tune_benchmark(bench, arch)
                for arch in ("maxwell", "volta")
            }
            for bench in TIER1_RECOMPUTE
        }
    }


# ---------------------------------------------------------------------------
# differential: beam search vs exhaustive ground truth
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [3, 11, 29])
def test_search_within_tolerance_of_exhaustive(seed):
    """The predictor-guided beam may prune, but the variant it ships must
    simulate within the documented tolerance of the exhaustive optimum."""
    k = generate(random_profile(seed))
    if not auto_targets(k):
        pytest.skip("profile has no occupancy cliff to search")
    cache = SimCache()
    exhaustive = search(
        k,
        SearchConfig(archs=("maxwell",), beam_width=10**6, top_k=10**6),
        cache=cache,
    )
    # beam_width/top_k >= space mean nothing was pruned: every enumerated
    # demotion was built and simulated
    baselines = 1
    assert exhaustive.report.explored == exhaustive.report.space_size - baselines
    assert exhaustive.report.simulated == exhaustive.report.space_size

    pruned = search(k, SearchConfig(archs=("maxwell",)), cache=cache)
    truth = exhaustive.report.cycles[exhaustive.report.chosen]
    got = pruned.report.cycles[pruned.report.chosen]
    assert got <= truth * (1 + SEARCH_TOLERANCE)
    # and the search never ships a semantics-breaking or unscheduled kernel
    assert equivalent(k, pruned.kernel)
    assert verify_schedule(pruned.kernel) == []


def test_search_never_worse_than_doing_nothing():
    k = generate(random_profile(5))
    out = search(k, SearchConfig(archs=("maxwell",)), cache=SimCache())
    base = out.report.cycles[out.report.baseline]
    assert out.report.cycles[out.report.chosen] <= base


# ---------------------------------------------------------------------------
# golden: pinned chosen variants for the paper benchmarks on both arches
# ---------------------------------------------------------------------------


def test_golden_choices_cover_benchmarks_and_arches(golden_choices):
    assert set(golden_choices) == set(PAPER_BENCHMARKS)
    for per_arch in golden_choices.values():
        assert sorted(per_arch) == ["maxwell", "volta"]


def test_bench_search_json_matches_golden(golden_choices, bench_search):
    """The committed BENCH_search.json must agree with the independent
    golden file — a stale regeneration cannot silently shift the pins."""
    for bench, per_arch in golden_choices.items():
        for arch, chosen in per_arch.items():
            assert bench_search["kernels"][bench][arch]["chosen"] == chosen, (
                f"{bench}/{arch}"
            )


def test_golden_search_choices_recompute(golden_choices, measured):
    """Live recompute of the measured (benchmark, arch) cells matches the
    pins — every cell at nightly scale, the tier-1 slice otherwise."""
    for bench, per_arch in measured["kernels"].items():
        for arch, row in per_arch.items():
            assert row["chosen"] == golden_choices[bench][arch], (
                f"{bench}/{arch}"
            )


def test_search_beats_or_matches_fixed_pipeline_everywhere(measured):
    """The PR acceptance criterion: the search-chosen variant is at least as
    good (simulated cycles) as the fixed make_variants+predict baseline on
    every benchmark x arch, and strictly better on at least one."""
    strict = 0
    for bench, per_arch in measured["kernels"].items():
        for arch, row in per_arch.items():
            assert row["cycles_chosen"] <= row["cycles_fixed"], f"{bench}/{arch}"
            strict += row["cycles_chosen"] < row["cycles_fixed"]
    assert strict >= 1
    if "summary" in measured:
        assert measured["summary"]["strict_wins"] == strict


def test_measured_summary_matches_committed(measured, bench_search):
    """Deterministic fields of a fresh recompute equal the committed report:
    per-cell values for every measured cell, plus the summary at nightly
    scale (throughput/wall-time fields excluded)."""
    for bench, per_arch in measured["kernels"].items():
        for arch, row in per_arch.items():
            committed = bench_search["kernels"][bench][arch]
            for key in ("chosen", "fixed_best", "cycles_chosen",
                        "cycles_fixed", "win", "speedup_vs_nvcc",
                        "agreement", "space_size", "explored"):
                assert row[key] == committed[key], f"{bench}/{arch}/{key}"
    if "summary" in measured:
        for key in ("searches", "explored", "geomean_win", "strict_wins",
                    "mean_agreement"):
            assert measured["summary"][key] == bench_search["summary"][key], key


# ---------------------------------------------------------------------------
# structure / edge cases
# ---------------------------------------------------------------------------


def _no_spill_kernel():
    """Every register is ABI (live-in/out): nothing is demotable."""
    k = Kernel(name="pinned", live_in={0, 1}, live_out={2}, num_blocks=64,
               threads_per_block=64)
    k.items = [
        Instr("FADD", [2], [0, 1]),
        Instr("STG", srcs=[1, 2]),
        Instr("EXIT"),
    ]
    return schedule(k)


def test_search_with_nothing_to_demote_keeps_baseline():
    k = _no_spill_kernel()
    out = search(k, SearchConfig(archs=("maxwell",)), cache=SimCache())
    assert out.report.chosen == "maxwell/nvcc"
    assert out.report.explored == 0
    assert out.report.space_size == 1  # just the baseline
    assert out.kernel.render() == k.render()
    assert out.kernel is not k  # a copy, never an alias


def test_search_report_json_is_deterministic_and_complete():
    k = generate(random_profile(7))
    r1 = search(k, SearchConfig(archs=("maxwell",)), cache=SimCache()).report
    r2 = search(k, SearchConfig(archs=("maxwell",)), cache=SimCache()).report
    assert r1.to_json() == r2.to_json()  # wall time excluded by contract
    j = r1.to_json()
    assert j["chosen"] in j["cycles"]
    assert j["baseline"] in j["cycles"]
    assert all(v["label"] for v in j["variants"])


def test_search_identical_across_pool_sizes():
    """1 worker vs N workers: byte-identical winning kernel, identical
    report (the hypothesis suite sweeps this over random kernels; this is
    the always-on pin)."""
    k = generate(random_profile(42))
    cfg = dict(max_targets=1, beam_width=3, top_k=2)
    serial = search(k, SearchConfig(workers=0, **cfg), cache=SimCache())
    pooled = search(k, SearchConfig(workers=3, **cfg), cache=SimCache())
    assert dumps(serial.kernel) == dumps(pooled.kernel)
    assert serial.report.to_json() == pooled.report.to_json()


def test_workers_and_seed_not_part_of_config_signature():
    """Neither knob changes the result, so neither may cause a cache miss."""
    assert (
        SearchConfig(workers=0).signature()
        == SearchConfig(workers=8).signature()
    )
    assert (
        SearchConfig(seed=0).signature() == SearchConfig(seed=99).signature()
    )
    assert (
        SearchConfig(beam_width=2).signature()
        != SearchConfig(beam_width=3).signature()
    )


def test_cross_arch_anchor_without_baseline_rejected():
    """An anchor on an arch outside the search has no comparable baseline
    (cross-arch cycles are different units) — reject instead of ranking it
    against the wrong nvcc."""
    from repro.arch import retarget

    k = generate(random_profile(7))
    foreign = retarget(k, "volta")
    with pytest.raises(ValueError, match="volta"):
        search(
            k,
            SearchConfig(archs=("maxwell",)),
            extra_variants={"volta/foreign": foreign},
            cache=SimCache(),
        )


def test_translate_binary_tune_rejects_fixed_pipeline_args():
    from repro.core.translator import translate_binary

    blob = dumps(generate(random_profile(7)))
    with pytest.raises(ValueError, match="do not apply"):
        translate_binary(blob, target_regs=32, tune=True)
    with pytest.raises(ValueError, match="conflicting verify"):
        translate_binary(
            blob, tune=True, verify="each",
            search_config=SearchConfig(archs=("maxwell",)),
        )


# ---------------------------------------------------------------------------
# service: tuned containers, notes, cache purity
# ---------------------------------------------------------------------------


def test_tune_embeds_search_notes_and_preserves_semantics():
    kernels = [generate(random_profile(13)), generate(random_profile(21))]
    blob = dumps(kernels)
    svc = TranslationService()
    out, batch = svc.tune(blob, SearchConfig(archs=("maxwell",)))
    notes = read_notes(out)
    decoded = loads_many(out)
    assert len(decoded) == len(kernels)
    for i, (orig, dec, rep) in enumerate(zip(kernels, decoded, batch.reports)):
        assert equivalent(orig, dec)
        assert rep.search is not None
        note = json.loads(notes[f"search.{i}.{orig.name}"])
        assert note == rep.search.to_json()
        assert note["chosen"] == rep.chosen


def test_retune_is_pure_cache_hit_and_byte_identical(monkeypatch):
    from repro.core import passes as passes_mod

    kernels = [generate(random_profile(33))]
    blob = dumps(kernels)
    svc = TranslationService()
    cfg = SearchConfig(archs=("maxwell",))
    out1, batch1 = svc.tune(blob, cfg)
    assert batch1.cached == [False]

    ran = []
    orig_run = passes_mod.PassPipeline.run
    monkeypatch.setattr(
        passes_mod.PassPipeline,
        "run",
        lambda self, ctx: ran.append(1) or orig_run(self, ctx),
    )
    out2, batch2 = svc.tune(blob, cfg)
    assert batch2.cached == [True]
    assert ran == []  # zero pipeline passes on the cached path
    assert out2 == out1  # byte-identical container, notes included
