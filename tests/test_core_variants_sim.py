"""Variant generation + timing-simulator tests (paper §5.3-5.5)."""

import math

import pytest

from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.sched import verify_schedule
from repro.core.simulator import flatten_trace, simulate, speedup
from repro.core.variants import VARIANT_NAMES, aggressive, make_variants


@pytest.fixture(scope="module")
def cfd_variants():
    return make_variants(PAPER_BENCHMARKS["cfd"])


def test_all_variants_present(cfd_variants):
    assert set(cfd_variants) == set(VARIANT_NAMES)


def test_variants_semantics_and_schedules(cfd_variants):
    base = cfd_variants["nvcc"].kernel
    for name, v in cfd_variants.items():
        assert equivalent(base, v.kernel), name
        assert verify_schedule(v.kernel) == [], name


def test_local_variant_spills_to_local_memory(cfd_variants):
    ops = {i.op for i in cfd_variants["local"].kernel.instructions()}
    assert "LDL" in ops and "STL" in ops
    assert cfd_variants["local"].spilled > 0


def test_local_shared_variant_uses_shared(cfd_variants):
    k = cfd_variants["local-shared"].kernel
    ops = {i.op for i in k.instructions()}
    assert "LDL" not in ops and "STL" not in ops
    assert k.demoted_size > 0


def test_remat_dilates_instruction_stream(cfd_variants):
    base = len(cfd_variants["nvcc"].kernel.instructions())
    ls = cfd_variants["local-shared"]
    assert ls.remat > 0
    assert len(ls.kernel.instructions()) > base


def test_aggressive_respects_target():
    base = paper_kernel("gaussian")
    v = aggressive(base, 36, spill_space="local")
    assert v.kernel.reg_count <= 36
    assert equivalent(base, v.kernel)


# ---------------------------------------------------------------------------
# simulator behaviour
# ---------------------------------------------------------------------------


def test_trace_expands_loops():
    k = paper_kernel("conv")
    trace = flatten_trace(k)
    assert len(trace) > len(k.instructions())


def test_sim_occupancy_helps_latency_bound():
    """More resident warps must speed up a latency-bound kernel (the paper's
    core premise).  nn is chase-load bound; demotion raises occupancy."""
    vs = make_variants(PAPER_BENCHMARKS["nn"])
    s_base = simulate(vs["nvcc"].kernel)
    s_rd = simulate(vs["regdem"].kernel)
    assert s_rd.occupancy.resident_warps > s_base.occupancy.resident_warps
    assert speedup(s_base, s_rd) > 1.0


def test_sim_fp64_insensitive_to_occupancy():
    """md is FP64-throughput-bound: no variant helps (paper §5.5)."""
    vs = make_variants(PAPER_BENCHMARKS["md"])
    s = {n: simulate(v.kernel) for n, v in vs.items()}
    base = s["nvcc"]
    for n in ("regdem", "local", "local-shared"):
        assert abs(speedup(base, s[n]) - 1.0) < 0.05, n


def test_sim_regdem_beats_local_on_spill_heavy():
    """cfd needs many spills: shared-memory demotion must beat local-memory
    spilling (the paper's headline comparison)."""
    vs = make_variants(PAPER_BENCHMARKS["cfd"])
    s = {n: simulate(v.kernel) for n, v in vs.items()}
    assert s["regdem"].total_cycles < s["local"].total_cycles
    assert s["regdem"].total_cycles < s["local-shared"].total_cycles


def test_sim_geomean_reproduces_paper_band():
    """Geomean RegDem speedup must land in the paper's reported band
    (1.07x nvcc geomean; we accept 1.02-1.15 for the simulator stand-in)."""
    logs = []
    for name, prof in PAPER_BENCHMARKS.items():
        vs = make_variants(prof)
        base = simulate(vs["nvcc"].kernel)
        logs.append(math.log(speedup(base, simulate(vs["regdem"].kernel))))
    gm = math.exp(sum(logs) / len(logs))
    assert 1.02 <= gm <= 1.15, gm
