"""Real-workload corpus tests (repro.data.corpus + benchmarks.corpus_bench).

Four layers:

* **extraction** — the corpus is deterministic, covers every model config
  and serving phase, and every extracted profile is generable and carries a
  real demotion target (``regdem_target < target_regs`` — the spill_targets
  32-register floor sat *above* small decode kernels until the corpus
  flushed it);
* **golden** — the extracted profiles are pinned field-for-field in
  ``tests/golden/corpus_profiles.json``; the per-cell search choices in
  ``tests/golden/corpus_choices.json`` must agree with the committed
  ``BENCH_corpus.json`` always, and with a live recompute (a small
  deterministic slice in tier-1; every cell when ``REGDEM_PROPERTY_SCALE``
  raises the budget, as nightly CI does);
* **variants** — the flushed unlaunchable-conversion bug stays fixed:
  corpus kernels with large static shared memory drop the Hayes & Zhang
  conversions that would exceed the per-block limit instead of crashing
  downstream occupancy math;
* **tune→serve** — a model config's corpus container round-trips through
  ``TranslationService.tune`` with a persistent ArtifactStore: the warm
  restart runs zero pipeline passes and returns byte-identical output.
"""

import dataclasses
import json
import os

import pytest

from repro.core.kernelgen import generate
from repro.core.search import SearchConfig
from repro.data.corpus import (
    CORPUS_BENCHMARKS,
    corpus_container,
    corpus_profiles,
    kernel_instances,
    model_corpus_names,
)

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

GOLDEN_PROFILES = os.path.join(
    os.path.dirname(__file__), "golden", "corpus_profiles.json"
)
GOLDEN_CHOICES = os.path.join(
    os.path.dirname(__file__), "golden", "corpus_choices.json"
)
BENCH_CORPUS_PATH = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_corpus.json"
)

#: tier-1 live-recompute slice: one cell per kernel kind x phase x arch
#: regime (prefill/decode, attn/ssd, small/large registers)
TIER1_RECOMPUTE = [
    "gemma3_1b.prefill.attn",
    "gemma3_1b.decode.attn",
    "mamba2_370m.prefill.ssd",
    "zamba2_2_7b.decode.ssd",
]


@pytest.fixture(scope="module")
def golden_profiles():
    with open(GOLDEN_PROFILES) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def golden_choices():
    with open(GOLDEN_CHOICES) as fh:
        return json.load(fh)


@pytest.fixture(scope="module")
def bench_corpus():
    with open(BENCH_CORPUS_PATH) as fh:
        return json.load(fh)


# ---------------------------------------------------------------------------
# extraction
# ---------------------------------------------------------------------------


def test_corpus_covers_every_model_and_phase():
    from repro.configs.base import ARCH_IDS

    models = {n.split(".")[0] for n in CORPUS_BENCHMARKS}
    assert models == set(ARCH_IDS)
    for model in ARCH_IDS:
        phases = {n.split(".")[1] for n in model_corpus_names(model)}
        assert phases == {"prefill", "decode"}, model
    # hybrid configs contribute both kernel kinds
    assert "zamba2_2_7b.prefill.attn" in CORPUS_BENCHMARKS
    assert "zamba2_2_7b.prefill.ssd" in CORPUS_BENCHMARKS


def test_corpus_extraction_is_deterministic():
    assert corpus_profiles() == corpus_profiles()
    assert [i.name for i in kernel_instances()] == list(CORPUS_BENCHMARKS)


def test_every_corpus_profile_generates_with_real_demotion_target():
    """Regression (corpus-flushed): spill_targets floors at 32 registers,
    which sits *above* a small decode kernel's register count — the
    extraction must never emit regdem_target >= target_regs."""
    for name, prof in CORPUS_BENCHMARKS.items():
        k = generate(prof)
        assert k.reg_count <= prof.target_regs + 2, name
        assert prof.regdem_target < prof.target_regs, name
        assert prof.n_state >= 2, name


def test_model_corpus_names_unknown_model():
    with pytest.raises(KeyError):
        model_corpus_names("not_a_model")


# ---------------------------------------------------------------------------
# golden pins
# ---------------------------------------------------------------------------


def test_golden_profiles_match_extraction(golden_profiles):
    """Field-for-field pin: any extraction drift must be a deliberate
    golden regeneration, never an accident."""
    live = {n: dataclasses.asdict(p) for n, p in CORPUS_BENCHMARKS.items()}
    assert live == golden_profiles


def test_bench_corpus_json_matches_golden_choices(golden_choices, bench_corpus):
    assert set(bench_corpus["kernels"]) == set(golden_choices)
    for name, per_arch in golden_choices.items():
        for arch, chosen in per_arch.items():
            assert bench_corpus["kernels"][name][arch]["chosen"] == chosen, (
                f"{name}/{arch}"
            )


def test_bench_corpus_beats_or_ties_fixed_everywhere(bench_corpus):
    """The PR acceptance criterion, checked against the committed report:
    the tuned search beats-or-ties the fixed §5.3 pick on every corpus
    kernel x arch cell."""
    s = bench_corpus["summary"]
    assert s["beats_or_ties"] == s["searches"]
    assert s["geomean_win"] >= 1.0
    for name, per_arch in bench_corpus["kernels"].items():
        for arch, row in per_arch.items():
            assert row["cycles_chosen"] <= row["cycles_fixed"], f"{name}/{arch}"


def test_golden_corpus_choices_recompute(golden_choices):
    """Live search recompute matches the pins.  Tier-1 runs a fixed slice
    of regimes; the nightly scale sweep recomputes every cell."""
    from benchmarks.search_bench import tune_profile

    names = list(golden_choices) if SCALE > 1 else TIER1_RECOMPUTE
    for name in names:
        for arch, chosen in golden_choices[name].items():
            row = tune_profile(CORPUS_BENCHMARKS[name], arch)
            assert row["chosen"] == chosen, f"{name}/{arch}"
            assert row["cycles_chosen"] <= row["cycles_fixed"], f"{name}/{arch}"


# ---------------------------------------------------------------------------
# variants: the unlaunchable-conversion regression
# ---------------------------------------------------------------------------


def test_unlaunchable_local_shared_dropped_not_crashing():
    """Regression (corpus-flushed): gemma3_1b.prefill.attn carries 24 KiB
    static shared memory x 256 threads — converting its spills to shared
    at the 32-register floor exceeds Maxwell's 48 KiB block limit.  The
    fixed §5.3 set must drop that unlaunchable conversion (as a real launch
    failure would) and the predictor must rank the remainder, not raise."""
    from repro.core.predictor import predict
    from repro.core.spillspace import spill_limit
    from repro.core.variants import make_variants_for

    prof = CORPUS_BENCHMARKS["gemma3_1b.prefill.attn"]
    k = generate(prof)
    fixed = make_variants_for(k, prof.regdem_target, prof.nvcc_spills)
    assert "local-shared" not in fixed          # would not fit -> not launchable
    assert "local-shared-relax" in fixed        # fits at the relaxed target
    for v in fixed.values():
        assert v.kernel.total_shared <= spill_limit(v.kernel), v.name
    best, _ = predict({n: v.kernel for n, v in fixed.items()})
    assert best in fixed


def test_small_kernels_keep_all_five_variants():
    """The drop is surgical: kernels whose conversions fit keep the full
    §5.3 matrix (the synthetic nine and small-smem corpus kernels)."""
    from repro.core.variants import VARIANT_NAMES, make_variants_for

    prof = CORPUS_BENCHMARKS["whisper_large_v3.decode.attn"]
    k = generate(prof)
    fixed = make_variants_for(k, prof.regdem_target, prof.nvcc_spills)
    assert set(fixed) == set(VARIANT_NAMES)


# ---------------------------------------------------------------------------
# tune -> serve round trip
# ---------------------------------------------------------------------------


def test_corpus_tune_serve_warm_restart_zero_passes(tmp_path):
    """A model config's corpus container tunes once, then a *fresh* service
    over the same store serves it with zero pipeline passes, byte-identical
    (the serve_batched.py end-to-end invariant)."""
    from repro.core.artifacts import ArtifactStore
    from repro.core.passes import PIPELINE_COUNTERS
    from repro.core.translator import TranslationService

    cfg = SearchConfig(max_targets=1, beam_width=2, top_k=1)
    data = corpus_container("whisper_large_v3")
    first, rep1 = TranslationService(store=ArtifactStore(str(tmp_path))).tune(
        data, cfg
    )
    assert rep1.cache_misses == len(model_corpus_names("whisper_large_v3"))

    svc = TranslationService(store=ArtifactStore(str(tmp_path)))
    before = dict(PIPELINE_COUNTERS)
    again, rep2 = svc.tune(data, cfg)
    after = dict(PIPELINE_COUNTERS)
    assert again == first
    assert rep2.cache_misses == 0 and rep2.hit_rate == 1.0
    assert after["passes"] == before["passes"]
    assert after["pipelines"] == before["pipelines"]
    assert svc.cache.disk_hits == len(rep2.reports)


def test_corpus_container_reports_embed_search_notes(tmp_path):
    """Tuned corpus containers carry their per-kernel search reports as
    .note sections, recoverable by name."""
    from repro.binary import read_notes
    from repro.core.translator import TranslationService

    cfg = SearchConfig(max_targets=1, beam_width=2, top_k=1)
    tuned, rep = TranslationService().tune(corpus_container("stablelm_3b"), cfg)
    notes = read_notes(tuned)
    for i, r in enumerate(rep.reports):
        key = f"search.{i}.{r.kernel_name}"
        assert key in notes
        payload = json.loads(notes[key])
        assert payload["chosen"] == r.search.chosen
