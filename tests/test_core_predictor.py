"""Performance-predictor tests (paper §4, Fig. 5/9)."""

import pytest

from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.predictor import (
    OCCUPANCY_CURVE,
    estimate_stalls,
    f_occupancy,
    naive_stalls,
    predict,
    predict_naive,
)
from repro.core.variants import make_variants


def test_occupancy_curve_monotone():
    ys = [y for _, y in OCCUPANCY_CURVE]
    assert all(a >= b for a, b in zip(ys, ys[1:]))
    assert OCCUPANCY_CURVE[-1][1] == pytest.approx(1.0)


def test_f_occupancy_interpolation():
    lo = OCCUPANCY_CURVE[0]
    hi = OCCUPANCY_CURVE[-1]
    assert f_occupancy(lo[0] / 2) == lo[1]          # clamp below
    assert f_occupancy(hi[0] + 1) == hi[1]          # clamp above
    mid = (OCCUPANCY_CURVE[2][0] + OCCUPANCY_CURVE[3][0]) / 2
    assert (
        min(OCCUPANCY_CURVE[3][1], OCCUPANCY_CURVE[2][1])
        <= f_occupancy(mid)
        <= max(OCCUPANCY_CURVE[3][1], OCCUPANCY_CURVE[2][1])
    )


def test_estimate_scales_with_loop_factor():
    k = paper_kernel("conv")
    total = estimate_stalls(k, occupancy=0.75)
    assert total > naive_stalls(k)  # loops weighted x10 + latency residuals


def test_estimate_monotone_in_occupancy_contention():
    # eq. 2: same code at higher occupancy sees more contention stalls
    k = paper_kernel("md5hash")
    assert estimate_stalls(k, 1.0) > estimate_stalls(k, 0.5)


def test_predictor_picks_regdem_for_spill_heavy():
    vs = make_variants(PAPER_BENCHMARKS["cfd"])
    best, preds = predict({n: v.kernel for n, v in vs.items()})
    assert best == "regdem"
    names = {p.name for p in preds}
    assert names == set(vs)


def test_predictor_avoids_worst_case():
    """§5.7: the predictor helps avoid the worst-case scenario.  For
    gaussian (tail-wave launch) it must not pick a deep-spill variant."""
    vs = make_variants(PAPER_BENCHMARKS["gaussian"])
    best, _ = predict({n: v.kernel for n, v in vs.items()})
    assert best != "local-shared"


def test_predictor_accuracy_band():
    """Predictor must reach >=90% of the oracle geomean (paper: 99%)."""
    import math

    from repro.core.simulator import simulate, speedup

    logs_o, logs_p = [], []
    for name, prof in PAPER_BENCHMARKS.items():
        vs = make_variants(prof)
        kernels = {n: v.kernel for n, v in vs.items()}
        sims = {n: simulate(k) for n, k in kernels.items()}
        base = sims["nvcc"]
        sp = {n: speedup(base, sims[n]) for n in kernels}
        oracle = max(sp.values())
        best, _ = predict(kernels)
        logs_o.append(math.log(oracle))
        logs_p.append(math.log(sp[best]))
    gm_o = math.exp(sum(logs_o) / len(logs_o))
    gm_p = math.exp(sum(logs_p) / len(logs_p))
    assert gm_p / gm_o >= 0.90, (gm_p, gm_o)


def test_naive_differs_from_full_predictor():
    vs = make_variants(PAPER_BENCHMARKS["nn"])
    kernels = {n: v.kernel for n, v in vs.items()}
    nv = predict_naive(kernels)
    full, _ = predict(kernels)
    # the naive scheme ignores occupancy and latency residuals; on nn it
    # keeps the baseline while the full predictor exploits occupancy
    assert nv == "nvcc"
    assert full != "nvcc"
