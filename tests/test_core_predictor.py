"""Performance-predictor tests (paper §4, Fig. 5/9)."""

import pytest

from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.predictor import (
    OCCUPANCY_CURVE,
    estimate_stalls,
    f_occupancy,
    naive_stalls,
    predict,
    predict_naive,
    ranking_agreement,
)
from repro.core.variants import make_variants


def test_occupancy_curve_monotone():
    ys = [y for _, y in OCCUPANCY_CURVE]
    assert all(a >= b for a, b in zip(ys, ys[1:]))
    assert OCCUPANCY_CURVE[-1][1] == pytest.approx(1.0)


def test_f_occupancy_interpolation():
    lo = OCCUPANCY_CURVE[0]
    hi = OCCUPANCY_CURVE[-1]
    assert f_occupancy(lo[0] / 2) == lo[1]          # clamp below
    assert f_occupancy(hi[0] + 1) == hi[1]          # clamp above
    mid = (OCCUPANCY_CURVE[2][0] + OCCUPANCY_CURVE[3][0]) / 2
    assert (
        min(OCCUPANCY_CURVE[3][1], OCCUPANCY_CURVE[2][1])
        <= f_occupancy(mid)
        <= max(OCCUPANCY_CURVE[3][1], OCCUPANCY_CURVE[2][1])
    )


def test_estimate_scales_with_loop_factor():
    k = paper_kernel("conv")
    total = estimate_stalls(k, occupancy=0.75)
    assert total > naive_stalls(k)  # loops weighted x10 + latency residuals


def test_estimate_monotone_in_occupancy_contention():
    # eq. 2: same code at higher occupancy sees more contention stalls
    k = paper_kernel("md5hash")
    assert estimate_stalls(k, 1.0) > estimate_stalls(k, 0.5)


def test_predictor_picks_regdem_for_spill_heavy():
    vs = make_variants(PAPER_BENCHMARKS["cfd"])
    best, preds = predict({n: v.kernel for n, v in vs.items()})
    assert best == "regdem"
    names = {p.name for p in preds}
    assert names == set(vs)


def test_predictor_avoids_worst_case():
    """§5.7: the predictor helps avoid the worst-case scenario.  For
    gaussian (tail-wave launch) it must not pick a deep-spill variant."""
    vs = make_variants(PAPER_BENCHMARKS["gaussian"])
    best, _ = predict({n: v.kernel for n, v in vs.items()})
    assert best != "local-shared"


def test_predictor_accuracy_band():
    """Predictor must reach >=90% of the oracle geomean (paper: 99%)."""
    import math

    from repro.core.simulator import simulate, speedup

    logs_o, logs_p = [], []
    for name, prof in PAPER_BENCHMARKS.items():
        vs = make_variants(prof)
        kernels = {n: v.kernel for n, v in vs.items()}
        sims = {n: simulate(k) for n, k in kernels.items()}
        base = sims["nvcc"]
        sp = {n: speedup(base, sims[n]) for n in kernels}
        oracle = max(sp.values())
        best, _ = predict(kernels)
        logs_o.append(math.log(oracle))
        logs_p.append(math.log(sp[best]))
    gm_o = math.exp(sum(logs_o) / len(logs_o))
    gm_p = math.exp(sum(logs_p) / len(logs_p))
    assert gm_p / gm_o >= 0.90, (gm_p, gm_o)


#: Pinned predictor-vs-simulator pairwise ranking agreement per benchmark
#: (9 benchmarks x 5 variants = 10 variant pairs each, so every value is a
#: multiple of 0.1).  The §5 accuracy claim as numbers: a regression in
#: ``estimate_stalls`` (or the occupancy curve, or the eq.-3 adjustment)
#: shifts these and fails loudly instead of silently degrading choices.
PINNED_AGREEMENT = {
    "cfd": 0.6, "qtc": 0.9, "md5hash": 0.9, "md": 0.8, "gaussian": 0.7,
    "conv": 0.3, "nn": 0.9, "pc": 0.8, "vp": 0.9,
}


def test_ranking_agreement_helper():
    assert ranking_agreement({"a": 1.0, "b": 2.0}, {"a": 10, "b": 20}) == 1.0
    assert ranking_agreement({"a": 1.0, "b": 2.0}, {"a": 20, "b": 10}) == 0.0
    # ties agree only with ties
    assert ranking_agreement({"a": 1.0, "b": 1.0}, {"a": 5, "b": 5}) == 1.0
    assert ranking_agreement({"a": 1.0, "b": 1.0}, {"a": 5, "b": 6}) == 0.0
    # disjoint / single-name inputs degenerate to perfect agreement
    assert ranking_agreement({"a": 1.0}, {"b": 2.0}) == 1.0


@pytest.mark.parametrize("name", sorted(PAPER_BENCHMARKS))
def test_predictor_fidelity_pinned(name):
    """Predictor-vs-simulator ranking agreement across the five §5.3
    variants, pinned per benchmark."""
    from repro.core.simcache import simulate_cached

    vs = make_variants(PAPER_BENCHMARKS[name])
    kernels = {n: v.kernel for n, v in vs.items()}
    _, preds = predict(kernels)
    predicted = {p.name: p.adjusted for p in preds}
    measured = {n: simulate_cached(k).total_cycles for n, k in kernels.items()}
    assert ranking_agreement(predicted, measured) == pytest.approx(
        PINNED_AGREEMENT[name], abs=1e-12
    )


def test_pinned_agreement_floor_guard():
    """Guard on the pins themselves (live values are checked per benchmark
    by test_predictor_fidelity_pinned): nobody may "fix" a fidelity
    regression by editing the pinned values below the headline floor."""
    assert sum(PINNED_AGREEMENT.values()) / len(PINNED_AGREEMENT) >= 0.75


def test_naive_differs_from_full_predictor():
    vs = make_variants(PAPER_BENCHMARKS["nn"])
    kernels = {n: v.kernel for n, v in vs.items()}
    nv = predict_naive(kernels)
    full, _ = predict(kernels)
    # the naive scheme ignores occupancy and latency residuals; on nn it
    # keeps the baseline while the full predictor exploits occupancy
    assert nv == "nvcc"
    assert full != "nvcc"
