"""RegDem algorithm tests: targets, semantics, barriers, layout (paper §3)."""

import itertools

import pytest

from repro.core.candidates import make_candidates, operand_conflicts
from repro.core.isa import NUM_SMEM_BANKS, equivalent, smem_bank
from repro.core.kernelgen import PAPER_BENCHMARKS, all_paper_kernels, generate, random_profile
from repro.core.occupancy import occupancy_of
from repro.core.regdem import REG_FLOOR, RegDemOptions, auto_targets, demote
from repro.core.sched import verify_schedule

KERNELS = all_paper_kernels()


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_demotion_reaches_table1_target(name):
    k = KERNELS[name]
    prof = PAPER_BENCHMARKS[name]
    res = demote(k, prof.regdem_target)
    assert res.kernel.reg_count <= prof.regdem_target
    assert res.reached_target
    # occupancy strictly improves (that is the whole point)
    assert occupancy_of(res.kernel).occupancy > occupancy_of(k).occupancy


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_demotion_preserves_semantics(name):
    k = KERNELS[name]
    res = demote(k, PAPER_BENCHMARKS[name].regdem_target)
    assert equivalent(k, res.kernel)


@pytest.mark.parametrize("name", sorted(KERNELS))
def test_demotion_schedule_is_consistent(name):
    k = KERNELS[name]
    res = demote(k, PAPER_BENCHMARKS[name].regdem_target)
    assert verify_schedule(res.kernel) == []


def test_all_option_combinations_safe():
    k = KERNELS["pc"]
    tgt = PAPER_BENCHMARKS["pc"].regdem_target
    for strat in ("static", "cfg", "conflict"):
        for b, e, r, s in itertools.product([False, True], repeat=4):
            opt = RegDemOptions(
                candidate_strategy=strat,
                bank_avoid=b,
                elim_redundant=e,
                reschedule=r,
                substitute=s,
            )
            res = demote(k, tgt, opt)
            assert equivalent(k, res.kernel), opt.label()
            assert verify_schedule(res.kernel) == [], opt.label()


def test_demoted_layout_is_bank_conflict_free():
    """Eq. 1 invariant: all threads of a warp hit distinct smem banks."""
    for n_threads in (64, 128, 256):
        for s in (0, 512, 2052):  # including a non-multiple-of-4 static size
            s_up = (s + 3) // 4 * 4
            for r in range(4):  # demoted register index
                banks = [
                    smem_bank(t * 4 + s_up + r * n_threads * 4) for t in range(32)
                ]
                assert len(set(banks)) == NUM_SMEM_BANKS


def test_demoted_size_accounting():
    k = KERNELS["nn"]
    res = demote(k, 32)
    assert res.kernel.demoted_size == res.demoted_words * k.threads_per_block * 4
    assert res.kernel.total_shared == k.shared_size + res.kernel.demoted_size


def test_stops_at_reg_floor():
    # demotion must not push below 32 registers (no occupancy gain there)
    k = KERNELS["md5hash"]
    res = demote(k, 8)
    assert res.kernel.reg_count >= REG_FLOOR


def test_multiword_demotion_alignment():
    """Force actual FP64-pair demotion: few single-word candidates exist, so
    reaching the target requires demoting aligned pairs (§3.2 extension)."""
    from repro.core.kernelgen import Profile, generate

    prof = Profile(
        name="fp64_heavy",
        target_regs=40,
        threads_per_block=256,
        num_blocks=512,
        shared_size=0,
        regdem_target=32,
        nvcc_spills=0,
        loop_trips=6,
        n_consts=2,
        n_temps=2,
        fp64_frac=1.0,
        loads_per_iter=1,
        seed=77,
    )
    k = generate(prof)
    res = demote(k, 32)
    assert equivalent(k, res.kernel)
    assert verify_schedule(res.kernel) == []
    pairs = [(r, w) for r, w in res.demoted if w == 2]
    assert pairs, "expected at least one demoted FP64 pair"
    # pair demotion uses an even-aligned RDV in the final numbering
    assert res.rdv % 2 == 0
    # per-word slots: every demoted word owns n*4 bytes of shared memory
    assert res.kernel.demoted_size == res.demoted_words * 256 * 4


def test_operand_conflict_pruning():
    k = KERNELS["cfd"]
    conf = operand_conflicts(k)
    res = demote(k, PAPER_BENCHMARKS["cfd"].regdem_target)
    demoted_regs = [r for r, _ in res.demoted]
    # no two demoted registers may conflict (they share one RDV)
    for a, b in itertools.combinations(demoted_regs, 2):
        assert b not in conf.get(a, set()), (a, b)


def test_candidate_strategies_order_and_exclusions():
    k = KERNELS["qtc"]
    for strat in ("static", "cfg", "conflict"):
        cands = make_candidates(k, strat)
        regs = [r for r, _ in cands]
        assert len(regs) == len(set(regs))
        for r in k.live_in:
            assert r not in regs
    with pytest.raises(ValueError):
        make_candidates(k, "bogus")


def test_auto_targets_match_occupancy_cliffs():
    k = KERNELS["cfd"]
    tgts = auto_targets(k)
    assert tgts and tgts[0] < k.reg_count
    occs = [occupancy_of(k).occupancy]
    for t in tgts:
        res = demote(k, t)
        occs.append(occupancy_of(res.kernel).occupancy)
    assert all(b > a for a, b in zip(occs, occs[1:]))


def test_random_kernels_demotable():
    for seed in range(12):
        k = generate(random_profile(seed))
        tgts = auto_targets(k)
        if not tgts:
            continue
        res = demote(k, tgts[0])
        assert equivalent(k, res.kernel), seed
        assert verify_schedule(res.kernel) == [], seed


def test_no_user_smem_traffic_without_static_allocation():
    """Regression (found by the autotuning-search seed sweep): a generated
    kernel with ``shared_size == 0`` must emit no user STS/LDS — offset 0
    is where eq. 1 places the demoted-register slots, so such traffic
    silently corrupted demoted values."""
    from repro.core.kernelgen import Profile

    prof = Profile(
        name="nosmem", target_regs=40, threads_per_block=128, num_blocks=256,
        shared_size=0, regdem_target=34, nvcc_spills=0, smem_ops_per_iter=2,
    )
    k = generate(prof)
    assert {"STS", "LDS"} & {i.op for i in k.instructions()} == set()
    res = demote(k, prof.regdem_target)
    assert equivalent(k, res.kernel)


def test_demotion_on_seed123_regression_kernel():
    """The concrete kernel the bug was found on: random_profile(123) has
    smem ops but no static shared allocation; demotion must stay
    dataflow-equivalent under every candidate strategy."""
    k = generate(random_profile(123))
    for strategy in ("static", "cfg", "conflict"):
        res = demote(k, 32, RegDemOptions(candidate_strategy=strategy))
        assert equivalent(k, res.kernel), strategy
        assert verify_schedule(res.kernel) == [], strategy
