"""End-to-end behaviour tests: the full pyReDe translation pipeline."""


from repro.core.isa import equivalent
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.occupancy import occupancy_of
from repro.core.postopt import eliminate_redundant
from repro.core.regdem import RegDemOptions, demote
from repro.core.sched import verify_schedule
from repro.core.translator import option_space, roundtrip, translate


def test_translate_pipeline_end_to_end():
    k = paper_kernel("conv")
    rep = translate(k)
    assert rep.chosen != "nvcc"  # conv benefits from demotion
    chosen = rep.chosen_kernel
    assert equivalent(k, chosen)
    assert verify_schedule(chosen) == []
    assert occupancy_of(chosen).occupancy > occupancy_of(k).occupancy
    # re-emission (the MaxAs step) is stable
    roundtrip(chosen)


def test_translate_explicit_target():
    k = paper_kernel("cfd")
    rep = translate(k, target_regs=56)
    assert all("@56" in n for n in rep.results)


def test_option_space_sizes():
    assert len(option_space()) == 12
    assert len(option_space(full=True)) == 48


def test_translate_considers_baseline():
    k = paper_kernel("gaussian")
    rep = translate(k)
    assert "nvcc" in rep.considered
    # predictions cover every considered variant
    assert set(rep.predictions) == set(rep.considered)


def test_postopt_passes_reduce_demote_traffic():
    k = paper_kernel("pc")
    res = demote(
        k,
        PAPER_BENCHMARKS["pc"].regdem_target,
        RegDemOptions(elim_redundant=False, reschedule=False, substitute=False),
    )
    raw = res.kernel
    n_before = sum(1 for i in raw.instructions() if i.tag == "demoted_load")
    removed = eliminate_redundant(raw, res.rdv)
    n_after = sum(1 for i in raw.instructions() if i.tag == "demoted_load")
    assert removed >= 0 and n_after <= n_before
    assert equivalent(k, raw)
    assert verify_schedule(raw) == []


def test_demotion_improves_occupancy_on_all_benchmarks():
    """Paper Table 1: RegDem improves occupancy on every benchmark."""
    for name, prof in PAPER_BENCHMARKS.items():
        k = paper_kernel(name)
        res = demote(k, prof.regdem_target)
        assert occupancy_of(res.kernel).occupancy > occupancy_of(k).occupancy, name
