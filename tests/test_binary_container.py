"""Container-format tests: metadata fidelity, multi-kernel files, and
strictness against corruption."""

import pytest

from repro.binary import container
from repro.binary.container import ContainerError, dumps, kernel_names, loads, loads_many
from repro.binary.encoding import EncodingError, instr_addr
from repro.core.isa import Instr, Kernel, Label
from repro.core.kernelgen import paper_kernel
from repro.core.regdem import auto_targets, demote
from repro.core.sched import schedule


def tiny_kernel(name="tiny") -> Kernel:
    k = Kernel(name=name, live_in={1}, live_out={7}, threads_per_block=64, num_blocks=8)
    k.items = [
        Instr("MOV32I", dsts=[4], imm=2.5),
        Instr("LDG", dsts=[5], srcs=[1], offset=0x40),
        Label("L0"),
        Instr("FADD", dsts=[7], srcs=[4, 5], pred=1, pred_neg=True),
        Instr("ISETP", srcs=[4, 5], pdst=2),
        Instr("BRA", target="L0", pred=2, trip_count=3),
        Instr("EXIT"),
    ]
    return schedule(k)


def test_metadata_round_trip():
    k = tiny_kernel()
    k.shared_size = 512
    k.demoted_size = 256
    k.rda = 9
    k2 = loads(dumps(k))
    assert k2.name == "tiny"
    assert (k2.threads_per_block, k2.num_blocks) == (64, 8)
    assert (k2.shared_size, k2.demoted_size) == (512, 256)
    assert k2.live_in == {1} and k2.live_out == {7}
    assert k2.rda == 9
    assert k2.render() == k.render()


def test_instruction_field_fidelity():
    k2 = loads(dumps(tiny_kernel()))
    mov, ldg, fadd, isetp, bra, exit_ = k2.instructions()
    assert mov.imm == 2.5 and mov.dsts == [4]
    assert ldg.offset == 0x40 and ldg.srcs == [1]
    assert fadd.pred == 1 and fadd.pred_neg is True
    assert isetp.pdst == 2 and isetp.dsts == []
    assert bra.target == "L0" and bra.trip_count == 3 and bra.pred == 2
    assert exit_.op == "EXIT"
    assert isinstance(k2.items[2], Label) and k2.items[2].name == "L0"


def test_demoted_kernel_tags_and_rda_survive():
    k = paper_kernel("conv")
    res = demote(k, auto_targets(k)[0])
    k2 = loads(dumps(res.kernel))
    assert k2.rda == res.kernel.rda
    assert k2.demoted_size == res.kernel.demoted_size
    tags = {i.tag for i in k2.instructions()}
    assert "demoted_load" in tags or "demoted_store" in tags
    assert k2.render() == res.kernel.render()


def test_multi_kernel_container():
    ks = [tiny_kernel("a"), paper_kernel("md"), tiny_kernel("c")]
    blob = dumps(ks)
    assert kernel_names(blob) == ["a", "md", "c"]
    back = loads_many(blob)
    assert [k.name for k in back] == ["a", "md", "c"]
    for orig, dec in zip(ks, back):
        assert dec.render() == orig.render()
    with pytest.raises(ContainerError):
        loads(blob)  # single-kernel accessor refuses multi-kernel files


def test_deterministic_bytes():
    assert dumps(tiny_kernel()) == dumps(tiny_kernel())


def test_bad_magic_rejected():
    blob = bytearray(dumps(tiny_kernel()))
    blob[0] ^= 0xFF
    with pytest.raises(ContainerError, match="magic"):
        loads(bytes(blob))


def test_truncation_rejected():
    blob = dumps(tiny_kernel())
    with pytest.raises(ContainerError):
        loads(blob[: len(blob) - 7])
    with pytest.raises(ContainerError):
        loads(blob[:16])


def test_bitflip_rejected_by_content_crc():
    k = tiny_kernel()
    blob = bytearray(dumps(k))
    text_off = 32 + container.KINFO_SIZE
    blob[text_off + instr_addr(0) + 16] ^= 0xFF  # a bit of the immediate
    with pytest.raises(ContainerError, match="content checksum"):
        loads(bytes(blob))


def test_reg_count_tamper_rejected():
    # flip a register number inside the first instruction record AND forge
    # the content CRC: the declared-vs-recomputed register count check must
    # still catch it.  Uses a v1 container (no per-kernel CRC) so the tamper
    # reaches that deeper line of defense.
    import struct
    import zlib

    k = tiny_kernel()
    blob = bytearray(dumps(k, version=1))
    # first text section starts right after the 32-byte header + kinfo
    text_off = 32 + container.KINFO_SIZES[1]
    dst_off = text_off + instr_addr(0) + 4  # record byte 4 = dst reg
    assert blob[dst_off] == 4  # MOV32I dst is R4
    blob[dst_off] = 200
    struct.pack_into("<I", blob, 28, zlib.crc32(bytes(blob[32:])) & 0xFFFFFFFF)
    with pytest.raises(ContainerError, match="reg count"):
        loads(bytes(blob))


def test_kernel_crc_tamper_rejected_in_v2():
    # same tamper with a forged outer CRC on a v2 container: the per-kernel
    # content CRC is the line of defense that fires
    import struct
    import zlib

    k = tiny_kernel()
    blob = bytearray(dumps(k))
    text_off = 32 + container.KINFO_SIZES[container.VERSION]
    blob[text_off + instr_addr(0) + 4] = 200
    struct.pack_into("<I", blob, 28, zlib.crc32(bytes(blob[32:])) & 0xFFFFFFFF)
    with pytest.raises(ContainerError, match="content CRC"):
        loads(bytes(blob))


def test_v1_container_still_loads():
    """Backward compatibility: v1 single-kernel containers load unchanged."""
    k = tiny_kernel()
    k.shared_size = 512
    k.rda = 9
    v1 = dumps(k, version=1)
    v2 = dumps(k, version=2)
    assert len(v1) == len(v2) - 4  # v2 adds exactly the 4-byte per-kernel CRC
    back = loads(v1)
    assert back.render() == k.render()
    assert back.rda == 9 and back.shared_size == 512
    assert back.arch == "maxwell"  # pre-registry containers default to Maxwell
    assert kernel_names(v1) == ["tiny"]
    # and re-dumping the v1-decoded kernel produces a current (v3) container
    assert loads(dumps(back)).render() == k.render()


def test_v2_multi_kernel_roundtrip_with_crcs():
    """A v2 multi-kernel container round-trips; per-kernel CRCs are stable,
    layout-independent, and equal for identical content."""
    a, b = tiny_kernel("a"), paper_kernel("md")
    blob = dumps([a, b, tiny_kernel("a")])
    back = loads_many(blob)
    assert [k.name for k in back] == ["a", "md", "a"]
    for orig, dec in zip([a, b, a], back):
        assert dec.render() == orig.render()
    # same content -> same CRC; CRC independent of sibling kernels
    assert container.kernel_crc(back[0]) == container.kernel_crc(back[2])
    assert container.kernel_crc(back[0]) == container.kernel_crc(tiny_kernel("a"))
    assert container.kernel_crc(back[0]) != container.kernel_crc(tiny_kernel("c"))


def test_unsupported_version_rejected():
    import struct

    blob = bytearray(dumps(tiny_kernel()))
    struct.pack_into("<H", blob, 8, 99)  # version field follows the 8B magic
    with pytest.raises(ContainerError, match="version"):
        loads(bytes(blob))
    with pytest.raises(ContainerError, match="version"):
        dumps(tiny_kernel(), version=99)


def test_empty_container_rejected():
    with pytest.raises(ContainerError):
        dumps([])


def test_unknown_opcode_version_guard(monkeypatch):
    blob = dumps(tiny_kernel())
    monkeypatch.setattr(container, "opcode_checksum", lambda: 0xDEADBEEF)
    with pytest.raises(ContainerError, match="checksum"):
        loads(blob)


def test_dangling_branch_target_rejected():
    k = Kernel(name="bad")
    k.items = [Instr("BRA", target="nowhere"), Instr("EXIT")]
    with pytest.raises(EncodingError, match="dangling"):
        dumps(k)


def test_oversized_trip_count_rejected():
    k = Kernel(name="bad")
    k.items = [
        Label("L"),
        Instr("BRA", target="L", trip_count=1 << 20),
        Instr("EXIT"),
    ]
    with pytest.raises(EncodingError, match="trip count"):
        dumps(k)
