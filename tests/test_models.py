"""Per-architecture smoke tests + model-level invariants.

Every assigned architecture instantiates its REDUCED config and runs one
forward/loss (and a prefill+decode round) on CPU, asserting output shapes
and finiteness, per the assignment.
"""

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, param_count, reduced_config, shape_cells
from repro.models import Model, transformer
from repro.models.attention import attention_chunked, attention_xla


def _batch_for(cfg, B, S, key):
    k1, k2 = jax.random.split(key)
    batch = {
        "tokens": jax.random.randint(k1, (B, S), 1, cfg.vocab),
        "targets": jax.random.randint(k2, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(k1, (B, 8, cfg.d_model), jnp.bfloat16)
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32)[None, :, None], (B, S, 3)
        )
    if cfg.family == "audio":
        batch["frame_embeds"] = jax.random.normal(k1, (B, 16, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss; shapes + no NaNs (the deliverable)."""
    cfg = reduced_config(arch)
    model = Model(cfg, attn_impl="xla")
    params, axes = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 32, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    # init loss must be near ln(vocab) (healthy initialization)
    assert abs(float(loss) - math.log(cfg.vocab)) < 1.5
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))), arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_prefill_decode(arch):
    cfg = reduced_config(arch)
    model = Model(cfg, attn_impl="xla")
    params, _ = model.init(jax.random.PRNGKey(0))
    batch = _batch_for(cfg, 2, 8, jax.random.PRNGKey(1))
    batch.pop("targets")
    h, state = model.prefill(params, batch, max_len=16)
    if cfg.family == "audio":
        # enc-dec prefill returns the encoder output; decoding starts at BOS
        assert h.shape == (2, batch["frame_embeds"].shape[1], cfg.d_model)
    else:
        assert h.shape[:2] == (2, 8)
    tok = jnp.argmax(model.logits(params, h[:, -1:]), -1).astype(jnp.int32)
    h2, state2 = model.decode_step(params, tok, state)
    assert h2.shape == (2, 1, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h2.astype(jnp.float32))))
    assert int(state2["pos"][0]) == int(state["pos"][0]) + 1


@pytest.mark.parametrize("arch", ["stablelm_3b", "gemma3_1b", "mamba2_370m", "zamba2_2_7b"])
def test_decode_consistency_with_forward(arch):
    """KV-cache / SSM-state decode must match the full forward (fp32, with
    fp32 caches isolated from quantization by tolerance)."""
    cfg = dataclasses.replace(reduced_config(arch), dtype=jnp.float32)
    model = Model(cfg, attn_impl="xla")
    params, _ = model.init(jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 1, cfg.vocab)
    if cfg.family in ("dense", "moe", "vlm"):
        h_full, _ = transformer.forward(cfg, params, toks, attn_impl="xla")
    elif cfg.family == "ssm":
        h_full, _ = model._ssm_forward(params, toks)
    else:
        from repro.models import hybrid

        h_full, _ = hybrid.forward(cfg, params, toks, attn_impl="xla")
    _, state = model.prefill(params, {"tokens": toks[:, :S]}, max_len=S + 4)
    h_dec, _ = model.decode_step(params, toks[:, S : S + 1], state)
    err = float(jnp.abs(h_dec[:, 0] - h_full[:, S]).max())
    assert err < 5e-2, err  # bf16 cache quantization bound


def test_full_configs_match_assignment():
    """The full (non-reduced) configs carry the exact assigned dimensions."""
    spec = {
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "gemma3_1b": (26, 1152, 4, 1, 6912, 262144),
        "qwen2_7b": (28, 3584, 28, 4, 18944, 152064),
        "granite_8b": (36, 4096, 32, 8, 14336, 49152),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "llama4_scout_17b_a16e": (48, 5120, 40, 8, 8192, 202048),
        "qwen2_vl_2b": (28, 1536, 12, 2, 8960, 151936),
        "whisper_large_v3": (32, 1280, 20, 20, 5120, 51866),
        "mamba2_370m": (48, 1024, 0, 0, 0, 50280),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
    }
    for arch, (L, D, Hq, Hkv, F, V) in spec.items():
        cfg = get_config(arch)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff, cfg.vocab) == (
            L, D, Hq, Hkv, F, V,
        ), arch
    assert get_config("qwen2_moe_a2_7b").moe.n_experts == 60
    assert get_config("qwen2_moe_a2_7b").moe.top_k == 4
    assert get_config("llama4_scout_17b_a16e").moe.n_experts == 16
    assert get_config("llama4_scout_17b_a16e").moe.top_k == 1
    assert get_config("mamba2_370m").ssm_state == 128
    assert get_config("zamba2_2_7b").ssm_state == 64


def test_shape_cells_cover_assignment():
    total = skipped = 0
    for arch in ARCH_IDS:
        cells = shape_cells(arch)
        assert [c.name for c in cells] == ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
        total += len(cells)
        skipped += sum(c.skipped for c in cells)
        # long_500k runs exactly for the sub-quadratic archs
        long = cells[-1]
        if arch in ("gemma3_1b", "llama4_scout_17b_a16e", "mamba2_370m", "zamba2_2_7b"):
            assert not long.skipped, arch
        else:
            assert long.skipped, arch
    assert total == 40
    assert skipped == 6


def test_param_counts_plausible():
    """Full configs land near their nameplate sizes."""
    expects = {
        "qwen2_7b": (6.5e9, 8.5e9),
        "granite_8b": (7e9, 9e9),
        "mamba2_370m": (3e8, 5e8),
        "gemma3_1b": (0.8e9, 1.6e9),
        "llama4_scout_17b_a16e": (90e9, 130e9),  # total (not active) params
    }
    for arch, (lo, hi) in expects.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, (arch, n)


def test_attention_chunked_matches_xla():
    key = jax.random.PRNGKey(0)
    B, S, Hq, Hkv, Dh = 2, 96, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, Dh))
    k = jax.random.normal(ks[1], (B, S, Hkv, Dh))
    v = jax.random.normal(ks[2], (B, S, Hkv, Dh))
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    from repro.models.common import causal_mask_bias

    for window in (None, 17):
        want = attention_xla(q, k, v, bias=causal_mask_bias(pos, pos, window=window))
        got = attention_chunked(q, k, v, pos, pos, window=window, kv_chunk=32)
        np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_moe_capacity_matches_dense_when_no_drop():
    cfg = reduced_config("qwen2_moe_a2_7b")
    m = dataclasses.replace(cfg.moe, capacity_factor=8.0)
    model = Model(dataclasses.replace(cfg, moe=m, dtype=jnp.float32), attn_impl="xla")
    params, _ = model.init(jax.random.PRNGKey(0))
    lp = jax.tree.map(lambda w: w[0], params["layers"])
    x = jax.random.normal(jax.random.PRNGKey(3), (300, cfg.d_model))
    y_cap = transformer.moe_ffn(x, lp, m, dense_path_max_tokens=0)
    y_dense = transformer.moe_ffn(x, lp, m, dense_path_max_tokens=1024)
    np.testing.assert_allclose(y_cap, y_dense, atol=1e-5, rtol=1e-5)


def test_mrope_differs_from_rope_only_in_rotation():
    from repro.models.common import apply_mrope, apply_rope

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 16, 4, 32))
    pos = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32)[None], (2, 16))
    mpos = jnp.stack([pos, pos, pos], axis=-1)
    # with identical position streams, M-RoPE == RoPE at the same theta
    a = apply_rope(x, pos, theta=1e6)
    b = apply_mrope(x, mpos, theta=1e6)
    np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
