"""Property-based tests (hypothesis) for the system's invariants.

``REGDEM_PROPERTY_SCALE`` multiplies every example budget — the nightly CI
workflow sets it to sweep a much larger input space than the per-push run.
"""

import os

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.compaction import compact
from repro.core.isa import NUM_SMEM_BANKS, equivalent, smem_bank
from repro.core.kernelgen import generate, random_profile
from repro.core.occupancy import MAXWELL, occupancy
from repro.core.regdem import RegDemOptions, auto_targets, demote
from repro.core.sched import verify_schedule

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

_slow = settings(
    max_examples=15 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_generated_kernels_schedule_clean(seed):
    k = generate(random_profile(seed))
    assert verify_schedule(k) == []


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_demotion_invariants(seed):
    """For any kernel and any occupancy-cliff target:
    semantics preserved, schedule clean, register count reduced,
    shared-memory accounting exact."""
    k = generate(random_profile(seed))
    targets = auto_targets(k)
    if not targets:
        return
    res = demote(k, targets[0])
    assert equivalent(k, res.kernel)
    assert verify_schedule(res.kernel) == []
    assert res.kernel.reg_count <= k.reg_count
    assert res.kernel.demoted_size == res.demoted_words * k.threads_per_block * 4


@given(
    seed=st.integers(min_value=0, max_value=10_000),
    strategy=st.sampled_from(["static", "cfg", "conflict"]),
    flags=st.tuples(st.booleans(), st.booleans(), st.booleans(), st.booleans()),
)
@settings(max_examples=20 * SCALE, deadline=None, suppress_health_check=[HealthCheck.too_slow])
def test_demotion_options_never_break(seed, strategy, flags):
    k = generate(random_profile(seed % 30))
    targets = auto_targets(k)
    if not targets:
        return
    b, e, r, s = flags
    opt = RegDemOptions(
        candidate_strategy=strategy,
        bank_avoid=b,
        elim_redundant=e,
        reschedule=r,
        substitute=s,
    )
    res = demote(k, targets[-1], opt)
    assert equivalent(k, res.kernel)
    assert verify_schedule(res.kernel) == []


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_compaction_idempotent_and_tight(seed):
    k = generate(random_profile(seed))
    kk = k.copy()
    compact(kk)
    once = kk.reg_count
    compact(kk)
    assert kk.reg_count == once  # idempotent
    assert equivalent(k, kk)


@given(
    n_threads=st.sampled_from([32, 64, 128, 192, 256, 512, 1024]),
    static=st.integers(min_value=0, max_value=4096),
    r=st.integers(min_value=0, max_value=24),
)
@settings(max_examples=60 * SCALE, deadline=None)
def test_eq1_layout_bank_conflict_free(n_threads, static, r):
    """Paper eq. 1: for any (threads/block, static smem, demoted index), a
    warp's 32 lanes always touch 32 distinct banks."""
    s_up = (static + 3) // 4 * 4
    banks = [smem_bank(t * 4 + s_up + r * n_threads * 4) for t in range(32)]
    assert len(set(banks)) == NUM_SMEM_BANKS


@given(
    regs=st.integers(min_value=1, max_value=255),
    thr=st.sampled_from([32, 64, 128, 256, 512, 1024]),
    smem=st.integers(min_value=0, max_value=MAXWELL.smem_per_block),
)
@settings(max_examples=100 * SCALE, deadline=None)
def test_occupancy_bounds(regs, thr, smem):
    occ = occupancy(regs, thr, smem)
    assert 0.0 <= occ.occupancy <= 1.0
    assert occ.resident_threads <= MAXWELL.max_threads
    assert occ.resident_warps <= MAXWELL.max_warps
    assert occ.resident_blocks <= MAXWELL.max_blocks
