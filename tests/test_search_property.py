"""Property-based tests (hypothesis) for the autotuning search.

The pinned contract: same kernel + same config ⇒ byte-identical winning
kernel and identical report, across repeated runs AND across process-pool
sizes (1 vs N workers); and re-tuning already-tuned content is a pure
translation-cache hit that runs zero pipeline passes.

``REGDEM_PROPERTY_SCALE`` multiplies the example budget (the nightly CI
workflow sweeps a much larger input space than the per-push run).
"""

import os
from unittest import mock

import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.binary import dumps
from repro.core.isa import equivalent
from repro.core.kernelgen import generate, random_profile
from repro.core.search import SearchConfig, search
from repro.core.simcache import SimCache
from repro.core.translator import TranslationService

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))

#: small bounds keep each example to a handful of pipeline runs
_CFG = dict(max_targets=1, beam_width=3, top_k=2)

_slow = settings(
    max_examples=5 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_search_deterministic_across_runs_and_pool_sizes(seed):
    k = generate(random_profile(seed))
    serial = search(k, SearchConfig(workers=0, **_CFG), cache=SimCache())
    again = search(k, SearchConfig(workers=0, **_CFG), cache=SimCache())
    pooled = search(k, SearchConfig(workers=2, **_CFG), cache=SimCache())
    # byte-identical winning kernel ...
    assert dumps(serial.kernel) == dumps(again.kernel) == dumps(pooled.kernel)
    # ... and identical reports (wall time excluded by to_json's contract)
    assert serial.report.to_json() == again.report.to_json()
    assert serial.report.to_json() == pooled.report.to_json()
    # the winner is always a valid translation of the input
    assert equivalent(k, serial.kernel)


@given(seed=st.integers(min_value=0, max_value=10_000))
@_slow
def test_parallel_search_leaves_cache_as_warm_as_serial(seed):
    """Worker caches are merged on join: after the search, the parent cache
    must serve every confirmed variant without re-measuring."""
    k = generate(random_profile(seed))
    cache = SimCache()
    out = search(k, SearchConfig(workers=2, **_CFG), cache=cache)
    assert len(cache) > 0
    # the winner's simulation was measured in a pool worker, merged on join,
    # and is now served from the parent cache without re-simulating
    hit = cache.peek_simulate(out.kernel)
    assert hit is not None
    assert hit.total_cycles == out.report.cycles[out.report.chosen]
    # and the parallel run leaves the exact entry set a serial run leaves
    serial_cache = SimCache()
    search(k, SearchConfig(workers=0, **_CFG), cache=serial_cache)
    assert sorted(map(repr, serial_cache.export()["sims"])) == sorted(
        map(repr, cache.export()["sims"])
    )
    assert sorted(map(repr, serial_cache.export()["stalls"])) == sorted(
        map(repr, cache.export()["stalls"])
    )


@given(seed=st.integers(min_value=0, max_value=10_000), workers=st.sampled_from([0, 2]))
@_slow
def test_retune_is_pure_cache_hit(seed, workers):
    """Tuning a container twice: the second pass is all cache hits, runs
    zero pipeline passes, and emits byte-identical container bytes — even
    when the second call uses a different pool size (the pool size is not
    part of the cache key)."""
    from repro.core import passes as passes_mod

    blob = dumps([generate(random_profile(seed))])
    svc = TranslationService()
    cfg1 = SearchConfig(workers=workers, **_CFG)
    cfg2 = SearchConfig(workers=2 - workers, **_CFG)
    out1, batch1 = svc.tune(blob, cfg1)
    assert batch1.cached == [False]

    with mock.patch.object(
        passes_mod.PassPipeline,
        "run",
        side_effect=AssertionError("pipeline pass ran on the cached path"),
    ):
        out2, batch2 = svc.tune(blob, cfg2)
    assert batch2.cached == [True]
    assert out2 == out1
