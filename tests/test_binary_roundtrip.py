"""Round-trip and binary-pipeline tests over the kernelgen corpus.

Covers the PR's acceptance bar: for every Table-1 kernel,
``loads(translate(dumps(k)))`` is dataflow-equivalent with a clean schedule,
and the overlay printer renders the control columns of a demoted variant.
"""

import json

import pytest

from repro.binary import dumps, loads
from repro.binary.overlay import overlay, overlay_lines
from repro.binary.roundtrip import check_roundtrip
from repro.core.isa import equivalent
from repro.core.kernelgen import (
    PAPER_BENCHMARKS,
    generate,
    paper_kernel,
    random_profile,
)
from repro.core.regdem import RegDemOptions, auto_targets, demote
from repro.core.sched import (
    export_ctrl_words,
    import_ctrl_words,
    verify_ctrl_words,
    verify_schedule,
)
from repro.core.translator import translate, translate_binary

CORPUS = sorted(PAPER_BENCHMARKS)


@pytest.mark.parametrize("name", CORPUS)
def test_corpus_roundtrip(name):
    check_roundtrip(paper_kernel(name))


@pytest.mark.parametrize("seed", range(8))
def test_random_kernel_roundtrip(seed):
    check_roundtrip(generate(random_profile(seed)))


@pytest.mark.parametrize("name", CORPUS)
def test_demoted_variant_roundtrip(name):
    k = paper_kernel(name)
    targets = auto_targets(k)
    if not targets:
        pytest.skip("no occupancy cliff to target")
    res = demote(k, targets[0], RegDemOptions(bank_avoid=True, reschedule=True))
    check_roundtrip(res.kernel)


@pytest.mark.parametrize("name", CORPUS)
def test_translate_binary_to_binary(name):
    """Acceptance: loads(translate(dumps(k))) is equivalent + schedule-clean."""
    k = paper_kernel(name)
    out = translate(dumps(k), options=[RegDemOptions()])
    assert isinstance(out, bytes)
    chosen = loads(out)
    assert equivalent(k, chosen)
    assert verify_schedule(chosen) == []


def test_translate_binary_report_matches_kernel_path():
    k = paper_kernel("md5hash")
    out, report = translate_binary(dumps(k))
    rep2 = translate(k)
    assert report.chosen == rep2.chosen
    assert report.considered == rep2.considered
    chosen = loads(out)
    expect = k if report.chosen == "nvcc" else report.chosen_kernel
    assert chosen.render() == expect.render()


def test_sched_words_travel_through_container():
    k = paper_kernel("nn")
    words = export_ctrl_words(k)
    assert verify_ctrl_words(k, words) == []
    k2 = loads(dumps(k))
    assert export_ctrl_words(k2) == words
    stripped = k.copy()
    for ins in stripped.instructions():
        ins.ctrl.stall = 0
        ins.ctrl.wait = set()
        ins.ctrl.write_bar = ins.ctrl.read_bar = None
    import_ctrl_words(stripped, words)
    assert stripped.render() == k.render()


def test_overlay_renders_demoted_variant_columns():
    """Acceptance: stall/yield/barrier columns for a demoted variant."""
    k = paper_kernel("conv")
    res = demote(k, auto_targets(k)[0])
    text = overlay(res.kernel)
    assert "ctrl=[stall Y | WR RD wait]" in text.splitlines()[0]
    body = text.splitlines()[1:]
    assert any("WR" in ln and "|" in ln for ln in body)  # write barrier set
    assert any("RD" in ln for ln in body)  # read barrier set (demoted store)
    assert any(" LDS " in ln for ln in body)  # demoted loads are visible
    # every instruction line carries an address and the packed word comment
    ins_lines = [ln for ln in body if ln.startswith("/*")]
    assert len(ins_lines) == len(res.kernel.instructions())
    assert all(ln.rstrip().endswith("*/") for ln in ins_lines)


def test_overlay_wait_mask_rendering():
    k = paper_kernel("cfd")
    lines = overlay_lines(k)
    # cfd is load-heavy: some instruction must wait on a barrier (a '1' bit)
    assert any(" | " in ln and "1" in ln.rsplit("|", 1)[1] for ln in lines)


def test_bench_binary_json_schema(tmp_path):
    from benchmarks import binary_bench

    path = tmp_path / "BENCH_binary.json"
    rows = list(binary_bench.binary_rows(str(path)))
    assert any(r.startswith("binary_corpus,") for r in rows)
    data = json.loads(path.read_text())
    assert set(data) == {"kernels", "summary"}
    assert set(data["kernels"]) == set(CORPUS)
    for rec in data["kernels"].values():
        assert rec["container_bytes"] > 0
        assert rec["encode_ns_per_instr"] > 0
        assert rec["decode_ns_per_instr"] > 0
