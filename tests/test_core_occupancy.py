"""Occupancy-calculator tests against the paper's Table 1 anchor points."""

import pytest

from repro.core.kernelgen import PAPER_BENCHMARKS, all_paper_kernels
from repro.core.occupancy import MAXWELL, occupancy, occupancy_of, spill_targets


# (regs, threads/block, smem) -> theoretical occupancy on CC 5.2
TABLE1_POINTS = [
    ("cfd", 68, 192, 0, 0.375),
    ("cfd@56", 56, 192, 0, 0.5625),
    ("qtc", 55, 64, 512, 0.5625),
    ("md5hash", 33, 256, 0, 0.75),
    ("md5hash@32", 32, 256, 0, 1.0),
    ("gaussian", 43, 64, 0, 0.65625),
    ("conv", 35, 128, 0, 0.75),
]


@pytest.mark.parametrize("name,regs,thr,smem,expect", TABLE1_POINTS)
def test_table1_theoretical_occupancy(name, regs, thr, smem, expect):
    assert occupancy(regs, thr, smem).occupancy == pytest.approx(expect)


def test_occupancy_is_step_function():
    # paper §2: occupancy is a step function of register count
    prev = None
    distinct = set()
    for regs in range(32, 80):
        occ = occupancy(regs, 192, 0).occupancy
        if prev is not None:
            assert occ <= prev + 1e-9  # monotone non-increasing in regs
        prev = occ
        distinct.add(occ)
    assert 3 <= len(distinct) <= 12  # cliffs, not a smooth slope


def test_register_limited_benchmarks():
    # every Table-1 benchmark must be register-limited (the paper's premise)
    for name, k in all_paper_kernels().items():
        assert occupancy_of(k).limiter == "registers", name


def test_spill_targets_hit_paper_targets():
    for name, prof in PAPER_BENCHMARKS.items():
        k_regs = prof.target_regs
        cliffs = spill_targets(k_regs, prof.threads_per_block, prof.shared_size)
        assert prof.regdem_target in cliffs, (name, cliffs)


def test_spill_targets_respect_smem_budget():
    # with no shared memory left, no spill target may be offered
    assert spill_targets(64, 256, 0, available_smem=0) == []


def test_smem_limits_enforced():
    with pytest.raises(ValueError):
        occupancy(32, 256, MAXWELL.smem_per_block + 1)
    with pytest.raises(ValueError):
        occupancy(300, 256, 0)


def test_occupancy_counts_demoted_smem():
    # demoted registers consume shared memory: at some point the smem cost
    # cancels the register gain and the cliff list stops
    cliffs = spill_targets(80, 1024, 40 * 1024)
    for tgt in cliffs:
        spilled = 80 - tgt
        assert 40 * 1024 + spilled * 1024 * 4 <= MAXWELL.smem_per_block
