"""Telemetry-layer tests: span nesting and exception safety, the
disabled-mode no-op contract, the metrics registry (and its pool
export/merge), and both exporters (JSONL + Chrome trace)."""

import json

import pytest

from repro import obs
from repro.core.kernelgen import paper_kernel
from repro.core.regdem import RegDemOptions, demote
from repro.obs import (
    NULL_SPAN,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    Telemetry,
    chrome_trace,
    hit_rate,
    to_jsonl,
)


@pytest.fixture
def tel():
    """The process-wide telemetry, enabled and clean; prior state (other
    tests may have recorded spans) is restored afterwards."""
    t = obs.get_telemetry()
    was_enabled = t.enabled
    saved_events = t.export_events(0)
    saved_metrics = t.registry.export()
    t.reset()
    t.enable()
    yield t
    t.reset()
    t.adopt(saved_events)
    t.registry.merge(saved_metrics)
    t.enabled = was_enabled


# ---------------------------------------------------------------------------
# spans
# ---------------------------------------------------------------------------


def test_spans_nest_and_link_parents(tel):
    with obs.span("outer", depth=0) as outer:
        with obs.span("inner") as inner:
            with obs.span("leaf"):
                pass
    by_name = {e.name: e for e in tel.events}
    assert set(by_name) == {"outer", "inner", "leaf"}
    assert by_name["outer"].parent_id is None
    assert by_name["inner"].parent_id == outer.span_id
    assert by_name["leaf"].parent_id == inner.span_id
    # inner spans close (and record) before their parents
    assert [e.name for e in tel.events] == ["leaf", "inner", "outer"]
    assert by_name["outer"].attrs == {"depth": 0}
    assert all(e.dur >= 0 for e in tel.events)


def test_span_set_attaches_midflight_attrs(tel):
    with obs.span("work", kernel="nn") as sp:
        sp.set(outcome="cached", n=3)
    (rec,) = tel.events
    assert rec.attrs == {"kernel": "nn", "outcome": "cached", "n": 3}


def test_exception_closes_every_open_span(tel):
    with pytest.raises(ValueError):
        with obs.span("outer"):
            with obs.span("inner"):
                raise ValueError("boom")
    by_name = {e.name: e for e in tel.events}
    assert set(by_name) == {"outer", "inner"}
    assert by_name["inner"].attrs["error"] == "ValueError"
    assert by_name["outer"].attrs["error"] == "ValueError"
    # the thread-local stack is coherent again: a new span is a root
    with obs.span("after"):
        pass
    assert tel.events[-1].parent_id is None


def test_leaked_span_does_not_corrupt_the_stack(tel):
    """A span entered by hand and never exited is popped by its parent's
    exit, keeping the timeline coherent."""
    outer = obs.span("outer")
    outer.__enter__()
    leaked = obs.span("leaked")
    leaked.__enter__()  # never exited
    outer.__exit__(None, None, None)
    assert [e.name for e in tel.events] == ["outer"]
    with obs.span("next"):
        pass
    assert tel.events[-1].parent_id is None


def test_disabled_span_is_the_shared_noop(tel):
    obs.disable()
    s = obs.span("anything", k=1)
    assert s is NULL_SPAN
    with s as inner:
        inner.set(a=1)  # chainable no-op
    assert tel.event_count() == 0
    # the telemetry-object path takes the same shortcut
    assert tel.span("x") is NULL_SPAN


def test_disabled_mode_records_nothing_at_volume(tel):
    obs.disable()
    for _ in range(10_000):
        with obs.span("hot"):
            pass
    assert tel.event_count() == 0
    assert len(tel.registry) == 0


def test_reset_drops_events_but_not_the_switch(tel):
    with obs.span("x"):
        pass
    tel.registry.counter("c").inc()
    tel.reset()
    assert tel.event_count() == 0
    assert len(tel.registry) == 0
    assert tel.enabled


# ---------------------------------------------------------------------------
# metrics
# ---------------------------------------------------------------------------


def test_counter_monotonic():
    c = Counter()
    c.inc()
    c.inc(4)
    assert c.snapshot() == 5
    with pytest.raises(ValueError):
        c.inc(-1)


def test_gauge_last_write():
    g = Gauge()
    g.set(3.5)
    g.set(1.0)
    assert g.snapshot() == 1.0


def test_histogram_percentiles_exact():
    h = Histogram()
    for v in range(1, 101):
        h.observe(float(v))
    snap = h.snapshot()
    assert snap["count"] == 100
    assert snap["min"] == 1.0 and snap["max"] == 100.0
    assert snap["mean"] == pytest.approx(50.5)
    assert snap["p50"] == 51.0  # nearest-rank over 1..100
    assert snap["p99"] == 99.0


def test_histogram_ring_trims_samples_not_books():
    h = Histogram(max_samples=4)
    for v in [100.0, 1.0, 2.0, 3.0, 4.0]:  # 100.0 falls out of the ring
        h.observe(v)
    assert h.count == 5
    assert h.total == 110.0
    assert h.vmax == 100.0  # extrema are exact even after trimming
    assert h.percentile(50) == 3.0  # percentiles see only the resident ring


def test_registry_get_or_create_and_type_guard():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    reg.gauge("g").set(2)
    with pytest.raises(TypeError):
        reg.counter("g")
    snap = reg.snapshot()
    assert list(snap) == sorted(snap)
    assert snap["a"] == 0 and snap["g"] == 2


def test_registry_export_merge_roundtrip():
    worker = MetricsRegistry()
    worker.counter("hits").inc(3)
    worker.gauge("entries").set(7)
    h = worker.histogram("ms")
    h.max_samples = 2  # force ring trimming so merge must restore the books
    for v in [50.0, 1.0, 2.0]:
        h.observe(v)

    parent = MetricsRegistry()
    parent.counter("hits").inc(1)
    parent.merge(worker.export())
    assert parent.counter("hits").value == 4  # counters add
    assert parent.gauge("entries").value == 7  # gauges last-write
    merged = parent.histogram("ms")
    assert merged.count == 3 and merged.total == 53.0 and merged.vmax == 50.0


def test_hit_rate_convention():
    assert hit_rate(3, 1) == 0.75
    assert hit_rate(0, 5) == 0.0
    # zero traffic has no meaningful rate: explicit error unless the caller
    # (a display/stats path) opts into a default
    with pytest.raises(ValueError, match="no cache accesses"):
        hit_rate(0, 0)
    assert hit_rate(0, 0, default=0.0) == 0.0


# ---------------------------------------------------------------------------
# pool-worker span exchange
# ---------------------------------------------------------------------------


def test_export_since_mark_and_adopt():
    worker = Telemetry()
    worker.enable()
    with worker.span("inherited"):
        pass
    mark = worker.event_count()
    with worker.span("task"):
        pass
    exported = worker.export_events(mark)
    assert [e.name for e in exported] == ["task"]

    parent = Telemetry()
    parent.enable()
    with parent.span("local"):
        pass
    assert parent.adopt(exported) == 1
    assert [e.name for e in parent.events] == ["local", "task"]
    assert parent.snapshot()["spans"] == 2


# ---------------------------------------------------------------------------
# exporters
# ---------------------------------------------------------------------------


def _record_timeline(tel):
    with obs.span("root", kind="test"):
        with obs.span("child-a"):
            pass
        with obs.span("child-b"):
            pass
    tel.registry.counter("n").inc(2)


def test_chrome_trace_is_valid_and_monotonic(tel):
    _record_timeline(tel)
    trace = json.loads(json.dumps(chrome_trace(tel)))  # JSON-serializable
    events = trace["traceEvents"]
    assert len(events) == 3
    rows = {}
    for e in events:
        assert e["ph"] == "X"
        assert e["dur"] >= 0
        assert e["ts"] >= 0
        row = (e["pid"], e["tid"])
        assert e["ts"] >= rows.get(row, 0.0)  # monotonic within each row
        rows[row] = e["ts"]
    assert min(e["ts"] for e in events) == 0.0  # rebased to the earliest span
    assert {e["name"] for e in events} == {"root", "child-a", "child-b"}


def test_jsonl_lines_parse_and_end_with_metrics(tel):
    _record_timeline(tel)
    lines = to_jsonl(tel).splitlines()
    parsed = [json.loads(ln) for ln in lines]
    assert all(p["kind"] == "span" for p in parsed[:-1])
    assert parsed[-1]["kind"] == "metrics"
    assert parsed[-1]["metrics"]["n"] == 2
    span_names = {p["name"] for p in parsed[:-1]}
    assert span_names == {"root", "child-a", "child-b"}


def test_write_trace_dispatches_on_extension(tel, tmp_path):
    _record_timeline(tel)
    chrome = tmp_path / "t.json"
    jsonl = tmp_path / "t.jsonl"
    assert obs.write_trace(str(chrome)) == "chrome"
    assert obs.write_trace(str(jsonl)) == "jsonl"
    assert "traceEvents" in json.loads(chrome.read_text())
    assert all(json.loads(ln) for ln in jsonl.read_text().splitlines())


# ---------------------------------------------------------------------------
# instrumentation integration: the pipeline actually emits spans + metrics
# ---------------------------------------------------------------------------


def test_pipeline_emits_spans_and_metrics(tel):
    demote(paper_kernel("nn"), 32, options=RegDemOptions())
    names = [e.name for e in tel.events]
    assert "pipeline" in names
    assert any(n.startswith("pass:") for n in names)
    # every pass span is a child of the pipeline span
    by_id = {e.span_id: e for e in tel.events}
    pipe = next(e for e in tel.events if e.name == "pipeline")
    for e in tel.events:
        if e.name.startswith("pass:"):
            assert by_id[e.parent_id].span_id == pipe.span_id
    snap = tel.registry.snapshot()
    assert snap["pipeline.runs"] >= 1
    assert snap["pipeline.passes"] >= 1
    assert any(k.startswith("pass:") or k.startswith("pass.") for k in snap)
