"""TranslationDaemon: serving semantics, deadlines, retries, degradation,
restart durability."""

import pytest

from repro.binary import dumps, kernel_names, loads_many
from repro.core.artifacts import ArtifactStore
from repro.core.kernelgen import paper_kernel
from repro.core.passes import PIPELINE_COUNTERS
from repro.core.search import SearchConfig
from repro.core.translator import TranslationService
from repro.runtime import DaemonConfig, TranslationDaemon
from repro.testing import FaultPlan
from repro.testing import injected as faults_injected

SMALL_TUNE = SearchConfig(max_targets=1, beam_width=2, top_k=1)


def _blob(*names):
    ks = [paper_kernel(n) for n in names]
    return dumps(ks[0]) if len(ks) == 1 else dumps(ks)


def test_lifecycle_and_submit_guard():
    d = TranslationDaemon()
    with pytest.raises(RuntimeError, match="not running"):
        d.submit(b"x")
    with d:
        with pytest.raises(ValueError, match="unknown mode"):
            d.submit(b"x", mode="optimize")
    d.stop()  # idempotent


def test_translate_matches_service_bytes():
    data = _blob("md5hash", "conv")
    expected, _ = TranslationService().translate(data)
    with TranslationDaemon() as d:
        resp = d.request(data)
    assert resp.ok and not resp.degraded
    assert resp.payload == expected
    assert resp.attempts == 1
    assert resp.report.kernel_names == ["md5hash", "conv"]


def test_tune_matches_service_bytes():
    data = _blob("md5hash")
    expected, _ = TranslationService().tune(data, SMALL_TUNE)
    with TranslationDaemon() as d:
        resp = d.request(data, mode="tune", config=SMALL_TUNE)
    assert resp.ok
    assert resp.payload == expected


def test_concurrent_submissions_all_complete():
    blobs = [_blob(n) for n in ("md5hash", "conv", "nn")]
    with TranslationDaemon(config=DaemonConfig(max_batch=3)) as d:
        handles = [d.submit(b) for b in blobs * 2]
        responses = [h.result(timeout=60) for h in handles]
    assert all(r.ok for r in responses)
    for blob, resp in zip(blobs * 2, responses):
        assert kernel_names(resp.payload) == kernel_names(blob)
    snap = d.metrics_snapshot()
    assert snap["requests"] == 6 and snap["ok"] == 6


def test_invalid_input_is_clean_error():
    with TranslationDaemon() as d:
        resp = d.request(b"not a container")
    assert resp.status == "error"
    assert resp.payload is None
    assert "invalid input container" in resp.reason
    assert d.metrics_snapshot()["errors"] == 1


def test_deadline_degrades_to_baseline_bytes():
    data = _blob("md5hash")
    with TranslationDaemon(config=DaemonConfig(deadline_s=0.0)) as d:
        resp = d.request(data, mode="tune")
    assert resp.degraded
    assert "deadline" in resp.reason
    # degraded payload is the verified do-nothing emission of the input
    from repro.binary.roundtrip import verified_dumps_many

    assert resp.payload == verified_dumps_many(loads_many(data))
    assert d.metrics_snapshot()["deadline_timeouts"] >= 1


def test_per_request_deadline_override():
    data = _blob("md5hash")
    with TranslationDaemon(config=DaemonConfig(deadline_s=60.0)) as d:
        resp = d.request(data, mode="tune", deadline_s=0.0)
    assert resp.degraded


def test_transient_fault_retry_then_success():
    """One injected failure on attempt 0; the retry serves the fault-free
    bytes — retries are invisible to the caller except in the count."""
    data = _blob("md5hash")
    expected, _ = TranslationService().translate(data)
    plan = FaultPlan(schedule={("daemon.error", "1"): 1})
    with faults_injected(plan):
        with TranslationDaemon(config=DaemonConfig(backoff_s=0.001)) as d:
            resp = d.request(data)
    assert resp.ok
    assert resp.payload == expected
    assert resp.attempts == 2
    assert d.metrics_snapshot()["retries"] == 1


def test_exhausted_retries_degrade():
    data = _blob("md5hash")
    plan = FaultPlan(error_p=1.0)  # every attempt fails
    with faults_injected(plan):
        cfg = DaemonConfig(max_retries=2, backoff_s=0.001)
        with TranslationDaemon(config=cfg) as d:
            resp = d.request(data)
    assert resp.degraded
    assert "after 3 attempt" in resp.reason
    from repro.binary.roundtrip import verified_dumps_many

    assert resp.payload == verified_dumps_many(loads_many(data))
    snap = d.metrics_snapshot()
    assert snap["retries"] == 3 and snap["degraded"] == 1
    assert snap["degradation_rate"] == 1.0


def test_latency_injection_bounded_by_deadline():
    """A hung translation cannot hold a response past its deadline."""
    import time

    data = _blob("md5hash")
    plan = FaultPlan(latency_p=1.0, latency_s=30.0)
    with faults_injected(plan):
        cfg = DaemonConfig(deadline_s=0.3)
        with TranslationDaemon(config=cfg) as d:
            t0 = time.monotonic()
            resp = d.request(data)
            elapsed = time.monotonic() - t0
    assert resp.degraded
    assert elapsed < 5.0  # far below the injected 30s hang


def test_warm_restart_serves_tuned_kernel_with_zero_passes(tmp_path):
    """The ISSUE acceptance bar: daemon restart, same store dir — repeat
    content is served byte-identically from disk without running a single
    pipeline pass, and counted as a disk cache hit."""
    data = _blob("md5hash")
    with TranslationDaemon(store=ArtifactStore(str(tmp_path))) as d:
        first = d.request(data, mode="tune", config=SMALL_TUNE)
    assert first.ok

    svc = TranslationService(store=ArtifactStore(str(tmp_path)))
    with TranslationDaemon(service=svc) as d2:
        before = dict(PIPELINE_COUNTERS)
        again = d2.request(data, mode="tune", config=SMALL_TUNE)
        after = dict(PIPELINE_COUNTERS)
    assert again.ok
    assert again.payload == first.payload
    assert after["passes"] == before["passes"]
    assert after["pipelines"] == before["pipelines"]
    snap = d2.metrics_snapshot()
    assert snap["service"]["cache"]["disk_hits"] == 1
    assert snap["service"]["cache"]["disk_hit_rate"] > 0
    assert snap["service"]["store"]["hits"] >= 1


def test_rejects_service_and_store():
    with pytest.raises(ValueError):
        TranslationDaemon(service=TranslationService(), store=object())


def test_metrics_snapshot_shape():
    with TranslationDaemon() as d:
        d.request(_blob("md5hash"))
        snap = d.metrics_snapshot()
    assert snap["running"] is True
    assert snap["completed"] == 1 and snap["inflight"] == 0
    assert snap["serve_ms"]["count"] == 1
    for key in ("requests", "ok", "degraded", "errors", "retries",
                "deadline_timeouts", "late_results", "degradation_rate"):
        assert key in snap
    assert "cache" in snap["service"]
