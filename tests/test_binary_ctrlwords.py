"""Golden-bytes tests for the 21-bit Maxwell control-word packing."""

import pytest

from repro.binary.ctrlwords import (
    BUNDLE_GROUP,
    CTRL_BITS,
    NOP_CTRL,
    CtrlWordError,
    pack_bundle,
    pack_ctrl,
    pack_stream,
    unpack_bundle,
    unpack_ctrl,
    unpack_stream,
)
from repro.core.isa import NUM_BARRIERS, Ctrl

# field shifts pinned by the format doc (SASSOverlay [5,3,3,6,3,1] layout)
YIELD_BIT = 1 << 4
WBAR_SHIFT, RBAR_SHIFT, WAIT_SHIFT = 5, 8, 11


def test_golden_default_ctrl():
    # stall=1, no yield (bit set), no barriers (7/7), empty wait mask
    assert pack_ctrl(Ctrl()) == 0x0007F1


def test_golden_branch_ctrl():
    # the scheduler's branch control: stall=5, nothing else
    assert pack_ctrl(Ctrl(stall=5)) == 0x0007F5


def test_golden_max_stall_all_barriers():
    ctrl = Ctrl(
        stall=15,
        yield_flag=True,
        write_bar=0,
        read_bar=5,
        wait=set(range(NUM_BARRIERS)),
    )
    expected = 15 | (0 << WBAR_SHIFT) | (5 << RBAR_SHIFT) | (0x3F << WAIT_SHIFT)
    assert pack_ctrl(ctrl) == expected == 0x01FD0F
    assert expected < (1 << CTRL_BITS)


def test_golden_yield_inversion():
    # yield ON means the hardware bit is CLEAR
    assert pack_ctrl(Ctrl(stall=0, yield_flag=True)) & YIELD_BIT == 0
    assert pack_ctrl(Ctrl(stall=0, yield_flag=False)) & YIELD_BIT == YIELD_BIT


@pytest.mark.parametrize(
    "ctrl",
    [
        Ctrl(),
        Ctrl(stall=15, yield_flag=True, write_bar=0, read_bar=5, wait=set(range(6))),
        Ctrl(stall=0, write_bar=3),
        Ctrl(stall=7, read_bar=0, wait={0, 2, 4}),
        Ctrl(stall=4, yield_flag=True, wait={5}),
    ],
)
def test_pack_unpack_identity(ctrl):
    back = unpack_ctrl(pack_ctrl(ctrl))
    assert (back.stall, back.yield_flag, back.write_bar, back.read_bar, back.wait) == (
        ctrl.stall,
        ctrl.yield_flag,
        ctrl.write_bar,
        ctrl.read_bar,
        ctrl.wait,
    )


def test_exhaustive_barrier_field_roundtrip():
    for wb in [None, 0, 1, 5]:
        for rb in [None, 0, 5]:
            for stall in (0, 1, 15):
                c = Ctrl(stall=stall, write_bar=wb, read_bar=rb)
                b = unpack_ctrl(pack_ctrl(c))
                assert (b.write_bar, b.read_bar, b.stall) == (wb, rb, stall)


def test_bundle_golden_layout():
    w = [pack_ctrl(Ctrl()), pack_ctrl(Ctrl(stall=5)), pack_ctrl(Ctrl(stall=2))]
    bundle = pack_bundle(w)
    assert bundle == w[0] | (w[1] << CTRL_BITS) | (w[2] << 2 * CTRL_BITS)
    assert bundle < (1 << 64)
    assert unpack_bundle(bundle) == w


def test_bundle_pads_with_nop():
    w = [pack_ctrl(Ctrl())]
    bundle = pack_bundle(w)
    assert unpack_bundle(bundle) == [w[0], NOP_CTRL, NOP_CTRL]
    nop = unpack_ctrl(NOP_CTRL)
    assert nop.stall == 0 and not nop.yield_flag
    assert nop.write_bar is None and nop.read_bar is None and nop.wait == set()


def test_stream_roundtrip_non_multiple_of_three():
    ctrls = [Ctrl(stall=i % 16, wait={i % 6}) for i in range(7)]
    bundles = pack_stream(ctrls)
    assert len(bundles) == (7 + BUNDLE_GROUP - 1) // BUNDLE_GROUP
    back = unpack_stream(bundles, 7)
    assert [c.stall for c in back] == [c.stall for c in ctrls]
    assert [c.wait for c in back] == [c.wait for c in ctrls]


@pytest.mark.parametrize(
    "bad",
    [
        Ctrl(stall=16),
        Ctrl(stall=-1),
        Ctrl(write_bar=6),
        Ctrl(read_bar=-1),
        Ctrl(wait={6}),
    ],
)
def test_unrepresentable_ctrl_raises(bad):
    with pytest.raises(CtrlWordError):
        pack_ctrl(bad)


def test_bundle_errors():
    with pytest.raises(CtrlWordError):
        pack_bundle([0, 0, 0, 0])
    with pytest.raises(CtrlWordError):
        pack_bundle([1 << CTRL_BITS])
    with pytest.raises(CtrlWordError):
        unpack_stream([], 1)
