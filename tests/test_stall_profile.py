"""Stall-attribution tests.

The contract under test: ``simulate(k, profile=True)`` charges **every**
idle issue-slot cycle to exactly one (static instruction, reason) bucket —
so the profile's books balance exactly against ``SimResult.issue_stalls``
on all nine paper benchmarks x every registered architecture — and the
profiled run is cycle-identical to the unprofiled one (attribution is an
observer, never a perturbation).  One kernel's full profile is pinned
against ``tests/golden/stall_profile.json``.
"""

import json
import os

import pytest

from repro.arch import retarget
from repro.arch.registry import arch_names
from repro.binary import overlay
from repro.core.kernelgen import PAPER_BENCHMARKS, paper_kernel
from repro.core.search import SearchConfig, search
from repro.core.simcache import SimCache
from repro.core.simulator import simulate
from repro.obs import REASONS, build_profile

GOLDEN = os.path.join(os.path.dirname(__file__), "golden", "stall_profile.json")

ARCHES = sorted(arch_names())
BENCHMARKS = sorted(PAPER_BENCHMARKS)


def _profiled(name: str, arch: str):
    k = retarget(paper_kernel(name), arch)
    return k, simulate(k, profile=True)


# ---------------------------------------------------------------------------
# exactness: the books balance on every benchmark x arch cell
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCHES)
@pytest.mark.parametrize("name", BENCHMARKS)
def test_attribution_balances_exactly(name, arch):
    k, res = _profiled(name, arch)
    p = res.stall_profile
    assert p is not None
    assert p.kernel_name == k.name and p.arch == arch
    # the three levels of the ledger agree to the cycle
    assert p.total == res.issue_stalls
    assert sum(p.per_reason.values()) == p.total
    assert sum(e.total for e in p.instructions) == p.total
    for e in p.instructions:
        assert e.total == sum(e.reasons.values())
        assert set(e.reasons) <= set(REASONS)
        assert e.total > 0  # only nonzero entries are kept
    # entries are in static program order with valid indices
    indices = [e.index for e in p.instructions]
    assert indices == sorted(indices)
    n_instrs = sum(1 for it in k.items if hasattr(it, "ctrl"))
    assert all(0 <= i < n_instrs for i in indices)


@pytest.mark.parametrize("arch", ARCHES)
@pytest.mark.parametrize("name", BENCHMARKS)
def test_profiling_is_a_pure_observer(name, arch):
    """Attribution must never perturb the simulation it measures."""
    k = retarget(paper_kernel(name), arch)
    plain = simulate(k)
    profiled = simulate(k, profile=True)
    assert profiled.total_cycles == plain.total_cycles
    assert profiled.cycles_per_wave == plain.cycles_per_wave
    assert profiled.issue_stalls == plain.issue_stalls
    assert plain.stall_profile is None


def test_golden_pinned_profile():
    """The full md5hash/maxwell attribution, pinned cycle-for-cycle."""
    _, res = _profiled("md5hash", "maxwell")
    with open(GOLDEN) as fh:
        golden = json.load(fh)
    assert res.stall_profile.to_json() == golden


# ---------------------------------------------------------------------------
# build_profile refuses books that don't balance
# ---------------------------------------------------------------------------


def test_unbalanced_blame_raises():
    k = paper_kernel("md5hash")
    uid = next(it.uid for it in k.items if hasattr(it, "ctrl"))
    with pytest.raises(AssertionError, match="does not balance"):
        build_profile(k, {(uid, "issue_stall"): 3}, total=4)


def test_unknown_instruction_blame_raises():
    k = paper_kernel("md5hash")
    with pytest.raises(AssertionError, match="not in the kernel"):
        build_profile(k, {(-12345, "issue_stall"): 3}, total=3)


# ---------------------------------------------------------------------------
# renderings: hot list, text table, overlay column
# ---------------------------------------------------------------------------


def test_hot_and_render():
    _, res = _profiled("md5hash", "maxwell")
    p = res.stall_profile
    hot = p.hot(3)
    assert len(hot) == 3
    assert hot[0].total == max(e.total for e in p.instructions)
    assert [e.total for e in hot] == sorted((e.total for e in hot), reverse=True)
    text = p.render(top=3)
    assert f"{p.total} stall cycles" in text
    for reason, cycles in p.per_reason.items():
        if cycles:
            assert reason in text


def test_overlay_profile_column():
    k = paper_kernel("md5hash")
    p = simulate(k, profile=True).stall_profile
    plain = overlay(k).splitlines()
    profiled = overlay(k, profile=p).splitlines()
    assert any("stall profile:" in ln for ln in profiled)
    assert not any("stall profile:" in ln for ln in plain)
    # exactly the blamed instructions gain the cycles/share/reason suffix
    annotated = [ln for ln in profiled if " |" in ln and "%" in ln]
    assert len(annotated) == len(p.instructions)
    top = p.hot(1)[0]
    assert any(top.top_reason in ln for ln in annotated)


# ---------------------------------------------------------------------------
# SimCache.profile: profiled results are cached like plain simulations
# ---------------------------------------------------------------------------


def test_simcache_profile_hits_and_stats():
    cache = SimCache()
    k = paper_kernel("nn")
    first = cache.profile(k)
    misses = cache.misses
    second = cache.profile(k)
    assert cache.misses == misses  # pure hit
    assert second.to_json() == first.to_json()
    assert cache.stats()["profile_entries"] >= 1
    # the plain-simulation table was warmed too, without a profile attached
    plain = cache.simulate(k)
    assert cache.misses == misses
    assert plain.stall_profile is None
    assert plain.issue_stalls == first.total


# ---------------------------------------------------------------------------
# search integration: SearchConfig(profile=True)
# ---------------------------------------------------------------------------


def test_search_reports_stall_profiles():
    cfg = SearchConfig(profile=True, archs=("maxwell",), beam_width=2, top_k=2)
    report = search(paper_kernel("md5hash"), cfg).report
    assert report.stall_profiles
    assert report.chosen in report.stall_profiles
    for label, prof in report.stall_profiles.items():
        assert prof.total == sum(e.total for e in prof.instructions)
    payload = report.to_json()
    assert set(payload["stall_profiles"]) == set(report.stall_profiles)
    # profile participates in the cache signature: a profiled and an
    # unprofiled search are distinct translation-cache entries
    assert cfg.signature() != SearchConfig(
        profile=False, archs=("maxwell",), beam_width=2, top_k=2
    ).signature()


def test_unprofiled_search_has_no_profiles():
    cfg = SearchConfig(archs=("maxwell",), beam_width=2, top_k=2)
    report = search(paper_kernel("md5hash"), cfg).report
    assert report.stall_profiles == {}
    assert report.to_json()["stall_profiles"] == {}
