"""Corrupt / truncated container bytes must raise a clean ``ContainerError``.

Regression suite for the loader's failure taxonomy across every supported
format version: whatever bytes arrive — truncated, bit-flipped, garbled
section tables, stale per-kernel CRCs — ``loads``/``loads_many`` either
return verified kernels or raise :class:`ContainerError` with a diagnosable
message.  Never a raw ``struct.error``/``IndexError`` traceback from deep
inside the codec, and never silently wrong kernels.
"""

import zlib

import pytest

from repro.binary import container
from repro.binary.container import ContainerError, dumps, loads
from repro.core.kernelgen import paper_kernel

VERSIONS = container.SUPPORTED_VERSIONS


def _blob(version):
    return dumps(paper_kernel("md5hash"), version=version)


def _refix_outer_crc(data: bytes) -> bytes:
    """Recompute the envelope checksum after deliberate inner corruption, so
    the test reaches the *inner* validation layers (section table, kinfo,
    per-kernel CRC, text decode) instead of stopping at the envelope."""
    fields = list(container._HDR.unpack(data[: container._HDR.size]))
    fields[-1] = zlib.crc32(data[32:]) & 0xFFFFFFFF
    return (
        container._HDR.pack(*fields)
        + b"\x00" * container._HDR_PAD
        + data[32:]
    )


def _section_span(data: bytes, kind) -> tuple:
    """(offset, size) of the first section of ``kind`` straight from the
    on-disk section table."""
    (_, _, n_sections, shoff, *_rest) = container._HDR.unpack(
        data[: container._HDR.size]
    )
    for i in range(n_sections):
        _, k, off, size = container._SEC.unpack_from(
            data, shoff + i * container._SEC.size
        )
        if k == kind and size:
            return off, size
    raise AssertionError(f"no section of kind {kind}")


@pytest.mark.parametrize("version", VERSIONS)
def test_truncated_header(version):
    data = _blob(version)
    for n in (0, 1, 16, 31):
        with pytest.raises(ContainerError):
            loads(data[:n])


@pytest.mark.parametrize("version", VERSIONS)
def test_truncated_body(version):
    data = _blob(version)
    with pytest.raises(ContainerError, match="size mismatch"):
        loads(data[:-7])


@pytest.mark.parametrize("version", VERSIONS)
def test_bad_magic(version):
    data = _blob(version)
    with pytest.raises(ContainerError, match="magic"):
        loads(b"XXXXXXXX" + data[8:])


@pytest.mark.parametrize("version", VERSIONS)
def test_envelope_checksum_catches_any_flip(version):
    """Without re-fixing the outer CRC, any body corruption is caught at
    the envelope."""
    data = _blob(version)
    for pos in (40, len(data) // 2, len(data) - 3):
        raw = bytearray(data)
        raw[pos] ^= 0x10
        with pytest.raises(ContainerError):
            loads(bytes(raw))


@pytest.mark.parametrize("version", VERSIONS)
def test_bad_section_table(version):
    """A garbled section table (checksum-consistent) is a clean error."""
    data = _blob(version)
    (_, _, n_sections, shoff, *_rest) = container._HDR.unpack(
        data[: container._HDR.size]
    )
    raw = bytearray(data)
    # point the second section's offset out of bounds
    row = shoff + container._SEC.size
    _, kind, _, size = container._SEC.unpack_from(raw, row)
    container._SEC.pack_into(raw, row, 0xFFFFFF, kind, 0xFFFFFFF0, size)
    with pytest.raises(ContainerError):
        loads(_refix_outer_crc(bytes(raw)))


@pytest.mark.parametrize("version", (2, 3))
def test_stale_kernel_crc(version):
    """v2+: a text-section flip behind a re-fixed envelope still fails the
    per-kernel content CRC — corruption is attributed to the kernel."""
    data = _blob(version)
    off, size = _section_span(data, container.SEC_TEXT)
    raw = bytearray(data)
    raw[off + size // 2] ^= 0x01
    with pytest.raises(ContainerError, match="content CRC mismatch"):
        loads(_refix_outer_crc(bytes(raw)))


def test_v1_corrupt_strtab_is_clean_error():
    """v1 has no per-kernel CRC; corruption that defeats the envelope must
    still surface as ContainerError, not a codec traceback."""
    data = _blob(1)
    off, size = _section_span(data, container.SEC_STRTAB)
    raw = bytearray(data)
    raw[off : off + size] = b"\xff" * size  # invalid UTF-8 everywhere
    with pytest.raises(ContainerError):
        loads(_refix_outer_crc(bytes(raw)))


@pytest.mark.parametrize("version", VERSIONS)
def test_corrupt_kinfo_is_clean_error(version):
    data = _blob(version)
    off, size = _section_span(data, container.SEC_KINFO)
    raw = bytearray(data)
    raw[off : off + size] = bytes((b ^ 0xA5) for b in raw[off : off + size])
    with pytest.raises(ContainerError):
        loads(_refix_outer_crc(bytes(raw)))


@pytest.mark.parametrize("version", (2, 3))
def test_random_flips_never_return_wrong_kernels(version):
    """Sweep single-bit flips across the whole container (with the envelope
    re-fixed, so inner layers do the work): every outcome is either a clean
    ContainerError or a kernel identical to the original — never silently
    different code.  This is the per-kernel CRC's guarantee, so it holds
    for v2+ only (v1 predates it — see the test below)."""
    data = _blob(version)
    original = loads(data).render()
    step = max(1, len(data) // 64)
    for pos in range(32, len(data), step):
        raw = bytearray(data)
        raw[pos] ^= 0x04
        try:
            k = loads(_refix_outer_crc(bytes(raw)))
        except ContainerError:
            continue
        # flips in dead padding / unread bytes may decode; they must decode
        # to the same kernel
        assert k.render() == original


def test_v1_random_flips_fail_cleanly_or_decode():
    """v1 cannot detect every checksum-consistent flip (no per-kernel CRC —
    the reason v2 grew one), but it must never leak a raw codec traceback:
    each flip either decodes or raises ContainerError."""
    data = _blob(1)
    step = max(1, len(data) // 64)
    for pos in range(32, len(data), step):
        raw = bytearray(data)
        raw[pos] ^= 0x04
        try:
            loads(_refix_outer_crc(bytes(raw)))
        except ContainerError:
            continue
