"""Container back-compat coverage: v1 and v2 byte streams load under the v3
reader, default to the Maxwell arch tag, and re-serialize as valid v3 —
plus the cross-arch container round-trip fuzz the nightly workflow runs
with a larger example budget (``REGDEM_PROPERTY_SCALE``)."""

import os
import struct

import pytest

from repro.binary import container
from repro.binary.container import dumps, loads, loads_many
from repro.core.isa import Instr, Kernel, Label
from repro.core.kernelgen import paper_kernel
from repro.core.sched import schedule


def tiny_kernel(name="tiny") -> Kernel:
    k = Kernel(name=name, live_in={1}, live_out={7}, threads_per_block=64, num_blocks=8)
    k.items = [
        Instr("MOV32I", dsts=[4], imm=2.5),
        Instr("LDG", dsts=[5], srcs=[1], offset=0x40),
        Label("L0"),
        Instr("FADD", dsts=[7], srcs=[4, 5], pred=1, pred_neg=True),
        Instr("BRA", target="L0", pred=1, trip_count=3),
        Instr("EXIT"),
    ]
    return schedule(k)


def _header_version(blob: bytes) -> int:
    return struct.unpack_from("<H", blob, 8)[0]  # version follows the 8B magic


@pytest.mark.parametrize("version", [1, 2])
def test_legacy_versions_load_under_v3_reader(version):
    k = tiny_kernel()
    k.shared_size = 256
    legacy = dumps(k, version=version)
    assert _header_version(legacy) == version

    back = loads(legacy)
    # pre-registry containers default to the Maxwell arch tag
    assert back.arch == "maxwell"
    assert back.render() == k.render()
    assert back.shared_size == 256

    # ... and re-serialize as a valid, loadable v3 container
    upgraded = dumps(back)
    assert _header_version(upgraded) == 3
    assert container.VERSION == 3
    again = loads(upgraded)
    assert again.arch == "maxwell"
    assert again.render() == k.render()
    # the v3 re-serialization is stable
    assert dumps(again) == upgraded


@pytest.mark.parametrize("version", [1, 2])
def test_legacy_multi_kernel_upgrade(version):
    ks = [tiny_kernel("a"), tiny_kernel("b"), tiny_kernel("a")]
    legacy = dumps(ks, version=version)
    back = loads_many(legacy)
    assert [k.arch for k in back] == ["maxwell"] * 3
    upgraded = dumps(back)
    assert _header_version(upgraded) == 3
    assert [k.render() for k in loads_many(upgraded)] == [k.render() for k in ks]


def test_v2_and_v3_store_identical_maxwell_crcs():
    """The per-kernel content CRC of a Maxwell kernel is version-invariant,
    so translation-cache keys survive the v3 upgrade."""
    k = tiny_kernel()
    v2 = loads(dumps(k, version=2))
    v3 = loads(dumps(k, version=3))
    assert v2.content_crc == v3.content_crc == container.kernel_crc(k)


def test_v3_kinfo_grows_by_arch_field():
    sizes = container.KINFO_SIZES
    assert sizes[2] == sizes[1] + 4  # content CRC
    assert sizes[3] == sizes[2] + 4  # arch strtab offset
    assert container.KINFO_SIZE == sizes[3]


def test_v3_unknown_arch_name_rejected():
    """A v3 container naming an unregistered arch fails loudly (with a
    forged CRC so the arch check itself is what fires)."""
    k = tiny_kernel()
    blob = bytearray(dumps(k))
    # grow a fake strtab entry is intrusive; instead point the arch offset
    # at the kernel-name string ("tiny"), which is not a registered arch.
    # kinfo is the first section after the 32-byte header; the arch offset
    # is the last 4 bytes of the single kinfo record.
    arch_off_pos = 32 + container.KINFO_SIZE - 4
    name_off = struct.unpack_from("<I", blob, 32)[0]  # kinfo field 0
    struct.pack_into("<I", blob, arch_off_pos, name_off)
    import zlib

    struct.pack_into("<I", blob, 28, zlib.crc32(bytes(blob[32:])) & 0xFFFFFFFF)
    with pytest.raises(container.ContainerError, match="unknown architecture"):
        loads(bytes(blob))


# ---------------------------------------------------------------------------
# cross-arch round-trip fuzz (nightly runs this with a larger budget)
# ---------------------------------------------------------------------------

pytest.importorskip("hypothesis", reason="fuzz tests need hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st  # noqa: E402

from repro.arch import arch_names, retarget  # noqa: E402
from repro.core.kernelgen import generate, random_profile  # noqa: E402

SCALE = max(1, int(os.environ.get("REGDEM_PROPERTY_SCALE", "1")))


@given(
    seed=st.integers(min_value=0, max_value=10_000),
    arch=st.sampled_from(sorted(arch_names())),
)
@settings(
    max_examples=10 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
def test_fuzz_cross_arch_container_roundtrip(seed, arch):
    """encode -> decode -> re-encode is byte-identity on every arch, and the
    decoded kernel re-renders identically (the round-trip oracle, fuzzed
    across both architectures)."""
    k = generate(random_profile(seed % 200))
    if arch != "maxwell":
        k = retarget(k, arch)
    blob = dumps(k)
    back = loads(blob)
    assert back.arch == arch
    assert back.render() == k.render()
    assert dumps(back) == blob


@given(seed=st.integers(min_value=0, max_value=10_000))
@settings(
    max_examples=5 * SCALE,
    deadline=None,
    suppress_health_check=[HealthCheck.too_slow],
)
def test_fuzz_mixed_arch_batch_roundtrip(seed):
    """Multi-kernel containers mixing arches round-trip byte-stably."""
    base = generate(random_profile(seed % 200))
    batch = [base] + [retarget(base, a) for a in sorted(arch_names()) if a != "maxwell"]
    blob = dumps(batch)
    back = loads_many(blob)
    assert [k.arch for k in back] == [k.arch for k in batch]
    assert dumps(back) == blob


def test_demoted_paper_kernel_upgrade_path():
    """A realistic v2 artifact (demoted kernel with spill tags) upgrades to
    v3 with content intact."""
    from repro.core.regdem import auto_targets, demote

    k = paper_kernel("conv")
    res = demote(k, auto_targets(k)[0])
    legacy = dumps(res.kernel, version=2)
    back = loads(legacy)
    assert back.arch == "maxwell"
    upgraded = dumps(back)
    assert _header_version(upgraded) == 3
    assert loads(upgraded).render() == res.kernel.render()
