"""Unified model API over all assigned architecture families.

``Model`` dispatches on ``ModelConfig.family``:

* ``dense`` / ``moe`` / ``vlm``  -> :mod:`repro.models.transformer`
* ``ssm``                        -> pure Mamba2 stack (transformer-free)
* ``hybrid``                     -> :mod:`repro.models.hybrid` (Zamba2)
* ``audio``                      -> :mod:`repro.models.encdec` (Whisper)

Every family exposes the same four entry points used by the trainer, the
server and the dry-run:

    init(rng)                          -> (params, logical_axes)
    train_loss(params, batch)          -> scalar loss
    prefill(params, batch)             -> (hidden, cache_state)
    decode_step(params, batch, state)  -> (hidden, new_state)
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import encdec, hybrid, mamba2, transformer
from .common import scan as common_scan
from .transformer import BIG, ModelConfig, MoEConfig

Pytree = Any

__all__ = ["Model", "ModelConfig", "MoEConfig", "BIG"]


class Model:
    def __init__(self, cfg: ModelConfig, attn_impl: str = "chunked", remat: str = "none"):
        self.cfg = cfg
        self.attn_impl = attn_impl
        self.remat = remat

    # -- init -----------------------------------------------------------------

    def abstract_init(self) -> Tuple[Pytree, Pytree]:
        """(ShapeDtypeStruct params, logical axes) without allocating anything
        — used by the dry-run to stand in for multi-billion-param weights."""
        box: Dict[str, Any] = {}

        def capture(k):
            p, a = self.init(k)
            box["axes"] = a
            return p

        params_struct = jax.eval_shape(capture, jax.random.PRNGKey(0))
        return params_struct, box["axes"]

    def init(self, rng: jax.Array) -> Tuple[Pytree, Pytree]:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm"):
            return transformer.init_params(cfg, rng)
        if cfg.family == "hybrid":
            return hybrid.init_params(cfg, rng)
        if cfg.family == "ssm":
            return self._init_ssm(rng)
        if cfg.family == "audio":
            return encdec.init_params(cfg, rng)
        raise ValueError(cfg.family)

    def _init_ssm(self, rng):
        cfg = self.cfg
        ks = jax.random.split(rng, 3)

        def init_one(k):
            p, _ = mamba2.init_mamba_layer(
                k, cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                dtype=cfg.dtype,
            )
            return p

        _, m_axes = mamba2.init_mamba_layer(
            ks[0], cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
            dtype=cfg.dtype,
        )
        layers = jax.vmap(init_one)(jax.random.split(ks[1], cfg.n_layers))
        params = {
            "embed": jnp.zeros((cfg.vocab, cfg.d_model), cfg.dtype)
            + 0.02 * jax.random.normal(ks[2], (cfg.vocab, cfg.d_model), cfg.dtype),
            "mamba": layers,
            "final_ln": jnp.zeros((cfg.d_model,), cfg.dtype),
        }
        axes = {
            "embed": ("vocab", "embed_tbl"),
            "mamba": {k: ("layers",) + v for k, v in m_axes.items()},
            "final_ln": ("embed",),
        }
        return params, axes

    # -- forward paths ----------------------------------------------------------

    def _ssm_forward(self, params, tokens, ssm_states=None, conv_states=None,
                     positions=None, decode=False):
        cfg = self.cfg
        B, S = tokens.shape
        h = params["embed"][tokens].astype(cfg.dtype)
        L = cfg.n_layers
        if ssm_states is None:
            d_inner, conv_dim = mamba2.mamba_dims(
                cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            )
            ssm_states = jnp.zeros(
                (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
            )
            conv_states = jnp.zeros((L, B, mamba2.D_CONV - 1, conv_dim), jnp.bfloat16)

        def body(carry, xs):
            hh = carry
            lp, ssm_i, conv_i = xs
            hh, new_ssm, new_conv = mamba2.mamba_layer(
                lp, hh, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                chunk=cfg.ssm_chunk,
                ssm_state=ssm_i if decode else None,
                conv_state=conv_i if decode else None,
                decode=decode,
            )
            if new_conv is None:
                new_conv = conv_i
            return hh, (new_ssm, new_conv)

        fn = body
        if self.remat in ("dots", "full"):
            fn = jax.checkpoint(body, prevent_cse=False)
        h, (nssm, nconv) = common_scan(fn, h, (params["mamba"], ssm_states, conv_states))
        h = transformer.rms_norm(h, params["final_ln"])
        return h, {"ssm": nssm, "conv": nconv}

    # -- public API ---------------------------------------------------------------

    def train_loss(self, params: Pytree, batch: Dict[str, jax.Array]) -> jax.Array:
        cfg = self.cfg
        tokens, targets = batch["tokens"], batch["targets"]
        if cfg.family in ("dense", "moe", "vlm"):
            h, _ = transformer.forward(
                cfg, params, tokens,
                attn_impl=self.attn_impl, remat=self.remat,
                patch_embeds=batch.get("patch_embeds"),
                mrope_positions=batch.get("mrope_positions"),
            )
            return transformer.lm_loss(cfg, params, h, targets)
        if cfg.family == "hybrid":
            h, _ = hybrid.forward(
                cfg, params, tokens, attn_impl=self.attn_impl, remat=self.remat
            )
            return hybrid.lm_head_loss(cfg, params, h, targets)
        if cfg.family == "ssm":
            h, _ = self._ssm_forward(params, tokens)
            tied = dataclasses.replace(cfg, tie_embeddings=True)
            return transformer.lm_loss(tied, {"embed": params["embed"]}, h, targets)
        if cfg.family == "audio":
            enc = encdec.encode(cfg, params, batch["frame_embeds"], self.attn_impl)
            h = encdec.decode_train(cfg, params, enc, tokens, self.attn_impl, self.remat)
            tied = dataclasses.replace(cfg, tie_embeddings=True)
            return transformer.lm_loss(tied, {"embed": params["embed"]}, h, targets)
        raise ValueError(cfg.family)

    def prefill(self, params: Pytree, batch: Dict[str, jax.Array], max_len: int):
        """Processes the prompt; returns (hidden, decode state)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        B, S = tokens.shape
        if cfg.family in ("dense", "moe", "vlm"):
            caches = transformer.init_kv_cache(cfg, B, max_len)
            cache_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (B, max_len)
            )
            h, new_caches = transformer.forward(
                cfg, params, tokens,
                attn_impl=self.attn_impl,
                patch_embeds=batch.get("patch_embeds"),
                mrope_positions=batch.get("mrope_positions"),
                kv_caches=caches, cache_positions=cache_pos,
            )
            return h, {"kv": new_caches, "pos": jnp.full((B,), S, jnp.int32)}
        if cfg.family == "ssm":
            h, st = self._ssm_forward(params, tokens)
            st["pos"] = jnp.full((B,), S, jnp.int32)
            return h, st
        if cfg.family == "hybrid":
            apps = hybrid.n_attn_applications(cfg)
            kv = (
                jnp.zeros((apps, B, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
                jnp.zeros((apps, B, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
            )
            cache_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (B, max_len)
            )
            h, st = hybrid.forward(
                cfg, params, tokens, attn_impl=self.attn_impl,
                kv_caches=kv, cache_positions=cache_pos,
            )
            st["pos"] = jnp.full((B,), S, jnp.int32)
            return h, st
        if cfg.family == "audio":
            enc = encdec.encode(cfg, params, batch["frame_embeds"], self.attn_impl)
            kv = (
                jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
                jnp.zeros((cfg.n_layers, B, max_len, cfg.n_kv_heads, cfg.dh), cfg.dtype),
            )
            return enc, {"kv": kv, "enc": enc, "pos": jnp.zeros((B,), jnp.int32)}
        raise ValueError(cfg.family)

    def decode_step(self, params: Pytree, tokens: jax.Array, state: Dict[str, Any]):
        """One new token per sequence against the cached state."""
        cfg = self.cfg
        B = tokens.shape[0]
        positions = state["pos"][:, None]
        if cfg.family in ("dense", "moe", "vlm"):
            kv = state["kv"]
            max_len = kv[0].shape[2]
            cache_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (B, max_len)
            )
            h, new_kv = transformer.forward(
                cfg, params, tokens, positions=positions,
                attn_impl=self.attn_impl,
                kv_caches=kv, cache_positions=cache_pos,
            )
            return h, {"kv": new_kv, "pos": state["pos"] + 1}
        if cfg.family == "ssm":
            h, st = self._ssm_forward(
                params, tokens, ssm_states=state["ssm"], conv_states=state["conv"],
                decode=True,
            )
            st["pos"] = state["pos"] + 1
            return h, st
        if cfg.family == "hybrid":
            kv = state["kv"]
            max_len = kv[0].shape[2]
            cache_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (B, max_len)
            )
            h, st = hybrid.forward(
                cfg, params, tokens, positions=positions, attn_impl=self.attn_impl,
                kv_caches=kv, cache_positions=cache_pos,
                ssm_states=state["ssm"], conv_states=state["conv"], decode=True,
            )
            st["pos"] = state["pos"] + 1
            return h, st
        if cfg.family == "audio":
            kv = state["kv"]
            max_len = kv[0].shape[2]
            cache_pos = jnp.broadcast_to(
                jnp.arange(max_len, dtype=jnp.int32)[None], (B, max_len)
            )
            h, new_kv = encdec.decode_step(
                cfg, params, state["enc"], tokens, positions, kv, cache_pos,
                self.attn_impl,
            )
            return h, {"kv": new_kv, "enc": state["enc"], "pos": state["pos"] + 1}
        raise ValueError(cfg.family)

    def logits(self, params: Pytree, h: jax.Array) -> jax.Array:
        cfg = self.cfg
        if cfg.family in ("dense", "moe", "vlm") and not cfg.tie_embeddings:
            return transformer.lm_head(cfg, params, h)
        return h @ params["embed"].T.astype(h.dtype)
