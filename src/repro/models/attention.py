"""Attention: GQA with three interchangeable inner implementations.

* ``xla``      plain softmax(QK^T)V — materializes (Sq, Skv) scores; fine for
               short sequences, used as the semantic reference.
* ``chunked``  online-softmax over KV chunks via ``jax.lax.scan`` — the
               *register-demotion adapted* formulation: the running
               (m, l, acc) statistics stay in the scan carry (registers /
               VMEM once compiled) instead of materializing scores to HBM.
               Memory O(Sq x chunk), required for the 32k/500k shape cells.
* ``pallas``   the TPU kernel (:mod:`repro.kernels.flash_attention`), same
               math with explicit VMEM scratch residency.

All paths share the GQA head-grouping and mask conventions and are tested
allclose against each other.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .common import scan as common_scan, NEG_INF, causal_mask_bias

DEFAULT_CHUNK = 1024


def _expand_kv(k: jax.Array, n_q_heads: int) -> jax.Array:
    """(B, S, Hkv, Dh) -> (B, S, Hq, Dh) by group broadcast."""
    b, s, hkv, dh = k.shape
    groups = n_q_heads // hkv
    if groups == 1:
        return k
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, hkv, groups, dh))
    return k.reshape(b, s, n_q_heads, dh)


def attention_xla(
    q: jax.Array,  # (B, Sq, Hq, Dh)
    k: jax.Array,  # (B, Skv, Hkv, Dh)
    v: jax.Array,  # (B, Skv, Hkv, Dh)
    bias: Optional[jax.Array] = None,  # (B, 1, Sq, Skv) additive
    scale: Optional[float] = None,
) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    k = _expand_kv(k, q.shape[2])
    v = _expand_kv(v, q.shape[2])
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    if bias is not None:
        logits = logits + bias
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def attention_chunked(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,  # (B, Sq)
    kv_positions: jax.Array,  # (B, Skv)
    window: Optional[int] = None,
    chunk_attn: Optional[int] = None,
    scale: Optional[float] = None,
    kv_chunk: int = DEFAULT_CHUNK,
) -> jax.Array:
    """Online-softmax attention, scanning KV in chunks.

    The (m, l, acc) running statistics live in the scan carry — the JAX-level
    analogue of RegDem's demoted registers: state that would otherwise be
    spilled to HBM as (Sq x Skv) score tiles stays resident across the
    chunk loop.  FLOPs are identical to ``attention_xla``; peak memory is
    O(Sq x kv_chunk) per head.
    """
    scale = scale if scale is not None else 1.0 / math.sqrt(q.shape[-1])
    b, sq, hq, dh = q.shape
    skv = k.shape[1]
    k = _expand_kv(k, hq)
    v = _expand_kv(v, hq)
    n_chunks = -(-skv // kv_chunk)
    pad = n_chunks * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, ((0, 0), (0, pad)), constant_values=-1)
    kc = k.reshape(b, n_chunks, kv_chunk, hq, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, kv_chunk, hq, dh).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(b, n_chunks, kv_chunk).transpose(1, 0, 2)

    qf = q.astype(jnp.float32)

    def step(carry, xs):
        m, l, acc = carry  # (B,H,Sq), (B,H,Sq), (B,Sq,H,Dh)
        kci, vci, pci = xs
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kci.astype(jnp.float32)) * scale
        valid = pci[:, None, None, :] >= 0
        ok = jnp.logical_and(valid, pci[:, None, None, :] <= q_positions[:, None, :, None])
        if window is not None:
            ok = jnp.logical_and(
                ok, pci[:, None, None, :] > q_positions[:, None, :, None] - window
            )
        if chunk_attn is not None:
            ok = jnp.logical_and(
                ok,
                (pci[:, None, None, :] // chunk_attn)
                == (q_positions[:, None, :, None] // chunk_attn),
            )
        logits = jnp.where(ok, logits, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bhqk,bkhd->bqhd", p, vci.astype(jnp.float32))
        acc_new = acc * corr.transpose(0, 2, 1)[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)
    a0 = jnp.zeros((b, sq, hq, dh), jnp.float32)
    (m, l, acc), _ = common_scan(step, (m0, l0, a0), (kc, vc, pc))
    l = jnp.maximum(l, 1e-30)
    out = acc / l.transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


def attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_positions: jax.Array,
    kv_positions: jax.Array,
    impl: str = "xla",
    window: Optional[int] = None,
    chunk_attn: Optional[int] = None,
    kv_chunk: int = DEFAULT_CHUNK,
) -> jax.Array:
    """Unified entry point used by every architecture."""
    if impl == "chunked":
        return attention_chunked(
            q, k, v, q_positions, kv_positions,
            window=window, chunk_attn=chunk_attn, kv_chunk=kv_chunk,
        )
    if impl == "pallas":
        from repro.kernels import ops as kernel_ops

        return kernel_ops.flash_attention(
            q, k, v, q_positions, kv_positions, window=window, chunk_attn=chunk_attn
        )
    bias = causal_mask_bias(q_positions, kv_positions, window=window, chunk=chunk_attn)
    return attention_xla(q, k, v, bias=bias)
