"""Shared model components: norms, rotary embeddings, initializers.

Everything is a plain function over pytrees of ``jnp`` arrays — no Flax/NNX
dependency — so that parameter sharding stays a pure metadata concern
(:mod:`repro.sharding`) and layer stacks can be ``jax.lax.scan``-ed with
O(1) HLO size in depth (required for the 512-device dry-run).
"""

from __future__ import annotations

import contextlib
import math
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

Pytree = Any

# ---------------------------------------------------------------------------
# Scan unrolling (dry-run accounting mode)
# ---------------------------------------------------------------------------

#: When True, every lax.scan in the model stack is fully unrolled.  The
#: dry-run uses this so ``compiled.cost_analysis()`` counts loop bodies the
#: correct number of times (XLA's analysis counts a while body once) and the
#: static HLO collective parse is exact.  Real runs keep scans rolled.
_UNROLL = {"on": False}


@contextlib.contextmanager
def unrolled_scans():
    prev = _UNROLL["on"]
    _UNROLL["on"] = True
    try:
        yield
    finally:
        _UNROLL["on"] = prev


def scan(f, init, xs, **kw):
    """lax.scan honouring the dry-run unroll switch."""
    if _UNROLL["on"]:
        kw = dict(kw, unroll=True)
    return jax.lax.scan(f, init, xs, **kw)


# ---------------------------------------------------------------------------
# Initialization
# ---------------------------------------------------------------------------


def trunc_normal(key: jax.Array, shape, std: float, dtype=jnp.float32) -> jax.Array:
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def dense_init(key: jax.Array, d_in: int, d_out: int, dtype=jnp.float32) -> jax.Array:
    return trunc_normal(key, (d_in, d_out), std=1.0 / math.sqrt(d_in), dtype=dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, and the M-RoPE hook for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float = 10_000.0) -> jax.Array:
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)  # (head_dim/2,)


def apply_rope(
    x: jax.Array,  # (B, S, H, Dh)
    positions: jax.Array,  # (B, S) int32
    theta: float = 10_000.0,
) -> jax.Array:
    freqs = rope_frequencies(x.shape[-1], theta)  # (Dh/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, Dh/2)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array,  # (B, S, H, Dh)
    positions: jax.Array,  # (B, S, 3) int32 — temporal / height / width
    theta: float = 1_000_000.0,
    sections: Tuple[int, int, int] = (2, 3, 3),  # qwen2-vl mrope_section /8ths
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the head dim is partitioned into three
    frequency sections, each rotated by its own position stream."""
    dh = x.shape[-1]
    freqs = rope_frequencies(dh, theta)  # (Dh/2,)
    n = dh // 2
    total = sum(sections)
    bounds = []
    acc = 0
    for s in sections:
        acc += round(n * s / total)
        bounds.append(acc)
    bounds[-1] = n
    sec_id = jnp.zeros((n,), jnp.int32)
    sec_id = jnp.where(jnp.arange(n) >= bounds[0], 1, sec_id)
    sec_id = jnp.where(jnp.arange(n) >= bounds[1], 2, sec_id)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),  # (B, S, 3)
        jnp.broadcast_to(sec_id[None, None, :], positions.shape[:2] + (n,)).astype(jnp.int32) ,
        axis=-1,
    )  # (B, S, n): per-frequency position stream
    angles = pos * freqs  # (B, S, n)
    sin = jnp.sin(angles)[:, :, None, :]
    cos = jnp.cos(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Activations
# ---------------------------------------------------------------------------


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate) * up


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


# ---------------------------------------------------------------------------
# Attention masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask_bias(
    q_positions: jax.Array,  # (B, Sq)
    kv_positions: jax.Array,  # (B, Skv)
    window: Optional[int] = None,
    chunk: Optional[int] = None,
) -> jax.Array:
    """(B, 1, Sq, Skv) additive bias: causal, optionally sliding-window
    (gemma3 local layers) or chunked (llama4 iRoPE chunked attention)."""
    q = q_positions[:, None, :, None]
    k = kv_positions[:, None, None, :]
    ok = k <= q
    if window is not None:
        ok = jnp.logical_and(ok, k > q - window)
    if chunk is not None:
        ok = jnp.logical_and(ok, (k // chunk) == (q // chunk))
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)
