"""Decoder-only transformer family: dense, MoE, VLM-backbone.

One parameterized implementation covers stablelm-3b, gemma3-1b, qwen2-7b,
granite-8b, qwen2-moe-a2.7b, llama4-scout and the qwen2-vl-2b backbone:

* GQA attention with optional QKV bias, per-layer sliding-window /
  chunked-attention masks (gemma3 5:1 local:global, llama4 iRoPE), per-layer
  RoPE enable/theta, M-RoPE for the VLM;
* dense SwiGLU or MoE FFN (shared + routed experts, top-k, capacity-based
  scatter dispatch so compiled FLOPs reflect *active* experts only);
* layer stacks are scanned (``jax.lax.scan``) over stacked parameters:
  HLO size is O(1) in depth, which keeps the 512-device dry-run tractable;
* three step flavours: ``train`` (full seq), ``prefill`` (returns KV cache),
  ``decode`` (one token against the cache).

Parameters are plain pytrees; a parallel *logical-axes* pytree drives
sharding (:mod:`repro.sharding`).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention
from .common import scan as common_scan, apply_mrope, apply_rope, rms_norm, swiglu, trunc_normal

Pytree = Any

#: sentinel "no restriction" for traced window/chunk masks inside scan
BIG = 1 << 30


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    d_ff_shared: int = 0
    shared_gate: bool = False       # qwen2-moe: sigmoid gate on shared expert
    capacity_factor: float = 1.25
    norm_topk: bool = True


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | vlm | audio | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    # attention pattern: period p means layer i is GLOBAL iff (i+1) % p == 0;
    # other layers use `window` (sliding) or `attn_chunk` (chunked)
    global_period: int = 1           # 1 => every layer global
    window: Optional[int] = None
    attn_chunk: Optional[int] = None
    nope_on_global: bool = False     # llama4 iRoPE: no RoPE on global layers
    local_rope_theta: Optional[float] = None  # gemma3: 10k local / 1M global
    moe: Optional[MoEConfig] = None
    mrope: bool = False              # qwen2-vl M-RoPE
    # ssm / hybrid knobs live in mamba2.py / hybrid.py but are carried here
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 0
    ssm_chunk: int = 256
    attn_period: int = 0             # hybrid: shared attn block every k layers
    dtype: Any = jnp.bfloat16
    # notes for DESIGN.md §Arch-applicability
    notes: str = ""

    @property
    def dh(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    def layer_kinds(self) -> jnp.ndarray:
        """0 = local/chunked layer, 1 = global layer."""
        idx = jnp.arange(self.n_layers)
        if self.global_period <= 1:
            return jnp.ones((self.n_layers,), jnp.int32)
        return ((idx + 1) % self.global_period == 0).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Parameter init (+ logical axes)
# ---------------------------------------------------------------------------

A = lambda *names: tuple(names)  # logical-axes shorthand


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Pytree, Pytree]:
    """Returns (params, logical_axes) with layer-stacked weights."""
    keys = jax.random.split(key, 16)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    dt = cfg.dtype

    def stack(initializer, k, *shape_axes):
        shape, axes = zip(*shape_axes)
        ks = jax.random.split(k, L)
        w = jax.vmap(lambda kk: initializer(kk, shape))(ks)
        return w, A("layers", *axes)

    def sdense(k, d_in, d_out, ax_in, ax_out):
        init = lambda kk, shape: trunc_normal(kk, shape, std=1.0 / math.sqrt(d_in), dtype=dt)
        return stack(init, k, (d_in, ax_in), (d_out, ax_out))

    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}

    # vocab matrices keep their D dim replicated ("embed_tbl"): FSDP-sharding
    # it makes the LM head contract over a data-sharded dim, and GSPMD then
    # all-reduces (B,S,V) logits over the data axis — gigabytes per step
    params["embed"] = trunc_normal(keys[0], (V, D), std=0.02, dtype=dt)
    axes["embed"] = A("vocab", "embed_tbl")

    layers: Dict[str, Any] = {}
    lax_: Dict[str, Any] = {}
    layers["ln1"], lax_["ln1"] = stack(
        lambda kk, s: jnp.zeros(s, dt), keys[1], (D, "embed")
    )
    layers["ln2"], lax_["ln2"] = stack(
        lambda kk, s: jnp.zeros(s, dt), keys[2], (D, "embed")
    )
    layers["wq"], lax_["wq"] = sdense(keys[3], D, Hq * Dh, "embed", "heads")
    layers["wk"], lax_["wk"] = sdense(keys[4], D, Hkv * Dh, "embed", "heads")
    layers["wv"], lax_["wv"] = sdense(keys[5], D, Hkv * Dh, "embed", "heads")
    layers["wo"], lax_["wo"] = sdense(keys[6], Hq * Dh, D, "heads", "embed")
    if cfg.qkv_bias:
        for nm, width in (("bq", Hq * Dh), ("bk", Hkv * Dh), ("bv", Hkv * Dh)):
            layers[nm], lax_[nm] = stack(
                lambda kk, s: jnp.zeros(s, dt), keys[7], (width, "heads")
            )
    if cfg.moe is None:
        layers["w_gate"], lax_["w_gate"] = sdense(keys[8], D, F, "embed", "ff")
        layers["w_up"], lax_["w_up"] = sdense(keys[9], D, F, "embed", "ff")
        layers["w_down"], lax_["w_down"] = sdense(keys[10], F, D, "ff", "embed")
    else:
        m = cfg.moe
        E, Fe = m.n_experts, m.d_ff_expert
        layers["router"], lax_["router"] = sdense(keys[8], D, E, "embed", "expert_dim")

        def estack(k, d_in, d_out, ax_in, ax_out):
            init = lambda kk, shape: trunc_normal(
                kk, shape, std=1.0 / math.sqrt(d_in), dtype=dt
            )
            ks = jax.random.split(k, L)
            w = jax.vmap(lambda kk: init(kk, (E, d_in, d_out)))(ks)
            return w, A("layers", "expert", ax_in, ax_out)

        layers["we_gate"], lax_["we_gate"] = estack(keys[9], D, Fe, "embed", "ff_expert")
        layers["we_up"], lax_["we_up"] = estack(keys[10], D, Fe, "embed", "ff_expert")
        layers["we_down"], lax_["we_down"] = estack(keys[11], Fe, D, "ff_expert", "embed")
        if m.n_shared:
            Fs = m.d_ff_shared
            layers["ws_gate"], lax_["ws_gate"] = sdense(keys[12], D, Fs, "embed", "ff")
            layers["ws_up"], lax_["ws_up"] = sdense(keys[13], D, Fs, "embed", "ff")
            layers["ws_down"], lax_["ws_down"] = sdense(keys[14], Fs, D, "ff", "embed")
            if m.shared_gate:
                layers["ws_g"], lax_["ws_g"] = sdense(keys[15], D, 1, "embed", None)
    params["layers"] = layers
    axes["layers"] = lax_

    params["final_ln"] = jnp.zeros((D,), dt)
    axes["final_ln"] = A("embed")
    if not cfg.tie_embeddings:
        params["lm_head"] = trunc_normal(keys[7], (D, V), std=1.0 / math.sqrt(D), dtype=dt)
        axes["lm_head"] = A("embed_tbl", "vocab")
    if cfg.family == "vlm":
        params["patch_proj"] = trunc_normal(keys[6], (D, D), std=1.0 / math.sqrt(D), dtype=dt)
        axes["patch_proj"] = A("embed", "embed2")
    return params, axes


# ---------------------------------------------------------------------------
# MoE dispatch (capacity-based scatter; FLOPs = active experts only)
# ---------------------------------------------------------------------------


def _moe_dense_exact(x, lp, m, gate, expert):
    """Exact no-drop MoE for small T: every expert runs on every token and
    the top-k mask selects.  O(T*E*D*F) — only used for decode-sized T."""
    T, D = x.shape
    h = swiglu(
        jnp.einsum("td,edf->tef", x, lp["we_gate"]),
        jnp.einsum("td,edf->tef", x, lp["we_up"]),
    )
    y_all = jnp.einsum("tef,efd->ted", h, lp["we_down"])  # (T, E, D)
    onehot = jax.nn.one_hot(expert, m.n_experts, dtype=y_all.dtype)  # (T,k,E)
    w = (onehot * gate[..., None].astype(y_all.dtype)).sum(axis=1)  # (T, E)
    return jnp.einsum("ted,te->td", y_all, w)


def moe_ffn(
    x: jax.Array,
    lp: Dict[str, jax.Array],
    m: MoEConfig,
    dense_path_max_tokens: int = 256,
) -> jax.Array:
    """x: (T, D) -> (T, D).  Sort-based position assignment + scatter into an
    (E, C, D) expert buffer; dropped tokens (over capacity) contribute 0.
    Decode-sized inputs (T <= dense_path_max_tokens) take the exact path."""
    T, D = x.shape
    E, k = m.n_experts, m.top_k
    C = max(1, int(math.ceil(T * k / E * m.capacity_factor)))

    logits = (x @ lp["router"]).astype(jnp.float32)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)  # (T, k)
    if m.norm_topk:
        gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    if T <= dense_path_max_tokens:
        y = _moe_dense_exact(x, lp, m, gate, expert)
        if m.n_shared:
            ys = swiglu(x @ lp["ws_gate"], x @ lp["ws_up"]) @ lp["ws_down"]
            if m.shared_gate:
                ys = ys * jax.nn.sigmoid((x @ lp["ws_g"]).astype(jnp.float32)).astype(ys.dtype)
            y = y + ys
        return y

    flat_e = expert.reshape(-1)  # (T*k,)
    # position of each assignment within its expert via stable sort
    perm = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[perm]
    idx = jnp.arange(T * k)
    is_start = jnp.concatenate([jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    group_start = jax.lax.associative_scan(jnp.maximum, jnp.where(is_start, idx, 0))
    pos_sorted = idx - group_start
    inv = jnp.argsort(perm, stable=True)
    pos = pos_sorted[inv]  # (T*k,) position within expert

    keep = pos < C
    dest = jnp.where(keep, flat_e * C + pos, E * C)  # drop bucket at E*C
    x_rep = jnp.repeat(x, k, axis=0)  # (T*k, D)
    buf = jnp.zeros((E * C + 1, D), x.dtype).at[dest].add(x_rep)
    xe = buf[: E * C].reshape(E, C, D)

    h = swiglu(
        jnp.einsum("ecd,edf->ecf", xe, lp["we_gate"]),
        jnp.einsum("ecd,edf->ecf", xe, lp["we_up"]),
    )
    ye = jnp.einsum("ecf,efd->ecd", h, lp["we_down"]).reshape(E * C, D)
    ye = jnp.concatenate([ye, jnp.zeros((1, D), ye.dtype)], axis=0)
    y = ye[dest] * (gate.reshape(-1, 1).astype(ye.dtype)) * keep[:, None]
    y = y.reshape(T, k, D).sum(axis=1)

    if m.n_shared:
        ys = swiglu(x @ lp["ws_gate"], x @ lp["ws_up"]) @ lp["ws_down"]
        if m.shared_gate:
            ys = ys * jax.nn.sigmoid((x @ lp["ws_g"]).astype(jnp.float32)).astype(ys.dtype)
        y = y + ys
    return y


# ---------------------------------------------------------------------------
# Transformer block + step functions
# ---------------------------------------------------------------------------


def _qkv(
    h: jax.Array, lp: Dict[str, jax.Array], cfg: ModelConfig
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, S, D = h.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    q = h @ lp["wq"]
    k = h @ lp["wk"]
    v = h @ lp["wv"]
    if cfg.qkv_bias:
        q = q + lp["bq"]
        k = k + lp["bk"]
        v = v + lp["bv"]
    return (
        q.reshape(B, S, Hq, Dh),
        k.reshape(B, S, Hkv, Dh),
        v.reshape(B, S, Hkv, Dh),
    )


def _rope(cfg: ModelConfig, x, positions, kind, mrope_positions=None):
    if cfg.mrope and mrope_positions is not None:
        return apply_mrope(x, mrope_positions, theta=cfg.rope_theta)
    theta = cfg.rope_theta
    if cfg.local_rope_theta is not None:
        # gemma3: local layers use the local theta; kind is traced
        pos_local = apply_rope(x, positions, cfg.local_rope_theta)
        pos_global = apply_rope(x, positions, theta)
        return jnp.where(kind[..., None, None, None] > 0, pos_global, pos_local)
    if cfg.nope_on_global:
        roped = apply_rope(x, positions, theta)
        return jnp.where(kind[..., None, None, None] > 0, x, roped)
    return apply_rope(x, positions, theta)


def _mask_params(cfg: ModelConfig, kind: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-layer (window, chunk) as traced ints (BIG = unrestricted)."""
    window = jnp.where(kind > 0, BIG, cfg.window or BIG)
    chunk = jnp.where(kind > 0, BIG, cfg.attn_chunk or BIG)
    return window, chunk


def block(
    cfg: ModelConfig,
    h: jax.Array,
    lp: Dict[str, jax.Array],
    kind: jax.Array,
    positions: jax.Array,
    attn_impl: str,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_positions: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """One pre-norm transformer block; returns (h, new_kv)."""
    x = rms_norm(h, lp["ln1"])
    q, k, v = _qkv(x, lp, cfg)
    q = _rope(cfg, q, positions, kind, mrope_positions)
    k = _rope(cfg, k, positions, kind, mrope_positions)

    if kv_cache is not None:
        ck, cv = kv_cache  # (B, Skv, Hkv, Dh)
        # decode: insert current token(s) at their positions
        upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))
        ck = upd(ck, k.astype(ck.dtype), positions[:, 0])
        cv = upd(cv, v.astype(cv.dtype), positions[:, 0])
        k_att, v_att = ck, cv
        kv_positions = cache_positions
        new_cache = (ck, cv)
    else:
        k_att, v_att = k, v
        kv_positions = positions
        new_cache = None

    window, chunk = _mask_params(cfg, kind)
    o = attention(
        q, k_att, v_att, positions, kv_positions,
        impl=attn_impl, window=window, chunk_attn=chunk,
    )
    B, S = h.shape[:2]
    h = h + (o.reshape(B, S, -1) @ lp["wo"]).astype(h.dtype)

    x = rms_norm(h, lp["ln2"])
    if cfg.moe is None:
        y = swiglu(x @ lp["w_gate"], x @ lp["w_up"]) @ lp["w_down"]
    else:
        y = moe_ffn(x.reshape(-1, cfg.d_model), lp, cfg.moe).reshape(x.shape)
    h = h + y.astype(h.dtype)
    return h, new_cache


def _split_moe_keys(cfg: ModelConfig, lp: Dict[str, jax.Array]):
    return lp


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,  # (B, S) int32
    positions: Optional[jax.Array] = None,
    attn_impl: str = "chunked",
    remat: str = "none",  # none | dots | full
    patch_embeds: Optional[jax.Array] = None,
    mrope_positions: Optional[jax.Array] = None,
    kv_caches: Optional[Tuple[jax.Array, jax.Array]] = None,  # (L,B,Skv,Hkv,Dh) x2
    cache_positions: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (final hidden states (B,S,D), stacked new KV caches or None)."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    if cfg.family == "vlm" and patch_embeds is not None:
        # frontend stub: precomputed patch embeddings occupy the prefix
        P = patch_embeds.shape[1]
        proj = (patch_embeds.astype(cfg.dtype) @ params["patch_proj"]).astype(cfg.dtype)
        h = jnp.concatenate([proj, h[:, P:]], axis=1)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    kinds = cfg.layer_kinds()

    def scan_body(carry, xs):
        h = carry
        if kv_caches is not None:
            lp, kind, ck, cv = xs
            h, new_kv = block(
                cfg, h, lp, kind, positions, attn_impl,
                kv_cache=(ck, cv), cache_positions=cache_positions,
                mrope_positions=mrope_positions,
            )
            return h, new_kv
        lp, kind = xs
        h, _ = block(
            cfg, h, lp, kind, positions, attn_impl,
            mrope_positions=mrope_positions,
        )
        return h, None

    body = scan_body
    if remat == "full":
        body = jax.checkpoint(scan_body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            scan_body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
            prevent_cse=False,
        )

    if kv_caches is not None:
        xs = (params["layers"], kinds, kv_caches[0], kv_caches[1])
        h, new_caches = common_scan(body, h, xs)
    else:
        h, new_caches = common_scan(body, h, (params["layers"], kinds))

    h = rms_norm(h, params["final_ln"])
    return h, new_caches


def lm_head(cfg: ModelConfig, params: Pytree, h: jax.Array) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return h @ w.astype(h.dtype)


def lm_loss(
    cfg: ModelConfig,
    params: Pytree,
    h: jax.Array,  # (B, S, D) final hidden
    targets: jax.Array,  # (B, S) int32
    chunk: int = 512,
) -> jax.Array:
    """Chunked cross-entropy: the (B,S,V) logits are never materialized.

    This is the framework-level register-demotion move: the per-chunk
    running loss lives in the scan carry while logits stay chunk-sized.
    """
    B, S, D = h.shape
    n = -(-S // chunk)
    pad = n * chunk - S
    if pad:
        h = jnp.pad(h, ((0, 0), (0, pad), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, pad)), constant_values=-1)
    hc = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    tc = targets.reshape(B, n, chunk).transpose(1, 0, 2)

    def step(acc, xs):
        hh, tt = xs
        logits = lm_head(cfg, params, hh).astype(jnp.float32)  # (B, c, V)
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(tt, 0)[..., None], axis=-1
        )[..., 0]
        valid = tt >= 0
        nll = jnp.where(valid, logz - gold, 0.0)
        return (acc[0] + nll.sum(), acc[1] + valid.sum()), None

    # checkpoint the chunk step: without it, reverse-mode AD saves every
    # chunk's (B, c, V) logits — reassembling exactly the full-logits tensor
    # the chunking exists to avoid
    step = jax.checkpoint(step, prevent_cse=False)
    (total, count), _ = common_scan(step, (jnp.float32(0.0), jnp.int32(0)), (hc, tc))
    return total / jnp.maximum(count, 1)


# ---------------------------------------------------------------------------
# KV cache helpers
# ---------------------------------------------------------------------------


def init_kv_cache(
    cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16
) -> Tuple[jax.Array, jax.Array]:
    shape = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def kv_cache_axes() -> Tuple[Tuple[str, ...], Tuple[str, ...]]:
    ax = ("layers", "batch", "kv_seq", "heads", "head_dim")
    return ax, ax
