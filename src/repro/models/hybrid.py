"""Zamba2-style hybrid: Mamba2 backbone + a *shared* attention block.

arXiv:2411.15242: a stack of Mamba2 layers, interleaved every ``attn_period``
layers with a full attention block whose weights are SHARED across all
applications (parameter-efficient global mixing).  Each application still
needs its own KV cache (activations differ), so caches are stacked over
applications, not layers.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .attention import attention
from .common import scan as common_scan, apply_rope, dense_init, rms_norm, swiglu, trunc_normal
from .mamba2 import init_mamba_layer, mamba_layer
from .transformer import ModelConfig

Pytree = Any


def n_attn_applications(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.attn_period if cfg.attn_period else 0


def init_params(cfg: ModelConfig, key: jax.Array) -> Tuple[Pytree, Pytree]:
    ks = jax.random.split(key, 8)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    dt = cfg.dtype

    # stacked mamba layers
    def init_one(k):
        p, _ = init_mamba_layer(
            k, D, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, dtype=dt
        )
        return p

    _, m_axes = init_mamba_layer(
        ks[0], D, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, dtype=dt
    )
    mamba = jax.vmap(init_one)(jax.random.split(ks[1], L))
    mamba_axes = {k: ("layers",) + v for k, v in m_axes.items()}

    # one shared attention block (+ its FFN)
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    shared = {
        "ln1": jnp.zeros((D,), dt),
        "wq": dense_init(ks[2], D, Hq * Dh, dt),
        "wk": dense_init(ks[3], D, Hkv * Dh, dt),
        "wv": dense_init(ks[4], D, Hkv * Dh, dt),
        "wo": dense_init(ks[5], Hq * Dh, D, dt),
        "ln2": jnp.zeros((D,), dt),
        "w_gate": dense_init(ks[6], D, F, dt),
        "w_up": dense_init(ks[7], D, F, dt),
        "w_down": dense_init(ks[2], F, D, dt),
    }
    shared_axes = {
        "ln1": ("embed",),
        "wq": ("embed", "heads"),
        "wk": ("embed", "heads"),
        "wv": ("embed", "heads"),
        "wo": ("heads", "embed"),
        "ln2": ("embed",),
        "w_gate": ("embed", "ff"),
        "w_up": ("embed", "ff"),
        "w_down": ("ff", "embed"),
    }

    params = {
        "embed": trunc_normal(ks[3], (V, D), std=0.02, dtype=dt),
        "mamba": mamba,
        "shared_attn": shared,
        "final_ln": jnp.zeros((D,), dt),
    }
    axes = {
        "embed": ("vocab", "embed_tbl"),
        "mamba": mamba_axes,
        "shared_attn": shared_axes,
        "final_ln": ("embed",),
    }
    return params, axes


def _shared_attn_block(
    cfg: ModelConfig,
    sp: Dict[str, jax.Array],
    h: jax.Array,
    positions: jax.Array,
    attn_impl: str,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_positions: Optional[jax.Array] = None,
):
    B, S, D = h.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    x = rms_norm(h, sp["ln1"])
    q = (x @ sp["wq"]).reshape(B, S, Hq, Dh)
    k = (x @ sp["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ sp["wv"]).reshape(B, S, Hkv, Dh)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kv_cache is not None:
        ck, cv = kv_cache
        upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))
        ck = upd(ck, k.astype(ck.dtype), positions[:, 0])
        cv = upd(cv, v.astype(cv.dtype), positions[:, 0])
        k_att, v_att, kv_pos = ck, cv, cache_positions
        new_cache = (ck, cv)
    else:
        k_att, v_att, kv_pos = k, v, positions
        new_cache = None
    o = attention(q, k_att, v_att, positions, kv_pos, impl=attn_impl)
    h = h + (o.reshape(B, S, -1) @ sp["wo"]).astype(h.dtype)
    x = rms_norm(h, sp["ln2"])
    h = h + (swiglu(x @ sp["w_gate"], x @ sp["w_up"]) @ sp["w_down"]).astype(h.dtype)
    return h, new_cache


def forward(
    cfg: ModelConfig,
    params: Pytree,
    tokens: jax.Array,
    positions: Optional[jax.Array] = None,
    attn_impl: str = "chunked",
    remat: str = "none",
    kv_caches: Optional[Tuple[jax.Array, jax.Array]] = None,  # (Apps,B,Skv,Hkv,Dh) x2
    cache_positions: Optional[jax.Array] = None,
    ssm_states: Optional[jax.Array] = None,   # (L, B, H, P, N)
    conv_states: Optional[jax.Array] = None,  # (L, B, D_CONV-1, conv_dim)
    decode: bool = False,
) -> Tuple[jax.Array, Dict[str, Any]]:
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    period = cfg.attn_period or (cfg.n_layers + 1)
    apps = n_attn_applications(cfg)

    def group_body(carry, xs):
        """One group = `period` mamba layers + one shared-attn application."""
        h, app_idx = carry
        lp_group, kv_k, kv_v, ssm_g, conv_g = xs

        def mamba_scan(carry_h, layer_xs):
            hh = carry_h
            lp, ssm_i, conv_i = layer_xs
            hh, new_ssm, new_conv = mamba_layer(
                lp, hh, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                chunk=cfg.ssm_chunk,
                ssm_state=ssm_i if decode else None,
                conv_state=conv_i if decode else None,
                decode=decode,
            )
            if new_conv is None:
                new_conv = conv_i
            return hh, (new_ssm, new_conv)

        h, (new_ssm_g, new_conv_g) = common_scan(
            mamba_scan, h, (lp_group, ssm_g, conv_g)
        )
        h, new_kv = _shared_attn_block(
            cfg, params["shared_attn"], h, positions, attn_impl,
            kv_cache=(kv_k, kv_v) if kv_caches is not None else None,
            cache_positions=cache_positions,
        )
        if new_kv is None:
            new_kv = (kv_k, kv_v)
        return (h, app_idx + 1), (new_kv[0], new_kv[1], new_ssm_g, new_conv_g)

    # reshape stacked layer params into (apps, period, ...)
    L = cfg.n_layers
    used = apps * period
    lp_used = jax.tree.map(lambda w: w[:used].reshape((apps, period) + w.shape[1:]), params["mamba"])

    if ssm_states is None:
        from .mamba2 import D_CONV, mamba_dims

        d_inner, conv_dim = mamba_dims(cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        ssm_states = jnp.zeros(
            (L, B, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32
        )
        conv_states = jnp.zeros((L, B, D_CONV - 1, conv_dim), jnp.bfloat16)
    ssm_g = ssm_states[:used].reshape((apps, period) + ssm_states.shape[1:])
    conv_g = conv_states[:used].reshape((apps, period) + conv_states.shape[1:])
    if kv_caches is not None:
        kv_k, kv_v = kv_caches
    else:
        Hkv, Dh = cfg.n_kv_heads, cfg.dh
        kv_k = jnp.zeros((apps, B, 1, Hkv, Dh), cfg.dtype)
        kv_v = jnp.zeros((apps, B, 1, Hkv, Dh), cfg.dtype)

    body = group_body
    if remat in ("dots", "full"):
        body = jax.checkpoint(group_body, prevent_cse=False)
    (h, _), (nk, nv, nssm, nconv) = common_scan(
        body, (h, 0), (lp_used, kv_k, kv_v, ssm_g, conv_g)
    )

    # trailing mamba layers (n_layers not divisible by period)
    rest = L - used
    if rest:
        lp_rest = jax.tree.map(lambda w: w[used:], params["mamba"])

        def tail_scan(carry_h, layer_xs):
            hh = carry_h
            lp, ssm_i, conv_i = layer_xs
            hh, new_ssm, new_conv = mamba_layer(
                lp, hh, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                chunk=cfg.ssm_chunk,
                ssm_state=ssm_i if decode else None,
                conv_state=conv_i if decode else None,
                decode=decode,
            )
            if new_conv is None:
                new_conv = conv_i
            return hh, (new_ssm, new_conv)

        h, (tssm, tconv) = common_scan(
            tail_scan, h, (lp_rest, ssm_states[used:], conv_states[used:])
        )
    h = rms_norm(h, params["final_ln"])

    state = {
        "kv": (nk, nv),
        "ssm": jnp.concatenate(
            [nssm.reshape((used,) + nssm.shape[2:])] + ([tssm] if rest else []), axis=0
        ),
        "conv": jnp.concatenate(
            [nconv.reshape((used,) + nconv.shape[2:])] + ([tconv] if rest else []), axis=0
        ),
    }
    return h, state


def lm_head_loss(cfg, params, h, targets, chunk: int = 512):
    from .transformer import lm_loss

    # tied embeddings (zamba2 ties); reuse the chunked CE with embed.T
    tied_cfg = cfg
    fake = {"embed": params["embed"]}
    import dataclasses as _dc

    tied = _dc.replace(cfg, tie_embeddings=True)
    return lm_loss(tied, fake, h, targets, chunk=chunk)
