"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

Per the assignment, the audio frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings (the output of the two-conv mel frontend), so
the encoder here is the transformer stack over frames; the decoder is a
standard causal transformer with cross-attention into the encoder output.

Whisper uses learned positions + pre-norm LayerNorm; we keep RMSNorm for
uniformity with the rest of the framework (backbone compute/shape identical,
noted in DESIGN.md).
"""

from __future__ import annotations

import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp

from .attention import attention
from .common import scan as common_scan, rms_norm, swiglu, trunc_normal

Pytree = Any


def init_params(cfg, key: jax.Array) -> Tuple[Pytree, Pytree]:
    """cfg: ModelConfig with n_layers = encoder layers = decoder layers."""
    ks = jax.random.split(key, 12)
    L, D, V = cfg.n_layers, cfg.d_model, cfg.vocab
    Hq, Hkv, Dh, F = cfg.n_heads, cfg.n_kv_heads, cfg.dh, cfg.d_ff
    dt = cfg.dtype

    def stack(k, d_in, d_out):
        init = lambda kk: trunc_normal(kk, (d_in, d_out), std=1.0 / math.sqrt(d_in), dtype=dt)
        return jax.vmap(init)(jax.random.split(k, L))

    def zstack(width):
        return jnp.zeros((L, width), dt)

    enc = {
        "ln1": zstack(D), "wq": stack(ks[0], D, Hq * Dh), "wk": stack(ks[1], D, Hkv * Dh),
        "wv": stack(ks[2], D, Hkv * Dh), "wo": stack(ks[3], Hq * Dh, D),
        "ln2": zstack(D), "w_gate": stack(ks[4], D, F), "w_up": stack(ks[5], D, F),
        "w_down": stack(ks[6], F, D),
    }
    dec = {
        "ln1": zstack(D), "wq": stack(ks[7], D, Hq * Dh), "wk": stack(ks[8], D, Hkv * Dh),
        "wv": stack(ks[9], D, Hkv * Dh), "wo": stack(ks[10], Hq * Dh, D),
        # cross attention
        "lnx": zstack(D), "xq": stack(ks[11], D, Hq * Dh), "xk": stack(ks[0], D, Hkv * Dh),
        "xv": stack(ks[1], D, Hkv * Dh), "xo": stack(ks[2], Hq * Dh, D),
        "ln2": zstack(D), "w_gate": stack(ks[3], D, F), "w_up": stack(ks[4], D, F),
        "w_down": stack(ks[5], F, D),
    }
    params = {
        "frame_proj": trunc_normal(ks[6], (D, D), std=1.0 / math.sqrt(D), dtype=dt),
        "enc": enc,
        "enc_ln": jnp.zeros((D,), dt),
        "embed": trunc_normal(ks[7], (V, D), std=0.02, dtype=dt),
        "dec": dec,
        "final_ln": jnp.zeros((D,), dt),
    }

    def axes_like(tree, table):
        return {k: table[k] for k in tree}

    mat2 = {
        "ln1": ("layers", "embed"), "wq": ("layers", "embed", "heads"),
        "wk": ("layers", "embed", "heads"), "wv": ("layers", "embed", "heads"),
        "wo": ("layers", "heads", "embed"), "ln2": ("layers", "embed"),
        "w_gate": ("layers", "embed", "ff"), "w_up": ("layers", "embed", "ff"),
        "w_down": ("layers", "ff", "embed"),
        "lnx": ("layers", "embed"), "xq": ("layers", "embed", "heads"),
        "xk": ("layers", "embed", "heads"), "xv": ("layers", "embed", "heads"),
        "xo": ("layers", "heads", "embed"),
    }
    axes = {
        "frame_proj": ("embed", "embed2"),
        "enc": axes_like(enc, mat2),
        "enc_ln": ("embed",),
        "embed": ("vocab", "embed_tbl"),
        "dec": axes_like(dec, mat2),
        "final_ln": ("embed",),
    }
    return params, axes


def _self_block(cfg, lp, h, positions, attn_impl, causal, kv_cache=None, cache_positions=None):
    B, S, D = h.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh
    x = rms_norm(h, lp["ln1"])
    q = (x @ lp["wq"]).reshape(B, S, Hq, Dh)
    k = (x @ lp["wk"]).reshape(B, S, Hkv, Dh)
    v = (x @ lp["wv"]).reshape(B, S, Hkv, Dh)
    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache
        upd = jax.vmap(lambda c, u, p: jax.lax.dynamic_update_slice(c, u, (p, 0, 0)))
        ck = upd(ck, k.astype(ck.dtype), positions[:, 0])
        cv = upd(cv, v.astype(cv.dtype), positions[:, 0])
        k, v, kv_pos = ck, cv, cache_positions
        new_cache = (ck, cv)
    else:
        kv_pos = positions
    if causal:
        o = attention(q, k, v, positions, kv_pos, impl=attn_impl)
    else:
        # bidirectional encoder: every query position sees all keys
        o = attention(q, k, v, jnp.full_like(positions, kv_pos.shape[1]), kv_pos, impl=attn_impl)
    h = h + (o.reshape(B, S, -1) @ lp["wo"]).astype(h.dtype)
    x = rms_norm(h, lp["ln2"])
    h = h + (swiglu(x @ lp["w_gate"], x @ lp["w_up"]) @ lp["w_down"]).astype(h.dtype)
    return h, new_cache


def encode(cfg, params, frame_embeds: jax.Array, attn_impl: str = "chunked") -> jax.Array:
    """frame_embeds: (B, T, D) precomputed (conv frontend stub)."""
    h = (frame_embeds.astype(cfg.dtype) @ params["frame_proj"]).astype(cfg.dtype)
    B, T, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))

    def body(carry, lp):
        hh, _ = _self_block(cfg, lp, carry, positions, attn_impl, causal=False)
        return hh, None

    h, _ = common_scan(body, h, params["enc"])
    return rms_norm(h, params["enc_ln"])


def decode_train(
    cfg, params, enc_out: jax.Array, tokens: jax.Array, attn_impl: str = "chunked",
    remat: str = "none",
) -> jax.Array:
    """Teacher-forced decoder pass; returns final hidden states."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    T = enc_out.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh

    def body(carry, lp):
        h = carry
        h, _ = _self_block(cfg, lp, h, positions, attn_impl, causal=True)
        # cross attention
        x = rms_norm(h, lp["lnx"])
        q = (x @ lp["xq"]).reshape(B, S, Hq, Dh)
        k = (enc_out @ lp["xk"]).reshape(B, T, Hkv, Dh)
        v = (enc_out @ lp["xv"]).reshape(B, T, Hkv, Dh)
        # bidirectional over encoder frames: q_position >= all kv positions
        o = attention(q, k, v, jnp.full((B, S), T, jnp.int32), enc_pos, impl=attn_impl)
        h = h + (o.reshape(B, S, -1) @ lp["xo"]).astype(h.dtype)
        return h, None

    fn = body
    if remat in ("dots", "full"):
        fn = jax.checkpoint(body, prevent_cse=False)
    h, _ = common_scan(fn, h, params["dec"])
    return rms_norm(h, params["final_ln"])


def decode_step(
    cfg, params, enc_out: jax.Array, tokens: jax.Array, positions: jax.Array,
    kv_caches: Tuple[jax.Array, jax.Array], cache_positions: jax.Array,
    attn_impl: str = "chunked",
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Single-token decoder step with self-attention KV cache."""
    B, S = tokens.shape
    h = params["embed"][tokens].astype(cfg.dtype)
    T = enc_out.shape[1]
    enc_pos = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32)[None], (B, T))
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.dh

    def body(carry, xs):
        h = carry
        lp, ck, cv = xs
        h, new_cache = _self_block(
            cfg, lp, h, positions, "chunked", causal=True,
            kv_cache=(ck, cv), cache_positions=cache_positions,
        )
        x = rms_norm(h, lp["lnx"])
        q = (x @ lp["xq"]).reshape(B, S, Hq, Dh)
        k = (enc_out @ lp["xk"]).reshape(B, T, Hkv, Dh)
        v = (enc_out @ lp["xv"]).reshape(B, T, Hkv, Dh)
        o = attention(q, k, v, jnp.full((B, S), T, jnp.int32), enc_pos, impl=attn_impl)
        h = h + (o.reshape(B, S, -1) @ lp["xo"]).astype(h.dtype)
        return h, new_cache

    h, new_caches = common_scan(body, h, (params["dec"],) + kv_caches)
    return rms_norm(h, params["final_ln"]), new_caches
