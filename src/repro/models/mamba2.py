"""Mamba2 (state-space duality / SSD) mixer — arXiv:2405.21060.

Chunked SSD algorithm in pure JAX: within chunks of length ``Q`` the
recurrence is computed in its quadratic "attention-like" dual form; across
chunks a ``jax.lax.scan`` carries the (H, P, N) recurrent state.

Register-demotion connection (DESIGN.md §2): the carried chunk state is the
demoted-register analogue — it stays resident (registers/VMEM) across the
chunk loop instead of being re-materialized from HBM, and the Pallas kernel
(:mod:`repro.kernels.mamba2_ssd`) makes that residency explicit with VMEM
scratch.

Decode is the O(1) recurrent update: ``h = dA * h + dt*B (x); y = C . h``.
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import scan as common_scan, rms_norm, trunc_normal

Pytree = Any

D_CONV = 4  # depthwise causal conv width (mamba2 default)
N_GROUPS = 1


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def mamba_dims(d_model: int, ssm_heads: int, ssm_head_dim: int, d_state: int):
    d_inner = ssm_heads * ssm_head_dim
    conv_dim = d_inner + 2 * N_GROUPS * d_state
    return d_inner, conv_dim


def init_mamba_layer(
    key: jax.Array,
    d_model: int,
    ssm_heads: int,
    ssm_head_dim: int,
    d_state: int,
    dtype=jnp.bfloat16,
) -> Tuple[Dict[str, jax.Array], Dict[str, Tuple[str, ...]]]:
    H, P, N = ssm_heads, ssm_head_dim, d_state
    d_inner, conv_dim = mamba_dims(d_model, H, P, N)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_inner + 2 * N_GROUPS * N + H  # z, x, B, C, dt
    params = {
        "in_proj": trunc_normal(ks[0], (d_model, proj_out), std=1.0 / math.sqrt(d_model), dtype=dtype),
        "conv_w": trunc_normal(ks[1], (D_CONV, conv_dim), std=0.2, dtype=dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "d_skip": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": trunc_normal(ks[2], (d_inner, d_model), std=1.0 / math.sqrt(d_inner), dtype=dtype),
        "ln": jnp.zeros((d_model,), dtype),
    }
    axes = {
        "in_proj": ("embed", "ff"),
        "conv_w": (None, "ff"),
        "conv_b": ("ff",),
        "a_log": (None,),
        "d_skip": (None,),
        "dt_bias": (None,),
        "norm": ("ff",),
        "out_proj": ("ff", "embed"),
        "ln": ("embed",),
    }
    return params, axes


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """Lower-triangular cumulative sums: out[..., i, j] = sum_{j<t<=i} x[t]."""
    q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    out = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), k=0)
    return jnp.where(mask, out, -jnp.inf)


def ssd_chunked(
    x: jax.Array,   # (B, S, H, P)
    dt: jax.Array,  # (B, S, H) — post-softplus
    a: jax.Array,   # (H,) — negative decay rates
    bm: jax.Array,  # (B, S, G, N)
    cm: jax.Array,  # (B, S, G, N)
    chunk: int = 256,
    h0: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y (B,S,H,P), final state (B,H,P,N))."""
    B, S, H, P = x.shape
    N = bm.shape[-1]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bm = jnp.pad(bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cm = jnp.pad(cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    Q = chunk
    xf = x.astype(jnp.float32).reshape(B, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(B, nc, Q, H)
    bf = bm.astype(jnp.float32).reshape(B, nc, Q, N_GROUPS, N)[..., 0, :]  # (B,nc,Q,N)
    cf = cm.astype(jnp.float32).reshape(B, nc, Q, N_GROUPS, N)[..., 0, :]

    da = dtf * a[None, None, None, :]  # (B, nc, Q, H) — negative
    da_cum = jnp.cumsum(da, axis=2)  # within chunk
    da_total = da_cum[:, :, -1:, :]  # (B, nc, 1, H)

    # ---- intra-chunk (quadratic dual form) ---------------------------------
    L = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B, nc, H, Q, Q)
    scores = jnp.einsum("bcqn,bckn->bcqk", cf, bf)  # (B, nc, Q, Q)
    y_intra = jnp.einsum("bchqk,bcqk,bckh,bckhp->bcqhp", L, scores, dtf, xf)

    # ---- chunk states ------------------------------------------------------
    decay_to_end = jnp.exp(da_total - da_cum)  # (B, nc, Q, H)
    states = jnp.einsum("bcqn,bcqh,bcqhp->bchpn", bf, dtf * decay_to_end, xf)

    # ---- inter-chunk recurrence -------------------------------------------
    chunk_decay = jnp.exp(da_total[:, :, 0, :])  # (B, nc, H)

    def scan_step(h, xs):
        st, dec = xs  # (B,H,P,N), (B,H)
        h_new = h * dec[:, :, None, None] + st
        return h_new, h  # emit state *before* this chunk

    init = h0 if h0 is not None else jnp.zeros((B, H, P, N), jnp.float32)
    h_last, h_prevs = common_scan(
        scan_step,
        init.astype(jnp.float32),
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)  # (B, nc, H, P, N)

    decay_from_start = jnp.exp(da_cum)  # (B, nc, Q, H)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", cf, decay_from_start, h_prevs
    )

    y = (y_intra + y_inter).reshape(B, nc * Q, H, P)[:, :S]
    return y, h_last


def ssd_decode_step(
    x: jax.Array,   # (B, H, P)
    dt: jax.Array,  # (B, H)
    a: jax.Array,   # (H,)
    bm: jax.Array,  # (B, N)
    cm: jax.Array,  # (B, N)
    h: jax.Array,   # (B, H, P, N) fp32
) -> Tuple[jax.Array, jax.Array]:
    da = jnp.exp(dt.astype(jnp.float32) * a[None, :])  # (B, H)
    dbx = jnp.einsum("bh,bn,bhp->bhpn", dt.astype(jnp.float32), bm.astype(jnp.float32), x.astype(jnp.float32))
    h_new = h * da[:, :, None, None] + dbx
    y = jnp.einsum("bn,bhpn->bhp", cm.astype(jnp.float32), h_new)
    return y, h_new


# ---------------------------------------------------------------------------
# Full mixer layer (conv frontend + SSD + gated output)
# ---------------------------------------------------------------------------


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over (B, S, C) with kernel (D_CONV, C)."""
    pad = w.shape[0] - 1
    uf = jnp.pad(u, ((0, 0), (pad, 0), (0, 0)))
    # unrolled depthwise conv: sum of shifted scaled copies (D_CONV is tiny)
    out = sum(
        uf[:, i : i + u.shape[1], :] * w[i][None, None, :] for i in range(w.shape[0])
    )
    return out + b[None, None, :]


def mamba_layer(
    lp: Dict[str, jax.Array],
    h: jax.Array,  # (B, S, D)
    ssm_heads: int,
    ssm_head_dim: int,
    d_state: int,
    chunk: int = 256,
    ssm_state: Optional[jax.Array] = None,   # (B,H,P,N) for decode
    conv_state: Optional[jax.Array] = None,  # (B, D_CONV-1, conv_dim)
    decode: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    """Returns (h_out, new_ssm_state, new_conv_state)."""
    B, S, D = h.shape
    H, P, N = ssm_heads, ssm_head_dim, d_state
    d_inner, conv_dim = mamba_dims(D, H, P, N)

    res = h
    x = rms_norm(h, lp["ln"])
    proj = x @ lp["in_proj"]  # (B, S, 2*d_inner + 2N + H)
    z, xbc, dt_raw = jnp.split(proj, [d_inner, d_inner + conv_dim], axis=-1)

    if decode:
        assert conv_state is not None
        window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc], axis=1)
        new_conv_state = window[:, 1:].astype(jnp.bfloat16)
        xbc_c = (
            jnp.einsum("bkc,kc->bc", window, lp["conv_w"]) + lp["conv_b"]
        )[:, None, :]
    else:
        xbc_c = _causal_conv(xbc, lp["conv_w"], lp["conv_b"])
        new_conv_state = xbc[:, -(D_CONV - 1):, :].astype(jnp.bfloat16) if S >= D_CONV - 1 else None
    xbc_c = jax.nn.silu(xbc_c)

    xs, bm, cm = jnp.split(xbc_c, [d_inner, d_inner + N_GROUPS * N], axis=-1)
    xs = xs.reshape(B, -1, H, P)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + lp["dt_bias"][None, None, :])
    a = -jnp.exp(lp["a_log"])  # (H,) negative

    if decode:
        assert ssm_state is not None
        y, new_state = ssd_decode_step(
            xs[:, 0], dt[:, 0], a, bm[:, 0], cm[:, 0], ssm_state
        )
        y = y[:, None]  # (B, 1, H, P)
    else:
        bm4 = bm.reshape(B, -1, N_GROUPS, N)
        cm4 = cm.reshape(B, -1, N_GROUPS, N)
        y, new_state = ssd_chunked(xs, dt, a, bm4, cm4, chunk=chunk, h0=ssm_state)

    y = y + xs.astype(jnp.float32) * lp["d_skip"][None, None, :, None]
    y = y.reshape(B, -1, d_inner).astype(h.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["norm"])
    out = res + (y @ lp["out_proj"]).astype(h.dtype)
    return out, new_state, new_conv_state
