"""Deterministic synthetic LM data pipeline.

Properties a real-cluster pipeline needs, kept here at full fidelity:

* **determinism under restart**: batch ``i`` is a pure function of
  ``(seed, i)`` — resuming from a checkpoint at step ``k`` replays exactly
  the data the crashed run would have seen (tested bit-exact);
* **per-host sharding**: each host generates only its slice of the global
  batch (``host_id``/``n_hosts``), so no broadcast is needed at scale;
* **sequence packing**: documents of random length are packed into fixed
  ``seq_len`` rows with EOS separators, and loss masking marks the padding
  tail (``targets = -1``).

The token *contents* are a structured pseudo-corpus (a Zipfian unigram mix
with short-range repetition), not uniform noise, so small-model training
loss decreases measurably — the end-to-end example trains on it.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mean_doc_len: int = 192
    eos: int = 0


def _zipf_probs(vocab: int, alpha: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** (-alpha)
    return (p / p.sum()).astype(np.float64)


class SyntheticLM:
    """Iterator of global batches (optionally host-sliced)."""

    def __init__(self, cfg: DataConfig, host_id: int = 0, n_hosts: int = 1):
        if cfg.global_batch % n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.cfg = cfg
        self.host_id = host_id
        self.n_hosts = n_hosts
        self._probs = _zipf_probs(min(cfg.vocab, 8192))

    def batch(self, index: int) -> Dict[str, np.ndarray]:
        """The ``index``-th global batch (this host's slice)."""
        cfg = self.cfg
        per_host = cfg.global_batch // self.n_hosts
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, index, self.host_id])
        )
        rows = []
        for _ in range(per_host):
            rows.append(self._pack_row(rng))
        tokens = np.stack(rows)  # (per_host, seq_len+1)
        return {
            "tokens": tokens[:, :-1].astype(np.int32),
            "targets": tokens[:, 1:].astype(np.int32),
        }

    def _pack_row(self, rng: np.random.Generator) -> np.ndarray:
        cfg = self.cfg
        out = np.empty(cfg.seq_len + 1, dtype=np.int64)
        pos = 0
        while pos < cfg.seq_len + 1:
            remaining = cfg.seq_len + 1 - pos
            doc_len = int(rng.geometric(1.0 / cfg.mean_doc_len))
            doc_len = min(max(doc_len, 4), remaining)
            base = rng.choice(len(self._probs), size=doc_len, p=self._probs)
            # short-range repetition: makes next-token prediction learnable
            rep = rng.random(doc_len) < 0.35
            for i in range(1, doc_len):
                if rep[i]:
                    base[i] = base[i - 1]
            base = base % cfg.vocab
            base[0] = cfg.eos
            out[pos : pos + doc_len] = base
            pos += doc_len
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        i = 0
        while True:
            yield self.batch(i)
            i += 1


def make_batch_shapes(
    family: str,
    global_batch: int,
    seq_len: int,
    d_model: int = 0,
    n_patches: int = 0,
    n_frames: int = 0,
) -> Dict[str, Tuple[Tuple[int, ...], str]]:
    """(shape, dtype) stand-ins per input for ``input_specs()`` (dry-run)."""
    shapes: Dict[str, Tuple[Tuple[int, ...], str]] = {
        "tokens": ((global_batch, seq_len), "int32"),
        "targets": ((global_batch, seq_len), "int32"),
    }
    if family == "vlm":
        shapes["patch_embeds"] = ((global_batch, n_patches, d_model), "bfloat16")
        shapes["mrope_positions"] = ((global_batch, seq_len, 3), "int32")
    if family == "audio":
        shapes["frame_embeds"] = ((global_batch, n_frames, d_model), "bfloat16")
    return shapes
