from .pipeline import DataConfig, SyntheticLM, make_batch_shapes

__all__ = ["DataConfig", "SyntheticLM", "make_batch_shapes"]
