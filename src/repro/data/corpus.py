"""Real-workload kernel corpus: Profiles extracted from the in-repo Pallas stack.

The nine :data:`~repro.core.kernelgen.PAPER_BENCHMARKS` profiles mirror the
paper's hand-picked SHOC/Rodinia kernels.  This module derives a *second*
benchmark corpus nobody hand-picked: for every registered model config
(:mod:`repro.configs`) and both serving phases (prefill + decode), the two
production Pallas kernels — :mod:`repro.kernels.flash_attention` and
:mod:`repro.kernels.mamba2_ssd` — are instantiated at their real launch
geometry and mapped onto a register/shared-memory/instruction-mix
:class:`~repro.core.kernelgen.Profile` the RegDem pipeline can tune.

Extraction model (deterministic, pure arithmetic — golden-pinned in
``tests/golden/corpus_profiles.json``):

* **block geometry** comes from the kernels' own tilers
  (:func:`~repro.kernels.flash_attention.choose_block_sizes`, the SSD
  head-block formula), at the serving shapes of :data:`repro.configs.base.
  SHAPES` (``prefill_32k`` / ``decode_32k``, clamped to per-model limits
  such as whisper's 1500-frame encoder);
* **threads/block** is one thread per q-row (attention) or per head-block
  lane group (SSD), clamped to the launchable [64, 256] range;
* **registers** count the per-thread live state the VMEM scratch holds on
  TPU: the accumulator slice + softmax running max/normalizer + operand
  fragment (attention), or the recurrent-state slice (SSD), plus the
  generator ABI (fixed + const-pool + temps);
* **shared memory** is the per-block share of the operand tiles a GPU
  lowering would stage (kv tile / B,C tile), capped inside the 48 KiB
  per-block limit so demotion still has spill room;
* **instruction mix** follows the kernel bodies: streaming operand loads,
  one store per chunk for SSD, SFU traffic for every ``exp``, predication
  where masking (window/chunk/causal-decode) predicates the inner loop;
* **regdem_target** is the first occupancy cliff
  (:func:`~repro.core.occupancy.spill_targets`) below the extracted
  register count — exactly the paper's §3 target chooser.

The corpus deliberately exercises ranges the synthetic nine never hit:
single-row decode blocks (threads=64, 2-trip loops), 24 KiB static shared
memory next to 80+ registers, and wide-head accumulators.
"""

from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.core.kernelgen import N_FIXED, Profile

#: serving shape cells (mirrors repro.configs.base.SHAPES, serving subset)
PREFILL_SEQ, PREFILL_BATCH = 32_768, 32
DECODE_SEQ, DECODE_BATCH = 32_768, 128

#: whisper limits (encoder frames / decoder positions)
WHISPER_FRAMES, WHISPER_DECODE = 1500, 448


@dataclass(frozen=True)
class KernelInstance:
    """One real Pallas kernel launch: (model config, phase, kernel, shapes)."""

    model: str
    phase: str                     # prefill | decode
    kernel: str                    # attn | ssd
    batch: int
    # attention geometry
    seq_q: int = 0
    seq_kv: int = 0
    heads: int = 0
    dh: int = 0
    window: Optional[int] = None
    chunk: Optional[int] = None
    # ssd geometry
    ssd_heads: int = 0
    ssd_head_dim: int = 0
    ssd_state: int = 0
    ssd_chunk: int = 0
    seq: int = 0

    @property
    def name(self) -> str:
        return f"{self.model}.{self.phase}.{self.kernel}"


def _clamp(x: int, lo: int, hi: int) -> int:
    return max(lo, min(hi, x))


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def _align(x: int, unit: int) -> int:
    return _ceil_div(x, unit) * unit


def _seed_of(name: str) -> int:
    # stable across runs/processes: content-derived, never hash()-derived
    return zlib.crc32(name.encode("utf-8")) % 10_000


# ---------------------------------------------------------------------------
# Launch-geometry enumeration
# ---------------------------------------------------------------------------


def kernel_instances() -> List[KernelInstance]:
    """Every (model config x phase) Pallas kernel launch, in registry order."""
    from repro.configs.base import ARCH_IDS, get_config

    out: List[KernelInstance] = []
    for model in ARCH_IDS:
        cfg = get_config(model)
        attn = cfg.family in ("dense", "moe", "vlm", "audio", "hybrid")
        ssd = cfg.family in ("ssm", "hybrid")
        for phase in ("prefill", "decode"):
            if attn:
                if cfg.family == "audio":
                    # whisper: encoder self-attention at prefill, decoder
                    # cross-attention over the 1500 encoder frames at decode
                    sq = WHISPER_FRAMES if phase == "prefill" else 1
                    skv = WHISPER_FRAMES
                else:
                    sq = PREFILL_SEQ if phase == "prefill" else 1
                    skv = PREFILL_SEQ if phase == "prefill" else DECODE_SEQ
                out.append(
                    KernelInstance(
                        model=model,
                        phase=phase,
                        kernel="attn",
                        batch=PREFILL_BATCH if phase == "prefill" else DECODE_BATCH,
                        seq_q=sq,
                        seq_kv=skv,
                        heads=cfg.n_heads,
                        dh=cfg.dh,
                        window=cfg.window,
                        chunk=cfg.attn_chunk,
                    )
                )
            if ssd:
                out.append(
                    KernelInstance(
                        model=model,
                        phase=phase,
                        kernel="ssd",
                        batch=PREFILL_BATCH if phase == "prefill" else DECODE_BATCH,
                        ssd_heads=cfg.ssm_heads,
                        ssd_head_dim=cfg.ssm_head_dim,
                        ssd_state=cfg.ssm_state,
                        ssd_chunk=cfg.ssm_chunk,
                        seq=PREFILL_SEQ if phase == "prefill" else cfg.ssm_chunk,
                    )
                )
    return out


# ---------------------------------------------------------------------------
# Profile extraction
# ---------------------------------------------------------------------------


def _finish(name: str, target: int, threads: int, num_blocks: int,
            smem: int, **mix) -> Profile:
    """Common tail: pick the occupancy-cliff demotion target (§3) and the
    nvcc-spill stand-in, then assemble the Profile."""
    from repro.core.occupancy import spill_targets

    # only cliffs strictly below the extracted count are real demotion
    # targets (spill_targets floors at 32, which can sit *above* a small
    # decode kernel's register count — flushed by the first corpus sweep)
    targets = [t for t in spill_targets(target, threads, smem) if t < target]
    regdem_target = targets[0] if targets else max(target - 6, 24)
    nvcc_spills = min(10, max(0, (target - regdem_target) // 3))
    return Profile(
        name=name,
        target_regs=target,
        threads_per_block=threads,
        num_blocks=num_blocks,
        shared_size=smem,
        regdem_target=regdem_target,
        nvcc_spills=nvcc_spills,
        seed=_seed_of(name),
        **mix,
    )


def extract_profile(inst: KernelInstance) -> Profile:
    """Map one real kernel launch onto a RegDem generation profile."""
    if inst.kernel == "attn":
        return _extract_attention(inst)
    return _extract_ssd(inst)


def _extract_attention(inst: KernelInstance) -> Profile:
    from repro.kernels.flash_attention import choose_block_sizes

    bq, bkv = choose_block_sizes(inst.seq_q, inst.seq_kv, inst.dh)
    # one thread per q row of the block, floored at two warps
    threads = _clamp(bq, 64, 256)
    q_blocks = _ceil_div(inst.seq_q, bq)
    num_blocks = _clamp(inst.batch * inst.heads * q_blocks, 8, 65_535)
    trips = _clamp(_ceil_div(inst.seq_kv, bkv), 2, 24)
    # per-thread online-softmax state: the acc slice (f32 words of the
    # (bq, dh) accumulator owned by this thread), m/l, and a q fragment
    acc_words = _clamp((bq * inst.dh) // (threads * 4), 6, 56)
    qfrag = _clamp(inst.dh // 32, 2, 8)
    n_state = acc_words + qfrag + 2
    n_consts, n_temps = 8, 6
    target = N_FIXED + n_consts + n_temps + n_state
    # kv-tile stage: the per-block share of the k+v operand tiles (1/16th,
    # the per-warp slice), capped to leave spill room under the 48 KiB limit
    smem = min(24_576, _align(2 * bkv * inst.dh * 2 // 16, 256))
    masked = inst.window is not None or inst.chunk is not None
    return _finish(
        inst.name, target, threads, num_blocks, smem,
        loop_trips=trips,
        n_consts=n_consts,
        n_temps=n_temps,
        loads_per_iter=2 + (inst.dh > 64),    # k tile + v tile (+wide second beat)
        stores_per_iter=1 if inst.phase == "prefill" else 0,
        smem_ops_per_iter=2,                  # stage/consume the kv tile
        sfu_per_iter=1 + masked,              # exp (+ mask-boundary recompute)
        predicated=masked or inst.phase == "decode",
    )


def _extract_ssd(inst: KernelInstance) -> Profile:
    P, N, H = inst.ssd_head_dim, inst.ssd_state, inst.ssd_heads
    # the kernel's own head-block formula (ssd_pallas): largest head block
    # whose f32 state fits the 8 MiB scratch share, rounded to divide H
    hb = min(H, max(1, (8 * 1024 * 1024) // (P * N * 4)))
    while H % hb:
        hb -= 1
    threads = _clamp(_align(hb * 4, 32), 64, 256)
    n_chunks = _ceil_div(inst.seq, inst.ssd_chunk)
    num_blocks = _clamp(inst.batch * (H // hb), 8, 65_535)
    trips = _clamp(n_chunks, 2, 24)
    # per-thread slice of the (hb, P, N) recurrent state + decay scalars
    state_words = _clamp((hb * P * N) // (threads * 32), 10, 56)
    n_state = state_words + 4
    n_consts, n_temps = 8, 8
    target = N_FIXED + n_consts + n_temps + n_state
    # B/C tile stage: per-block share of the (chunk, N) operand tiles
    smem = min(16_384, _align(2 * inst.ssd_chunk * N * 4 // 8, 256))
    return _finish(
        inst.name, target, threads, num_blocks, smem,
        loop_trips=trips,
        n_consts=n_consts,
        n_temps=n_temps,
        loads_per_iter=3,                     # x, B, C tiles
        stores_per_iter=1,                    # y written back per chunk
        smem_ops_per_iter=2,                  # stage/consume the B/C tiles
        sfu_per_iter=2,                       # exp(segsum), exp(decay)
        predicated=False,
    )


# ---------------------------------------------------------------------------
# The corpus
# ---------------------------------------------------------------------------


def corpus_profiles() -> Dict[str, Profile]:
    """name -> Profile for every real kernel launch (the corpus)."""
    return {inst.name: extract_profile(inst) for inst in kernel_instances()}


#: the second benchmark corpus, alongside kernelgen.PAPER_BENCHMARKS
CORPUS_BENCHMARKS: Dict[str, Profile] = corpus_profiles()


def corpus_kernel(name: str):
    """Generate + schedule one corpus kernel (like ``paper_kernel``)."""
    from repro.core.kernelgen import generate

    return generate(CORPUS_BENCHMARKS[name])


def all_corpus_kernels() -> Dict[str, object]:
    from repro.core.kernelgen import generate

    return {name: generate(p) for name, p in CORPUS_BENCHMARKS.items()}


def model_corpus_names(model: str) -> List[str]:
    """The corpus kernels one model config's serving path launches."""
    names = [n for n in CORPUS_BENCHMARKS if n.split(".", 1)[0] == model]
    if not names:
        known = sorted({n.split(".", 1)[0] for n in CORPUS_BENCHMARKS})
        raise KeyError(f"no corpus kernels for model {model!r} (known: {known})")
    return names


def corpus_container(model: str, arch: str = "maxwell") -> bytes:
    """Multi-kernel container bytes for one model config's corpus kernels —
    the payload the tune-and-serve path feeds ``TranslationService.tune``."""
    from repro.arch import retarget
    from repro.binary import container
    from repro.core.kernelgen import generate

    kernels = []
    for name in model_corpus_names(model):
        k = generate(CORPUS_BENCHMARKS[name])
        kernels.append(k if arch == "maxwell" else retarget(k, arch))
    return container.dumps(kernels)
