"""Maxwell-like abstract GPU ISA.

RegDem (the paper) operates on NVIDIA SASS extracted from .cubin files via
MaxAs.  nvcc/SASS are unavailable here, so the faithful reproduction runs on
an abstract ISA that preserves every property the RegDem algorithm touches:

* 32-bit general registers ``R0..R254`` plus the zero register ``RZ``;
  kernel register usage is charged by the *highest used register number + 1*
  (paper §3, challenge 5);
* multi-word (64-bit) values occupy an *aligned* even/odd register pair and
  create register aliases (challenge 3);
* the Maxwell control word: per-instruction stall count, yield flag, a write
  barrier index, a read barrier index and a 6-bit wait mask over the six
  hardware scoreboard barriers (challenge 4);
* a 4-bank register file (``bank = reg % 4``; same-instruction same-bank
  source operands serialize — challenge 6);
* 32 x 4-byte shared memory banks (challenge 1);
* opcode classes with distinct latencies and per-SM throughputs (used by the
  performance predictor, paper §4 eq. 2).

The module also provides basic-block / CFG construction and a scalar
interpreter used to prove that binary translation preserves dataflow
semantics (the correctness oracle for :mod:`repro.core.regdem`).
"""

from __future__ import annotations

import enum
import math
import re
from dataclasses import dataclass, field
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

# ---------------------------------------------------------------------------
# Registers
# ---------------------------------------------------------------------------

#: Zero register number (reads as 0, writes are discarded) — SASS ``RZ``.
RZ: int = 255

#: Number of general-purpose register banks on Maxwell.
NUM_REG_BANKS: int = 4

#: Number of 4-byte shared-memory banks.
NUM_SMEM_BANKS: int = 32

#: Number of hardware scoreboard ("instruction") barriers on Maxwell/Pascal.
NUM_BARRIERS: int = 6

#: Stall latencies used by the paper (§3.2): device/global memory and shared
#: memory access latencies in cycles.
GL_MEM_STALL: int = 200
SH_MEM_STALL: int = 24


def reg_bank(reg: int) -> int:
    """Register-file bank of ``reg`` (Maxwell: 4 banks, ``reg % 4``)."""
    return reg % NUM_REG_BANKS


def smem_bank(byte_addr: int) -> int:
    """Shared-memory bank of a byte address (32 banks of 4-byte words)."""
    return (byte_addr // 4) % NUM_SMEM_BANKS


# ---------------------------------------------------------------------------
# Opcode metadata
# ---------------------------------------------------------------------------


class OpClass(enum.Enum):
    """Functional-unit class of an opcode.

    ``throughput`` is instructions/cycle/SM (Maxwell GM200 numbers used by the
    paper: 128 FP32 cores, 4 FP64 cores, 32 LSU lanes, 32 SFU lanes).
    ``latency`` is the producer->consumer latency in cycles.
    """

    FP32 = ("fp32", 128, 6)
    INT = ("int", 128, 6)
    FP64 = ("fp64", 4, 48)
    SFU = ("sfu", 32, 20)
    LSU_GLOBAL = ("lsu_global", 32, GL_MEM_STALL)
    LSU_SHARED = ("lsu_shared", 32, SH_MEM_STALL)
    LSU_LOCAL = ("lsu_local", 32, GL_MEM_STALL)
    CONTROL = ("control", 128, 6)
    MISC = ("misc", 32, 20)

    def __init__(self, tag: str, throughput: int, latency: int):
        self.tag = tag
        self.throughput = throughput
        self.latency = latency


#: Maximum per-SM instruction throughput (FP32 cores) — eq. 2 in the paper.
MAX_THROUGHPUT: int = 128


@dataclass(frozen=True)
class OpInfo:
    """Static description of one opcode."""

    name: str
    klass: OpClass
    #: number of destination registers (before widening for 64-bit ops)
    n_dst: int
    #: number of source register operands
    n_src: int
    #: 32-bit words per register operand (2 => aligned even/odd pair)
    width: int = 1
    is_load: bool = False
    is_store: bool = False
    is_branch: bool = False
    is_exit: bool = False
    #: FLOPs contributed per thread (for roofline-style accounting)
    flops: int = 0

    @property
    def is_memory(self) -> bool:
        return self.is_load or self.is_store

    @property
    def needs_write_barrier(self) -> bool:
        """Variable-latency result => consumer must wait on a write barrier."""
        return self.is_load or self.klass in (OpClass.FP64, OpClass.SFU)

    @property
    def needs_read_barrier(self) -> bool:
        """Stores hold source operands in flight => write-after-read hazard."""
        return self.is_store


def _op(name, klass, n_dst, n_src, **kw) -> Tuple[str, OpInfo]:
    return name, OpInfo(name, klass, n_dst, n_src, **kw)


#: The opcode table.  A compact but representative subset of Maxwell SASS.
OPCODES: Dict[str, OpInfo] = dict(
    [
        # 32-bit floating point
        _op("FADD", OpClass.FP32, 1, 2, flops=1),
        _op("FMUL", OpClass.FP32, 1, 2, flops=1),
        _op("FFMA", OpClass.FP32, 1, 3, flops=2),
        _op("FMNMX", OpClass.FP32, 1, 2, flops=1),
        # integer
        _op("IADD", OpClass.INT, 1, 2),
        _op("ISCADD", OpClass.INT, 1, 2),  # a*imm + b (shift-add)
        _op("XMAD", OpClass.INT, 1, 3),  # 16x16+32 multiply-add
        _op("LOP", OpClass.INT, 1, 2),  # logic op (AND)
        _op("SHL", OpClass.INT, 1, 1),
        _op("SHR", OpClass.INT, 1, 1),
        _op("MOV", OpClass.INT, 1, 1),
        _op("MOV32I", OpClass.INT, 1, 0),
        _op("ISETP", OpClass.INT, 0, 2),  # writes predicate, not a register
        # 64-bit floating point (register pairs)
        _op("DADD", OpClass.FP64, 1, 2, width=2, flops=1),
        _op("DMUL", OpClass.FP64, 1, 2, width=2, flops=1),
        _op("DFMA", OpClass.FP64, 1, 3, width=2, flops=2),
        # special function unit
        _op("MUFU", OpClass.SFU, 1, 1, flops=1),  # rcp/sqrt/exp family
        # memory
        _op("LDG", OpClass.LSU_GLOBAL, 1, 1, is_load=True),
        _op("STG", OpClass.LSU_GLOBAL, 0, 2, is_store=True),
        _op("LDG64", OpClass.LSU_GLOBAL, 1, 1, width=2, is_load=True),
        _op("STG64", OpClass.LSU_GLOBAL, 0, 2, width=2, is_store=True),
        _op("LDS", OpClass.LSU_SHARED, 1, 1, is_load=True),
        _op("STS", OpClass.LSU_SHARED, 0, 2, is_store=True),
        _op("LDL", OpClass.LSU_LOCAL, 1, 1, is_load=True),
        _op("STL", OpClass.LSU_LOCAL, 0, 2, is_store=True),
        # misc / control
        _op("S2R", OpClass.MISC, 1, 0),  # read special register (tid etc.)
        _op("BRA", OpClass.CONTROL, 0, 0, is_branch=True),
        _op("EXIT", OpClass.CONTROL, 0, 0, is_exit=True),
        _op("NOP", OpClass.CONTROL, 0, 0),
        _op("BAR", OpClass.CONTROL, 0, 0),  # __syncthreads
        # warp-level register resource sharing (arXiv 1503.05694): loads and
        # stores against the co-scheduled warps' shared demoted-slot pool.
        # MISC class: near-register-file port, cheaper than the smem path.
        # Appended after the original table so every pre-existing opcode id
        # (container encodings, CRCs) is unchanged.
        _op("LDP", OpClass.MISC, 1, 1, is_load=True),
        _op("STP", OpClass.MISC, 0, 2, is_store=True),
        # compressed spill slots (arXiv 2006.05693): static pack/unpack of a
        # demoted value around its shared-memory slot (ALU for smem bytes)
        _op("PCK", OpClass.INT, 1, 1),
        _op("UPCK", OpClass.INT, 1, 1),
    ]
)


# ---------------------------------------------------------------------------
# Control information (the Maxwell control word)
# ---------------------------------------------------------------------------


@dataclass
class Ctrl:
    """Per-instruction scheduling control (MaxAs-style).

    ``stall``      issue-stall cycles before the next instruction.
    ``yield_flag`` allow the scheduler to switch warps.
    ``write_bar``  barrier index signalled when the result is written.
    ``read_bar``   barrier index signalled when operands have been read.
    ``wait``       set of barrier indices this instruction waits on.
    """

    stall: int = 1
    yield_flag: bool = False
    write_bar: Optional[int] = None
    read_bar: Optional[int] = None
    wait: Set[int] = field(default_factory=set)

    def copy(self) -> "Ctrl":
        return Ctrl(self.stall, self.yield_flag, self.write_bar, self.read_bar, set(self.wait))

    def encode(self) -> str:
        """MaxAs-like control string ``wait:read:write:yield:stall``."""
        wmask = sum(1 << b for b in self.wait)
        rb = "-" if self.read_bar is None else str(self.read_bar)
        wb = "-" if self.write_bar is None else str(self.write_bar)
        y = "Y" if self.yield_flag else "-"
        return f"{wmask:02x}:{rb}:{wb}:{y}:{self.stall:x}"


# ---------------------------------------------------------------------------
# Instructions
# ---------------------------------------------------------------------------


_UID = [0]


def _next_uid() -> int:
    _UID[0] += 1
    return _UID[0]


#: Instr fields whose assignment invalidates the per-instruction operand
#: cache (opcode metadata, register words, bank conflicts).
_OPERAND_FIELDS = frozenset(("op", "dsts", "srcs"))


class _OperandList(list):
    """A list that invalidates its owning Instr's operand cache on mutation.

    ``dsts``/``srcs`` keep full list semantics (``ins.dsts == [r]``,
    ``.append`` in the parser, ...), but in-place mutation after the cache
    has been read cannot leave it stale."""

    __slots__ = ("_owner",)

    def __init__(self, iterable=(), owner=None):
        super().__init__(iterable)
        self._owner = owner


def _invalidating(name):
    base = getattr(list, name)

    def method(self, *args, **kwargs):
        # getattr guard: pickle restores list items before the _owner slot
        owner = getattr(self, "_owner", None)
        if owner is not None:
            object.__setattr__(owner, "_opc", None)
        return base(self, *args, **kwargs)

    method.__name__ = name
    return method


for _m in (
    "__setitem__", "__delitem__", "__iadd__", "__imul__",
    "append", "extend", "insert", "pop", "remove", "clear", "sort", "reverse",
):
    setattr(_OperandList, _m, _invalidating(_m))
del _m


@dataclass
class Instr:
    """One machine instruction.

    ``dsts``/``srcs`` are *leading* register numbers; for ``width == 2``
    opcodes the odd alias ``r+1`` is implicitly used as well (see
    :meth:`dst_words` / :meth:`src_words`).  Memory ops carry an address
    register in ``srcs[0]`` (loads) / ``srcs[0]`` plus value ``srcs[1]``
    (stores) and an immediate byte ``offset``.

    Derived operand metadata (:attr:`info`, :meth:`dst_words`,
    :meth:`src_words`, :meth:`reg_bank_conflicts`) is computed once per
    static instruction and cached; assignment to ``op``/``dsts``/``srcs``
    and in-place mutation of the operand lists (wrapped in
    :class:`_OperandList`) both invalidate the cache.
    """

    op: str
    dsts: List[int] = field(default_factory=list)
    srcs: List[int] = field(default_factory=list)
    imm: Optional[float] = None
    offset: int = 0
    #: branch target label name (BRA)
    target: Optional[str] = None
    #: predicate register index (None = unpredicated); negated if pred_neg
    pred: Optional[int] = None
    pred_neg: bool = False
    #: destination predicate (ISETP)
    pdst: Optional[int] = None
    ctrl: Ctrl = field(default_factory=Ctrl)
    #: trip count metadata for backward branches (set by kernelgen; used by
    #: the timing simulator and the CFG loop analysis)
    trip_count: Optional[int] = None
    #: provenance tag: "orig" | "demoted_load" | "demoted_store" | "remat"
    #: | "spill_load" | "spill_store"
    tag: str = "orig"
    uid: int = field(default_factory=_next_uid)

    # -- operand cache -------------------------------------------------------

    def __setattr__(self, name: str, value) -> None:
        if name in _OPERAND_FIELDS:
            if isinstance(value, list):
                value = _OperandList(value, self)
            object.__setattr__(self, name, value)
            object.__setattr__(self, "_opc", None)
        else:
            object.__setattr__(self, name, value)

    def _operand_cache(self) -> tuple:
        """(info, dst_words, src_words, bank_conflicts), computed lazily."""
        info = OPCODES[self.op]
        dw: List[int] = []
        for r in self.dsts:
            if r == RZ:
                continue
            dw.extend(range(r, r + info.width))
        sw: List[int] = []
        w = info.width
        is_memory = info.is_memory
        for i, r in enumerate(self.srcs):
            if r == RZ:
                continue
            # address operands of wide memory ops are still 32-bit
            if is_memory and i == 0:
                sw.append(r)
            else:
                sw.extend(range(r, r + w))
        banks: Dict[int, Set[int]] = {}
        for r in set(sw):
            banks.setdefault(reg_bank(r), set()).add(r)
        conflicts = sum(len(v) - 1 for v in banks.values())
        # width-map contributions: leading reg -> operand width, with the
        # address operand of memory ops pinned to width 1 (it stays 32-bit
        # even for wide loads/stores)
        went: List[Tuple[int, int]] = []
        for r in self.dsts:
            if r != RZ:
                went.append((r, w))
        for i, r in enumerate(self.srcs):
            if r != RZ:
                went.append((r, 1 if (is_memory and i == 0) else w))
        lead = frozenset(r for r in self.dsts + self.srcs if r != RZ)
        allw = frozenset(dw + sw)
        cache = (info, tuple(dw), tuple(sw), conflicts, tuple(went), lead, allw)
        object.__setattr__(self, "_opc", cache)
        return cache

    # -- static metadata ----------------------------------------------------

    @property
    def info(self) -> OpInfo:
        c = self._opc
        return (c or self._operand_cache())[0]

    @property
    def is_label(self) -> bool:
        return False

    # -- register accessors (alias-aware) ------------------------------------

    def dst_words(self) -> Tuple[int, ...]:
        """All destination register words including 64-bit aliases."""
        c = self._opc
        return (c or self._operand_cache())[1]

    def src_words(self) -> Tuple[int, ...]:
        c = self._opc
        return (c or self._operand_cache())[2]

    def regs(self) -> FrozenSet[int]:
        c = self._opc
        return (c or self._operand_cache())[6]

    def width_entries(self) -> Tuple[Tuple[int, int], ...]:
        """(reg, width) width-map contributions of this instruction."""
        c = self._opc
        return (c or self._operand_cache())[4]

    def leading_regs(self) -> FrozenSet[int]:
        c = self._opc
        return (c or self._operand_cache())[5]

    def uses(self, reg: int) -> bool:
        return reg in self.regs()

    def rename(self, old: int, new: int) -> None:
        """Rename a *leading* register operand everywhere it appears."""
        self.dsts = [new if r == old else r for r in self.dsts]
        self.srcs = [new if r == old else r for r in self.srcs]

    # -- register bank conflicts ---------------------------------------------

    def reg_bank_conflicts(self) -> int:
        """Number of serialized extra cycles from same-bank source operands."""
        c = self._opc
        return (c or self._operand_cache())[3]

    # -- printing -------------------------------------------------------------

    def render(self) -> str:
        parts = []
        if self.pred is not None:
            parts.append(f"@{'!' if self.pred_neg else ''}P{self.pred}")
        ops: List[str] = []
        if self.pdst is not None:
            ops.append(f"P{self.pdst}")
        info = self.info
        for r in self.dsts:
            ops.append(_rname(r))
        if info.is_load:
            ops.append(f"[{_rname(self.srcs[0])}+{self.offset:#x}]")
        elif info.is_store:
            ops.append(f"[{_rname(self.srcs[0])}+{self.offset:#x}]")
            ops.extend(_rname(r) for r in self.srcs[1:])
        else:
            ops.extend(_rname(r) for r in self.srcs)
        if self.imm is not None:
            ops.append(repr(self.imm))
        if self.target is not None:
            ops.append(self.target)
        parts.append(f"{self.op} {', '.join(ops)};")
        return f"/*{self.ctrl.encode()}*/ {' '.join(parts)}"


def _rname(r: int) -> str:
    return "RZ" if r == RZ else f"R{r}"


@dataclass
class Label:
    """Pseudo-instruction: a branch target."""

    name: str
    uid: int = field(default_factory=_next_uid)

    @property
    def is_label(self) -> bool:
        return True

    def render(self) -> str:
        return f"{self.name}:"


Item = object  # Instr | Label


# ---------------------------------------------------------------------------
# Kernel container
# ---------------------------------------------------------------------------


@dataclass
class Kernel:
    """A GPU kernel: an instruction stream plus launch geometry.

    ``shared_size``   statically allocated shared memory bytes (programmer's).
    ``demoted_size``  dynamically allocated bytes appended by RegDem.
    """

    name: str
    items: List[Item] = field(default_factory=list)
    threads_per_block: int = 256
    num_blocks: int = 1024
    shared_size: int = 0
    demoted_size: int = 0
    #: registers holding kernel parameters / thread id at entry (live-in)
    live_in: Set[int] = field(default_factory=set)
    #: registers whose final value is the kernel's observable output
    live_out: Set[int] = field(default_factory=set)
    #: RDA register (demoted base address) once RegDem reserved it
    rda: Optional[int] = None
    #: target architecture, a :mod:`repro.arch` registry name.  Everything
    #: arch-specific (codec, scheduler latencies, occupancy limits, spill
    #: budget) resolves through this tag.
    arch: str = "maxwell"

    # -- basic queries --------------------------------------------------------

    def instructions(self) -> List[Instr]:
        return [it for it in self.items if isinstance(it, Instr)]

    def used_registers(self) -> Set[int]:
        used: Set[int] = set(self.live_in) | set(self.live_out)
        for ins in self.instructions():
            used |= ins.regs()
        used.discard(RZ)
        return used

    @property
    def reg_count(self) -> int:
        """Architectural register usage: highest used register number + 1."""
        used = self.used_registers()
        return (max(used) + 1) if used else 0

    @property
    def total_shared(self) -> int:
        return self.shared_size + self.demoted_size

    def copy(self) -> "Kernel":
        k = Kernel(
            name=self.name,
            items=[],
            threads_per_block=self.threads_per_block,
            num_blocks=self.num_blocks,
            shared_size=self.shared_size,
            demoted_size=self.demoted_size,
            live_in=set(self.live_in),
            live_out=set(self.live_out),
            rda=self.rda,
            arch=self.arch,
        )
        items = k.items
        for it in self.items:
            if isinstance(it, Instr):
                # positional construction (fields in declaration order);
                # dataclasses.replace costs a kwargs dict + field walk per
                # instruction, which dominates copy() on the search hot path
                items.append(
                    Instr(
                        it.op, list(it.dsts), list(it.srcs), it.imm,
                        it.offset, it.target, it.pred, it.pred_neg,
                        it.pdst, it.ctrl.copy(), it.trip_count, it.tag,
                    )
                )
            else:
                items.append(Label(it.name, uid=_next_uid()))
        return k

    def render(self) -> str:
        # the arch tag is printed only off-default so that Maxwell kernels
        # render byte-identically to the pre-registry layout
        arch_tag = "" if self.arch == "maxwell" else f" arch={self.arch}"
        lines = [
            f"// kernel {self.name}  regs={self.reg_count} "
            f"threads/block={self.threads_per_block} smem={self.shared_size}"
            f"+{self.demoted_size}B{arch_tag}"
        ]
        for it in self.items:
            pad = "" if isinstance(it, Label) else "    "
            lines.append(pad + it.render())
        return "\n".join(lines)

    # -- static instruction counts (used by candidate strategies) ------------

    def static_access_counts(self) -> Dict[int, int]:
        counts: Dict[int, int] = {}
        for ins in self.instructions():
            for r in ins.leading_regs():
                counts[r] = counts.get(r, 0) + 1
        return counts


# ---------------------------------------------------------------------------
# SASS-text round trip
# ---------------------------------------------------------------------------

_INS_RE = re.compile(
    r"^/\*(?P<ctrl>[0-9a-f]{2}:[0-5\-]:[0-5\-]:[Y\-]:[0-9a-f])\*/\s*"
    r"(?:@(?P<neg>!)?P(?P<pred>\d)\s+)?(?P<body>.+);$"
)


def parse_ctrl(text: str) -> Ctrl:
    wmask_s, rb, wb, y, stall = text.split(":")
    wmask = int(wmask_s, 16)
    return Ctrl(
        stall=int(stall, 16),
        yield_flag=(y == "Y"),
        write_bar=None if wb == "-" else int(wb),
        read_bar=None if rb == "-" else int(rb),
        wait={b for b in range(NUM_BARRIERS) if wmask & (1 << b)},
    )


def parse_kernel(text: str, **kernel_kwargs) -> Kernel:
    """Parse the output of :meth:`Kernel.render` back into a Kernel.

    This is the pyReDe "disassembler" direction; :meth:`Kernel.render` is the
    assembler direction.  ``render(parse(render(k))) == render(k)`` is tested.
    """
    k = Kernel(name="parsed", **kernel_kwargs)
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            if line.startswith("// kernel"):
                toks = line.split()
                k.name = toks[2]
                for tok in toks[3:]:
                    if tok.startswith("arch="):
                        k.arch = tok[len("arch="):]
            continue
        if line.endswith(":") and not line.startswith("/*"):
            k.items.append(Label(line[:-1]))
            continue
        m = _INS_RE.match(line)
        if not m:
            raise ValueError(f"unparseable SASS line: {line!r}")
        ctrl = parse_ctrl(m.group("ctrl"))
        body = m.group("body")
        opname, _, rest = body.partition(" ")
        info = OPCODES[opname]
        ins = Instr(op=opname, ctrl=ctrl)
        if m.group("pred") is not None:
            ins.pred = int(m.group("pred"))
            ins.pred_neg = m.group("neg") == "!"
        toks = [t.strip() for t in rest.split(",")] if rest else []
        toks = [t for t in toks if t]

        def reg_of(tok: str) -> int:
            return RZ if tok == "RZ" else int(tok[1:])

        i = 0
        if toks and toks[0].startswith("P") and info.n_dst == 0 and opname == "ISETP":
            ins.pdst = int(toks[0][1:])
            i = 1
        for _ in range(info.n_dst):
            ins.dsts.append(reg_of(toks[i]))
            i += 1
        if info.is_memory:
            mtok = toks[i]
            i += 1
            mm = re.match(r"\[(R\d+|RZ)\+(0x[0-9a-f]+|\d+)\]", mtok)
            assert mm, mtok
            ins.srcs.append(reg_of(mm.group(1)))
            ins.offset = int(mm.group(2), 0)
        while i < len(toks):
            t = toks[i]
            if t.startswith("R") and (t == "RZ" or t[1:].isdigit()):
                ins.srcs.append(reg_of(t))
            elif t.startswith(".L") or t.startswith("L"):
                ins.target = t
            else:
                ins.imm = float(t)
            i += 1
        k.items.append(ins)
    return k


# ---------------------------------------------------------------------------
# CFG
# ---------------------------------------------------------------------------


@dataclass
class BasicBlock:
    index: int
    label: Optional[str]
    instrs: List[Instr] = field(default_factory=list)
    succs: List[int] = field(default_factory=list)
    preds: List[int] = field(default_factory=list)
    #: loop nesting depth (0 = not in a loop), filled by find_loops
    loop_depth: int = 0


class CFG:
    """Basic blocks + edges for a :class:`Kernel`.

    Blocks split at labels and after branches/exits, exactly the granularity
    the barrier tracker needs ("barriers are cleared before jump instructions,
    and hence can only span basic blocks" — paper §3.2).
    """

    def __init__(self, kernel: Kernel):
        self.kernel = kernel
        self.blocks: List[BasicBlock] = []
        self._build()
        self._find_loops()

    def _build(self) -> None:
        label_block: Dict[str, int] = {}
        cur = BasicBlock(0, None)
        self.blocks = [cur]

        def new_block(label: Optional[str]) -> BasicBlock:
            blk = BasicBlock(len(self.blocks), label)
            self.blocks.append(blk)
            return blk

        for it in self.kernel.items:
            if isinstance(it, Label):
                if cur.instrs or cur.label is not None:
                    nxt = new_block(it.name)
                    cur = nxt
                else:
                    cur.label = it.name
                label_block[it.name] = cur.index
            else:
                cur.instrs.append(it)
                if it.info.is_branch or it.info.is_exit:
                    cur = new_block(None)
        if not self.blocks[-1].instrs and self.blocks[-1].label is None and len(self.blocks) > 1:
            self.blocks.pop()

        # edges
        for i, blk in enumerate(self.blocks):
            last = blk.instrs[-1] if blk.instrs else None
            fallthrough = i + 1 < len(self.blocks)
            if last is not None and last.info.is_exit:
                continue
            if last is not None and last.info.is_branch:
                tgt = label_block.get(last.target)
                if tgt is not None:
                    blk.succs.append(tgt)
                if last.pred is not None and fallthrough:
                    blk.succs.append(i + 1)
            elif fallthrough:
                blk.succs.append(i + 1)
        for blk in self.blocks:
            for s in blk.succs:
                self.blocks[s].preds.append(blk.index)

    def _find_loops(self) -> None:
        """Mark loop bodies via back edges (succ index <= block index)."""
        for blk in self.blocks:
            for s in blk.succs:
                if s <= blk.index:  # back edge -> natural loop [s, blk]
                    for b in self.blocks[s : blk.index + 1]:
                        b.loop_depth += 1

    def block_of(self, ins: Instr) -> Optional[BasicBlock]:
        for blk in self.blocks:
            if any(i.uid == ins.uid for i in blk.instrs):
                return blk
        return None


# ---------------------------------------------------------------------------
# Liveness (per-block, backwards) — used by value-register substitution
# ---------------------------------------------------------------------------


def liveness(kernel: Kernel) -> Dict[int, Tuple[Set[int], Set[int]]]:
    """Per-block (live_in, live_out) register word sets via fixpoint."""
    cfg = CFG(kernel)
    use: Dict[int, Set[int]] = {}
    defs: Dict[int, Set[int]] = {}
    for blk in cfg.blocks:
        u: Set[int] = set()
        d: Set[int] = set()
        for ins in blk.instrs:
            for r in ins.src_words():
                if r not in d:
                    u.add(r)
            d |= set(ins.dst_words())
        use[blk.index] = u
        defs[blk.index] = d

    live_in: Dict[int, Set[int]] = {b.index: set() for b in cfg.blocks}
    live_out: Dict[int, Set[int]] = {b.index: set() for b in cfg.blocks}
    # kernel outputs are live at exit blocks
    exit_blocks = [
        b.index for b in cfg.blocks if any(i.info.is_exit for i in b.instrs)
    ] or [cfg.blocks[-1].index]
    changed = True
    while changed:
        changed = False
        for blk in reversed(cfg.blocks):
            out: Set[int] = set()
            for s in blk.succs:
                out |= live_in[s]
            if blk.index in exit_blocks:
                out |= set(kernel.live_out)
            inn = use[blk.index] | (out - defs[blk.index])
            if out != live_out[blk.index] or inn != live_in[blk.index]:
                live_out[blk.index] = out
                live_in[blk.index] = inn
                changed = True
    return {b.index: (live_in[b.index], live_out[b.index]) for b in cfg.blocks}


# ---------------------------------------------------------------------------
# Scalar interpreter (dataflow-equivalence oracle)
# ---------------------------------------------------------------------------


class Interp:
    """Executes a kernel for ONE representative thread with concrete values.

    Used to verify that translated kernels compute the same ``live_out``
    values and the same global-store stream as the original.  Demoted
    registers live in per-thread shared-memory words (eq. 1 guarantees each
    thread owns a private word per demoted register), so a scalar execution
    is a sound equivalence check for RegDem's transformations.
    """

    MAX_STEPS = 2_000_000

    def __init__(self, kernel: Kernel, tid: int = 0):
        self.k = kernel
        self.tid = tid
        self.regs: Dict[int, float] = {RZ: 0.0}
        self.preds: Dict[int, bool] = {}
        self.smem: Dict[int, float] = {}
        self.lmem: Dict[int, float] = {}
        self.gmem: Dict[int, float] = {}
        #: warp-shared demoted-slot pool (LDP/STP).  Scalar execution models
        #: one thread, whose pool slots are private by construction — the
        #: per-warp sharing is an occupancy/latency property, not a dataflow
        #: one (co-scheduled warps never alias each other's slots).
        self.pmem: Dict[int, float] = {}
        self.stores: List[Tuple[int, float]] = []

    def run(self, inputs: Dict[int, float], gmem: Optional[Dict[int, float]] = None):
        self.regs.update(inputs)
        if gmem:
            self.gmem.update(gmem)
        labels = {
            it.name: i for i, it in enumerate(self.k.items) if isinstance(it, Label)
        }
        pc = 0
        steps = 0
        trip_counters: Dict[int, int] = {}
        while pc < len(self.k.items):
            steps += 1
            if steps > self.MAX_STEPS:
                raise RuntimeError("interpreter step limit exceeded")
            it = self.k.items[pc]
            if isinstance(it, Label):
                pc += 1
                continue
            ins: Instr = it
            if ins.pred is not None:
                pval = self.preds.get(ins.pred, False)
                if ins.pred_neg:
                    pval = not pval
                if not pval:
                    pc += 1
                    continue
            if ins.info.is_exit:
                break
            if ins.info.is_branch:
                tgt = labels[ins.target]
                if ins.trip_count is not None and tgt < pc:
                    # counted loop: honour the metadata trip count so that
                    # kernels without full index arithmetic still terminate.
                    n = trip_counters.get(ins.uid, 0) + 1
                    trip_counters[ins.uid] = n
                    if n < ins.trip_count:
                        pc = tgt
                    else:
                        trip_counters[ins.uid] = 0
                        pc += 1
                else:
                    pc = tgt
                continue
            self._exec(ins)
            pc += 1
        return {r: self.regs.get(r, 0.0) for r in self.k.live_out}

    # -- semantics ------------------------------------------------------------

    def _r(self, r: int) -> float:
        return 0.0 if r == RZ else self.regs.get(r, 0.0)

    def _w(self, r: int, v: float) -> None:
        if r != RZ:
            self.regs[r] = v

    def _r64(self, r: int) -> float:
        return self._r(r)  # value carried in leading word; alias is shadow

    def _w64(self, r: int, v: float) -> None:
        self._w(r, v)
        self._w(r + 1, _alias_marker(v))

    def _exec(self, ins: Instr) -> None:
        op = ins.op
        s = ins.srcs
        imm = ins.imm if ins.imm is not None else 0.0
        if op in ("FADD", "IADD"):
            self._w(ins.dsts[0], self._r(s[0]) + (self._r(s[1]) if len(s) > 1 else imm))
        elif op == "ISCADD":
            self._w(ins.dsts[0], self._r(s[0]) * (2 ** int(imm)) + self._r(s[1]))
        elif op == "FMUL":
            self._w(ins.dsts[0], self._r(s[0]) * (self._r(s[1]) if len(s) > 1 else imm))
        elif op == "FFMA":
            self._w(ins.dsts[0], self._r(s[0]) * self._r(s[1]) + self._r(s[2]))
        elif op == "FMNMX":
            self._w(ins.dsts[0], max(self._r(s[0]), self._r(s[1])))
        elif op == "XMAD":
            self._w(ins.dsts[0], self._r(s[0]) * self._r(s[1]) + self._r(s[2]))
        elif op == "LOP":
            self._w(ins.dsts[0], float(int(self._r(s[0])) & int(self._r(s[1]))))
        elif op == "SHL":
            self._w(ins.dsts[0], self._r(s[0]) * (2 ** int(imm)))
        elif op == "SHR":
            self._w(ins.dsts[0], float(int(self._r(s[0])) >> int(imm)))
        elif op in ("MOV",):
            self._w(ins.dsts[0], self._r(s[0]))
        elif op == "MOV32I":
            self._w(ins.dsts[0], imm)
        elif op == "ISETP":
            self.preds[ins.pdst] = self._r(s[0]) < self._r(s[1])
        elif op in ("DADD", "DMUL", "DFMA"):
            a, b = self._r64(s[0]), self._r64(s[1])
            if op == "DADD":
                v = a + b
            elif op == "DMUL":
                v = a * b
            else:
                v = a * b + self._r64(s[2])
            self._w64(ins.dsts[0], v)
        elif op == "MUFU":
            x = self._r(s[0])
            self._w(ins.dsts[0], 1.0 / x if x not in (0, 0.0) else math.inf)
        elif op in ("LDG", "LDG64"):
            addr = int(self._r(s[0])) + ins.offset
            v = self.gmem.get(addr, float((addr * 2654435761) % 1009) / 1009.0)
            if op == "LDG64":
                self._w64(ins.dsts[0], v)
            else:
                self._w(ins.dsts[0], v)
        elif op in ("STG", "STG64"):
            addr = int(self._r(s[0])) + ins.offset
            v = self._r64(s[1]) if op == "STG64" else self._r(s[1])
            self.gmem[addr] = v
            self.stores.append((addr, v))
        elif op == "LDS":
            self._w(ins.dsts[0], self.smem.get(int(self._r(s[0])) + ins.offset, 0.0))
        elif op == "STS":
            self.smem[int(self._r(s[0])) + ins.offset] = self._r(s[1])
        elif op == "LDL":
            self._w(ins.dsts[0], self.lmem.get(int(self._r(s[0])) + ins.offset, 0.0))
        elif op == "STL":
            self.lmem[int(self._r(s[0])) + ins.offset] = self._r(s[1])
        elif op == "LDP":
            self._w(ins.dsts[0], self.pmem.get(int(self._r(s[0])) + ins.offset, 0.0))
        elif op == "STP":
            self.pmem[int(self._r(s[0])) + ins.offset] = self._r(s[1])
        elif op in ("PCK", "UPCK"):
            # static compression is value-preserving on the modelled float
            # domain: pack/unpack is an ALU-cost identity round-trip
            self._w(ins.dsts[0], self._r(s[0]))
        elif op == "S2R":
            self._w(ins.dsts[0], float(self.tid))
        elif op in ("NOP", "BAR"):
            pass
        else:  # pragma: no cover - defensive
            raise NotImplementedError(op)


def _alias_marker(v: float) -> float:
    """Shadow value stored in the odd word of a 64-bit pair."""
    return -v if v == v else v


def equivalent(a: Kernel, b: Kernel, trials: int = 4, seed: int = 0) -> bool:
    """Dataflow equivalence of two kernels over random inputs."""
    import random

    rng = random.Random(seed)
    for t in range(trials):
        inputs_a = {r: rng.uniform(1.0, 2.0) for r in a.live_in}
        # map by register number: transformations never rename live-ins
        inputs_b = {r: inputs_a.get(r, rng.uniform(1.0, 2.0)) for r in b.live_in}
        ia, ib = Interp(a, tid=t), Interp(b, tid=t)
        out_a = ia.run(dict(inputs_a))
        out_b = ib.run(dict(inputs_b))
        for r in a.live_out:
            va, vb = out_a.get(r), out_b.get(r)
            if va is None or vb is None or not _close(va, vb):
                return False
        if len(ia.stores) != len(ib.stores):
            return False
        for (aa, va), (ab, vb) in zip(ia.stores, ib.stores):
            if aa != ab or not _close(va, vb):
                return False
    return True


def _close(x: float, y: float, tol: float = 1e-9) -> bool:
    if math.isinf(x) or math.isinf(y):
        return x == y
    return abs(x - y) <= tol * max(1.0, abs(x), abs(y))
