"""Synthetic "nvcc": generates schedule-optimized kernels for the ISA.

The paper evaluates RegDem on nine benchmark kernels (Table 1/2).  nvcc and
the original CUDA sources cannot run here, so this module generates SASS-like
stand-ins whose *register-pressure-relevant* profile matches Table 1 exactly:
register count, threads/block, blocks, static shared memory, and the
dominant instruction mix (FP64 for ``md``, tree-traversal loads for the FSM
suite, streaming global traffic for ``cfd``/``qtc``, ALU-heavy hashing for
``md5hash``...).

The generated kernels are *real programs* over the abstract ISA: they
execute on :class:`repro.core.isa.Interp` (so binary translation can be
verified semantics-preserving) and on the timing simulator (so variants can
be graded), and they are scheduled by :func:`repro.core.sched.schedule` the
way ptxas would schedule them.
"""

from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, List

from .isa import Instr, Kernel, Label
from .sched import schedule

# Fixed low registers (the "ABI"):
R_TID = 0       # thread id (S2R)
R_IN = 1        # input base pointer (live-in)
R_OUT = 2       # output base pointer (live-in)
R_CNT = 3       # loop counter
R_LIM = 4       # loop limit
R_ADDR = 5      # streaming address
N_FIXED = 6


@dataclass
class Profile:
    """Generation profile for one benchmark kernel (Table 1 row)."""

    name: str
    target_regs: int              # Table 1 "# Registers Used (orig)"
    threads_per_block: int
    num_blocks: int
    shared_size: int              # static shared memory bytes
    regdem_target: int            # Table 1 "target" register count
    nvcc_spills: int              # Table 1 "# Registers Spilled (nvcc)"
    loop_trips: int = 10
    #: number of rematerializable constant registers (MOV32I pool)
    n_consts: int = 8
    #: temporaries for streaming loads etc.
    n_temps: int = 6
    #: fraction of state registers that are FP64 pairs (md == 1.0)
    fp64_frac: float = 0.0
    #: streaming global loads per loop iteration
    loads_per_iter: int = 2
    #: dependent (pointer-chasing) global loads per iteration: each load's
    #: address derives from the previous load's value.  Kills per-warp MLP,
    #: so occupancy directly buys memory parallelism — the regime where the
    #: paper's benchmarks (tree traversals, unstructured grids) live.
    chase_loads: int = 0
    #: global stores per loop iteration (streaming output)
    stores_per_iter: int = 0
    #: user shared-memory ops per loop iteration (tree traversal caches)
    smem_ops_per_iter: int = 0
    #: SFU ops per loop iteration (rsqrt / exp flavour)
    sfu_per_iter: int = 0
    #: use predicated ops in the body (divergence flavour)
    predicated: bool = False
    seed: int = 0

    @property
    def n_state(self) -> int:
        n = self.target_regs - N_FIXED - self.n_consts - self.n_temps
        if n <= 1:
            raise ValueError(f"profile {self.name}: register budget too small")
        if self.fp64_frac > 0 and n % 2:
            n -= 1  # keep pair alignment
        return n


#: Table 1 of the paper, transcribed.  (threads/block, #blocks, smem bytes,
#: orig regs, target regs, nvcc spill count at the target.)
PAPER_BENCHMARKS: Dict[str, Profile] = {
    p.name: p
    for p in [
        Profile("cfd", 68, 192, 1008, 0, 56, 10, loop_trips=12,
                n_consts=10, n_temps=8, loads_per_iter=4, chase_loads=2,
                stores_per_iter=1, sfu_per_iter=1, seed=1),
        Profile("qtc", 55, 64, 1538, 512, 48, 8, loop_trips=16,
                n_consts=8, n_temps=6, loads_per_iter=2, chase_loads=3,
                smem_ops_per_iter=2, predicated=True, seed=2),
        Profile("md5hash", 33, 256, 93790, 0, 32, 0, loop_trips=16,
                n_consts=6, n_temps=4, loads_per_iter=0, sfu_per_iter=0,
                seed=3),
        Profile("md", 34, 256, 228, 0, 32, 1, loop_trips=12,
                n_consts=6, n_temps=6, fp64_frac=1.0, loads_per_iter=2,
                sfu_per_iter=1, seed=4),
        Profile("gaussian", 43, 64, 500, 0, 40, 1, loop_trips=10,
                n_consts=8, n_temps=6, loads_per_iter=2, chase_loads=2,
                stores_per_iter=1, seed=5),
        Profile("conv", 35, 128, 16384, 0, 32, 0, loop_trips=9,
                n_consts=8, n_temps=4, loads_per_iter=2, stores_per_iter=1,
                seed=6),
        Profile("nn", 35, 192, 1024, 1556, 32, 0, loop_trips=14,
                n_consts=6, n_temps=5, loads_per_iter=2, chase_loads=3,
                smem_ops_per_iter=2, predicated=True, seed=7),
        Profile("pc", 36, 256, 1024, 2079, 32, 2, loop_trips=14,
                n_consts=6, n_temps=5, loads_per_iter=2, chase_loads=2,
                smem_ops_per_iter=2, predicated=True, seed=8),
        Profile("vp", 34, 256, 2048, 2079, 32, 0, loop_trips=14,
                n_consts=6, n_temps=4, loads_per_iter=2, chase_loads=3,
                smem_ops_per_iter=2, predicated=True, seed=9),
    ]
}


def generate(profile: Profile) -> Kernel:
    """Generate + schedule one kernel for ``profile``."""
    rng = random.Random(profile.seed)
    k = Kernel(
        name=profile.name,
        threads_per_block=profile.threads_per_block,
        num_blocks=profile.num_blocks,
        shared_size=profile.shared_size,
        live_in={R_IN, R_OUT},
    )
    items: List[object] = k.items
    n_state = profile.n_state
    consts = list(range(N_FIXED, N_FIXED + profile.n_consts))
    state0 = N_FIXED + profile.n_consts
    if profile.fp64_frac > 0 and state0 % 2:
        state0 += 1  # alignment for double pairs
    n_fp64_words = int(n_state * profile.fp64_frac) // 2 * 2
    fp64_pairs = [state0 + 2 * i for i in range(n_fp64_words // 2)]
    fp32_state = list(range(state0 + n_fp64_words, state0 + n_state))
    temps = list(range(state0 + n_state, state0 + n_state + profile.n_temps))

    def emit(op, dsts=(), srcs=(), **kw):
        items.append(Instr(op, list(dsts), list(srcs), **kw))

    # ---- prologue -----------------------------------------------------------
    emit("S2R", [R_TID])
    emit("MOV32I", [R_CNT], imm=0.0)
    emit("MOV32I", [R_LIM], imm=float(profile.loop_trips))
    emit("ISCADD", [R_ADDR], [R_TID, R_IN], imm=2.0)  # addr = tid*4 + in
    for i, c in enumerate(consts):
        emit("MOV32I", [c], imm=round(0.5 + 0.25 * i, 4))
    for i, t in enumerate(temps):
        emit("MOV32I", [t], imm=float(i))
    # initial state loads from global memory
    for i, r in enumerate(fp32_state):
        emit("LDG", [r], [R_ADDR], offset=4 * i)
    for i, r in enumerate(fp64_pairs):
        emit("LDG64", [r], [R_ADDR], offset=4 * len(fp32_state) + 8 * i)

    # ---- main loop ----------------------------------------------------------
    items.append(Label("LOOP"))
    body_rng = rng

    def some_const() -> int:
        return body_rng.choice(consts)

    # streaming loads into temps
    for j in range(profile.loads_per_iter):
        t = temps[j % len(temps)]
        emit("LDG", [t], [R_ADDR], offset=0x100 + 4 * j)
    # dependent load chain (tree traversal / unstructured-grid indirection)
    if profile.chase_loads:
        c0 = temps[0]
        emit("LDG", [c0], [R_ADDR], offset=0x300)
        prev = c0
        for j in range(1, profile.chase_loads):
            t = temps[j % len(temps)]
            emit("LDG", [t], [prev], offset=0x10 * j)
            prev = t
        tgt0 = fp32_state[0] if fp32_state else temps[-1]
        emit("FADD", [tgt0], [tgt0, prev])
    # predicate for divergence-flavoured profiles
    if profile.predicated:
        emit("ISETP", srcs=[temps[0] if temps else consts[0], some_const()], pdst=0)
    # state updates: i-th state register gets 1 + (i % 3) uses
    for i, r in enumerate(fp32_state):
        uses = 1 + (i % 3)
        for u in range(uses):
            other = fp32_state[(i + u + 1) % len(fp32_state)]
            pred = 0 if (profile.predicated and (i + u) % 4 == 0) else None
            emit("FFMA", [r], [r, some_const(), other], pred=pred)
    for i, r in enumerate(fp64_pairs):
        other = fp64_pairs[(i + 1) % len(fp64_pairs)]
        emit("DFMA", [r], [r, other, r])
        if i % 2 == 0:
            emit("DADD", [r], [r, other])
    # fold streamed values into state
    for j in range(profile.loads_per_iter):
        t = temps[j % len(temps)]
        tgt = fp32_state[j % len(fp32_state)] if fp32_state else fp64_pairs[0]
        if fp32_state:
            emit("FFMA", [tgt], [t, some_const(), tgt])
        else:
            emit("FADD", [temps[-1]], [t, temps[-1]])
    # user shared memory traffic (tree-traversal caches): stays inside the
    # programmer's static allocation [0, shared_size).  A profile with no
    # static allocation gets no user smem ops — emitting them at offset 0
    # would write *outside* the declared region, exactly where RegDem's
    # demoted-register slots start (eq. 1 puts them at the end of the
    # static allocation), silently corrupting any demoted value.
    smem_ops = profile.smem_ops_per_iter if profile.shared_size >= 4 else 0
    for j in range(smem_ops):
        t = temps[(j + 1) % len(temps)]
        off = (4 * j * 32) % profile.shared_size
        if j % 2 == 0:
            emit("STS", srcs=[R_TID, fp32_state[j % len(fp32_state)] if fp32_state else temps[0]], offset=off)
        else:
            emit("LDS", [t], [R_TID], offset=off)
            tgt = fp32_state[(j * 5) % len(fp32_state)] if fp32_state else temps[0]
            emit("FADD", [tgt], [tgt, t])
    # SFU flavour
    for j in range(profile.sfu_per_iter):
        src = fp32_state[(3 * j) % len(fp32_state)] if fp32_state else fp64_pairs[0]
        emit("MUFU", [temps[(j + 2) % len(temps)]], [src])
    # streaming stores
    for j in range(profile.stores_per_iter):
        v = fp32_state[(7 * j) % len(fp32_state)] if fp32_state else temps[0]
        emit("STG", srcs=[R_ADDR, v], offset=0x200 + 4 * j)
    # loop bookkeeping
    emit("IADD", [R_ADDR], [R_ADDR], imm=float(4 * profile.loads_per_iter))
    emit("IADD", [R_CNT], [R_CNT], imm=1.0)
    emit("ISETP", srcs=[R_CNT, R_LIM], pdst=1)
    items.append(
        Instr("BRA", target="LOOP", pred=1, trip_count=profile.loop_trips)
    )

    # ---- epilogue: reduce state, store outputs ------------------------------
    if fp32_state:
        acc = temps[0]
        emit("MOV", [acc], [fp32_state[0]])
        for r in fp32_state[1:]:
            emit("FADD", [acc], [acc, r])
        emit("STG", srcs=[R_OUT, acc], offset=0x0)
    if fp64_pairs:
        dacc = fp64_pairs[0]
        for r in fp64_pairs[1:]:
            emit("DADD", [dacc], [dacc, r])
        emit("STG64", srcs=[R_OUT, dacc], offset=0x10)
    emit("EXIT")

    schedule(k)
    assert k.reg_count <= profile.target_regs + 2, (
        f"{profile.name}: generated {k.reg_count} regs, wanted {profile.target_regs}"
    )
    return k


def paper_kernel(name: str) -> Kernel:
    """One of the nine Table-1 stand-ins."""
    return generate(PAPER_BENCHMARKS[name])


def all_paper_kernels() -> Dict[str, Kernel]:
    return {name: generate(p) for name, p in PAPER_BENCHMARKS.items()}


def random_profile(seed: int) -> Profile:
    """A random profile for property-based testing."""
    rng = random.Random(seed)
    target = rng.randint(34, 90)
    n_consts = rng.randint(4, 10)
    n_temps = rng.randint(3, 8)
    # keep the state width positive
    while target - N_FIXED - n_consts - n_temps < 4:
        target += 4
    return Profile(
        name=f"rand{seed}",
        target_regs=target,
        threads_per_block=rng.choice([64, 128, 192, 256]),
        num_blocks=rng.choice([128, 1024, 4096]),
        shared_size=rng.choice([0, 0, 512, 2048]),
        regdem_target=max(32, target - rng.randint(2, 16)),
        nvcc_spills=rng.randint(0, 4),
        loop_trips=rng.randint(3, 12),
        n_consts=n_consts,
        n_temps=n_temps,
        fp64_frac=rng.choice([0.0, 0.0, 0.0, 0.5]),
        loads_per_iter=rng.randint(0, 4),
        stores_per_iter=rng.randint(0, 2),
        smem_ops_per_iter=rng.randint(0, 2) if rng.random() < 0.5 else 0,
        sfu_per_iter=rng.randint(0, 2),
        predicated=rng.random() < 0.4,
        seed=seed,
    )
