"""Post-spilling optimizations (paper §3.4.2).

Three passes over a demoted kernel:

* :func:`eliminate_redundant` — drop demoted loads whose value is already in
  the value register, and demoted stores overwritten before any reload;
* :func:`reschedule` — hoist demoted loads as early as legally possible and
  relax the read barrier of demoted stores whose value register is never
  rewritten in the barrier scope;
* :func:`substitute_value_register` — per-block liveness finds free
  registers; distinct demoted-access *spans* get distinct temporaries so
  several demoted values can be in flight simultaneously.

All passes maintain the barrier-consistency invariant checked by
:func:`repro.core.sched.verify_schedule`.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set

from .isa import RZ, Instr, Kernel, Label, liveness
from .sched import fixup_stalls


def _scopes(items: List[object]) -> List[List[int]]:
    """Indices of instructions grouped by barrier scope (label/branch walls)."""
    out: List[List[int]] = []
    cur: List[int] = []
    for i, it in enumerate(items):
        if isinstance(it, Label):
            if cur:
                out.append(cur)
            cur = []
            continue
        cur.append(i)
        if it.info.is_branch or it.info.is_exit:
            out.append(cur)
            cur = []
    if cur:
        out.append(cur)
    return out


def _remove_barrier_waits(items: List[object], scope: List[int], start: int, bars: Set[int]) -> None:
    """Remove waits on ``bars`` from instructions after position ``start`` in
    ``scope``, stopping per-barrier once another setter re-arms it."""
    live = set(bars)
    for idx in scope:
        if idx <= start or not live:
            continue
        ins: Instr = items[idx]
        if ins is None:  # already deleted in this pass
            continue
        ins.ctrl.wait -= live
        for b in list(live):
            if ins.ctrl.write_bar == b or ins.ctrl.read_bar == b:
                live.discard(b)


def _delete(kernel: Kernel, idx: int, scope: List[int]) -> None:
    """Delete instruction ``idx``, transferring its wait mask forward and
    cleaning up waits on the barriers it used to set."""
    ins: Instr = kernel.items[idx]
    sets = {b for b in (ins.ctrl.write_bar, ins.ctrl.read_bar) if b is not None}
    if sets:
        _remove_barrier_waits(kernel.items, scope, idx, sets)
    if ins.ctrl.wait:
        # transfer hazard waits to the next surviving instruction; if none
        # remains in the scope they protected only the deleted instruction
        for j in scope:
            if j > idx and kernel.items[j] is not None:
                kernel.items[j].ctrl.wait |= ins.ctrl.wait
                break
    kernel.items[idx] = None  # type: ignore[assignment]


def _commit_deletes(kernel: Kernel) -> None:
    kernel.items = [it for it in kernel.items if it is not None]


# ---------------------------------------------------------------------------
# Pass 1: eliminating redundant demote code
# ---------------------------------------------------------------------------


def eliminate_redundant(kernel: Kernel, rdv: int) -> int:
    """Remove provably redundant demoted loads/stores; returns #removed."""
    removed = 0
    items = kernel.items
    for scope in _scopes(items):
        # (a) redundant loads: RDV already holds this demoted word
        holds: Dict[int, int] = {}  # value-reg -> smem offset it holds
        for idx in scope:
            ins: Instr = items[idx]
            if ins is None:
                continue
            if ins.tag == "demoted_load":
                vreg = ins.dsts[0]
                if holds.get(vreg) == ins.offset:
                    _delete(kernel, idx, scope)
                    removed += 1
                    continue
                if ins.pred is None:
                    holds[vreg] = ins.offset
                else:
                    holds.pop(vreg, None)
            elif ins.tag == "demoted_store":
                vreg = ins.srcs[1]
                if ins.pred is None:
                    holds[vreg] = ins.offset
                else:
                    holds.pop(vreg, None)
            else:
                for r in ins.dst_words():
                    holds.pop(r, None)
        # (b) dead stores: overwritten before any reload of the same word
        for pos, idx in enumerate(scope):
            ins = items[idx]
            if ins is None or getattr(ins, "tag", None) != "demoted_store" or ins.pred is not None:
                continue
            for later_idx in scope[pos + 1 :]:
                later = items[later_idx]
                if later is None:
                    continue
                if later.tag == "demoted_load" and later.offset == ins.offset:
                    break
                if (
                    later.tag == "demoted_store"
                    and later.offset == ins.offset
                    and later.pred is None
                ):
                    _delete(kernel, idx, scope)
                    removed += 1
                    break
    _commit_deletes(kernel)
    return removed


# ---------------------------------------------------------------------------
# Pass 2: updating the instruction schedule
# ---------------------------------------------------------------------------


def reschedule(kernel: Kernel, rdv: int, rda: int, max_hoist: int = 8) -> int:
    """Hoist demoted loads earlier; relax demoted-store read barriers."""
    moved = 0
    items = kernel.items

    # --- store barrier relaxation -------------------------------------------
    for scope in _scopes(items):
        for pos, idx in enumerate(scope):
            ins: Instr = items[idx]
            if ins.tag != "demoted_store" or ins.ctrl.read_bar is None:
                continue
            vreg = ins.srcs[1]
            rewritten = any(
                vreg in items[j].dst_words() for j in scope[pos + 1 :]
            )
            if not rewritten:
                bar = ins.ctrl.read_bar
                ins.ctrl.read_bar = None
                _remove_barrier_waits(items, scope, idx, {bar})
                moved += 1

    # --- demoted load hoisting ----------------------------------------------
    def war_guard_bars(i_pred: int, vreg: int) -> Set[int]:
        """Read barriers unresolved just before position ``i_pred`` that guard
        ``vreg`` (an in-flight store still reads it).  The load must not move
        above an instruction whose wait resolves one of these."""
        pending: Dict[int, int] = {}
        # walk the enclosing scope up to i_pred
        for j in range(i_pred, -1, -1):
            it = items[j]
            if isinstance(it, Label) or (
                isinstance(it, Instr) and (it.info.is_branch or it.info.is_exit)
            ):
                start = j + 1
                break
        else:
            start = 0
        for j in range(start, i_pred):
            x = items[j]
            if not isinstance(x, Instr):
                continue
            for b in x.ctrl.wait:
                for r in [r for r, bb in pending.items() if bb == b]:
                    del pending[r]
            if x.ctrl.read_bar is not None:
                for r in x.src_words():
                    pending[r] = x.ctrl.read_bar
        return {b for r, b in pending.items() if r == vreg}

    def legal_swap(i: int, p: Instr, load: Instr) -> bool:
        if p.info.is_branch or p.info.is_exit:
            return False
        vreg = load.dsts[0]
        if vreg in p.dst_words() or vreg in p.src_words():
            return False
        if load.srcs[0] in p.dst_words():
            return False
        # predicate dependence
        if load.pred is not None and p.pdst == load.pred:
            return False
        # shared-memory aliasing: demoted slots only alias demoted accesses
        # to the same offset; stay conservative around user smem stores
        if p.op == "STS" and (p.tag != "demoted_store" or p.offset == load.offset):
            return False
        if p.tag == "demoted_load" and p.offset == load.offset:
            return False
        # barrier interactions
        p_sets = {b for b in (p.ctrl.write_bar, p.ctrl.read_bar) if b is not None}
        l_sets = {b for b in (load.ctrl.write_bar, load.ctrl.read_bar) if b is not None}
        if p_sets & l_sets:
            return False
        if p_sets & load.ctrl.wait or l_sets & p.ctrl.wait:
            return False
        # WAR guard: p's wait may be what licenses the load to clobber vreg
        if p.ctrl.wait & war_guard_bars(i - 1, vreg):
            return False
        return True

    changed = True
    passes = 0
    while changed and passes < max_hoist:
        changed = False
        passes += 1
        for i in range(1, len(items)):
            ins = items[i]
            if not isinstance(ins, Instr) or ins.tag != "demoted_load":
                continue
            p = items[i - 1]
            if not isinstance(p, Instr):
                continue
            if legal_swap(i, p, ins):
                items[i - 1], items[i] = ins, p
                moved += 1
                changed = True
    fixup_stalls(kernel)
    return moved


# ---------------------------------------------------------------------------
# Pass 3: substituting the value register
# ---------------------------------------------------------------------------


def substitute_value_register(kernel: Kernel, rdv: int, reg_budget: int) -> int:
    """Give distinct demoted-access spans distinct free registers.

    A *span* is the run from a demoted load (or the renamed defining
    instruction) through the matching demoted store / last use.  With one
    RDV only one demoted value can be in flight; substitution widens the
    window so hoisting (pass 2) can overlap several demoted accesses.
    Returns the number of spans renamed.
    """
    live = liveness(kernel)
    from .isa import CFG

    cfg = CFG(kernel)
    renamed = 0
    for blk in cfg.blocks:
        if not blk.instrs:
            continue
        lin, lout = live[blk.index]
        used: Set[int] = set()
        for ins in blk.instrs:
            used |= ins.regs()
        # a temporary must already exist in the program (else resurrecting it
        # would raise the packed register count) but be dead across this block
        program_regs = kernel.used_registers()
        free = [
            f
            for f in sorted(program_regs)
            if f < reg_budget and f not in used and f not in lin and f not in lout and f != RZ
        ]
        if not free:
            continue
        # collect spans: one span per RDV *value lifetime* (from the load or
        # defining instruction through every use, including demoted stores
        # and post-elimination reuses, until the value is replaced)
        spans: List[List[Instr]] = []
        cur: Optional[List[Instr]] = None
        for ins in blk.instrs:
            touches = rdv in ins.leading_regs()
            if not touches:
                if (rdv + 1) in ins.regs() and cur is not None:
                    # odd-alias access (pair traffic): poison the span
                    spans.remove(cur)
                    cur = None
                continue
            if ins.info.width == 2:
                # pair spans keep RDV (substitution would need an aligned
                # free pair); poison any open span for safety
                if cur is not None:
                    spans.remove(cur)
                cur = None
                continue
            replaces_value = (
                ins.tag == "demoted_load" and ins.dsts[0] == rdv
            ) or (
                ins.tag != "demoted_store"
                and rdv in ins.dsts
                and rdv not in ins.srcs
            )
            if replaces_value:
                cur = [ins]
                spans.append(cur)
            elif cur is not None:
                cur.append(ins)
            else:
                # reads RDV with unknown provenance (should not happen: loads
                # are inserted next to uses) — bail out for the whole block
                spans = []
                break
        # leave every other span on RDV; give the rest free registers
        fi = 0
        for si, span in enumerate(spans):
            if si % 2 == 0 or fi >= len(free):
                continue
            f = free[fi]
            fi += 1
            for ins in span:
                ins.rename(rdv, f)
            renamed += 1
    if renamed:
        fixup_stalls(kernel)
    return renamed
