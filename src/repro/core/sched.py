"""Control-word scheduling for the abstract ISA.

Two entry points:

* :func:`schedule` — assigns a full Maxwell-style control word (stall,
  write/read barriers, wait masks) to a freshly generated instruction
  stream.  This plays the role of nvcc/ptxas's scheduler and produces the
  "efficient nvcc-generated binary" RegDem starts from (paper §1).

* :func:`fixup_stalls` — after a binary transformation inserted or removed
  instructions, recompute the *stall counts only*, leaving barrier
  assignments untouched (RegDem manages barriers itself through the barrier
  tracker; the paper notes "register allocation and instruction scheduling
  are interacting compiler passes, [so] our optimization considers the
  effect on the instruction schedule and performs updates where needed").

The scheduler's output travels in *machine form*: :func:`export_ctrl_words`
packs every instruction's control into the 21-bit Maxwell layout of
:mod:`repro.binary.ctrlwords` (what the container's text sections store) and
:func:`import_ctrl_words` applies packed words back onto an instruction
stream, so schedules survive the binary->binary pipeline losslessly.

Scheduling model (per basic block, matching the simulator):

* A fixed-latency producer (FP32/INT ALU, 6 cycles) must be separated from
  its consumer by >= latency cycles; the separation is the sum of stall
  counts of the instructions in between (plus theirs own issue cycle).
* Variable-latency producers (memory, FP64, SFU) signal a write barrier;
  consumers carry the barrier index in their wait mask.  Stores additionally
  signal a read barrier to release their source operands.
* Barriers do not survive branches: they are always resolved before the end
  of a basic block (paper §3.2 key observation).
"""

from __future__ import annotations

from typing import Dict, List, Set

from .isa import NUM_BARRIERS, RZ, Ctrl, Instr, Kernel, Label

#: Fixed producer->consumer latency for pipelined (non-barrier) ops
#: (Maxwell; per-arch values come from the :mod:`repro.arch` registry).
ALU_LATENCY = 6
#: Issue cost of a branch/exit.
CTRL_STALL = 5
MAX_STALL = 15


def _arch_of(kernel: Kernel):
    """The kernel's :class:`repro.arch.Arch` (lazy import: repro.arch pulls
    in the binary codecs, which must not load at repro.core import time)."""
    from repro.arch import arch_of

    return arch_of(kernel)


def _blocks(kernel: Kernel) -> List[List[Instr]]:
    """Instruction runs delimited by labels/branches (barrier scopes)."""
    out: List[List[Instr]] = []
    cur: List[Instr] = []
    for it in kernel.items:
        if isinstance(it, Label):
            if cur:
                out.append(cur)
            cur = []
            continue
        cur.append(it)
        if it.info.is_branch or it.info.is_exit:
            out.append(cur)
            cur = []
    if cur:
        out.append(cur)
    return out


def schedule(kernel: Kernel) -> Kernel:
    """Assign control words in-place; returns the kernel for chaining.

    The machine model (barrier count, fixed latencies) comes from the
    kernel's architecture via the :mod:`repro.arch` registry."""
    arch = _arch_of(kernel)
    for block in _blocks(kernel):
        _schedule_block(block, arch)
    return kernel


def _schedule_block(block: List[Instr], arch=None) -> None:
    if arch is None:
        from repro.arch import get_arch

        arch = get_arch("maxwell")
    num_barriers = arch.num_barriers
    # barrier bookkeeping: barrier index -> producing instr position
    barrier_of_reg: Dict[int, int] = {}   # reg word -> barrier idx guarding it
    barrier_busy: List[bool] = [False] * num_barriers
    read_guard: Dict[int, int] = {}       # reg word -> read barrier of a store
    ready_at: Dict[int, int] = {}         # reg word -> cycle value is ready
    now = 0

    def alloc_barrier(ins: Instr) -> int:
        for b in range(num_barriers):
            if not barrier_busy[b]:
                barrier_busy[b] = True
                return b
        # all six barriers busy: resolve the lowest-numbered one on this
        # instruction first (this is what ptxas emits: a forced wait), then
        # reuse it.  Mirrors the paper's "if the barrier ... was already
        # occupied by a different instruction, additional stalls are
        # introduced".
        b = min(
            set(barrier_of_reg.values()) | set(read_guard.values()) | {0}
        )
        ins.ctrl.wait.add(b)
        for r in [r for r, bb in barrier_of_reg.items() if bb == b]:
            del barrier_of_reg[r]
        for r in [r for r, bb in read_guard.items() if bb == b]:
            del read_guard[r]
        barrier_busy[b] = True
        return b

    for idx, ins in enumerate(block):
        ins.ctrl = Ctrl()
        # 1. wait on barriers guarding our source (and overwritten) operands
        waits: Set[int] = set()
        for r in ins.src_words():
            if r in barrier_of_reg:
                waits.add(barrier_of_reg.pop(r))
        for r in ins.dst_words():
            if r in barrier_of_reg:  # WAW with in-flight load
                waits.add(barrier_of_reg.pop(r))
            if r in read_guard:  # WAR with in-flight store operand
                waits.add(read_guard.pop(r))
        ins.ctrl.wait = waits
        for b in waits:
            barrier_busy[b] = False
            # a barrier resolution releases every register it guarded
            for r in [r for r, bb in barrier_of_reg.items() if bb == b]:
                del barrier_of_reg[r]
            for r in [r for r, bb in read_guard.items() if bb == b]:
                del read_guard[r]

        # 2. fixed-latency RAW separation via stall accumulation
        need = now
        for r in ins.src_words():
            need = max(need, ready_at.get(r, 0))
        if need > now and idx > 0:
            gap = need - now
            # push the gap into preceding stall counts (bounded per instr)
            j = idx - 1
            while gap > 0 and j >= 0:
                add = min(gap, MAX_STALL - block[j].ctrl.stall)
                block[j].ctrl.stall += add
                gap -= add
                j -= 1
            now = need

        # 3. issue
        info = ins.info
        if info.needs_write_barrier:
            b = alloc_barrier(ins)
            ins.ctrl.write_bar = b
            for r in ins.dst_words():
                barrier_of_reg[r] = b
        elif ins.dst_words():
            for r in ins.dst_words():
                ready_at[r] = now + arch.fixed_latency(info.klass)
        if info.needs_read_barrier:
            b = alloc_barrier(ins)
            ins.ctrl.read_bar = b
            for r in ins.src_words():
                if r != RZ:
                    read_guard[r] = b
        ins.ctrl.stall = CTRL_STALL if (info.is_branch or info.is_exit) else 1
        now += ins.ctrl.stall

    # close the block: final branch/exit (or last instr) must drain barriers
    if block:
        last = block[-1]
        pend = set(barrier_of_reg.values()) | set(read_guard.values())
        pend |= {b for b in range(num_barriers) if barrier_busy[b]}
        last.ctrl.wait |= pend


def export_ctrl_words(kernel: Kernel) -> List[int]:
    """The kernel's schedule as packed control words, one per instruction
    in stream order (machine form of :func:`schedule`'s output), in the
    kernel's architecture layout."""
    codec = _arch_of(kernel).codec
    return [codec.pack_ctrl(ins.ctrl) for ins in kernel.instructions()]


def import_ctrl_words(kernel: Kernel, words: List[int]) -> Kernel:
    """Apply packed control words (in the kernel's architecture layout)
    onto the kernel's instructions in-place (inverse of
    :func:`export_ctrl_words`); returns the kernel."""
    codec = _arch_of(kernel).codec
    instrs = kernel.instructions()
    if len(words) != len(instrs):
        raise ValueError(
            f"{kernel.name}: {len(words)} control words for {len(instrs)} instructions"
        )
    for ins, word in zip(instrs, words):
        ins.ctrl = codec.unpack_ctrl(word)
    return kernel


def verify_ctrl_words(kernel: Kernel, words: List[int]) -> List[str]:
    """Validate a packed control-word stream against a kernel's instruction
    stream without mutating it: the words are applied to a copy and checked
    with :func:`verify_schedule`."""
    return verify_schedule(import_ctrl_words(kernel.copy(), words))


def fixup_stalls(kernel: Kernel) -> Kernel:
    """Recompute stall counts after a transformation, keeping barriers.

    Walks each barrier scope, recomputing the fixed-latency RAW gaps the same
    way :func:`_schedule_block` does, but honours the (possibly transformed)
    barrier assignments already present on the instructions.
    """
    arch = _arch_of(kernel)
    for block in _blocks(kernel):
        ready_at: Dict[int, int] = {}
        now = 0
        for idx, ins in enumerate(block):
            # reset stall to the base issue cost, preserving barrier fields
            base = CTRL_STALL if (ins.info.is_branch or ins.info.is_exit) else 1
            ins.ctrl.stall = base
            need = now
            barrier_guarded = _barrier_guarded_regs(block, idx)
            for r in ins.src_words():
                if r not in barrier_guarded:
                    need = max(need, ready_at.get(r, 0))
            if need > now and idx > 0:
                gap = need - now
                j = idx - 1
                while gap > 0 and j >= 0:
                    add = min(gap, MAX_STALL - block[j].ctrl.stall)
                    block[j].ctrl.stall += add
                    gap -= add
                    j -= 1
                now = need
            if ins.dst_words() and not ins.info.needs_write_barrier:
                lat = arch.fixed_latency(ins.info.klass)
                for r in ins.dst_words():
                    ready_at[r] = now + lat
            now += ins.ctrl.stall
    return kernel


def _barrier_guarded_regs(block: List[Instr], upto: int) -> Set[int]:
    """Registers whose readiness is enforced by a barrier wait at ``upto``."""
    guarded: Set[int] = set()
    waits = block[upto].ctrl.wait
    if not waits:
        return guarded
    for prev in block[:upto]:
        if prev.ctrl.write_bar in waits:
            guarded |= set(prev.dst_words())
    return guarded


def repair_war(kernel: Kernel) -> int:
    """Insert missing WAR waits: any instruction overwriting a register that
    an in-flight store still reads (unresolved read barrier) must wait on
    that barrier.  Used after transformations that insert new writers (e.g.
    rematerialization in the comparison variants).  Returns #waits added."""
    added = 0
    for block in _blocks(kernel):
        pending: Dict[int, int] = {}
        for ins in block:
            for b in ins.ctrl.wait:
                for r in [r for r, bb in pending.items() if bb == b]:
                    del pending[r]
            for r in ins.dst_words():
                if r in pending:
                    ins.ctrl.wait.add(pending.pop(r))
                    added += 1
            if ins.ctrl.read_bar is not None:
                for r in ins.src_words():
                    if r != RZ:
                        pending[r] = ins.ctrl.read_bar
    return added


def verify_block(block: List[Instr], num_barriers: int = NUM_BARRIERS) -> List[str]:
    """Schedule validation of ONE barrier scope (see :func:`verify_schedule`).

    Barriers never span scopes, so scopes verify independently — this is what
    lets the pass pipeline re-verify only the scopes a pass touched.
    """
    errors: List[str] = []
    pending_write: Dict[int, int] = {}  # reg -> barrier
    pending_read: Dict[int, int] = {}
    for ins in block:
        for b in ins.ctrl.wait:
            if not 0 <= b < num_barriers:
                errors.append(f"{ins.render()}: wait on bad barrier {b}")
            pending_write = {r: bb for r, bb in pending_write.items() if bb != b}
            pending_read = {r: bb for r, bb in pending_read.items() if bb != b}
        for r in ins.src_words():
            if r in pending_write:
                errors.append(
                    f"{ins.render()}: reads R{r} guarded by unresolved "
                    f"barrier {pending_write[r]}"
                )
        for r in ins.dst_words():
            if r in pending_write:
                errors.append(
                    f"{ins.render()}: WAW on R{r} with unresolved "
                    f"barrier {pending_write[r]}"
                )
            if r in pending_read:
                errors.append(
                    f"{ins.render()}: WAR on R{r} with unresolved read "
                    f"barrier {pending_read[r]}"
                )
        if ins.ctrl.write_bar is not None:
            for r in ins.dst_words():
                pending_write[r] = ins.ctrl.write_bar
        if ins.ctrl.read_bar is not None:
            for r in ins.src_words():
                if r != RZ:
                    pending_read[r] = ins.ctrl.read_bar
    return errors


def verify_schedule(kernel: Kernel) -> List[str]:
    """Static schedule validation; returns a list of violations (empty = ok).

    Checks, per barrier scope:
      * every consumer of a barrier-producing instruction waits on (or is
        issued after something that waited on) its write barrier;
      * store read-barriers protect their operands against overwrite;
      * barrier indices are within range.
    Used by tests and by the translator's self-check.
    """
    errors: List[str] = []
    num_barriers = _arch_of(kernel).num_barriers
    for block in _blocks(kernel):
        errors.extend(verify_block(block, num_barriers))
    return errors
