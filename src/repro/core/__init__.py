"""Core: the paper's contribution (RegDem + predictor + pyReDe translator).

Faithful-reproduction layer:

* :mod:`repro.core.isa`         Maxwell-like abstract ISA + interpreter
* :mod:`repro.core.occupancy`   CC 5.2 occupancy calculator
* :mod:`repro.core.sched`       control-word scheduler / verifier
* :mod:`repro.core.kernelgen`   synthetic "nvcc" + Table-1 benchmark corpus
* :mod:`repro.core.candidates`  §3.4.3 candidate orderings
* :mod:`repro.core.strategies`  pluggable spill-strategy registry (the
                                 paper's orderings + related-work families)
* :mod:`repro.core.spillspace`  where spilled words live (shared vs local)
* :mod:`repro.core.passes`      the unified spill-transform pass pipeline
* :mod:`repro.core.regdem`      §3 demotion algorithm (Fig. 3), as a
                                 pipeline configuration
* :mod:`repro.core.compaction`  §3.3 relocation space (Fig. 4)
* :mod:`repro.core.postopt`     §3.4 post-spilling optimizations
* :mod:`repro.core.variants`    §5.3 comparison variants (Table 3), same
                                 pipeline, different configurations
* :mod:`repro.core.simulator`   cycle-approximate Maxwell timing model
                                 (two-stage: trace compiler + event-driven
                                 issue loop, cycle-exact vs the reference)
* :mod:`repro.core.simcache`    content-addressed sim/analysis cache
* :mod:`repro.core.predictor`   §4 compile-time performance predictor
* :mod:`repro.core.search`      predictor-guided parallel autotuning search
                                 over the widened variant space
* :mod:`repro.core.translator`  pyReDe driver: batch, cached, multi-kernel
                                 binary-translation service

Architecture registry (see README.md "Architectures"):

* :mod:`repro.arch`  per-arch descriptors (SMConfig, codec, latencies,
                     banking) resolved from each kernel's ``arch`` tag;
                     ships Maxwell/Pascal and Volta/Turing backends

Binary substrate (the pseudo-cubin layer the translator runs on; see
README.md "Binary container format"):

* :mod:`repro.binary.ctrlwords`  21-bit Maxwell control-word packing
* :mod:`repro.binary.archcodec`  per-arch text codecs (Maxwell bundles,
                                 Volta/Turing in-word control fields)
* :mod:`repro.binary.encoding`   fixed-width instruction records
* :mod:`repro.binary.container`  pseudo-cubin ``dumps``/``loads`` (v3:
                                 per-kernel arch tag)
* :mod:`repro.binary.overlay`    SASSOverlay-style annotated disassembly
* :mod:`repro.binary.roundtrip`  encode/decode self-check oracle

TPU-adaptation layer (see DESIGN.md §2):

* :mod:`repro.core.vmem_demotion`  VMEM-scratch residency policies
* :mod:`repro.core.tpu_predictor`  static variant selector over XLA artifacts
"""

from .isa import Instr, Kernel, Label, equivalent, parse_kernel
from .occupancy import MAXWELL, Occupancy, occupancy, occupancy_of, spill_targets
from .passes import (
    Pass,
    PassContext,
    PassPipeline,
    PassStat,
    PassVerificationError,
    aggressive_pipeline,
    demotion_pipeline,
    stats_by_pass,
)
from .regdem import RegDemOptions, RegDemResult, auto_targets, demote
from .search import (
    SearchConfig,
    SearchOutcome,
    SearchReport,
    ScoredVariant,
    search,
)
from .simcache import DEFAULT_SIM_CACHE, SimCache, simulate_cached
from .simulator import SimResult, simulate, simulate_reference, speedup
from .spillspace import LocalSpace, SharedSpace, SpillSpace
from .strategies import (
    Strategy,
    StrategyHints,
    get_strategy,
    register_strategy,
    strategy_names,
)
from .translator import (
    BatchTranslationReport,
    TranslationCache,
    TranslationReport,
    TranslationService,
    translate,
    translate_binary,
)

__all__ = [
    "Instr",
    "Kernel",
    "Label",
    "equivalent",
    "parse_kernel",
    "MAXWELL",
    "Occupancy",
    "occupancy",
    "occupancy_of",
    "spill_targets",
    "Pass",
    "PassContext",
    "PassPipeline",
    "PassStat",
    "PassVerificationError",
    "aggressive_pipeline",
    "demotion_pipeline",
    "stats_by_pass",
    "LocalSpace",
    "SharedSpace",
    "SpillSpace",
    "Strategy",
    "StrategyHints",
    "get_strategy",
    "register_strategy",
    "strategy_names",
    "RegDemOptions",
    "RegDemResult",
    "auto_targets",
    "demote",
    "SearchConfig",
    "SearchOutcome",
    "SearchReport",
    "ScoredVariant",
    "search",
    "DEFAULT_SIM_CACHE",
    "SimCache",
    "simulate_cached",
    "SimResult",
    "simulate",
    "simulate_reference",
    "speedup",
    "BatchTranslationReport",
    "TranslationCache",
    "TranslationReport",
    "TranslationService",
    "translate",
    "translate_binary",
]
