/* Native issue loop for the RegDem timing simulator.
 *
 * A statement-for-statement translation of the scheduling semantics of
 * repro.core.simulator._issue_loop (which is itself cycle-exact with
 * simulate_reference): warps round-robin under the arch's issue width,
 * per-class unit capacity gates issue, a cycle in which nothing issues
 * jumps to the next warp-ready or unit-free event, and (optionally) every
 * idle cycle is charged to exactly one (record, reason) blame bucket.
 *
 * All clocks are IEEE-754 binary64, the same representation CPython floats
 * use, and every operation performed on them (compare, add, max, truncate)
 * is exact in both languages — so this engine is state-for-state identical
 * to the Python fallback, checkpoint captures included.  The Python side
 * (repro.core._native) owns compilation, marshalling and the reason-code
 * order; keep the two files in sync.
 */
#include <math.h>
#include <stdint.h>
#include <string.h>

#define N_REASONS 5
#define REASON_STALL 0
#define REASON_BANK 1
#define REASON_MEM 2
#define REASON_BAR 3
#define REASON_UNIT 4

/* params_i layout (mirrored in repro.core._native) */
enum {
    PI_N_TRACE,
    PI_N_RECORDS,
    PI_N_WARPS,
    PI_ISSUE_WIDTH,
    PI_NUM_BARRIERS,
    PI_N_CLASSES,
    PI_PROFILE,
    PI_N_THRESHOLDS,
    PI_RR,
    PI_IDLE,
    PI_FRONTIER,
    PI_COUNT
};

/* out_i layout */
enum { PO_IDLE, PO_FRONTIER, PO_RR, PO_N_CAPTURED, PO_COUNT };

int64_t regdem_issue_loop(
    const int64_t *params_i,
    const double *params_d, /* [max_cycles, cycle0] */
    const int64_t *code,    /* n_trace dynamic positions -> record index */
    const int64_t *r_klass, /* per-record fields, n_records each */
    const int64_t *r_cost,
    const int64_t *r_wbar,
    const int64_t *r_rbar,
    const int64_t *r_wlat,
    const int64_t *r_rlat,
    const int64_t *r_confl,
    const int64_t *r_mem,
    const int64_t *wait_off,  /* n_records + 1 */
    const int64_t *wait_data, /* flattened wait sets */
    const double *intervals,  /* n_classes */
    int64_t *pc,              /* n_warps, in/out */
    double *next_time,        /* n_warps, in/out */
    double *bars,             /* n_warps * num_barriers, in/out */
    double *unit_free,        /* n_classes, in/out */
    int64_t *blame,           /* n_records * N_REASONS (profile only) */
    int64_t *warp_blame,      /* n_warps * 2: (rec, reason) (profile only) */
    int64_t *bar_setter,      /* n_warps * num_barriers (profile only) */
    const int64_t *thresholds, /* ascending capture milestones */
    int64_t *cap_i, /* per slot: frontier, idle, rr, pc[], wblame[], bset[] */
    double *cap_d,  /* per slot: cycle, next_time[], bars[], unit_free[] */
    int64_t *cap_blame, /* per slot: n_records * N_REASONS */
    double *out_d,      /* [cycle] */
    int64_t *out_i      /* PO_COUNT */
) {
    const int64_t n_trace = params_i[PI_N_TRACE];
    const int64_t n_records = params_i[PI_N_RECORDS];
    const int64_t n_warps = params_i[PI_N_WARPS];
    const int64_t issue_width = params_i[PI_ISSUE_WIDTH];
    const int64_t nb = params_i[PI_NUM_BARRIERS];
    const int64_t nc = params_i[PI_N_CLASSES];
    const int profile = (int)params_i[PI_PROFILE];
    const int64_t n_thr = params_i[PI_N_THRESHOLDS];
    int64_t rr = params_i[PI_RR];
    int64_t idle_cycles = params_i[PI_IDLE];
    int64_t frontier = params_i[PI_FRONTIER];
    const double max_cycles = params_d[0];
    double cycle = params_d[1];

    int64_t n_done = 0;
    for (int64_t w = 0; w < n_warps; w++)
        if (pc[w] >= n_trace) n_done++;

    int64_t thr_cur = 0, n_cap = 0;
    const int64_t slot_i = 3 + 3 * n_warps + n_warps * nb;
    const int64_t slot_d = 1 + n_warps + n_warps * nb + nc;

    while (n_done < n_warps && cycle < max_cycles) {
        /* checkpoint capture at trace-position milestones (loop top) */
        if (thr_cur < n_thr && n_done == 0 && frontier >= thresholds[thr_cur]) {
            while (thr_cur < n_thr && frontier >= thresholds[thr_cur])
                thr_cur++;
            int64_t *ci = cap_i + n_cap * slot_i;
            double *cd = cap_d + n_cap * slot_d;
            ci[0] = frontier;
            ci[1] = idle_cycles;
            ci[2] = rr;
            memcpy(ci + 3, pc, (size_t)n_warps * sizeof(int64_t));
            if (profile) {
                memcpy(ci + 3 + n_warps, warp_blame,
                       (size_t)(2 * n_warps) * sizeof(int64_t));
                memcpy(ci + 3 + 3 * n_warps, bar_setter,
                       (size_t)(n_warps * nb) * sizeof(int64_t));
                memcpy(cap_blame + n_cap * n_records * N_REASONS, blame,
                       (size_t)(n_records * N_REASONS) * sizeof(int64_t));
            }
            cd[0] = cycle;
            memcpy(cd + 1, next_time, (size_t)n_warps * sizeof(double));
            memcpy(cd + 1 + n_warps, bars,
                   (size_t)(n_warps * nb) * sizeof(double));
            memcpy(cd + 1 + n_warps + n_warps * nb, unit_free,
                   (size_t)nc * sizeof(double));
            n_cap++;
        }

        const double cap = cycle + 1.0;
        int64_t issued = 0;
        for (int64_t k = 0; k < n_warps; k++) {
            int64_t w = rr + k;
            if (w >= n_warps) w -= n_warps;
            if (next_time[w] > cycle) continue; /* blocked (done parks at inf) */
            int64_t p = pc[w];
            int64_t j = code[p];
            int64_t ki = r_klass[j];
            double uf = unit_free[ki];
            if (uf >= cap) continue; /* unit capacity spent this cycle */
            /* ---- issue ---- */
            issued++;
            unit_free[ki] = (uf > cycle ? uf : cycle) + intervals[ki];
            double t = cycle + (double)r_cost[j];
            double *bw = bars + w * nb;
            int64_t b = r_wbar[j];
            if (b >= 0) bw[b] = cycle + (double)r_wlat[j];
            b = r_rbar[j];
            if (b >= 0) bw[b] = cycle + (double)r_rlat[j];
            if (profile) {
                int64_t *bs = bar_setter + w * nb;
                if (r_wbar[j] >= 0) bs[r_wbar[j]] = j;
                if (r_rbar[j] >= 0) bs[r_rbar[j]] = j;
            }
            p++;
            pc[w] = p;
            if (p > frontier) frontier = p;
            if (p >= n_trace) {
                n_done++;
                next_time[w] = INFINITY;
            } else if (!profile) {
                int64_t jn = code[p];
                for (int64_t q = wait_off[jn]; q < wait_off[jn + 1]; q++) {
                    double v = bw[wait_data[q]];
                    if (v > t) t = v;
                }
                next_time[w] = t;
            } else {
                /* same wait maximization, additionally tracking which event
                 * bounds t: the issued instruction's own cost (stall / bank
                 * conflict) or a scoreboard barrier and its setter */
                int64_t rec = j;
                int64_t reason = r_confl[j] ? REASON_BANK : REASON_STALL;
                int64_t *bs = bar_setter + w * nb;
                int64_t jn = code[p];
                for (int64_t q = wait_off[jn]; q < wait_off[jn + 1]; q++) {
                    int64_t bb = wait_data[q];
                    double v = bw[bb];
                    if (v > t) {
                        t = v;
                        int64_t sj = bs[bb];
                        if (sj >= 0) {
                            rec = sj;
                            reason = r_mem[sj] ? REASON_MEM : REASON_BAR;
                        }
                    }
                }
                next_time[w] = t;
                warp_blame[2 * w] = rec;
                warp_blame[2 * w + 1] = reason;
            }
            if (issued >= issue_width) break;
        }
        if (issued) {
            rr++;
            if (rr >= n_warps) rr = 0;
            cycle += 1.0;
            continue;
        }
        /* Idle: jump to the next time anything can happen.  Two shapes,
         * both counted exactly as the reference engine does:
         *   - no warp ready: one iteration jumps to the earliest warp-ready
         *     event (rr advances once);
         *   - a warp is ready but its unit is at capacity: the reference
         *     crawls cycle-by-cycle until the unit frees or another warp
         *     readies; the k crawl cycles collapse into one iteration with
         *     rr += k and idle += k. */
        rr++;
        if (rr >= n_warps) rr = 0;
        double mn_wait = INFINITY;
        int64_t w_wait = -1; /* first strict minimum, ascending warp order */
        double mn_block = INFINITY;
        int64_t w_block = -1;
        for (int64_t w = 0; w < n_warps; w++) {
            double v = next_time[w];
            if (v > cycle) {
                if (v < mn_wait) {
                    mn_wait = v;
                    w_wait = w;
                }
            } else {
                /* ready but unit-blocked: the unit frees at floor(clock) */
                int64_t ki = r_klass[code[pc[w]]];
                double bv = (double)(int64_t)unit_free[ki];
                if (bv < mn_block) {
                    mn_block = bv;
                    w_block = w;
                }
            }
        }
        double nxt;
        int64_t kk;
        if (mn_block < INFINITY) {
            nxt = mn_block < mn_wait ? mn_block : mn_wait;
            if (nxt < cap)
                nxt = cap;
            else if (nxt > max_cycles)
                nxt = max_cycles; /* the reference stops exactly at the cap */
            kk = (int64_t)(nxt - cycle);
            idle_cycles += kk;
            rr += kk - 1;
            rr %= n_warps;
            if (profile && kk) {
                if (mn_block <= mn_wait) {
                    blame[code[pc[w_block]] * N_REASONS + REASON_UNIT] += kk;
                } else {
                    blame[warp_blame[2 * w_wait] * N_REASONS +
                          warp_blame[2 * w_wait + 1]] += kk;
                }
            }
        } else {
            nxt = mn_wait > cap ? mn_wait : cap;
            kk = (int64_t)(nxt - cycle);
            idle_cycles += kk;
            if (profile && kk) {
                blame[warp_blame[2 * w_wait] * N_REASONS +
                      warp_blame[2 * w_wait + 1]] += kk;
            }
        }
        cycle = nxt;
    }
    out_d[0] = cycle;
    out_i[PO_IDLE] = idle_cycles;
    out_i[PO_FRONTIER] = frontier;
    out_i[PO_RR] = rr;
    out_i[PO_N_CAPTURED] = n_cap;
    return 0;
}
