"""Register compaction via a relocation space (paper §3.3, Fig. 4).

Demoted registers leave gaps in the register numbering, but the ISA charges
a kernel by its *highest used register number*, so the space must be packed.
The relocation space is an array with one slot per physical register; gaps
are pushed toward the end with two operations:

* **shifting**  — move the next register down into the lowest gap;
* **swapping**  — when alignment blocks a multi-word register from shifting,
  exchange it with the *swapping window* (the ``width`` slots directly below
  it), which moves the pair down while preserving even alignment.

The §3.4.1 bank-conflict-aware variant first looks for a same-bank register
within a window of four to fill the gap, reverting to plain shifting when
that would strand an even-numbered gap (register count reduction is the top
priority).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Set

from .isa import Kernel
from .candidates import width_map

NUM_BANK_WINDOW = 4  # swapping window for the bank-aware variant (§3.4.1)


def folded_widths(kernel: Kernel) -> Dict[int, int]:
    """Width map with pair aliases folded: if ``r`` is a 64-bit pair, a
    standalone single-word entry for ``r+1`` (the alias word, which code may
    still write individually, e.g. pair initialization) belongs to the pair
    and must not occupy its own relocation slot."""
    widths = width_map(kernel)
    for r, w in list(widths.items()):
        if w == 2 and widths.get(r + 1) == 1:
            del widths[r + 1]
    return widths


# ---------------------------------------------------------------------------
# Relocation space
# ---------------------------------------------------------------------------


class RelocationSpace:
    """One slot per physical register; multi-word registers occupy
    ``width`` consecutive slots but are represented (and moved) as a unit,
    which "prevents the algorithm from breaking register aliases"."""

    def __init__(self, kernel: Kernel):
        from repro.arch import arch_of

        #: arch banking for the §3.4.1 bank-aware fill (Maxwell: reg % 4,
        #: Volta: reg % 2) — must match the model charging the conflicts
        self.reg_bank = arch_of(kernel).reg_bank
        widths = folded_widths(kernel)
        self.pinned: Set[int] = set(kernel.live_in) | set(kernel.live_out)
        top = max(widths) + max(widths.values(), default=1) if widths else 0
        self.slots: List[Optional[int]] = [None] * (top + 1)
        self.width: Dict[int, int] = {}
        for r, w in widths.items():
            # odd alias words were folded into their pair by width_map users;
            # guard anyway
            if any(self.slots[r + j] is not None for j in range(w)):
                continue
            for j in range(w):
                self.slots[r + j] = r
            self.width[r] = w
        #: final placement: original reg -> new leading position
        self.moves: Dict[int, int] = {}

    # -- queries --------------------------------------------------------------

    def lowest_gap(self, start: int = 0) -> Optional[int]:
        top = self.highest_used()
        for i in range(start, top):
            if self.slots[i] is None:
                return i
        return None

    def highest_used(self) -> int:
        for i in range(len(self.slots) - 1, -1, -1):
            if self.slots[i] is not None:
                return i + 1
        return 0

    def next_movable_above(self, pos: int) -> Optional[int]:
        """Leading slot index of the next movable register above ``pos``."""
        i = pos + 1
        top = self.highest_used()
        while i < top:
            r = self.slots[i]
            if r is not None and i == self._lead(i) and r not in self.pinned:
                return i
            i += 1
        return None

    def _lead(self, pos: int) -> int:
        r = self.slots[pos]
        while pos > 0 and self.slots[pos - 1] == r:
            pos -= 1
        return pos

    # -- operations -------------------------------------------------------------

    def place(self, lead_pos: int, new_pos: int) -> None:
        r = self.slots[lead_pos]
        w = self.width[r]
        for j in range(w):
            assert self.slots[lead_pos + j] == r
            self.slots[lead_pos + j] = None
        for j in range(w):
            assert self.slots[new_pos + j] is None, "placement collision"
            self.slots[new_pos + j] = r

    def shift(self, gap: int, lead_pos: int) -> bool:
        """Fig. 4(a)/(b): move the register at ``lead_pos`` into ``gap``."""
        r = self.slots[lead_pos]
        w = self.width[r]
        if w == 2 and gap % 2 != 0:
            return False  # alignment restriction (Fig. 4b)
        if any(
            gap + j >= lead_pos or self.slots[gap + j] is not None for j in range(w)
        ):
            if not all(
                gap + j < lead_pos and self.slots[gap + j] is None for j in range(w)
            ):
                return False
        self.place(lead_pos, gap)
        return True

    def swap_window(self, lead_pos: int) -> bool:
        """Fig. 4(c): exchange the multi-word register at ``lead_pos`` with
        the window of ``width`` slots directly below it."""
        r = self.slots[lead_pos]
        w = self.width[r]
        lo = lead_pos - w
        if lo < 0:
            return False
        window = self.slots[lo:lead_pos]
        # window must contain only movable single-word registers and gaps
        for x in set(window):
            if x is None:
                continue
            if x in self.pinned or self.width.get(x, 1) != 1:
                return False
        # perform the exchange: pair drops by w, window contents rise by w
        singles = [x for x in window if x is not None]
        for j in range(w):
            self.slots[lo + j] = r
        pos = lead_pos
        for x in singles:
            self.slots[pos] = x
            pos += 1
        for j in range(pos, lead_pos + w):
            self.slots[j] = None
        return True

    # -- the packing loop -------------------------------------------------------

    def pack(self, bank_avoid: bool = False) -> Dict[int, int]:
        guard = 0
        while True:
            guard += 1
            if guard > 10_000:  # pragma: no cover - defensive
                raise RuntimeError("compaction did not converge")
            gap = self.lowest_gap()
            if gap is None:
                break
            moved = False
            if bank_avoid:
                moved = self._bank_aware_fill(gap)
            if not moved:
                pos = self.next_movable_above(gap)
                if pos is None:
                    break
                if self.shift(gap, pos):
                    moved = True
                elif self.width[self.slots[pos]] == 2:
                    # alignment blocked the shift: swap first (Fig. 4c), the
                    # next iteration re-tries the (now lower) configuration
                    moved = self.swap_window(pos)
                    if not moved:
                        # give up on this gap: try the next register above
                        nxt = self.next_movable_above(pos)
                        if nxt is None:
                            break
                        moved = self.shift(gap, nxt) or self.swap_window(nxt)
                if not moved:
                    # nothing above fits this gap; look past it
                    nxt_gap = self.lowest_gap(start=gap + 1)
                    if nxt_gap is None or nxt_gap == gap:
                        break
                    continue
            if not moved:
                break
        return self.extract_moves()

    def _bank_aware_fill(self, gap: int) -> bool:
        """§3.4.1: prefer filling ``gap`` with a same-bank register found
        within a window of four slots above it."""
        # an even gap with a free odd neighbour should be saved for a pair if
        # one exists above ("we revert to the original algorithm in that case
        # since reducing register count is the top priority")
        pair_waiting = any(
            w == 2 and self.slots[r] == r
            for r, w in self.width.items()
            if r > gap and r not in self.pinned and self.slots[r] is not None
        )
        if (
            gap % 2 == 0
            and gap + 1 < len(self.slots)
            and self.slots[gap + 1] is None
            and pair_waiting
        ):
            return False
        pos = gap + 1
        seen = 0
        top = self.highest_used()
        while pos < top and seen < NUM_BANK_WINDOW:
            r = self.slots[pos]
            if r is not None and pos == self._lead(pos) and r not in self.pinned:
                seen += 1
                if self.width[r] == 1 and self.reg_bank(pos) == self.reg_bank(gap):
                    self.place(pos, gap)
                    return True
            pos += 1
        return False

    def extract_moves(self) -> Dict[int, int]:
        moves: Dict[int, int] = {}
        for i, r in enumerate(self.slots):
            if r is not None and (i == 0 or self.slots[i - 1] != r):
                if i != r:
                    moves[r] = i
                    if self.width.get(r, 1) == 2:
                        # the alias word moves with its pair (code may name
                        # it directly, e.g. MOV32I into the high word)
                        moves[r + 1] = i + 1
        return moves


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def compact(kernel: Kernel, bank_avoid: bool = False) -> Dict[int, int]:
    """Pack the register space in-place, renaming registers in the code.

    Returns the applied rename map (old -> new leading register number)."""
    space = RelocationSpace(kernel)
    moves = space.pack(bank_avoid=bank_avoid)
    if moves:
        _apply_renames(kernel, moves)
    return moves


def _apply_renames(kernel: Kernel, moves: Dict[int, int]) -> None:
    for ins in kernel.instructions():
        ins.dsts = [moves.get(r, r) for r in ins.dsts]
        ins.srcs = [moves.get(r, r) for r in ins.srcs]
    if kernel.rda is not None:
        kernel.rda = moves.get(kernel.rda, kernel.rda)


def packed_reg_count(kernel: Kernel) -> int:
    """Best-achievable register count after compaction (used as the loop
    condition in RegDem's while loop: ``p.reg_count`` post-packing)."""
    widths = folded_widths(kernel)
    pinned = (set(kernel.live_in) | set(kernel.live_out)) & set(widths)
    occupied: Set[int] = set()
    for r in pinned:
        for j in range(widths[r]):
            occupied.add(r + j)
    pairs = sorted(r for r, w in widths.items() if w == 2 and r not in pinned)
    singles = sorted(r for r, w in widths.items() if w == 1 and r not in pinned)
    for _ in pairs:
        pos = 0
        while pos % 2 or pos in occupied or pos + 1 in occupied:
            pos += 1
        occupied |= {pos, pos + 1}
    for _ in singles:
        pos = 0
        while pos in occupied:
            pos += 1
        occupied.add(pos)
    return (max(occupied) + 1) if occupied else 0
