"""RegDem: register demotion to shared memory (paper §3, Fig. 3).

Given a scheduled kernel and a target register count, demote excess
registers to shared memory one at a time:

1. reserve a demoted-base-address register (RDA) and a demoted-value
   register (RDV) — "at least two registers must be added" (§3.2);
2. per candidate: rename every occurrence to RDV, insert ``LDS``/``STS``
   around uses/defs with barriers chosen by the *barrier tracker*
   (``GetBarrier``/``UpdateBarrierTracker`` in Fig. 3);
3. prune candidates with operand conflicts against the demoted register;
4. stop at the target, at 32 registers (no occupancy benefit below), or
   when candidates run out;
5. compact the register space (§3.3) and fix up stall counts.

Shared-memory layout (eq. 1): the r-th demoted word of thread ``t`` lives at
``t*4 + s + r*n*4`` (``s`` = static allocation rounded to bank alignment,
``n`` = threads/block), which is bank-conflict-free by construction.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from .candidates import make_candidates, operand_conflicts, width_map
from .compaction import compact, packed_reg_count
from .isa import (
    GL_MEM_STALL,
    NUM_BARRIERS,
    NUM_REG_BANKS,
    RZ,
    SH_MEM_STALL,
    Ctrl,
    Instr,
    Kernel,
    Label,
    OpClass,
)
from .sched import fixup_stalls

#: Hard floor below which demotion gives no occupancy benefit (paper §3).
REG_FLOOR = 32
#: Maxwell per-block shared memory limit.
SMEM_LIMIT = 48 * 1024


@dataclass
class RegDemOptions:
    """Optimization options (the paper's exhaustive-search dimensions)."""

    candidate_strategy: str = "cfg"      # §3.4.3 (Fig. 8)
    bank_avoid: bool = True              # §3.4.1 (Fig. 7)
    elim_redundant: bool = True          # §3.4.2 pass 1 (Fig. 7)
    reschedule: bool = True              # §3.4.2 pass 2 (Fig. 7)
    substitute: bool = True              # §3.4.2 pass 3 (Fig. 7)

    def label(self) -> str:
        flags = "".join(
            "1" if f else "0"
            for f in (self.bank_avoid, self.elim_redundant, self.reschedule, self.substitute)
        )
        return f"{self.candidate_strategy}:{flags}"


@dataclass
class RegDemResult:
    kernel: Kernel
    demoted: List[Tuple[int, int]]       # (original reg, width)
    demoted_words: int
    rdv: int
    rda: int
    target: int
    options: RegDemOptions
    reached_target: bool
    _rdv_wide: bool = False

    @property
    def demoted_count(self) -> int:
        """Register words moved to shared memory (paper Table 1, "# Registers
        Spilled / RegDem")."""
        return self.demoted_words


# ---------------------------------------------------------------------------
# Barrier tracker (Fig. 3, lines 32-53)
# ---------------------------------------------------------------------------


class BarrierTracker:
    """Tracks which instruction last set each scoreboard barrier and the
    stall cycles elapsed since, to hand out the least-costly barrier."""

    def __init__(self) -> None:
        self.slots: List[Optional[List]] = [None] * NUM_BARRIERS

    def reset(self) -> None:
        """Barriers cannot span basic blocks (cleared before jumps)."""
        self.slots = [None] * NUM_BARRIERS

    def get_barrier(self, setter: Instr) -> int:
        """Fig. 3 ``GetBarrier``: a free barrier, else the one whose pending
        latency is closest to already-elapsed (minimum residual stall).

        When a busy barrier must be reused, the new setter first *waits* on
        it — this is the "additional stalls" the paper describes, made
        explicit so the schedule verifier and simulator see the true cost.
        """
        for b in range(NUM_BARRIERS):
            if self.slots[b] is None:
                self.slots[b] = [setter, 0]
                return b
        best_b, best_stall = None, GL_MEM_STALL + 1
        for b in range(NUM_BARRIERS):
            inst, elapsed = self.slots[b]
            if inst.info.klass is OpClass.LSU_GLOBAL or inst.info.klass is OpClass.LSU_LOCAL:
                residual = GL_MEM_STALL - elapsed
            elif inst.info.klass is OpClass.LSU_SHARED:
                residual = SH_MEM_STALL - elapsed
            else:
                residual = inst.info.klass.latency - elapsed
            if residual < best_stall:
                best_b, best_stall = b, residual
        setter.ctrl.wait.add(best_b)
        self.slots[best_b] = [setter, 0]
        return best_b

    def update(self, inst: Instr) -> None:
        """Fig. 3 ``UpdateBarrierTracker`` (waits cleared before records so
        that a forced reuse in :meth:`get_barrier` stays consistent)."""
        for b in inst.ctrl.wait:
            if self.slots[b] is not None and self.slots[b][0] is not inst:
                self.slots[b] = None
        if inst.ctrl.read_bar is not None:
            self.slots[inst.ctrl.read_bar] = [inst, 0]
        if inst.ctrl.write_bar is not None:
            self.slots[inst.ctrl.write_bar] = [inst, 0]
        for b in range(NUM_BARRIERS):
            if self.slots[b] is not None and self.slots[b][0] is not inst:
                self.slots[b][1] += inst.ctrl.stall


# ---------------------------------------------------------------------------
# RDV bank choice (§3.4.1, first strategy)
# ---------------------------------------------------------------------------


def choose_rdv_bank(kernel: Kernel, candidates: Sequence[Tuple[int, int]], wide: bool) -> int:
    """Pick the register bank for RDV minimizing same-instruction conflicts.

    For every instruction that touches a candidate register, count the source
    operands (post-rename survivors) that would share RDV's bank.
    """
    cand_regs = {r for r, _ in candidates}
    banks = [0, 2] if wide else [0, 1, 2, 3]
    scores = {b: 0 for b in banks}
    for ins in kernel.instructions():
        touched = [r for r in ins.leading_regs() if r in cand_regs]
        if not touched:
            continue
        others = [r for r in ins.src_words() if r not in cand_regs and r != RZ]
        for b in banks:
            scores[b] += sum(1 for r in others if r % 4 == b)
    return min(banks, key=lambda b: (scores[b], b))


# ---------------------------------------------------------------------------
# The demotion transformation
# ---------------------------------------------------------------------------


def _round4(x: int) -> int:
    return (x + 3) // 4 * 4


def demote(
    kernel: Kernel,
    target_regs: int,
    options: Optional[RegDemOptions] = None,
) -> RegDemResult:
    """Run RegDem on ``kernel`` toward ``target_regs``; returns a new kernel."""
    from . import postopt  # local import: postopt imports nothing from here

    options = options or RegDemOptions()
    k = kernel.copy()
    n = k.threads_per_block
    s_up = _round4(k.shared_size)

    candidates = make_candidates(k, options.candidate_strategy)
    conflicts = operand_conflicts(k)

    # ---- reserve RDV (+ alias if any pair candidates) and RDA --------------
    wide = any(w == 2 for _, w in candidates)
    base = k.reg_count
    if wide and base % 2:
        base += 1  # RDV must be even-numbered for pair demotion (§3.2)
    if options.bank_avoid:
        want_bank = choose_rdv_bank(k, candidates, wide)
        rdv = base
        step = 2 if wide else 1
        while rdv % NUM_REG_BANKS != want_bank:
            rdv += step
    else:
        rdv = base
    rda = rdv + (2 if wide else 1)
    k.rda = rda

    # ---- prologue: RDA = tid * 4 (eq. 1 base address) -----------------------
    s2r = Instr("S2R", [rdv], ctrl=Ctrl(stall=1))
    shl = Instr("SHL", [rda], [rdv], imm=2.0, ctrl=Ctrl(stall=1))
    tracker = BarrierTracker()
    s2r.ctrl.write_bar = tracker.get_barrier(s2r)
    shl.ctrl.wait.add(s2r.ctrl.write_bar)
    k.items[:0] = [s2r, shl]

    demoted: List[Tuple[int, int]] = []
    demoted_words = 0

    while candidates:
        eff = packed_reg_count(k)
        if eff <= max(target_regs, REG_FLOOR):
            break
        r, width = candidates.pop(0)
        offsets = [s_up + (demoted_words + j) * n * 4 for j in range(width)]
        _demote_one(k, r, width, offsets, rdv, rda)
        demoted.append((r, width))
        demoted_words += width
        k.demoted_size = demoted_words * n * 4
        if k.total_shared > SMEM_LIMIT:
            raise ValueError(f"{k.name}: demotion exceeds shared memory limit")
        # prune operand conflicts (§3.1 challenge 2)
        bad = conflicts.get(r, set())
        candidates = [(c, w) for c, w in candidates if c not in bad]

    # ---- redundancy elimination, compaction (§3.3), then the schedule-level
    # post-spilling optimizations (§3.4.2) on the packed register space ------
    if options.elim_redundant:
        postopt.eliminate_redundant(k, rdv)
    moves = compact(k, bank_avoid=options.bank_avoid)
    rdv = moves.get(rdv, rdv)
    rda = k.rda if k.rda is not None else rda
    if options.substitute:
        postopt.substitute_value_register(k, rdv, k.reg_count)
    if options.reschedule:
        postopt.reschedule(k, rdv, rda)
    fixup_stalls(k)

    res = RegDemResult(
        kernel=k,
        demoted=demoted,
        demoted_words=demoted_words,
        rdv=rdv,
        rda=rda,
        target=target_regs,
        options=options,
        reached_target=k.reg_count <= max(target_regs, REG_FLOOR),
    )
    res._rdv_wide = wide
    return res


def _demote_one(
    k: Kernel,
    r: int,
    width: int,
    offsets: List[int],
    rdv: int,
    rda: int,
    load_op: str = "LDS",
    store_op: str = "STS",
) -> None:
    """Demote one register (Fig. 3 main loop body): walk the program,
    rename ``r`` -> RDV, insert demoted loads/stores with tracked barriers.

    Parameterized over the spill space: (``LDS``/``STS``, rda=tid*4) realizes
    RegDem's shared-memory demotion; (``LDL``/``STL``, rda=RZ) realizes
    nvcc-style local-memory spilling for the comparison variants (§5.3)."""
    tracker = BarrierTracker()
    new_items: List[object] = []
    #: waits to attach to the next real instruction (line 18-19 of Fig. 3)
    pending_next_wait: Set[int] = set()
    #: register word -> unresolved read barrier guarding it (a store still
    #: holds the register as a source operand).  A new writer of the word —
    #: e.g. an inserted demoted load clobbering RDV after a *user* store
    #: whose address register was demoted — must wait on it (WAR).
    pending_read: Dict[int, int] = {}
    prev_real: Optional[Instr] = None

    def append(ins_or_label) -> None:
        nonlocal prev_real
        new_items.append(ins_or_label)
        if isinstance(ins_or_label, Instr):
            nonlocal pending_next_wait
            ins = ins_or_label
            if pending_next_wait:
                ins.ctrl.wait |= pending_next_wait
                pending_next_wait = set()
            # WAR guard against in-flight store reads
            for rw in ins.dst_words():
                if rw in pending_read:
                    ins.ctrl.wait.add(pending_read.pop(rw))
            for b in ins.ctrl.wait:
                for rw in [r for r, bb in pending_read.items() if bb == b]:
                    del pending_read[rw]
            if ins.ctrl.read_bar is not None:
                for rw in ins.src_words():
                    if rw != RZ:
                        pending_read[rw] = ins.ctrl.read_bar
            tracker.update(ins)
            prev_real = ins

    for it in k.items:
        if isinstance(it, Label):
            tracker.reset()
            pending_read.clear()
            new_items.append(it)
            continue
        ins: Instr = it
        if ins.info.is_branch:
            tracker.reset()
            pending_read.clear()
        if r not in ins.leading_regs():
            append(ins)
            continue

        is_dst = r in ins.dsts
        is_src = r in ins.srcs
        ins.rename(r, rdv)

        # ---- read access: LDS RDV, [RDA+offset] before inst (lines 20-29) --
        if is_src:
            for j in range(width):
                lds = Instr(
                    load_op,
                    [rdv + j],
                    [rda],
                    offset=offsets[j],
                    pred=ins.pred,
                    pred_neg=ins.pred_neg,
                    tag="demoted_load",
                )
                lds.ctrl.read_bar = tracker.get_barrier(lds)
                lds.ctrl.write_bar = tracker.get_barrier(lds)
                ins.ctrl.wait.add(lds.ctrl.read_bar)
                ins.ctrl.wait.add(lds.ctrl.write_bar)
                if (
                    prev_real is not None
                    and prev_real.tag == "demoted_store"
                    and prev_real.ctrl.read_bar is not None
                ):
                    # RDV must be free before the demoted register is loaded
                    lds.ctrl.wait.add(prev_real.ctrl.read_bar)
                append(lds)
        append(ins)

        # ---- write access: STS [RDA+offset], RDV after inst (lines 11-19) --
        if is_dst:
            for j in range(width):
                sts = Instr(
                    store_op,
                    srcs=[rda, rdv + j],
                    offset=offsets[j],
                    pred=ins.pred,
                    pred_neg=ins.pred_neg,
                    tag="demoted_store",
                )
                if ins.info.needs_write_barrier and ins.ctrl.write_bar is None:
                    ins.ctrl.write_bar = tracker.get_barrier(ins)
                if ins.ctrl.write_bar is not None:
                    sts.ctrl.wait.add(ins.ctrl.write_bar)
                sts.ctrl.read_bar = tracker.get_barrier(sts)
                append(sts)
                # the *next* instruction must wait for RDV to be read back out
                # (Fig. 3 lines 18-19) — recorded after append so the store
                # does not wait on its own barrier
                pending_next_wait.add(sts.ctrl.read_bar)

    # drain: if the stream ended with a pending wait, park it on the last
    # real instruction (kernels end in EXIT, so this is the normal path)
    if pending_next_wait and prev_real is not None:
        prev_real.ctrl.wait |= pending_next_wait
    k.items = new_items


# ---------------------------------------------------------------------------
# Driver: pick spill targets automatically (paper §3 "automatic utility")
# ---------------------------------------------------------------------------


def auto_targets(kernel: Kernel) -> List[int]:
    from .occupancy import spill_targets

    return spill_targets(
        kernel.reg_count,
        kernel.threads_per_block,
        kernel.shared_size,
        available_smem=SMEM_LIMIT - kernel.shared_size,
    )
