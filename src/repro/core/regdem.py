"""RegDem: register demotion to shared memory (paper §3, Fig. 3).

Given a scheduled kernel and a target register count, demote excess
registers to shared memory one at a time:

1. reserve a demoted-base-address register (RDA) and a demoted-value
   register (RDV) — "at least two registers must be added" (§3.2);
2. per candidate: rename every occurrence to RDV, insert ``LDS``/``STS``
   around uses/defs with barriers chosen by the *barrier tracker*
   (``GetBarrier``/``UpdateBarrierTracker`` in Fig. 3);
3. prune candidates with operand conflicts against the demoted register;
4. stop at the target, at 32 registers (no occupancy benefit below), or
   when candidates run out;
5. compact the register space (§3.3) and fix up stall counts.

Shared-memory layout (eq. 1): the r-th demoted word of thread ``t`` lives at
``t*4 + s + r*n*4`` (``s`` = static allocation rounded to bank alignment,
``n`` = threads/block), which is bank-conflict-free by construction.

:func:`demote` is a thin configuration of the unified pass pipeline
(:mod:`repro.core.passes`): it binds a :class:`~repro.core.spillspace.
SharedSpace` to :func:`repro.core.passes.demotion_pipeline` and packages the
pipeline outcome as a :class:`RegDemResult`.  The demotion machinery itself
(barrier tracker, per-register transform, pass implementations) lives in
:mod:`repro.core.passes`, shared with the §5.3 comparison variants.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Tuple

from .isa import Kernel
from .passes import (  # noqa: F401  (re-exported: historical home of these names)
    REG_FLOOR,
    BarrierTracker,
    PassContext,
    PassStat,
    RegDemOptions,
    choose_rdv_bank,
    demote_register,
    demotion_pipeline,
    stats_by_pass,
)
from .spillspace import SMEM_LIMIT, SharedSpace  # noqa: F401  (re-exported)


@dataclass
class RegDemResult:
    kernel: Kernel
    demoted: List[Tuple[int, int]]       # (original reg, width)
    demoted_words: int
    rdv: int
    rda: int
    target: int
    options: RegDemOptions
    reached_target: bool
    #: per-pass diagnostics/timings from the pipeline run, in order
    passes: List[PassStat] = field(default_factory=list)
    _rdv_wide: bool = False

    @property
    def demoted_count(self) -> int:
        """Register words moved to shared memory (paper Table 1, "# Registers
        Spilled / RegDem")."""
        return self.demoted_words

    def pass_stats(self) -> dict:
        """Per-pass stats keyed by pass name (re-runs suffixed ``#n``, see
        :func:`repro.core.passes.stats_by_pass`)."""
        return stats_by_pass(self.passes)


def demote(
    kernel: Kernel,
    target_regs: int,
    options: Optional[RegDemOptions] = None,
    verify: str = "each",
    space=None,
    select=None,
    pipeline=None,
    observer=None,
) -> RegDemResult:
    """Run RegDem on ``kernel`` toward ``target_regs``; returns a new kernel.

    ``verify`` is the pipeline self-check policy (see
    :class:`repro.core.passes.PassPipeline`); the default proves schedule
    validity and dataflow equivalence after every pass.

    The remaining keywords are the strategy-registry extension points
    (:mod:`repro.core.strategies`): ``space`` overrides the
    :class:`~repro.core.spillspace.SharedSpace` destination, ``select``
    overrides the candidate queue builder, ``pipeline`` replaces the
    standard :func:`~repro.core.passes.demotion_pipeline` schedule (its own
    verify policy then applies), and ``observer`` is forwarded to
    :meth:`~repro.core.passes.PassPipeline.run` (per-pass hooks for the
    prefix-invariant property tests).
    """
    options = options or RegDemOptions()
    ctx = PassContext(
        kernel,
        SharedSpace() if space is None else space,
        options,
        target=target_regs,
        select=select,
    )
    pipe = pipeline if pipeline is not None else demotion_pipeline(options, verify=verify)
    pipe.run(ctx, observer=observer)
    res = RegDemResult(
        kernel=ctx.kernel,
        demoted=ctx.demoted,
        demoted_words=ctx.demoted_words,
        rdv=ctx.rdv,
        rda=ctx.rda,
        target=target_regs,
        options=options,
        reached_target=ctx.kernel.reg_count <= ctx.floor,
        passes=ctx.passes,
    )
    res._rdv_wide = ctx.wide
    return res


# ---------------------------------------------------------------------------
# Driver: pick spill targets automatically (paper §3 "automatic utility")
# ---------------------------------------------------------------------------


def auto_targets(kernel: Kernel, max_targets: Optional[int] = None) -> List[int]:
    """Occupancy-cliff register targets for ``kernel`` under its own
    architecture's SM limits and spill budget, best-first.

    ``max_targets`` truncates the ladder (the autotuning search uses it to
    bound the variant space per kernel; ``None`` keeps every cliff)."""
    from repro.arch import arch_of

    from .occupancy import spill_targets

    arch = arch_of(kernel)
    targets = spill_targets(
        kernel.reg_count,
        kernel.threads_per_block,
        kernel.shared_size,
        available_smem=arch.smem_spill_limit - kernel.shared_size,
        sm=arch.sm,
    )
    if max_targets is not None:
        targets = targets[:max_targets]
    return targets
