"""Spill spaces: where demoted/spilled register words live.

The paper's transformation stack is parameterized over *where* a spilled
word goes.  RegDem demotes to **shared memory** (eq. 1 layout, ``LDS``/
``STS``, a per-thread base register computed in a prologue); the nvcc
``--maxrregcount`` comparison variants spill to off-chip **local memory**
(``LDL``/``STL``, thread-indexed by the hardware, no base register).  Both
also underlie the research alternatives the §5.3 variants model.

:class:`SpillSpace` captures that choice as one object handed to the pass
pipeline (:mod:`repro.core.passes`) instead of the ``load_op``/``store_op``/
``rda`` parameter plumbing that used to thread through the demotion loop:

* :class:`SharedSpace` — RegDem's bank-conflict-free shared-memory layout
  (eq. 1): the r-th demoted word of thread ``t`` lives at
  ``t*4 + s + r*n*4`` (``s`` = static allocation rounded up to word
  alignment, ``n`` = threads/block).  Needs a base register (RDA = tid*4)
  and accounts every spilled word against the 48 KiB Maxwell limit.
* :class:`LocalSpace` — nvcc-style local-memory spill slots at
  ``r*4``; the hardware scales by thread, so no base register and no
  shared-memory accounting.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from .passes import PassContext

#: Maxwell per-block shared memory limit (bytes).  Per-arch budgets come
#: from the :mod:`repro.arch` registry (see :func:`spill_limit`).
SMEM_LIMIT = 48 * 1024


def spill_limit(kernel) -> int:
    """The per-block shared-memory budget demotion may spill into, from the
    kernel's architecture (Maxwell 48 KiB, Volta/Turing 96 KiB)."""
    from repro.arch import arch_of

    return arch_of(kernel).smem_spill_limit


def _round4(x: int) -> int:
    return (x + 3) // 4 * 4


class SpillSpace:
    """Where spilled register words live: opcodes, addressing, accounting."""

    #: human-readable space name (diagnostics / pass stats)
    name: str = "abstract"
    #: opcode loading one spilled word back into the value register
    load_op: str = "LD?"
    #: opcode storing the value register out to the spill slot
    store_op: str = "ST?"
    #: whether demoted addressing needs a reserved base register (RDA)
    needs_base: bool = False

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        """Byte offsets of the next ``width`` spill slots (the next demoted
        word index is ``ctx.demoted_words``)."""
        raise NotImplementedError

    def emit_prologue(self, ctx: "PassContext") -> int:
        """Emit base-address setup at kernel entry; returns #instructions
        inserted.  Default: the space needs no prologue."""
        return 0

    def account(self, ctx: "PassContext") -> None:
        """Update per-kernel bookkeeping after a register was spilled."""


class SharedSpace(SpillSpace):
    """RegDem's demoted-register space in unused shared memory (eq. 1)."""

    name = "shared"
    load_op = "LDS"
    store_op = "STS"
    needs_base = True

    def __init__(self, check_limit: bool = True):
        #: raise when demotion would exceed the hardware shared-memory limit
        #: (RegDem refuses; the Hayes & Zhang conversion variants historically
        #: did not guard, so the comparison pipeline disables the check)
        self.check_limit = check_limit

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        n = ctx.kernel.threads_per_block
        s_up = _round4(ctx.kernel.shared_size)
        return [s_up + (ctx.demoted_words + j) * n * 4 for j in range(width)]

    def emit_prologue(self, ctx: "PassContext") -> int:
        # RDA = tid * 4 (eq. 1 base address), barriers via the tracker
        from .isa import Ctrl, Instr
        from .passes import BarrierTracker

        s2r = Instr("S2R", [ctx.rdv], ctrl=Ctrl(stall=1))
        shl = Instr("SHL", [ctx.rda], [ctx.rdv], imm=2.0, ctrl=Ctrl(stall=1))
        tracker = BarrierTracker(ctx.arch)
        s2r.ctrl.write_bar = tracker.get_barrier(s2r)
        shl.ctrl.wait.add(s2r.ctrl.write_bar)
        ctx.kernel.items[:0] = [s2r, shl]
        return 2

    def account(self, ctx: "PassContext") -> None:
        k = ctx.kernel
        k.demoted_size = ctx.demoted_words * k.threads_per_block * 4
        limit = spill_limit(k)
        if self.check_limit and k.total_shared > limit:
            raise ValueError(
                f"{k.name}: demotion exceeds shared memory limit "
                f"({limit // 1024} KiB on arch {k.arch!r})"
            )


class LocalSpace(SpillSpace):
    """nvcc-style local-memory spill slots (per-thread, hardware-indexed)."""

    name = "local"
    load_op = "LDL"
    store_op = "STL"
    needs_base = False

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        return [(ctx.demoted_words + j) * 4 for j in range(width)]


def spill_space(name: str, **kwargs) -> SpillSpace:
    """Look up a spill space by name (``"shared"`` / ``"local"``); keyword
    arguments are forwarded to the space constructor (e.g.
    ``spill_space("shared", check_limit=False)``)."""
    if name == "shared":
        return SharedSpace(**kwargs)
    if name == "local":
        return LocalSpace(**kwargs)
    raise ValueError(f"unknown spill space {name!r}; want 'shared' or 'local'")
