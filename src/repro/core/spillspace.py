"""Spill spaces: where demoted/spilled register words live.

The paper's transformation stack is parameterized over *where* a spilled
word goes.  RegDem demotes to **shared memory** (eq. 1 layout, ``LDS``/
``STS``, a per-thread base register computed in a prologue); the nvcc
``--maxrregcount`` comparison variants spill to off-chip **local memory**
(``LDL``/``STL``, thread-indexed by the hardware, no base register).  Both
also underlie the research alternatives the §5.3 variants model.

:class:`SpillSpace` captures that choice as one object handed to the pass
pipeline (:mod:`repro.core.passes`) instead of the ``load_op``/``store_op``/
``rda`` parameter plumbing that used to thread through the demotion loop:

* :class:`SharedSpace` — RegDem's bank-conflict-free shared-memory layout
  (eq. 1): the r-th demoted word of thread ``t`` lives at
  ``t*4 + s + r*n*4`` (``s`` = static allocation rounded up to word
  alignment, ``n`` = threads/block).  Needs a base register (RDA = tid*4)
  and accounts every spilled word against the 48 KiB Maxwell limit.
* :class:`LocalSpace` — nvcc-style local-memory spill slots at
  ``r*4``; the hardware scales by thread, so no base register and no
  shared-memory accounting.
"""

from __future__ import annotations

from typing import TYPE_CHECKING, List

if TYPE_CHECKING:  # pragma: no cover - import cycle guard (typing only)
    from .passes import PassContext

#: Maxwell per-block shared memory limit (bytes).  Per-arch budgets come
#: from the :mod:`repro.arch` registry (see :func:`spill_limit`).
SMEM_LIMIT = 48 * 1024


def spill_limit(kernel) -> int:
    """The per-block shared-memory budget demotion may spill into, from the
    kernel's architecture (Maxwell 48 KiB, Volta/Turing 96 KiB)."""
    from repro.arch import arch_of

    return arch_of(kernel).smem_spill_limit


def _round4(x: int) -> int:
    return (x + 3) // 4 * 4


class SpillSpace:
    """Where spilled register words live: opcodes, addressing, accounting."""

    #: human-readable space name (diagnostics / pass stats)
    name: str = "abstract"
    #: opcode loading one spilled word back into the value register
    load_op: str = "LD?"
    #: opcode storing the value register out to the spill slot
    store_op: str = "ST?"
    #: whether demoted addressing needs a reserved base register (RDA)
    needs_base: bool = False
    #: opcode packing the value register before each demoted store / after
    #: each demoted load (``None`` = values go to slots verbatim).  Set by
    #: the compressed-slot space (arXiv 2006.05693).
    pack_op: "str | None" = None
    unpack_op: "str | None" = None

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        """Byte offsets of the next ``width`` spill slots (the next demoted
        word index is ``ctx.demoted_words``)."""
        raise NotImplementedError

    def emit_prologue(self, ctx: "PassContext") -> int:
        """Emit base-address setup at kernel entry; returns #instructions
        inserted.  Default: the space needs no prologue."""
        return 0

    def has_room(self, ctx: "PassContext", width: int) -> bool:
        """Whether the space can hold ``width`` more demoted words.  The
        demotion loop checks this *before* popping a candidate, so a space
        with a hard capacity (e.g. the cross-block carve pool) stops the
        demotion gracefully instead of raising mid-pipeline."""
        return True

    def account(self, ctx: "PassContext") -> None:
        """Update per-kernel bookkeeping after a register was spilled."""


class SharedSpace(SpillSpace):
    """RegDem's demoted-register space in unused shared memory (eq. 1)."""

    name = "shared"
    load_op = "LDS"
    store_op = "STS"
    needs_base = True

    def __init__(self, check_limit: bool = True):
        #: raise when demotion would exceed the hardware shared-memory limit
        #: (RegDem refuses; the Hayes & Zhang conversion variants historically
        #: did not guard, so the comparison pipeline disables the check)
        self.check_limit = check_limit

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        n = ctx.kernel.threads_per_block
        s_up = _round4(ctx.kernel.shared_size)
        return [s_up + (ctx.demoted_words + j) * n * 4 for j in range(width)]

    def emit_prologue(self, ctx: "PassContext") -> int:
        # RDA = tid * 4 (eq. 1 base address), barriers via the tracker
        from .isa import Ctrl, Instr
        from .passes import BarrierTracker

        s2r = Instr("S2R", [ctx.rdv], ctrl=Ctrl(stall=1))
        shl = Instr("SHL", [ctx.rda], [ctx.rdv], imm=2.0, ctrl=Ctrl(stall=1))
        tracker = BarrierTracker(ctx.arch)
        s2r.ctrl.write_bar = tracker.get_barrier(s2r)
        shl.ctrl.wait.add(s2r.ctrl.write_bar)
        ctx.kernel.items[:0] = [s2r, shl]
        return 2

    def account(self, ctx: "PassContext") -> None:
        k = ctx.kernel
        k.demoted_size = ctx.demoted_words * k.threads_per_block * 4
        limit = spill_limit(k)
        if self.check_limit and k.total_shared > limit:
            raise ValueError(
                f"{k.name}: demotion exceeds shared memory limit "
                f"({limit // 1024} KiB on arch {k.arch!r})"
            )


class LocalSpace(SpillSpace):
    """nvcc-style local-memory spill slots (per-thread, hardware-indexed)."""

    name = "local"
    load_op = "LDL"
    store_op = "STL"
    needs_base = False

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        return [(ctx.demoted_words + j) * 4 for j in range(width)]


class WarpPoolSpace(SpillSpace):
    """Warp-level register resource sharing (arXiv 1503.05694).

    Demoted words live in a register-file-backed slot pool shared by
    ``share`` co-scheduled warps (``LDP``/``STP``, MISC class — a
    near-register-file port, cheaper than the shared-memory path and with
    zero shared-memory footprint).  The pool is hardware thread-indexed, so
    no base register; the per-warp register cost — each warp's share of the
    pool, ``ceil(demoted_words / share)`` registers — is charged honestly
    by :class:`~repro.core.passes.PoolAnchorPass` after compaction.
    """

    name = "warp_pool"
    load_op = "LDP"
    store_op = "STP"
    needs_base = False

    def __init__(self, share: int = 2):
        if share < 2:
            raise ValueError(f"warp pool needs share >= 2 warps, got {share}")
        #: co-scheduled warps sharing the pool
        self.share = share

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        return [(ctx.demoted_words + j) * 4 for j in range(width)]


class CarveSpace(SharedSpace):
    """Scratchpad sharing across thread blocks (arXiv 1607.03238).

    Demotion slots are carved from the *per-SM* scratchpad pool left unused
    by resident blocks' allocations, instead of this block's own budget —
    same eq.-1 layout and ``LDS``/``STS`` access path as
    :class:`SharedSpace`, but ``demoted_size`` stays zero (nothing is
    charged against this block's allocation, so the occupancy calculator
    sees no shared-memory growth).  Feasibility is a per-SM budget instead:
    every resident block needs its carve alongside every block's static
    allocation, checked in :meth:`has_room` so the demotion loop stops
    gracefully when the SM pool is exhausted.
    """

    name = "carve"

    def __init__(self):
        super().__init__(check_limit=False)

    def _carve_budget(self, ctx: "PassContext", extra_words: int) -> bool:
        from repro.arch import arch_of

        from .occupancy import _ceil_to, occupancy

        k = ctx.kernel
        sm = arch_of(k).sm
        carve = (ctx.demoted_words + extra_words) * k.threads_per_block * 4
        # resident blocks at the demotion target: the whole point is the
        # post-demotion occupancy, so the carve must fit at that block count
        occ = occupancy(max(ctx.floor, 32), k.threads_per_block, k.shared_size, sm)
        static = _ceil_to(k.shared_size, sm.smem_alloc_unit) if k.shared_size else 0
        return occ.resident_blocks * (static + carve) <= sm.smem_bytes

    def has_room(self, ctx: "PassContext", width: int) -> bool:
        return self._carve_budget(ctx, width)

    def account(self, ctx: "PassContext") -> None:
        # nothing lands in this block's own allocation; the per-SM pool
        # budget was enforced by has_room before the demotion ran
        pass


class CompressedSpace(SharedSpace):
    """Compressed spill slots (arXiv 2006.05693).

    Demoted values are packed by static compression to 2-byte slots —
    half the eq.-1 shared-memory footprint — at the cost of one ALU
    ``PCK`` before every demoted store and one ``UPCK`` after every
    demoted load.  Only width-1 registers are compressible (pairs keep
    full-precision lanes), which the strategy's candidate filter enforces.
    """

    name = "compressed"
    pack_op = "PCK"
    unpack_op = "UPCK"

    #: bytes per compressed slot (vs 4 for a full word)
    SLOT_BYTES = 2

    def offsets(self, ctx: "PassContext", width: int) -> List[int]:
        n = ctx.kernel.threads_per_block
        s_up = _round4(ctx.kernel.shared_size)
        return [
            s_up + (ctx.demoted_words + j) * n * self.SLOT_BYTES
            for j in range(width)
        ]

    def emit_prologue(self, ctx: "PassContext") -> int:
        # RDA = tid * SLOT_BYTES: the eq.-1 base scaled to compressed slots
        from .isa import Ctrl, Instr
        from .passes import BarrierTracker

        s2r = Instr("S2R", [ctx.rdv], ctrl=Ctrl(stall=1))
        shl = Instr("SHL", [ctx.rda], [ctx.rdv], imm=1.0, ctrl=Ctrl(stall=1))
        tracker = BarrierTracker(ctx.arch)
        s2r.ctrl.write_bar = tracker.get_barrier(s2r)
        shl.ctrl.wait.add(s2r.ctrl.write_bar)
        ctx.kernel.items[:0] = [s2r, shl]
        return 2

    def account(self, ctx: "PassContext") -> None:
        k = ctx.kernel
        k.demoted_size = ctx.demoted_words * k.threads_per_block * self.SLOT_BYTES
        limit = spill_limit(k)
        if self.check_limit and k.total_shared > limit:
            raise ValueError(
                f"{k.name}: compressed demotion exceeds shared memory limit "
                f"({limit // 1024} KiB on arch {k.arch!r})"
            )


def spill_space(name: str, **kwargs) -> SpillSpace:
    """Look up a spill space by name; keyword arguments are forwarded to the
    space constructor (e.g. ``spill_space("shared", check_limit=False)``)."""
    spaces = {
        "shared": SharedSpace,
        "local": LocalSpace,
        "warp_pool": WarpPoolSpace,
        "carve": CarveSpace,
        "compressed": CompressedSpace,
    }
    if name not in spaces:
        raise ValueError(
            f"unknown spill space {name!r}; want one of {sorted(spaces)}"
        )
    return spaces[name](**kwargs)
