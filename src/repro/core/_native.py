"""Loader for the natively compiled issue loop (``_sim_engine.c``).

The simulator's hot loop is plain scalar arithmetic over a few small
arrays — exactly the shape CPython is slowest at and a C compiler is best
at.  This module compiles ``_sim_engine.c`` once per machine with the
toolchain's C compiler (no third-party dependency; the image bakes the
compiler in), caches the shared object keyed by the source hash, and
exposes the entry point with the same signature as
:func:`repro.core.simulator._issue_loop`.

Everything is optional: if the compiler is missing, the build fails, or
``REGDEM_SIM_NATIVE=0`` is set, :func:`engine` returns ``None`` and the
simulator silently runs its pure-Python loop — which is state-for-state
identical (the conformance test drives both engines over the benchmark
suite, profiled and checkpointed runs included).
"""

from __future__ import annotations

import ctypes
import hashlib
import os
import subprocess
import tempfile
import warnings
from typing import List, Optional

import numpy as np

from repro import obs
from repro.obs.stallprof import R_BANK, R_BAR, R_MEM, R_STALL, R_UNIT

#: reason-code order pinned by ``_sim_engine.c`` (REASON_* enum)
REASON_LIST = [R_STALL, R_BANK, R_MEM, R_BAR, R_UNIT]
REASON_INDEX = {r: i for i, r in enumerate(REASON_LIST)}
N_REASONS = len(REASON_LIST)

_SOURCE = os.path.join(os.path.dirname(__file__), "_sim_engine.c")

_fn = None
_failed = False
_warned = False


def _warn_fallback(exc: Exception) -> None:
    """The compile failed: say so **once** and count it, instead of
    silently serving ~20x lower simulator throughput.  In production the
    ``simulator.native_unavailable`` counter is the diagnosable signal
    (warnings scroll away; ``metrics_snapshot()`` does not)."""
    global _warned
    if obs.enabled():
        obs.metrics().counter("simulator.native_unavailable").inc()
    if not _warned:
        _warned = True
        warnings.warn(
            f"native simulator engine unavailable ({exc!r}); falling back "
            "to the pure-Python issue loop (~20x slower; results are "
            "identical). Set CC to a working C compiler, or set "
            "REGDEM_SIM_NATIVE=0 to silence this warning.",
            RuntimeWarning,
            stacklevel=4,
        )


def _cache_dir() -> str:
    override = os.environ.get("REGDEM_NATIVE_CACHE")
    if override:
        return override
    # repo-local build cache (src/repro/core -> repo root); fall back to the
    # system temp dir when the tree is read-only
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", ".."))
    cand = os.path.join(root, ".sim_cache")
    try:
        os.makedirs(cand, exist_ok=True)
        return cand
    except OSError:
        return tempfile.gettempdir()


def _compile():
    with open(_SOURCE, "rb") as fh:
        source = fh.read()
    digest = hashlib.sha256(source).hexdigest()[:16]
    so_path = os.path.join(_cache_dir(), f"regdem_sim_{digest}.so")
    if not os.path.exists(so_path):
        cc = os.environ.get("CC", "cc")
        tmp = f"{so_path}.tmp.{os.getpid()}"
        subprocess.run(
            [cc, "-O2", "-shared", "-fPIC", "-o", tmp, _SOURCE, "-lm"],
            check=True,
            capture_output=True,
        )
        os.replace(tmp, so_path)  # atomic: concurrent builders agree
    lib = ctypes.CDLL(so_path)
    fn = lib.regdem_issue_loop
    fn.restype = ctypes.c_int64
    fn.argtypes = [ctypes.c_void_p] * 27
    return fn


def available() -> bool:
    """True when the compiled engine is (or can be made) loadable."""
    return engine() is not None


def engine():
    """The native issue-loop entry point, or ``None`` (Python fallback)."""
    global _fn, _failed
    if os.environ.get("REGDEM_SIM_NATIVE", "1").lower() in ("0", "off", "false"):
        return None
    if _failed:
        return None
    if _fn is None:
        try:
            _fn = _compile()
        except Exception as exc:
            _failed = True
            _warn_fallback(exc)
            return None
    return _run


def _waits_flat(ct):
    flat = getattr(ct, "_waits_flat", None)
    if flat is None:
        n_records = len(ct.klass)
        off = np.zeros(n_records + 1, np.int64)
        data: List[int] = []
        for j, ws in enumerate(ct.waits):
            data.extend(ws)
            off[j + 1] = len(data)
        flat = (off, np.asarray(data, dtype=np.int64))
        ct._waits_flat = flat
    return flat


def _run(
    ct,
    n_warps: int,
    max_cycles: int,
    intervals: Optional[List[float]] = None,
    issue_width: int = 4,
    num_barriers: int = 6,
    blame=None,
    resume=None,
    capture=None,
):
    """Marshal one :func:`_issue_loop` call into the compiled engine."""
    from . import simulator as _sim

    n_trace = len(ct.code)
    if n_trace == 0:
        return 0.0, 0
    if intervals is None:
        intervals = _sim._KLASS_INTERVAL
    n_records = len(ct.klass)
    nb = num_barriers
    nc = len(intervals)
    profile = blame is not None
    wait_off, wait_data = _waits_flat(ct)

    pc = np.zeros(n_warps, np.int64)
    next_time = np.zeros(n_warps, np.float64)
    bars = np.zeros(n_warps * nb, np.float64)
    unit_free = np.zeros(nc, np.float64)
    intervals_a = np.asarray(intervals, np.float64)
    rr = 0
    cycle0 = 0.0
    idle0 = 0
    frontier0 = 0
    blame_a = warp_blame = bar_setter = None
    if profile:
        blame_a = np.zeros(n_records * N_REASONS, np.int64)
        warp_blame = np.zeros(n_warps * 2, np.int64)
        warp_blame[0::2] = int(ct.code[0])  # (first record, R_STALL)
        bar_setter = np.full(n_warps * nb, -1, np.int64)
    if resume is not None:
        pc[:] = resume.pc
        next_time[:] = resume.next_time
        bars[:] = np.asarray(resume.bars, np.float64).ravel()
        unit_free[:] = resume.unit_free
        rr = resume.rr
        cycle0 = resume.cycle
        idle0 = resume.idle_cycles
        frontier0 = resume.frontier
        if profile:
            for (rec, reason), c in resume.blame.items():
                blame_a[rec * N_REASONS + REASON_INDEX[reason]] += c
            for w, (rec, reason) in enumerate(resume.warp_blame):
                warp_blame[2 * w] = rec
                warp_blame[2 * w + 1] = REASON_INDEX[reason]
            bar_setter[:] = np.asarray(resume.bar_setter, np.int64).ravel()

    # capture milestones: same rule the Python loop applies
    thresholds: List[int] = []
    if capture is not None and n_trace >= _sim._CKPT_MIN_TRACE:
        marks = {n_trace // d for d in _sim._CKPT_FRACTIONS}
        marks.add((3 * n_trace) // 4)
        thresholds = sorted(m for m in marks if frontier0 < m < n_trace)
    n_thr = len(thresholds)
    slot_i = 3 + 3 * n_warps + n_warps * nb
    slot_d = 1 + n_warps + n_warps * nb + nc
    thr_a = np.asarray(thresholds, np.int64) if n_thr else None
    cap_i = np.zeros(n_thr * slot_i, np.int64) if n_thr else None
    cap_d = np.zeros(n_thr * slot_d, np.float64) if n_thr else None
    cap_blame = (
        np.zeros(n_thr * n_records * N_REASONS, np.int64)
        if (n_thr and profile)
        else None
    )

    params_i = np.asarray(
        [
            n_trace,
            n_records,
            n_warps,
            issue_width,
            nb,
            nc,
            1 if profile else 0,
            n_thr,
            rr,
            idle0,
            frontier0,
        ],
        np.int64,
    )
    params_d = np.asarray([float(max_cycles), cycle0], np.float64)
    out_i = np.zeros(4, np.int64)
    out_d = np.zeros(1, np.float64)

    def ptr(a):
        return a.ctypes.data if a is not None else 0

    _fn(
        ptr(params_i),
        ptr(params_d),
        ptr(ct.code),
        ptr(ct.klass),
        ptr(ct.cost),
        ptr(ct.write_bar),
        ptr(ct.read_bar),
        ptr(ct.write_lat),
        ptr(ct.read_lat),
        ptr(ct.conflicts),
        ptr(ct.is_mem),
        ptr(wait_off),
        ptr(wait_data),
        ptr(intervals_a),
        ptr(pc),
        ptr(next_time),
        ptr(bars),
        ptr(unit_free),
        ptr(blame_a),
        ptr(warp_blame),
        ptr(bar_setter),
        ptr(thr_a),
        ptr(cap_i),
        ptr(cap_d),
        ptr(cap_blame),
        ptr(out_d),
        ptr(out_i),
    )

    cycle = float(out_d[0])
    idle_cycles = int(out_i[0])
    if profile:
        for idx in np.nonzero(blame_a)[0].tolist():
            blame[(idx // N_REASONS, REASON_LIST[idx % N_REASONS])] = int(
                blame_a[idx]
            )
    n_cap = int(out_i[3])
    if capture is not None and n_cap:
        for s in range(n_cap):
            ci = cap_i[s * slot_i : (s + 1) * slot_i]
            cd = cap_d[s * slot_d : (s + 1) * slot_d]
            cp_blame = cp_wblame = cp_bset = None
            if profile:
                bl = cap_blame[
                    s * n_records * N_REASONS : (s + 1) * n_records * N_REASONS
                ]
                cp_blame = {
                    (idx // N_REASONS, REASON_LIST[idx % N_REASONS]): int(bl[idx])
                    for idx in np.nonzero(bl)[0].tolist()
                }
                wb = ci[3 + n_warps : 3 + 3 * n_warps]
                cp_wblame = tuple(
                    (int(wb[2 * w]), REASON_LIST[int(wb[2 * w + 1])])
                    for w in range(n_warps)
                )
                bs = ci[3 + 3 * n_warps :]
                cp_bset = tuple(
                    tuple(bs[w * nb : (w + 1) * nb].tolist())
                    for w in range(n_warps)
                )
            capture.append(
                _sim.SimCheckpoint(
                    frontier=int(ci[0]),
                    cycle=float(cd[0]),
                    idle_cycles=int(ci[1]),
                    rr=int(ci[2]),
                    pc=tuple(ci[3 : 3 + n_warps].tolist()),
                    next_time=tuple(cd[1 : 1 + n_warps].tolist()),
                    bars=tuple(
                        tuple(
                            cd[1 + n_warps + w * nb : 1 + n_warps + (w + 1) * nb]
                            .tolist()
                        )
                        for w in range(n_warps)
                    ),
                    unit_free=tuple(cd[1 + n_warps + n_warps * nb :].tolist()),
                    profiled=profile,
                    blame=cp_blame,
                    warp_blame=cp_wblame,
                    bar_setter=cp_bset,
                )
            )
    return cycle, idle_cycles
