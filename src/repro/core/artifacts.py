"""Persistent content-addressed artifact store.

The disk tier under :class:`~repro.core.translator.TranslationCache` and
:class:`~repro.core.simcache.SimCache`: finished translations and
simulator measurements spill here and survive process restarts, so a tuned
kernel is served byte-identically across daemon restarts with **zero**
pipeline passes re-run (ROADMAP: "a hot kernel should be served from cache
in microseconds cluster-wide, not re-tuned per process").

Design constraints, in order:

1. **Never serve wrong bytes.**  Every entry carries CRC32s over its
   metadata and payload plus explicit lengths; a read validates all of
   them (and that the stored key matches the requested key — a filename
   hash collision must never alias entries) before returning anything.
   Anything that fails validation is *quarantined* — moved aside into
   ``quarantine/`` for post-mortem, never deleted silently, never served —
   and reported as a miss, so the caller recomputes.
2. **Crash-safe writes.**  Entries are written with the shared atomic
   recipe (:func:`repro.util.write_bytes_atomic`: same-dir tmp + fsync +
   rename), so a crash mid-write leaves either no entry or a stale
   ``*.tmp`` that :meth:`ArtifactStore.recover` sweeps on open.  Torn
   writes that reach the final file anyway (lying hardware) are caught by
   check 1 on the next read.
3. **Bounded.**  ``max_entries`` caps the object count with LRU eviction —
   reads refresh an entry's mtime, eviction removes the stalest
   ``(mtime, name)`` first, deterministically.

Layout under ``root``::

    objects/<2-hex shard>/<sha256 of key>.art     one file per entry
    quarantine/<original name>.<reason>           corrupt entries, kept

Entry file format (little-endian)::

    magic "RDART\\x01" | u16 format version | u32 meta len | u32 payload len
    | u32 meta crc | u32 payload crc | meta (JSON, utf-8) | payload

The JSON meta always contains the full ``key`` string (collision guard)
plus whatever the caller stored.  Fault injection (:mod:`repro.testing.
faults`) hooks the write path (torn/tmp writes) and the read path (bit
flips) — the chaos suite drives those to prove property 1 holds.
"""

from __future__ import annotations

import hashlib
import json
import os
import struct
import zlib
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.testing import faults as _faults
from repro.util import sweep_tmp_files, write_bytes_atomic

MAGIC = b"RDART\x01"
#: bump when the entry layout (or the pickled payload conventions of a
#: consumer) changes incompatibly; mismatched entries are quarantined
STORE_VERSION = 1

_HDR = struct.Struct("<6sHIIII")  # magic, version, meta_len, payload_len,
#                                   meta_crc, payload_crc


class ArtifactStore:
    """Content-addressed, corruption-safe, LRU-bounded on-disk store.

    Keys are arbitrary strings (callers build them from kernel content CRC
    + translation/simulation parameters + arch).  Values are opaque payload
    bytes plus a small JSON-able metadata dict.
    """

    def __init__(self, root: str, max_entries: Optional[int] = None):
        self.root = os.path.abspath(root)
        self.objects_dir = os.path.join(self.root, "objects")
        self.quarantine_dir = os.path.join(self.root, "quarantine")
        self.max_entries = max_entries
        os.makedirs(self.objects_dir, exist_ok=True)
        os.makedirs(self.quarantine_dir, exist_ok=True)
        self.hits = 0
        self.misses = 0
        self.puts = 0
        self.evictions = 0
        self.quarantined = 0
        #: stale tmp files of interrupted writes removed on open
        self.recovered = self.recover()

    # -- pathing ---------------------------------------------------------------

    @staticmethod
    def _digest(key: str) -> str:
        return hashlib.sha256(key.encode("utf-8")).hexdigest()

    def _path(self, key: str) -> str:
        d = self._digest(key)
        return os.path.join(self.objects_dir, d[:2], d + ".art")

    # -- recovery & quarantine -------------------------------------------------

    def recover(self) -> int:
        """Sweep stale ``*.tmp`` leftovers of interrupted atomic writes
        (the crash-mid-write self-heal).  Returns the number removed."""
        removed = len(sweep_tmp_files(self.objects_dir))
        try:
            shards = os.listdir(self.objects_dir)
        except OSError:
            return removed
        for shard in shards:
            removed += len(sweep_tmp_files(os.path.join(self.objects_dir, shard)))
        return removed

    def _quarantine(self, path: str, reason: str) -> None:
        """Move a bad entry aside — kept for post-mortem, never served."""
        self.quarantined += 1
        if obs.enabled():
            obs.metrics().counter("artifact_store.quarantined").inc()
        dest = os.path.join(
            self.quarantine_dir, f"{os.path.basename(path)}.{reason}"
        )
        try:
            os.replace(path, dest)
        except OSError:
            # last resort: a bad entry we cannot move must not keep being
            # re-read as if it were data
            try:
                os.unlink(path)
            except OSError:
                pass

    # -- write -----------------------------------------------------------------

    def put(self, key: str, payload: bytes, meta: Optional[dict] = None) -> bool:
        """Persist one entry (overwriting any previous value for ``key``).

        Returns ``True`` on success.  Injected write faults surface the way
        a real crash would: a ``store.tmp`` fault leaves a stale tmp file
        and no entry (returns ``False``); a ``store.torn`` fault leaves a
        truncated final file for the read path to catch and quarantine.
        """
        full_meta = dict(meta or {})
        full_meta["key"] = key
        meta_bytes = json.dumps(full_meta, sort_keys=True).encode("utf-8")
        blob = (
            _HDR.pack(
                MAGIC,
                STORE_VERSION,
                len(meta_bytes),
                len(payload),
                zlib.crc32(meta_bytes) & 0xFFFFFFFF,
                zlib.crc32(payload) & 0xFFFFFFFF,
            )
            + meta_bytes
            + payload
        )
        path = self._path(key)
        os.makedirs(os.path.dirname(path), exist_ok=True)

        inj = _faults.active()
        if inj is not None and inj.fire("store.tmp", key):
            # simulate dying before the rename: partial tmp file, no entry
            with open(path + ".crash.tmp", "wb") as fh:
                fh.write(blob[: inj.torn_length(len(blob), key)])
            return False
        if inj is not None and inj.fire("store.torn", key):
            # simulate a torn write reaching the final file (fsync lied)
            with open(path, "wb") as fh:
                fh.write(blob[: inj.torn_length(len(blob), key)])
            self.puts += 1
            self._evict()
            return True

        write_bytes_atomic(path, blob)
        self.puts += 1
        if obs.enabled():
            obs.metrics().counter("artifact_store.puts").inc()
        self._evict()
        return True

    # -- read ------------------------------------------------------------------

    def get(self, key: str) -> Optional[Tuple[bytes, dict]]:
        """Return ``(payload, meta)`` for ``key``, or ``None``.

        Every failure mode — missing, truncated, bit-flipped, version
        mismatch, key collision — is a miss; corrupt files are quarantined
        on the way.  A served payload always re-verified its CRC in this
        call (degraded or byte-identical, never corrupt).
        """
        path = self._path(key)
        try:
            with open(path, "rb") as fh:
                blob = fh.read()
        except OSError:
            self.misses += 1
            if obs.enabled():
                obs.metrics().counter("artifact_store.misses").inc()
            return None

        inj = _faults.active()
        if inj is not None and inj.fire("store.flip", key):
            blob = inj.flip_bit(blob, key=key)

        entry = self._validate(blob, key)
        if entry is None:
            self._quarantine(path, "corrupt")
            self.misses += 1
            if obs.enabled():
                obs.metrics().counter("artifact_store.misses").inc()
            return None
        self.hits += 1
        if obs.enabled():
            obs.metrics().counter("artifact_store.hits").inc()
        try:
            os.utime(path)  # LRU touch
        except OSError:
            pass
        return entry

    @staticmethod
    def _validate(blob: bytes, key: str) -> Optional[Tuple[bytes, dict]]:
        """Full structural + integrity validation of one entry file."""
        if len(blob) < _HDR.size:
            return None
        magic, version, meta_len, payload_len, meta_crc, payload_crc = _HDR.unpack(
            blob[: _HDR.size]
        )
        if magic != MAGIC or version != STORE_VERSION:
            return None
        if len(blob) != _HDR.size + meta_len + payload_len:
            return None
        meta_bytes = blob[_HDR.size : _HDR.size + meta_len]
        payload = blob[_HDR.size + meta_len :]
        if zlib.crc32(meta_bytes) & 0xFFFFFFFF != meta_crc:
            return None
        if zlib.crc32(payload) & 0xFFFFFFFF != payload_crc:
            return None
        try:
            meta = json.loads(meta_bytes.decode("utf-8"))
        except (UnicodeDecodeError, json.JSONDecodeError):
            return None
        if not isinstance(meta, dict) or meta.get("key") != key:
            return None  # filename-hash collision guard
        return payload, meta

    # -- bounds ----------------------------------------------------------------

    def _entries(self) -> List[str]:
        out: List[str] = []
        try:
            shards = os.listdir(self.objects_dir)
        except OSError:
            return out
        for shard in sorted(shards):
            sdir = os.path.join(self.objects_dir, shard)
            if not os.path.isdir(sdir):
                continue
            for name in sorted(os.listdir(sdir)):
                if name.endswith(".art"):
                    out.append(os.path.join(sdir, name))
        return out

    def __len__(self) -> int:
        return len(self._entries())

    def _evict(self) -> None:
        """LRU-evict down to ``max_entries`` (stalest ``(mtime, name)``
        first — deterministic under equal timestamps)."""
        if self.max_entries is None:
            return
        entries = self._entries()
        excess = len(entries) - self.max_entries
        if excess <= 0:
            return

        def age(path: str) -> tuple:
            try:
                return (os.path.getmtime(path), os.path.basename(path))
            except OSError:
                return (0.0, os.path.basename(path))

        for path in sorted(entries, key=age)[:excess]:
            try:
                os.unlink(path)
                self.evictions += 1
                if obs.enabled():
                    obs.metrics().counter("artifact_store.evictions").inc()
            except OSError:
                pass

    # -- telemetry -------------------------------------------------------------

    def stats(self) -> Dict[str, object]:
        return {
            "root": self.root,
            "entries": len(self),
            "capacity": self.max_entries,
            "hits": self.hits,
            "misses": self.misses,
            "hit_rate": round(
                obs.hit_rate(self.hits, self.misses, default=0.0), 3
            ),
            "puts": self.puts,
            "evictions": self.evictions,
            "quarantined": self.quarantined,
            "recovered_tmp": self.recovered,
        }
