"""Code-variant generation (paper Table 3 / §5.3).

Five variants of every benchmark kernel:

* ``nvcc``               the baseline: efficient scheduling, high register
                          count, no restriction;
* ``regdem``             this paper: demotion to shared memory at the
                          Table-1 target register count;
* ``local``              nvcc with ``--maxrregcount``: *aggressive register
                          allocation* — rematerialize what it can (slower
                          instruction sequences / "zero spilling") and spill
                          the rest to off-chip **local** memory;
* ``local-shared``       Hayes & Zhang [11]: the ``local`` variant at a
                          32-register target with its spill code converted to
                          shared memory (the closest research alternative);
* ``local-shared-relax`` the same conversion at RegDem's register target
                          (the enhanced research alternative).

The aggressive allocator mirrors nvcc's documented behaviour: it prefers
re-materialization over spilling (avoiding local-memory latency at the cost
of extra dynamic instructions), which is exactly the single-thread
performance loss the paper's §5.5 discussion attributes to the alternatives.

All five variants are instances of the unified pass pipeline
(:mod:`repro.core.passes`): :func:`aggressive` binds
:func:`~repro.core.passes.aggressive_pipeline` to a
:class:`~repro.core.spillspace.LocalSpace` or
:class:`~repro.core.spillspace.SharedSpace`, and ``regdem`` is
:func:`repro.core.regdem.demote`'s demotion pipeline.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Union

from .isa import Kernel
from .kernelgen import Profile, generate
from .passes import PassContext, PassStat, RegDemOptions, aggressive_pipeline
from .regdem import REG_FLOOR, RegDemResult, demote
from .spillspace import LocalSpace, SpillSpace
from .spillspace import spill_space as make_space

VARIANT_NAMES = ("nvcc", "regdem", "local", "local-shared", "local-shared-relax")


@dataclass
class Variant:
    name: str
    kernel: Kernel
    #: registers spilled/demoted to memory (words)
    spilled: int = 0
    #: registers removed via rematerialization
    remat: int = 0
    #: RegDem result when applicable
    regdem: Optional[RegDemResult] = None
    #: per-pass diagnostics/timings from the generating pipeline
    passes: List[PassStat] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Aggressive register allocation (the nvcc --maxrregcount model)
# ---------------------------------------------------------------------------


def aggressive(
    kernel: Kernel,
    target_regs: int,
    spill_space: Union[str, SpillSpace] = "local",
    max_remat: Optional[int] = None,
    verify: str = "each",
) -> Variant:
    """Reduce register usage to ``target_regs`` the way nvcc does under
    ``--maxrregcount``: rematerialize first, then spill.

    ``spill_space='shared'`` converts the spill code to shared memory — the
    Hayes & Zhang local->shared transformation [11].  A
    :class:`~repro.core.spillspace.SpillSpace` instance is also accepted.
    """
    if isinstance(spill_space, SpillSpace):
        space = spill_space
    elif spill_space == "shared":
        # the historical conversion never guarded the 48 KiB limit
        space = make_space("shared", check_limit=False)
    else:
        space = make_space(spill_space)

    opts = RegDemOptions(
        candidate_strategy="static",
        bank_avoid=False,
        elim_redundant=False,
        reschedule=False,
        substitute=False,
    )
    ctx = PassContext(
        kernel,
        space,
        opts,
        target=target_regs,
        floor=max(target_regs, 0),  # nvcc honours the raw target, not REG_FLOOR
        max_remat=max_remat,
    )
    aggressive_pipeline(verify=verify).run(ctx)
    name = "local" if isinstance(space, LocalSpace) else "local-shared"
    return Variant(
        name=name,
        kernel=ctx.kernel,
        spilled=ctx.demoted_words,
        remat=ctx.remat,
        passes=ctx.passes,
    )


# ---------------------------------------------------------------------------
# The Table-3 variant matrix
# ---------------------------------------------------------------------------


def make_variants(
    profile: Profile,
    regdem_options: Optional[RegDemOptions] = None,
    verify: str = "final",
    extra_strategies: Optional[List[str]] = None,
) -> Dict[str, Variant]:
    """Build all five §5.3 variants for one benchmark profile.

    ``verify`` is the pass-pipeline self-check policy.  Variant generation is
    the measurement hot path, so the default is ``"final"`` — the full
    schedule + dataflow check once per pipeline, after the last pass — which
    produces byte-identical kernels to ``"each"`` (regression-tested) at a
    fraction of the cost.  Pass ``"each"`` to fault-localize a broken pass.

    ``extra_strategies`` appends registry-built variants (one per named
    :mod:`repro.core.strategies` strategy, probe options, best cliff
    target) to the paper's five.
    """
    return make_variants_for(
        generate(profile),
        profile.regdem_target,
        nvcc_spills=profile.nvcc_spills,
        regdem_options=regdem_options,
        verify=verify,
        extra_strategies=extra_strategies,
    )


def make_variants_for(
    base: Kernel,
    target: int,
    nvcc_spills: int = 0,
    regdem_options: Optional[RegDemOptions] = None,
    verify: str = "final",
    extra_strategies: Optional[List[str]] = None,
) -> Dict[str, Variant]:
    """The §5.3 variant matrix for a pre-built baseline kernel.

    :func:`make_variants` is this applied to a freshly generated Table-1
    profile; calling it directly lets the cross-arch benchmarks and the
    autotuning search build the same comparison set for a *retargeted*
    baseline (``repro.arch.retarget``), whose arch tag every pipeline pass
    and the simulator then honour.
    """
    out: Dict[str, Variant] = {}
    out["nvcc"] = Variant(name="nvcc", kernel=base)

    rd = demote(base, target, regdem_options or RegDemOptions(), verify=verify)
    out["regdem"] = Variant(
        name="regdem", kernel=rd.kernel, spilled=rd.demoted_words, regdem=rd,
        passes=rd.passes,
    )

    # nvcc's remat capacity is bounded so that its local-spill count matches
    # the Table-1 "# Registers Spilled (nvcc)" column for this benchmark
    reduction = max(0, base.reg_count - target)
    cap = max(0, reduction - nvcc_spills)

    loc = aggressive(base, target, spill_space="local", max_remat=cap, verify=verify)
    loc.name = "local"
    out["local"] = loc

    ls = aggressive(base, REG_FLOOR, spill_space="shared", verify=verify)
    ls.name = "local-shared"
    out["local-shared"] = ls

    lsr = aggressive(base, target, spill_space="shared", max_remat=cap, verify=verify)
    lsr.name = "local-shared-relax"
    out["local-shared-relax"] = lsr

    # The Hayes & Zhang conversions are built unguarded (check_limit=False:
    # the historical transformation spills however much the register target
    # demands), so on kernels with large *static* shared memory the converted
    # spill arena can push total_shared past the per-block limit — such a
    # variant would fail to launch on real hardware, and downstream occupancy
    # math rightly refuses it.  Drop unlaunchable conversions from the
    # comparison set, exactly as a real experiment would have to.  RegDem
    # itself never needs this: its §3 target chooser only picks cliffs whose
    # spills fit (flushed by the real-workload corpus: 24 KiB kv-tile smem
    # x 256 threads overflows at the 32-register floor).
    from .spillspace import spill_limit

    for name in ("local-shared", "local-shared-relax"):
        if out[name].kernel.total_shared > spill_limit(out[name].kernel):
            del out[name]

    # registry-built extras: one variant per named strategy at its probe
    # combo and best cliff target (its own ladder; the paper target when
    # the ladder is empty)
    for name in extra_strategies or ():
        from repro.arch import arch_of

        from .strategies import get_strategy

        strat = get_strategy(name)
        if strat.archs is not None and arch_of(base).name not in strat.archs:
            continue
        if not strat.select(base):
            continue
        targets = strat.targets(base, 1)
        tgt = targets[0] if targets else target
        res = strat.build(base, tgt, strat.option_combos(False)[0], verify=verify)
        out[name] = Variant(
            name=name,
            kernel=res.kernel,
            spilled=res.demoted_words,
            regdem=res,
            passes=res.passes,
        )
    return out
