"""Code-variant generation (paper Table 3 / §5.3).

Five variants of every benchmark kernel:

* ``nvcc``               the baseline: efficient scheduling, high register
                          count, no restriction;
* ``regdem``             this paper: demotion to shared memory at the
                          Table-1 target register count;
* ``local``              nvcc with ``--maxrregcount``: *aggressive register
                          allocation* — rematerialize what it can (slower
                          instruction sequences / "zero spilling") and spill
                          the rest to off-chip **local** memory;
* ``local-shared``       Hayes & Zhang [11]: the ``local`` variant at a
                          32-register target with its spill code converted to
                          shared memory (the closest research alternative);
* ``local-shared-relax`` the same conversion at RegDem's register target
                          (the enhanced research alternative).

The aggressive allocator mirrors nvcc's documented behaviour: it prefers
re-materialization over spilling (avoiding local-memory latency at the cost
of extra dynamic instructions), which is exactly the single-thread
performance loss the paper's §5.5 discussion attributes to the alternatives.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from .candidates import make_candidates, operand_conflicts
from .compaction import compact, packed_reg_count
from .isa import RZ, Ctrl, Instr, Kernel, Label
from .kernelgen import Profile, generate
from .regdem import REG_FLOOR, RegDemOptions, RegDemResult, _demote_one, demote
from .sched import fixup_stalls, repair_war

VARIANT_NAMES = ("nvcc", "regdem", "local", "local-shared", "local-shared-relax")


@dataclass
class Variant:
    name: str
    kernel: Kernel
    #: registers spilled/demoted to memory (words)
    spilled: int = 0
    #: registers removed via rematerialization
    remat: int = 0
    #: RegDem result when applicable
    regdem: Optional[RegDemResult] = None


# ---------------------------------------------------------------------------
# Aggressive register allocation (the nvcc --maxrregcount model)
# ---------------------------------------------------------------------------


def _const_defs(kernel: Kernel) -> Dict[int, float]:
    """Registers defined exactly once, by a ``MOV32I`` (rematerializable)."""
    defs: Dict[int, List[Instr]] = {}
    for ins in kernel.instructions():
        for r in ins.dsts:
            defs.setdefault(r, []).append(ins)
    out: Dict[int, float] = {}
    for r, instrs in defs.items():
        if len(instrs) == 1 and instrs[0].op == "MOV32I" and instrs[0].pred is None:
            out[r] = instrs[0].imm or 0.0
    return out


def _remat_one(kernel: Kernel, r: int, value: float, tmp: int) -> None:
    """Remove ``r``'s constant definition; recompute into ``tmp`` before each
    use ("less efficient instruction sequences", paper §1)."""
    new_items: List[object] = []
    for it in kernel.items:
        if isinstance(it, Label):
            new_items.append(it)
            continue
        ins: Instr = it
        if ins.op == "MOV32I" and ins.dsts == [r]:
            continue  # drop the definition
        if r in ins.srcs:
            mov = Instr(
                "MOV32I",
                [tmp],
                imm=value,
                pred=ins.pred,
                pred_neg=ins.pred_neg,
                tag="remat",
            )
            new_items.append(mov)
            ins.srcs = [tmp if s == r else s for s in ins.srcs]
        new_items.append(ins)
    kernel.items = new_items


def aggressive(
    kernel: Kernel,
    target_regs: int,
    spill_space: str = "local",
    max_remat: Optional[int] = None,
) -> Variant:
    """Reduce register usage to ``target_regs`` the way nvcc does under
    ``--maxrregcount``: rematerialize first, then spill.

    ``spill_space='shared'`` converts the spill code to shared memory — the
    Hayes & Zhang local->shared transformation [11].
    """
    k = kernel.copy()
    n = k.threads_per_block
    consts = _const_defs(k)
    victims = make_candidates(k, "static")
    conflicts = operand_conflicts(k)

    # reserve the spill value register and a distinct remat temporary
    # (one instruction may need both a reloaded spill and a recomputed
    # constant simultaneously); shared space also needs a base register
    base = k.reg_count
    wide = any(w == 2 for _, w in victims)
    if wide and base % 2:
        base += 1
    rsv = base
    rtmp = rsv + (2 if wide else 1)
    if spill_space == "shared":
        rda = rtmp + 1
        k.rda = rda
        s2r = Instr("S2R", [rsv], ctrl=Ctrl(stall=1))
        shl = Instr("SHL", [rda], [rsv], imm=2.0, ctrl=Ctrl(stall=15))
        s2r.ctrl.write_bar = 0
        shl.ctrl.wait.add(0)
        k.items[:0] = [s2r, shl]
        load_op, store_op = "LDS", "STS"
        s_up = (k.shared_size + 3) // 4 * 4
    else:
        rda = RZ
        load_op, store_op = "LDL", "STL"
        s_up = 0

    remat_done = 0
    rematted: Set[int] = set()
    spilled_words = 0
    spilled_regs: List[Tuple[int, int]] = []
    floor = max(target_regs, 0)

    # pass 1: rematerialization (nvcc prefers slower sequences over spills).
    # Two rematerialized values in one instruction would need two temps, so
    # conflicting candidates are skipped (same rule as demotion conflicts).
    for r, width in list(victims):
        if packed_reg_count(k) <= floor:
            break
        if width != 1 or r not in consts:
            continue
        if max_remat is not None and remat_done >= max_remat:
            break
        if conflicts.get(r, set()) & rematted:
            continue
        _remat_one(k, r, consts[r], rtmp)
        remat_done += 1
        rematted.add(r)
        victims = [(v, w) for v, w in victims if v != r]
    repair_war(k)

    # pass 2: spill the remainder
    while victims and packed_reg_count(k) > floor:
        r, width = victims.pop(0)
        if spill_space == "shared":
            offsets = [s_up + (spilled_words + j) * n * 4 for j in range(width)]
        else:
            offsets = [(spilled_words + j) * 4 for j in range(width)]
        _demote_one(k, r, width, offsets, rsv, rda, load_op, store_op)
        spilled_regs.append((r, width))
        spilled_words += width
        if spill_space == "shared":
            k.demoted_size = spilled_words * n * 4
        bad = conflicts.get(r, set())
        victims = [(v, w) for v, w in victims if v not in bad]

    compact(k)
    fixup_stalls(k)
    name = "local" if spill_space == "local" else "local-shared"
    return Variant(name=name, kernel=k, spilled=spilled_words, remat=remat_done)


# ---------------------------------------------------------------------------
# The Table-3 variant matrix
# ---------------------------------------------------------------------------


def make_variants(
    profile: Profile,
    regdem_options: Optional[RegDemOptions] = None,
) -> Dict[str, Variant]:
    """Build all five §5.3 variants for one benchmark profile."""
    base = generate(profile)
    target = profile.regdem_target

    out: Dict[str, Variant] = {}
    out["nvcc"] = Variant(name="nvcc", kernel=base)

    rd = demote(base, target, regdem_options or RegDemOptions())
    out["regdem"] = Variant(
        name="regdem", kernel=rd.kernel, spilled=rd.demoted_words, regdem=rd
    )

    # nvcc's remat capacity is bounded so that its local-spill count matches
    # the Table-1 "# Registers Spilled (nvcc)" column for this benchmark
    reduction = max(0, base.reg_count - target)
    cap = max(0, reduction - profile.nvcc_spills)

    loc = aggressive(base, target, spill_space="local", max_remat=cap)
    loc.name = "local"
    out["local"] = loc

    ls = aggressive(base, REG_FLOOR, spill_space="shared")
    ls.name = "local-shared"
    out["local-shared"] = ls

    lsr = aggressive(base, target, spill_space="shared", max_remat=cap)
    lsr.name = "local-shared-relax"
    out["local-shared-relax"] = lsr
    return out
