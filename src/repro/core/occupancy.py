"""Maxwell (CC 5.2 / GM200) occupancy calculator.

Reproduces the CUDA Occupancy Calculator's step function [paper ref 23]:
occupancy cliffs occur at register-count boundaries, which is the entire
premise of RegDem (paper §1-2).  Validated in tests against the Table-1
benchmark points of the paper (e.g. cfd: 68 regs x 192 thr -> 0.375
theoretical; 56 regs -> 0.5625).
"""

from __future__ import annotations

import math
from dataclasses import dataclass


@dataclass(frozen=True)
class SMConfig:
    """Per-SM resource limits."""

    registers: int = 64 * 1024            # 32-bit registers per SM
    max_threads: int = 2048
    max_warps: int = 64
    max_blocks: int = 32
    smem_bytes: int = 96 * 1024            # GM200: 96 KB per SM
    smem_per_block: int = 48 * 1024        # max static+dynamic per block
    warp_size: int = 32
    reg_alloc_unit: int = 256              # registers, allocated per warp
    smem_alloc_unit: int = 256             # bytes
    max_regs_per_thread: int = 255
    num_sms: int = 24                      # GTX Titan X (GM200)


MAXWELL = SMConfig()


def _ceil_to(x: int, unit: int) -> int:
    return ((x + unit - 1) // unit) * unit


@dataclass(frozen=True)
class Occupancy:
    """Result of the calculator for one kernel configuration."""

    resident_blocks: int
    resident_warps: int
    resident_threads: int
    occupancy: float
    limiter: str  # "registers" | "smem" | "threads" | "blocks"

    def __float__(self) -> float:
        return self.occupancy


def occupancy(
    regs_per_thread: int,
    threads_per_block: int,
    smem_per_block: int = 0,
    sm: SMConfig = MAXWELL,
) -> Occupancy:
    """Theoretical occupancy of a kernel launch on one SM."""
    if threads_per_block <= 0 or threads_per_block > 1024:
        raise ValueError(f"bad threads_per_block={threads_per_block}")
    if regs_per_thread > sm.max_regs_per_thread:
        raise ValueError(f"regs_per_thread={regs_per_thread} exceeds ISA max")
    warps_per_block = math.ceil(threads_per_block / sm.warp_size)

    limits = {}
    # registers: allocated per warp with granularity reg_alloc_unit
    regs_per_warp = _ceil_to(max(regs_per_thread, 1) * sm.warp_size, sm.reg_alloc_unit)
    limits["registers"] = sm.registers // (regs_per_warp * warps_per_block)
    # shared memory
    if smem_per_block > sm.smem_per_block:
        raise ValueError("shared memory exceeds per-block limit")
    if smem_per_block > 0:
        limits["smem"] = sm.smem_bytes // _ceil_to(smem_per_block, sm.smem_alloc_unit)
    else:
        limits["smem"] = sm.max_blocks
    limits["threads"] = sm.max_threads // threads_per_block
    limits["blocks"] = sm.max_blocks
    # warp ceiling folds into the thread limit
    limits["threads"] = min(limits["threads"], sm.max_warps // warps_per_block)

    blocks = min(limits.values())
    limiter = min(limits, key=lambda k: limits[k])
    warps = blocks * warps_per_block
    return Occupancy(
        resident_blocks=blocks,
        resident_warps=warps,
        resident_threads=warps * sm.warp_size,
        occupancy=warps / sm.max_warps,
        limiter=limiter,
    )


def occupancy_of(kernel, sm: SMConfig | None = None) -> Occupancy:
    """Occupancy of a :class:`repro.core.isa.Kernel` under its own
    architecture's SM limits (override with ``sm``)."""
    if sm is None:
        from repro.arch import arch_of

        sm = arch_of(kernel).sm
    return occupancy(
        kernel.reg_count, kernel.threads_per_block, kernel.total_shared, sm
    )


def spill_targets(
    regs_per_thread: int,
    threads_per_block: int,
    smem_per_block: int,
    available_smem: int | None = None,
    sm: SMConfig = MAXWELL,
    bytes_per_slot: int = 4,
    reg_cost_per_word: float = 0.0,
    feasible=None,
) -> list[int]:
    """Register targets that land exactly on occupancy cliffs.

    This is RegDem's "automatic utility that chooses different register
    counts to spill such that different occupancy cliffs could be achieved
    and the spills can fit in the available shared memory" (paper §3).
    Returns candidate ``target_regs`` values in decreasing order, each the
    largest register count achieving a strictly higher occupancy level than
    the previous, floored at 32 registers (below which occupancy no longer
    improves — paper §3).

    The cost model is parameterized for the registered spill-strategy
    families (:mod:`repro.core.strategies`):

    * ``bytes_per_slot`` — per-thread shared-memory bytes one demoted word
      occupies (4 = eq.-1 full words; 2 = compressed slots; 0 = a space
      whose slots are not charged against this block's allocation);
    * ``reg_cost_per_word`` — extra architectural registers each demoted
      word costs (warp-level resource sharing charges ``1/share``: the
      slot pool is register-file backed and shared by co-scheduled warps);
    * ``feasible`` — optional ``(spilled_words, Occupancy) -> bool`` veto
      for budgets outside the per-block charge (e.g. the per-SM scratchpad
      pool a cross-block carve draws from).

    Defaults reproduce the paper's shared-memory ladder exactly.
    """
    base = occupancy(max(regs_per_thread, 1), threads_per_block, smem_per_block, sm)
    targets: list[int] = []
    best = base.occupancy
    for regs in range(regs_per_thread - 1, 31, -1):
        # demoted registers consume shared memory themselves (eq. 1 layout);
        # the occupancy check must charge for it, or the "gain" is illusory.
        spilled = regs_per_thread - regs
        smem_needed = spilled * threads_per_block * bytes_per_slot
        budget = (
            available_smem
            if available_smem is not None
            else sm.smem_per_block - smem_per_block
        )
        if smem_needed > budget:
            break
        eff_regs = regs + math.ceil(spilled * reg_cost_per_word)
        if eff_regs >= regs_per_thread:
            continue
        occ = occupancy(eff_regs, threads_per_block, smem_per_block + smem_needed, sm)
        if feasible is not None and not feasible(spilled, occ):
            continue
        if occ.occupancy > best:
            targets.append(regs)
            best = occ.occupancy
    return targets
