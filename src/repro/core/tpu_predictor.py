"""The RegDem performance predictor, adapted to XLA artifacts (DESIGN.md §2).

The paper's contract: *statically rank code variants from the compiled
binary, never run the worst one, tie-break toward more optimizations*.
Here the "binary" is the SPMD-partitioned HLO module of a (sharding x
remat x microbatch x attention-impl) variant, and the stall model becomes
the three-term roofline:

    t(variant) = max(compute, memory, collective)     -- bound model
               + alpha * sum(non-dominant terms)      -- overlap imperfection

mirroring eq. 2/3's structure (per-unit contention + an empirical
adjustment).  ``alpha`` plays the role of the f(occupancy) fit: it was
calibrated so the ranking matches the measured ordering on the cells where
several variants were lowered (see EXPERIMENTS.md §Perf).

The selector consumes records produced by :mod:`repro.launch.dryrun`
(flops / bytes / wire collective bytes per device) and returns the
variant to ship, exactly like :func:`repro.core.predictor.predict` does
for SASS variants.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

#: TPU v5e per-chip constants (same as benchmarks.roofline)
PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

#: imperfect-overlap weight (calibrated; see module docstring)
ALPHA = 0.15


@dataclasses.dataclass(frozen=True)
class VariantCost:
    name: str
    compute_s: float
    memory_s: float
    collective_s: float
    fits_hbm: bool
    #: optimization-option count for the paper's tie-break rule
    n_options: int = 0

    @property
    def terms(self) -> Dict[str, float]:
        return {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }

    @property
    def dominant(self) -> str:
        return max(self.terms, key=self.terms.get)

    @property
    def estimate_s(self) -> float:
        t = self.terms
        dom = max(t.values())
        return dom + ALPHA * (sum(t.values()) - dom)


def cost_from_record(rec: Dict[str, Any], name: Optional[str] = None,
                     hbm_bytes: int = 16 * 2**30, n_options: int = 0) -> VariantCost:
    """Build a VariantCost from a dry-run record."""
    wire = rec["collectives"].get("wire_bytes", rec["collectives"]["total_bytes"])
    mem = rec["memory"]
    used = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"]
    return VariantCost(
        name=name or f"{rec['arch']}/{rec['shape']}/{rec.get('variant', 'base')}",
        compute_s=rec["flops"] / PEAK_FLOPS,
        memory_s=rec["bytes_accessed"] / HBM_BW,
        collective_s=wire / LINK_BW,
        fits_hbm=used <= hbm_bytes,
        n_options=n_options,
    )


def select(variants: List[VariantCost]) -> Tuple[VariantCost, List[VariantCost]]:
    """Rank variants; infeasible (HBM-overflow) ones are never chosen when a
    feasible variant exists (the paper's worst-case-avoidance property)."""
    if not variants:
        raise ValueError("no variants")
    feasible = [v for v in variants if v.fits_hbm] or list(variants)
    ranked = sorted(feasible, key=lambda v: (v.estimate_s, -v.n_options))
    return ranked[0], sorted(variants, key=lambda v: v.estimate_s)
