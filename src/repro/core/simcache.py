"""Content-addressed simulation & analysis caching.

The simulator is this repo's measurement instrument, and the benchmark
harness, the predictor calibration, and the translation service all measure
the *same kernels* over and over (fig6's nvcc baselines are fig9's, fig7's
``full`` demotion is table1's ``regdem`` variant, ...).  A
:class:`SimCache` makes every one of those a cache hit:

* **key** — the kernel's content CRC (:func:`repro.binary.container.
  kernel_crc`, the same content address the v2 container stores and the
  translation cache keys) plus the SM configuration and engine parameters;
* **collision guard** — a 32-bit CRC can collide, so every entry stores the
  input kernel's rendering and a hit is only served when it matches: a
  colliding kernel is a miss, never another kernel's measurement;
* **stores** — finished :class:`~repro.core.simulator.SimResult` runs and
  the predictor's whole-program stall estimates (keyed additionally by
  occupancy), both immutable-by-convention; hits return shallow copies.

:data:`DEFAULT_SIM_CACHE` is the process-wide instance shared by
``benchmarks.paper_figs``, :func:`repro.core.predictor.fit_occupancy_curve`,
:func:`repro.core.predictor.predict` (and through it the
:class:`~repro.core.translator.TranslationService` predictor path).
"""

from __future__ import annotations

import dataclasses
import pickle
from typing import Dict, Optional, Tuple

from repro import obs
from repro.obs import hit_rate as _hit_rate
from repro.obs.stallprof import StallProfile

from .isa import Kernel
from .occupancy import SMConfig
from .simulator import CheckpointStore, SimResult, simulate


def _guard(kernel: Kernel) -> str:
    """Collision-guard string: everything the simulator and the stall
    estimator observe.  ``Kernel.render()`` covers the instruction stream
    and control words; launch geometry and loop trip counts ride alongside
    (they are in the CRC but not in the rendering)."""
    trips = ",".join(
        str(ins.trip_count)
        for ins in kernel.instructions()
        if ins.trip_count is not None
    )
    return (
        f"{kernel.num_blocks}|{kernel.threads_per_block}|"
        f"{kernel.shared_size}|{kernel.demoted_size}|{trips}\n"
        + kernel.render()
    )


class SimCache:
    """Content-addressed cache of simulator runs and stall-estimate analyses.

    ``max_entries`` bounds each table FIFO-style (insertion order), matching
    :class:`repro.core.translator.TranslationCache`; ``None`` is unbounded
    (the benchmark harness working set is small and enumerable).

    ``store`` (an :class:`~repro.core.artifacts.ArtifactStore`) makes the
    ``sims`` and ``stalls`` tables restart-durable: every put spills a
    pickled ``(render, value)`` pair to disk, and a memory miss warm-loads
    from the store before falling through to a real (re-)simulation.
    Profiles and checkpoints stay memory-only — profiles re-derive from one
    profiled run, and checkpoints are bulky mid-trace engine states.
    """

    def __init__(
        self,
        max_entries: Optional[int] = None,
        store: Optional[object] = None,
    ):
        self.max_entries = max_entries
        self.store = store
        self.disk_hits = 0
        #: (crc, sm, max_cycles) -> (render, SimResult)
        self._sims: Dict[tuple, Tuple[str, SimResult]] = {}
        #: (crc, occupancy) -> (render, stalls)
        self._stalls: Dict[tuple, Tuple[str, float]] = {}
        #: (crc, sm, max_cycles) -> (render, StallProfile)
        self._profiles: Dict[tuple, Tuple[str, StallProfile]] = {}
        #: resumable issue-loop states for incremental re-simulation: a miss
        #: on the full-result tables can still resume mid-trace from the
        #: deepest checkpoint whose schedule prefix matches (simulator-owned
        #: keying; exactness is the checkpoint's validity condition)
        self.checkpoints = CheckpointStore()
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._sims) + len(self._stalls) + len(self._profiles)

    @property
    def hit_rate(self) -> float:
        """Hit fraction; raises :class:`ValueError` before any access (a
        rate over zero traffic is undefined, not 0%)."""
        return _hit_rate(self.hits, self.misses)

    def stats(self) -> Dict[str, float]:
        out = {
            "hits": self.hits,
            "misses": self.misses,
            "disk_hits": self.disk_hits,
            "evictions": self.evictions,
            "capacity": self.max_entries,
            "hit_rate": round(_hit_rate(self.hits, self.misses, default=0.0), 3),
            "sim_entries": len(self._sims),
            "stall_entries": len(self._stalls),
            "profile_entries": len(self._profiles),
            "checkpoint_entries": len(self.checkpoints),
            "checkpoint_reuse_rate": round(self.checkpoints.reuse_rate, 3),
        }
        if self.store is not None:
            out["disk_hit_rate"] = round(
                _hit_rate(self.disk_hits, self.misses, default=0.0), 3
            )
        return out

    def clear(self) -> None:
        self._sims.clear()
        self._stalls.clear()
        self._profiles.clear()
        self.checkpoints.clear()
        self.hits = 0
        self.misses = 0
        self.disk_hits = 0
        self.evictions = 0

    # -- keying ---------------------------------------------------------------

    @staticmethod
    def content_key(kernel: Kernel) -> int:
        """The kernel's content address (v2-container CRC, recomputed only
        for kernels that did not come out of a v2 container)."""
        crc = getattr(kernel, "content_crc", None)
        if crc is None:
            from repro.binary.container import kernel_crc

            crc = kernel_crc(kernel)
        return crc

    #: tables that spill to / warm-load from the artifact store
    _DURABLE_TABLES = ("sims", "stalls")

    @staticmethod
    def _store_key(table_name: str, key: tuple) -> str:
        return f"simcache:{table_name}:{key!r}"

    def _disk_get(self, table_name: str, key: tuple, render: str):
        """Warm-load one entry from the artifact store, or ``None``.

        The store already CRC-verifies the payload; the unpickle guard and
        the render comparison protect against a key collision or a payload
        written by an incompatible version — either is a miss, never a
        wrong value."""
        entry = self.store.get(self._store_key(table_name, key))
        if entry is None:
            return None
        payload, _meta = entry
        try:
            stored_render, value = pickle.loads(payload)
        except Exception:
            return None
        if stored_render != render:
            return None
        return value

    def _get(self, table: dict, key: tuple, render: str, table_name: str = ""):
        entry = table.get(key)
        if entry is not None and entry[0] == render:
            self.hits += 1
            if obs.enabled():
                obs.metrics().counter("simcache.hits").inc()
            return entry[1]
        if self.store is not None and table_name in self._DURABLE_TABLES:
            value = self._disk_get(table_name, key, render)
            if value is not None:
                # repopulate memory without re-spilling what disk just served
                self._mem_put(table, key, render, value)
                self.hits += 1
                self.disk_hits += 1
                if obs.enabled():
                    obs.metrics().counter("simcache.hits").inc()
                    obs.metrics().counter("simcache.disk_hits").inc()
                return value
        self.misses += 1
        if obs.enabled():
            obs.metrics().counter("simcache.misses").inc()
        return None

    def _mem_put(self, table: dict, key: tuple, render: str, value) -> None:
        if self.max_entries is not None and len(table) >= self.max_entries:
            table.pop(next(iter(table)))
            self.evictions += 1
            if obs.enabled():
                obs.metrics().counter("simcache.evictions").inc()
        table[key] = (render, value)

    def _put(
        self, table: dict, key: tuple, render: str, value, table_name: str = ""
    ) -> None:
        self._mem_put(table, key, render, value)
        if self.store is not None and table_name in self._DURABLE_TABLES:
            try:
                payload = pickle.dumps((render, value), protocol=4)
            except Exception:
                return  # unpicklable value: memory-only, never fatal
            self.store.put(
                self._store_key(table_name, key),
                payload,
                meta={"table": table_name},
            )

    # -- cached operations ----------------------------------------------------

    def simulate(
        self,
        kernel: Kernel,
        sm: Optional[SMConfig] = None,
        max_cycles: int = 50_000_000,
    ) -> SimResult:
        """:func:`repro.core.simulator.simulate`, content-cached.

        ``sm=None`` resolves to the kernel's architecture SM configuration
        *before* keying, so the same kernel simulated with and without an
        explicit (identical) SMConfig shares one cache entry.

        A full-result miss still goes through :attr:`checkpoints`: the run
        resumes from the deepest valid mid-trace state a sibling kernel
        captured and contributes its own captures back (incremental
        re-simulation)."""
        if sm is None:
            from repro.arch import arch_of

            sm = arch_of(kernel).sm
        key = (self.content_key(kernel), sm, max_cycles)
        render = _guard(kernel)
        hit = self._get(self._sims, key, render, "sims")
        if hit is not None:
            return dataclasses.replace(hit)
        res = simulate(kernel, sm, max_cycles, checkpoints=self.checkpoints)
        self._put(self._sims, key, render, res, "sims")
        return dataclasses.replace(res)

    def peek_simulate(
        self,
        kernel: Kernel,
        sm: Optional[SMConfig] = None,
        max_cycles: int = 50_000_000,
    ) -> Optional[SimResult]:
        """Return the cached :class:`SimResult` for ``kernel`` if present,
        else ``None`` — without running the simulator and without touching
        the hit/miss counters (used by the search engine to partition work
        before fanning the remainder out to a process pool)."""
        if sm is None:
            from repro.arch import arch_of

            sm = arch_of(kernel).sm
        key = (self.content_key(kernel), sm, max_cycles)
        entry = self._sims.get(key)
        if entry is not None and entry[0] == _guard(kernel):
            return dataclasses.replace(entry[1])
        return None

    def profile(
        self,
        kernel: Kernel,
        sm: Optional[SMConfig] = None,
        max_cycles: int = 50_000_000,
    ) -> StallProfile:
        """Stall-attribution profile of ``kernel``, content-cached.

        A miss runs the profiled engine once and warms *both* tables: the
        :class:`~repro.obs.stallprof.StallProfile` here and the (identical
        cycle counts, see ``simulate(profile=True)``) :class:`SimResult` in
        the plain simulation table, so a profiled confirm stage leaves the
        cache as warm as an unprofiled one."""
        if sm is None:
            from repro.arch import arch_of

            sm = arch_of(kernel).sm
        key = (self.content_key(kernel), sm, max_cycles)
        render = _guard(kernel)
        hit = self._get(self._profiles, key, render, "profiles")
        if hit is not None:
            return hit
        res = simulate(
            kernel, sm, max_cycles, profile=True, checkpoints=self.checkpoints
        )
        prof = res.stall_profile
        self._put(self._profiles, key, render, prof, "profiles")
        if key not in self._sims:
            self._put(
                self._sims,
                key,
                render,
                dataclasses.replace(res, stall_profile=None),
                "sims",
            )
        return prof

    def simulate_batch(
        self,
        kernels,
        sm: Optional[SMConfig] = None,
        max_cycles: int = 50_000_000,
        profile: bool = False,
    ):
        """Batched :meth:`simulate`/:meth:`profile` over sibling kernels.

        Delegates to :func:`repro.core.simulator.simulate_batch` with this
        cache plugged in: content-identical members dedup through the
        result tables, and distinct members that share a schedule prefix
        resume each other's checkpoints.  Element-wise identical to calling
        :meth:`simulate` per kernel."""
        from .simulator import simulate_batch as _simulate_batch

        return _simulate_batch(
            kernels, sm, max_cycles, profile=profile, cache=self
        )

    def estimate_stalls(self, kernel: Kernel, occupancy: float) -> float:
        """:func:`repro.core.predictor.estimate_stalls`, content-cached.

        Occupancy is part of the key: the estimate scales per-instruction
        stalls by it (eq. 2), so the same binary at a different occupancy is
        a different analysis.
        """
        key = (self.content_key(kernel), occupancy)
        render = _guard(kernel)
        hit = self._get(self._stalls, key, render, "stalls")
        if hit is not None:
            return hit
        from .predictor import estimate_stalls

        val = estimate_stalls(kernel, occupancy)
        self._put(self._stalls, key, render, val, "stalls")
        return val

    # -- pool-worker cache exchange -------------------------------------------

    def export(self) -> Dict[str, dict]:
        """Snapshot every entry as a picklable payload for :meth:`merge`.

        A search-pool worker runs with a fresh private cache, does its
        measurements, and ships the entries back to the parent so the
        process-wide cache ends a parallel search exactly as warm as a
        serial one would leave it.  Checkpoints stay local: they are
        mid-trace engine states, bulky and machine-local by nature, and
        re-deriving them is one partial simulation."""
        return {
            "sims": dict(self._sims),
            "stalls": dict(self._stalls),
            "profiles": dict(self._profiles),
        }

    def merge(self, exported: Dict[str, dict]) -> int:
        """Adopt entries from an :meth:`export` payload; first writer wins
        (an existing entry is never overwritten, so the merge result does
        not depend on worker completion order).  Returns the number of
        entries added."""
        added = 0
        for name, table, incoming in (
            ("sims", self._sims, exported.get("sims", {})),
            ("stalls", self._stalls, exported.get("stalls", {})),
            ("profiles", self._profiles, exported.get("profiles", {})),
        ):
            for key in sorted(incoming, key=repr):
                if key not in table:
                    render, value = incoming[key]
                    self._put(table, key, render, value, name)
                    added += 1
        return added


#: Process-wide cache shared by the benchmark harness, the predictor, and
#: the translation service's predictor path.  Bounded: the harness working
#: set is tiny, but the service path feeds this cache one stall-estimate
#: entry per (kernel, occupancy) it predicts over, and a long-running
#: service must not grow memory without bound.
DEFAULT_SIM_CACHE = SimCache(max_entries=4096)


def simulate_cached(
    kernel: Kernel,
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
    cache: Optional[SimCache] = None,
) -> SimResult:
    """Content-cached :func:`~repro.core.simulator.simulate` (process-wide
    :data:`DEFAULT_SIM_CACHE` unless a cache is supplied)."""
    if cache is None:
        cache = DEFAULT_SIM_CACHE
    return cache.simulate(kernel, sm, max_cycles)


def estimate_stalls_cached(
    kernel: Kernel,
    occupancy: float,
    cache: Optional[SimCache] = None,
) -> float:
    """Content-cached :func:`~repro.core.predictor.estimate_stalls`."""
    if cache is None:
        cache = DEFAULT_SIM_CACHE
    return cache.estimate_stalls(kernel, occupancy)
