"""Supervised process pool: crashed workers restart, repeat offenders
quarantine.

``multiprocessing.Pool`` turns one crashed worker (segfault, OOM kill,
``os._exit``) into a hung or failed *whole search*.  This pool supervises
instead:

* each worker is a dedicated process with its own duplex pipe; the parent
  always knows **which task** a worker was running, so a crash is
  attributed exactly;
* a crashed worker is restarted and its task re-queued;
* a task that has killed ``quarantine_after`` workers (default 2 — one
  crash could be the worker's bad luck, two on the same task is the task)
  is **quarantined**: its slot in the result list becomes a
  :class:`Quarantined` marker instead of taking the pool down a third
  time.  The caller decides what "serve baseline" means for its domain
  (the autotuning search drops the variant; the daemon degrades the
  response).

Determinism: results are ordered by submission index regardless of worker
scheduling, task functions are pure, and the parent's fault-injection plan
(:mod:`repro.testing.faults`) is forwarded to every worker — injected
crash schedules are keyed by ``(task index, attempt)``, so a chaos run
replays identically.

Exceptions *raised by a task* (as opposed to a worker death) propagate to
the caller after the pool shuts down, matching ``Pool.map`` semantics.
"""

from __future__ import annotations

import multiprocessing
import os
import random
from multiprocessing.connection import wait as _conn_wait
from typing import Callable, List, Optional, Sequence

from repro import obs
from repro.testing import faults as _faults

#: how many workers one task may kill before it is quarantined
QUARANTINE_AFTER = 2


class Quarantined:
    """Result placeholder for a task that repeatedly killed its worker."""

    __slots__ = ("index", "crashes")

    def __init__(self, index: int, crashes: int):
        self.index = index
        self.crashes = crashes

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"Quarantined(index={self.index}, crashes={self.crashes})"


class WorkerCrashError(RuntimeError):
    """Raised only when supervision itself cannot make progress (e.g. a
    worker dies faster than it can accept any task, repeatedly)."""


def _worker_main(conn, fn: Callable, seed: int, plan) -> None:
    """Worker loop: receive ``(index, attempt, payload)``, run, reply.

    The parent's fault plan is installed first, so injected ``worker.crash``
    faults fire *here* — a hard ``os._exit`` that never unwinds, exactly
    like a segfault from the parent's point of view.
    """
    random.seed(seed)
    if plan is not None:
        _faults.install(plan)
    while True:
        msg = conn.recv()
        if msg is None:
            conn.close()
            return
        index, attempt, payload = msg
        inj = _faults.active()
        if inj is not None and inj.fire("worker.crash", str(index), attempt):
            os._exit(13)
        try:
            result = fn(payload)
        except BaseException as exc:  # ship the exception to the parent
            try:
                conn.send((index, False, exc))
            except Exception:
                conn.send((index, False, RuntimeError(repr(exc))))
            continue
        conn.send((index, True, result))


class _Worker:
    __slots__ = ("proc", "conn", "current", "attempt")

    def __init__(self, ctx, fn, seed, plan):
        parent_conn, child_conn = ctx.Pipe(duplex=True)
        self.proc = ctx.Process(
            target=_worker_main, args=(child_conn, fn, seed, plan), daemon=True
        )
        self.proc.start()
        child_conn.close()
        self.conn = parent_conn
        self.current: Optional[int] = None  # task index in flight
        self.attempt = 0


def supervised_map(
    fn: Callable,
    payloads: Sequence,
    workers: int,
    seed: int = 0,
    quarantine_after: int = QUARANTINE_AFTER,
) -> List[object]:
    """Map ``fn`` over ``payloads`` on a supervised process pool.

    Returns results in submission order; slots whose task was quarantined
    hold a :class:`Quarantined` instance.  ``workers <= 1`` (or a single
    payload) runs in-process — byte-identical results, no supervision
    needed (and injected worker crashes never fire in-process: they would
    take down the caller, which is exactly what the pool exists to
    prevent).
    """
    n = len(payloads)
    if workers <= 1 or n <= 1:
        return [fn(p) for p in payloads]
    try:
        ctx = multiprocessing.get_context("fork")
    except ValueError:  # pragma: no cover - non-POSIX fallback
        ctx = multiprocessing.get_context("spawn")

    plan = None
    inj = _faults.active()
    if inj is not None:
        plan = inj.plan

    n_workers = min(workers, n)
    results: List[object] = [None] * n
    done = [False] * n
    crashes = [0] * n
    pending: List[int] = list(range(n))  # FIFO of task indices
    task_error: Optional[BaseException] = None

    pool: List[_Worker] = [
        _Worker(ctx, fn, seed, plan) for _ in range(n_workers)
    ]

    def dispatch() -> None:
        for i, w in enumerate(pool):
            if w.current is None and pending and task_error is None:
                idx = pending.pop(0)
                try:
                    w.conn.send((idx, crashes[idx], payloads[idx]))
                except (OSError, BrokenPipeError):
                    # worker died while idle: replace it and re-queue
                    pending.insert(0, idx)
                    w.proc.join()
                    pool[i] = _Worker(ctx, fn, seed, plan)
                    continue
                w.current = idx
                w.attempt = crashes[idx]

    def handle_crash(w: _Worker) -> Optional[_Worker]:
        idx = w.current
        try:
            w.conn.close()
        except OSError:
            pass
        w.proc.join()
        if idx is not None:
            crashes[idx] += 1
            if obs.enabled():
                obs.metrics().counter("workerpool.crashes").inc()
            if crashes[idx] >= quarantine_after:
                results[idx] = Quarantined(idx, crashes[idx])
                done[idx] = True
                if obs.enabled():
                    obs.metrics().counter("workerpool.quarantined").inc()
            else:
                pending.insert(0, idx)  # retry first: keeps latency bounded
        # restart unless there is nothing left for a fresh worker to do
        if pending or any(
            ww.current is not None for ww in pool if ww is not w
        ):
            if obs.enabled():
                obs.metrics().counter("workerpool.restarts").inc()
            return _Worker(ctx, fn, seed, plan)
        return None

    try:
        while not all(done) and task_error is None:
            dispatch()
            busy = [w for w in pool if w.current is not None]
            if not busy:
                if pending:
                    # workers died without accepting work and were not
                    # replaced — cannot happen unless spawning itself fails
                    raise WorkerCrashError(
                        "no live workers left with tasks still pending"
                    )
                break
            readable = _conn_wait(
                [w.conn for w in busy] + [w.proc.sentinel for w in busy]
            )
            replaced: List[tuple] = []
            for w in busy:
                if w.conn in readable:
                    try:
                        index, ok, value = w.conn.recv()
                    except (EOFError, OSError):
                        # died mid-send: treat as a crash on this task
                        nw = handle_crash(w)
                        if nw is not None:
                            replaced.append((w, nw))
                        continue
                    if ok:
                        results[index] = value
                        done[index] = True
                    else:
                        task_error = value
                    w.current = None
                elif w.proc.sentinel in readable and not w.conn.poll():
                    nw = handle_crash(w)
                    if nw is not None:
                        replaced.append((w, nw))
            for old, new in replaced:
                pool[pool.index(old)] = new
    finally:
        for w in pool:
            try:
                if w.proc.is_alive() and w.current is None:
                    w.conn.send(None)
            except (OSError, BrokenPipeError):
                pass
        for w in pool:
            w.proc.join(timeout=2.0)
            if w.proc.is_alive():
                w.proc.terminate()
                w.proc.join(timeout=2.0)
            try:
                w.conn.close()
            except OSError:
                pass

    if task_error is not None:
        raise task_error
    return results
