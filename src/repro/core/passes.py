"""The unified spill-transform pass pipeline (paper §3 machinery).

Every spilling flow in this repo — RegDem demotion (:func:`repro.core.
regdem.demote`), the nvcc ``--maxrregcount`` model (:func:`repro.core.
variants.aggressive`), the Hayes & Zhang local→shared conversion, and the
pyReDe translator's variant enumeration — is one machine: reserve scratch
registers, emit an addressing prologue, move register words into a
:class:`~repro.core.spillspace.SpillSpace`, then clean up (redundancy
elimination, compaction, substitution, rescheduling, stall fixup).  This
module expresses that machine once:

* :class:`Pass`          one named transformation over a :class:`PassContext`;
* :class:`PassContext`   kernel + spill space + reserved registers +
                         candidate queue + per-pass diagnostics/timings;
* :class:`PassPipeline`  runs a pass schedule and, after **every** pass,
                         the schedule verifier and the dataflow-equivalence
                         oracle (``verify="each"``, the default) — a pipeline
                         that corrupts a kernel mid-flight fails loudly at
                         the exact pass that broke it.

The concrete passes mirror the paper's transformation stack: prologue
(§3.2), per-register demotion (Fig. 3), rematerialization (§5.3's nvcc
model), redundancy elimination (§3.4.2 pass 1), compaction (§3.3),
substitution (§3.4.2 pass 3), rescheduling (§3.4.2 pass 2), stall fixup.
:func:`demotion_pipeline` and :func:`aggressive_pipeline` assemble the two
schedules; ``demote()``/``aggressive()``/``make_variants()``/``translate()``
are thin configurations of them.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

from repro import obs

from .candidates import make_candidates, operand_conflicts
from .compaction import compact, packed_reg_count
from .isa import (
    RZ,
    Instr,
    Kernel,
    Label,
    equivalent,
)
from .sched import _blocks, fixup_stalls, repair_war, verify_block
from .spillspace import SpillSpace

#: Hard floor below which demotion gives no occupancy benefit (paper §3).
REG_FLOOR = 32

#: Process-wide pipeline execution counters (observability; the translation
#: cache's acceptance test reads these to prove a cache hit ran zero passes).
PIPELINE_COUNTERS = {"pipelines": 0, "passes": 0}


class PassVerificationError(RuntimeError):
    """A pipeline self-check failed: the named pass broke the kernel."""


# ---------------------------------------------------------------------------
# Incremental verification signatures
# ---------------------------------------------------------------------------
#
# The pipeline's self-checks are incremental: each check records what it
# proved, keyed by content signatures, so the next check only re-analyzes
# what a pass actually touched.
#
# * The *schedule* verifier is per-barrier-scope local (barriers never span
#   scopes), so only scopes whose scheduling signature changed re-verify.
# * The *dataflow* oracle (interpreter equivalence vs the original) is
#   whole-program, but a pass that leaves every semantic field untouched —
#   e.g. a stall fixup, which edits only control words — cannot change
#   dataflow, so the oracle is skipped while the semantic signature of the
#   kernel matches the last proven-equivalent state.
#
# Signatures are full tuples (not hashes): a skipped check must imply true
# content identity, never a hash coincidence.


def _sem_sig_item(it) -> tuple:
    """Everything the scalar interpreter can observe about one stream item."""
    if isinstance(it, Label):
        return ("L", it.name)
    return (
        it.op, tuple(it.dsts), tuple(it.srcs), it.imm, it.offset, it.target,
        it.pred, it.pred_neg, it.pdst, it.trip_count,
    )


def _sem_signature(kernel: Kernel) -> tuple:
    """Semantic content of the whole kernel (dataflow-oracle inputs)."""
    return (
        tuple(_sem_sig_item(it) for it in kernel.items),
        frozenset(kernel.live_in),
        frozenset(kernel.live_out),
    )


def _sched_signature(block: List[Instr]) -> tuple:
    """Schedule-verifier-visible content of one barrier scope."""
    return tuple(
        (
            _sem_sig_item(i),
            i.ctrl.stall,
            i.ctrl.write_bar,
            i.ctrl.read_bar,
            tuple(sorted(i.ctrl.wait)),
        )
        for i in block
    )


@dataclass
class RegDemOptions:
    """Optimization options (the paper's exhaustive-search dimensions)."""

    candidate_strategy: str = "cfg"      # §3.4.3 (Fig. 8)
    bank_avoid: bool = True              # §3.4.1 (Fig. 7)
    elim_redundant: bool = True          # §3.4.2 pass 1 (Fig. 7)
    reschedule: bool = True              # §3.4.2 pass 2 (Fig. 7)
    substitute: bool = True              # §3.4.2 pass 3 (Fig. 7)

    def label(self) -> str:
        flags = "".join(
            "1" if f else "0"
            for f in (self.bank_avoid, self.elim_redundant, self.reschedule, self.substitute)
        )
        return f"{self.candidate_strategy}:{flags}"


@dataclass
class PassStat:
    """One executed pass: wall time plus whatever the pass reported."""

    name: str
    seconds: float
    stats: Dict[str, int] = field(default_factory=dict)

    def describe(self) -> str:
        body = " ".join(f"{k}={v}" for k, v in sorted(self.stats.items()))
        return f"{self.name}: {self.seconds * 1e3:.2f}ms {body}".rstrip()


def stats_by_pass(passes: Sequence[PassStat]) -> Dict[str, Dict[str, int]]:
    """Executed-pass stats keyed by pass name, duplicates preserved.

    A schedule may legitimately run the same pass more than once (e.g. a
    tuning pipeline that re-runs ``fixup_stalls``); re-runs get ``#2``,
    ``#3``, ... suffixes in execution order instead of silently overwriting
    the first run's numbers.
    """
    out: Dict[str, Dict[str, int]] = {}
    seen: Dict[str, int] = {}
    for p in passes:
        n = seen.get(p.name, 0) + 1
        seen[p.name] = n
        key = p.name if n == 1 else f"{p.name}#{n}"
        out[key] = dict(p.stats)
    return out


class PassContext:
    """Everything the passes share for one spilling run over one kernel.

    The context owns a *copy* of the input kernel (``self.kernel``) and keeps
    the untouched original (``self.original``) for the pipeline's
    dataflow-equivalence self-check.
    """

    def __init__(
        self,
        kernel: Kernel,
        space: SpillSpace,
        options: Optional[RegDemOptions] = None,
        target: int = REG_FLOOR,
        floor: Optional[int] = None,
        max_remat: Optional[int] = None,
        select: Optional[Callable[[Kernel], List[Tuple[int, int]]]] = None,
    ):
        self.original = kernel
        self.kernel = kernel.copy()
        self.space = space
        self.options = options or RegDemOptions()
        self.target = target
        #: the kernel's architecture descriptor — parameterizes barrier
        #: tracking, register banking, and the spill budget for every pass
        from repro.arch import arch_of

        self.arch = arch_of(kernel)
        #: register count at which spilling stops; RegDem clamps to
        #: REG_FLOOR (no occupancy benefit below 32), the aggressive
        #: allocator honours the raw target like nvcc does
        self.floor = max(target, REG_FLOOR) if floor is None else floor
        self.max_remat = max_remat

        #: ordered demotion queue [(leading_reg, width)], pruned as passes
        #: run.  ``select`` overrides the default queue builder — registered
        #: strategies (:mod:`repro.core.strategies`) use it to filter or
        #: reorder candidates beyond the paper's three orderings.
        self.candidates: List[Tuple[int, int]] = (
            select(self.kernel)
            if select is not None
            else make_candidates(self.kernel, self.options.candidate_strategy)
        )
        self.conflicts: Dict[int, Set[int]] = operand_conflicts(self.kernel)

        # reserved registers (filled by ReserveRegistersPass)
        self.rdv: int = RZ          # demoted-value register
        self.rda: int = RZ          # demoted-base-address register
        self.rtmp: Optional[int] = None  # rematerialization temporary
        self.wide: bool = False     # RDV is an even-aligned pair

        # outcome accumulators
        self.demoted: List[Tuple[int, int]] = []   # (original reg, width)
        self.demoted_words: int = 0
        self.remat: int = 0
        self.rematted: Set[int] = set()

        #: per-pass diagnostics/timings, in execution order
        self.passes: List[PassStat] = []

        # incremental-verification state: per-scope schedule signatures last
        # proven valid (None = nothing proven yet) and the semantic signature
        # last proven dataflow-equivalent to the original (the fresh copy is
        # equivalent by construction)
        self._sched_sigs: Optional[List[tuple]] = None
        self._sem_verified: tuple = _sem_signature(self.kernel)

    def pass_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-pass stats keyed by pass name; a re-run pass gets a ``#n``
        suffix (see :func:`stats_by_pass`) instead of clobbering the first
        run's numbers."""
        return stats_by_pass(self.passes)


class Pass:
    """One named, self-contained transformation over a :class:`PassContext`.

    Subclasses set :attr:`name` and implement :meth:`run`, returning a stats
    dict (or ``None``) that the pipeline records with the pass timing.
    """

    name: str = "pass"

    def run(self, ctx: PassContext) -> Optional[Dict[str, int]]:
        raise NotImplementedError


class PassPipeline:
    """A pass schedule with built-in self-checking.

    ``verify`` policies:

    * ``"each"``      (default) after every pass, run the schedule verifier
                      and the dataflow-equivalence oracle against the
                      original kernel — the paper's translator promise,
                      enforced at pass granularity;
    * ``"schedule"``  schedule verifier only after every pass (cheap);
    * ``"final"``     both checks once, after the last pass;
    * ``"none"``      no checks (callers own verification).
    """

    VERIFY_MODES = ("each", "schedule", "final", "none")

    def __init__(self, passes: Sequence[Pass], verify: str = "each"):
        if verify not in self.VERIFY_MODES:
            raise ValueError(f"unknown verify mode {verify!r}; want one of {self.VERIFY_MODES}")
        self.passes = list(passes)
        self.verify = verify

    def run(
        self,
        ctx: PassContext,
        observer: Optional[Callable[[Pass, PassContext], None]] = None,
    ) -> PassContext:
        PIPELINE_COUNTERS["pipelines"] += 1
        with obs.span(
            "pipeline", kernel=ctx.kernel.name, passes=len(self.passes),
            verify=self.verify,
        ):
            for p in self.passes:
                with obs.span(f"pass:{p.name}"):
                    t0 = time.perf_counter()
                    stats = p.run(ctx) or {}
                    dt = time.perf_counter() - t0
                ctx.passes.append(PassStat(p.name, dt, stats))
                PIPELINE_COUNTERS["passes"] += 1
                if obs.enabled():
                    reg = obs.metrics()
                    reg.counter("pipeline.passes").inc()
                    reg.histogram(f"pass.{p.name}.ms").observe(dt * 1e3)
                    for k, v in stats.items():
                        if isinstance(v, (int, float)) and v:
                            reg.counter(f"pass.{p.name}.{k}").inc(v)
                if self.verify == "each":
                    self.check(ctx, p.name)
                elif self.verify == "schedule":
                    self.check(ctx, p.name, semantics=False)
                if observer is not None:
                    observer(p, ctx)
            if self.verify == "final":
                self.check(ctx, "final")
            if obs.enabled():
                obs.metrics().counter("pipeline.runs").inc()
        return ctx

    @staticmethod
    def check(ctx: PassContext, label: str, semantics: bool = True) -> None:
        """Incremental self-check: re-verify only what changed.

        Barrier scopes whose scheduling signature matches the last proven
        state are skipped (scope verification is content-local); the
        whole-program dataflow oracle is skipped while the kernel's semantic
        signature matches the last proven-equivalent state (e.g. after a
        stall fixup, which edits only control words).
        """
        blocks = _blocks(ctx.kernel)
        sigs = [_sched_signature(b) for b in blocks]
        old = ctx._sched_sigs
        for i, (block, sig) in enumerate(zip(blocks, sigs)):
            if old is not None and i < len(old) and old[i] == sig:
                continue
            errs = verify_block(block, ctx.arch.num_barriers)
            if errs:
                ctx._sched_sigs = None
                raise PassVerificationError(
                    f"{ctx.kernel.name}: schedule violations after pass "
                    f"'{label}': {errs[:3]}"
                )
        ctx._sched_sigs = sigs
        if semantics:
            sem = _sem_signature(ctx.kernel)
            if sem != ctx._sem_verified:
                if not equivalent(ctx.original, ctx.kernel):
                    raise PassVerificationError(
                        f"{ctx.kernel.name}: dataflow mismatch vs original "
                        f"after pass '{label}'"
                    )
                ctx._sem_verified = sem


# ---------------------------------------------------------------------------
# Barrier tracker (Fig. 3, lines 32-53)
# ---------------------------------------------------------------------------


class BarrierTracker:
    """Tracks which instruction last set each scoreboard barrier and the
    stall cycles elapsed since, to hand out the least-costly barrier.

    ``arch`` supplies the barrier count and the residual-latency table
    (``None`` = Maxwell)."""

    def __init__(self, arch=None) -> None:
        if arch is None:
            from repro.arch import get_arch

            arch = get_arch("maxwell")
        self.arch = arch
        self.num_barriers = arch.num_barriers
        self.slots: List[Optional[List]] = [None] * self.num_barriers

    def reset(self) -> None:
        """Barriers cannot span basic blocks (cleared before jumps)."""
        self.slots = [None] * self.num_barriers

    def get_barrier(self, setter: Instr) -> int:
        """Fig. 3 ``GetBarrier``: a free barrier, else the one whose pending
        latency is closest to already-elapsed (minimum residual stall).

        When a busy barrier must be reused, the new setter first *waits* on
        it — this is the "additional stalls" the paper describes, made
        explicit so the schedule verifier and simulator see the true cost.
        """
        for b in range(self.num_barriers):
            if self.slots[b] is None:
                self.slots[b] = [setter, 0]
                return b
        best_b, best_stall = None, self.arch.latency.global_mem + 1
        for b in range(self.num_barriers):
            inst, elapsed = self.slots[b]
            residual = self.arch.residual_latency(inst.info.klass) - elapsed
            if residual < best_stall:
                best_b, best_stall = b, residual
        setter.ctrl.wait.add(best_b)
        self.slots[best_b] = [setter, 0]
        return best_b

    def update(self, inst: Instr) -> None:
        """Fig. 3 ``UpdateBarrierTracker`` (waits cleared before records so
        that a forced reuse in :meth:`get_barrier` stays consistent)."""
        slots = self.slots
        ctrl = inst.ctrl
        for b in ctrl.wait:
            s = slots[b]
            if s is not None and s[0] is not inst:
                slots[b] = None
        if ctrl.read_bar is not None:
            slots[ctrl.read_bar] = [inst, 0]
        if ctrl.write_bar is not None:
            slots[ctrl.write_bar] = [inst, 0]
        stall = ctrl.stall
        for s in slots:
            if s is not None and s[0] is not inst:
                s[1] += stall


# ---------------------------------------------------------------------------
# RDV bank choice (§3.4.1, first strategy)
# ---------------------------------------------------------------------------


def choose_rdv_bank(
    kernel: Kernel,
    candidates: Sequence[Tuple[int, int]],
    wide: bool,
    arch=None,
) -> int:
    """Pick the register bank for RDV minimizing same-instruction conflicts.

    For every instruction that touches a candidate register, count the source
    operands (post-rename survivors) that would share RDV's bank.  Banking
    comes from the architecture (Maxwell: 4 banks, even banks for pairs;
    Volta: 2 banks, pairs pinned to bank 0).
    """
    if arch is None:
        from repro.arch import arch_of

        arch = arch_of(kernel)
    cand_regs = {r for r, _ in candidates}
    banks = arch.rdv_banks(wide)
    scores = {b: 0 for b in banks}
    for ins in kernel.instructions():
        touched = [r for r in ins.leading_regs() if r in cand_regs]
        if not touched:
            continue
        others = [r for r in ins.src_words() if r not in cand_regs and r != RZ]
        for b in banks:
            scores[b] += sum(1 for r in others if arch.reg_bank(r) == b)
    return min(banks, key=lambda b: (scores[b], b))


# ---------------------------------------------------------------------------
# The per-register demotion transform (Fig. 3 main loop body)
# ---------------------------------------------------------------------------


def demote_register(
    k: Kernel,
    r: int,
    width: int,
    offsets: List[int],
    rdv: int,
    rda: int,
    space: SpillSpace,
) -> None:
    """Demote one register: walk the program, rename ``r`` -> RDV, insert
    spill-space loads/stores with tracked barriers.

    The :class:`~repro.core.spillspace.SpillSpace` supplies the opcodes:
    shared space (``LDS``/``STS``, rda=tid*4) realizes RegDem's demotion;
    local space (``LDL``/``STL``, rda=RZ) realizes nvcc-style local-memory
    spilling for the comparison variants (§5.3)."""
    from repro.arch import arch_of

    tracker = BarrierTracker(arch_of(k))
    new_items: List[object] = []
    #: waits to attach to the next real instruction (line 18-19 of Fig. 3)
    pending_next_wait: Set[int] = set()
    #: register word -> unresolved read barrier guarding it (a store still
    #: holds the register as a source operand).  A new writer of the word —
    #: e.g. an inserted demoted load clobbering RDV after a *user* store
    #: whose address register was demoted — must wait on it (WAR).
    pending_read: Dict[int, int] = {}
    prev_real: Optional[Instr] = None

    def append(ins_or_label) -> None:
        nonlocal prev_real
        new_items.append(ins_or_label)
        if isinstance(ins_or_label, Instr):
            nonlocal pending_next_wait
            ins = ins_or_label
            ctrl = ins.ctrl
            if pending_next_wait:
                ctrl.wait |= pending_next_wait
                pending_next_wait = set()
            if pending_read:
                # WAR guard against in-flight store reads
                for rw in ins.dst_words():
                    if rw in pending_read:
                        ctrl.wait.add(pending_read.pop(rw))
                if pending_read and ctrl.wait:
                    waits = ctrl.wait
                    for rw in [
                        r for r, bb in pending_read.items() if bb in waits
                    ]:
                        del pending_read[rw]
            if ctrl.read_bar is not None:
                for rw in ins.src_words():
                    if rw != RZ:
                        pending_read[rw] = ctrl.read_bar
            tracker.update(ins)
            prev_real = ins

    for it in k.items:
        if isinstance(it, Label):
            tracker.reset()
            pending_read.clear()
            new_items.append(it)
            continue
        ins: Instr = it
        if ins.info.is_branch:
            tracker.reset()
            pending_read.clear()
        if r not in ins.leading_regs():
            append(ins)
            continue

        is_dst = r in ins.dsts
        is_src = r in ins.srcs
        ins.rename(r, rdv)

        # ---- read access: LDS RDV, [RDA+offset] before inst (lines 20-29) --
        if is_src:
            for j in range(width):
                lds = Instr(
                    space.load_op,
                    [rdv + j],
                    [rda],
                    offset=offsets[j],
                    pred=ins.pred,
                    pred_neg=ins.pred_neg,
                    tag="demoted_load",
                )
                lds.ctrl.read_bar = tracker.get_barrier(lds)
                lds.ctrl.write_bar = tracker.get_barrier(lds)
                if (
                    prev_real is not None
                    and prev_real.tag == "demoted_store"
                    and prev_real.ctrl.read_bar is not None
                ):
                    # RDV must be free before the demoted register is loaded
                    lds.ctrl.wait.add(prev_real.ctrl.read_bar)
                append(lds)
                if space.unpack_op is not None:
                    # the unpack consumes the loaded value, taking over the
                    # load's barrier waits; the renamed instruction then only
                    # needs the fixed-latency ALU gap fixup_stalls inserts
                    upk = Instr(
                        space.unpack_op,
                        [rdv + j],
                        [rdv + j],
                        pred=ins.pred,
                        pred_neg=ins.pred_neg,
                        tag="demoted_unpack",
                    )
                    upk.ctrl.wait.add(lds.ctrl.read_bar)
                    upk.ctrl.wait.add(lds.ctrl.write_bar)
                    append(upk)
                else:
                    ins.ctrl.wait.add(lds.ctrl.read_bar)
                    ins.ctrl.wait.add(lds.ctrl.write_bar)
        append(ins)

        # ---- write access: STS [RDA+offset], RDV after inst (lines 11-19) --
        if is_dst:
            for j in range(width):
                if ins.info.needs_write_barrier and ins.ctrl.write_bar is None:
                    ins.ctrl.write_bar = tracker.get_barrier(ins)
                if space.pack_op is not None:
                    # the pack consumes the produced value, taking over the
                    # producer's write-barrier wait; the store then only
                    # needs the ALU gap against the pack
                    pck = Instr(
                        space.pack_op,
                        [rdv + j],
                        [rdv + j],
                        pred=ins.pred,
                        pred_neg=ins.pred_neg,
                        tag="demoted_pack",
                    )
                    if ins.ctrl.write_bar is not None:
                        pck.ctrl.wait.add(ins.ctrl.write_bar)
                    append(pck)
                sts = Instr(
                    space.store_op,
                    srcs=[rda, rdv + j],
                    offset=offsets[j],
                    pred=ins.pred,
                    pred_neg=ins.pred_neg,
                    tag="demoted_store",
                )
                if space.pack_op is None and ins.ctrl.write_bar is not None:
                    sts.ctrl.wait.add(ins.ctrl.write_bar)
                sts.ctrl.read_bar = tracker.get_barrier(sts)
                append(sts)
                # the *next* instruction must wait for RDV to be read back out
                # (Fig. 3 lines 18-19) — recorded after append so the store
                # does not wait on its own barrier
                pending_next_wait.add(sts.ctrl.read_bar)

    # drain: if the stream ended with a pending wait, park it on the last
    # real instruction (kernels end in EXIT, so this is the normal path)
    if pending_next_wait and prev_real is not None:
        prev_real.ctrl.wait |= pending_next_wait
    k.items = new_items


# ---------------------------------------------------------------------------
# Rematerialization helpers (the nvcc --maxrregcount model, §5.3)
# ---------------------------------------------------------------------------


def _const_defs(kernel: Kernel) -> Dict[int, float]:
    """Registers defined exactly once, by a ``MOV32I`` (rematerializable)."""
    defs: Dict[int, List[Instr]] = {}
    for ins in kernel.instructions():
        for r in ins.dsts:
            defs.setdefault(r, []).append(ins)
    out: Dict[int, float] = {}
    for r, instrs in defs.items():
        if len(instrs) == 1 and instrs[0].op == "MOV32I" and instrs[0].pred is None:
            out[r] = instrs[0].imm or 0.0
    return out


def _remat_one(kernel: Kernel, r: int, value: float, tmp: int) -> None:
    """Remove ``r``'s constant definition; recompute into ``tmp`` before each
    use ("less efficient instruction sequences", paper §1)."""
    new_items: List[object] = []
    for it in kernel.items:
        if isinstance(it, Label):
            new_items.append(it)
            continue
        ins: Instr = it
        if ins.op == "MOV32I" and ins.dsts == [r]:
            continue  # drop the definition
        if r in ins.srcs:
            mov = Instr(
                "MOV32I",
                [tmp],
                imm=value,
                pred=ins.pred,
                pred_neg=ins.pred_neg,
                tag="remat",
            )
            new_items.append(mov)
            ins.srcs = [tmp if s == r else s for s in ins.srcs]
        new_items.append(ins)
    kernel.items = new_items


# ---------------------------------------------------------------------------
# Concrete passes (the paper's transformation stack)
# ---------------------------------------------------------------------------


class ReserveRegistersPass(Pass):
    """Reserve RDV (+ alias for pair demotion), the optional remat temporary,
    and RDA when the spill space needs a base register — "at least two
    registers must be added" (§3.2)."""

    name = "reserve"

    def __init__(self, bank_tune: bool = False, remat_temp: bool = False):
        self.bank_tune = bank_tune      # §3.4.1 RDV bank choice
        self.remat_temp = remat_temp    # distinct temp for rematerialization

    def run(self, ctx: PassContext) -> Dict[str, int]:
        k = ctx.kernel
        wide = any(w == 2 for _, w in ctx.candidates)
        base = k.reg_count
        if wide and base % 2:
            base += 1  # RDV must be even-numbered for pair demotion (§3.2)
        if self.bank_tune and ctx.options.bank_avoid:
            want_bank = choose_rdv_bank(k, ctx.candidates, wide, ctx.arch)
            rdv = base
            step = 2 if wide else 1
            while ctx.arch.reg_bank(rdv) != want_bank:
                rdv += step
        else:
            rdv = base
        nxt = rdv + (2 if wide else 1)
        if self.remat_temp:
            # one instruction may need both a reloaded spill and a recomputed
            # constant simultaneously
            ctx.rtmp = nxt
            nxt += 1
        if ctx.space.needs_base:
            ctx.rda = nxt
            k.rda = nxt
        else:
            ctx.rda = RZ
        ctx.rdv = rdv
        ctx.wide = wide
        return {"rdv": rdv, "rda": ctx.rda, "wide": int(wide)}


class ProloguePass(Pass):
    """Base-address setup at kernel entry (§3.2: RDA = tid*4 for shared
    space); a no-op for spaces without a base register."""

    name = "prologue"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        return {"inserted": ctx.space.emit_prologue(ctx)}


class RematerializationPass(Pass):
    """nvcc's documented preference: recompute single-def constants instead
    of spilling, trading dynamic instructions for register pressure (§5.3).
    Two rematerialized values in one instruction would need two temps, so
    conflicting candidates are skipped (same rule as demotion conflicts)."""

    name = "rematerialize"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        k = ctx.kernel
        consts = _const_defs(k)
        done = 0
        for r, width in list(ctx.candidates):
            if packed_reg_count(k) <= ctx.floor:
                break
            if width != 1 or r not in consts:
                continue
            if ctx.max_remat is not None and ctx.remat + done >= ctx.max_remat:
                break
            if ctx.conflicts.get(r, set()) & ctx.rematted:
                continue
            _remat_one(k, r, consts[r], ctx.rtmp)
            done += 1
            ctx.rematted.add(r)
            ctx.candidates = [(v, w) for v, w in ctx.candidates if v != r]
        war_added = repair_war(k)
        ctx.remat += done
        return {"rematerialized": done, "war_waits_added": war_added}


class DemotionPass(Pass):
    """The Fig. 3 main loop: demote candidates one at a time until the
    register floor is reached, pruning operand conflicts (§3.1 challenge 2)
    after every demoted register."""

    name = "demote"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        k = ctx.kernel
        regs = words = pruned = 0
        space_full = 0
        while ctx.candidates:
            if packed_reg_count(k) <= ctx.floor:
                break
            if not ctx.space.has_room(ctx, ctx.candidates[0][1]):
                space_full = 1
                break
            r, width = ctx.candidates.pop(0)
            offsets = ctx.space.offsets(ctx, width)
            demote_register(k, r, width, offsets, ctx.rdv, ctx.rda, ctx.space)
            ctx.demoted.append((r, width))
            ctx.demoted_words += width
            ctx.space.account(ctx)
            regs += 1
            words += width
            bad = ctx.conflicts.get(r, set())
            before = len(ctx.candidates)
            ctx.candidates = [(c, w) for c, w in ctx.candidates if c not in bad]
            pruned += before - len(ctx.candidates)
        return {
            "demoted_regs": regs,
            "demoted_words": words,
            "conflicts_pruned": pruned,
            "space_full": space_full,
        }


class RedundancyEliminationPass(Pass):
    """§3.4.2 pass 1: drop provably redundant demoted loads/stores."""

    name = "eliminate_redundant"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        from . import postopt

        return {"removed": postopt.eliminate_redundant(ctx.kernel, ctx.rdv)}


class CompactionPass(Pass):
    """§3.3: pack the register space through the relocation space, then
    re-aim RDV/RDA at their post-compaction homes."""

    name = "compact"

    def __init__(self, bank_avoid: Optional[bool] = None):
        #: None = follow ctx.options.bank_avoid (the §3.4.1 variant)
        self.bank_avoid = bank_avoid

    def run(self, ctx: PassContext) -> Dict[str, int]:
        k = ctx.kernel
        bank = ctx.options.bank_avoid if self.bank_avoid is None else self.bank_avoid
        moves = compact(k, bank_avoid=bank)
        ctx.rdv = moves.get(ctx.rdv, ctx.rdv)
        ctx.rda = k.rda if k.rda is not None else ctx.rda
        return {"moved": len(moves), "reg_count": k.reg_count}


class SubstitutionPass(Pass):
    """§3.4.2 pass 3: give distinct demoted-access spans distinct free
    registers so several demoted values can be in flight simultaneously."""

    name = "substitute"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        from . import postopt

        renamed = postopt.substitute_value_register(
            ctx.kernel, ctx.rdv, ctx.kernel.reg_count
        )
        return {"renamed_spans": renamed}


class ReschedulePass(Pass):
    """§3.4.2 pass 2: hoist demoted loads earlier and relax demoted-store
    read barriers where provably safe."""

    name = "reschedule"

    def run(self, ctx: PassContext) -> Dict[str, int]:
        from . import postopt

        return {"moved": postopt.reschedule(ctx.kernel, ctx.rdv, ctx.rda)}


class StallFixupPass(Pass):
    """Recompute stall counts for the transformed stream, keeping the
    barrier assignments the demotion machinery placed."""

    name = "fixup_stalls"

    def run(self, ctx: PassContext) -> None:
        fixup_stalls(ctx.kernel)


class PoolAnchorPass(Pass):
    """Charge the warp-pool register cost (arXiv 1503.05694) honestly.

    Warp-level resource sharing backs demoted slots with the register file:
    each warp gives up its share of the pool — ``ceil(demoted_words /
    share)`` architectural registers.  The compiler model can't shrink the
    register file, so after compaction this pass anchors the kernel's
    register count at the true post-sharing demand by defining the highest
    pool register with a dead ``MOV`` at kernel entry.  Runs after
    :class:`CompactionPass` (so compaction can't pack the charge away) and
    before :class:`StallFixupPass` (the anchor is an ordinary 1-stall ALU
    op)."""

    name = "pool_anchor"

    def __init__(self, share: int):
        if share < 2:
            raise ValueError(f"warp pool needs share >= 2 warps, got {share}")
        self.share = share

    def run(self, ctx: PassContext) -> Dict[str, int]:
        import math

        if not ctx.demoted_words:
            return {"pool_regs": 0}
        k = ctx.kernel
        pool_regs = math.ceil(ctx.demoted_words / self.share)
        from .isa import Ctrl

        anchor = Instr(
            "MOV",
            [k.reg_count + pool_regs - 1],
            [RZ],
            ctrl=Ctrl(stall=1),
            tag="pool_anchor",
        )
        k.items[:0] = [anchor]
        return {"pool_regs": pool_regs, "reg_count": k.reg_count}


# ---------------------------------------------------------------------------
# Pipeline configurations
# ---------------------------------------------------------------------------


def demotion_pipeline(options: Optional[RegDemOptions] = None, verify: str = "each") -> PassPipeline:
    """RegDem's §3 schedule: prologue → demotion → redundancy elimination →
    compaction → substitution → rescheduling → stall fixup, with the
    optional passes gated by ``options``."""
    options = options or RegDemOptions()
    passes: List[Pass] = [
        ReserveRegistersPass(bank_tune=True),
        ProloguePass(),
        DemotionPass(),
    ]
    if options.elim_redundant:
        passes.append(RedundancyEliminationPass())
    passes.append(CompactionPass())
    if options.substitute:
        passes.append(SubstitutionPass())
    if options.reschedule:
        passes.append(ReschedulePass())
    passes.append(StallFixupPass())
    return PassPipeline(passes, verify=verify)


def aggressive_pipeline(verify: str = "each") -> PassPipeline:
    """The nvcc ``--maxrregcount`` model (§5.3): rematerialize first, spill
    the remainder, compact without bank tuning, fix up stalls."""
    return PassPipeline(
        [
            ReserveRegistersPass(bank_tune=False, remat_temp=True),
            ProloguePass(),
            RematerializationPass(),
            DemotionPass(),
            CompactionPass(bank_avoid=False),
            StallFixupPass(),
        ],
        verify=verify,
    )
