"""VMEM residency policies — the program-level register-demotion analogue.

RegDem's decision (paper §3): for each over-subscribed register, pick the
spill tier (shared memory vs local memory) and accept the access overhead
that maximizes throughput via occupancy.  The framework-level analogue
decides, per layer family, where *cross-iteration working state* lives:

* ``DEMOTE_VMEM``   fused kernel keeps the state in VMEM scratch across the
                    inner loop (flash-attention accumulators, SSD chunk
                    state) — the shared-memory demotion;
* ``SPILL_HBM``     materialize intermediates to HBM between ops (what a
                    naive lowering of the two-pass formulation does) — the
                    local-memory spill;
* ``RECOMPUTE``     rematerialize in backward (remat policy) — nvcc's
                    "slower instruction sequences / zero spilling".

``plan_residency`` sizes the working set against the VMEM budget exactly
like :func:`repro.core.occupancy.spill_targets` sizes spills against shared
memory, and returns per-site decisions the variant generator turns into
(attention impl x remat x block shape) combinations for the TPU predictor.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List

from repro.models import ModelConfig


class Residency(enum.Enum):
    DEMOTE_VMEM = "demote_vmem"
    SPILL_HBM = "spill_hbm"
    RECOMPUTE = "recompute"


VMEM_BUDGET = 64 * 1024 * 1024  # conservative per-core VMEM, bytes


@dataclasses.dataclass(frozen=True)
class Site:
    """One demotion site: a loop-carried working set in a hot kernel."""

    name: str
    #: bytes of carried state per grid step (the "registers" to demote)
    state_bytes: int
    #: bytes of the per-step operand working set
    operand_bytes: int
    #: HBM traffic incurred per step if the state is spilled instead
    spill_bytes_per_step: int
    steps: int


def attention_site(cfg: ModelConfig, seq_q: int, seq_kv: int,
                   block_q: int = 512, block_kv: int = 1024) -> Site:
    dh = cfg.dh
    state = (2 * block_q + block_q * dh) * 4          # m, l, acc (fp32)
    operand = (block_q * dh + 2 * block_kv * dh) * 2  # q, k, v (bf16)
    spill = block_q * dh * 4 + 2 * block_q * 4        # partial o + stats
    return Site(
        name="attention_accumulator",
        state_bytes=state,
        operand_bytes=operand,
        spill_bytes_per_step=spill,
        steps=max(1, seq_kv // block_kv),
    )


def ssd_site(cfg: ModelConfig, seq: int) -> Site:
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    state = h * p * n * 4
    q = cfg.ssm_chunk
    operand = (q * h * p + q * h + 2 * q * n) * 4
    return Site(
        name="ssd_chunk_state",
        state_bytes=state,
        operand_bytes=operand,
        spill_bytes_per_step=state,
        steps=max(1, seq // max(cfg.ssm_chunk, 1)),
    )


def plan_residency(sites: List[Site], vmem_budget: int = VMEM_BUDGET) -> Dict[str, Residency]:
    """Greedy demotion plan: keep state in VMEM while the double-buffered
    working set fits (eq.-1-style budget check); otherwise spill.  States
    that are cheap to recompute relative to their spill traffic recompute."""
    plan: Dict[str, Residency] = {}
    used = 0
    for site in sorted(sites, key=lambda s: -s.spill_bytes_per_step * s.steps):
        need = site.state_bytes + 2 * site.operand_bytes  # double-buffered
        if used + need <= vmem_budget:
            plan[site.name] = Residency.DEMOTE_VMEM
            used += need
        elif site.state_bytes < site.spill_bytes_per_step // 2:
            plan[site.name] = Residency.RECOMPUTE
        else:
            plan[site.name] = Residency.SPILL_HBM
    return plan


def spilled_hbm_traffic(site: Site, residency: Residency) -> int:
    """Extra HBM bytes a non-demoted site pays (feeds the memory term)."""
    if residency is Residency.DEMOTE_VMEM:
        return 0
    if residency is Residency.SPILL_HBM:
        return site.spill_bytes_per_step * site.steps * 2  # write + read back
    return site.spill_bytes_per_step  # recompute: one final write
