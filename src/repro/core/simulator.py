"""Cycle-approximate multi-warp timing simulator for the abstract ISA.

The paper evaluates variants with nvprof on a GTX Titan X.  Without the GPU,
this simulator is the measurement instrument: it models the Maxwell
microarchitecture features that RegDem's trade-off lives on:

* **occupancy-driven latency hiding** — ``resident_warps`` warps round-robin
  on an SM with an issue width of 4 (four warp schedulers); a warp blocked on
  a scoreboard barrier or stall count lets others issue;
* **scoreboard barriers** — write barriers signal at producer latency
  (global 200cy / shared 24cy / FP64 48cy / SFU 20cy), read barriers at
  operand-read time; wait masks block issue;
* **functional-unit contention** — per-class issue intervals derived from
  unit counts (FP32 128 lanes -> 4 warps/cycle, FP64 4 lanes -> 1 warp per
  8 cycles, LSU/SFU 32 lanes -> 1 warp/cycle).  This is what makes ``md``
  (FP64-bound) immune to occupancy gains, exactly as in §5.5;
* **register bank conflicts** — serialized operand reads extend issue time;
* **stall counts** — fixed-latency dependencies honoured as scheduled.

The simulator executes the *dynamic* instruction stream (loops expanded via
the ``trip_count`` metadata), one SM's resident warps at a time, and scales
to the full launch by wave count.  Its absolute cycle counts are
approximations; variant *ratios* (speedups) are the quantity of interest,
mirroring how the paper reports Fig. 6.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

from .isa import Instr, Kernel, Label, NUM_BARRIERS, OpClass
from .occupancy import MAXWELL, Occupancy, SMConfig, occupancy_of

#: per-class issue interval in cycles per warp-instruction:
#: 32 lanes-per-warp / unit lanes.
ISSUE_INTERVAL: Dict[OpClass, float] = {
    OpClass.FP32: 32 / 128,
    OpClass.INT: 32 / 128,
    OpClass.FP64: 32 / 4,
    OpClass.SFU: 32 / 32,
    OpClass.LSU_GLOBAL: 32 / 32,
    OpClass.LSU_SHARED: 32 / 32,
    OpClass.LSU_LOCAL: 32 / 32,
    OpClass.CONTROL: 32 / 128,
    OpClass.MISC: 32 / 32,
}

#: number of warp schedulers per SM (Maxwell: 4, single-issue modelled)
ISSUE_WIDTH = 4

#: barrier signal latency per class (producer completion).  Local-memory
#: traffic is L1-cached on Maxwell, so its *effective* latency sits between
#: shared memory and DRAM — the paper's whole premise is the ordering
#: shared (24) < local (cached, ~80) < global (200).
LOCAL_EFFECTIVE_LATENCY = 80


def _signal_latency(ins: Instr) -> int:
    k = ins.info.klass
    if k is OpClass.LSU_GLOBAL:
        return 200
    if k is OpClass.LSU_LOCAL:
        return LOCAL_EFFECTIVE_LATENCY
    if k is OpClass.LSU_SHARED:
        return 24
    return k.latency


def flatten_trace(kernel: Kernel, max_len: int = 200_000) -> List[Instr]:
    """Expand the dynamic instruction stream of one warp.

    Backward branches with ``trip_count`` metadata loop that many times;
    unpredicated forward branches are taken; predicated forward branches
    fall through (SIMT serialization of the cold path is approximated by
    the predicated instructions already present in the stream).
    """
    labels = {it.name: i for i, it in enumerate(kernel.items) if isinstance(it, Label)}
    trace: List[Instr] = []
    counters: Dict[int, int] = {}
    pc = 0
    while pc < len(kernel.items):
        it = kernel.items[pc]
        if isinstance(it, Label):
            pc += 1
            continue
        ins: Instr = it
        trace.append(ins)
        if len(trace) > max_len:
            raise RuntimeError(f"{kernel.name}: dynamic trace exceeds {max_len}")
        if ins.info.is_exit:
            break
        if ins.info.is_branch:
            tgt = labels[ins.target]
            if ins.trip_count is not None and tgt < pc:
                n = counters.get(ins.uid, 0) + 1
                counters[ins.uid] = n
                if n < ins.trip_count:
                    pc = tgt
                else:
                    counters[ins.uid] = 0
                    pc += 1
            elif ins.pred is None:
                pc = tgt
            else:
                pc += 1
            continue
        pc += 1
    return trace


@dataclass
class SimResult:
    kernel_name: str
    cycles_per_wave: int
    waves: float
    total_cycles: int
    occupancy: Occupancy
    dynamic_instructions: int
    issue_stalls: int  # cycles where no warp could issue


def simulate(
    kernel: Kernel,
    sm: SMConfig = MAXWELL,
    max_cycles: int = 50_000_000,
) -> SimResult:
    """Simulate one wave of resident warps on one SM; scale by wave count."""
    occ = occupancy_of(kernel, sm)
    trace = flatten_trace(kernel)
    n_warps = max(occ.resident_warps, 1)

    # per-warp state
    pc = [0] * n_warps
    ready = [0.0] * n_warps  # earliest issue cycle
    bar_signal = [[0.0] * NUM_BARRIERS for _ in range(n_warps)]
    done = [False] * n_warps
    n_done = 0

    unit_free: Dict[OpClass, float] = {k: 0.0 for k in OpClass}
    cycle = 0.0
    idle_cycles = 0
    rr = 0  # round-robin pointer

    def warp_next_time(w: int) -> float:
        """Earliest cycle warp ``w`` could issue its next instruction."""
        t = ready[w]
        ins = trace[pc[w]]
        for b in ins.ctrl.wait:
            t = max(t, bar_signal[w][b])
        return t

    while n_done < n_warps and cycle < max_cycles:
        issued = 0
        for k in range(n_warps):
            if issued >= ISSUE_WIDTH:
                break
            w = (rr + k) % n_warps
            if done[w]:
                continue
            ins = trace[pc[w]]
            if ready[w] > cycle:
                continue
            if any(bar_signal[w][b] > cycle for b in ins.ctrl.wait):
                continue
            klass = ins.info.klass
            # the unit blocks only once this cycle's issue capacity is spent
            # (e.g. FP32 interval 0.25 -> four issues per cycle)
            if unit_free[klass] >= cycle + 1:
                continue
            # ---- issue -----------------------------------------------------
            issued += 1
            unit_free[klass] = max(unit_free[klass], cycle) + ISSUE_INTERVAL[klass]
            issue_cost = max(1, ins.ctrl.stall) + ins.reg_bank_conflicts()
            ready[w] = cycle + issue_cost
            if ins.ctrl.write_bar is not None:
                bar_signal[w][ins.ctrl.write_bar] = cycle + _signal_latency(ins)
            if ins.ctrl.read_bar is not None:
                # operands are read shortly after issue
                bar_signal[w][ins.ctrl.read_bar] = cycle + min(
                    _signal_latency(ins), 20
                )
            pc[w] += 1
            if pc[w] >= len(trace):
                done[w] = True
                n_done += 1
        rr = (rr + 1) % n_warps
        if issued == 0:
            # jump to the next time anything can happen
            nxt = min(
                (warp_next_time(w) for w in range(n_warps) if not done[w]),
                default=cycle + 1,
            )
            nxt = max(nxt, cycle + 1)
            idle_cycles += int(nxt - cycle)
            cycle = nxt
        else:
            cycle += 1

    # fractional waves: charge the launch by work/throughput, not by rounding
    # partial waves up (a 1.2-wave launch is not 2x a 1.0-wave launch)
    blocks_per_wave = max(occ.resident_blocks, 1) * sm.num_sms
    waves = kernel.num_blocks / blocks_per_wave
    return SimResult(
        kernel_name=kernel.name,
        cycles_per_wave=int(cycle),
        waves=max(1.0, waves),
        total_cycles=int(cycle * max(1.0, waves)),
        occupancy=occ,
        dynamic_instructions=len(trace),
        issue_stalls=idle_cycles,
    )


def speedup(base: SimResult, other: SimResult) -> float:
    """Speedup of ``other`` over ``base`` (>1 means faster)."""
    return base.total_cycles / other.total_cycles
