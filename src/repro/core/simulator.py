"""Cycle-approximate multi-warp timing simulator for the abstract ISA.

The paper evaluates variants with nvprof on a GTX Titan X.  Without the GPU,
this simulator is the measurement instrument: it models the Maxwell
microarchitecture features that RegDem's trade-off lives on:

* **occupancy-driven latency hiding** — ``resident_warps`` warps round-robin
  on an SM with an issue width of 4 (four warp schedulers); a warp blocked on
  a scoreboard barrier or stall count lets others issue;
* **scoreboard barriers** — write barriers signal at producer latency
  (global 200cy / shared 24cy / FP64 48cy / SFU 20cy), read barriers at
  operand-read time; wait masks block issue;
* **functional-unit contention** — per-class issue intervals derived from
  unit counts (FP32 128 lanes -> 4 warps/cycle, FP64 4 lanes -> 1 warp per
  8 cycles, LSU/SFU 32 lanes -> 1 warp/cycle).  This is what makes ``md``
  (FP64-bound) immune to occupancy gains, exactly as in §5.5;
* **register bank conflicts** — serialized operand reads extend issue time;
* **stall counts** — fixed-latency dependencies honoured as scheduled.

The simulator executes the *dynamic* instruction stream (loops expanded via
the ``trip_count`` metadata), one SM's resident warps at a time, and scales
to the full launch by wave count.  Its absolute cycle counts are
approximations; variant *ratios* (speedups) are the quantity of interest,
mirroring how the paper reports Fig. 6.

Engine architecture (two stages)
--------------------------------

:func:`simulate` runs a **trace compiler** followed by an **event-driven
issue loop**:

1. :func:`compile_trace` flattens the dynamic stream once and lowers every
   *unique static instruction* to a flat numeric record — op-class index,
   issue cost (stall + register-bank conflicts), scoreboard wait set,
   write/read barrier index, and signal latencies.  The dynamic trace
   becomes a list of record indices, so the hot loop touches no
   :class:`~repro.core.isa.Instr` objects, no properties and no
   generator expressions.
2. :func:`_issue_loop` replays the exact scheduling semantics of the
   original cycle-by-cycle engine over those records, caching each warp's
   next-possible-issue time (it only changes when that warp issues — the
   scoreboard is per-warp state) and skipping idle spans to the next event.

The pre-optimization engine is preserved verbatim as
:func:`simulate_reference`; the golden parity test pins
``simulate() == simulate_reference()`` cycle-exactly across every paper
benchmark × variant.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.obs.stallprof import R_BANK, R_BAR, R_MEM, R_STALL, R_UNIT, StallProfile

from .isa import Instr, Kernel, Label, NUM_BARRIERS, OpClass
from .occupancy import Occupancy, SMConfig, occupancy_of

#: per-class issue interval in cycles per warp-instruction:
#: 32 lanes-per-warp / unit lanes (Maxwell table; per-arch values come
#: from the :mod:`repro.arch` registry).
ISSUE_INTERVAL: Dict[OpClass, float] = {
    OpClass.FP32: 32 / 128,
    OpClass.INT: 32 / 128,
    OpClass.FP64: 32 / 4,
    OpClass.SFU: 32 / 32,
    OpClass.LSU_GLOBAL: 32 / 32,
    OpClass.LSU_SHARED: 32 / 32,
    OpClass.LSU_LOCAL: 32 / 32,
    OpClass.CONTROL: 32 / 128,
    OpClass.MISC: 32 / 32,
}

#: number of warp schedulers per SM (Maxwell: 4, single-issue modelled)
ISSUE_WIDTH = 4

#: barrier signal latency per class (producer completion).  Local-memory
#: traffic is L1-cached on Maxwell, so its *effective* latency sits between
#: shared memory and DRAM — the paper's whole premise is the ordering
#: shared (24) < local (cached, ~80) < global (200).
LOCAL_EFFECTIVE_LATENCY = 80


def _arch_of(kernel: Kernel):
    from repro.arch import arch_of

    return arch_of(kernel)


def _signal_latency(ins: Instr, arch=None) -> int:
    k = ins.info.klass
    if arch is not None:
        return arch.signal_latency(k)
    if k is OpClass.LSU_GLOBAL:
        return 200
    if k is OpClass.LSU_LOCAL:
        return LOCAL_EFFECTIVE_LATENCY
    if k is OpClass.LSU_SHARED:
        return 24
    return k.latency


def flatten_trace(kernel: Kernel, max_len: int = 200_000) -> List[Instr]:
    """Expand the dynamic instruction stream of one warp.

    Backward branches with ``trip_count`` metadata loop that many times;
    unpredicated forward branches are taken; predicated forward branches
    fall through (SIMT serialization of the cold path is approximated by
    the predicated instructions already present in the stream).
    """
    labels = {it.name: i for i, it in enumerate(kernel.items) if isinstance(it, Label)}
    trace: List[Instr] = []
    counters: Dict[int, int] = {}
    pc = 0
    while pc < len(kernel.items):
        it = kernel.items[pc]
        if isinstance(it, Label):
            pc += 1
            continue
        ins: Instr = it
        trace.append(ins)
        if len(trace) > max_len:
            raise RuntimeError(f"{kernel.name}: dynamic trace exceeds {max_len}")
        if ins.info.is_exit:
            break
        if ins.info.is_branch:
            tgt = labels[ins.target]
            if ins.trip_count is not None and tgt < pc:
                n = counters.get(ins.uid, 0) + 1
                counters[ins.uid] = n
                if n < ins.trip_count:
                    pc = tgt
                else:
                    counters[ins.uid] = 0
                    pc += 1
            elif ins.pred is None:
                pc = tgt
            else:
                pc += 1
            continue
        pc += 1
    return trace


@dataclass
class SimResult:
    kernel_name: str
    cycles_per_wave: int
    waves: float
    total_cycles: int
    occupancy: Occupancy
    dynamic_instructions: int
    issue_stalls: int  # cycles where no warp could issue
    #: per-instruction, per-reason attribution of ``issue_stalls`` — filled
    #: only by ``simulate(..., profile=True)``; its total balances exactly
    #: against ``issue_stalls``
    stall_profile: Optional[StallProfile] = None


#: stable integer index per op class (trace-record encoding)
_KLASS_INDEX: Dict[OpClass, int] = {k: i for i, k in enumerate(OpClass)}

#: per-class issue interval, indexed by class index
_KLASS_INTERVAL: List[float] = [ISSUE_INTERVAL[k] for k in OpClass]


@dataclass
class CompiledTrace:
    """Stage 1 output: the dynamic stream lowered to flat numeric records.

    ``code[i]`` indexes the record arrays for the i-th dynamic instruction;
    every unique static instruction is lowered exactly once, so loops cost
    one record however many times they expand.
    """

    code: List[int] = field(default_factory=list)   # dynamic stream -> record index
    klass: List[int] = field(default_factory=list)  # op-class index (into _KLASS_INTERVAL)
    cost: List[int] = field(default_factory=list)   # issue cost: max(1, stall) + bank conflicts
    waits: List[Tuple[int, ...]] = field(default_factory=list)  # scoreboard barriers gating issue
    write_bar: List[int] = field(default_factory=list)  # barrier signalled at result latency (-1: none)
    read_bar: List[int] = field(default_factory=list)   # barrier signalled at operand read (-1: none)
    write_lat: List[int] = field(default_factory=list)  # producer signal latency
    read_lat: List[int] = field(default_factory=list)   # operand-read signal latency
    uid: List[int] = field(default_factory=list)        # static Instr.uid per record
    conflicts: List[int] = field(default_factory=list)  # bank-conflict share of cost
    is_mem: List[int] = field(default_factory=list)     # 1 = memory-class producer

    def __len__(self) -> int:
        return len(self.code)


#: op-class indices whose barrier waits attribute as memory latency
_MEM_KLASS = {
    _KLASS_INDEX[OpClass.LSU_GLOBAL],
    _KLASS_INDEX[OpClass.LSU_SHARED],
    _KLASS_INDEX[OpClass.LSU_LOCAL],
}


def compile_trace(trace: List[Instr], arch=None) -> CompiledTrace:
    """Lower the dynamic stream to flat records (one per static instruction).

    ``arch`` supplies the machine model (bank conflicts, signal latencies,
    operand-read release cap); ``None`` keeps the Maxwell table."""
    ct = CompiledTrace()
    rec_of: Dict[int, int] = {}
    read_cap = 20 if arch is None else arch.latency.read_release
    for ins in trace:
        j = rec_of.get(ins.uid)
        if j is None:
            j = len(ct.klass)
            rec_of[ins.uid] = j
            ctrl = ins.ctrl
            conflicts = (
                ins.reg_bank_conflicts() if arch is None else arch.bank_conflicts(ins)
            )
            ki = _KLASS_INDEX[ins.info.klass]
            ct.klass.append(ki)
            ct.cost.append(max(1, ctrl.stall) + conflicts)
            ct.waits.append(tuple(sorted(ctrl.wait)))
            ct.write_bar.append(-1 if ctrl.write_bar is None else ctrl.write_bar)
            ct.read_bar.append(-1 if ctrl.read_bar is None else ctrl.read_bar)
            lat = _signal_latency(ins, arch)
            ct.write_lat.append(lat)
            ct.read_lat.append(min(lat, read_cap))
            ct.uid.append(ins.uid)
            ct.conflicts.append(conflicts)
            ct.is_mem.append(1 if ki in _MEM_KLASS else 0)
        ct.code.append(j)
    return ct


def _issue_loop(
    ct: CompiledTrace,
    n_warps: int,
    max_cycles: int,
    intervals: Optional[List[float]] = None,
    issue_width: int = ISSUE_WIDTH,
    num_barriers: int = NUM_BARRIERS,
    blame: Optional[Dict[Tuple[int, str], int]] = None,
) -> Tuple[float, int]:
    """Stage 2: the event-driven issue loop; returns (cycles, idle_cycles).

    Cycle-exact replay of the reference engine's semantics: warps round-robin
    under an issue width of 4, per-class unit capacity gates issue, and a
    cycle in which nothing issues jumps straight to the next warp-ready
    event.  A warp's earliest issue time is cached — the scoreboard is
    per-warp state, so it can only change when that warp itself issues; a
    finished warp parks at ``inf``.

    ``blame`` (optional) turns on stall attribution: every idle cycle the
    loop counts is also charged to exactly one ``(record_index, reason)``
    key in the dict — the scheduling decisions themselves are untouched, so
    a profiled run is cycle-identical to an unprofiled one.  At issue time
    each warp remembers *why* it will next be blocked (its own stall
    count / bank conflicts, or a scoreboard barrier and that barrier's
    setter); at idle time the warp whose event bounds the jump donates its
    recorded reason, and ready-but-unit-blocked warps charge the busy
    unit's instruction instead.
    """
    n_trace = len(ct.code)
    if n_trace == 0:
        return 0.0, 0
    # per-dynamic-position record fields (one indirection instead of two)
    code = ct.code
    p_klass = [ct.klass[j] for j in code]
    p_cost = [ct.cost[j] for j in code]
    p_wbar = [ct.write_bar[j] for j in code]
    p_rbar = [ct.read_bar[j] for j in code]
    p_wlat = [ct.write_lat[j] for j in code]
    p_rlat = [ct.read_lat[j] for j in code]
    #: wait set of the *next* position (what the issuing warp blocks on);
    #: empty tuple past the end
    p_next_waits = [ct.waits[j] for j in code[1:]] + [()]
    if intervals is None:
        intervals = _KLASS_INTERVAL

    pc = [0] * n_warps
    bars = [[0.0] * num_barriers for _ in range(n_warps)]
    #: earliest cycle each warp can issue its next instruction (inf = done)
    next_time = [0.0] * n_warps
    n_done = 0
    unit_free = [0.0] * len(intervals)
    cycle = 0.0
    idle_cycles = 0
    rr = 0
    inf = float("inf")

    # stall-attribution state (profiled runs only): per-warp barrier setter
    # records and the (record, reason) each blocked warp would charge
    if blame is not None:
        rec_conflicts = ct.conflicts
        rec_mem = ct.is_mem
        bar_setter = [[-1] * num_barriers for _ in range(n_warps)]
        warp_blame: List[Tuple[int, str]] = [(code[0], R_STALL)] * n_warps

    while n_done < n_warps and cycle < max_cycles:
        issued = 0
        cap = cycle + 1
        for rot in (range(rr, n_warps), range(rr)):
            for w in rot:
                if next_time[w] > cycle:  # blocked, or done (parked at inf)
                    continue
                p = pc[w]
                ki = p_klass[p]
                uf = unit_free[ki]
                # the unit blocks only once this cycle's capacity is spent
                if uf >= cap:
                    continue
                # ---- issue -------------------------------------------------
                issued += 1
                unit_free[ki] = (uf if uf > cycle else cycle) + intervals[ki]
                t = cycle + p_cost[p]
                bw = bars[w]
                b = p_wbar[p]
                if b >= 0:
                    bw[b] = cycle + p_wlat[p]
                b = p_rbar[p]
                if b >= 0:
                    # operands are read shortly after issue
                    bw[b] = cycle + p_rlat[p]
                if blame is not None:
                    j = code[p]
                    bs = bar_setter[w]
                    if p_wbar[p] >= 0:
                        bs[p_wbar[p]] = j
                    if p_rbar[p] >= 0:
                        bs[p_rbar[p]] = j
                p += 1
                pc[w] = p
                if p >= n_trace:
                    n_done += 1
                    next_time[w] = inf
                elif blame is None:
                    ws = p_next_waits[p - 1]
                    if ws:
                        for b in ws:
                            v = bw[b]
                            if v > t:
                                t = v
                    next_time[w] = t
                else:
                    # same wait maximization, additionally tracking which
                    # event bounds t: the issued instruction's own cost
                    # (stall / bank conflict) or a barrier and its setter
                    j = code[p - 1]
                    rec = j
                    reason = R_BANK if rec_conflicts[j] else R_STALL
                    bs = bar_setter[w]
                    for b in p_next_waits[p - 1]:
                        v = bw[b]
                        if v > t:
                            t = v
                            sj = bs[b]
                            if sj >= 0:
                                rec = sj
                                reason = R_MEM if rec_mem[sj] else R_BAR
                    next_time[w] = t
                    warp_blame[w] = (rec, reason)
                if issued >= issue_width:
                    break
            if issued >= issue_width:
                break
        rr += 1
        if rr >= n_warps:
            rr = 0
        if issued:
            cycle += 1
        else:
            # Jump to the next time anything can happen.  Two distinct idle
            # shapes, both replayed exactly as the reference engine counts
            # them (done warps sit at inf; the loop guard ensures at least
            # one warp is live):
            #
            # * no warp is ready: one reference iteration jumps straight to
            #   the earliest warp-ready event (rr advances once);
            # * some warp is ready but its unit is at capacity: the
            #   reference crawls cycle-by-cycle (rr and idle advance per
            #   cycle) until a unit frees (cycle + 1 > unit_free, i.e. at
            #   floor(unit_free)) or another warp becomes ready — nothing
            #   can issue in between, so the k crawl cycles collapse into
            #   one iteration with rr += k and idle += k.
            mn_wait = inf   # earliest blocked-warp ready time
            mn_block = inf  # earliest unit-free event of a ready warp
            w_wait = w_block = 0  # warps owning those bounds (attribution)
            for w in range(n_warps):
                v = next_time[w]
                if v <= cycle:
                    v = float(int(unit_free[p_klass[pc[w]]]))
                    if v < mn_block:
                        mn_block = v
                        w_block = w
                elif v < mn_wait:
                    mn_wait = v
                    w_wait = w
            if mn_block < inf:
                nxt = mn_block if mn_block < mn_wait else mn_wait
                if nxt < cap:
                    nxt = cap
                elif nxt > max_cycles:
                    # the reference crawls one cycle per iteration and stops
                    # exactly at the cap — clamp the bulk jump likewise
                    nxt = float(max_cycles)
                k = int(nxt - cycle)
                idle_cycles += k
                rr += k - 1
                rr %= n_warps
                if blame is not None and k:
                    if mn_block <= mn_wait:
                        key = (code[pc[w_block]], R_UNIT)
                    else:
                        key = warp_blame[w_wait]
                    blame[key] = blame.get(key, 0) + k
            else:
                nxt = mn_wait if mn_wait > cap else cap
                k = int(nxt - cycle)
                idle_cycles += k
                if blame is not None and k:
                    key = warp_blame[w_wait]
                    blame[key] = blame.get(key, 0) + k
            cycle = nxt
    return cycle, idle_cycles


def simulate(
    kernel: Kernel,
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
    profile: bool = False,
) -> SimResult:
    """Simulate one wave of resident warps on one SM; scale by wave count.

    Two-stage engine: :func:`compile_trace` lowers the dynamic stream to
    flat numeric records, :func:`_issue_loop` replays the scheduling
    semantics event-to-event.  Cycle-exact with :func:`simulate_reference`.

    The machine model (unit lanes, latencies, issue width) comes from the
    kernel's architecture; ``sm`` overrides the occupancy limits only
    (default: the arch's own SMConfig), which permits deliberate
    cross-arch what-ifs like ``simulate(volta_kernel, MAXWELL)``.

    ``profile=True`` additionally attributes every idle cycle to a static
    instruction and a reason (:class:`repro.obs.stallprof.StallProfile` on
    ``SimResult.stall_profile``); the attribution is bookkeeping only —
    cycle counts are identical either way, and the profile total balances
    exactly against ``issue_stalls``.
    """
    with obs.span("simulate", kernel=kernel.name, profile=profile) as sp:
        arch = _arch_of(kernel)
        if sm is None:
            sm = arch.sm
        occ = occupancy_of(kernel, sm)
        trace = flatten_trace(kernel)
        n_warps = max(occ.resident_warps, 1)
        ct = compile_trace(trace, arch)
        intervals = [arch.issue_interval(k) for k in OpClass]
        blame: Optional[Dict[Tuple[int, str], int]] = {} if profile else None
        cycle, idle_cycles = _issue_loop(
            ct, n_warps, max_cycles, intervals, arch.issue_width,
            arch.num_barriers, blame,
        )

        stall_profile = None
        if profile:
            from repro.obs.stallprof import build_profile

            by_uid: Dict[Tuple[int, str], int] = {}
            for (rec, reason), c in blame.items():
                key = (ct.uid[rec], reason)
                by_uid[key] = by_uid.get(key, 0) + c
            stall_profile = build_profile(kernel, by_uid, idle_cycles)

        # fractional waves: charge the launch by work/throughput, not by
        # rounding partial waves up (a 1.2-wave launch is not 2x a 1.0-wave
        # launch)
        blocks_per_wave = max(occ.resident_blocks, 1) * sm.num_sms
        waves = kernel.num_blocks / blocks_per_wave
        sp.set(cycles=int(cycle), warps=n_warps, instrs=len(trace))
        return SimResult(
            kernel_name=kernel.name,
            cycles_per_wave=int(cycle),
            waves=max(1.0, waves),
            total_cycles=int(cycle * max(1.0, waves)),
            occupancy=occ,
            dynamic_instructions=len(trace),
            issue_stalls=idle_cycles,
            stall_profile=stall_profile,
        )


def simulate_reference(
    kernel: Kernel,
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
) -> SimResult:
    """The pre-optimization cycle-by-cycle engine, kept verbatim as the
    parity oracle for :func:`simulate` (golden test: identical cycles).

    Arch-parameterized the same way as :func:`simulate`, so the parity
    holds per architecture."""
    arch = _arch_of(kernel)
    if sm is None:
        sm = arch.sm
    issue_width = arch.issue_width
    num_barriers = arch.num_barriers
    issue_interval = {k: arch.issue_interval(k) for k in OpClass}
    occ = occupancy_of(kernel, sm)
    trace = flatten_trace(kernel)
    n_warps = max(occ.resident_warps, 1)

    # per-warp state
    pc = [0] * n_warps
    ready = [0.0] * n_warps  # earliest issue cycle
    bar_signal = [[0.0] * num_barriers for _ in range(n_warps)]
    done = [False] * n_warps
    n_done = 0

    unit_free: Dict[OpClass, float] = {k: 0.0 for k in OpClass}
    cycle = 0.0
    idle_cycles = 0
    rr = 0  # round-robin pointer

    def warp_next_time(w: int) -> float:
        """Earliest cycle warp ``w`` could issue its next instruction."""
        t = ready[w]
        ins = trace[pc[w]]
        for b in ins.ctrl.wait:
            t = max(t, bar_signal[w][b])
        return t

    while n_done < n_warps and cycle < max_cycles:
        issued = 0
        for k in range(n_warps):
            if issued >= issue_width:
                break
            w = (rr + k) % n_warps
            if done[w]:
                continue
            ins = trace[pc[w]]
            if ready[w] > cycle:
                continue
            if any(bar_signal[w][b] > cycle for b in ins.ctrl.wait):
                continue
            klass = ins.info.klass
            # the unit blocks only once this cycle's issue capacity is spent
            # (e.g. FP32 interval 0.25 -> four issues per cycle)
            if unit_free[klass] >= cycle + 1:
                continue
            # ---- issue -----------------------------------------------------
            issued += 1
            unit_free[klass] = max(unit_free[klass], cycle) + issue_interval[klass]
            issue_cost = max(1, ins.ctrl.stall) + arch.bank_conflicts(ins)
            ready[w] = cycle + issue_cost
            if ins.ctrl.write_bar is not None:
                bar_signal[w][ins.ctrl.write_bar] = cycle + _signal_latency(ins, arch)
            if ins.ctrl.read_bar is not None:
                # operands are read shortly after issue
                bar_signal[w][ins.ctrl.read_bar] = cycle + min(
                    _signal_latency(ins, arch), arch.latency.read_release
                )
            pc[w] += 1
            if pc[w] >= len(trace):
                done[w] = True
                n_done += 1
        rr = (rr + 1) % n_warps
        if issued == 0:
            # jump to the next time anything can happen
            nxt = min(
                (warp_next_time(w) for w in range(n_warps) if not done[w]),
                default=cycle + 1,
            )
            nxt = max(nxt, cycle + 1)
            idle_cycles += int(nxt - cycle)
            cycle = nxt
        else:
            cycle += 1

    # fractional waves: charge the launch by work/throughput, not by rounding
    # partial waves up (a 1.2-wave launch is not 2x a 1.0-wave launch)
    blocks_per_wave = max(occ.resident_blocks, 1) * sm.num_sms
    waves = kernel.num_blocks / blocks_per_wave
    return SimResult(
        kernel_name=kernel.name,
        cycles_per_wave=int(cycle),
        waves=max(1.0, waves),
        total_cycles=int(cycle * max(1.0, waves)),
        occupancy=occ,
        dynamic_instructions=len(trace),
        issue_stalls=idle_cycles,
    )


def speedup(base: SimResult, other: SimResult) -> float:
    """Speedup of ``other`` over ``base`` (>1 means faster)."""
    return base.total_cycles / other.total_cycles
