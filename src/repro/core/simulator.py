"""Cycle-approximate multi-warp timing simulator for the abstract ISA.

The paper evaluates variants with nvprof on a GTX Titan X.  Without the GPU,
this simulator is the measurement instrument: it models the Maxwell
microarchitecture features that RegDem's trade-off lives on:

* **occupancy-driven latency hiding** — ``resident_warps`` warps round-robin
  on an SM with an issue width of 4 (four warp schedulers); a warp blocked on
  a scoreboard barrier or stall count lets others issue;
* **scoreboard barriers** — write barriers signal at producer latency
  (global 200cy / shared 24cy / FP64 48cy / SFU 20cy), read barriers at
  operand-read time; wait masks block issue;
* **functional-unit contention** — per-class issue intervals derived from
  unit counts (FP32 128 lanes -> 4 warps/cycle, FP64 4 lanes -> 1 warp per
  8 cycles, LSU/SFU 32 lanes -> 1 warp/cycle).  This is what makes ``md``
  (FP64-bound) immune to occupancy gains, exactly as in §5.5;
* **register bank conflicts** — serialized operand reads extend issue time;
* **stall counts** — fixed-latency dependencies honoured as scheduled.

The simulator executes the *dynamic* instruction stream (loops expanded via
the ``trip_count`` metadata), one SM's resident warps at a time, and scales
to the full launch by wave count.  Its absolute cycle counts are
approximations; variant *ratios* (speedups) are the quantity of interest,
mirroring how the paper reports Fig. 6.

Engine architecture (two stages)
--------------------------------

:func:`simulate` runs a **trace compiler** followed by an **event-driven
issue loop**:

1. :func:`compile_trace` flattens the dynamic stream once and lowers every
   *unique static instruction* to a flat numeric record — op-class index,
   issue cost (stall + register-bank conflicts), scoreboard wait set,
   write/read barrier index, and signal latencies.  Records live in numpy
   arrays; the per-dynamic-position views the issue loop runs over are
   gathered with one fancy-index per field, so the hot loop touches no
   :class:`~repro.core.isa.Instr` objects, no properties and no
   generator expressions.
2. :func:`_issue_loop` replays the exact scheduling semantics of the
   original cycle-by-cycle engine over those records, caching each warp's
   next-possible-issue time (it only changes when that warp issues — the
   scoreboard is per-warp state) and skipping idle spans to the next event.
   When the toolchain's C compiler is present (it is baked into the image)
   the loop runs as a natively compiled translation of the same algorithm
   (:mod:`repro.core._native`); the pure-Python loop is the always-available
   fallback and the two are state-for-state identical.  Either engine can
   additionally capture **resumable checkpoints** at trace-position
   milestones and later resume from one, so re-simulating a kernel whose
   schedule only changed in a suffix replays only the suffix
   (:class:`SimCheckpoint` / :class:`CheckpointStore`;
   ``repro.core.simcache.SimCache`` persists these alongside results).

Every acceleration is exact: the golden parity test pins
``simulate() == simulate_reference()`` cycle-for-cycle across every paper
benchmark × variant, and property tests drive random kernels through
checkpointed, batched and profiled runs against the reference engine.
:func:`simulate_batch` runs a set of sibling variants through one
checkpoint store in prefix-sharing order — the search's confirm stage and
``make_variants`` scoring cost one sweep instead of N cold runs.
"""

from __future__ import annotations

import heapq
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.obs.stallprof import R_BANK, R_BAR, R_MEM, R_STALL, R_UNIT, StallProfile

from .isa import Instr, Kernel, Label, NUM_BARRIERS, OpClass
from .occupancy import Occupancy, SMConfig, occupancy_of

#: per-class issue interval in cycles per warp-instruction:
#: 32 lanes-per-warp / unit lanes (Maxwell table; per-arch values come
#: from the :mod:`repro.arch` registry).
ISSUE_INTERVAL: Dict[OpClass, float] = {
    OpClass.FP32: 32 / 128,
    OpClass.INT: 32 / 128,
    OpClass.FP64: 32 / 4,
    OpClass.SFU: 32 / 32,
    OpClass.LSU_GLOBAL: 32 / 32,
    OpClass.LSU_SHARED: 32 / 32,
    OpClass.LSU_LOCAL: 32 / 32,
    OpClass.CONTROL: 32 / 128,
    OpClass.MISC: 32 / 32,
}

#: number of warp schedulers per SM (Maxwell: 4, single-issue modelled)
ISSUE_WIDTH = 4

#: barrier signal latency per class (producer completion).  Local-memory
#: traffic is L1-cached on Maxwell, so its *effective* latency sits between
#: shared memory and DRAM — the paper's whole premise is the ordering
#: shared (24) < local (cached, ~80) < global (200).
LOCAL_EFFECTIVE_LATENCY = 80


def _arch_of(kernel: Kernel):
    from repro.arch import arch_of

    return arch_of(kernel)


def _signal_latency(ins: Instr, arch=None) -> int:
    k = ins.info.klass
    if arch is not None:
        return arch.signal_latency(k)
    if k is OpClass.LSU_GLOBAL:
        return 200
    if k is OpClass.LSU_LOCAL:
        return LOCAL_EFFECTIVE_LATENCY
    if k is OpClass.LSU_SHARED:
        return 24
    return k.latency


class Trace(list):
    """Dynamic instruction stream of one warp.

    A plain list of :class:`~repro.core.isa.Instr` with one extra bit:
    ``truncated`` is True when the expansion hit ``max_len`` and the tail
    was dropped — capped simulations must be visible, never silent.
    """

    truncated: bool = False


#: kernels already warned about a truncated trace (one warning per kernel
#: per process; the telemetry counter counts every occurrence)
_TRUNCATION_WARNED: set = set()


def flatten_trace(kernel: Kernel, max_len: int = 200_000) -> "Trace":
    """Expand the dynamic instruction stream of one warp.

    Backward branches with ``trip_count`` metadata loop that many times;
    unpredicated forward branches are taken; predicated forward branches
    fall through (SIMT serialization of the cold path is approximated by
    the predicated instructions already present in the stream).

    An expansion longer than ``max_len`` is truncated there, with the cap
    made visible three ways (no-silent-caps rule): the returned
    :class:`Trace` has ``truncated=True`` (propagated to
    ``SimResult.truncated``), the ``simulator.trace_truncated`` telemetry
    counter increments, and a one-time-per-kernel warning is emitted.
    """
    labels = {it.name: i for i, it in enumerate(kernel.items) if isinstance(it, Label)}
    trace = Trace()
    counters: Dict[int, int] = {}
    pc = 0
    while pc < len(kernel.items):
        it = kernel.items[pc]
        if isinstance(it, Label):
            pc += 1
            continue
        ins: Instr = it
        if len(trace) >= max_len:
            trace.truncated = True
            if obs.enabled():
                obs.metrics().counter("simulator.trace_truncated").inc()
            if kernel.name not in _TRUNCATION_WARNED:
                _TRUNCATION_WARNED.add(kernel.name)
                warnings.warn(
                    f"{kernel.name}: dynamic trace exceeds {max_len} "
                    f"instructions; simulation runs on the truncated prefix "
                    f"(SimResult.truncated=True)",
                    RuntimeWarning,
                    stacklevel=2,
                )
            break
        trace.append(ins)
        if ins.info.is_exit:
            break
        if ins.info.is_branch:
            tgt = labels[ins.target]
            if ins.trip_count is not None and tgt < pc:
                n = counters.get(ins.uid, 0) + 1
                counters[ins.uid] = n
                if n < ins.trip_count:
                    pc = tgt
                else:
                    counters[ins.uid] = 0
                    pc += 1
            elif ins.pred is None:
                pc = tgt
            else:
                pc += 1
            continue
        pc += 1
    return trace


@dataclass
class SimResult:
    kernel_name: str
    cycles_per_wave: int
    waves: float
    total_cycles: int
    occupancy: Occupancy
    dynamic_instructions: int
    issue_stalls: int  # cycles where no warp could issue
    #: per-instruction, per-reason attribution of ``issue_stalls`` — filled
    #: only by ``simulate(..., profile=True)``; its total balances exactly
    #: against ``issue_stalls``
    stall_profile: Optional[StallProfile] = None
    #: True when the dynamic trace hit the ``flatten_trace`` length cap and
    #: the simulation ran on a truncated prefix
    truncated: bool = False


#: stable integer index per op class (trace-record encoding)
_KLASS_INDEX: Dict[OpClass, int] = {k: i for i, k in enumerate(OpClass)}

#: per-class issue interval, indexed by class index
_KLASS_INTERVAL: List[float] = [ISSUE_INTERVAL[k] for k in OpClass]


@dataclass
class CompiledTrace:
    """Stage 1 output: the dynamic stream lowered to flat numeric records.

    ``code[i]`` indexes the record arrays for the i-th dynamic instruction;
    every unique static instruction is lowered exactly once, so loops cost
    one record however many times they expand.  All numeric fields are
    numpy int arrays (``len``, iteration and indexing behave like the
    former list encoding); ``waits`` stays a list of tuples — wait sets are
    ragged and consumed as tuples by the issue loop.
    """

    code: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    klass: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    cost: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    waits: List[Tuple[int, ...]] = field(default_factory=list)
    write_bar: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    read_bar: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    write_lat: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    read_lat: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    uid: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    conflicts: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))
    is_mem: np.ndarray = field(default_factory=lambda: np.zeros(0, np.int64))

    def __len__(self) -> int:
        return len(self.code)


#: op-class indices whose barrier waits attribute as memory latency
_MEM_KLASS = {
    _KLASS_INDEX[OpClass.LSU_GLOBAL],
    _KLASS_INDEX[OpClass.LSU_SHARED],
    _KLASS_INDEX[OpClass.LSU_LOCAL],
}


def compile_trace(trace: List[Instr], arch=None) -> CompiledTrace:
    """Lower the dynamic stream to flat records (one per static instruction).

    ``arch`` supplies the machine model (bank conflicts, signal latencies,
    operand-read release cap); ``None`` keeps the Maxwell table."""
    code: List[int] = []
    klass: List[int] = []
    cost: List[int] = []
    waits: List[Tuple[int, ...]] = []
    write_bar: List[int] = []
    read_bar: List[int] = []
    write_lat: List[int] = []
    read_lat: List[int] = []
    uid: List[int] = []
    conflicts_l: List[int] = []
    is_mem: List[int] = []
    rec_of: Dict[int, int] = {}
    read_cap = 20 if arch is None else arch.latency.read_release
    for ins in trace:
        j = rec_of.get(ins.uid)
        if j is None:
            j = len(klass)
            rec_of[ins.uid] = j
            ctrl = ins.ctrl
            conflicts = (
                ins.reg_bank_conflicts() if arch is None else arch.bank_conflicts(ins)
            )
            ki = _KLASS_INDEX[ins.info.klass]
            klass.append(ki)
            cost.append(max(1, ctrl.stall) + conflicts)
            waits.append(tuple(sorted(ctrl.wait)))
            write_bar.append(-1 if ctrl.write_bar is None else ctrl.write_bar)
            read_bar.append(-1 if ctrl.read_bar is None else ctrl.read_bar)
            lat = _signal_latency(ins, arch)
            write_lat.append(lat)
            read_lat.append(min(lat, read_cap))
            uid.append(ins.uid)
            conflicts_l.append(conflicts)
            is_mem.append(1 if ki in _MEM_KLASS else 0)
        code.append(j)
    return CompiledTrace(
        code=np.asarray(code, dtype=np.int64),
        klass=np.asarray(klass, dtype=np.int64),
        cost=np.asarray(cost, dtype=np.int64),
        waits=waits,
        write_bar=np.asarray(write_bar, dtype=np.int64),
        read_bar=np.asarray(read_bar, dtype=np.int64),
        write_lat=np.asarray(write_lat, dtype=np.int64),
        read_lat=np.asarray(read_lat, dtype=np.int64),
        uid=np.asarray(uid, dtype=np.int64),
        conflicts=np.asarray(conflicts_l, dtype=np.int64),
        is_mem=np.asarray(is_mem, dtype=np.int64),
    )


def position_signatures(ct: CompiledTrace) -> List[tuple]:
    """Per-dynamic-position engine-visible signature of a compiled trace.

    ``sigs[p]`` captures everything the issue loop reads about position
    ``p`` — record index, op class, cost, wait set, barrier slots, signal
    latencies, conflicts and memory-ness.  Two compiled traces that agree
    on ``sigs[:F+1]`` evolve identically while every warp's pc stays
    ≤ ``F`` — this is the checkpoint-reuse validity condition (record
    indices are first-occurrence ordinals, so an equal signature prefix
    implies equal record numbering for every record referenced in it, which
    keeps stall-attribution keys portable too).

    The signature list is memoised on the trace and its element tuples are
    shared per record, so a 100k-position loopy trace costs one tuple per
    *static* instruction plus a pointer per position.
    """
    sigs = getattr(ct, "_pos_sigs", None)
    if sigs is None:
        klass = ct.klass.tolist()
        cost = ct.cost.tolist()
        wbar = ct.write_bar.tolist()
        rbar = ct.read_bar.tolist()
        wlat = ct.write_lat.tolist()
        rlat = ct.read_lat.tolist()
        confl = ct.conflicts.tolist()
        mem = ct.is_mem.tolist()
        rec_sigs = [
            (j, klass[j], cost[j], wbar[j], rbar[j], wlat[j], rlat[j],
             confl[j], mem[j], ct.waits[j])
            for j in range(len(ct.klass))
        ]
        sigs = [rec_sigs[j] for j in ct.code.tolist()]
        ct._pos_sigs = sigs
    return sigs


@dataclass
class SimCheckpoint:
    """A resumable issue-loop state, captured at a trace-position milestone.

    Valid to resume any kernel whose :func:`position_signatures` agree with
    the captured kernel's on ``[0, frontier]`` (no warp had advanced past
    ``frontier``), under the same (n_warps, intervals, issue_width,
    num_barriers) family, for any ``max_cycles`` greater than ``cycle``.
    ``profiled`` checkpoints carry the stall-attribution books and can seed
    both profiled and plain runs; unprofiled ones only seed plain runs (a
    profiled run resumed without its books could never balance).
    """

    frontier: int
    cycle: float
    idle_cycles: int
    rr: int
    pc: Tuple[int, ...]
    next_time: Tuple[float, ...]
    bars: Tuple[Tuple[float, ...], ...]
    unit_free: Tuple[float, ...]
    profiled: bool = False
    blame: Optional[Dict[Tuple[int, str], int]] = None
    warp_blame: Optional[Tuple[Tuple[int, str], ...]] = None
    bar_setter: Optional[Tuple[Tuple[int, ...], ...]] = None


class CheckpointStore:
    """Content-keyed store of :class:`SimCheckpoint` entries.

    Keys are ``(family, frontier, signature-prefix-tuple)`` — the full
    engine-visible prefix is the collision guard (a checkpoint is never
    served to a kernel it is not exactly valid for).  Signature tuples are
    shared per static record, so stored prefixes cost pointers, not copies.
    FIFO-bounded like the other caches; ``reuse_rate`` reports the
    position-weighted fraction of simulated work served from checkpoints.
    """

    def __init__(self, max_entries: Optional[int] = 256):
        self.max_entries = max_entries
        self._entries: Dict[tuple, SimCheckpoint] = {}
        #: family -> descending list of frontiers ever stored (probe order)
        self._lengths: Dict[tuple, List[int]] = {}
        self.hits = 0
        self.misses = 0
        self.positions_total = 0
        self.positions_resumed = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def reuse_rate(self) -> float:
        """Fraction of dynamic trace positions skipped by resuming."""
        if not self.positions_total:
            return 0.0
        return self.positions_resumed / self.positions_total

    def lookup(
        self,
        family: tuple,
        sigs: List[tuple],
        max_cycles: int,
        profiled: bool,
    ) -> Optional[SimCheckpoint]:
        """Deepest stored checkpoint exactly valid for this trace, or None."""
        self.positions_total += len(sigs)
        for frontier in self._lengths.get(family, ()):
            if frontier + 1 >= len(sigs):
                continue
            cp = self._entries.get((family, frontier, tuple(sigs[: frontier + 1])))
            if cp is None or cp.cycle >= max_cycles:
                continue
            if profiled and not cp.profiled:
                continue
            self.hits += 1
            self.positions_resumed += frontier + 1
            if obs.enabled():
                obs.metrics().counter("simcache.ckpt_hits").inc()
            return cp
        self.misses += 1
        if obs.enabled():
            obs.metrics().counter("simcache.ckpt_misses").inc()
        return None

    def offer(
        self, family: tuple, sigs: List[tuple], checkpoints: Sequence[SimCheckpoint]
    ) -> int:
        """Adopt captured checkpoints; an existing entry is only replaced
        when the newcomer adds the stall-attribution books (a profiled
        checkpoint serves both engines, a plain one only the plain engine).
        Returns the number of entries stored."""
        added = 0
        for cp in checkpoints:
            key = (family, cp.frontier, tuple(sigs[: cp.frontier + 1]))
            old = self._entries.get(key)
            if old is not None and (old.profiled or not cp.profiled):
                continue
            if (
                old is None
                and self.max_entries is not None
                and len(self._entries) >= self.max_entries
            ):
                self._entries.pop(next(iter(self._entries)))
            self._entries[key] = cp
            lens = self._lengths.setdefault(family, [])
            if cp.frontier not in lens:
                lens.append(cp.frontier)
                lens.sort(reverse=True)
            added += 1
        return added

    def clear(self) -> None:
        self._entries.clear()
        self._lengths.clear()
        self.hits = 0
        self.misses = 0
        self.positions_total = 0
        self.positions_resumed = 0

    def stats(self) -> Dict[str, float]:
        return {
            "entries": len(self._entries),
            "hits": self.hits,
            "misses": self.misses,
            "reuse_rate": round(self.reuse_rate, 3),
        }


def _native_engine():
    """The compiled issue loop (``_sim_engine.c`` via :mod:`._native`), or
    ``None`` when unavailable / disabled — the Python loop then runs."""
    from . import _native

    return _native.engine()


#: trace-length fractions at which the issue loop captures checkpoints
_CKPT_FRACTIONS = (8, 4, 2)  # denominators: n/8, n/4, n/2 — plus 3n/4
#: traces shorter than this are not worth checkpointing
_CKPT_MIN_TRACE = 64


def _issue_loop(
    ct: CompiledTrace,
    n_warps: int,
    max_cycles: int,
    intervals: Optional[List[float]] = None,
    issue_width: int = ISSUE_WIDTH,
    num_barriers: int = NUM_BARRIERS,
    blame: Optional[Dict[Tuple[int, str], int]] = None,
    resume: Optional[SimCheckpoint] = None,
    capture: Optional[List[SimCheckpoint]] = None,
) -> Tuple[float, int]:
    """Stage 2: the event-driven issue loop; returns (cycles, idle_cycles).

    Cycle-exact replay of the reference engine's semantics: warps round-robin
    under an issue width of 4, per-class unit capacity gates issue, and a
    cycle in which nothing issues jumps straight to the next warp-ready
    event.  A warp's earliest issue time is cached — the scoreboard is
    per-warp state, so it can only change when that warp itself issues; a
    finished warp parks at ``inf``.

    ``blame`` (optional) turns on stall attribution: every idle cycle the
    loop counts is also charged to exactly one ``(record_index, reason)``
    key in the dict — the scheduling decisions themselves are untouched, so
    a profiled run is cycle-identical to an unprofiled one.  At issue time
    each warp remembers *why* it will next be blocked (its own stall
    count / bank conflicts, or a scoreboard barrier and that barrier's
    setter); at idle time the warp whose event bounds the jump donates its
    recorded reason, and ready-but-unit-blocked warps charge the busy
    unit's instruction instead.

    ``resume`` (optional) starts the loop from a previously captured
    :class:`SimCheckpoint` instead of cycle 0; ``capture`` (optional) is a
    list the loop appends fresh checkpoints to as the position frontier
    crosses trace-length milestones.  Both are exact: a resumed run
    finishes in the state a cold run would have reached.

    When the native engine is available (:mod:`repro.core._native`) the
    whole loop — blame, resume and capture included — runs compiled; this
    Python body is the fallback and the conformance reference for it.
    """
    native = _native_engine()
    if native is not None:
        return native(
            ct, n_warps, max_cycles, intervals, issue_width, num_barriers,
            blame, resume, capture,
        )
    n_trace = len(ct.code)
    if n_trace == 0:
        return 0.0, 0
    # per-dynamic-position record fields: one numpy gather per field, then
    # plain lists for the scalar hot loop (list indexing beats ndarray
    # scalar indexing by a wide margin in CPython)
    code_a = ct.code
    code = code_a.tolist()
    p_klass = ct.klass[code_a].tolist()
    p_cost = ct.cost[code_a].tolist()
    p_wbar = ct.write_bar[code_a].tolist()
    p_rbar = ct.read_bar[code_a].tolist()
    p_wlat = ct.write_lat[code_a].tolist()
    p_rlat = ct.read_lat[code_a].tolist()
    #: wait set of the *next* position (what the issuing warp blocks on);
    #: empty tuple past the end
    p_next_waits = [ct.waits[j] for j in code[1:]] + [()]
    if intervals is None:
        intervals = _KLASS_INTERVAL

    pc = [0] * n_warps
    bars = [[0.0] * num_barriers for _ in range(n_warps)]
    #: earliest cycle each warp can issue its next instruction (inf = done)
    next_time = [0.0] * n_warps
    n_done = 0
    unit_free = [0.0] * len(intervals)
    cycle = 0.0
    idle_cycles = 0
    rr = 0
    inf = float("inf")

    # stall-attribution state (profiled runs only): per-warp barrier setter
    # records and the (record, reason) each blocked warp would charge
    if blame is not None:
        rec_conflicts = ct.conflicts
        rec_mem = ct.is_mem
        bar_setter = [[-1] * num_barriers for _ in range(n_warps)]
        warp_blame: List[Tuple[int, str]] = [(code[0], R_STALL)] * n_warps

    frontier = 0
    if resume is not None:
        pc = list(resume.pc)
        next_time = list(resume.next_time)
        bars = [list(bw) for bw in resume.bars]
        unit_free = list(resume.unit_free)
        cycle = resume.cycle
        idle_cycles = resume.idle_cycles
        rr = resume.rr
        frontier = resume.frontier
        if blame is not None:
            blame.update(resume.blame)
            warp_blame = list(resume.warp_blame)
            bar_setter = [list(bs) for bs in resume.bar_setter]

    # event-driven ready tracking: a per-class bitmask of ready warps (bit w
    # set = warp w's next instruction is class c and its scoreboard allows
    # issue) plus a min-heap of (wake time, warp) for blocked warps.  The
    # issue scan walks set bits in round-robin rotation instead of scanning
    # every warp every cycle, so a unit-saturated cycle costs O(classes);
    # heap tuple order (time, warp) reproduces the reference engine's
    # first-strict-minimum tie-breaking exactly.
    heappush = heapq.heappush
    heappop = heapq.heappop
    n_classes = len(intervals)
    class_masks = [0] * n_classes
    heap: List[Tuple[float, int]] = []
    for w in range(n_warps):
        v = next_time[w]
        if v <= cycle:
            class_masks[p_klass[pc[w]]] |= 1 << w
        else:
            heap.append((v, w))
    if heap:
        heapq.heapify(heap)
    full_mask = (1 << n_warps) - 1

    # checkpoint capture milestones (positions the frontier must cross)
    thresholds: List[int] = []
    if capture is not None and n_trace >= _CKPT_MIN_TRACE:
        marks = {n_trace // d for d in _CKPT_FRACTIONS}
        marks.add((3 * n_trace) // 4)
        thresholds = sorted(m for m in marks if frontier < m < n_trace)

    while n_done < n_warps and cycle < max_cycles:
        while heap and heap[0][0] <= cycle:
            _, w = heappop(heap)
            class_masks[p_klass[pc[w]]] |= 1 << w
        if thresholds and n_done == 0 and frontier >= thresholds[0]:
            while thresholds and frontier >= thresholds[0]:
                thresholds.pop(0)
            capture.append(
                SimCheckpoint(
                    frontier=frontier,
                    cycle=cycle,
                    idle_cycles=idle_cycles,
                    rr=rr,
                    pc=tuple(pc),
                    next_time=tuple(next_time),
                    bars=tuple(tuple(bw) for bw in bars),
                    unit_free=tuple(unit_free),
                    profiled=blame is not None,
                    blame=dict(blame) if blame is not None else None,
                    warp_blame=tuple(warp_blame) if blame is not None else None,
                    bar_setter=(
                        tuple(tuple(bs) for bs in bar_setter)
                        if blame is not None
                        else None
                    ),
                )
            )
        cap = cycle + 1
        # classes whose unit still has capacity this cycle contribute their
        # ready warps to the eligible set
        elig = 0
        for c in range(n_classes):
            m = class_masks[c]
            if m and unit_free[c] < cap:
                elig |= m
        if elig:
            # visit eligible warps in round-robin rotation: bit i of the
            # rotated mask is warp (rr + i) mod n_warps, and extracting
            # ascending set bits replays the reference scan order exactly
            rot = ((elig >> rr) | (elig << (n_warps - rr))) & full_mask
            issued = 0
            while rot:
                lsb = rot & -rot
                w = lsb.bit_length() - 1 + rr
                if w >= n_warps:
                    w -= n_warps
                p = pc[w]
                ki = p_klass[p]
                uf = unit_free[ki]
                # the unit blocks only once this cycle's capacity is spent;
                # a class saturated mid-cycle drops all its pending warps
                # from the rotation, exactly as the reference skips them
                if uf >= cap:
                    cm = class_masks[ki]
                    rot &= ~(((cm >> rr) | (cm << (n_warps - rr))) & full_mask)
                    continue
                # ---- issue -------------------------------------------------
                rot ^= lsb
                issued += 1
                class_masks[ki] &= ~(1 << w)
                unit_free[ki] = (uf if uf > cycle else cycle) + intervals[ki]
                t = cycle + p_cost[p]
                bw = bars[w]
                b = p_wbar[p]
                if b >= 0:
                    bw[b] = cycle + p_wlat[p]
                b = p_rbar[p]
                if b >= 0:
                    # operands are read shortly after issue
                    bw[b] = cycle + p_rlat[p]
                if blame is not None:
                    j = code[p]
                    bs = bar_setter[w]
                    if p_wbar[p] >= 0:
                        bs[p_wbar[p]] = j
                    if p_rbar[p] >= 0:
                        bs[p_rbar[p]] = j
                p += 1
                pc[w] = p
                if p > frontier:
                    frontier = p
                if p >= n_trace:
                    n_done += 1
                    next_time[w] = inf
                elif blame is None:
                    ws = p_next_waits[p - 1]
                    if ws:
                        for b in ws:
                            v = bw[b]
                            if v > t:
                                t = v
                    next_time[w] = t
                    heappush(heap, (t, w))
                else:
                    # same wait maximization, additionally tracking which
                    # event bounds t: the issued instruction's own cost
                    # (stall / bank conflict) or a barrier and its setter
                    j = code[p - 1]
                    rec = j
                    reason = R_BANK if rec_conflicts[j] else R_STALL
                    bs = bar_setter[w]
                    for b in p_next_waits[p - 1]:
                        v = bw[b]
                        if v > t:
                            t = v
                            sj = bs[b]
                            if sj >= 0:
                                rec = sj
                                reason = R_MEM if rec_mem[sj] else R_BAR
                    next_time[w] = t
                    heappush(heap, (t, w))
                    warp_blame[w] = (rec, reason)
                if issued >= issue_width:
                    break
            rr += 1
            if rr >= n_warps:
                rr = 0
            cycle += 1
        else:
            # Jump to the next time anything can happen.  Two distinct idle
            # shapes, both replayed exactly as the reference engine counts
            # them (done warps are in neither the masks nor the heap; the
            # loop guard ensures at least one warp is live):
            #
            # * no warp is ready: one reference iteration jumps straight to
            #   the earliest warp-ready event (rr advances once);
            # * some warp is ready but its unit is at capacity: the
            #   reference crawls cycle-by-cycle (rr and idle advance per
            #   cycle) until a unit frees (cycle + 1 > unit_free, i.e. at
            #   floor(unit_free)) or another warp becomes ready — nothing
            #   can issue in between, so the k crawl cycles collapse into
            #   one iteration with rr += k and idle += k.
            #
            # The heap top is the earliest blocked-warp event with the
            # reference's first-strict-minimum warp tie-break ((time, warp)
            # tuple order); the block bound scans classes, and the owning
            # warp (attribution only) is the lowest set bit over the
            # minimum's classes — the first warp the reference would have
            # recorded.
            rr += 1
            if rr >= n_warps:
                rr = 0
            mn_wait = heap[0][0] if heap else inf
            mn_block = inf  # earliest unit-free event of a ready warp
            blk_mask = 0    # ready warps of the classes bounding mn_block
            for c in range(n_classes):
                m = class_masks[c]
                if not m:
                    continue
                v = float(int(unit_free[c]))
                if v < mn_block:
                    mn_block = v
                    blk_mask = m
                elif v == mn_block:
                    blk_mask |= m
            if mn_block < inf:
                nxt = mn_block if mn_block < mn_wait else mn_wait
                if nxt < cap:
                    nxt = cap
                elif nxt > max_cycles:
                    # the reference crawls one cycle per iteration and stops
                    # exactly at the cap — clamp the bulk jump likewise
                    nxt = float(max_cycles)
                k = int(nxt - cycle)
                idle_cycles += k
                rr += k - 1
                rr %= n_warps
                if blame is not None and k:
                    if mn_block <= mn_wait:
                        w_block = (blk_mask & -blk_mask).bit_length() - 1
                        key = (code[pc[w_block]], R_UNIT)
                    else:
                        key = warp_blame[heap[0][1]]
                    blame[key] = blame.get(key, 0) + k
            else:
                nxt = mn_wait if mn_wait > cap else cap
                k = int(nxt - cycle)
                idle_cycles += k
                if blame is not None and k:
                    key = warp_blame[heap[0][1]]
                    blame[key] = blame.get(key, 0) + k
            cycle = nxt
    return cycle, idle_cycles


def _engine_family(n_warps: int, intervals: List[float], arch) -> tuple:
    """Checkpoint compatibility key: everything the issue loop's evolution
    depends on besides the compiled trace itself."""
    return (n_warps, tuple(intervals), arch.issue_width, arch.num_barriers)


def simulate(
    kernel: Kernel,
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
    profile: bool = False,
    checkpoints: Optional[CheckpointStore] = None,
    _prep: Optional[tuple] = None,
) -> SimResult:
    """Simulate one wave of resident warps on one SM; scale by wave count.

    Two-stage engine: :func:`compile_trace` lowers the dynamic stream to
    flat numeric records, :func:`_issue_loop` replays the scheduling
    semantics event-to-event.  Cycle-exact with :func:`simulate_reference`.

    The machine model (unit lanes, latencies, issue width) comes from the
    kernel's architecture; ``sm`` overrides the occupancy limits only
    (default: the arch's own SMConfig), which permits deliberate
    cross-arch what-ifs like ``simulate(volta_kernel, MAXWELL)``.

    ``profile=True`` additionally attributes every idle cycle to a static
    instruction and a reason (:class:`repro.obs.stallprof.StallProfile` on
    ``SimResult.stall_profile``); the attribution is bookkeeping only —
    cycle counts are identical either way, and the profile total balances
    exactly against ``issue_stalls``.

    ``checkpoints`` (optional) plugs in a :class:`CheckpointStore`: the run
    resumes from the deepest exactly-valid captured state and contributes
    fresh captures back — incremental re-simulation for kernels that share
    a schedule prefix (``SimCache`` wires its own store through here).

    ``_prep`` is internal: :func:`simulate_batch` already flattened and
    compiled every member's trace to order the batch, and hands the work
    over instead of paying the trace compiler twice per kernel.
    """
    with obs.span("simulate", kernel=kernel.name, profile=profile) as sp:
        if _prep is not None:
            arch, sm, occ, trace, ct = _prep
        else:
            arch = _arch_of(kernel)
            if sm is None:
                sm = arch.sm
            occ = occupancy_of(kernel, sm)
            trace = flatten_trace(kernel)
            ct = compile_trace(trace, arch)
        n_warps = max(occ.resident_warps, 1)
        intervals = [arch.issue_interval(k) for k in OpClass]
        blame: Optional[Dict[Tuple[int, str], int]] = {} if profile else None
        resume = None
        capture: Optional[List[SimCheckpoint]] = None
        family = sigs = None
        if checkpoints is not None:
            sigs = position_signatures(ct)
            family = _engine_family(n_warps, intervals, arch)
            resume = checkpoints.lookup(family, sigs, max_cycles, profile)
            capture = []
        cycle, idle_cycles = _issue_loop(
            ct, n_warps, max_cycles, intervals, arch.issue_width,
            arch.num_barriers, blame, resume=resume, capture=capture,
        )
        if checkpoints is not None and capture:
            checkpoints.offer(family, sigs, capture)

        stall_profile = None
        if profile:
            from repro.obs.stallprof import build_profile

            by_uid: Dict[Tuple[int, str], int] = {}
            uid = ct.uid.tolist()
            for (rec, reason), c in blame.items():
                key = (uid[rec], reason)
                by_uid[key] = by_uid.get(key, 0) + c
            stall_profile = build_profile(kernel, by_uid, idle_cycles)

        # fractional waves: charge the launch by work/throughput, not by
        # rounding partial waves up (a 1.2-wave launch is not 2x a 1.0-wave
        # launch)
        blocks_per_wave = max(occ.resident_blocks, 1) * sm.num_sms
        waves = kernel.num_blocks / blocks_per_wave
        sp.set(cycles=int(cycle), warps=n_warps, instrs=len(trace))
        return SimResult(
            kernel_name=kernel.name,
            cycles_per_wave=int(cycle),
            waves=max(1.0, waves),
            total_cycles=int(cycle * max(1.0, waves)),
            occupancy=occ,
            dynamic_instructions=len(trace),
            issue_stalls=idle_cycles,
            stall_profile=stall_profile,
            truncated=trace.truncated,
        )


def simulate_batch(
    kernels: Sequence[Kernel],
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
    profile: bool = False,
    cache=None,
    checkpoints: Optional[CheckpointStore] = None,
) -> List[SimResult]:
    """Simulate a batch of sibling kernels through one checkpoint store.

    Element-wise identical to calling :func:`simulate` per kernel (the
    differential property test pins this, stall books included) — the win
    is scheduling: kernels are visited in signature-prefix order, so each
    run resumes from the deepest checkpoint its predecessors captured, and
    variants that only diverge in a schedule suffix replay only the suffix.

    ``cache`` (optional, a ``repro.core.simcache.SimCache``) serves and
    warms full results too, which additionally dedups content-identical
    batch members; otherwise ``checkpoints`` (default: a fresh private
    store) carries the intra-batch reuse.
    """
    kernels = list(kernels)
    if not kernels:
        return []
    with obs.span("simulate_batch", kernels=len(kernels), profile=profile):
        if checkpoints is None:
            checkpoints = (
                cache.checkpoints if cache is not None else CheckpointStore()
            )
        order = []
        preps = []
        for i, k in enumerate(kernels):
            arch = _arch_of(k)
            sm_k = sm if sm is not None else arch.sm
            occ = occupancy_of(k, sm_k)
            trace = flatten_trace(k)
            ct = compile_trace(trace, arch)
            intervals = [arch.issue_interval(kl) for kl in OpClass]
            family = _engine_family(max(occ.resident_warps, 1), intervals, arch)
            order.append((family, position_signatures(ct), i))
            preps.append((arch, sm_k, occ, trace, ct))
        order.sort(key=lambda t: (t[0], t[1]))
        results: List[Optional[SimResult]] = [None] * len(kernels)
        for _, _, i in order:
            k = kernels[i]
            if cache is not None:
                if profile:
                    prof = cache.profile(k, sm, max_cycles)
                    res = cache.simulate(k, sm, max_cycles)
                    res.stall_profile = prof
                else:
                    res = cache.simulate(k, sm, max_cycles)
            else:
                res = simulate(
                    k, sm, max_cycles, profile, checkpoints, _prep=preps[i]
                )
            results[i] = res
        return results


def simulate_reference(
    kernel: Kernel,
    sm: Optional[SMConfig] = None,
    max_cycles: int = 50_000_000,
) -> SimResult:
    """The pre-optimization cycle-by-cycle engine, kept verbatim as the
    parity oracle for :func:`simulate` (golden test: identical cycles).

    Arch-parameterized the same way as :func:`simulate`, so the parity
    holds per architecture."""
    arch = _arch_of(kernel)
    if sm is None:
        sm = arch.sm
    issue_width = arch.issue_width
    num_barriers = arch.num_barriers
    issue_interval = {k: arch.issue_interval(k) for k in OpClass}
    occ = occupancy_of(kernel, sm)
    trace = flatten_trace(kernel)
    n_warps = max(occ.resident_warps, 1)

    # per-warp state
    pc = [0] * n_warps
    ready = [0.0] * n_warps  # earliest issue cycle
    bar_signal = [[0.0] * num_barriers for _ in range(n_warps)]
    done = [False] * n_warps
    n_done = 0

    unit_free: Dict[OpClass, float] = {k: 0.0 for k in OpClass}
    cycle = 0.0
    idle_cycles = 0
    rr = 0  # round-robin pointer

    def warp_next_time(w: int) -> float:
        """Earliest cycle warp ``w`` could issue its next instruction."""
        t = ready[w]
        ins = trace[pc[w]]
        for b in ins.ctrl.wait:
            t = max(t, bar_signal[w][b])
        return t

    while n_done < n_warps and cycle < max_cycles:
        issued = 0
        for k in range(n_warps):
            if issued >= issue_width:
                break
            w = (rr + k) % n_warps
            if done[w]:
                continue
            ins = trace[pc[w]]
            if ready[w] > cycle:
                continue
            if any(bar_signal[w][b] > cycle for b in ins.ctrl.wait):
                continue
            klass = ins.info.klass
            # the unit blocks only once this cycle's issue capacity is spent
            # (e.g. FP32 interval 0.25 -> four issues per cycle)
            if unit_free[klass] >= cycle + 1:
                continue
            # ---- issue -----------------------------------------------------
            issued += 1
            unit_free[klass] = max(unit_free[klass], cycle) + issue_interval[klass]
            issue_cost = max(1, ins.ctrl.stall) + arch.bank_conflicts(ins)
            ready[w] = cycle + issue_cost
            if ins.ctrl.write_bar is not None:
                bar_signal[w][ins.ctrl.write_bar] = cycle + _signal_latency(ins, arch)
            if ins.ctrl.read_bar is not None:
                # operands are read shortly after issue
                bar_signal[w][ins.ctrl.read_bar] = cycle + min(
                    _signal_latency(ins, arch), arch.latency.read_release
                )
            pc[w] += 1
            if pc[w] >= len(trace):
                done[w] = True
                n_done += 1
        rr = (rr + 1) % n_warps
        if issued == 0:
            # jump to the next time anything can happen
            nxt = min(
                (warp_next_time(w) for w in range(n_warps) if not done[w]),
                default=cycle + 1,
            )
            nxt = max(nxt, cycle + 1)
            idle_cycles += int(nxt - cycle)
            cycle = nxt
        else:
            cycle += 1

    # fractional waves: charge the launch by work/throughput, not by rounding
    # partial waves up (a 1.2-wave launch is not 2x a 1.0-wave launch)
    blocks_per_wave = max(occ.resident_blocks, 1) * sm.num_sms
    waves = kernel.num_blocks / blocks_per_wave
    return SimResult(
        kernel_name=kernel.name,
        cycles_per_wave=int(cycle),
        waves=max(1.0, waves),
        total_cycles=int(cycle * max(1.0, waves)),
        occupancy=occ,
        dynamic_instructions=len(trace),
        issue_stalls=idle_cycles,
        truncated=trace.truncated,
    )


def speedup(base: SimResult, other: SimResult) -> float:
    """Speedup of ``other`` over ``base`` (>1 means faster).

    A zero-cycle denominator (an empty or fully truncated-away kernel) has
    no meaningful ratio; that is an explicit error, never a
    ZeroDivisionError from deep inside a report."""
    if other.total_cycles == 0:
        raise ValueError(
            f"speedup undefined: {other.kernel_name} simulated to 0 cycles"
        )
    return base.total_cycles / other.total_cycles
