"""Compile-time performance predictor (paper §4, Fig. 5).

Estimates program cost in *stall cycles* from the binary alone:

1. per-basic-block stall accumulation, scaling each instruction's annotated
   stall by occupancy-driven contention and unit throughput (eq. 2):
   ``stall = inst_stall * occupancy * MAX_THROUGHPUT / inst_throughput``;
2. memory stalls from the barrier tracker: time between barrier set and
   first wait, floored by the memory latency (GL_MEM_STALL / SH_MEM_STALL);
3. loop bodies weighted by ``LOOP_FACTOR`` (10);
4. whole-program adjustment by the empirical occupancy curve (eq. 3):
   ``stall_program = f(occ) / f(occ_max) * stall_count``.

``f`` is fitted once on compute-intensive microbenchmarks whose occupancy is
swept by register usage, exactly as §4 describes — here the measurements
come from the timing simulator instead of a Titan X.

The module also provides the ``naive`` ablation (raw static stall count) the
paper compares against in Fig. 9.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

from .isa import (
    CFG,
    Instr,
    Kernel,
)
from .occupancy import SMConfig, occupancy_of

#: generic loop weight (paper §4 step two)
LOOP_FACTOR = 10


def _arch_of(kernel: Kernel):
    from repro.arch import arch_of

    return arch_of(kernel)


def estimate_stalls(kernel: Kernel, occupancy: Optional[float] = None) -> float:
    """Fig. 5: whole-program stall estimate at the given occupancy.

    The contention term (eq. 2), the barrier residual latencies, and the
    register banking come from the kernel's architecture."""
    arch = _arch_of(kernel)
    if occupancy is None:
        occupancy = occupancy_of(kernel, arch.sm).occupancy
    cfg = CFG(kernel)
    block_stall: Dict[int, float] = {}

    for blk in cfg.blocks:
        stall = 0.0
        tracker: List[Optional[Tuple[Instr, float]]] = [None] * arch.num_barriers
        for ins in blk.instrs:
            inst_stall = (
                ins.ctrl.stall * occupancy * arch.throughput_ratio(ins.info.klass)
            )
            inst_stall += arch.bank_conflicts(ins)
            # barrier bookkeeping (lines 7-12)
            if ins.ctrl.read_bar is not None:
                tracker[ins.ctrl.read_bar] = (ins, 0.0)
            if ins.ctrl.write_bar is not None:
                tracker[ins.ctrl.write_bar] = (ins, 0.0)
            # waits: residual memory latency (lines 13-19)
            for b in ins.ctrl.wait:
                if tracker[b] is None:
                    continue
                setter, elapsed = tracker[b]
                lat = arch.residual_latency(setter.info.klass)
                if elapsed < lat:
                    stall += lat - elapsed
                tracker[b] = None
            # elapse (lines 20-21)
            for b in range(arch.num_barriers):
                if tracker[b] is not None:
                    tracker[b] = (tracker[b][0], tracker[b][1] + inst_stall)
            stall += inst_stall
        block_stall[blk.index] = stall

    # step two: loop weighting (multiplicative per nesting level)
    total = 0.0
    for blk in cfg.blocks:
        total += block_stall[blk.index] * (LOOP_FACTOR ** blk.loop_depth)
    return total


def naive_stalls(kernel: Kernel) -> float:
    """The Fig. 9 ``naive`` scheme: raw static stall-count sum."""
    return float(sum(ins.ctrl.stall for ins in kernel.instructions()))


def strategy_access_cost(hints, arch) -> float:
    """Predicted cycles one demoted-slot access costs under ``arch``, from
    a strategy's :class:`~repro.core.strategies.StrategyHints` alone — no
    variant built yet.

    The slot load/store pays its access path's latency
    (``hints.latency_class`` names the :class:`~repro.arch.registry.
    LatencyModel` attribute) plus one fixed ALU latency per pack/unpack op
    (``hints.access_overhead``).  The autotuning search breaks exact
    predictor ties toward the strategy with the cheaper access path; the
    paper orderings all share one hints object, so their relative ordering
    is unchanged by this tie-break.
    """
    return (
        getattr(arch.latency, hints.latency_class)
        + hints.access_overhead * arch.latency.alu
    )


# ---------------------------------------------------------------------------
# The empirical occupancy-performance curve f(x) (eq. 3)
# ---------------------------------------------------------------------------

#: Normalized execution time vs occupancy, fitted with
#: :func:`fit_occupancy_curve` (regenerate with
#: ``python -m repro.core.predictor``).  Shape matches Volkov's observation
#: [35]: steep gains up to ~0.5 occupancy, diminishing returns above.
OCCUPANCY_CURVE: List[Tuple[float, float]] = [
    (0.125, 49.154),
    (0.1875, 21.976),
    (0.25, 12.525),
    (0.3125, 8.196),
    (0.5, 3.283),
    (0.625, 2.128),
    (0.75, 1.526),
    (1.0, 1.0),
]


def f_occupancy(x: float, curve: Optional[Sequence[Tuple[float, float]]] = None) -> float:
    """Piecewise-linear interpolation of the occupancy curve."""
    pts = list(curve or OCCUPANCY_CURVE)
    if x <= pts[0][0]:
        return pts[0][1]
    for (x0, y0), (x1, y1) in zip(pts, pts[1:]):
        if x <= x1:
            t = (x - x0) / (x1 - x0)
            return y0 + t * (y1 - y0)
    return pts[-1][1]


def fit_occupancy_curve(threads_per_block: int = 128) -> List[Tuple[float, float]]:
    """Re-fit ``OCCUPANCY_CURVE`` from simulator microbenchmarks.

    One compute-intensive kernel (dependent FMA chains plus a global load
    stream); its occupancy is swept *without changing the instruction
    stream* by padding the register count — "measuring only the impact of
    occupancy on performance" (§4).

    Calibration: the predictor multiplies per-instruction stalls by
    occupancy (eq. 2), so for identical code ``est(x) ∝ x`` and the eq.-3
    curve must satisfy ``measured(x)/measured(1) = f(x)/f(1) * x``, i.e.
    ``f(x) = measured_ratio(x) / x``.  This makes the fitted curve the exact
    inverse correction for the contention term on occupancy-only changes.
    """
    from .isa import Instr
    from .kernelgen import Profile, generate
    from .simcache import DEFAULT_SIM_CACHE

    prof = Profile(
        name="occ_micro",
        target_regs=32,
        threads_per_block=threads_per_block,
        num_blocks=8192,
        shared_size=0,
        regdem_target=32,
        nvcc_spills=0,
        loop_trips=12,
        n_consts=4,
        n_temps=4,
        loads_per_iter=2,
        chase_loads=1,
        seed=1234,
    )
    base = generate(prof)
    variants = []
    for pad_regs in (32, 40, 48, 64, 84, 96, 128, 168, 255):
        k = base.copy()
        if pad_regs > k.reg_count:
            # touch a high register once: same dynamic behaviour, padded
            # register footprint (the occupancy-calculator sees pad_regs)
            k.items.insert(0, Instr("MOV", [pad_regs - 1], [255]))
        variants.append(k)
    # one batched sweep: pad values below reg_count dedup to the base kernel
    # through the cache, the rest share the engine's checkpoint store
    sims = DEFAULT_SIM_CACHE.simulate_batch(variants)
    results: List[Tuple[float, float]] = [
        (sim.occupancy.occupancy, float(sim.total_cycles)) for sim in sims
    ]
    agg: Dict[float, List[float]] = {}
    for occ, t in results:
        agg.setdefault(round(occ, 4), []).append(t)
    pts = sorted((o, sum(v) / len(v)) for o, v in agg.items())
    o_max, t_max = pts[-1]
    out: List[Tuple[float, float]] = []
    prev = float("inf")
    for o, t in pts:
        fx = (t / t_max) / (o / o_max)
        fx = min(fx, prev)  # enforce monotone non-increasing
        prev = fx
        out.append((o, round(fx, 3)))
    return out


# ---------------------------------------------------------------------------
# Variant selection (the §4 contract)
# ---------------------------------------------------------------------------


@dataclass
class Prediction:
    name: str
    stalls: float
    occupancy: float
    adjusted: float


def _launch_occupancy(kernel: Kernel, sm: SMConfig) -> float:
    """Upper bound on achieved occupancy from the launch size alone: a grid
    too small to fill every SM cannot benefit from a higher theoretical
    ceiling (this is why tail-wave benchmarks gain nothing from demotion)."""
    warps_per_block = -(-kernel.threads_per_block // sm.warp_size)
    total_warps = kernel.num_blocks * warps_per_block
    return min(1.0, total_warps / (sm.num_sms * sm.max_warps))


def achieved_occupancy(kernel: Kernel, sm: Optional[SMConfig] = None) -> float:
    """Achieved-occupancy estimate: the theoretical ceiling capped by what
    the launch size can actually fill.  The single definition shared by
    :func:`predict` and the autotuning search, so both paths always score
    variants under the same occupancy model."""
    if sm is None:
        sm = _arch_of(kernel).sm
    return min(occupancy_of(kernel, sm).occupancy, _launch_occupancy(kernel, sm))


def predict(
    variants: Dict[str, Kernel],
    sm: Optional[SMConfig] = None,
    curve: Optional[Sequence[Tuple[float, float]]] = None,
    option_rank: Optional[Dict[str, int]] = None,
) -> Tuple[str, List[Prediction]]:
    """Rank code variants; returns (best_name, all_predictions).

    ``option_rank`` breaks ties toward more enabled performance options
    (paper §5.7: "counting on potential benefits of the enabled options").
    ``sm`` overrides the occupancy limits; by default each variant is
    judged under its own architecture's SM configuration.
    """
    from .simcache import estimate_stalls_cached

    def _sm(k: Kernel) -> SMConfig:
        return sm if sm is not None else _arch_of(k).sm

    occs = {n: achieved_occupancy(k, _sm(k)) for n, k in variants.items()}
    occ_max = max(occs.values())
    preds: List[Prediction] = []
    for n, k in variants.items():
        # content-cached: a variant already analyzed at this occupancy
        # anywhere in the process (e.g. by a previous translation of the
        # same kernel) is served from DEFAULT_SIM_CACHE
        raw = estimate_stalls_cached(k, occs[n])
        adj = f_occupancy(occs[n], curve) / f_occupancy(occ_max, curve) * raw
        preds.append(Prediction(name=n, stalls=raw, occupancy=occs[n], adjusted=adj))
    rank = option_rank or {}
    best = min(preds, key=lambda p: (p.adjusted, -rank.get(p.name, 0)))
    return best.name, preds


def predict_naive(variants: Dict[str, Kernel]) -> str:
    return min(variants, key=lambda n: naive_stalls(variants[n]))


def ranking_agreement(
    predicted: Dict[str, float], measured: Dict[str, float]
) -> float:
    """Pairwise ordering agreement between a predicted cost ranking and a
    measured one (the §5 accuracy claim, as one number).

    For every unordered pair of variants present in both dicts, the pair is
    *concordant* when the predictor orders it the same way the measurement
    does (both tie, or both strictly agree on which is cheaper).  Returns
    concordant / total pairs, 1.0 when fewer than two variants overlap.
    This is what the autotuning search and ``BENCH_search.json`` report as
    ``agreement``, and what the predictor-fidelity test pins.
    """
    names = sorted(set(predicted) & set(measured))
    pairs = concordant = 0
    for i, a in enumerate(names):
        for b in names[i + 1 :]:
            pairs += 1
            dp = predicted[a] - predicted[b]
            dm = measured[a] - measured[b]
            if (dp == 0 and dm == 0) or dp * dm > 0:
                concordant += 1
    return concordant / pairs if pairs else 1.0


if __name__ == "__main__":  # pragma: no cover
    pts = fit_occupancy_curve()
    print("OCCUPANCY_CURVE = [")
    for o, t in pts:
        print(f"    ({o}, {t}),")
    print("]")
