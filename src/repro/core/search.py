"""Predictor-guided parallel autotuning search over the RegDem variant space.

The paper's pipeline is "generate variants, let the compile-time predictor
pick one" (§4-§5) over a fixed, hand-picked variant set.  This module
searches the much larger space the machinery already supports:

* every registered :mod:`repro.core.strategies` strategy — the paper's
  candidate orderings (``static``/``cfg``/``conflict``) plus the
  related-work families (``warp_share``/``block_share``/``compressed``),
* each strategy's own occupancy-cliff target ladder and option combos,
* every registered :mod:`repro.arch` backend the kernel can retarget to.

Exhaustively simulating that space is what the predictor exists to avoid, so
the search is staged:

1. **enumerate** the space (cheap descriptors, nothing built yet);
2. **beam** — build one probe variant per (arch, target, strategy) and score
   it with the compile-time predictor (:func:`~repro.core.predictor.
   estimate_stalls` + occupancy, eq. 2/3 — no simulation), keeping the
   ``beam_width`` best;
3. **expand** the option knobs for beam survivors only, predictor-scored the
   same way;
4. **confirm** the global ``top_k`` (plus every ``nvcc`` baseline and any
   caller-supplied anchor variants) on the event-driven simulator through
   :class:`~repro.core.simcache.SimCache`, and ship the variant with the
   fewest simulated cycles.

Stages 2-4 fan out over a **deterministic process pool**: tasks are pure
functions of their payload, submitted and joined in enumeration order, each
worker process is seeded once from ``config.seed`` at startup (hygiene —
the tasks themselves never draw randomness, and the caller's in-process
RNG state is never touched), and each task measures into a private
:class:`SimCache` whose entries are merged into the parent cache on
join (first writer wins) — so the result, the report, and the final cache
contents are identical for 1 worker and N workers.  ``workers`` is therefore
deliberately **not** part of :meth:`SearchConfig.signature`, and repeated
tuning of the same content is a pure :class:`~repro.core.translator.
TranslationCache` hit.

``SEARCH_TOLERANCE`` documents the contract the differential tests hold the
beam to: the chosen variant's simulated cycles stay within 5% of the
exhaustive simulate-everything optimum (the predictor's §5 accuracy claim —
the paper reaches 99% of oracle performance — leaves that much room for
pruning error).
"""

from __future__ import annotations

import copy
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

from repro import obs
from repro.obs.stallprof import StallProfile

from .candidates import STRATEGIES, spillable  # noqa: F401  (STRATEGIES re-exported)
from .isa import Kernel
from .predictor import achieved_occupancy, f_occupancy, ranking_agreement
from .simcache import DEFAULT_SIM_CACHE, SimCache
from .strategies import get_strategy, strategy_names
from .workerpool import Quarantined, WorkerCrashError, supervised_map

#: Relative simulated-cycle slack the beam search is allowed vs exhaustive
#: ground truth (pinned by the differential tests).
SEARCH_TOLERANCE = 0.05


@dataclass(frozen=True)
class SearchConfig:
    """Knobs of the autotuning search.

    Everything except ``workers`` is part of :meth:`signature` (the
    translation-cache key): the pool size affects wall time only, never the
    result — pinned by the determinism property test.
    """

    #: registered strategy names to probe (:mod:`repro.core.strategies`);
    #: ``None`` = every registered strategy, in registration order
    strategies: Optional[Tuple[str, ...]] = None
    #: arch registry names to retarget to; ``None`` = every registered arch
    archs: Optional[Tuple[str, ...]] = None
    #: truncate the auto_targets ladder per arch (None = every cliff)
    max_targets: Optional[int] = None
    #: sweep all 2^4 option-flag combinations per beam survivor instead of
    #: the grouped Fig.-7 dimensions (bank avoidance x enhancements)
    full_options: bool = False
    #: (arch, target, strategy) probes kept after predictor scoring
    beam_width: int = 6
    #: variants confirmed on the simulator (baselines/anchors ride free)
    top_k: int = 4
    #: process-pool size; <=1 runs in-process (identical results either way)
    workers: int = 0
    #: pool-worker RNG seed (hygiene only: no task draws randomness)
    seed: int = 0
    #: pass-pipeline self-check policy.  The default ``"chosen"`` builds
    #: variants unchecked and verifies only the winning kernel (schedule +
    #: dataflow equivalence vs its arch baseline) once, after selection —
    #: what ships is always verified, and the N-1 losing pipeline runs skip
    #: the oracle.  Any :class:`~repro.core.passes.PassPipeline` policy
    #: (``"each"``/``"schedule"``/``"final"``/``"none"``) applies to every
    #: variant instead.
    verify: str = "chosen"
    #: attribute stall cycles per instruction/reason for every confirmed
    #: variant (:attr:`SearchReport.stall_profiles`) — extra profiled
    #: simulator runs, so off by default
    profile: bool = False

    def signature(self) -> tuple:
        """Everything that determines the search *result* (cache key).

        ``workers`` and ``seed`` are deliberately absent: neither changes
        the outcome (the tasks are pure and never draw randomness), so
        tuning the same content under a different pool size or seed must be
        a cache hit, not a re-search.  An explicit ``strategies`` tuple
        signs as itself — byte-identical to the pre-registry signatures for
        the paper's names (pinned by the signature-stability test);
        ``None`` resolves to the registered names, so registering a new
        strategy correctly invalidates default-config tunes."""
        return (
            tuple(strategy_names()) if self.strategies is None else tuple(self.strategies),
            None if self.archs is None else tuple(self.archs),
            self.max_targets,
            self.full_options,
            self.beam_width,
            self.top_k,
            self.verify,
            self.profile,
        )


@dataclass
class ScoredVariant:
    """One predictor-scored point of the search space."""

    label: str
    arch: str
    #: demotion register target (None for baselines/anchors)
    target: Optional[int]
    #: RegDemOptions label (None for baselines/anchors)
    options: Optional[str]
    regs: int
    demoted_words: int
    occupancy: float
    #: raw whole-program stall estimate at ``occupancy`` (eq. 2)
    stalls: float
    #: eq.-3 adjusted estimate, comparable within one architecture
    adjusted: float = 0.0
    #: predicted cost relative to the same arch's ``nvcc`` baseline — the
    #: ranking metric.  Cycle and stall counts of different architectures
    #: are different units (Volta's latency model roughly halves Maxwell's
    #: cycle counts for the same program), so the search compares variants
    #: by how much they beat *their own* arch's do-nothing option.
    rel: float = 1.0
    #: search stage that produced it: baseline | beam | expand | anchor
    stage: str = "beam"
    #: simulated cycles, filled for confirmed variants only
    cycles: Optional[int] = None

    def to_json(self) -> dict:
        return {
            "label": self.label,
            "arch": self.arch,
            "target": self.target,
            "options": self.options,
            "regs": self.regs,
            "demoted_words": self.demoted_words,
            "occupancy": round(self.occupancy, 6),
            "stalls": round(self.stalls, 3),
            "adjusted": round(self.adjusted, 3),
            "rel": round(self.rel, 6),
            "stage": self.stage,
            "cycles": self.cycles,
        }


@dataclass
class SearchReport:
    """Everything one kernel's search did and found.

    :meth:`to_json` is deterministic (wall-clock time excluded), which is
    what lets a tuned container embed the report as a ``.note`` section and
    still be byte-identical across repeat runs.
    """

    kernel_name: str
    input_arch: str
    chosen: str
    #: what the predictor alone would have shipped (argmin adjusted)
    predictor_choice: str
    #: the do-nothing option, always confirmed
    baseline: str
    #: enumerable size of the widened space (demotions + baselines)
    space_size: int
    #: demotion pipelines actually run (beam + expand)
    explored: int
    #: variants confirmed on the simulator
    simulated: int
    beam: List[str] = field(default_factory=list)
    #: predictor-vs-simulator ranking agreement over the confirmed set
    #: (orderings compared on baseline-relative cost)
    agreement: float = 1.0
    variants: List[ScoredVariant] = field(default_factory=list)
    #: label -> simulated cycles for every confirmed variant
    cycles: Dict[str, int] = field(default_factory=dict)
    #: simulated speedup of the chosen variant over its arch's baseline
    speedup: float = 1.0
    #: best confirmed variant per architecture
    per_arch: Dict[str, str] = field(default_factory=dict)
    #: label -> stall-attribution profile for every confirmed variant
    #: (populated when :attr:`SearchConfig.profile` is set; deterministic,
    #: so profiled reports stay byte-identical across repeat runs)
    stall_profiles: Dict[str, StallProfile] = field(default_factory=dict)
    seconds: float = 0.0
    #: raw :meth:`to_json` dict stashed by :meth:`from_json`.  A report
    #: warm-loaded from the artifact store does not reconstruct
    #: ``stall_profiles`` as objects, yet its container ``.note`` sections
    #: must stay byte-identical to the original — so re-serialization
    #: returns the stash verbatim.
    _raw: Optional[dict] = field(default=None, repr=False, compare=False)

    def to_json(self) -> dict:
        if self._raw is not None:
            return copy.deepcopy(self._raw)
        return {
            "kernel": self.kernel_name,
            "input_arch": self.input_arch,
            "chosen": self.chosen,
            "predictor_choice": self.predictor_choice,
            "baseline": self.baseline,
            "space_size": self.space_size,
            "explored": self.explored,
            "simulated": self.simulated,
            "beam": list(self.beam),
            "agreement": round(self.agreement, 4),
            "speedup": round(self.speedup, 4),
            "per_arch": dict(sorted(self.per_arch.items())),
            "cycles": dict(sorted(self.cycles.items())),
            "stall_profiles": {
                lb: p.to_json() for lb, p in sorted(self.stall_profiles.items())
            },
            "variants": [v.to_json() for v in self.variants],
        }

    @classmethod
    def from_json(cls, data: dict) -> "SearchReport":
        """Rebuild a report from its :meth:`to_json` dict (disk warm-load).

        Variants round-trip exactly (``to_json`` keys are the field names);
        stall profiles stay raw-JSON-only — :meth:`to_json` returns the
        stashed original, so a warm-loaded container re-serializes
        byte-identically."""
        rep = cls(
            kernel_name=data["kernel"],
            input_arch=data["input_arch"],
            chosen=data["chosen"],
            predictor_choice=data["predictor_choice"],
            baseline=data["baseline"],
            space_size=data["space_size"],
            explored=data["explored"],
            simulated=data["simulated"],
            beam=list(data.get("beam", [])),
            agreement=data.get("agreement", 1.0),
            variants=[ScoredVariant(**v) for v in data.get("variants", [])],
            cycles={k: int(v) for k, v in data.get("cycles", {}).items()},
            speedup=data.get("speedup", 1.0),
            per_arch=dict(data.get("per_arch", {})),
        )
        rep._raw = copy.deepcopy(data)
        return rep


@dataclass
class SearchOutcome:
    """The winning kernel plus the full search report."""

    kernel: Kernel
    report: SearchReport
    #: variant labels dropped because their pool task repeatedly crashed
    #: its worker (see :mod:`repro.core.workerpool`).  Non-empty means the
    #: outcome is *not* the fault-free search result — the translation
    #: service refuses to cache or serve it (the daemon degrades instead).
    quarantined: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Pure worker tasks (module-level: picklable under fork and spawn)
# ---------------------------------------------------------------------------


def _task_obs_begin(tel: tuple) -> tuple:
    """Worker-side telemetry entry: honour the parent's on/off switch and
    mark the event prefix a fork inherits, so only task-added spans export.

    The per-task registry clear keeps metric accounting exact: the fork
    snapshot (and any earlier task's already-exported observations in a
    reused worker process) must never export twice."""
    parent_pid, enabled = tel
    t = obs.get_telemetry()
    if enabled:
        if os.getpid() != parent_pid:
            t.registry.clear()
        t.enabled = True
    return parent_pid, t.event_count()


def _task_obs_end(tel_state: tuple) -> tuple:
    """Worker-side telemetry exit: ``(span_records, metrics_export)`` for
    the parent's :meth:`Telemetry.adopt` / :meth:`MetricsRegistry.merge`.
    Empty when the task ran in-process (spans already landed in the parent
    timeline directly) or telemetry is off."""
    parent_pid, mark = tel_state
    t = obs.get_telemetry()
    if os.getpid() == parent_pid or not t.enabled:
        return (), {}
    return tuple(t.export_events(mark)), t.registry.export()


def _build_variant(base, target, strategy, combo, verify, cache):
    """Build + predictor-score one demotion variant.

    Pure function of its inputs — the in-process stage loop and the pool
    task (:func:`_expand_one`) both run exactly this, so pool size can
    never change a result.  ``strategy`` is a registry name and ``combo``
    one of its option combos (primitives only: picklable either way).
    Returns ``(RegDemResult, occupancy, stalls)`` with the stall estimate
    measured through ``cache``.
    """
    res = get_strategy(strategy).build(base, target, combo, verify=verify)
    occ = achieved_occupancy(res.kernel)
    stalls = cache.estimate_stalls(res.kernel, occ)
    return res, occ, stalls


def _expand_one(payload: tuple) -> tuple:
    """Pool-worker wrapper of :func:`_build_variant`: deserialize the base,
    build + score into a private cache, ship everything back picklable.
    Returns ``(index, kernel_blob, regs, demoted_words, occupancy,
    raw_stalls, cache_export, obs_export)``.
    """
    (index, base_blob, target, strategy, combo, verify, tel) = payload
    from repro.binary import container

    tel_state = _task_obs_begin(tel)
    with obs.span("search.variant", index=index, target=target):
        base = container.loads(base_blob)
        cache = SimCache()
        res, occ, stalls = _build_variant(base, target, strategy, combo, verify, cache)
    return (
        index,
        container.dumps(res.kernel),
        res.kernel.reg_count,
        res.demoted_words,
        occ,
        stalls,
        cache.export(),
        _task_obs_end(tel_state),
    )


def _simulate_one(payload: tuple) -> tuple:
    """Simulate (and optionally stall-profile) one confirmed variant;
    returns ``(index, SimResult, cache_export, obs_export)`` — the profile
    rides home inside the cache export's ``profiles`` table."""
    (index, blob, profile, tel) = payload
    from repro.binary import container

    tel_state = _task_obs_begin(tel)
    with obs.span("search.confirm_sim", index=index):
        kernel = container.loads(blob)
        cache = SimCache()
        if profile:
            cache.profile(kernel)
        res = cache.simulate(kernel)
    return index, res, cache.export(), _task_obs_end(tel_state)


def _pool_map(fn, payloads: Sequence[tuple], workers: int, seed: int = 0) -> list:
    """Run ``fn`` over ``payloads`` with deterministic result ordering.

    ``workers <= 1`` (or a single payload) runs in-process through the very
    same task functions, so pool size can never change a result — only
    completion time.  Results come back in submission order regardless of
    which worker finished first.

    The pool is **supervised** (:func:`repro.core.workerpool.
    supervised_map`): a crashed worker is restarted and its task retried;
    a task that repeatedly kills its worker comes back as a
    :class:`~repro.core.workerpool.Quarantined` marker instead of hanging
    or failing the whole search — the stage loops drop that variant and
    record it in :attr:`SearchOutcome.quarantined`.
    """
    return supervised_map(fn, payloads, workers, seed=seed)


# ---------------------------------------------------------------------------
# The search driver
# ---------------------------------------------------------------------------


def _flag_combos(full: bool) -> List[Tuple[bool, bool, bool, bool]]:
    """Option-knob combinations, probe (all-on) first.

    Grouped mode is the Fig.-7 ablation grid: bank-conflict avoidance x
    the §3.4.2 enhancement passes as one dimension.  ``full`` sweeps all
    2^4 flag combinations (the paper's exhaustive search).
    """
    if full:
        combos = [
            (b, e, r, s)
            for b in (True, False)
            for e in (True, False)
            for r in (True, False)
            for s in (True, False)
        ]
    else:
        combos = [(b, e, e, e) for b in (True, False) for e in (True, False)]
    return combos


def _resolve_archs(kernel: Kernel, config: SearchConfig) -> List[str]:
    """Canonical arch names to search, input arch first, rest sorted."""
    from repro.arch import arch_names, arch_of, get_arch

    own = arch_of(kernel).name
    if config.archs is None:
        names = set(arch_names())
    else:
        names = {get_arch(a).name for a in config.archs}
    rest = sorted(n for n in names if n != own)
    return ([own] if own in names else []) + rest


def search(
    kernel: Kernel,
    config: Optional[SearchConfig] = None,
    extra_variants: Optional[Dict[str, Kernel]] = None,
    cache: Optional[SimCache] = None,
) -> SearchOutcome:
    """Autotune one kernel over the widened variant space.

    ``extra_variants`` (label -> kernel) are *anchors*: always confirmed on
    the simulator alongside the searched top-k, so the winner is guaranteed
    no worse than any of them (the benchmark harness anchors the fixed §5.3
    variant set this way).  ``cache`` defaults to the process-wide
    :data:`~repro.core.simcache.DEFAULT_SIM_CACHE`.
    """
    config = config or SearchConfig()
    with obs.span("search", kernel=kernel.name, workers=config.workers):
        return _search_impl(kernel, config, extra_variants, cache)


def _adopt_obs(obs_export: tuple) -> None:
    """Merge one pool task's telemetry into the parent timeline/registry
    (called in submission order — histogram replay order is deterministic)."""
    spans, metric_export = obs_export
    if spans:
        obs.get_telemetry().adopt(list(spans))
    if metric_export:
        obs.metrics().merge(metric_export)


def _search_impl(
    kernel: Kernel,
    config: SearchConfig,
    extra_variants: Optional[Dict[str, Kernel]],
    cache: Optional[SimCache],
) -> SearchOutcome:
    from repro.arch import arch_of, retarget
    from repro.binary import container

    cache = cache if cache is not None else DEFAULT_SIM_CACHE
    #: rides in every pool payload: workers mirror the parent's telemetry
    #: switch and ship their spans/metrics back on join
    tel = (os.getpid(), obs.enabled())
    t0 = time.perf_counter()

    own = arch_of(kernel).name
    archs = _resolve_archs(kernel, config)
    # the do-nothing option is always on the table, even when the caller
    # restricted the search to foreign archs
    base_archs = archs if own in archs else [own] + archs

    bases: Dict[str, Kernel] = {}
    blobs: Dict[str, bytes] = {}
    for arch in base_archs:
        base = kernel if arch == own else retarget(kernel, arch)
        bases[arch] = base
        blobs[arch] = container.dumps(base)

    strategy_list = [
        get_strategy(s)
        for s in (strategy_names() if config.strategies is None else config.strategies)
    ]
    scored: Dict[str, ScoredVariant] = {}
    kernels: Dict[str, Kernel] = {}

    # -- baselines (scored in-process: no pipeline to run) --------------------
    for arch in base_archs:
        base = bases[arch]
        label = f"{arch}/nvcc"
        occ = achieved_occupancy(base)
        scored[label] = ScoredVariant(
            label=label,
            arch=arch,
            target=None,
            options=None,
            regs=base.reg_count,
            demoted_words=0,
            occupancy=occ,
            stalls=cache.estimate_stalls(base, occ),
            stage="baseline",
        )
        kernels[label] = base

    # -- stage 1: enumerate + probe (one probe-combo demotion per
    #    (arch, strategy, target)) ---------------------------------------------
    specs: List[Tuple[str, int, str, tuple]] = []
    space_size = len(base_archs)  # the baselines
    for arch in archs:
        base = bases[arch]
        if not spillable(base):
            continue
        for strat in strategy_list:
            if strat.archs is not None and arch not in strat.archs:
                continue
            if not strat.select(base):
                # strategy-specific candidate filter left nothing to demote
                continue
            targets = strat.targets(base, config.max_targets)
            combos = strat.option_combos(config.full_options)
            space_size += len(targets) * len(combos)
            for tgt in targets:
                specs.append((arch, tgt, strat.name, combos[0]))

    #: the pipeline self-check each variant build runs ("chosen" defers
    #: all verification to the single post-selection winner check)
    pipeline_verify = "none" if config.verify == "chosen" else config.verify

    #: variant labels dropped because their pool task repeatedly crashed
    #: its worker — reported on the outcome so callers can refuse to treat
    #: a narrowed search as the fault-free result
    quarantined_labels: List[str] = []

    def quarantine(label: str) -> None:
        quarantined_labels.append(label)
        if obs.enabled():
            obs.metrics().counter("search.quarantined").inc()

    def run_stage(stage_specs, stage_name):
        in_process = config.workers <= 1 or len(stage_specs) <= 1
        # (kernel, regs, demoted_words, occupancy, stalls) — or None for a
        # spec whose pool task was quarantined
        rows = []
        with obs.span(f"search.{stage_name}", variants=len(stage_specs)):
            if in_process:
                # the pool task's exact work minus its container round-trips,
                # measured straight into the parent cache
                for i, (arch, tgt, strat, flags) in enumerate(stage_specs):
                    with obs.span("search.variant", index=i, target=tgt):
                        res, occ, stalls = _build_variant(
                            bases[arch], tgt, strat, flags, pipeline_verify, cache
                        )
                    rows.append(
                        (res.kernel, res.kernel.reg_count, res.demoted_words,
                         occ, stalls)
                    )
            else:
                payloads = [
                    (i, blobs[arch], tgt, strat, flags, pipeline_verify, tel)
                    for i, (arch, tgt, strat, flags) in enumerate(stage_specs)
                ]
                results = _pool_map(
                    _expand_one, payloads, config.workers, config.seed
                )
                for item in results:
                    if isinstance(item, Quarantined):
                        rows.append(None)
                        continue
                    (_, blob, regs, words, occ, stalls, export, obs_export) = item
                    cache.merge(export)
                    _adopt_obs(obs_export)
                    rows.append((container.loads(blob), regs, words, occ, stalls))
        for (arch, tgt, strat, combo), row in zip(stage_specs, rows):
            opts_label = get_strategy(strat).options_label(combo)
            label = f"{arch}/regdem@{tgt}:{opts_label}"
            if row is None:
                quarantine(label)
                continue
            k_out, regs, words, occ, stalls = row
            scored[label] = ScoredVariant(
                label=label,
                arch=arch,
                target=tgt,
                options=opts_label,
                regs=regs,
                demoted_words=words,
                occupancy=occ,
                stalls=stalls,
                stage=stage_name,
            )
            kernels[label] = k_out

    run_stage(specs, "beam")

    own_baseline = f"{own}/nvcc"

    def adjust() -> None:
        """eq. 3 adjustment plus baseline normalization.

        ``adjusted`` applies the occupancy-curve correction (comparable
        within one arch); ``rel`` divides by the same arch's ``nvcc``
        baseline, which is what makes scores comparable *across* archs —
        different architectures' stall/cycle counts are different units.
        """
        occ_max = max(v.occupancy for v in scored.values())
        denom = f_occupancy(occ_max)
        for v in scored.values():
            v.adjusted = f_occupancy(v.occupancy) / denom * v.stalls
        for v in scored.values():
            # every scored arch has a baseline: search archs are a subset of
            # base_archs and anchors are validated on entry
            base = scored[f"{v.arch}/nvcc"]
            v.rel = v.adjusted / base.adjusted if base.adjusted else 1.0

    adjust()
    probes = [v for v in scored.values() if v.stage == "beam"]

    def access_cost(v: ScoredVariant) -> float:
        # exact predictor ties break toward the strategy whose demoted-slot
        # access path is cheaper (registry hints; identical across the
        # paper orderings, so their historical ordering is untouched)
        from repro.arch import get_arch

        from .predictor import strategy_access_cost

        strat = get_strategy(v.options.split(":", 1)[0])
        return strategy_access_cost(strat.hints, get_arch(v.arch))

    beam = sorted(probes, key=lambda v: (v.rel, access_cost(v), v.label))[
        : config.beam_width
    ]
    beam_labels = [v.label for v in beam]

    # -- stage 2: expand the option knobs for beam survivors (each survivor
    #    sweeps its own strategy's remaining combos) ---------------------------
    expand_specs = [
        (v.arch, v.target, strat_name, combo)
        for v in beam
        for strat_name in (v.options.split(":", 1)[0],)
        for combo in get_strategy(strat_name).option_combos(config.full_options)[1:]
    ]
    run_stage(expand_specs, "expand")

    # -- anchors ---------------------------------------------------------------
    for label, k in sorted((extra_variants or {}).items()):
        if label in scored:
            continue
        anchor_arch = arch_of(k).name
        if anchor_arch not in bases:
            # without that arch's nvcc baseline there is nothing comparable
            # to rank the anchor against (cross-arch cycle counts are
            # different units), and the "winner is no worse than any
            # anchor" guarantee would silently break
            raise ValueError(
                f"anchor {label!r} is on arch {anchor_arch!r}, which is not "
                f"part of this search ({sorted(bases)}); include it in "
                "SearchConfig.archs or retarget the anchor"
            )
        occ = achieved_occupancy(k)
        scored[label] = ScoredVariant(
            label=label,
            arch=anchor_arch,
            target=None,
            options=None,
            regs=k.reg_count,
            demoted_words=0,
            occupancy=occ,
            stalls=cache.estimate_stalls(k, occ),
            stage="anchor",
        )
        kernels[label] = k

    adjust()

    # -- stage 3: confirm on the simulator ------------------------------------
    demoted = [v for v in scored.values() if v.stage in ("beam", "expand")]
    top = sorted(demoted, key=lambda v: (v.rel, v.label))[: config.top_k]
    confirm = sorted(
        {v.label for v in scored.values() if v.stage in ("baseline", "anchor")}
        | {v.label for v in top}
    )
    pending_labels: List[str] = []
    cycles: Dict[str, int] = {}
    for label in confirm:
        hit = cache.peek_simulate(kernels[label])
        if hit is not None and not config.profile:
            cycles[label] = hit.total_cycles
        else:
            pending_labels.append(label)
    in_process = config.workers <= 1 or len(pending_labels) <= 1
    with obs.span(
        "search.confirm",
        variants=len(confirm),
        pool=0 if in_process else len(pending_labels),
    ):
        if in_process:
            # batched sweep straight through the parent cache: no
            # serialization round-trips, and variants that share a schedule
            # prefix resume each other's checkpoints (element-wise identical
            # to per-variant simulation — the pooled path below measures the
            # very same results into worker-private caches)
            for label, res in zip(
                pending_labels,
                cache.simulate_batch(
                    [kernels[lb] for lb in pending_labels],
                    profile=config.profile,
                ),
            ):
                cycles[label] = res.total_cycles
        else:
            pending = [
                (i, container.dumps(kernels[lb]), config.profile, tel)
                for i, lb in enumerate(pending_labels)
            ]
            sim_results = _pool_map(
                _simulate_one, pending, config.workers, config.seed
            )
            for lb, item in zip(pending_labels, sim_results):
                if isinstance(item, Quarantined):
                    quarantine(lb)
                    continue
                (_, res, export, obs_export) = item
                cache.merge(export)
                _adopt_obs(obs_export)
                cycles[lb] = res.total_cycles
    if quarantined_labels:
        # a quarantined confirm task left its label without cycles; a
        # variant whose arch baseline itself vanished has nothing
        # comparable to rank against (cross-arch cycle counts are
        # different units) and is dropped with it
        confirm = [
            lb
            for lb in confirm
            if lb in cycles and f"{scored[lb].arch}/nvcc" in cycles
        ]
        if own_baseline not in confirm:
            raise WorkerCrashError(
                f"search cannot rank anything: the input-arch baseline "
                f"{own_baseline!r} was quarantined"
            )
    for label in confirm:
        scored[label].cycles = cycles[label]

    # stall attribution for the confirmed set: served from the merged
    # profiles table (the workers already ran the profiled engine)
    stall_profiles: Dict[str, StallProfile] = {}
    if config.profile:
        for label in confirm:
            stall_profiles[label] = cache.profile(kernels[label])

    # measured cost relative to the same arch's confirmed baseline — the
    # cross-arch-comparable ground truth mirroring ScoredVariant.rel
    def ratio(label: str) -> float:
        return cycles[label] / cycles[f"{scored[label].arch}/nvcc"]

    # exact ties go to the input arch's do-nothing baseline, then by label
    chosen = min(confirm, key=lambda lb: (ratio(lb), lb != own_baseline, lb))
    predictor_choice = min(
        scored.values(), key=lambda v: (v.rel, v.label != own_baseline, v.label)
    ).label
    agreement = ranking_agreement(
        {lb: scored[lb].rel for lb in confirm}, {lb: ratio(lb) for lb in confirm}
    )
    per_arch: Dict[str, str] = {}
    for lb in confirm:
        a = scored[lb].arch
        if a not in per_arch or (ratio(lb), lb) < (ratio(per_arch[a]), per_arch[a]):
            per_arch[a] = lb

    report = SearchReport(
        kernel_name=kernel.name,
        input_arch=own,
        chosen=chosen,
        predictor_choice=predictor_choice,
        baseline=own_baseline,
        space_size=space_size,
        explored=len(specs) + len(expand_specs),
        simulated=len(confirm),
        beam=beam_labels,
        agreement=agreement,
        variants=sorted(scored.values(), key=lambda v: (v.rel, v.label)),
        cycles=cycles,
        speedup=1.0 / ratio(chosen) if ratio(chosen) else 1.0,
        per_arch=per_arch,
        stall_profiles=stall_profiles,
        seconds=time.perf_counter() - t0,
    )
    winner = kernels[chosen]
    if config.verify == "chosen" and scored[chosen].stage in ("beam", "expand"):
        _verify_winner(bases[scored[chosen].arch], winner, chosen)
    # never hand back an alias of the caller's kernel or an anchor
    return SearchOutcome(
        kernel=winner.copy(),
        report=report,
        quarantined=sorted(quarantined_labels),
    )


def _verify_winner(base: Kernel, winner: Kernel, label: str) -> None:
    """The ``verify="chosen"`` deferred self-check: the one kernel a search
    ships gets the full schedule + dataflow-equivalence oracle (baselines
    and anchors are verified where they were built)."""
    from .isa import equivalent
    from .passes import PassVerificationError
    from .sched import verify_schedule

    errs = verify_schedule(winner)
    if errs:
        raise PassVerificationError(
            f"search winner {label!r} has schedule violations: {errs[:3]}"
        )
    if not equivalent(base, winner):
        raise PassVerificationError(
            f"search winner {label!r} is not dataflow-equivalent to its "
            f"arch baseline"
        )
