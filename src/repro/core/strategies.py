"""The pluggable spill-strategy registry.

RegDem's gains come from *choosing* among spill-code variants (paper §5.3),
and the predictor-guided search automates the choice — but until this
module the choosable space was hardwired: three candidate orderings as a
string tuple (``repro.core.candidates.STRATEGIES``) dispatched by
``if/elif``, one spill destination, one pass schedule.  A
:class:`Strategy` descriptor makes each point of that space a first-class
registered object:

* ``select``        the candidate-queue builder (ordering + filters);
* ``build``         the pass-pipeline factory: baseline kernel + register
                    target + option combo -> :class:`~repro.core.regdem.
                    RegDemResult`;
* ``options_cls``   the per-strategy options dataclass (what used to be
                    flat :class:`~repro.core.passes.RegDemOptions` knobs);
* ``option_combos`` the combos the search sweeps, probe combo first;
* ``options_label`` combo -> stable label suffix (cache keys, reports,
                    golden files);
* ``hints``         :class:`StrategyHints` the predictor uses to price a
                    demoted-slot access before anything is built;
* ``targets``       the per-strategy occupancy-cliff register ladder
                    (each family charges its own smem/register costs);
* ``archs``         optional arch allow-list (``None`` = every arch).

``candidates.make_candidates``, ``variants.make_variants_for``,
``SearchConfig``'s space enumeration, ``TranslationService.tune`` and the
benchmark harness all resolve strategies through :func:`get_strategy`, so
registering one new object widens every consumer at once.  The paper's
orderings (``static``/``cfg``/``conflict``) are registered under their
historical names with byte-identical candidate queues, option labels and
pipelines — existing cache keys, golden files and tuned containers stay
meaningful.

Three families from related work ship registered:

* ``warp_share``   warp-level register resource sharing (arXiv
  1503.05694): co-scheduled warps share a register-file-backed demoted-slot
  pool (``LDP``/``STP``, near-RF latency, zero shared-memory traffic);
  each warp is charged its pool share (``ceil(words/share)`` registers) by
  :class:`~repro.core.passes.PoolAnchorPass`.
* ``block_share``  scratchpad sharing across thread blocks (arXiv
  1607.03238): spill slots carved from the *per-SM* scratchpad pool other
  resident blocks leave unused (:class:`~repro.core.spillspace.
  CarveSpace`) — nothing lands in this block's own allocation, so the
  occupancy calculator never sees smem growth; a per-SM budget gates the
  demotion loop instead.
* ``compressed``   compressed spill slots (arXiv 2006.05693): spilled
  values packed to 2-byte slots (:class:`~repro.core.spillspace.
  CompressedSpace`) — half the smem footprint per word, paid for with one
  ``PCK``/``UPCK`` ALU op around every demoted store/load; only width-1
  registers are candidates (pairs keep full-precision lanes).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Tuple

from .candidates import order_candidates
from .passes import (
    CompactionPass,
    DemotionPass,
    Pass,
    PassPipeline,
    PoolAnchorPass,
    ProloguePass,
    RedundancyEliminationPass,
    RegDemOptions,
    ReserveRegistersPass,
    StallFixupPass,
)
from .regdem import RegDemResult, auto_targets, demote
from .spillspace import CarveSpace, CompressedSpace, WarpPoolSpace


# ---------------------------------------------------------------------------
# Per-strategy options dataclasses (satellite: knobs leave RegDemOptions)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PaperOptions:
    """The §3.4 knobs of the paper's candidate-ordering strategies."""

    bank_avoid: bool = True       # §3.4.1 RDV bank-conflict avoidance
    elim_redundant: bool = True   # §3.4.2 pass 1
    reschedule: bool = True       # §3.4.2 pass 2
    substitute: bool = True       # §3.4.2 pass 3

    def combo(self) -> Tuple[bool, bool, bool, bool]:
        return (self.bank_avoid, self.elim_redundant, self.reschedule, self.substitute)


@dataclass(frozen=True)
class WarpShareOptions:
    """Warp-level resource sharing (1503.05694) knobs."""

    share: int = 2                # co-scheduled warps sharing the slot pool
    elim_redundant: bool = True

    def combo(self) -> Tuple[int, bool]:
        return (self.share, self.elim_redundant)


@dataclass(frozen=True)
class BlockShareOptions(PaperOptions):
    """Cross-block scratchpad sharing (1607.03238) reuses the §3.4 knobs:
    the carve changes *where* slots live, not the demotion machinery."""


@dataclass(frozen=True)
class CompressedOptions:
    """Compressed spill slots (2006.05693) knobs.  Rescheduling and
    substitution are structurally off: the pack/unpack ops own the barrier
    protocol around every slot access."""

    bank_avoid: bool = True
    elim_redundant: bool = True

    def combo(self) -> Tuple[bool, bool]:
        return (self.bank_avoid, self.elim_redundant)


# ---------------------------------------------------------------------------
# The descriptor
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StrategyHints:
    """Predictor cost priors for one strategy, readable before any variant
    is built (:func:`repro.core.predictor.strategy_access_cost` prices a
    demoted-slot access from these; the search uses that price to break
    exact predictor ties toward the cheaper access path)."""

    #: per-thread shared-memory bytes one demoted word occupies in *this
    #: block's* allocation (4 = eq.-1 full word, 2 = compressed, 0 = not
    #: charged here)
    smem_bytes_per_word: int = 4
    #: architectural registers one demoted word costs (warp pools charge
    #: ``1/share``; everything else 0)
    reg_cost_per_word: float = 0.0
    #: extra fixed-latency ALU ops per demoted access (pack/unpack)
    access_overhead: int = 0
    #: :class:`repro.arch.registry.LatencyModel` attribute of the slot
    #: access path ("shared", "misc", "local", ...)
    latency_class: str = "shared"


@dataclass(frozen=True)
class Strategy:
    """One registered spill strategy (see module docstring for fields)."""

    name: str
    doc: str
    #: grouping for reports/histograms: "paper" for the §3.4.3 orderings,
    #: the family name itself for the related-work strategies
    family: str
    options_cls: type
    hints: StrategyHints
    #: Kernel -> ordered demotion queue [(leading_reg, width)]
    select: Callable[[object], List[Tuple[int, int]]]
    #: full_options -> option combos (tuples of primitives, probe first)
    option_combos: Callable[[bool], List[tuple]]
    #: combo -> stable label suffix, "<name>:<combo-encoding>"
    options_label: Callable[[tuple], str]
    #: (base, target, combo, verify=..., observer=...) -> RegDemResult
    build: Callable[..., RegDemResult]
    #: (base, max_targets) -> occupancy-cliff register ladder
    targets: Callable[[object, Optional[int]], List[int]]
    #: arch allow-list (canonical registry names); None = every arch
    archs: Optional[Tuple[str, ...]] = None


_REGISTRY: Dict[str, Strategy] = {}


def register_strategy(strategy: Strategy) -> Strategy:
    """Register ``strategy`` under its name; returns it.  Duplicate names
    are an error — strategies are identity-by-name everywhere (labels,
    cache keys, golden files), so silent replacement would corrupt all of
    them."""
    if strategy.name in _REGISTRY:
        raise ValueError(f"strategy {strategy.name!r} already registered")
    _REGISTRY[strategy.name] = strategy
    return strategy


def get_strategy(name: str) -> Strategy:
    """Resolve a strategy by registry name."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown strategy {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def strategy_names() -> List[str]:
    """Registered strategy names, in registration order (the paper's three
    first — the order the default search space enumerates)."""
    return list(_REGISTRY)


def strategies() -> List[Strategy]:
    """Registered strategies, in registration order."""
    return list(_REGISTRY.values())


# ---------------------------------------------------------------------------
# The paper's candidate-ordering strategies (§3.4.3), registered under
# their historical names with byte-identical behaviour
# ---------------------------------------------------------------------------


def _paper_combos(full: bool) -> List[Tuple[bool, bool, bool, bool]]:
    """The historical option grid: grouped Fig.-7 dimensions by default
    (bank avoidance x enhancement passes), all 2^4 flags when ``full``.
    Probe combo (all-on) first."""
    if full:
        return [
            (b, e, r, s)
            for b in (True, False)
            for e in (True, False)
            for r in (True, False)
            for s in (True, False)
        ]
    return [(b, e, e, e) for b in (True, False) for e in (True, False)]


def _bits(flags: tuple) -> str:
    return "".join("1" if f else "0" for f in flags)


def _paper_regdem_options(ordering: str, combo: tuple) -> RegDemOptions:
    bank, elim, resched, subst = combo
    return RegDemOptions(
        candidate_strategy=ordering,
        bank_avoid=bank,
        elim_redundant=elim,
        reschedule=resched,
        substitute=subst,
    )


def _register_paper(name: str, doc: str) -> Strategy:
    def select(kernel):
        return order_candidates(kernel, name)

    def label(combo: tuple) -> str:
        # byte-identical to RegDemOptions.label() — pinned by the
        # signature-stability tests
        return f"{name}:{_bits(combo)}"

    def build(base, target, combo, verify: str = "each", observer=None):
        opts = _paper_regdem_options(name, combo)
        return demote(base, target, opts, verify=verify, observer=observer)

    def targets(base, max_targets=None):
        return auto_targets(base, max_targets=max_targets)

    return register_strategy(
        Strategy(
            name=name,
            doc=doc,
            family="paper",
            options_cls=PaperOptions,
            hints=StrategyHints(),
            select=select,
            option_combos=_paper_combos,
            options_label=label,
            build=build,
            targets=targets,
        )
    )


_register_paper("static", "ascending static access count (§3.4.3)")
_register_paper("cfg", "CFG-weighted access count, loops x10 (§3.4.3)")
_register_paper("conflict", "ascending operand-conflict degree (§3.4.3)")


# ---------------------------------------------------------------------------
# warp_share — warp-level register resource sharing (arXiv 1503.05694)
# ---------------------------------------------------------------------------


def _warp_share_combos(full: bool) -> List[Tuple[int, bool]]:
    return [(2, True), (4, True), (2, False), (4, False)]


def _warp_share_label(combo: tuple) -> str:
    share, elim = combo
    return f"warp_share:s{share}e{int(elim)}"


def _warp_share_build(base, target, combo, verify: str = "each", observer=None):
    share, elim = combo
    opts = RegDemOptions(
        candidate_strategy="cfg",
        bank_avoid=True,
        elim_redundant=elim,
        reschedule=False,
        substitute=False,
    )
    passes: List[Pass] = [
        ReserveRegistersPass(bank_tune=True),
        ProloguePass(),
        DemotionPass(),
    ]
    if elim:
        passes.append(RedundancyEliminationPass())
    passes += [CompactionPass(), PoolAnchorPass(share), StallFixupPass()]
    return demote(
        base,
        target,
        opts,
        space=WarpPoolSpace(share),
        pipeline=PassPipeline(passes, verify=verify),
        observer=observer,
    )


def _warp_share_targets(base, max_targets=None):
    from repro.arch import arch_of

    from .occupancy import spill_targets

    # slots are register-file backed: zero smem per word, but each word
    # costs 1/share registers (the probe share of 2) — the ladder only
    # keeps cliffs that survive that charge
    targets = spill_targets(
        base.reg_count,
        base.threads_per_block,
        base.shared_size,
        sm=arch_of(base).sm,
        bytes_per_slot=0,
        reg_cost_per_word=0.5,
    )
    return targets if max_targets is None else targets[:max_targets]


register_strategy(
    Strategy(
        name="warp_share",
        doc="warp-level register resource sharing (arXiv 1503.05694)",
        family="warp_share",
        options_cls=WarpShareOptions,
        hints=StrategyHints(
            smem_bytes_per_word=0,
            reg_cost_per_word=0.5,
            access_overhead=0,
            latency_class="misc",
        ),
        select=lambda kernel: order_candidates(kernel, "cfg"),
        option_combos=_warp_share_combos,
        options_label=_warp_share_label,
        build=_warp_share_build,
        targets=_warp_share_targets,
    )
)


# ---------------------------------------------------------------------------
# block_share — scratchpad sharing across thread blocks (arXiv 1607.03238)
# ---------------------------------------------------------------------------


def _block_share_build(base, target, combo, verify: str = "each", observer=None):
    opts = _paper_regdem_options("cfg", combo)
    return demote(
        base, target, opts, verify=verify, space=CarveSpace(), observer=observer
    )


def _block_share_targets(base, max_targets=None):
    from repro.arch import arch_of

    from .occupancy import _ceil_to, spill_targets

    sm = arch_of(base).sm
    static = _ceil_to(base.shared_size, sm.smem_alloc_unit) if base.shared_size else 0

    def feasible(spilled: int, occ) -> bool:
        # every resident block needs its carve from the per-SM pool,
        # alongside every block's static allocation (1607.03238's budget)
        carve = spilled * base.threads_per_block * 4
        return occ.resident_blocks * (static + carve) <= sm.smem_bytes

    targets = spill_targets(
        base.reg_count,
        base.threads_per_block,
        base.shared_size,
        sm=sm,
        bytes_per_slot=0,       # nothing lands in this block's allocation
        feasible=feasible,
    )
    return targets if max_targets is None else targets[:max_targets]


register_strategy(
    Strategy(
        name="block_share",
        doc="cross-thread-block scratchpad sharing (arXiv 1607.03238)",
        family="block_share",
        options_cls=BlockShareOptions,
        hints=StrategyHints(
            smem_bytes_per_word=0,
            reg_cost_per_word=0.0,
            access_overhead=0,
            latency_class="shared",
        ),
        select=lambda kernel: order_candidates(kernel, "cfg"),
        option_combos=_paper_combos,
        options_label=lambda combo: f"block_share:{_bits(combo)}",
        build=_block_share_build,
        targets=_block_share_targets,
    )
)


# ---------------------------------------------------------------------------
# compressed — compressed spill slots (arXiv 2006.05693)
# ---------------------------------------------------------------------------


def _compressed_select(kernel) -> List[Tuple[int, int]]:
    # only width-1 registers compress (pairs keep full-precision lanes)
    return [(r, w) for r, w in order_candidates(kernel, "static") if w == 1]


def _compressed_combos(full: bool) -> List[Tuple[bool, bool]]:
    return [(True, True), (False, True), (True, False), (False, False)]


def _compressed_build(base, target, combo, verify: str = "each", observer=None):
    bank, elim = combo
    opts = RegDemOptions(
        candidate_strategy="static",
        bank_avoid=bank,
        elim_redundant=elim,
        reschedule=False,
        substitute=False,
    )
    return demote(
        base,
        target,
        opts,
        verify=verify,
        space=CompressedSpace(),
        select=_compressed_select,
        observer=observer,
    )


def _compressed_targets(base, max_targets=None):
    from repro.arch import arch_of

    from .occupancy import spill_targets

    arch = arch_of(base)
    targets = spill_targets(
        base.reg_count,
        base.threads_per_block,
        base.shared_size,
        available_smem=arch.smem_spill_limit - base.shared_size,
        sm=arch.sm,
        bytes_per_slot=CompressedSpace.SLOT_BYTES,
    )
    return targets if max_targets is None else targets[:max_targets]


register_strategy(
    Strategy(
        name="compressed",
        doc="compressed spill slots via static value compression (arXiv 2006.05693)",
        family="compressed",
        options_cls=CompressedOptions,
        hints=StrategyHints(
            smem_bytes_per_word=CompressedSpace.SLOT_BYTES,
            reg_cost_per_word=0.0,
            access_overhead=1,
            latency_class="shared",
        ),
        select=_compressed_select,
        option_combos=_compressed_combos,
        options_label=lambda combo: f"compressed:{_bits(combo)}",
        build=_compressed_build,
        targets=_compressed_targets,
    )
)
