"""pyReDe — the binary translator driver (paper §1, §5.1).

The paper's tool extracts SASS from a ``.cubin``, applies RegDem, and
re-inserts the code with MaxAs.  Here the "binary" is the textual rendering
of the abstract ISA; the driver exposes the same pipeline:

    parse -> choose targets -> transform (RegDem) -> self-check -> re-emit

The self-check runs the schedule verifier and the dataflow-equivalence
oracle on every emitted variant — a translated binary that fails either is
a translator bug, never a tolerated output.

``translate`` is the "automatic utility" of §3: it enumerates occupancy
cliffs, generates a RegDem variant per (target x option-combination), and
uses the §4 performance predictor to pick what to ship.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .candidates import STRATEGIES
from .isa import Kernel, equivalent, parse_kernel
from .occupancy import occupancy_of
from .predictor import predict
from .regdem import RegDemOptions, RegDemResult, auto_targets, demote
from .sched import verify_schedule


class TranslationError(RuntimeError):
    """Raised when a transformed binary fails self-checks."""


@dataclass
class TranslationReport:
    kernel_name: str
    baseline_regs: int
    chosen: str
    considered: List[str]
    predictions: Dict[str, float]
    results: Dict[str, RegDemResult] = field(default_factory=dict)

    @property
    def chosen_kernel(self) -> Kernel:
        if self.chosen == "nvcc":
            raise KeyError("baseline chosen; no transformed kernel")
        return self.results[self.chosen].kernel


def option_space(
    strategies: Tuple[str, ...] = STRATEGIES,
    full: bool = False,
) -> List[RegDemOptions]:
    """The optimization-option combinations the predictor searches.

    ``full`` sweeps all 2^4 flag combinations per strategy (the paper's
    exhaustive search); the default uses the grouped Fig.-7 dimensions
    (bank-conflict avoidance, performance-enhancement passes on/off).
    """
    out: List[RegDemOptions] = []
    if full:
        for strat in strategies:
            for b, e, r, s in itertools.product([False, True], repeat=4):
                out.append(
                    RegDemOptions(
                        candidate_strategy=strat,
                        bank_avoid=b,
                        elim_redundant=e,
                        reschedule=r,
                        substitute=s,
                    )
                )
    else:
        for strat in strategies:
            for bank in (False, True):
                for enh in (False, True):
                    out.append(
                        RegDemOptions(
                            candidate_strategy=strat,
                            bank_avoid=bank,
                            elim_redundant=enh,
                            reschedule=enh,
                            substitute=enh,
                        )
                    )
    return out


def self_check(original: Kernel, transformed: Kernel, label: str) -> None:
    errs = verify_schedule(transformed)
    if errs:
        raise TranslationError(f"{label}: schedule violations: {errs[:3]}")
    if not equivalent(original, transformed):
        raise TranslationError(f"{label}: dataflow mismatch vs original")


def translate(
    kernel: Kernel,
    target_regs: Optional[int] = None,
    options: Optional[List[RegDemOptions]] = None,
    use_predictor: bool = True,
) -> TranslationReport:
    """Run the full pyReDe pipeline on one kernel."""
    targets = [target_regs] if target_regs is not None else auto_targets(kernel)
    opts = options or option_space()

    variants: Dict[str, Kernel] = {"nvcc": kernel}
    results: Dict[str, RegDemResult] = {}
    ranks: Dict[str, int] = {"nvcc": 0}
    for tgt in targets:
        for opt in opts:
            label = f"regdem@{tgt}:{opt.label()}"
            res = demote(kernel, tgt, opt)
            self_check(kernel, res.kernel, label)
            variants[label] = res.kernel
            results[label] = res
            ranks[label] = sum(
                (opt.bank_avoid, opt.elim_redundant, opt.reschedule, opt.substitute)
            )

    if use_predictor and len(variants) > 1:
        best, preds = predict(variants, option_rank=ranks)
        predictions = {p.name: p.adjusted for p in preds}
    else:
        best = next(iter(results), "nvcc")
        predictions = {}

    return TranslationReport(
        kernel_name=kernel.name,
        baseline_regs=kernel.reg_count,
        chosen=best,
        considered=sorted(variants),
        predictions=predictions,
        results=results,
    )


def roundtrip(kernel: Kernel) -> Kernel:
    """Assembler/disassembler round trip (the MaxAs insertion step)."""
    text = kernel.render()
    k2 = parse_kernel(
        text,
        threads_per_block=kernel.threads_per_block,
        num_blocks=kernel.num_blocks,
        shared_size=kernel.shared_size,
        demoted_size=kernel.demoted_size,
        live_in=set(kernel.live_in),
        live_out=set(kernel.live_out),
    )
    k2.rda = kernel.rda
    if k2.render().splitlines()[1:] != text.splitlines()[1:]:
        raise TranslationError(f"{kernel.name}: unstable round trip")
    return k2
