"""pyReDe — the binary translator driver (paper §1, §5.1).

The paper's tool extracts SASS from a ``.cubin``, applies RegDem, and
re-inserts the code with MaxAs.  The same pipeline runs here on the
pseudo-cubin container of :mod:`repro.binary`:

    disassemble (loads) -> choose targets -> transform (pass pipeline)
        -> self-check -> reassemble (dumps)

``translate`` is bytes-in / bytes-out when handed container bytes — a true
binary->binary translator — and also accepts an in-memory :class:`Kernel`,
returning the full :class:`TranslationReport` for inspection.

Every variant is produced by the unified pass pipeline
(:mod:`repro.core.passes`), which runs the schedule verifier and the
dataflow-equivalence oracle per its ``verify`` policy — the service hot
path uses ``verify="final"`` (both checks once, after the last pass; output
is byte-identical to ``verify="each"``, regression-tested), and
``verify="each"`` remains available to fault-localize a broken pass; the
container round-trip oracle then guards every emitted binary.  A translated
binary that fails any of these is a translator bug, never a tolerated
output.  Per-pass diagnostics/timings surface in
:attr:`TranslationReport.pass_stats`.

``translate`` is the "automatic utility" of §3: it enumerates occupancy
cliffs, generates a RegDem variant per (target x option-combination), and
uses the §4 performance predictor to pick what to ship.

At the service layer, :class:`TranslationService` makes the translator a
**batch, cached, multi-kernel** pipeline: :func:`translate_binary` accepts a
multi-kernel container (format v2), translates every kernel in it, and keys
a :class:`TranslationCache` by per-kernel content CRC
(:func:`repro.binary.container.kernel_crc`) plus the translation parameters,
so a repeated kernel is served byte-identically with zero pipeline passes
run.
"""

from __future__ import annotations

import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from repro import obs
from repro.obs import Histogram
from repro.obs import hit_rate as _hit_rate

from .candidates import STRATEGIES
from .isa import Kernel, equivalent, parse_kernel
from .passes import PassStat, PassVerificationError
from .predictor import predict
from .regdem import RegDemOptions, RegDemResult, auto_targets, demote
from .sched import verify_schedule
from .search import SearchConfig, SearchReport, search


class TranslationError(RuntimeError):
    """Raised when a transformed binary fails self-checks."""


class DegradedSearchError(TranslationError):
    """An autotuning search completed only by quarantining crashed tasks.

    The reduced-space winner is verified-correct, but it is **not** the
    fault-free search result, so the strict service layer refuses to cache
    or serve it silently.  The translation daemon catches this and applies
    its degradation policy (retry, then serve the nvcc baseline flagged
    ``degraded``)."""


@dataclass
class TranslationReport:
    kernel_name: str
    baseline_regs: int
    chosen: str
    considered: List[str]
    predictions: Dict[str, float]
    results: Dict[str, RegDemResult] = field(default_factory=dict)
    #: per-pass diagnostics/timings per considered variant label
    pass_stats: Dict[str, List[PassStat]] = field(default_factory=dict)
    #: autotuning search report when this translation came from
    #: :meth:`TranslationService.tune` (``predictions`` then holds each
    #: variant's baseline-relative predicted cost)
    search: Optional[SearchReport] = None

    @property
    def chosen_kernel(self) -> Kernel:
        if self.chosen == "nvcc":
            raise KeyError("baseline chosen; no transformed kernel")
        return self.results[self.chosen].kernel

    @property
    def total_pipeline_seconds(self) -> float:
        """Wall time spent inside transformation passes for this kernel."""
        return sum(p.seconds for stats in self.pass_stats.values() for p in stats)


def option_space(
    strategies: Tuple[str, ...] = STRATEGIES,
    full: bool = False,
) -> List[RegDemOptions]:
    """The optimization-option combinations the predictor searches.

    ``full`` sweeps all 2^4 flag combinations per strategy (the paper's
    exhaustive search); the default uses the grouped Fig.-7 dimensions
    (bank-conflict avoidance, performance-enhancement passes on/off).

    Strategy names resolve through the registry
    (:func:`repro.core.strategies.get_strategy` — unknown names get the
    helpful listing error).  The fixed ``translate`` pipeline builds
    :class:`RegDemOptions`, which only the paper's ordering strategies
    carry; the related-work families are searched via
    :meth:`TranslationService.tune` / :func:`repro.core.search.search`.
    """
    from .strategies import get_strategy

    for strat in strategies:
        s = get_strategy(strat)
        if s.family != "paper":
            raise ValueError(
                f"strategy {strat!r} (family {s.family!r}) has no "
                "RegDemOptions grid; the fixed translate pipeline covers "
                "the paper orderings only — search the related-work "
                "families via TranslationService.tune / repro.core.search"
            )
    out: List[RegDemOptions] = []
    if full:
        for strat in strategies:
            for b, e, r, s in itertools.product([False, True], repeat=4):
                out.append(
                    RegDemOptions(
                        candidate_strategy=strat,
                        bank_avoid=b,
                        elim_redundant=e,
                        reschedule=r,
                        substitute=s,
                    )
                )
    else:
        for strat in strategies:
            for bank in (False, True):
                for enh in (False, True):
                    out.append(
                        RegDemOptions(
                            candidate_strategy=strat,
                            bank_avoid=bank,
                            elim_redundant=enh,
                            reschedule=enh,
                            substitute=enh,
                        )
                    )
    return out


def self_check(original: Kernel, transformed: Kernel, label: str) -> None:
    """Schedule + dataflow validation of one transformed kernel (the same
    checks the pass pipeline applies after every pass)."""
    errs = verify_schedule(transformed)
    if errs:
        raise TranslationError(f"{label}: schedule violations: {errs[:3]}")
    if not equivalent(original, transformed):
        raise TranslationError(f"{label}: dataflow mismatch vs original")


def translate(
    kernel: Union[Kernel, bytes, bytearray, memoryview],
    target_regs: Optional[int] = None,
    options: Optional[List[RegDemOptions]] = None,
    use_predictor: bool = True,
    verify: str = "final",
) -> Union[TranslationReport, bytes]:
    """Run the full pyReDe pipeline on one kernel.

    Given a :class:`Kernel`, returns the :class:`TranslationReport`.  Given
    pseudo-cubin container bytes (:func:`repro.binary.dumps`), runs the same
    pipeline binary->binary — over *every* kernel in the container — and
    returns the container bytes of the chosen variants, the paper's actual
    tool shape.

    ``verify`` is the pass-pipeline self-check policy (default ``"final"``:
    schedule + dataflow checks once per variant pipeline, byte-identical
    output to ``"each"``).
    """
    if isinstance(kernel, (bytes, bytearray, memoryview)):
        out, _ = translate_binary(
            bytes(kernel),
            target_regs=target_regs,
            options=options,
            use_predictor=use_predictor,
            verify=verify,
        )
        return out
    targets = [target_regs] if target_regs is not None else auto_targets(kernel)
    opts = options or option_space()

    variants: Dict[str, Kernel] = {"nvcc": kernel}
    results: Dict[str, RegDemResult] = {}
    ranks: Dict[str, int] = {"nvcc": 0}
    pass_stats: Dict[str, List[PassStat]] = {}
    for tgt in targets:
        for opt in opts:
            label = f"regdem@{tgt}:{opt.label()}"
            # the pipeline self-checks schedule validity and dataflow
            # equivalence per the verify policy; surface failures under the
            # translator's exception type
            try:
                res = demote(kernel, tgt, opt, verify=verify)
            except PassVerificationError as exc:
                raise TranslationError(f"{label}: {exc}") from exc
            variants[label] = res.kernel
            results[label] = res
            pass_stats[label] = res.passes
            ranks[label] = sum(
                (opt.bank_avoid, opt.elim_redundant, opt.reschedule, opt.substitute)
            )

    if use_predictor and len(variants) > 1:
        best, preds = predict(variants, option_rank=ranks)
        predictions = {p.name: p.adjusted for p in preds}
    else:
        best = next(iter(results), "nvcc")
        predictions = {}

    return TranslationReport(
        kernel_name=kernel.name,
        baseline_regs=kernel.reg_count,
        chosen=best,
        considered=sorted(variants),
        predictions=predictions,
        results=results,
        pass_stats=pass_stats,
    )


# ---------------------------------------------------------------------------
# The batch, cached, multi-kernel binary-translation service
# ---------------------------------------------------------------------------


class TranslationCache:
    """Content-CRC-keyed cache of finished translations.

    The key is ``(kernel_crc(kernel), target_regs, option labels,
    use_predictor)`` — everything that determines the translator's output.
    Because a 32-bit CRC can collide, every entry also stores the input
    kernel's rendering and a hit is only served when it matches — a
    colliding kernel is treated as a miss, never given another kernel's
    translation.  A hit returns a *copy* of the chosen kernel (callers may
    mutate it), whose re-serialization is byte-identical to the original
    translation, plus the original :class:`TranslationReport`.  The report
    object is **shared** between the original miss and every later hit:
    treat it as read-only.  No pipeline pass runs on a hit.

    With a persistent ``store`` (:class:`~repro.core.artifacts.
    ArtifactStore`), finished translations **spill to disk** and survive
    process restarts: an in-memory miss falls through to the store, and a
    verified disk entry — chosen-kernel container bytes plus a summary
    report, input-render collision guard intact — is warm-loaded with zero
    pipeline passes run, byte-identical to the original translation, and
    counted in :attr:`disk_hits`.  Warm-loaded reports are **summaries**:
    ``results``/``pass_stats`` are empty (the per-variant kernels were
    never persisted), but ``chosen``/``considered``/``predictions`` and a
    tune's :attr:`TranslationReport.search` (with byte-stable ``to_json``)
    are intact — everything the serving path reads.
    """

    def __init__(self, max_entries: Optional[int] = None, store=None):
        self._entries: Dict[tuple, Tuple[str, Kernel, TranslationReport]] = {}
        self.max_entries = max_entries
        #: optional repro.core.artifacts.ArtifactStore persistence tier
        self.store = store
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.disk_hits = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def hit_rate(self) -> float:
        """Hit fraction; raises :class:`ValueError` before any access (a
        rate over zero traffic is undefined, not 0%)."""
        return _hit_rate(self.hits, self.misses)

    def stats(self) -> Dict[str, float]:
        out = {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "capacity": self.max_entries,
            "entries": len(self._entries),
            "hit_rate": round(_hit_rate(self.hits, self.misses, default=0.0), 3),
            "disk_hits": self.disk_hits,
        }
        if self.store is not None:
            out["disk_hit_rate"] = self.store.stats()["hit_rate"]
        return out

    @staticmethod
    def content_crc(kernel: Kernel) -> int:
        # kernels decoded from a v2 container carry their verified content
        # CRC; recompute (one text encode) only for v1/in-memory kernels
        crc = getattr(kernel, "content_crc", None)
        if crc is None:
            from repro.binary.container import kernel_crc

            crc = kernel_crc(kernel)
        return crc

    @staticmethod
    def key(
        kernel: Kernel,
        target_regs: Optional[int],
        options: Optional[List[RegDemOptions]],
        use_predictor: bool,
    ) -> tuple:
        opt_sig = None if options is None else tuple(o.label() for o in options)
        return (TranslationCache.content_crc(kernel), target_regs, opt_sig, use_predictor)

    @staticmethod
    def tune_key(kernel: Kernel, config: SearchConfig) -> tuple:
        """Cache key for :meth:`TranslationService.tune` results: content CRC
        plus everything that determines the search outcome.  The pool size is
        not in :meth:`SearchConfig.signature`, so a result tuned with one
        worker is a hit for a later N-worker call (and vice versa)."""
        return (TranslationCache.content_crc(kernel), "tune", config.signature())

    @staticmethod
    def _store_key(key: tuple) -> str:
        """Stable string address of one cache key for the artifact store
        (the tuples hold only ints/strings/bools/None, whose ``repr`` is
        deterministic across processes)."""
        return f"translation:{key!r}"

    @staticmethod
    def _report_to_json(report: TranslationReport) -> dict:
        """The persistable summary of a report (per-variant kernels and
        pass stats are deliberately not spilled — only what serving reads)."""
        return {
            "kernel_name": report.kernel_name,
            "baseline_regs": report.baseline_regs,
            "chosen": report.chosen,
            "considered": list(report.considered),
            "predictions": dict(report.predictions),
            "search": None if report.search is None else report.search.to_json(),
        }

    @staticmethod
    def _report_from_json(data: dict) -> TranslationReport:
        search = None
        if data.get("search") is not None:
            search = SearchReport.from_json(data["search"])
        return TranslationReport(
            kernel_name=data["kernel_name"],
            baseline_regs=data["baseline_regs"],
            chosen=data["chosen"],
            considered=list(data["considered"]),
            predictions=dict(data["predictions"]),
            search=search,
        )

    def _disk_get(
        self, key: tuple, kernel: Kernel
    ) -> Optional[Tuple[Kernel, TranslationReport]]:
        """Warm-load one entry from the persistent store (in-memory miss
        path).  Every byte served was CRC-verified by the store this call;
        the input-render guard and a full container decode re-verify the
        translation-level invariants on top.  A verified load also
        repopulates the in-memory table, so the next hit is memory-speed."""
        entry = self.store.get(self._store_key(key))
        if entry is None:
            return None
        payload, meta = entry
        if meta.get("input_render") != kernel.render():
            return None  # CRC collision or stale schema: recompute
        try:
            from repro.binary import container

            chosen = container.loads(payload)
            report = self._report_from_json(meta["report"])
        except Exception:
            # an entry the store verified but this code version cannot
            # decode (e.g. written by a newer schema) is a miss, not a crash
            return None
        self._entries[key] = (meta["input_render"], chosen.copy(), report)
        self.disk_hits += 1
        if obs.enabled():
            obs.metrics().counter("translation_cache.disk_hits").inc()
        return chosen.copy(), report

    def get(self, key: tuple, kernel: Kernel) -> Optional[Tuple[Kernel, TranslationReport]]:
        entry = self._entries.get(key)
        if entry is not None:
            input_render, chosen, report = entry
            if input_render == kernel.render():
                self.hits += 1
                if obs.enabled():
                    obs.metrics().counter("translation_cache.hits").inc()
                return chosen.copy(), report
        if self.store is not None:
            warm = self._disk_get(key, kernel)
            if warm is not None:
                self.hits += 1
                if obs.enabled():
                    obs.metrics().counter("translation_cache.hits").inc()
                return warm
        self.misses += 1
        if obs.enabled():
            obs.metrics().counter("translation_cache.misses").inc()
        return None

    def put(self, key: tuple, kernel: Kernel, chosen: Kernel, report: TranslationReport) -> None:
        if self.max_entries is not None and len(self._entries) >= self.max_entries:
            # drop the oldest entry (insertion order) — simple FIFO bound
            self._entries.pop(next(iter(self._entries)))
            self.evictions += 1
            if obs.enabled():
                obs.metrics().counter("translation_cache.evictions").inc()
        self._entries[key] = (kernel.render(), chosen.copy(), report)
        if self.store is not None:
            from repro.binary import container

            self.store.put(
                self._store_key(key),
                container.dumps(chosen),
                meta={
                    "input_render": kernel.render(),
                    "report": self._report_to_json(report),
                },
            )


@dataclass
class BatchTranslationReport:
    """Outcome of one batch translation: per-kernel reports + cache telemetry.

    ``reports`` entries for cached kernels are the *shared* report objects
    from the original translation — read, don't mutate."""

    reports: List[TranslationReport]
    #: per kernel, whether it was served from the translation cache
    cached: List[bool]
    cache_hits: int
    cache_misses: int

    @property
    def kernel_names(self) -> List[str]:
        return [r.kernel_name for r in self.reports]

    @property
    def hit_rate(self) -> float:
        # an empty batch reports 0.0 (display convention, not a decision)
        return _hit_rate(self.cache_hits, self.cache_misses, default=0.0)


class TranslationService:
    """Batch, cached, multi-kernel binary-translation service.

    Holds one :class:`TranslationCache` across calls; feed it multi-kernel
    (or single-kernel) container bytes and it translates every kernel,
    serving repeated content from the cache without running a single
    pipeline pass.
    """

    def __init__(
        self,
        target_regs: Optional[int] = None,
        options: Optional[List[RegDemOptions]] = None,
        use_predictor: bool = True,
        cache: Optional[TranslationCache] = None,
        verify: str = "final",
        store=None,
    ):
        if store is not None and cache is not None:
            raise ValueError(
                "pass either a cache (optionally built with store=...) or a "
                "store, not both"
            )
        self.target_regs = target_regs
        self.options = options
        self.use_predictor = use_predictor
        self.cache = cache if cache is not None else TranslationCache(store=store)
        #: pass-pipeline self-check policy ("final" on the serving hot path;
        #: byte-identical output to "each" — regression-tested)
        self.verify = verify
        # service-level metrics stay always-on (one histogram append per
        # call — nothing per instruction); they are the payload of the
        # planned daemon /metrics endpoint (ROADMAP open item 1)
        self._translate_ms = Histogram()
        self._kernels_done = 0
        self._busy_seconds = 0.0

    def _record_call(self, n_kernels: int, seconds: float) -> None:
        self._translate_ms.observe(seconds * 1e3)
        self._kernels_done += n_kernels
        self._busy_seconds += seconds
        if obs.enabled():
            reg = obs.metrics()
            reg.counter("service.kernels").inc(n_kernels)
            reg.histogram("service.translate_ms").observe(seconds * 1e3)

    @property
    def kernels_per_second(self) -> float:
        """Lifetime service throughput: kernels translated per busy second
        (wall time inside translate/tune calls, idle time excluded)."""
        return self._kernels_done / self._busy_seconds if self._busy_seconds else 0.0

    def metrics_snapshot(self) -> Dict[str, object]:
        """The service's health as one plain dict: call latency distribution
        (p50/p99), throughput, and translation-cache telemetry — the shape
        the future translation daemon will serve from its metrics endpoint."""
        snap = {
            "calls": self._translate_ms.count,
            "kernels": self._kernels_done,
            "kernels_per_s": round(self.kernels_per_second, 3),
            "translate_ms": self._translate_ms.snapshot(),
            "cache": self.cache.stats(),
        }
        if self.cache.store is not None:
            snap["store"] = self.cache.store.stats()
        return snap

    def translate(self, data: bytes) -> Tuple[bytes, BatchTranslationReport]:
        """Container bytes in, container bytes out, every kernel translated."""
        from repro.binary import container
        from repro.binary.roundtrip import RoundTripError, verified_dumps_many

        t_call = time.perf_counter()
        kernels = container.loads_many(data)
        hits0, misses0 = self.cache.hits, self.cache.misses
        chosen_list: List[Kernel] = []
        reports: List[TranslationReport] = []
        cached_flags: List[bool] = []
        with obs.span("service.translate", kernels=len(kernels)):
            for kernel in kernels:
                with obs.span("translate", kernel=kernel.name) as sp:
                    key = self.cache.key(
                        kernel, self.target_regs, self.options, self.use_predictor
                    )
                    entry = self.cache.get(key, kernel)
                    if entry is not None:
                        chosen, report = entry
                        cached_flags.append(True)
                    else:
                        report = translate(
                            kernel,
                            target_regs=self.target_regs,
                            options=self.options,
                            use_predictor=self.use_predictor,
                            verify=self.verify,
                        )
                        chosen = kernel if report.chosen == "nvcc" else report.chosen_kernel
                        self.cache.put(key, kernel, chosen, report)
                        cached_flags.append(False)
                    sp.set(cached=cached_flags[-1], chosen=report.chosen)
                chosen_list.append(chosen)
                reports.append(report)

            try:
                out = verified_dumps_many(chosen_list)
            except RoundTripError as exc:
                raise TranslationError(str(exc)) from exc
        self._record_call(len(kernels), time.perf_counter() - t_call)
        return out, BatchTranslationReport(
            reports=reports,
            cached=cached_flags,
            cache_hits=self.cache.hits - hits0,
            cache_misses=self.cache.misses - misses0,
        )

    def tune(
        self, data: bytes, config: Optional[SearchConfig] = None
    ) -> Tuple[bytes, BatchTranslationReport]:
        """Autotune every kernel in the container (:func:`repro.core.search.
        search`) instead of the fixed predictor-only pipeline.

        Each kernel comes back as its best-found variant; the per-kernel
        :class:`~repro.core.search.SearchReport` rides in the emitted
        container as a ``.note.search.<index>.<name>`` JSON section
        (:func:`repro.binary.container.read_notes`) and on
        :attr:`TranslationReport.search`.  Results are served from the same
        :class:`TranslationCache` as plain translations, keyed by content CRC
        + search signature: re-tuning known content runs **zero** pipeline
        passes and re-emits byte-identical container bytes.
        """
        import json

        from repro.binary import container
        from repro.binary.roundtrip import RoundTripError, verified_dumps_many

        config = config or SearchConfig()
        t_call = time.perf_counter()
        kernels = container.loads_many(data)
        hits0, misses0 = self.cache.hits, self.cache.misses
        chosen_list: List[Kernel] = []
        reports: List[TranslationReport] = []
        cached_flags: List[bool] = []
        notes: Dict[str, bytes] = {}
        with obs.span("service.tune", kernels=len(kernels)):
            for i, kernel in enumerate(kernels):
                with obs.span("tune", kernel=kernel.name) as sp:
                    key = self.cache.tune_key(kernel, config)
                    entry = self.cache.get(key, kernel)
                    if entry is not None:
                        chosen, report = entry
                        cached_flags.append(True)
                    else:
                        outcome = search(kernel, config)
                        if outcome.quarantined:
                            # crashed-and-quarantined tasks shrank the
                            # search space: the result is not the fault-free
                            # one, so never cache or silently serve it
                            raise DegradedSearchError(
                                f"{kernel.name}: search quarantined "
                                f"{len(outcome.quarantined)} crashed task(s) "
                                f"({outcome.quarantined[:3]}); result is not "
                                "the fault-free search outcome"
                            )
                        report = TranslationReport(
                            kernel_name=kernel.name,
                            baseline_regs=kernel.reg_count,
                            chosen=outcome.report.chosen,
                            considered=sorted(v.label for v in outcome.report.variants),
                            predictions={
                                v.label: v.rel for v in outcome.report.variants
                            },
                            search=outcome.report,
                        )
                        chosen = outcome.kernel
                        self.cache.put(key, kernel, chosen, report)
                        cached_flags.append(False)
                    sp.set(cached=cached_flags[-1], chosen=report.chosen)
                chosen_list.append(chosen)
                reports.append(report)
                # SearchReport.to_json is deterministic (no wall times), so a
                # cache-hit re-tune emits byte-identical notes
                notes[f"search.{i}.{kernel.name}"] = json.dumps(
                    report.search.to_json(), sort_keys=True
                ).encode("utf-8")

            try:
                out = verified_dumps_many(chosen_list, notes=notes)
            except RoundTripError as exc:
                raise TranslationError(str(exc)) from exc
        self._record_call(len(kernels), time.perf_counter() - t_call)
        return out, BatchTranslationReport(
            reports=reports,
            cached=cached_flags,
            cache_hits=self.cache.hits - hits0,
            cache_misses=self.cache.misses - misses0,
        )


def translate_binary(
    data: bytes,
    target_regs: Optional[int] = None,
    options: Optional[List[RegDemOptions]] = None,
    use_predictor: bool = True,
    cache: Optional[TranslationCache] = None,
    verify: str = "final",
    tune: bool = False,
    search_config: Optional[SearchConfig] = None,
) -> Tuple[bytes, Union[TranslationReport, BatchTranslationReport]]:
    """Binary->binary pyReDe: container bytes in, container bytes out.

    Disassembles the container, runs the pass pipeline on **every** kernel
    in it (with an optional shared :class:`TranslationCache`), and
    reassembles the chosen variants (the unmodified input kernel where the
    predictor keeps the nvcc baseline).  The emitted container passes the
    round-trip oracle before being returned.

    ``tune=True`` routes through :meth:`TranslationService.tune`: the full
    predictor-guided autotuning search (``search_config``, default
    :class:`~repro.core.search.SearchConfig`) replaces the fixed pipeline,
    and each kernel's search report is embedded as a container note.

    For a single-kernel container the second return value is that kernel's
    :class:`TranslationReport` (the historical contract); for a multi-kernel
    container it is the :class:`BatchTranslationReport`.
    """
    service = TranslationService(
        target_regs=target_regs,
        options=options,
        use_predictor=use_predictor,
        cache=cache,
        verify=verify,
    )
    if tune:
        # the search replaces the fixed pipeline wholesale: silently
        # accepting its knobs would let a caller believe a constraint took
        # effect when it did not
        if target_regs is not None or options is not None or not use_predictor:
            raise ValueError(
                "tune=True replaces the fixed pipeline; target_regs/options/"
                "use_predictor do not apply — configure search_config instead"
            )
        if search_config is None:
            # the default translate verify ("final") maps to the search's
            # own default ("chosen": verify the winner once); an explicit
            # non-default policy is honoured per variant
            search_config = (
                SearchConfig() if verify == "final" else SearchConfig(verify=verify)
            )
        elif verify != "final" and verify != search_config.verify:
            raise ValueError(
                "conflicting verify policies: pass verify through "
                "search_config when tuning"
            )
        out, batch = service.tune(data, search_config)
    else:
        out, batch = service.translate(data)
    if len(batch.reports) == 1:
        return out, batch.reports[0]
    return out, batch


def roundtrip(kernel: Kernel) -> Kernel:
    """Assembler/disassembler round trip (the MaxAs insertion step).

    Pushes the kernel through *both* codecs — the textual SASS rendering and
    the binary container — and demands they agree: an instability in either
    direction is a translator bug.
    """
    text = kernel.render()
    k2 = parse_kernel(
        text,
        threads_per_block=kernel.threads_per_block,
        num_blocks=kernel.num_blocks,
        shared_size=kernel.shared_size,
        demoted_size=kernel.demoted_size,
        live_in=set(kernel.live_in),
        live_out=set(kernel.live_out),
        arch=kernel.arch,
    )
    k2.rda = kernel.rda
    if k2.render().splitlines()[1:] != text.splitlines()[1:]:
        raise TranslationError(f"{kernel.name}: unstable text round trip")
    from repro.binary.roundtrip import RoundTripError, check_roundtrip

    # check_roundtrip's render-identity check is the cross-codec agreement:
    # the decoded kernel re-renders to the exact text parsed above.
    try:
        return check_roundtrip(kernel, check_semantics=False)
    except RoundTripError as exc:
        raise TranslationError(str(exc)) from exc
