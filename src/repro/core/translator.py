"""pyReDe — the binary translator driver (paper §1, §5.1).

The paper's tool extracts SASS from a ``.cubin``, applies RegDem, and
re-inserts the code with MaxAs.  The same pipeline runs here on the
pseudo-cubin container of :mod:`repro.binary`:

    disassemble (loads) -> choose targets -> transform (RegDem)
        -> self-check -> reassemble (dumps)

``translate`` is bytes-in / bytes-out when handed container bytes — a true
binary->binary translator — and also accepts an in-memory :class:`Kernel`,
returning the full :class:`TranslationReport` for inspection.

The self-check runs the schedule verifier and the dataflow-equivalence
oracle on every emitted variant, and the container round-trip oracle on
every emitted binary — a translated binary that fails any of these is a
translator bug, never a tolerated output.

``translate`` is the "automatic utility" of §3: it enumerates occupancy
cliffs, generates a RegDem variant per (target x option-combination), and
uses the §4 performance predictor to pick what to ship.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from .candidates import STRATEGIES
from .isa import Kernel, equivalent, parse_kernel
from .occupancy import occupancy_of
from .predictor import predict
from .regdem import RegDemOptions, RegDemResult, auto_targets, demote
from .sched import verify_schedule


class TranslationError(RuntimeError):
    """Raised when a transformed binary fails self-checks."""


@dataclass
class TranslationReport:
    kernel_name: str
    baseline_regs: int
    chosen: str
    considered: List[str]
    predictions: Dict[str, float]
    results: Dict[str, RegDemResult] = field(default_factory=dict)

    @property
    def chosen_kernel(self) -> Kernel:
        if self.chosen == "nvcc":
            raise KeyError("baseline chosen; no transformed kernel")
        return self.results[self.chosen].kernel


def option_space(
    strategies: Tuple[str, ...] = STRATEGIES,
    full: bool = False,
) -> List[RegDemOptions]:
    """The optimization-option combinations the predictor searches.

    ``full`` sweeps all 2^4 flag combinations per strategy (the paper's
    exhaustive search); the default uses the grouped Fig.-7 dimensions
    (bank-conflict avoidance, performance-enhancement passes on/off).
    """
    out: List[RegDemOptions] = []
    if full:
        for strat in strategies:
            for b, e, r, s in itertools.product([False, True], repeat=4):
                out.append(
                    RegDemOptions(
                        candidate_strategy=strat,
                        bank_avoid=b,
                        elim_redundant=e,
                        reschedule=r,
                        substitute=s,
                    )
                )
    else:
        for strat in strategies:
            for bank in (False, True):
                for enh in (False, True):
                    out.append(
                        RegDemOptions(
                            candidate_strategy=strat,
                            bank_avoid=bank,
                            elim_redundant=enh,
                            reschedule=enh,
                            substitute=enh,
                        )
                    )
    return out


def self_check(original: Kernel, transformed: Kernel, label: str) -> None:
    errs = verify_schedule(transformed)
    if errs:
        raise TranslationError(f"{label}: schedule violations: {errs[:3]}")
    if not equivalent(original, transformed):
        raise TranslationError(f"{label}: dataflow mismatch vs original")


def translate(
    kernel: Union[Kernel, bytes, bytearray, memoryview],
    target_regs: Optional[int] = None,
    options: Optional[List[RegDemOptions]] = None,
    use_predictor: bool = True,
) -> Union[TranslationReport, bytes]:
    """Run the full pyReDe pipeline on one kernel.

    Given a :class:`Kernel`, returns the :class:`TranslationReport`.  Given
    pseudo-cubin container bytes (:func:`repro.binary.dumps`), runs the same
    pipeline binary->binary and returns the container bytes of the chosen
    variant — the paper's actual tool shape.
    """
    if isinstance(kernel, (bytes, bytearray, memoryview)):
        out, _ = translate_binary(
            bytes(kernel),
            target_regs=target_regs,
            options=options,
            use_predictor=use_predictor,
        )
        return out
    targets = [target_regs] if target_regs is not None else auto_targets(kernel)
    opts = options or option_space()

    variants: Dict[str, Kernel] = {"nvcc": kernel}
    results: Dict[str, RegDemResult] = {}
    ranks: Dict[str, int] = {"nvcc": 0}
    for tgt in targets:
        for opt in opts:
            label = f"regdem@{tgt}:{opt.label()}"
            res = demote(kernel, tgt, opt)
            self_check(kernel, res.kernel, label)
            variants[label] = res.kernel
            results[label] = res
            ranks[label] = sum(
                (opt.bank_avoid, opt.elim_redundant, opt.reschedule, opt.substitute)
            )

    if use_predictor and len(variants) > 1:
        best, preds = predict(variants, option_rank=ranks)
        predictions = {p.name: p.adjusted for p in preds}
    else:
        best = next(iter(results), "nvcc")
        predictions = {}

    return TranslationReport(
        kernel_name=kernel.name,
        baseline_regs=kernel.reg_count,
        chosen=best,
        considered=sorted(variants),
        predictions=predictions,
        results=results,
    )


def translate_binary(
    data: bytes,
    target_regs: Optional[int] = None,
    options: Optional[List[RegDemOptions]] = None,
    use_predictor: bool = True,
) -> Tuple[bytes, TranslationReport]:
    """Binary->binary pyReDe: container bytes in, container bytes out.

    Disassembles the single-kernel container, runs :func:`translate`, and
    reassembles the chosen variant (the unmodified input kernel when the
    predictor keeps the nvcc baseline).  The emitted container passes the
    round-trip oracle before being returned.
    """
    from repro.binary import container
    from repro.binary.roundtrip import RoundTripError, verified_dumps

    kernel = container.loads(data)
    report = translate(
        kernel,
        target_regs=target_regs,
        options=options,
        use_predictor=use_predictor,
    )
    chosen = kernel if report.chosen == "nvcc" else report.chosen_kernel
    try:
        out = verified_dumps(chosen)
    except RoundTripError as exc:
        raise TranslationError(str(exc)) from exc
    return out, report


def roundtrip(kernel: Kernel) -> Kernel:
    """Assembler/disassembler round trip (the MaxAs insertion step).

    Pushes the kernel through *both* codecs — the textual SASS rendering and
    the binary container — and demands they agree: an instability in either
    direction is a translator bug.
    """
    text = kernel.render()
    k2 = parse_kernel(
        text,
        threads_per_block=kernel.threads_per_block,
        num_blocks=kernel.num_blocks,
        shared_size=kernel.shared_size,
        demoted_size=kernel.demoted_size,
        live_in=set(kernel.live_in),
        live_out=set(kernel.live_out),
    )
    k2.rda = kernel.rda
    if k2.render().splitlines()[1:] != text.splitlines()[1:]:
        raise TranslationError(f"{kernel.name}: unstable text round trip")
    from repro.binary.roundtrip import RoundTripError, check_roundtrip

    # check_roundtrip's render-identity check is the cross-codec agreement:
    # the decoded kernel re-renders to the exact text parsed above.
    try:
        return check_roundtrip(kernel, check_semantics=False)
    except RoundTripError as exc:
        raise TranslationError(str(exc)) from exc
