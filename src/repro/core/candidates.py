"""Candidate-register selection for demotion (paper §3.4.3).

Three strategies, each estimating register access counts; candidates are
chosen in *ascending* order of the estimate (cheapest-to-demote first):

* ``static``   one pass over the assembly, counting static accesses;
* ``cfg``      per-basic-block counts, blocks inside loops weighted x10;
* ``conflict`` ascending number of operand conflicts (ties: static count).

Excluded from candidacy: live-in/live-out (ABI) registers, RZ, and the odd
alias words of 64-bit pairs (pairs are demoted through their leading word).
"""

from __future__ import annotations

from typing import Dict, List, Set, Tuple

from .isa import CFG, RZ, Kernel

STRATEGIES = ("static", "cfg", "conflict")

#: Generic loop weight (paper §3.4.3 / §4: "a generic value of 10").
LOOP_FACTOR = 10


def width_map(kernel: Kernel) -> Dict[int, int]:
    """reg -> operand width (2 for 64-bit pairs), by leading register.

    Merges the per-instruction ``width_entries`` (cached on the instruction
    alongside its operand words: address operands of memory ops contribute
    width 1, everything else its opcode width) with ``max`` — the demotion
    pipeline recomputes this map after every mutation, so only instructions
    actually touched by a rename pay the re-parse."""
    widths: Dict[int, int] = {}
    get = widths.get
    for ins in kernel.instructions():
        for r, w in ins.width_entries():
            if w > get(r, 0):
                widths[r] = w
    return widths


def operand_conflicts(kernel: Kernel) -> Dict[int, Set[int]]:
    """reg -> set of registers co-occurring in the same instruction.

    Two demoted registers appearing in one instruction would need two value
    temporaries (an *operand conflict*, §3.1 challenge 2), so after demoting
    ``r`` every conflicting candidate is dropped.
    """
    conf: Dict[int, Set[int]] = {}
    for ins in kernel.instructions():
        regs = [r for r in ins.leading_regs() if r != RZ]
        for a in regs:
            for b in regs:
                if a != b:
                    conf.setdefault(a, set()).add(b)
    return conf


def _excluded(kernel: Kernel) -> Set[int]:
    widths = width_map(kernel)
    excl: Set[int] = set(kernel.live_in) | set(kernel.live_out) | {RZ}
    if kernel.rda is not None:
        excl.add(kernel.rda)
    # odd alias words of pairs are not independent candidates
    for r, w in widths.items():
        if w == 2:
            excl.add(r + 1)
    return excl


def spillable(kernel: Kernel) -> List[int]:
    """Leading registers eligible for demotion, ascending.

    The strategy-independent candidate pool: everything :func:`make_candidates`
    could ever return, before any cost ordering.  The autotuning search uses
    it to prune kernels with nothing to demote without running a pipeline.
    """
    widths = width_map(kernel)
    excl = _excluded(kernel)
    return [r for r in sorted(widths) if r not in excl]


def order_candidates(kernel: Kernel, ordering: str) -> List[Tuple[int, int]]:
    """The §3.4.3 cost orderings over the spillable pool, by name.

    This is the ordering primitive the strategy registry builds on:
    registered strategies compose an ordering with their own filters (e.g.
    compressed slots keep only width-1 candidates).
    """
    if ordering not in STRATEGIES:
        raise ValueError(
            f"unknown candidate ordering {ordering!r}; want one of {STRATEGIES}"
        )
    widths = width_map(kernel)
    excl = _excluded(kernel)
    regs = [r for r in sorted(widths) if r not in excl]

    if ordering == "static":
        counts = kernel.static_access_counts()
        key = lambda r: (counts.get(r, 0), r)
    elif ordering == "cfg":
        cfg = CFG(kernel)
        weighted: Dict[int, float] = {}
        for blk in cfg.blocks:
            w = LOOP_FACTOR ** blk.loop_depth
            for ins in blk.instrs:
                for r in ins.leading_regs():
                    weighted[r] = weighted.get(r, 0.0) + w
        key = lambda r: (weighted.get(r, 0.0), r)
    else:  # conflict
        conf = operand_conflicts(kernel)
        counts = kernel.static_access_counts()
        key = lambda r: (len(conf.get(r, ())), counts.get(r, 0), r)

    return [(r, widths[r]) for r in sorted(regs, key=key)]


def make_candidates(kernel: Kernel, strategy: str) -> List[Tuple[int, int]]:
    """Ordered demotion queue: list of (leading_reg, width).

    ``strategy`` resolves through the registry
    (:func:`repro.core.strategies.get_strategy`), so any registered name —
    paper ordering or new family — is valid here; the paper's three names
    keep their historical byte-identical orderings.
    """
    from .strategies import get_strategy

    return get_strategy(strategy).select(kernel)
