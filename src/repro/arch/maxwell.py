"""The Maxwell/Pascal backend — the paper's target architecture.

Numbers are the GM200 (GTX Titan X) model the rest of the repo has always
used; this module only *names* them.  The descriptor's values are pinned by
the pre-registry golden tests (Table-3 demotion counts,
``tests/golden/sim_cycles.json``, container golden bytes): the Maxwell path
through every parameterized layer must stay byte- and cycle-identical.

Model notes:

* four warp schedulers, dual-issue capable; the simulator models an SM
  issue width of 4 (single-issue per scheduler), the historical engine
  value the golden cycle counts pin;
* 21-bit control words bundled 3-per-64-bit ahead of their instructions
  (:class:`repro.binary.archcodec.MaxwellCodec`);
* 4 register banks (``reg % 4``), 6 scoreboard barriers;
* 48 KiB per-block shared memory, of which demotion may use whatever the
  kernel's static allocation leaves free.
"""

from __future__ import annotations

from repro.binary.archcodec import MAXWELL_CODEC
from repro.core.isa import OpClass
from repro.core.occupancy import MAXWELL as MAXWELL_SM

from .registry import Arch, LatencyModel, register_arch

#: Functional-unit lanes per SM (GM200: 128 FP32 cores, 4 FP64, 32 LSU,
#: 32 SFU) — identical to the throughputs baked into :class:`OpClass`.
MAXWELL_LANES = {
    OpClass.FP32: 128,
    OpClass.INT: 128,
    OpClass.FP64: 4,
    OpClass.SFU: 32,
    OpClass.LSU_GLOBAL: 32,
    OpClass.LSU_SHARED: 32,
    OpClass.LSU_LOCAL: 32,
    OpClass.CONTROL: 128,
    OpClass.MISC: 32,
}

MAXWELL_ARCH = register_arch(
    Arch(
        name="maxwell",
        full_name="NVIDIA Maxwell/Pascal (CC 5.x/6.x)",
        chips=("GM200", "GM204", "GP102"),
        sm=MAXWELL_SM,
        latency=LatencyModel(
            alu=6,
            control=6,
            misc=20,
            fp64=48,
            sfu=20,
            shared=24,
            # local-memory traffic is L1-cached: effective latency between
            # shared (24) and DRAM (200) — the paper's premise ordering
            local=80,
            global_mem=200,
            read_release=20,
        ),
        lanes=MAXWELL_LANES,
        codec=MAXWELL_CODEC,
        num_barriers=6,
        num_reg_banks=4,
        num_smem_banks=32,
        schedulers=4,
        dual_issue=True,
        issue_width=4,
        smem_spill_limit=48 * 1024,
        max_regs_per_thread=255,
        aliases=("pascal", "sm_50", "sm_52", "sm_60", "sm_61", "gm200"),
    )
)
