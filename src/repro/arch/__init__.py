"""repro.arch — the GPU architecture registry.

Two backends ship in-tree:

* ``maxwell`` — the paper's Maxwell/Pascal model (bundled 21-bit control
  words, 4 register banks, 48 KiB shared per block);
* ``volta`` — a Volta/Turing model after TuringAs (128-bit instructions
  with in-word control fields, 2 register banks, dual-issue removed,
  96 KiB shared carve-out).

Kernels name their architecture (:attr:`repro.core.isa.Kernel.arch`);
:func:`arch_of` resolves the descriptor that parameterizes scheduling,
simulation, occupancy, spilling, and the binary codec.  :func:`retarget`
ports a kernel to another architecture by re-scheduling it under that
arch's machine model.
"""

from .registry import (
    Arch,
    ArchError,
    LatencyModel,
    arch_names,
    arch_of,
    get_arch,
    register_arch,
)
from .maxwell import MAXWELL_ARCH
from .volta import VOLTA_ARCH


def retarget(kernel, arch) -> "object":
    """Port a kernel to another architecture.

    Copies the kernel, tags it with the target arch, and re-runs the
    control-word scheduler under that arch's machine model (barrier count,
    fixed latencies) — the moral equivalent of recompiling the same
    program for a new GPU generation.  The input kernel is not mutated.
    """
    from repro.core.sched import schedule

    target = arch if isinstance(arch, Arch) else get_arch(arch)
    out = kernel.copy()
    out.arch = target.name
    return schedule(out)


__all__ = [
    "Arch",
    "ArchError",
    "LatencyModel",
    "MAXWELL_ARCH",
    "VOLTA_ARCH",
    "arch_names",
    "arch_of",
    "get_arch",
    "register_arch",
    "retarget",
]
