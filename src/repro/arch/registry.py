"""The GPU architecture registry: one :class:`Arch` descriptor per backend.

RegDem is a SASS-level binary translation, so everything about it is
architecture-specific: the control-word layout, the scoreboard-barrier
count, register-file banking, functional-unit latencies/throughputs, and
the occupancy limits whose cliffs the whole optimization chases.  The
:class:`Arch` descriptor gathers those properties into one object that
parameterizes every layer of the stack:

* :mod:`repro.binary` — per-arch text-section codec (control-word layout),
  the v3 container's per-kernel arch tag;
* :mod:`repro.core.sched` / :mod:`repro.core.passes` — barrier count,
  fixed latencies, register banking for RDV placement;
* :mod:`repro.core.simulator` / :mod:`repro.core.predictor` — unit lanes
  (issue intervals / throughput ratios), signal latencies, issue width;
* :mod:`repro.core.occupancy` / :mod:`repro.core.spillspace` — the
  :class:`~repro.core.occupancy.SMConfig` limits and the shared-memory
  spill budget.

Kernels carry their architecture as a registry name
(:attr:`repro.core.isa.Kernel.arch`, default ``"maxwell"``); every
consumer resolves the descriptor through :func:`arch_of`.  Registering a
new architecture is the extension point — see README "Architectures".
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Mapping, Tuple

from repro.core.isa import Instr, RZ, OpClass
from repro.core.occupancy import SMConfig


class ArchError(ValueError):
    """Unknown architecture name or invalid registration."""


@dataclass(frozen=True)
class LatencyModel:
    """Producer->consumer / completion latencies in cycles, per arch.

    ``alu``/``control``/``misc`` are the fixed-latency classes the
    scheduler separates with stall counts; ``fp64``/``sfu`` and the three
    memory spaces signal scoreboard barriers at these latencies.
    ``read_release`` caps how soon a read barrier (store operand release)
    signals after issue.
    """

    alu: int
    control: int
    misc: int
    fp64: int
    sfu: int
    shared: int
    local: int
    global_mem: int
    read_release: int = 20


@dataclass(frozen=True, eq=False)
class Arch:
    """One GPU architecture: codec + machine model + occupancy limits.

    Instances are registry singletons (identity hash/eq); resolve them via
    :func:`get_arch` / :func:`arch_of`, never by constructing duplicates.
    """

    name: str
    full_name: str
    #: example chips / compute capabilities (documentation only)
    chips: Tuple[str, ...]
    sm: SMConfig
    latency: LatencyModel
    #: functional-unit lanes per SM, per op class (issue interval is
    #: ``32 / lanes``; throughput ratio is ``max_lanes / lanes``)
    lanes: Mapping[OpClass, int]
    #: text-section codec (control-word layout); resolved lazily by name
    #: from repro.binary.archcodec to keep this module import-light
    codec: object = field(repr=False, default=None)
    num_barriers: int = 6
    num_reg_banks: int = 4
    num_smem_banks: int = 32
    #: warp schedulers per SM and issues per scheduler per cycle
    #: (Volta/Turing removed dual-issue: one instruction per scheduler)
    schedulers: int = 4
    dual_issue: bool = False
    #: modelled SM issue width (warp-instructions per cycle)
    issue_width: int = 4
    #: per-block shared-memory budget demotion may spill into
    smem_spill_limit: int = 48 * 1024
    #: architectural per-thread register ceiling (R0..Rn-1; the 256th
    #: encoding slot is RZ on every generation modelled here)
    max_regs_per_thread: int = 255
    aliases: Tuple[str, ...] = ()

    # -- derived model queries -------------------------------------------------

    @property
    def max_lanes(self) -> int:
        return max(self.lanes.values())

    def issue_interval(self, klass: OpClass) -> float:
        """Cycles between warp-instructions of ``klass`` (32 / unit lanes)."""
        return 32 / self.lanes[klass]

    def throughput_ratio(self, klass: OpClass) -> float:
        """Contention term of predictor eq. 2: max_lanes / unit lanes."""
        return self.max_lanes / self.lanes[klass]

    def fixed_latency(self, klass: OpClass) -> int:
        """Producer->consumer latency of non-barrier (pipelined) classes."""
        if klass in (OpClass.FP32, OpClass.INT):
            return self.latency.alu
        if klass is OpClass.CONTROL:
            return self.latency.control
        if klass is OpClass.MISC:
            return self.latency.misc
        return self.residual_latency(klass)

    def signal_latency(self, klass: OpClass) -> int:
        """Write-barrier signal latency (producer completion) per class."""
        if klass is OpClass.LSU_GLOBAL:
            return self.latency.global_mem
        if klass is OpClass.LSU_LOCAL:
            return self.latency.local
        if klass is OpClass.LSU_SHARED:
            return self.latency.shared
        return self.residual_latency(klass)

    def residual_latency(self, klass: OpClass) -> int:
        """Barrier-tracker residual latency: what a reused barrier's setter
        still owes.  Local memory is charged at DRAM latency here (the
        tracker is conservative), matching the paper's Fig. 3 machinery."""
        if klass in (OpClass.LSU_GLOBAL, OpClass.LSU_LOCAL):
            return self.latency.global_mem
        if klass is OpClass.LSU_SHARED:
            return self.latency.shared
        if klass is OpClass.FP64:
            return self.latency.fp64
        if klass is OpClass.SFU:
            return self.latency.sfu
        if klass is OpClass.MISC:
            return self.latency.misc
        if klass is OpClass.CONTROL:
            return self.latency.control
        return self.latency.alu

    # -- register banking ------------------------------------------------------

    def reg_bank(self, reg: int) -> int:
        """Register-file bank of ``reg`` (Maxwell: 4 banks; Volta: 2)."""
        return reg % self.num_reg_banks

    def bank_conflicts(self, ins: Instr) -> int:
        """Serialized extra cycles from same-bank source operands."""
        if self.num_reg_banks == 4:
            # the Instr-level cache computes exactly this banking
            return ins.reg_bank_conflicts()
        banks: Dict[int, set] = {}
        for r in set(ins.src_words()):
            if r == RZ:
                continue
            banks.setdefault(self.reg_bank(r), set()).add(r)
        return sum(len(v) - 1 for v in banks.values())

    def rdv_banks(self, wide: bool) -> List[int]:
        """Banks RDV may land in (§3.4.1): any bank, but pair demotion
        needs an even-aligned RDV, restricting it to even banks."""
        return [b for b in range(self.num_reg_banks) if not wide or b % 2 == 0]

    def smem_bank(self, byte_addr: int) -> int:
        return (byte_addr // 4) % self.num_smem_banks

    def describe(self) -> Dict[str, object]:
        """JSON-friendly summary (used by ``benchmarks.run --only arch``)."""
        return {
            "full_name": self.full_name,
            "chips": list(self.chips),
            "ctrl_codec": type(self.codec).__name__ if self.codec else None,
            "num_barriers": self.num_barriers,
            "num_reg_banks": self.num_reg_banks,
            "schedulers": self.schedulers,
            "dual_issue": self.dual_issue,
            "issue_width": self.issue_width,
            "regs_per_sm": self.sm.registers,
            "max_warps": self.sm.max_warps,
            "smem_bytes_per_sm": self.sm.smem_bytes,
            "smem_per_block": self.sm.smem_per_block,
            "smem_spill_limit": self.smem_spill_limit,
            "alu_latency": self.latency.alu,
            "shared_latency": self.latency.shared,
            "global_latency": self.latency.global_mem,
            "num_sms": self.sm.num_sms,
        }


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Arch] = {}
_ALIASES: Dict[str, str] = {}


def register_arch(arch: Arch) -> Arch:
    """Register ``arch`` under its name and aliases; returns it."""
    if arch.name in _REGISTRY:
        raise ArchError(f"architecture {arch.name!r} already registered")
    if arch.codec is None:
        raise ArchError(f"architecture {arch.name!r} has no text codec")
    _REGISTRY[arch.name] = arch
    for alias in arch.aliases:
        if alias in _REGISTRY or alias in _ALIASES:
            raise ArchError(f"alias {alias!r} already registered")
        _ALIASES[alias] = arch.name
    return arch


def get_arch(name: str) -> Arch:
    """Resolve an architecture by registry name or alias."""
    key = name.lower()
    key = _ALIASES.get(key, key)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ArchError(
            f"unknown architecture {name!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def arch_names() -> List[str]:
    """Registered canonical architecture names, sorted."""
    return sorted(_REGISTRY)


def arch_of(kernel) -> Arch:
    """The :class:`Arch` a kernel is encoded/scheduled for."""
    return get_arch(getattr(kernel, "arch", "maxwell"))
