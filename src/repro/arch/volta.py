"""The Volta/Turing backend (CC 7.x), modelled after TuringAs.

Differences from Maxwell that matter to RegDem, each carried by the
descriptor so every layer picks them up through the registry:

* **encoding** — 128-bit instructions with *in-word* control fields
  (stall / yield / wbar / rbar / wait mask at bit 105); no 3-instruction
  control bundles (:class:`repro.binary.archcodec.VoltaCodec`).  The yield
  bit is encoded directly, not inverted;
* **register file** — 2 banks (64-bit wide; ``reg % 2``) instead of
  Maxwell's 4, so RDV bank tuning has fewer choices and wide (pair)
  demotion pins RDV to bank 0;
* **schedulers** — dual-issue removed: four partitions, one instruction
  per partition per cycle; math units are 16/32-lane, so a warp occupies
  its unit for more cycles (lanes table below);
* **latencies** — shorter ALU pipeline (4 cycles), fast FP64 (32 lanes),
  ~19-cycle shared memory, deeper DRAM path;
* **occupancy / shared memory** — unified L1/shared carve-out: up to
  96 KiB of shared memory per block (vs Maxwell's 48 KiB), which widens
  the shared-memory budget demotion can spill into;
* **registers** — the 256-slot encoding ceiling is unchanged (R0..R254
  usable, slot 255 = RZ), but allocation granularity still steps per
  8 registers/thread x 32 threads.

Numbers are a GV100-class model (80 SMs); absolute values are model
approximations — like the Maxwell table, variant *ratios* are the
quantity of interest.
"""

from __future__ import annotations

from repro.binary.archcodec import VOLTA_CODEC
from repro.core.isa import OpClass
from repro.core.occupancy import SMConfig

from .registry import Arch, LatencyModel, register_arch

#: GV100-class per-SM limits.
VOLTA_SM = SMConfig(
    registers=64 * 1024,
    max_threads=2048,
    max_warps=64,
    max_blocks=32,
    smem_bytes=96 * 1024,
    smem_per_block=96 * 1024,  # unified L1/shared carve-out, opt-in per block
    warp_size=32,
    reg_alloc_unit=256,
    smem_alloc_unit=256,
    max_regs_per_thread=255,
    num_sms=80,
)

#: Functional-unit lanes per SM sub-core x 4 partitions (V100: 64 FP32,
#: 64 INT32, 32 FP64, 16 SFU, 32 LSU lanes per SM).
VOLTA_LANES = {
    OpClass.FP32: 64,
    OpClass.INT: 64,
    OpClass.FP64: 32,
    OpClass.SFU: 16,
    OpClass.LSU_GLOBAL: 32,
    OpClass.LSU_SHARED: 32,
    OpClass.LSU_LOCAL: 32,
    OpClass.CONTROL: 64,
    OpClass.MISC: 32,
}

VOLTA_ARCH = register_arch(
    Arch(
        name="volta",
        full_name="NVIDIA Volta/Turing (CC 7.x)",
        chips=("GV100", "TU102", "TU104"),
        sm=VOLTA_SM,
        latency=LatencyModel(
            alu=4,
            control=4,
            misc=15,
            fp64=8,
            sfu=16,
            shared=19,
            local=70,
            global_mem=375,
            read_release=20,
        ),
        lanes=VOLTA_LANES,
        codec=VOLTA_CODEC,
        num_barriers=6,
        num_reg_banks=2,
        num_smem_banks=32,
        schedulers=4,
        dual_issue=False,  # Volta removed dual-issue
        issue_width=4,
        smem_spill_limit=96 * 1024,
        max_regs_per_thread=255,
        aliases=("turing", "sm_70", "sm_75", "gv100", "tu102"),
    )
)
