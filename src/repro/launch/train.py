"""Training launcher: ``python -m repro.launch.train --arch <id> [options]``.

Real (CPU-scale) runs use the host mesh; the production flags mirror what a
TPU deployment would pass.  ``--smoke`` trains the reduced config of the
chosen architecture — every assigned arch is selectable.
"""

import argparse

from repro.configs import ARCH_IDS, get_config, param_count, reduced_config
from repro.data import DataConfig
from repro.launch.mesh import make_host_mesh
from repro.optim import AdamWConfig
from repro.runtime import TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS + [a.replace("_", "-") for a in ARCH_IDS])
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "dots", "full"])
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    arch = args.arch.replace("-", "_")
    cfg = reduced_config(arch) if args.smoke else get_config(arch)
    print(f"arch {cfg.name} ({cfg.family}): {param_count(cfg)/1e6:.1f}M params")

    trainer = Trainer(
        model_cfg=cfg,
        opt_cfg=AdamWConfig(lr=args.lr, total_steps=args.steps),
        train_cfg=TrainConfig(
            steps=args.steps,
            microbatches=args.microbatches,
            checkpoint_every=args.ckpt_every,
            checkpoint_dir=args.ckpt_dir,
            remat=args.remat,
            fsdp=args.fsdp,
            attn_impl="xla" if args.seq_len <= 2048 else "chunked",
        ),
        data_cfg=DataConfig(
            vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch
        ),
        mesh=make_host_mesh(),
    )
    out = trainer.run()
    losses = out["losses"]
    print(f"trained {out['final_step']} steps; loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"restarts={out['restarts']} stragglers={out['stragglers']}")


if __name__ == "__main__":
    main()
