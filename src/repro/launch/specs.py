"""Abstract input specs + sharding policies for every (arch x shape) cell.

``cell_inputs`` builds ShapeDtypeStruct stand-ins (no allocation) for the
inputs of each step kind; ``cell_shardings`` assigns NamedShardings:

* batch dims shard over the data axes (``pod`` x ``data``); a batch of 1
  (long_500k) leaves batch unsharded and puts the model axis on the KV/SSM
  sequence/state dims instead;
* KV caches shard heads over ``model`` when the head count divides the axis,
  else the cache *sequence* is sharded over ``model`` (GQA archs with few
  KV heads — exactness preserved, collectives appear in the roofline);
* SSM states shard their head dim over ``model`` when divisible.
"""

from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ShapeCell
from repro.models import ModelConfig
from repro.models.mamba2 import D_CONV, mamba_dims
from repro.models import hybrid as hybrid_mod

S = jax.ShapeDtypeStruct


def data_axes(mesh: Mesh):
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def model_axis_size(mesh: Mesh) -> int:
    return mesh.shape.get("model", 1)


# ---------------------------------------------------------------------------
# Shape-cell geometry per family
# ---------------------------------------------------------------------------


def cell_geometry(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, int]:
    """Resolve the canonical (seq_len x batch) into per-family input dims."""
    g = {"batch": cell.global_batch, "seq": cell.seq_len, "n_patches": 0, "n_frames": 0}
    if cfg.family == "vlm":
        g["n_patches"] = 256  # fixed-resolution stub: 256 patch tokens prefix
    if cfg.family == "audio":
        g["n_frames"] = 1500  # 30 s of audio
        # the seq budget is split: 1500 encoder frames + decoder positions
        g["seq"] = max(cell.seq_len - 1500, 448 if cell.kind != "train" else 2048)
        if cell.kind == "train":
            g["seq"] = min(g["seq"], 4096)
    return g


# ---------------------------------------------------------------------------
# Abstract inputs per step kind
# ---------------------------------------------------------------------------


def train_inputs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    g = cell_geometry(cfg, cell)
    B, Sq = g["batch"], g["seq"]
    out = {
        "tokens": S((B, Sq), jnp.int32),
        "targets": S((B, Sq), jnp.int32),
    }
    if cfg.family == "vlm":
        out["patch_embeds"] = S((B, g["n_patches"], cfg.d_model), jnp.bfloat16)
        out["mrope_positions"] = S((B, Sq, 3), jnp.int32)
    if cfg.family == "audio":
        out["frame_embeds"] = S((B, g["n_frames"], cfg.d_model), jnp.bfloat16)
    return out


def prefill_inputs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    out = train_inputs(cfg, cell)
    out.pop("targets")
    return out


def decode_state_struct(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Abstract decode state matching Model.prefill's output structure."""
    st: Dict[str, Any] = {"pos": S((batch,), jnp.int32)}
    if cfg.family in ("dense", "moe", "vlm"):
        kv = S((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh), jnp.bfloat16)
        st["kv"] = (kv, kv)
    elif cfg.family == "ssm":
        d_inner, conv_dim = mamba_dims(cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        st["ssm"] = S((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        st["conv"] = S((cfg.n_layers, batch, D_CONV - 1, conv_dim), jnp.bfloat16)
    elif cfg.family == "hybrid":
        apps = hybrid_mod.n_attn_applications(cfg)
        d_inner, conv_dim = mamba_dims(cfg.d_model, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state)
        kv = S((apps, batch, max_len, cfg.n_kv_heads, cfg.dh), jnp.bfloat16)
        st["kv"] = (kv, kv)
        st["ssm"] = S((cfg.n_layers, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32)
        st["conv"] = S((cfg.n_layers, batch, D_CONV - 1, conv_dim), jnp.bfloat16)
    elif cfg.family == "audio":
        kv = S((cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.dh), jnp.bfloat16)
        st["kv"] = (kv, kv)
        st["enc"] = S((batch, 1500, cfg.d_model), cfg.dtype)
    return st


def decode_inputs(cfg: ModelConfig, cell: ShapeCell) -> Tuple[Dict[str, Any], Dict[str, Any]]:
    g = cell_geometry(cfg, cell)
    B = g["batch"]
    max_len = g["seq"] if cfg.family != "audio" else max(g["seq"], 448)
    # pad the cache length to a multiple of 1024 so a model-axis-sharded
    # sequence dim always divides (e.g. whisper's 31268-token budget)
    max_len = -(-max_len // 1024) * 1024
    tokens = S((B, 1), jnp.int32)
    return {"tokens": tokens}, decode_state_struct(cfg, B, max_len)


# ---------------------------------------------------------------------------
# Shardings
# ---------------------------------------------------------------------------


def _dp_for_batch(mesh: Mesh, batch: int):
    dp = data_axes(mesh)
    if dp is None:
        return None
    size = int(np.prod([mesh.shape[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
    return dp if batch % size == 0 and batch >= size else None


def batch_shardings(mesh: Mesh, inputs: Dict[str, Any], batch: int) -> Dict[str, Any]:
    dp = _dp_for_batch(mesh, batch)

    def shard(leaf):
        return NamedSharding(mesh, P(*([dp] + [None] * (len(leaf.shape) - 1))))

    return jax.tree.map(shard, inputs)


def state_shardings(cfg: ModelConfig, mesh: Mesh, state: Dict[str, Any], batch: int) -> Dict[str, Any]:
    dp = _dp_for_batch(mesh, batch)
    ms = model_axis_size(mesh)
    heads_shardable = cfg.n_kv_heads > 0 and cfg.n_kv_heads % ms == 0
    ssm_shardable = cfg.ssm_heads > 0 and cfg.ssm_heads % ms == 0
    # batch=1 (long_500k): put every mesh axis on the sequence/state dims
    seq_axes: Any = "model" if dp is not None else tuple(
        a for a in ("pod", "data", "model") if a in mesh.axis_names
    )

    out: Dict[str, Any] = {}
    for key, leaf in state.items():
        if key == "pos":
            out[key] = NamedSharding(mesh, P(dp))
        elif key == "kv":
            if heads_shardable:
                spec = P(None, dp, None, "model", None)
            else:
                spec = P(None, dp, seq_axes, None, None)
            out[key] = (NamedSharding(mesh, spec), NamedSharding(mesh, spec))
        elif key == "ssm":
            spec = P(None, dp, "model" if ssm_shardable else None, None, None)
            out[key] = NamedSharding(mesh, spec)
        elif key == "conv":
            out[key] = NamedSharding(mesh, P(None, dp, None, "model"))
        elif key == "enc":
            out[key] = NamedSharding(mesh, P(dp, None, None))
        else:  # pragma: no cover
            out[key] = NamedSharding(mesh, P())
    return out
