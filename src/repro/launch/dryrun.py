import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the real step function (train_step /
prefill_step / serve_step), lowers it with explicit in_shardings on the
production mesh, compiles it, and extracts:

* ``memory_analysis()``  — per-device argument/temp/output bytes (the
  "proves it fits" check against the 16 GB v5e HBM);
* ``cost_analysis()``    — per-device HLO FLOPs and bytes accessed;
* collective bytes       — parsed from the optimized (SPMD-partitioned)
  HLO text: operand sizes of all-reduce / all-gather / reduce-scatter /
  all-to-all / collective-permute ops;

and appends the record to a JSON results file consumed by the roofline
benchmark (EXPERIMENTS.md §Dry-run / §Roofline).

Usage::

    python -m repro.launch.dryrun --arch qwen2_7b --shape train_4k
    python -m repro.launch.dryrun --all --mesh both --out dryrun.json
"""

import argparse
import json
import re
import time
import traceback
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, ShapeCell, get_config, shape_cells
from repro.launch import specs
from repro.launch.mesh import make_production_mesh
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.sharding import check_divisibility, default_rules, logical_to_sharding

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1,
    "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_TYPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]+)\}")


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Per-device collective traffic from the SPMD-partitioned module.

    Optimized HLO does not annotate operand types inline, so sizes come from
    the *result* shape on each collective line, converted to operand bytes
    (all-gather result = operand x group; reduce-scatter operand = result x
    group) and to estimated *wire* bytes per device for the roofline term
    (ring algorithms: all-reduce ~ 2x(g-1)/g x size, (all-)gather/scatter ~
    (g-1)/g x full size).
    """
    per_op: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    wire = 0.0
    for line in hlo_text.splitlines():
        for coll in _COLLECTIVES:
            if f" {coll}(" not in line:
                continue
            lhs = line.split(f" {coll}(")[0]
            shapes = _TYPE_RE.findall(lhs)
            if not shapes:
                continue
            result = sum(_shape_bytes(d, s) for d, s in shapes)
            gm = _GROUPS_RE.search(line)
            g = len(gm.group(1).split(",")) if gm else 1
            g = max(g, 1)
            counts[coll] += 1
            if coll == "all-gather":
                operand = result // g
                wire += result * (g - 1) / g
            elif coll == "reduce-scatter":
                operand = result * g
                wire += operand * (g - 1) / g
            elif coll == "all-reduce":
                operand = result
                wire += 2 * result * (g - 1) / g
            elif coll == "collective-permute":
                operand = result
                wire += result
            else:  # all-to-all
                operand = result
                wire += result * (g - 1) / g
            per_op[coll] += operand
            break  # one collective per line in optimized HLO
    return {
        "bytes_by_type": per_op,
        "counts": counts,
        "total_bytes": sum(per_op.values()),
        "wire_bytes": int(wire),
    }


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def build_cell(
    arch: str,
    cell: ShapeCell,
    mesh: Mesh,
    *,
    remat: str = "full",
    fsdp: bool = True,
    attn_impl: str = "chunked",
    microbatches: int = 1,
    extra_rules=None,
) -> Tuple[Any, Tuple, Tuple]:
    """Returns (step_fn, abstract_args, in_shardings)."""
    cfg = get_config(arch)
    model = Model(cfg, attn_impl=attn_impl, remat=remat)
    rules = extra_rules or default_rules(
        mesh, n_experts=(cfg.moe.n_experts if cfg.moe else 0), fsdp=fsdp and cell.kind == "train"
    )
    params_struct, axes = model.abstract_init()
    p_shard = logical_to_sharding(axes, mesh, rules, like=params_struct)
    g = specs.cell_geometry(cfg, cell)

    if cell.kind == "train":
        opt_struct = jax.eval_shape(adamw_init, params_struct)
        opt_shard = {
            "mu": p_shard,
            "nu": p_shard,
            "count": NamedSharding(mesh, P()),
        }
        ocfg = AdamWConfig()

        dp = specs.data_axes(mesh)

        def train_step(params, opt_state, batch):
            if microbatches > 1:
                def micro(acc, mb):
                    loss, grads = jax.value_and_grad(model.train_loss)(params, mb)
                    return jax.tree.map(jnp.add, acc, grads), loss

                def split_mb(x):
                    y = x.reshape(
                        (microbatches, x.shape[0] // microbatches) + x.shape[1:]
                    )
                    # keep the batch shard on dim 1: without the constraint
                    # GSPMD falls back to "involuntary full rematerialization"
                    # (replicating the whole batch) on the reshape
                    spec = P(*([None, dp] + [None] * (y.ndim - 2)))
                    return jax.lax.with_sharding_constraint(y, NamedSharding(mesh, spec))

                from repro.models.common import scan as common_scan

                split = jax.tree.map(split_mb, batch)
                zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
                gsum, losses = common_scan(micro, zero, split)
                grads = jax.tree.map(lambda g: g / microbatches, gsum)
                loss = losses.mean()
            else:
                loss, grads = jax.value_and_grad(model.train_loss)(params, batch)
            new_p, new_o, metrics = adamw_update(ocfg, params, grads, opt_state)
            metrics["loss"] = loss
            return new_p, new_o, metrics

        batch = specs.train_inputs(cfg, cell)
        b_shard = specs.batch_shardings(mesh, batch, g["batch"])
        return train_step, (params_struct, opt_struct, batch), (p_shard, opt_shard, b_shard)

    if cell.kind == "prefill":
        def prefill_step(params, batch):
            h, state = model.prefill(params, batch, max_len=g["seq"])
            logits = model.logits(params, h[:, -1:])
            return jnp.argmax(logits, axis=-1), state

        batch = specs.prefill_inputs(cfg, cell)
        b_shard = specs.batch_shardings(mesh, batch, g["batch"])
        return prefill_step, (params_struct, batch), (p_shard, b_shard)

    # decode
    def serve_step(params, tokens, state):
        h, new_state = model.decode_step(params, tokens, state)
        logits = model.logits(params, h[:, -1:])
        return jnp.argmax(logits, axis=-1), new_state

    tok_struct, state_struct = specs.decode_inputs(cfg, cell)
    tok_shard = specs.batch_shardings(mesh, tok_struct, g["batch"])
    st_shard = specs.state_shardings(cfg, mesh, state_struct, g["batch"])
    return (
        serve_step,
        (params_struct, tok_struct["tokens"], state_struct),
        (p_shard, tok_shard["tokens"], st_shard),
    )


# ---------------------------------------------------------------------------
# The dry run
# ---------------------------------------------------------------------------


def run_cell(
    arch: str,
    cell: ShapeCell,
    mesh: Mesh,
    mesh_name: str,
    *,
    remat: str = "full",
    fsdp: bool = True,
    attn_impl: str = "chunked",
    microbatches: int = 1,
    keep_text: bool = False,
    mode: str = "rolled",
) -> Dict[str, Any]:
    record: Dict[str, Any] = {
        "arch": arch,
        "shape": cell.name,
        "mesh": mesh_name,
        "kind": cell.kind,
        "seq_len": cell.seq_len,
        "global_batch": cell.global_batch,
        "remat": remat,
        "fsdp": fsdp,
        "microbatches": microbatches,
        "mode": mode,
    }
    if cell.skipped:
        record["status"] = "skipped"
        record["skip_reason"] = cell.skip_reason
        return record
    cfg = get_config(arch)
    problems = check_divisibility(cfg, mesh, cell.global_batch)
    try:
        import contextlib

        from repro.models.common import unrolled_scans

        step_fn, args, in_shardings = build_cell(
            arch, cell, mesh,
            remat=remat, fsdp=fsdp, attn_impl=attn_impl, microbatches=microbatches,
        )
        t0 = time.time()
        # "unrolled" mode expands every scan so cost_analysis counts loop
        # bodies the correct number of times and the static collective parse
        # is exact — used for roofline calibration cells.  "rolled" (default)
        # keeps while loops: fast compiles, realistic memory analysis; its
        # flops/collectives count loop bodies once (see benchmarks.roofline
        # for the analytic-model correction).
        ctx = unrolled_scans() if mode == "unrolled" else contextlib.nullcontext()
        with ctx:
            lowered = jax.jit(step_fn, in_shardings=in_shardings).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        text = compiled.as_text()
        colls = collective_bytes(text)
        record.update(
            status="ok",
            lower_s=round(t_lower, 1),
            compile_s=round(t_compile, 1),
            flops=float(ca.get("flops", 0.0)),
            bytes_accessed=float(ca.get("bytes accessed", 0.0)),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "alias_bytes": ma.alias_size_in_bytes,
            },
            collectives=colls,
            divisibility=problems,
        )
        if keep_text:
            record["hlo_text"] = text
    except Exception as e:  # noqa: BLE001 - report, don't crash the sweep
        record["status"] = "error"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-2000:]
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--remat", default="full", choices=["none", "dots", "full"])
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--attn", default="chunked", choices=["chunked", "xla"])
    ap.add_argument("--mode", default="rolled", choices=["rolled", "unrolled"])
    ap.add_argument("--out", default="dryrun_results.json")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ARCH_IDS
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {
        (r["arch"], r["shape"], r["mesh"], r.get("remat"), r.get("microbatches"), r.get("mode"))
        for r in results
    }

    for multi in meshes:
        mesh = make_production_mesh(multi_pod=multi)
        mesh_name = "2x16x16" if multi else "16x16"
        for arch in archs:
            for cell in shape_cells(arch):
                if args.shape and cell.name != args.shape:
                    continue
                key = (arch, cell.name, mesh_name, args.remat, args.microbatches, args.mode)
                if key in done:
                    continue
                print(f"[dryrun] {arch} x {cell.name} on {mesh_name} ({args.mode}) ...", flush=True)
                rec = run_cell(
                    arch, cell, mesh, mesh_name,
                    remat=args.remat, fsdp=not args.no_fsdp,
                    attn_impl=args.attn, microbatches=args.microbatches,
                    mode=args.mode,
                )
                status = rec["status"]
                extra = (
                    f"flops={rec.get('flops', 0):.3e} "
                    f"temp={rec.get('memory', {}).get('temp_bytes', 0)/2**30:.2f}GiB "
                    f"coll={rec.get('collectives', {}).get('total_bytes', 0)/2**30:.3f}GiB"
                    if status == "ok"
                    else rec.get("skip_reason") or rec.get("error", "")[:200]
                )
                print(f"[dryrun]   -> {status}: {extra}", flush=True)
                results.append(rec)
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skipped" for r in results)
    n_err = sum(r["status"] == "error" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skipped, {n_err} errors -> {args.out}")


if __name__ == "__main__":
    main()
