"""Production mesh construction.

Single pod: 256 chips as (data=16, model=16).
Multi-pod:  2 pods x 256 chips as (pod=2, data=16, model=16); the ``pod``
axis extends data parallelism across the inter-pod links (one gradient
all-reduce crosses it per step).

``make_production_mesh`` is a function (never module-level state) so that
importing this module never touches JAX device state — only the dry-run
entry point forces the 512-device host platform.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """A small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))
