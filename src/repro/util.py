"""Small shared utilities: durable, atomic file writes.

One implementation of "write a file so that a crash can never leave a
half-written result behind", shared by the benchmark harness
(``BENCH_*.json`` baselines the CI trend gate reads) and the persistent
artifact store (:mod:`repro.core.artifacts`).  The recipe:

1. write to a temporary file **in the destination directory** (same
   filesystem, so the final rename is atomic on POSIX and Windows);
2. flush and ``fsync`` the file so the bytes are on disk, not in the page
   cache, before the rename makes them visible;
3. ``os.replace`` over the destination (atomic swap);
4. best-effort ``fsync`` of the directory so the rename itself is durable.

A reader therefore sees either the old complete file or the new complete
file — never a torn mixture.  An interrupted write leaves at most a stale
``*.tmp`` file, which writers clean up opportunistically
(:func:`sweep_tmp_files`).
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Iterable, List

#: suffix every atomic writer uses for its in-flight temporary files, so a
#: crash leftover is recognizable (and removable) by any later process
TMP_SUFFIX = ".tmp"


def fsync_dir(path: str) -> None:
    """Best-effort directory fsync (makes a completed rename durable).

    Some filesystems/platforms refuse ``open`` on directories; that only
    costs durability of the *rename* on power loss, never atomicity, so
    failures are swallowed.
    """
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_bytes_atomic(path: str, data: bytes, fsync: bool = True) -> None:
    """Atomically (and, by default, durably) write ``data`` to ``path``."""
    path = os.path.abspath(path)
    directory = os.path.dirname(path)
    fd, tmp = tempfile.mkstemp(
        dir=directory, prefix=os.path.basename(path) + ".", suffix=TMP_SUFFIX
    )
    try:
        with os.fdopen(fd, "wb") as fh:
            fh.write(data)
            if fsync:
                fh.flush()
                os.fsync(fh.fileno())
        os.replace(tmp, path)
        if fsync:
            fsync_dir(directory)
    except BaseException:
        # never leave the temp file behind on a failed/interrupted write
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise


def write_json_atomic(path: str, obj: object, fsync: bool = True) -> None:
    """Atomically write ``obj`` as pretty-printed, key-sorted JSON.

    The one writer behind every ``BENCH_*.json`` report and every artifact
    -store metadata file: an interrupted run (ctrl-C, OOM, CI timeout, power
    loss) can never leave a truncated JSON behind for the CI perf-trend gate
    — or a restarted daemon — to trip over.
    """
    text = json.dumps(obj, indent=2, sort_keys=True) + "\n"
    write_bytes_atomic(path, text.encode("utf-8"), fsync=fsync)


def sweep_tmp_files(directory: str, suffix: str = TMP_SUFFIX) -> List[str]:
    """Remove stale ``*.tmp`` leftovers of interrupted atomic writes.

    Returns the paths removed.  Called by long-lived owners of a directory
    (the artifact store on open); safe to race — a concurrent unlink is
    treated as already-done.
    """
    removed: List[str] = []
    try:
        names: Iterable[str] = os.listdir(directory)
    except OSError:
        return removed
    for name in names:
        if not name.endswith(suffix):
            continue
        full = os.path.join(directory, name)
        try:
            if os.path.isfile(full):
                os.unlink(full)
                removed.append(full)
        except OSError:
            pass
    return removed
