"""Maxwell control-word packing (SASSOverlay field layout).

On Maxwell/Pascal every instruction carries 21 bits of scheduling control,
and three consecutive instructions share one 64-bit control *bundle* that
precedes them in the text section.  The per-instruction layout, LSB first,
matches the field list SASSOverlay decodes (``[5, 3, 3, 6, 3, 1]``):

====  =====  ====================================================
bits  field  meaning
====  =====  ====================================================
0-3   stall  issue-stall cycles before the next instruction (0-15)
4     yield  *inverted* yield flag: bit set => NO yield
5-7   wbar   write-barrier index signalled on result write (7 = none)
8-10  rbar   read-barrier index signalled on operand read (7 = none)
11-16 wait   6-bit mask over the scoreboard barriers to wait on
17-19 reuse  operand-reuse cache slots (unused by the abstract ISA)
20    pad    reserved, always 0
====  =====  ====================================================

``pack_ctrl``/``unpack_ctrl`` convert :class:`repro.core.isa.Ctrl` to and
from this 21-bit integer; ``pack_bundle``/``unpack_bundle`` gang three of
them into the 64-bit word the container's text sections store.  The packed
form is lossless over every control word :func:`repro.core.sched.schedule`
can produce, which is what makes the container a faithful carrier of the
schedule (golden-byte tests pin the exact layout).
"""

from __future__ import annotations

from typing import Iterable, List, Sequence

from repro.core.isa import NUM_BARRIERS, Ctrl

#: Bits of control information per instruction.
CTRL_BITS = 21

#: Instructions covered by one 64-bit control bundle.
BUNDLE_GROUP = 3

#: Barrier-field value meaning "no barrier signalled".
NO_BARRIER = 7

_STALL_MASK = 0xF
_YIELD_BIT = 1 << 4
_WBAR_SHIFT = 5
_RBAR_SHIFT = 8
_WAIT_SHIFT = 11
_WAIT_MASK = (1 << NUM_BARRIERS) - 1
_CTRL_MASK = (1 << CTRL_BITS) - 1

#: Control word of an idle slot (stall 0, no yield, no barriers, no waits) —
#: used to pad the final bundle of a text section.
NOP_CTRL = _YIELD_BIT | (NO_BARRIER << _WBAR_SHIFT) | (NO_BARRIER << _RBAR_SHIFT)


class CtrlWordError(ValueError):
    """Raised when a control word cannot be represented in 21 bits."""


def pack_ctrl(ctrl: Ctrl) -> int:
    """Pack one :class:`Ctrl` into its 21-bit machine form."""
    if not 0 <= ctrl.stall <= _STALL_MASK:
        raise CtrlWordError(f"stall {ctrl.stall} out of range 0..15")
    word = ctrl.stall & _STALL_MASK
    # hardware encodes yield inverted: bit set means "do not yield"
    if not ctrl.yield_flag:
        word |= _YIELD_BIT
    for name, bar, shift in (
        ("write", ctrl.write_bar, _WBAR_SHIFT),
        ("read", ctrl.read_bar, _RBAR_SHIFT),
    ):
        if bar is None:
            word |= NO_BARRIER << shift
        else:
            if not 0 <= bar < NUM_BARRIERS:
                raise CtrlWordError(f"{name} barrier {bar} out of range 0..5")
            word |= bar << shift
    wait = 0
    for b in ctrl.wait:
        if not 0 <= b < NUM_BARRIERS:
            raise CtrlWordError(f"wait barrier {b} out of range 0..5")
        wait |= 1 << b
    word |= wait << _WAIT_SHIFT
    return word


def unpack_ctrl(word: int) -> Ctrl:
    """Decode a 21-bit control word back into a :class:`Ctrl`."""
    if not 0 <= word <= _CTRL_MASK:
        raise CtrlWordError(f"control word {word:#x} wider than {CTRL_BITS} bits")
    wbar = (word >> _WBAR_SHIFT) & 0x7
    rbar = (word >> _RBAR_SHIFT) & 0x7
    wait = (word >> _WAIT_SHIFT) & _WAIT_MASK
    return Ctrl(
        stall=word & _STALL_MASK,
        yield_flag=not (word & _YIELD_BIT),
        write_bar=None if wbar == NO_BARRIER else wbar,
        read_bar=None if rbar == NO_BARRIER else rbar,
        wait={b for b in range(NUM_BARRIERS) if wait & (1 << b)},
    )


def pack_bundle(words: Sequence[int]) -> int:
    """Pack up to three 21-bit control words into one 64-bit bundle.

    Slot 0 occupies the low bits, like the Maxwell control bundle preceding
    its three instructions.  Missing trailing slots are filled with
    :data:`NOP_CTRL`.
    """
    if len(words) > BUNDLE_GROUP:
        raise CtrlWordError(f"bundle holds at most {BUNDLE_GROUP} control words")
    bundle = 0
    for slot in range(BUNDLE_GROUP):
        word = words[slot] if slot < len(words) else NOP_CTRL
        if not 0 <= word <= _CTRL_MASK:
            raise CtrlWordError(f"control word {word:#x} wider than {CTRL_BITS} bits")
        bundle |= word << (slot * CTRL_BITS)
    return bundle


def unpack_bundle(bundle: int, count: int = BUNDLE_GROUP) -> List[int]:
    """Split a 64-bit bundle back into its first ``count`` control words."""
    if not 0 <= bundle < (1 << 64):
        raise CtrlWordError("bundle must be a 64-bit value")
    if not 0 <= count <= BUNDLE_GROUP:
        raise CtrlWordError(f"count must be 0..{BUNDLE_GROUP}")
    return [(bundle >> (slot * CTRL_BITS)) & _CTRL_MASK for slot in range(count)]


def pack_stream(ctrls: Iterable[Ctrl]) -> List[int]:
    """Pack a whole instruction stream's controls into 64-bit bundles."""
    words = [pack_ctrl(c) for c in ctrls]
    return [
        pack_bundle(words[i : i + BUNDLE_GROUP])
        for i in range(0, len(words), BUNDLE_GROUP)
    ]


def unpack_stream(bundles: Sequence[int], n_instrs: int) -> List[Ctrl]:
    """Inverse of :func:`pack_stream` for ``n_instrs`` instructions."""
    need = (n_instrs + BUNDLE_GROUP - 1) // BUNDLE_GROUP
    if len(bundles) < need:
        raise CtrlWordError(
            f"{n_instrs} instructions need {need} bundles, got {len(bundles)}"
        )
    ctrls: List[Ctrl] = []
    for i, bundle in enumerate(bundles[:need]):
        left = n_instrs - i * BUNDLE_GROUP
        for word in unpack_bundle(bundle, min(BUNDLE_GROUP, left)):
            ctrls.append(unpack_ctrl(word))
    return ctrls
