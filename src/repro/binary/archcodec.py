"""Per-architecture text-section codecs.

The container's ``.text.<kernel>`` sections carry two things per
instruction: the 24-byte instruction record (:mod:`repro.binary.encoding`)
and the scheduling control word.  *Where* the control bits live is an
architecture property, and it is the most visible encoding difference
between the two GPU generations this repo models:

* **Maxwell/Pascal** (:class:`MaxwellCodec`) — 21 bits of control per
  instruction, three instructions sharing one 64-bit control *bundle* that
  precedes them (the SASSOverlay layout of :mod:`repro.binary.ctrlwords`).
  Text-section shape: ``[8-byte bundle][3 x 24-byte records]`` groups, the
  trailing group zero-padded.

* **Volta/Turing** (:class:`VoltaCodec`) — TuringAs-style 128-bit
  instructions with *in-word* control fields: every instruction is
  self-contained, no bundling.  The real encoding parks the control block
  at bits 105..125 of the 128-bit word; the abstract record mirrors that
  with a trailing 8-byte "high word" whose bits 41..61 (= 105-64 .. 125-64)
  hold the control field.  Text-section shape: one 32-byte record per
  instruction (``[24-byte record][8-byte high word]``).

  The Volta field order matches TuringAs: stall 0-3, yield bit 4 (set =
  MAY yield — *not* inverted, unlike Maxwell), write barrier 5-7, read
  barrier 8-10, wait mask 11-16, operand-reuse 17-20 (4 bits, always 0
  here).

Codec instances are owned by :class:`repro.arch.Arch` descriptors;
:mod:`repro.binary.encoding` and :mod:`repro.binary.container` resolve the
codec from the kernel's arch tag.  The Maxwell codec is byte-identical to
the historical (pre-registry) layout — golden tests pin both layouts.
"""

from __future__ import annotations

import struct
from typing import List, Sequence, Tuple

from repro.core.isa import Ctrl

from .ctrlwords import (
    BUNDLE_GROUP,
    CTRL_BITS,
    CtrlWordError,
    NO_BARRIER,
    pack_ctrl,
    unpack_ctrl,
)

#: Bytes of one instruction record.  :mod:`repro.binary.encoding` imports
#: this module (not the other way around), derives its own size from the
#: struct layout, and asserts the two agree.
RECORD_SIZE = 24


class TextCodec:
    """Arch-specific packing of (records, control words) into text bytes."""

    #: registry name of the owning architecture
    name: str = "abstract"
    #: bits of control information per instruction
    ctrl_bits: int = 0

    def pack_ctrl(self, ctrl: Ctrl) -> int:
        raise NotImplementedError

    def unpack_ctrl(self, word: int) -> Ctrl:
        raise NotImplementedError

    def text_size(self, n_instrs: int) -> int:
        """Exact byte size of a text section holding ``n_instrs``."""
        raise NotImplementedError

    def instr_addr(self, index: int) -> int:
        """Byte offset of instruction ``index`` within its text section."""
        raise NotImplementedError

    def encode_text_section(
        self, records: Sequence[bytes], ctrls: Sequence[Ctrl]
    ) -> bytes:
        raise NotImplementedError

    def decode_text_section(
        self, data: bytes, n_instrs: int
    ) -> Tuple[List[Ctrl], List[bytes]]:
        """Inverse of :meth:`encode_text_section`: ``(ctrls, records)``."""
        raise NotImplementedError


class MaxwellCodec(TextCodec):
    """Maxwell/Pascal: 21-bit control words bundled 3-per-64-bit."""

    name = "maxwell"
    ctrl_bits = CTRL_BITS
    bundle_group = BUNDLE_GROUP
    #: bytes of one text-section group: control bundle + three records
    group_size = 8 + BUNDLE_GROUP * RECORD_SIZE

    def pack_ctrl(self, ctrl: Ctrl) -> int:
        return pack_ctrl(ctrl)

    def unpack_ctrl(self, word: int) -> Ctrl:
        return unpack_ctrl(word)

    def text_size(self, n_instrs: int) -> int:
        n_groups = (n_instrs + BUNDLE_GROUP - 1) // BUNDLE_GROUP
        return n_groups * self.group_size

    def instr_addr(self, index: int) -> int:
        g, slot = divmod(index, BUNDLE_GROUP)
        return g * self.group_size + 8 + slot * RECORD_SIZE

    def encode_text_section(
        self, records: Sequence[bytes], ctrls: Sequence[Ctrl]
    ) -> bytes:
        from .ctrlwords import pack_stream

        bundles = pack_stream(ctrls)
        out = bytearray()
        for g, bundle in enumerate(bundles):
            out += struct.pack("<Q", bundle)
            group = records[g * BUNDLE_GROUP : (g + 1) * BUNDLE_GROUP]
            for rec in group:
                out += rec
            # pad the trailing group so every group is group_size bytes
            out += b"\x00" * ((BUNDLE_GROUP - len(group)) * RECORD_SIZE)
        return bytes(out)

    def decode_text_section(
        self, data: bytes, n_instrs: int
    ) -> Tuple[List[Ctrl], List[bytes]]:
        from .ctrlwords import unpack_stream

        n_groups = (n_instrs + BUNDLE_GROUP - 1) // BUNDLE_GROUP
        bundles = [
            struct.unpack_from("<Q", data, g * self.group_size)[0]
            for g in range(n_groups)
        ]
        ctrls = unpack_stream(bundles, n_instrs)
        records: List[bytes] = []
        for i in range(n_instrs):
            off = self.instr_addr(i)
            records.append(data[off : off + RECORD_SIZE])
        return ctrls, records


# ---------------------------------------------------------------------------
# Volta/Turing: in-word control fields (TuringAs layout)
# ---------------------------------------------------------------------------

#: Bit position of the control block within the 128-bit instruction word
#: (TuringAs packs ``ctrl << 105`` into the high bits).
VOLTA_CTRL_BIT_OFFSET = 105

#: The control block's shift within the trailing 8-byte high word.
_HI_SHIFT = VOLTA_CTRL_BIT_OFFSET - 64  # 41

_STALL_MASK = 0xF
_YIELD_BIT = 1 << 4
_WBAR_SHIFT = 5
_RBAR_SHIFT = 8
_WAIT_SHIFT = 11
_REUSE_BITS = 4  # Volta grows the reuse field to 4 bits (unused here)
_VOLTA_CTRL_BITS = 21
_VOLTA_CTRL_MASK = (1 << _VOLTA_CTRL_BITS) - 1


class VoltaCodec(TextCodec):
    """Volta/Turing: 128-bit instructions, control in-word at bit 105.

    Abstract record: ``[24-byte instruction record][8-byte high word]``;
    the high word carries ``pack_ctrl(ctrl) << 41`` (mirroring bits
    105..125 of the real 128-bit instruction).  No bundling, no padding.
    """

    name = "volta"
    ctrl_bits = _VOLTA_CTRL_BITS
    #: bytes per instruction (the abstract stand-in for 128-bit + payload)
    instr_size = RECORD_SIZE + 8

    def __init__(self, num_barriers: int = 6):
        self.num_barriers = num_barriers
        self._wait_mask = (1 << num_barriers) - 1

    def pack_ctrl(self, ctrl: Ctrl) -> int:
        if not 0 <= ctrl.stall <= _STALL_MASK:
            raise CtrlWordError(f"stall {ctrl.stall} out of range 0..15")
        word = ctrl.stall & _STALL_MASK
        # Volta encodes yield directly: bit set means the warp MAY yield
        if ctrl.yield_flag:
            word |= _YIELD_BIT
        for what, bar, shift in (
            ("write", ctrl.write_bar, _WBAR_SHIFT),
            ("read", ctrl.read_bar, _RBAR_SHIFT),
        ):
            if bar is None:
                word |= NO_BARRIER << shift
            else:
                if not 0 <= bar < self.num_barriers:
                    raise CtrlWordError(
                        f"{what} barrier {bar} out of range 0..{self.num_barriers - 1}"
                    )
                word |= bar << shift
        wait = 0
        for b in ctrl.wait:
            if not 0 <= b < self.num_barriers:
                raise CtrlWordError(
                    f"wait barrier {b} out of range 0..{self.num_barriers - 1}"
                )
            wait |= 1 << b
        word |= wait << _WAIT_SHIFT
        return word

    def unpack_ctrl(self, word: int) -> Ctrl:
        if not 0 <= word <= _VOLTA_CTRL_MASK:
            raise CtrlWordError(
                f"control word {word:#x} wider than {_VOLTA_CTRL_BITS} bits"
            )
        wbar = (word >> _WBAR_SHIFT) & 0x7
        rbar = (word >> _RBAR_SHIFT) & 0x7
        wait = (word >> _WAIT_SHIFT) & self._wait_mask
        return Ctrl(
            stall=word & _STALL_MASK,
            yield_flag=bool(word & _YIELD_BIT),
            write_bar=None if wbar == NO_BARRIER else wbar,
            read_bar=None if rbar == NO_BARRIER else rbar,
            wait={b for b in range(self.num_barriers) if wait & (1 << b)},
        )

    def text_size(self, n_instrs: int) -> int:
        return n_instrs * self.instr_size

    def instr_addr(self, index: int) -> int:
        return index * self.instr_size

    def encode_text_section(
        self, records: Sequence[bytes], ctrls: Sequence[Ctrl]
    ) -> bytes:
        if len(records) != len(ctrls):
            raise CtrlWordError(
                f"{len(records)} records for {len(ctrls)} control words"
            )
        out = bytearray()
        for rec, ctrl in zip(records, ctrls):
            out += rec
            out += struct.pack("<Q", self.pack_ctrl(ctrl) << _HI_SHIFT)
        return bytes(out)

    def decode_text_section(
        self, data: bytes, n_instrs: int
    ) -> Tuple[List[Ctrl], List[bytes]]:
        ctrls: List[Ctrl] = []
        records: List[bytes] = []
        for i in range(n_instrs):
            off = i * self.instr_size
            records.append(data[off : off + RECORD_SIZE])
            (hi,) = struct.unpack_from("<Q", data, off + RECORD_SIZE)
            if hi & ~(_VOLTA_CTRL_MASK << _HI_SHIFT):
                raise CtrlWordError(
                    f"instruction {i}: non-control bits set in the high word"
                )
            ctrls.append(self.unpack_ctrl(hi >> _HI_SHIFT))
        return ctrls, records


#: Shared codec instances (codecs are stateless; arches reference these).
MAXWELL_CODEC = MaxwellCodec()
VOLTA_CODEC = VoltaCodec()
