"""SASSOverlay-style annotated disassembly.

Renders a kernel the way ``sassoverlay.py`` augments ``nvdisasm`` output:
each instruction line carries its text-section byte address and a control
column block

    [ stall Y | WRn RDn  wwwwww ]

where ``stall`` is the issue-stall count, ``Y`` marks a yielding slot,
``WRn``/``RDn`` are the write/read scoreboard barriers the instruction
*sets*, and ``wwwwww`` is the 6-bit mask of barriers it *waits* on.  This is
the debugging view for schedule inspection and predictor calibration: stall
chains and barrier round trips are visible at a glance, column-aligned.
"""

from __future__ import annotations

from typing import List, Union

from repro.core.isa import Ctrl, Instr, Kernel, Label

from .archcodec import MAXWELL_CODEC


def format_ctrl_columns(ctrl: Ctrl) -> str:
    """One control word as a fixed-width ``[ .. | .. ]`` column block."""
    stall = str(ctrl.stall)
    y = "Y" if ctrl.yield_flag else " "
    wr = f"WR{ctrl.write_bar}" if ctrl.write_bar is not None else "   "
    rd = f"RD{ctrl.read_bar}" if ctrl.read_bar is not None else "   "
    wait = "".join(
        "1" if b in ctrl.wait else "0" for b in reversed(range(6))
    ) if ctrl.wait else "......"
    return f"[{stall:>2s} {y} | {wr} {rd} {wait} ]"


def _strip_ctrl_comment(rendered: str) -> str:
    """Drop the leading ``/*ww:r:w:y:s*/`` comment from ``Instr.render``."""
    if rendered.startswith("/*"):
        end = rendered.find("*/")
        if end != -1:
            return rendered[end + 2 :].lstrip()
    return rendered


def overlay_lines(
    kernel: Union[Kernel, List[object]], profile=None
) -> List[str]:
    """Annotated disassembly lines for a kernel (or raw item list).

    Addresses and packed control words follow the kernel's architecture
    codec (raw item lists use the Maxwell layout).

    ``profile`` (a :class:`repro.obs.stallprof.StallProfile`, e.g. from
    ``simulate(kernel, profile=True)``) appends a hot-instruction column —
    attributed stall cycles, share of the kernel's total, dominant reason —
    to every line the simulator blamed, turning the schedule view into a
    profile view (``translate --profile``)."""
    items = kernel.items if isinstance(kernel, Kernel) else kernel
    codec = MAXWELL_CODEC
    lines: List[str] = []
    if isinstance(kernel, Kernel):
        from repro.arch import arch_of

        codec = arch_of(kernel).codec
        arch_tag = "" if kernel.arch == "maxwell" else f"arch={kernel.arch} "
        lines.append(
            f"// kernel {kernel.name}  regs={kernel.reg_count} "
            f"threads/block={kernel.threads_per_block} "
            f"smem={kernel.shared_size}+{kernel.demoted_size}B "
            f"{arch_tag}ctrl=[stall Y | WR RD wait]"
        )
        if profile is not None:
            lines.append(
                f"// stall profile: {profile.total} attributed stall cycles "
                "(columns: cycles, share, dominant reason)"
            )
    by_index = profile.by_index() if profile is not None else {}
    body_width = max(
        (len(_strip_ctrl_comment(it.render())) for it in items if isinstance(it, Instr)),
        default=0,
    )
    idx = 0
    for it in items:
        if isinstance(it, Label):
            lines.append(it.render())
            continue
        body = _strip_ctrl_comment(it.render())
        line = (
            f"/*{codec.instr_addr(idx):04x}*/ {body:<{body_width}s}  "
            f"{format_ctrl_columns(it.ctrl)} /*{codec.pack_ctrl(it.ctrl):06x}*/"
        )
        entry = by_index.get(idx)
        if entry is not None:
            line += (
                f"  |{entry.total:>9d} {profile.share(entry):6.1%}"
                f" {entry.top_reason}"
            )
        lines.append(line)
        idx += 1
    return lines


def overlay(kernel: Union[Kernel, List[object]], profile=None) -> str:
    """Annotated disassembly as one string (see :func:`overlay_lines`)."""
    return "\n".join(overlay_lines(kernel, profile=profile))
