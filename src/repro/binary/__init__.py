"""Binary substrate: a pseudo-cubin assembler/disassembler for the abstract ISA.

The paper's pyReDe tool is a *binary* translator: it extracts SASS from a
``.cubin``, rewrites it, and re-inserts the machine code.  This package gives
the reproduction the same substrate — a fixed-width machine encoding of
:mod:`repro.core.isa` instructions, Maxwell-style bundled control words, and a
minimal cubin-like container — so :func:`repro.core.translator.translate` can
run bytes-in / bytes-out instead of operating on the textual rendering.

Modules
-------

* :mod:`repro.binary.ctrlwords`  21-bit control-word packing (stall, yield,
  read/write barrier, wait mask) and 64-bit 3-instruction bundles
* :mod:`repro.binary.encoding`   fixed-width (24-byte) instruction records
* :mod:`repro.binary.container`  pseudo-cubin container: header, section
  table, string table, per-kernel metadata; ``dumps``/``loads``
* :mod:`repro.binary.overlay`    SASSOverlay-style annotated disassembly
* :mod:`repro.binary.roundtrip`  encode/decode self-checks (dataflow
  equivalence + schedule validity + stable re-render)
"""

from .archcodec import (
    MAXWELL_CODEC,
    VOLTA_CODEC,
    MaxwellCodec,
    TextCodec,
    VoltaCodec,
)
from .container import (
    VERSION,
    ContainerError,
    dumps,
    kernel_crc,
    kernel_names,
    loads,
    loads_many,
    read_notes,
)
from .ctrlwords import (
    CTRL_BITS,
    pack_bundle,
    pack_ctrl,
    unpack_bundle,
    unpack_ctrl,
)
from .encoding import (
    INSTR_RECORD_SIZE,
    EncodingError,
    decode_instr,
    decode_text,
    encode_instr,
    encode_text,
)
from .overlay import format_ctrl_columns, overlay, overlay_lines
from .roundtrip import (
    RoundTripError,
    check_roundtrip,
    roundtrip,
    verified_dumps,
    verified_dumps_many,
)

__all__ = [
    "CTRL_BITS",
    "INSTR_RECORD_SIZE",
    "MAXWELL_CODEC",
    "VOLTA_CODEC",
    "VERSION",
    "ContainerError",
    "EncodingError",
    "MaxwellCodec",
    "RoundTripError",
    "TextCodec",
    "VoltaCodec",
    "check_roundtrip",
    "decode_instr",
    "decode_text",
    "dumps",
    "encode_instr",
    "encode_text",
    "format_ctrl_columns",
    "kernel_crc",
    "kernel_names",
    "loads",
    "loads_many",
    "overlay",
    "overlay_lines",
    "pack_bundle",
    "pack_ctrl",
    "read_notes",
    "roundtrip",
    "unpack_bundle",
    "unpack_ctrl",
    "verified_dumps",
    "verified_dumps_many",
]
