"""Pseudo-cubin container: serialize kernels as self-describing binaries.

A real ``.cubin`` is an ELF: header, section table, ``.strtab``, one
``.text.<kernel>`` section per kernel plus ``.nv.info`` metadata (register
count, shared-memory size, parameters).  This container mirrors that shape
at the smallest size that still exercises every pyReDe pipeline step:

========================  ==================================================
region                    contents
========================  ==================================================
header (32 B)             magic, version, section count/offset, kernel
                          count, opcode-table checksum, content checksum
``.kinfo``                one fixed 168-byte record per kernel: name, launch
                          geometry, shared/demoted bytes, declared register
                          count, RDA register, live-in/out bitmasks, tag
                          table
``.text.<kernel>``        bundled control words + instruction records
                          (:mod:`repro.binary.encoding`)
``.labels.<kernel>``      label table: (strtab name, instruction index)
``.strtab``               null-terminated strings (kernel/label/tag names)
section table (16 B/row)  (name, kind, offset, size) per section, ELF-style
                          with a null section at index 0
========================  ==================================================

``dumps``/``loads`` are strict: every structural invariant (magic, version,
opcode-table checksum, section bounds, declared vs. recomputed register
count) is checked on load, so a corrupted or stale container fails loudly
instead of producing a subtly wrong kernel.

Format v2 extends the v1 ``.kinfo`` record with a **per-kernel content
CRC** — :func:`kernel_crc` over the kernel's name, launch metadata,
tag/label tables, and text bytes.  It is the integrity check for each kernel
of a multi-kernel container and the key of the translation cache
(:class:`repro.core.translator.TranslationCache`): two kernels with equal
CRCs translate to byte-identical output, so repeated kernels skip the pass
pipeline entirely.

Format v3 (current) adds a **per-kernel architecture tag** — a strtab
offset naming the :mod:`repro.arch` registry entry the kernel is encoded
for.  The arch determines the text-section codec (Maxwell's bundled
control words vs Volta/Turing's in-word control fields) and everything
downstream (scheduler latencies, occupancy limits, spill budget).  v1 and
v2 containers still load unchanged and default to the ``maxwell`` arch;
writing v1/v2 is only possible for Maxwell kernels (older readers cannot
represent any other arch).
"""

from __future__ import annotations

import struct
import zlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.core.isa import OPCODES, Kernel

from . import encoding

MAGIC = b"RDEMCBN\x01"
VERSION = 3
SUPPORTED_VERSIONS = (1, 2, 3)

#: Section kinds (the ``kind`` column of the section table).
SEC_NULL, SEC_STRTAB, SEC_KINFO, SEC_TEXT, SEC_LABELS, SEC_NOTE = range(6)

_HDR = struct.Struct("<8sHHIHHIII")  # magic, version, n_sections, shoff,
#                                      strtab index, n_kernels, opcode crc,
#                                      file size, content crc
_HDR_PAD = 32 - _HDR.size
_SEC = struct.Struct("<IIII")  # name_off, kind, offset, size
_LBL = struct.Struct("<II")  # name_off, instr_idx
_KINFO_V1 = struct.Struct("<IIIHHIIIIIHH16I32s32s")
_KINFO_V2 = struct.Struct("<IIIHHIIIIIHH16I32s32sI")  # v1 + per-kernel CRC
_KINFO_V3 = struct.Struct("<IIIHHIIIIIHH16I32s32sII")  # v2 + arch name off
_KINFO_BY_VERSION = {1: _KINFO_V1, 2: _KINFO_V2, 3: _KINFO_V3}
KINFO_SIZES = {v: s.size for v, s in _KINFO_BY_VERSION.items()}
KINFO_SIZE = KINFO_SIZES[VERSION]
_NONE16 = 0xFFFF
_MAX_TAGS = 16


class ContainerError(ValueError):
    """Raised on malformed, corrupted, or incompatible container bytes."""


def _get_arch(name: str):
    """Resolve an arch descriptor, mapping unknown names to ContainerError.

    Lazy import: :mod:`repro.arch` pulls in the codec modules of this
    package, so the registry is resolved at call time, not import time.
    """
    from repro.arch import ArchError, get_arch

    try:
        return get_arch(name)
    except ArchError as exc:
        raise ContainerError(str(exc)) from None


def opcode_checksum() -> int:
    """CRC of the ISA opcode table — guards against decoding a container
    produced under a different opcode numbering."""
    return zlib.crc32(",".join(OPCODES).encode()) & 0xFFFFFFFF


def _content_crc(
    name: str,
    threads: int,
    blocks: int,
    shared: int,
    demoted: int,
    reg_count: int,
    rda_enc: int,
    live_in_mask: bytes,
    live_out_mask: bytes,
    tags: Sequence[str],
    labels: Sequence[Tuple[str, int]],
    text: bytes,
    arch: str = "maxwell",
) -> int:
    """The per-kernel content CRC over everything translation can observe.

    Computed from *resolved* strings (never strtab offsets), so the value is
    independent of section layout, sibling kernels, and container version —
    which is what makes it usable as the translation-cache key.  The arch
    tag is mixed in only off-default so that Maxwell CRCs stay identical to
    their historical v2 values (cache keys survive the v3 upgrade)."""
    h = zlib.crc32(name.encode("utf-8"))
    if arch != "maxwell":
        h = zlib.crc32(b"arch:" + arch.encode("utf-8") + b"\x00", h)
    h = zlib.crc32(
        struct.pack("<IIIIIH", threads, blocks, shared, demoted, reg_count, rda_enc), h
    )
    h = zlib.crc32(live_in_mask, h)
    h = zlib.crc32(live_out_mask, h)
    h = zlib.crc32("\x00".join(tags).encode("utf-8"), h)
    for lbl_name, pos in labels:
        h = zlib.crc32(lbl_name.encode("utf-8") + struct.pack("<I", pos), h)
    h = zlib.crc32(text, h)
    return h & 0xFFFFFFFF


def kernel_crc(kernel: Kernel) -> int:
    """Content CRC of one kernel — what a v2+ container stores in ``.kinfo``
    and what keys the translation cache.  Equal CRCs mean the binary
    translator produces byte-identical output."""
    arch = getattr(kernel, "arch", "maxwell")
    codec = _get_arch(arch).codec
    tags = encoding.collect_tags(kernel.items)
    text, labels = encoding.encode_text(kernel.items, tags, codec=codec)
    return _content_crc(
        kernel.name,
        kernel.threads_per_block,
        kernel.num_blocks,
        kernel.shared_size,
        kernel.demoted_size,
        kernel.reg_count,
        _NONE16 if kernel.rda is None else kernel.rda,
        _regmask(kernel.live_in),
        _regmask(kernel.live_out),
        tags,
        labels,
        text,
        arch,
    )


def _regmask(regs: Iterable[int]) -> bytes:
    mask = 0
    for r in regs:
        if not 0 <= r <= 255:
            raise ContainerError(f"register R{r} out of bitmask range")
        mask |= 1 << r
    return mask.to_bytes(32, "little")


def _unmask(mask: bytes) -> set:
    value = int.from_bytes(mask, "little")
    return {r for r in range(256) if value & (1 << r)}


class _StrTab:
    """Deduplicating null-terminated string table (offset 0 = empty)."""

    def __init__(self) -> None:
        self.blob = bytearray(b"\x00")
        self.offsets: Dict[str, int] = {"": 0}

    def add(self, s: str) -> int:
        if s not in self.offsets:
            self.offsets[s] = len(self.blob)
            self.blob += s.encode("utf-8") + b"\x00"
        return self.offsets[s]

    @staticmethod
    def read(blob: bytes, off: int) -> str:
        if off >= len(blob):
            raise ContainerError(f"string offset {off} past strtab end")
        end = blob.find(b"\x00", off)
        if end == -1:
            raise ContainerError(f"unterminated string at strtab offset {off}")
        try:
            return blob[off:end].decode("utf-8")
        except UnicodeDecodeError as exc:
            raise ContainerError(
                f"corrupt string at strtab offset {off}: {exc}"
            ) from None


def dumps(
    kernels: Union[Kernel, Iterable[Kernel]],
    version: int = VERSION,
    notes: Optional[Dict[str, bytes]] = None,
) -> bytes:
    """Serialize one kernel (or an iterable of kernels) to container bytes.

    ``version`` selects the container format (v3 default; v1/v2 write the
    legacy records — no arch tag, v1 also no per-kernel CRC — for interop
    tests, and can only represent Maxwell kernels).

    ``notes`` attaches opaque metadata blobs as ``.note.<name>`` sections
    (ELF ``.note``-style), emitted in sorted name order for byte-stable
    output.  Notes ride outside the kernel records: they never affect a
    kernel's content CRC or decoding (every reader skips unknown section
    kinds), but the container-level content checksum covers them.  The
    translation service stores each tuned kernel's search report this way;
    :func:`read_notes` retrieves them."""
    if version not in SUPPORTED_VERSIONS:
        raise ContainerError(f"cannot write container version {version}")
    klist = [kernels] if isinstance(kernels, Kernel) else list(kernels)
    if not klist:
        raise ContainerError("cannot serialize an empty kernel list")

    strtab = _StrTab()
    # section rows accumulate as (name, kind, payload); offsets assigned below
    sections: List[Tuple[str, int, bytes]] = [("", SEC_NULL, b"")]
    kinfo_records: List[bytes] = []

    for kernel in klist:
        # the tag is stored VERBATIM (aliases like "turing" included) so the
        # decoded kernel round-trips render- and byte-identically; behaviour
        # always resolves through the registry, which knows the aliases
        arch_name = getattr(kernel, "arch", "maxwell")
        arch = _get_arch(arch_name)
        if version < 3 and arch_name != "maxwell":
            # pre-v3 containers have no arch field: a legacy reader would
            # load this kernel as literal "maxwell", silently dropping the
            # tag (and, for v2, invalidating the stored CRC) — so even
            # maxwell *aliases* like "pascal" require v3
            raise ContainerError(
                f"kernel {kernel.name}: container version {version} cannot "
                f"represent arch {arch_name!r} (v3 required)"
            )
        codec = arch.codec
        tags = encoding.collect_tags(kernel.items)
        text, labels = encoding.encode_text(kernel.items, tags, codec=codec)
        text_sec = len(sections) + 1  # +1: .kinfo is inserted at index 1
        sections.append((f".text.{kernel.name}", SEC_TEXT, text))
        lbl_blob = b"".join(
            _LBL.pack(strtab.add(name), pos) for name, pos in labels
        )
        sections.append((f".labels.{kernel.name}", SEC_LABELS, lbl_blob))

        tag_offs = [strtab.add(t) for t in tags] + [0] * (_MAX_TAGS - len(tags))
        rda_enc = _NONE16 if kernel.rda is None else kernel.rda
        live_in_mask = _regmask(kernel.live_in)
        live_out_mask = _regmask(kernel.live_out)
        fields = (
            strtab.add(kernel.name),
            len(kernel.instructions()),
            len(labels),
            text_sec,
            text_sec + 1,
            kernel.threads_per_block,
            kernel.num_blocks,
            kernel.shared_size,
            kernel.demoted_size,
            kernel.reg_count,
            rda_enc,
            len(tags),
            *tag_offs,
            live_in_mask,
            live_out_mask,
        )
        if version >= 2:
            crc = _content_crc(
                kernel.name,
                kernel.threads_per_block,
                kernel.num_blocks,
                kernel.shared_size,
                kernel.demoted_size,
                kernel.reg_count,
                rda_enc,
                live_in_mask,
                live_out_mask,
                tags,
                labels,
                text,
                arch_name,
            )
            fields = fields + (crc,)
        if version >= 3:
            fields = fields + (strtab.add(arch_name),)
        kinfo_records.append(_KINFO_BY_VERSION[version].pack(*fields))

    for note_name in sorted(notes or {}):
        payload = notes[note_name]
        if not isinstance(payload, (bytes, bytearray, memoryview)):
            raise ContainerError(f"note {note_name!r}: payload must be bytes")
        sections.append((f".note.{note_name}", SEC_NOTE, bytes(payload)))

    sections.insert(1, (".kinfo", SEC_KINFO, b"".join(kinfo_records)))
    sections.append((".strtab", SEC_STRTAB, b""))  # payload patched below
    strtab_index = len(sections) - 1

    # resolve section names through the strtab *before* freezing its payload
    name_offs = [strtab.add(name) for name, _, _ in sections]
    sections[strtab_index] = (".strtab", SEC_STRTAB, bytes(strtab.blob))

    offset = 32  # header
    rows: List[bytes] = []
    payload = bytearray()
    for (name, kind, data), name_off in zip(sections, name_offs):
        rows.append(_SEC.pack(name_off, kind, offset if data else 0, len(data)))
        payload += data
        offset += len(data)
    shoff = offset
    total = shoff + len(rows) * _SEC.size

    body = bytes(payload) + b"".join(rows)
    header = _HDR.pack(
        MAGIC,
        version,
        len(sections),
        shoff,
        strtab_index,
        len(klist),
        opcode_checksum(),
        total,
        zlib.crc32(body) & 0xFFFFFFFF,
    ) + b"\x00" * _HDR_PAD
    return header + body


def _parse_sections(data: bytes) -> Tuple[List[Tuple[str, int, bytes]], int, int]:
    """Validate the envelope and return ``[(name, kind, payload)]``, the
    kernel count, and the container version."""
    if len(data) < 32:
        raise ContainerError("container truncated before header")
    (magic, version, n_sections, shoff, strtab_index, n_kernels, opc_crc, total,
     content_crc) = _HDR.unpack(data[: _HDR.size])
    if magic != MAGIC:
        raise ContainerError(f"bad magic {magic!r}")
    if version not in SUPPORTED_VERSIONS:
        raise ContainerError(f"unsupported container version {version}")
    if opc_crc != opcode_checksum():
        raise ContainerError(
            "opcode-table checksum mismatch: container was produced under a "
            "different ISA opcode numbering"
        )
    if total != len(data):
        raise ContainerError(f"container size mismatch: header says {total}, got {len(data)}")
    if zlib.crc32(data[32:]) & 0xFFFFFFFF != content_crc:
        raise ContainerError("content checksum mismatch: container is corrupted")
    if shoff + n_sections * _SEC.size > len(data):
        raise ContainerError("section table out of bounds")
    raw_rows = [
        _SEC.unpack_from(data, shoff + i * _SEC.size) for i in range(n_sections)
    ]
    if not 0 <= strtab_index < n_sections or raw_rows[strtab_index][1] != SEC_STRTAB:
        raise ContainerError("bad strtab section index")
    for name_off, kind, offset, size in raw_rows:
        if size and not 32 <= offset <= len(data) - size:
            raise ContainerError("section payload out of bounds")
    s_off, s_size = raw_rows[strtab_index][2], raw_rows[strtab_index][3]
    strtab = data[s_off : s_off + s_size]
    out = []
    for name_off, kind, offset, size in raw_rows:
        out.append((_StrTab.read(strtab, name_off), kind, data[offset : offset + size]))
    return out, n_kernels, version


def loads_many(data: bytes) -> List[Kernel]:
    """Deserialize every kernel in the container (any supported version)."""
    sections, n_kernels, version = _parse_sections(data)
    kinfo_struct = _KINFO_BY_VERSION[version]
    kinfo_size = kinfo_struct.size
    strtab = next(payload for _, kind, payload in sections if kind == SEC_STRTAB)
    kinfo = next((payload for _, kind, payload in sections if kind == SEC_KINFO), None)
    if kinfo is None:
        raise ContainerError("container has no .kinfo section")
    if len(kinfo) != n_kernels * kinfo_size:
        raise ContainerError(
            f".kinfo holds {len(kinfo)} bytes, expected {n_kernels * kinfo_size}"
        )

    kernels: List[Kernel] = []
    for i in range(n_kernels):
        rec = kinfo_struct.unpack_from(kinfo, i * kinfo_size)
        (name_off, n_instrs, n_labels, text_sec, labels_sec,
         threads, blocks, shared, demoted, reg_count, rda, n_tags) = rec[:12]
        tag_offs = rec[12:28]
        live_in_mask, live_out_mask = rec[28], rec[29]
        stored_crc = rec[30] if version >= 2 else None
        # pre-v3 containers predate the arch registry: always Maxwell.  The
        # stored tag (possibly an alias) is preserved verbatim on the kernel
        # so dump/load/dump is byte-identity; the descriptor resolves it.
        arch_name = _StrTab.read(strtab, rec[31]) if version >= 3 else "maxwell"
        arch = _get_arch(arch_name)
        if not 0 < n_tags <= _MAX_TAGS:
            raise ContainerError(f"bad tag-table size {n_tags}")
        tags = [_StrTab.read(strtab, off) for off in tag_offs[:n_tags]]
        if not 0 <= text_sec < len(sections) or sections[text_sec][1] != SEC_TEXT:
            raise ContainerError(f"kernel {i}: bad text section index {text_sec}")
        if not 0 <= labels_sec < len(sections) or sections[labels_sec][1] != SEC_LABELS:
            raise ContainerError(f"kernel {i}: bad label section index {labels_sec}")
        lbl_blob = sections[labels_sec][2]
        if len(lbl_blob) != n_labels * _LBL.size:
            raise ContainerError(f"kernel {i}: label table size mismatch")
        labels = []
        for j in range(n_labels):
            noff, pos = _LBL.unpack_from(lbl_blob, j * _LBL.size)
            if pos > n_instrs:
                raise ContainerError(f"kernel {i}: label position {pos} past end")
            labels.append((_StrTab.read(strtab, noff), pos))

        name = _StrTab.read(strtab, name_off)
        if stored_crc is not None:
            # per-kernel integrity, checked on the raw section bytes *before*
            # any decoding work is spent on a corrupt kernel
            recomputed = _content_crc(
                name, threads, blocks, shared, demoted, reg_count, rda,
                live_in_mask, live_out_mask, tags, labels, sections[text_sec][2],
                arch_name,
            )
            if recomputed != stored_crc:
                raise ContainerError(
                    f"kernel {name}: per-kernel content CRC mismatch "
                    f"(stored {stored_crc:#010x}, recomputed {recomputed:#010x})"
                )

        # decode failures on corrupt-but-checksum-consistent bytes (or v1
        # containers, which have no per-kernel CRC) must surface as the
        # container's own error type, never a raw struct/IndexError
        # traceback from deep inside the codec
        try:
            items = encoding.decode_text(
                sections[text_sec][2], n_instrs, labels, tags, codec=arch.codec
            )
        except ContainerError:
            raise
        except (encoding.EncodingError, struct.error, IndexError, KeyError,
                ValueError, UnicodeDecodeError) as exc:
            raise ContainerError(
                f"kernel {name}: corrupt text section: {exc}"
            ) from None
        kernel = Kernel(
            name=name,
            items=items,
            threads_per_block=threads,
            num_blocks=blocks,
            shared_size=shared,
            demoted_size=demoted,
            live_in=_unmask(live_in_mask),
            live_out=_unmask(live_out_mask),
            rda=None if rda == _NONE16 else rda,
            arch=arch_name,
        )
        if kernel.reg_count != reg_count:
            raise ContainerError(
                f"kernel {kernel.name}: declared reg count {reg_count} != "
                f"recomputed {kernel.reg_count}"
            )
        if stored_crc is not None:
            # hand the verified CRC to consumers (the translation cache keys
            # on it) so they need not re-encode the kernel to recompute it
            kernel.content_crc = stored_crc
        kernels.append(kernel)
    return kernels


def loads(data: bytes) -> Kernel:
    """Deserialize a single-kernel container."""
    kernels = loads_many(data)
    if len(kernels) != 1:
        raise ContainerError(
            f"expected a single-kernel container, found {len(kernels)} "
            "(use loads_many)"
        )
    return kernels[0]


def read_notes(data: bytes) -> Dict[str, bytes]:
    """Metadata blobs attached with ``dumps(..., notes=...)``, keyed by note
    name (the section name minus its ``.note.`` prefix)."""
    sections, _, _ = _parse_sections(data)
    notes: Dict[str, bytes] = {}
    for name, kind, payload in sections:
        if kind == SEC_NOTE:
            notes[name[len(".note."):]] = payload
    return notes


def kernel_names(data: bytes) -> List[str]:
    """Kernel names in the container, without decoding any text section."""
    sections, n_kernels, version = _parse_sections(data)
    size = KINFO_SIZES[version]
    strtab = next(payload for _, kind, payload in sections if kind == SEC_STRTAB)
    kinfo = next((payload for _, kind, payload in sections if kind == SEC_KINFO), None)
    if kinfo is None or len(kinfo) != n_kernels * size:
        raise ContainerError("malformed .kinfo section")
    kinfo_struct = _KINFO_BY_VERSION[version]
    return [
        _StrTab.read(strtab, kinfo_struct.unpack_from(kinfo, i * size)[0])
        for i in range(n_kernels)
    ]
