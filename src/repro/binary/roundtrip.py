"""Encode/decode self-checks for the binary substrate.

The translator's guarantee is *semantic*: a container emitted by pyReDe must
decode to a kernel that is dataflow-equivalent to what was encoded, carry an
identical schedule, and re-render to the identical SASS text.  This module
is that oracle; :func:`repro.core.translator.translate` calls it on every
container it emits, and the test suite runs it over the whole kernelgen
corpus.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence

from repro.core.isa import Kernel, equivalent
from repro.core.sched import verify_schedule

from .container import dumps, loads, loads_many


class RoundTripError(AssertionError):
    """A container failed the encode/decode self-check."""


def roundtrip(kernel: Kernel) -> Kernel:
    """``loads(dumps(kernel))`` — one trip through the container."""
    return loads(dumps(kernel))


def verified_dumps(kernel: Kernel, check_semantics: bool = True) -> bytes:
    """Serialize the kernel and prove the container round trip is faithful;
    returns the verified container bytes.

    Checks, strongest first:

    1. the re-rendered SASS text is byte-identical (control words included),
       so encode/decode is the identity on the observable program;
    2. the decoded kernel re-encodes to the identical container bytes
       (serialization is deterministic and stable);
    3. schedule validity is preserved exactly (same violation list, which is
       empty for anything the translator emits);
    4. optionally, the decoded kernel is dataflow-equivalent on the
       interpreter — the same oracle the translator applies to demotion.
    """
    blob = dumps(kernel)
    _check_against(kernel, blob, check_semantics)
    return blob


def verified_dumps_many(
    kernels: Sequence[Kernel],
    check_semantics: bool = True,
    notes: Optional[Dict[str, bytes]] = None,
) -> bytes:
    """Multi-kernel :func:`verified_dumps`: serialize the batch into one
    container and prove the round trip is faithful for **every** kernel
    (render identity, byte stability, schedule preservation, and optionally
    dataflow equivalence); returns the verified container bytes.

    ``notes`` are attached as ``.note.*`` sections and take part in the
    byte-stability check (re-encoding the decoded kernels with the same
    notes must reproduce the container bit for bit)."""
    klist = list(kernels)
    blob = dumps(klist, notes=notes)
    decoded = loads_many(blob)
    if len(decoded) != len(klist):
        raise RoundTripError(
            f"container holds {len(decoded)} kernels, expected {len(klist)}"
        )
    for kernel, dec in zip(klist, decoded):
        _check_pair(kernel, dec, check_semantics)
    if dumps(decoded, notes=notes) != blob:
        raise RoundTripError("multi-kernel container bytes are not stable")
    return blob


def check_roundtrip(kernel: Kernel, check_semantics: bool = True) -> Kernel:
    """Assert the container round trip is faithful (see
    :func:`verified_dumps`); returns the decoded kernel."""
    blob = dumps(kernel)
    return _check_against(kernel, blob, check_semantics)


def _check_pair(kernel: Kernel, decoded: Kernel, check_semantics: bool) -> None:
    if decoded.render() != kernel.render():
        raise RoundTripError(
            f"{kernel.name}: decode(encode(k)) renders differently:\n"
            f"--- original ---\n{kernel.render()}\n"
            f"--- decoded ---\n{decoded.render()}"
        )
    if verify_schedule(decoded) != verify_schedule(kernel):
        raise RoundTripError(
            f"{kernel.name}: schedule violations changed across round trip"
        )
    if check_semantics and not equivalent(kernel, decoded):
        raise RoundTripError(f"{kernel.name}: decoded kernel is not dataflow-equivalent")


def _check_against(kernel: Kernel, blob: bytes, check_semantics: bool) -> Kernel:
    decoded = loads(blob)
    if decoded.render() != kernel.render():
        raise RoundTripError(
            f"{kernel.name}: decode(encode(k)) renders differently:\n"
            f"--- original ---\n{kernel.render()}\n"
            f"--- decoded ---\n{decoded.render()}"
        )
    if dumps(decoded) != blob:
        raise RoundTripError(f"{kernel.name}: container bytes are not stable")
    if verify_schedule(decoded) != verify_schedule(kernel):
        raise RoundTripError(f"{kernel.name}: schedule violations changed across round trip")
    if check_semantics and not equivalent(kernel, decoded):
        raise RoundTripError(f"{kernel.name}: decoded kernel is not dataflow-equivalent")
    return decoded
