"""Fixed-width machine encoding of abstract-ISA instructions.

Real Maxwell packs one instruction into a 64-bit word and bundles 21 bits of
control per instruction into a preceding 64-bit control word (three
instructions per bundle).  The abstract ISA carries more per-instruction
payload than fits in 64 bits (a full float64 immediate, a 32-bit address
offset, trip-count metadata), so the record here is 24 bytes — but the
*shape* of the text section is kept faithful: groups of one 8-byte control
bundle followed by its three instruction records.

Instruction record layout (little-endian, 24 bytes):

======  ====  ======================================================
offset  size  field
======  ====  ======================================================
0       1     opcode index (into the sorted :data:`OPCODE_IDS` table)
1       1     flags: bit0 has_imm, bit1 has_target, bit2 has_pred,
              bit3 pred_neg, bit4 has_pdst, bit5 has_trip
2       1     pred (low nibble) | pdst (high nibble)
3       1     n_src (bits 0-1) | n_dst (bit 2) | tag index (bits 3-6)
4       4     dst, src0, src1, src2 register numbers (RZ = 255)
8       4     memory offset immediate (unsigned)
12      2     branch-target label index (0xffff = none)
14      2     loop trip count (0xffff = none)
16      8     float64 immediate
======  ====  ======================================================

The encoder is strict: any instruction the record cannot represent exactly
raises :class:`EncodingError` rather than silently truncating — the
round-trip self check (:mod:`repro.binary.roundtrip`) depends on encode
being injective.
"""

from __future__ import annotations

import struct
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.isa import OPCODES, Instr, Label

from .archcodec import MAXWELL_CODEC, RECORD_SIZE as _CODEC_RECORD_SIZE, TextCodec
from .ctrlwords import BUNDLE_GROUP

#: Stable opcode numbering: insertion order of the ISA opcode table.
OPCODE_IDS: Dict[str, int] = {name: i for i, name in enumerate(OPCODES)}
OPCODE_NAMES: List[str] = list(OPCODES)

#: Documented provenance tags (isa.Instr.tag); containers may extend this
#: per kernel for tags introduced by future transformations.
DEFAULT_TAGS: Tuple[str, ...] = (
    "orig",
    "demoted_load",
    "demoted_store",
    "remat",
    "spill_load",
    "spill_store",
)

_REC = struct.Struct("<BBBBBBBBIHHd")
INSTR_RECORD_SIZE = _REC.size  # 24
assert INSTR_RECORD_SIZE == 24 == _CODEC_RECORD_SIZE

#: Bytes of one text-section group: control bundle + three records.
GROUP_SIZE = 8 + BUNDLE_GROUP * INSTR_RECORD_SIZE

_F_IMM = 1 << 0
_F_TARGET = 1 << 1
_F_PRED = 1 << 2
_F_PRED_NEG = 1 << 3
_F_PDST = 1 << 4
_F_TRIP = 1 << 5

_NONE16 = 0xFFFF
_MAX_SRCS = 3
_MAX_TAGS = 16


class EncodingError(ValueError):
    """Raised when an instruction cannot be represented in the record."""


def _check(cond: bool, ins: Instr, what: str) -> None:
    if not cond:
        raise EncodingError(f"{ins.render()}: {what}")


def encode_instr(
    ins: Instr,
    label_index: Mapping[str, int],
    tags: Sequence[str] = DEFAULT_TAGS,
) -> bytes:
    """Encode one instruction into its 24-byte record.

    ``label_index`` maps branch-target label names to label-table indices;
    ``tags`` is the per-kernel tag table the record's tag field indexes.
    """
    opcode = OPCODE_IDS.get(ins.op)
    _check(opcode is not None, ins, f"unknown opcode {ins.op!r}")
    _check(len(ins.dsts) <= 1, ins, f"{len(ins.dsts)} destinations (max 1)")
    _check(len(ins.srcs) <= _MAX_SRCS, ins, f"{len(ins.srcs)} sources (max {_MAX_SRCS})")
    for r in ins.dsts + ins.srcs:
        _check(0 <= r <= 255, ins, f"register R{r} out of range")
    _check(0 <= ins.offset < (1 << 32), ins, f"offset {ins.offset:#x} out of range")

    flags = 0
    if ins.imm is not None:
        flags |= _F_IMM
    target = _NONE16
    if ins.target is not None:
        flags |= _F_TARGET
        if ins.target not in label_index:
            raise EncodingError(f"{ins.render()}: dangling branch target {ins.target!r}")
        target = label_index[ins.target]
        _check(target < _NONE16, ins, "label index out of range")
    pred = 0
    if ins.pred is not None:
        flags |= _F_PRED
        _check(0 <= ins.pred <= 15, ins, f"predicate P{ins.pred} out of range")
        pred = ins.pred
        if ins.pred_neg:
            flags |= _F_PRED_NEG
    pdst = 0
    if ins.pdst is not None:
        flags |= _F_PDST
        _check(0 <= ins.pdst <= 15, ins, f"predicate dst P{ins.pdst} out of range")
        pdst = ins.pdst
    trip = _NONE16
    if ins.trip_count is not None:
        flags |= _F_TRIP
        _check(0 <= ins.trip_count < _NONE16, ins, f"trip count {ins.trip_count} out of range")
        trip = ins.trip_count
    try:
        tag_idx = tags.index(ins.tag)
    except ValueError:
        raise EncodingError(f"{ins.render()}: tag {ins.tag!r} not in tag table {tags}")
    _check(tag_idx < _MAX_TAGS, ins, "tag table overflow")

    shape = len(ins.srcs) | (len(ins.dsts) << 2) | (tag_idx << 3)
    regs = (ins.dsts + [0])[:1] + ins.srcs + [0] * (_MAX_SRCS - len(ins.srcs))
    return _REC.pack(
        opcode,
        flags,
        pred | (pdst << 4),
        shape,
        regs[0],
        regs[1],
        regs[2],
        regs[3],
        ins.offset,
        target,
        trip,
        ins.imm if ins.imm is not None else 0.0,
    )


def decode_instr(
    record: bytes,
    label_names: Sequence[str],
    tags: Sequence[str] = DEFAULT_TAGS,
) -> Instr:
    """Decode one 24-byte record (inverse of :func:`encode_instr`).

    The control word is *not* part of the record; callers overlay it from
    the text section's bundles (see :func:`decode_text`).
    """
    if len(record) != INSTR_RECORD_SIZE:
        raise EncodingError(f"record must be {INSTR_RECORD_SIZE} bytes, got {len(record)}")
    (opcode, flags, predbyte, shape, dst, s0, s1, s2, offset, target, trip, imm) = _REC.unpack(record)
    if opcode >= len(OPCODE_NAMES):
        raise EncodingError(f"bad opcode index {opcode}")
    n_src = shape & 0x3
    n_dst = (shape >> 2) & 0x1
    tag_idx = (shape >> 3) & 0xF
    if tag_idx >= len(tags):
        raise EncodingError(f"bad tag index {tag_idx} for tag table {tags}")
    ins = Instr(op=OPCODE_NAMES[opcode])
    ins.dsts = [dst][:n_dst]
    ins.srcs = [s0, s1, s2][:n_src]
    ins.offset = offset
    ins.tag = tags[tag_idx]
    if flags & _F_IMM:
        ins.imm = imm
    if flags & _F_TARGET:
        if target >= len(label_names):
            raise EncodingError(f"bad label index {target}")
        ins.target = label_names[target]
    if flags & _F_PRED:
        ins.pred = predbyte & 0xF
        ins.pred_neg = bool(flags & _F_PRED_NEG)
    if flags & _F_PDST:
        ins.pdst = predbyte >> 4
    if flags & _F_TRIP:
        ins.trip_count = trip
    return ins


# ---------------------------------------------------------------------------
# Text sections: bundled control words + instruction records
# ---------------------------------------------------------------------------


def collect_tags(items: Sequence[object]) -> List[str]:
    """Per-kernel tag table: documented tags first, then any novel ones."""
    tags = list(DEFAULT_TAGS)
    for it in items:
        if isinstance(it, Instr) and it.tag not in tags:
            tags.append(it.tag)
    if len(tags) > _MAX_TAGS:
        raise EncodingError(f"more than {_MAX_TAGS} distinct instruction tags")
    return tags


def encode_text(
    items: Sequence[object],
    tags: Optional[Sequence[str]] = None,
    codec: Optional[TextCodec] = None,
) -> Tuple[bytes, List[Tuple[str, int]]]:
    """Encode an item stream (instructions + labels) into a text section.

    Returns ``(text_bytes, labels)`` where ``labels`` is the label table:
    ``(name, instruction_index)`` pairs, the index being the position of the
    first instruction *after* the label (``n_instrs`` for trailing labels).
    Labels live in the container's label section, not in the text bytes —
    exactly how a cubin keeps symbols out of ``.text``.

    ``codec`` chooses the architecture's text layout (control-word packing
    and record geometry; default: Maxwell's bundled layout).
    """
    if tags is None:
        tags = collect_tags(items)
    if codec is None:
        codec = MAXWELL_CODEC
    instrs = [it for it in items if isinstance(it, Instr)]
    labels: List[Tuple[str, int]] = []
    pos = 0
    for it in items:
        if isinstance(it, Label):
            labels.append((it.name, pos))
        elif isinstance(it, Instr):
            pos += 1
        else:
            raise EncodingError(f"unencodable item {it!r}")
    label_index = {}
    for i, (name, _) in enumerate(labels):
        label_index.setdefault(name, i)

    records = [encode_instr(ins, label_index, tags) for ins in instrs]
    out = codec.encode_text_section(records, [ins.ctrl for ins in instrs])
    return out, labels


def decode_text(
    data: bytes,
    n_instrs: int,
    labels: Sequence[Tuple[str, int]],
    tags: Sequence[str] = DEFAULT_TAGS,
    codec: Optional[TextCodec] = None,
) -> List[object]:
    """Decode a text section back into the item stream (inverse of
    :func:`encode_text`)."""
    if codec is None:
        codec = MAXWELL_CODEC
    if len(data) != codec.text_size(n_instrs):
        raise EncodingError(
            f"text section is {len(data)} bytes; {n_instrs} instructions "
            f"need {codec.text_size(n_instrs)} ({codec.name} layout)"
        )
    ctrls, records = codec.decode_text_section(data, n_instrs)
    label_names = [name for name, _ in labels]
    instrs: List[Instr] = []
    for i in range(n_instrs):
        ins = decode_instr(records[i], label_names, tags)
        ins.ctrl = ctrls[i]
        instrs.append(ins)

    items: List[object] = []
    by_pos: Dict[int, List[str]] = {}
    for name, pos in labels:
        by_pos.setdefault(pos, []).append(name)
    for i, ins in enumerate(instrs):
        for name in by_pos.get(i, []):
            items.append(Label(name))
        items.append(ins)
    for name in by_pos.get(n_instrs, []):
        items.append(Label(name))
    return items


def instr_addr(index: int, codec: Optional[TextCodec] = None) -> int:
    """Byte offset of instruction ``index`` within its text section
    (Maxwell's bundled layout unless another arch codec is given)."""
    return (codec or MAXWELL_CODEC).instr_addr(index)
