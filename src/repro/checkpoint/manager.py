"""Checkpointing: atomic, async, keep-k, reshard-on-restore.

Designed for the fault-tolerance contract of the trainer:

* **atomicity** — arrays are written to ``step_<n>.tmp`` and renamed only
  after a manifest (pytree structure + shapes + dtypes + data-batch index)
  is fully written, so a crash mid-save never corrupts the latest
  checkpoint;
* **async** — ``save()`` snapshots arrays to host memory synchronously
  (cheap) and writes to disk on a worker thread, overlapping I/O with the
  next training steps; ``wait()`` joins before the next save or exit;
* **keep-k GC** — older checkpoints beyond ``keep`` are deleted after a
  successful save;
* **elastic restore** — ``restore`` takes target shardings (possibly for a
  *different* mesh than the save-time mesh) and ``device_put``s each leaf
  accordingly: checkpoint + new mesh = resharded job, which is the
  elastic-rescale path.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any

_SEP = "/"


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_tree(path: str, tree: Pytree, extra: Optional[Dict[str, Any]] = None) -> None:
    """Synchronous atomic save of a pytree of arrays."""
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    manifest = {"leaves": [], "extra": extra or {}}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype.kind not in "biufc":  # extension dtype (bfloat16, fp8…)
            arr = arr.view(f"u{arr.dtype.itemsize}")  # raw-bits container
        fname = key.replace(_SEP, "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": dtype_str}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)


def restore_tree(
    path: str,
    like: Pytree,
    shardings: Optional[Pytree] = None,
) -> Tuple[Pytree, Dict[str, Any]]:
    """Restore into the structure of ``like``; optionally reshard leaves.

    ``shardings`` may target a different mesh than the checkpoint was saved
    under (elastic restore) — each leaf is host-loaded then placed.
    """
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (
        jax.tree.leaves(
            shardings,
            is_leaf=lambda s: isinstance(s, jax.sharding.Sharding),
        )
        if shardings is not None
        else [None] * len(flat)
    )
    leaves = []
    for (pathk, leaf), shard in zip(flat, shard_flat):
        key = _SEP.join(_path_str(p) for p in pathk)
        entry = by_key.get(key)
        if entry is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, entry["file"]))
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected {np.shape(leaf)}"
            )
        if str(arr.dtype) != entry["dtype"]:
            # extension dtypes (bfloat16) need ml_dtypes-aware resolution
            import ml_dtypes

            try:
                target = np.dtype(entry["dtype"])
            except TypeError:
                target = np.dtype(getattr(ml_dtypes, entry["dtype"]))
            if arr.dtype.itemsize == target.itemsize and arr.dtype.kind in "uV":
                arr = arr.view(target)  # raw-bits container round trip
            else:
                arr = arr.astype(target)
        if shard is not None:
            leaves.append(jax.device_put(arr, shard))
        else:
            leaves.append(jax.device_put(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ------------------------------------------------------------------

    def save(self, step: int, tree: Pytree, extra: Optional[Dict[str, Any]] = None,
             async_: bool = True) -> None:
        self.wait()
        # snapshot to host memory before returning control to training
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        extra = dict(extra or {}, step=step)
        path = self._path(step)

        def work():
            try:
                save_tree(path, host, extra)
                self._gc()
            except BaseException as e:  # pragma: no cover - surfaced in wait()
                self._error = e

        if async_:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()
        else:
            work()
            self._raise_if_failed()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        self._raise_if_failed()

    def _raise_if_failed(self):
        if self._error is not None:
            err, self._error = self._error, None
            raise RuntimeError("async checkpoint save failed") from err

    # -- restore -----------------------------------------------------------------

    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return max(steps) if steps else None

    def restore(
        self, like: Pytree, step: Optional[int] = None, shardings: Optional[Pytree] = None
    ) -> Tuple[Pytree, Dict[str, Any]]:
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.directory}")
        return restore_tree(self._path(step), like, shardings)

    # -- misc --------------------------------------------------------------------

    def _path(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1])
            for n in os.listdir(self.directory)
            if n.startswith("step_") and not n.endswith(".tmp")
        )
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(self._path(s), ignore_errors=True)
