"""Logical-axis sharding rules (GSPMD / MaxText style).

Every parameter and activation carries a tuple of *logical* axis names; a
per-run rule table maps logical names to mesh axes.  The production meshes
(:mod:`repro.launch.mesh`) expose axes ``("data", "model")`` single-pod and
``("pod", "data", "model")`` multi-pod; the pod axis extends data
parallelism across pods (gradient all-reduce crosses the DCI/ICI boundary
once per step).

Default rule set:

* ``embed``/``ff``/``heads``/``vocab``   -> tensor parallel over ``model``
* ``layers``/norm scales                 -> replicated
* ``batch``                             -> data parallel over ``(pod, data)``
* ``expert``                            -> expert parallel over ``model`` when
  the expert count divides the model axis; otherwise experts replicate and
  ``ff_expert`` takes the model axis (TP inside experts) — see
  DESIGN.md §Arch-applicability.
* optional FSDP: parameters additionally shard their ``embed``/``ff`` (dim0)
  axis over ``data`` (zero-3 style), controlled per run.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Pytree = Any


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Logical axis -> mesh axis (or None=replicate, or tuple of mesh axes)."""

    table: Tuple[Tuple[str, Any], ...]

    def get(self, logical: Optional[str]):
        if logical is None:
            return None
        for name, mesh_ax in self.table:
            if name == logical:
                return mesh_ax
        return None

    def spec(self, axes: Optional[Tuple[Optional[str], ...]]) -> P:
        if axes is None:
            return P()
        return P(*(self.get(a) for a in axes))


def default_rules(
    mesh: Mesh,
    *,
    n_experts: int = 0,
    fsdp: bool = False,
    sequence_parallel: bool = False,
) -> ShardingRules:
    axes = mesh.axis_names
    model_ax = "model" if "model" in axes else None
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    dp: Any = data_axes if len(data_axes) > 1 else (data_axes[0] if data_axes else None)
    model_size = mesh.shape.get("model", 1) if model_ax else 1

    expert_ax: Any = None
    ff_expert_ax: Any = model_ax
    if n_experts and model_ax and n_experts % model_size == 0:
        expert_ax, ff_expert_ax = model_ax, None  # clean EP

    table = [
        # parameters
        ("vocab", model_ax),
        ("embed", dp if fsdp else None),
        ("embed_tbl", None),  # vocab matrices: never FSDP the D dim
        ("embed2", None),
        ("heads", model_ax),
        ("ff", model_ax),
        ("expert", expert_ax),
        ("ff_expert", ff_expert_ax),
        ("expert_dim", None),
        ("layers", None),
        # activations
        ("batch", dp),
        ("seq", model_ax if sequence_parallel else None),
        ("kv_seq", None),
        ("head_dim", None),
        ("act_embed", None),
    ]
    return ShardingRules(table=tuple(table))


def _axis_size(mesh: Mesh, mesh_ax) -> int:
    if mesh_ax is None:
        return 1
    if isinstance(mesh_ax, tuple):
        return int(np.prod([mesh.shape[a] for a in mesh_ax]))
    return mesh.shape[mesh_ax]


def logical_to_sharding(
    axes_tree: Pytree, mesh: Mesh, rules: ShardingRules, like: Optional[Pytree] = None
) -> Pytree:
    """Map a logical-axes pytree (tuples are leaves) to NamedShardings.

    When ``like`` (a matching pytree of arrays/ShapeDtypeStructs) is given,
    any dimension not divisible by its assigned mesh axes is replicated
    instead — e.g. whisper's vocab 51866 and mamba2's 50280 do not divide
    the 16-way model axis, so their embedding tables replicate (explicit
    pjit shardings require exact divisibility)."""

    is_axes_leaf = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )

    def to_sharding(axes, leaf=None):
        mesh_axes = [rules.get(a) for a in axes]
        if leaf is not None:
            shape = leaf.shape
            mesh_axes = [
                ax if ax is None or d % _axis_size(mesh, ax) == 0 else None
                for d, ax in zip(shape, mesh_axes)
            ]
        return NamedSharding(mesh, P(*mesh_axes))

    if like is None:
        return jax.tree.map(to_sharding, axes_tree, is_leaf=is_axes_leaf)
    return jax.tree.map(to_sharding, axes_tree, like, is_leaf=is_axes_leaf)


def batch_specs(mesh: Mesh, batch_shapes: Dict[str, Tuple[int, ...]], rules: ShardingRules) -> Dict[str, NamedSharding]:
    """Shardings for a model input batch: dim0 = batch (data parallel)."""
    out = {}
    for name, shape in batch_shapes.items():
        spec = [rules.get("batch")] + [None] * (len(shape) - 1)
        out[name] = NamedSharding(mesh, P(*spec))
    return out


def check_divisibility(cfg, mesh: Mesh, global_batch: int) -> list[str]:
    """Static validation that a (config x mesh x batch) cell is shardable.

    Returns a list of human-readable problems (empty = OK).  Called by the
    dry-run before lowering so failures are diagnosed, not debugged from
    XLA errors.
    """
    problems = []
    model = mesh.shape.get("model", 1)
    data = int(np.prod([mesh.shape.get(a, 1) for a in ("pod", "data")]))
    if global_batch % data and global_batch >= data:
        problems.append(f"global_batch {global_batch} % data {data} != 0")
    if cfg.n_heads % model and cfg.n_heads >= model:
        problems.append(f"n_heads {cfg.n_heads} % model {model} != 0")
    return problems
